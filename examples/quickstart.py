"""Quickstart: TC-MIS end-to-end on one graph, in ~20 lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (
    TCMISConfig, build_block_tiles, cardinality, ecl_mis, is_valid_mis,
    luby_mis, tc_mis,
)
from repro.graphs.generators import GRAPH_SUITE


def main() -> None:
    # a reduced-scale stand-in for the paper's G3 (delaunay_n19)
    g = GRAPH_SUITE["G3"].make(8192, 0)
    print(f"graph: |V|={g.n_nodes:,} half-edges={g.n_edges:,}")

    # 1. tile the adjacency matrix (the paper's §3.2 representation)
    tiled = build_block_tiles(g, tile_size=64)
    print(f"BSR: {tiled.n_tiles:,} tiles of {tiled.tile_size}×{tiled.tile_size}")

    # 2. run all three algorithms
    key = jax.random.key(0)
    for name, res in [
        ("luby  ", luby_mis(g, key)),
        ("ecl   ", ecl_mis(g, key)),
        ("tc-mis", tc_mis(g, tiled, key, TCMISConfig(heuristic="h3"))),
    ]:
        assert is_valid_mis(g, res.in_mis)
        print(f"{name}: |MIS|={cardinality(res.in_mis):,} "
              f"rounds={int(res.rounds)} valid=True")


if __name__ == "__main__":
    main()

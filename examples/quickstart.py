"""Quickstart: TC-MIS end-to-end on one graph, in ~20 lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (
    TCMISConfig, build_block_tiles, cardinality, ecl_mis, engine_names,
    is_valid_mis, luby_mis, tc_mis,
)
from repro.graphs.generators import GRAPH_SUITE


def main() -> None:
    # a reduced-scale stand-in for the paper's G3 (delaunay_n19)
    g = GRAPH_SUITE["G3"].make(8192, 0)
    print(f"graph: |V|={g.n_nodes:,} half-edges={g.n_edges:,}")

    # 1. tile the adjacency matrix (the paper's §3.2 representation)
    tiled = build_block_tiles(g, tile_size=64)
    print(f"BSR: {tiled.n_tiles:,} tiles of {tiled.tile_size}×{tiled.tile_size}")

    # 2. baselines on the edge list
    key = jax.random.key(0)
    for name, res in [("luby", luby_mis(g, key)), ("ecl ", ecl_mis(g, key))]:
        assert is_valid_mis(g, res.in_mis)
        print(f"{name}  : |MIS|={cardinality(res.in_mis):,} "
              f"rounds={int(res.rounds)} valid=True")

    # 3. TC-MIS on the oracle engine at full example scale
    res = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3"))
    assert is_valid_mis(g, res.in_mis)
    print(f"tc-mis: |MIS|={cardinality(res.in_mis):,} "
          f"rounds={int(res.rounds)} valid=True")

    # 4. the registry contract, one engine per line: same priorities ⇒ the
    #    identical set from every backend.  (Smaller graph: the Pallas
    #    engines run interpret-mode on CPU — python per grid step.)
    g_s = GRAPH_SUITE["G3"].make(1024, 0)
    tiled_s = build_block_tiles(g_s, tile_size=32)
    ref = None
    for backend in engine_names():
        r = tc_mis(g_s, tiled_s, key, TCMISConfig(heuristic="h3", backend=backend))
        assert is_valid_mis(g_s, r.in_mis)
        ref = r.in_mis if ref is None else ref
        assert bool(jax.numpy.all(r.in_mis == ref)), backend
        print(f"tc-mis[{backend:12s}]: |MIS|={cardinality(r.in_mis):,} "
              f"rounds={int(r.rounds)} valid=True")


if __name__ == "__main__":
    main()

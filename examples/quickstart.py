"""Quickstart: TC-MIS end-to-end on one graph, in ~20 lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.api import PlanCache, Solver, SolveOptions
from repro.core import cardinality, ecl_mis, engine_names, is_valid_mis, luby_mis
from repro.graphs.generators import GRAPH_SUITE


def main() -> None:
    # a reduced-scale stand-in for the paper's G3 (delaunay_n19)
    g = GRAPH_SUITE["G3"].make(8192, 0)
    print(f"graph: |V|={g.n_nodes:,} half-edges={g.n_edges:,}")

    # 1. baselines on the edge list
    key = jax.random.key(0)
    for name, res in [("luby", luby_mis(g, key)), ("ecl ", ecl_mis(g, key))]:
        assert is_valid_mis(g, res.in_mis)
        print(f"{name}  : |MIS|={cardinality(res.in_mis):,} "
              f"rounds={int(res.rounds)} valid=True")

    # 2. TC-MIS through the front door: the Solver plans (BSR tiling, the
    #    paper's §3.2 representation), routes, and runs to convergence
    solver = Solver(SolveOptions(heuristic="h3", engine="tiled_ref", tile_size=64))
    plan = solver.plan(g)
    print(f"BSR: {plan.tiled.n_tiles:,} tiles of {plan.tile_size}×{plan.tile_size}"
          f" (routing: {solver.route(plan)})")
    res = solver.solve(plan)
    assert is_valid_mis(g, jax.numpy.asarray(res.in_mis))
    print(f"tc-mis: |MIS|={res.mis_size:,} rounds={res.rounds} valid=True")

    # 3. the registry contract, one engine per line: same priorities ⇒ the
    #    identical set from every backend.  (Smaller graph: the Pallas
    #    engines run interpret-mode on CPU — python per grid step.)
    g_s = GRAPH_SUITE["G3"].make(1024, 0)
    plans = PlanCache(tile_size=32)   # shared plan cache: ONE tiling, 4 engines
    ref = None
    for backend in engine_names():
        r = Solver(SolveOptions(heuristic="h3", engine=backend, tile_size=32),
                   plans=plans).solve(g_s)
        assert is_valid_mis(g_s, jax.numpy.asarray(r.in_mis))
        ref = r.in_mis if ref is None else ref
        assert bool(np.all(r.in_mis == ref)), backend
        print(f"tc-mis[{backend:12s}]: |MIS|={r.mis_size:,} "
              f"rounds={r.rounds} valid=True")


if __name__ == "__main__":
    main()

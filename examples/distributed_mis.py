"""Multi-chip TC-MIS: row-partitioned BSR + bit-packed frontier gathers,
verified bit-identical to the single-device run.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_mis.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.dist.compat import install as _install_jax_compat

_install_jax_compat()   # modern sharding API on 0.4.x jax too

from repro.core import (
    DistConfig, TCMISConfig, build_block_tiles, build_distributed_mis,
    cardinality, is_valid_mis, make_priorities, shard_tiled, tc_mis,
)
from repro.graphs.generators import GRAPH_SUITE


def main() -> None:
    n_dev = len(jax.devices())
    mesh = jax.make_mesh(
        (2, n_dev // 2), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    g = GRAPH_SUITE["G5"].make(10_000, 0)  # web-Google stand-in
    tiled = build_block_tiles(g, tile_size=64)
    sharded = shard_tiled(tiled, n_shards=n_dev)
    print(f"|V|={g.n_nodes:,}; {tiled.n_tiles:,} tiles -> "
          f"{sharded.tiles.shape[1]:,}/shard × {n_dev} shards")

    key = jax.random.key(0)
    pri = make_priorities("h3", key, g.n_nodes, g.degrees())
    run = build_distributed_mis(sharded, mesh, DistConfig(bitpack=True))
    res = run(pri)
    in_mis = res.in_mis[: g.n_nodes]
    print(f"distributed: |MIS|={cardinality(in_mis):,} rounds={int(res.rounds)}"
          f" valid={is_valid_mis(g, in_mis)}")

    single = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3"))
    print("matches single-device bit-for-bit:",
          bool(jnp.all(in_mis == single.in_mis)))


if __name__ == "__main__":
    main()

"""Multi-chip TC-MIS: row-partitioned BSR + bit-packed frontier gathers,
verified bit-identical to the single-device run — both reached through the
same `Solver` front door (`placement` is the only thing that changes).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_mis.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.dist.compat import install as _install_jax_compat

_install_jax_compat()   # modern sharding API on 0.4.x jax too

from repro.api import PlanCache, Solver, SolveOptions
from repro.core import is_valid_mis
from repro.graphs.generators import GRAPH_SUITE


def main() -> None:
    n_dev = len(jax.devices())
    g = GRAPH_SUITE["G5"].make(10_000, 0)  # web-Google stand-in

    plans = PlanCache(tile_size=64)        # one BSR build, both placements
    sharded = Solver(SolveOptions(heuristic="h3", tile_size=64,
                                  placement="sharded", bitpack=True),
                     plans=plans)
    plan = sharded.plan(g)
    print(f"|V|={g.n_nodes:,}; {plan.tiled.n_tiles:,} tiles over {n_dev} shards "
          f"(routing: {sharded.route(plan)})")

    res = sharded.solve(plan)
    print(f"distributed: |MIS|={res.mis_size:,} rounds={res.rounds}"
          f" valid={is_valid_mis(g, jax.numpy.asarray(res.in_mis))}"
          f" shards={res.stats['n_shards']}")

    local = Solver(SolveOptions(heuristic="h3", engine="tiled_ref",
                                tile_size=64, placement="local"),
                   plans=plans).solve(plan)
    print("matches single-device bit-for-bit:",
          bool(np.all(res.in_mis == local.in_mis)))


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a small LM for a few hundred steps with
the fault-tolerant loop (checkpoint/restart exercised mid-run).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.configs.common import make_lm_train_step
from repro.data.pipeline import TokenStream, prefetch
from repro.launch.train import small_variant
from repro.models import transformer as tf
from repro.train import LoopConfig, OptConfig, TrainLoop, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    cfg = small_variant(REGISTRY[args.arch].config)
    params = tf.init_lm(jax.random.key(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} (reduced): {n/1e6:.1f}M params")

    raw = jax.jit(make_lm_train_step(
        cfg, OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)))

    def step_fn(state, batch):
        p, o = state
        tokens, targets = batch
        p, o, loss, xent = raw(p, o, jnp.asarray(tokens), jnp.asarray(targets))
        return (p, o), {"loss": loss, "xent": xent}

    loop = TrainLoop(
        step_fn=step_fn,
        init_state=(params, adamw_init(params)),
        stream=TokenStream(cfg.vocab, batch=8, seq=128, seed=11),
        cfg=LoopConfig(ckpt_dir=args.ckpt, checkpoint_every=50),
    )
    print(f"resuming from step {loop.start_step}" if loop.start_step
          else "fresh run")
    result = loop.run(args.steps)
    print(f"final: {result['metrics']}  "
          f"(uniform={float(np.log(cfg.vocab)):.3f} nats)")
    print(f"stragglers={result['stragglers']} recoveries={result['recoveries']}")


if __name__ == "__main__":
    main()

"""Dynamic MIS in ~30 lines: a mutating graph, repaired — not re-solved.

    PYTHONPATH=src python examples/dynamic_mis.py

Ingests a graph, then applies a stream of edge deltas.  Each delta patches
the cached plan tile-locally (`Plan.apply_delta`) and repairs the prior
solution by warm-starting the round engine on just the dirty frontier
(`Solver.update`, DESIGN.md §12) — compare the repair round counts against
what a cold re-solve of the same mutated graph needs.
"""
import jax.numpy as jnp

from repro.api import Solver, SolveOptions
from repro.core.validate import is_valid_mis_jit
from repro.dyngraph import random_delta
from repro.graphs.generators import erdos_renyi


def main() -> None:
    # 1. ingest and cold-solve the initial graph
    g = erdos_renyi(600, avg_deg=6.0, seed=0)
    solver = Solver(SolveOptions(
        engine="tiled_ref", tile_size=16, repair="incremental",
    ))
    result = solver.solve(g)
    print(f"initial: |V|={g.n_nodes} |E|={g.n_edges // 2} "
          f"|MIS|={result.mis_size} rounds={result.rounds}")

    # 2. a stream of deltas: each patches the plan and repairs the solution
    for step in range(1, 6):
        delta = random_delta(result.plan.g, n_add=6, n_remove=6, seed=step)
        result = solver.update(result, delta)          # incremental repair
        cold = solver.solve(result.plan)               # the counterfactual
        ok = all(is_valid_mis_jit(result.plan.g, jnp.asarray(result.in_mis_plan)))
        assert ok, "repaired solution failed the MIS invariants"
        print(f"delta {step}: +{delta.n_add}/-{delta.n_remove} edges "
              f"(epoch {result.plan.epoch})  repair rounds={result.rounds}  "
              f"cold rounds={cold.rounds}  |MIS|={result.mis_size} "
              f"(cold {cold.mis_size})  valid={ok}")

    # 3. the plan cache followed the lineage: one live entry, stale epochs
    #    evicted — and every repair reused the first compiled repair program
    #    shape permitting (see `compile:` in result.stats)
    print(f"plan cache: {solver.plans.stats}")


if __name__ == "__main__":
    main()

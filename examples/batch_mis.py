"""Batched MIS serving in ~30 lines: many graphs, ONE engine dispatch.

    PYTHONPATH=src python examples/batch_mis.py
"""
import jax
import numpy as np

from repro.core import TCMISConfig, cardinality, is_valid_mis, tc_mis
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw
from repro.serve_mis import PlanCache, pack_batch, request_key


def main() -> None:
    # 1. a heterogeneous batch of small graphs (the serving workload)
    graphs = [grid2d(8, 8), powerlaw(80, seed=1), erdos_renyi(50, seed=2),
              grid2d(4, 12), erdos_renyi(30, avg_deg=3.0, seed=3),
              powerlaw(64, seed=4), erdos_renyi(96, seed=5), grid2d(6, 6)]

    # 2. plan each once (content-hashed cache: repeats would be free)
    cache = PlanCache(tile_size=16)
    plans = [cache.plan(g)[0] for g in graphs]

    # 3. block-diagonal packing: per-graph priorities, tile-aligned slots
    base = jax.random.key(0)
    keys = [request_key(base, p) for p in plans]
    batch = pack_batch(plans, keys, "h3")
    print(f"packed {batch.n_graphs} graphs -> {batch.g.n_nodes} vertices, "
          f"{batch.tiled.n_tiles} tiles, bucket {batch.signature()}")

    # 4. ONE tc_mis dispatch solves the whole batch
    cfg = TCMISConfig(heuristic="h3", backend="tiled_ref")
    res = tc_mis(batch.g, batch.tiled, base, cfg, priorities=batch.priorities,
                 alive0=batch.alive0, col_gate=batch.col_gate)

    # 5. per-graph results are bit-identical to solo runs of each member
    for i, (plan, key, mis) in enumerate(zip(plans, keys, batch.unpack(res.in_mis))):
        solo = tc_mis(plan.g, plan.tiled, key, cfg)
        assert is_valid_mis(plan.g, jax.numpy.asarray(mis))
        assert bool(np.all(mis == np.asarray(solo.in_mis)))
        print(f"graph {i}: |V|={plan.n_nodes:3d} |MIS|={cardinality(jax.numpy.asarray(mis)):3d} "
              f"valid=True matches_solo=True")


if __name__ == "__main__":
    main()

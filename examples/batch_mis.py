"""Batched MIS serving in ~25 lines: many graphs, ONE engine dispatch.

    PYTHONPATH=src python examples/batch_mis.py
"""
import numpy as np

from repro.api import Solver, SolveOptions
from repro.core import is_valid_mis
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw


def main() -> None:
    # 1. a heterogeneous batch of small graphs (the serving workload)
    graphs = [grid2d(8, 8), powerlaw(80, seed=1), erdos_renyi(50, seed=2),
              grid2d(4, 12), erdos_renyi(30, avg_deg=3.0, seed=3),
              powerlaw(64, seed=4), erdos_renyi(96, seed=5), grid2d(6, 6)]

    # 2. ONE dispatch solves the whole batch: the Solver plans each graph
    #    once (content-hashed cache — repeats would be free), packs them
    #    block-diagonally with per-graph priorities, and routes the bucket
    solver = Solver(SolveOptions(heuristic="h3", engine="tiled_ref", tile_size=16))
    results = solver.solve_many(graphs)
    first = results[0].stats
    print(f"packed {len(graphs)} graphs -> bucket {first['bucket']} "
          f"({first['compile']}, one dispatch)")

    # 3. per-graph results are bit-identical to solo runs of each member —
    #    members are solved under content-derived keys, so a solo solve
    #    under the same key reproduces the member exactly
    import jax.numpy as jnp
    for i, (g, res) in enumerate(zip(graphs, results)):
        solo = solver.solve(res.plan, key=solver.request_key(res.plan))
        assert is_valid_mis(g, jnp.asarray(res.in_mis))
        assert bool(np.all(res.in_mis == solo.in_mis))
        assert res.rounds == solo.rounds   # per-MEMBER round counter
        print(f"graph {i}: |V|={res.plan.n_nodes:3d} |MIS|={res.mis_size:3d} "
              f"rounds={res.rounds} valid=True matches_solo=True")


if __name__ == "__main__":
    main()

"""Batched serving example: prefill + KV-cache decode (ring buffer for SWA,
latent cache for MLA).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b --gen 24
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.exit(serve.main())

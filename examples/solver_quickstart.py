"""The front door in one screen: `Plan` / `SolveOptions` / `Solver`.

Every MIS execution path of this repo — single graphs, batched serving
workloads, profiled engine runs, and (on multi-device hosts) the sharded
path — is reached through the same three nouns (DESIGN.md §10).

    PYTHONPATH=src python examples/solver_quickstart.py
"""
import numpy as np

from repro.api import Plan, Solver, SolveOptions, choose_tile_size
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw


def main() -> None:
    g = erdos_renyi(600, avg_deg=6.0, seed=0)

    # -- one graph, default options (auto tile size, auto placement) -------
    solver = Solver(SolveOptions(engine="tiled_ref"))   # jnp oracle: CPU-honest
    res = solver.solve(g)
    print(f"solve:       |V|={g.n_nodes} -> |MIS|={res.mis_size} "
          f"rounds={res.rounds} placement={res.placement} "
          f"T={res.plan.tile_size} (auto-T policy: "
          f"{choose_tile_size(g.n_nodes, g.n_edges)})")

    # -- a serving-style workload: ONE dispatch for the whole batch --------
    batch = [grid2d(6, 6), powerlaw(48, seed=1), erdos_renyi(64, seed=2),
             erdos_renyi(24, avg_deg=3.0, seed=3)]
    many = Solver(SolveOptions(engine="tiled_ref", tile_size=16))
    results = many.solve_many(batch)
    print(f"solve_many:  {len(results)} graphs, bucket "
          f"{results[0].stats['bucket']}, per-member rounds "
          f"{[r.rounds for r in results]}")
    assert many.solve_many([]) == []            # no bucket for nothing
    assert many.solve_many([batch[0]])[0].placement == "local"  # or a singleton

    # -- plans are immutable, content-addressed artifacts ------------------
    plan = Plan.build(g, tile_size=32)
    again = many.solve(plan)                     # a Plan routes like a Graph
    print(f"Plan.build:  key={plan.key[:12]}… T={plan.tile_size} "
          f"tiles={plan.tiled.n_tiles} |MIS|={again.mis_size}")

    # -- the profiler twin returns the SAME set with per-phase timers ------
    prof, times = solver.profile(g)
    assert bool(np.all(prof.in_mis == res.in_mis))
    share = {k: round(1e3 * times[k], 2) for k in ("phase1", "phase2", "phase3")}
    print(f"profile:     bit-identical to solve; ms/phase={share} "
          f"rounds={times['rounds']}")


if __name__ == "__main__":
    main()

"""Operator's-eye view of a running MIS service (DESIGN.md §17).

    PYTHONPATH=src python examples/health_dashboard.py

Pushes synthetic traffic through `MISService` — a heterogeneous solve wave
followed by a chained delta stream against one served graph — then prints
what an operator would scrape:

  1. SLO quantiles   p50/p95/p99 per op (solve / update / batched) and per
                     span-taxonomy stage, from the fixed-bucket histograms
                     the service fills in `step()`
  2. drift trend     per-epoch touched tiles, dirty fraction and the
                     tile-locality-decay gauge recorded by `patch_plan`
  3. roofline        predicted vs measured per-round cost (model error %) —
                     large on CPU by design; the TREND is the signal
  4. promtext        the full merged snapshot in Prometheus text format,
                     exactly what `--metrics-path` exports for a textfile
                     collector

Everything here reads eager-side instruments only: the jitted hot path is
untouched (§14 zero-cost contract).
"""
from __future__ import annotations

import os
import tempfile

from repro.dyngraph import random_delta
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw
from repro.obs import to_promtext
from repro.serve_mis import MISService, ServeConfig


def _quantiles(snap: dict, name: str) -> str:
    h = snap.get(name)
    if not isinstance(h, dict) or not h.get("count"):
        return "(no samples)"
    return (f"n={h['count']:<3d} p50={h['p50']:>8.3f}ms "
            f"p95={h['p95']:>8.3f}ms p99={h['p99']:>8.3f}ms "
            f"max={h['max']:>8.3f}ms")


def main() -> None:
    # a trace sink turns on the span taxonomy — without one, steps run the
    # untraced dispatch path and the per-stage histograms stay empty
    trace_path = os.path.join(tempfile.mkdtemp(prefix="mis-health-"),
                              "trace.jsonl")
    service = MISService(ServeConfig(
        tile_size=16, engine="tiled_ref", max_batch=4,
        repair="incremental", telemetry=True, trace_path=trace_path,
    ))

    # -- 1. a solve wave: heterogeneous graphs, some batched together -------
    graphs = [
        erdos_renyi(400, avg_deg=6.0, seed=1),
        powerlaw(400, avg_deg=4.0, seed=2),
        grid2d(20, 20, seed=3),
        erdos_renyi(400, avg_deg=6.0, seed=4),
        erdos_renyi(200, avg_deg=3.0, seed=5),
    ]
    for g in graphs:
        service.submit(g)
    responses = service.drain()
    assert all(r.valid for r in responses), "solve wave produced invalid MIS"
    target = responses[0].id     # the graph the delta stream will mutate

    # -- 2. a chained delta stream: each update targets the previous one ---
    print("== drift trend (chained delta stream) ==")
    print(f"{'epoch':>5} {'touched_frac':>12} {'dirty_frac':>10} "
          f"{'occupancy':>9} {'locality_decay':>14}")
    for step in range(1, 6):
        plan = service._results[target].plan
        delta = random_delta(plan.g, n_add=8, n_remove=8, seed=step)
        target = service.submit_update(target, delta)
        (resp,) = service.drain()
        assert resp.valid, f"repair failed at delta {step}"
        snap = service.metrics_snapshot()
        print(f"{snap.get('dyngraph.epoch', 0):>5} "
              f"{snap.get('dyngraph.touched_frac', 0.0):>12.4f} "
              f"{snap.get('dyngraph.dirty_frac', 0.0):>10.4f} "
              f"{snap.get('dyngraph.occupancy', 0.0):>9.5f} "
              f"{snap.get('dyngraph.locality_decay', 0.0):>14.4f}")

    snap = service.metrics_snapshot()

    # -- 3. SLO quantiles per op and per span stage -------------------------
    print("\n== SLO latency quantiles (fixed-bucket histograms) ==")
    for op in ("solve", "batched", "update"):
        print(f"  {op:<8} {_quantiles(snap, f'service.latency_ms.{op}')}")
    print("  span stages:")
    for name in sorted(snap):
        if name.startswith("service.span_ms."):
            stage = name[len("service.span_ms."):]
            print(f"    {stage:<18} {_quantiles(snap, name)}")

    # -- 4. roofline attribution (predicted vs measured per-round cost) ----
    print("\n== roofline attribution (last solve) ==")
    print(f"  predicted={snap.get('perf.roofline_predicted_us', 0.0):.1f}us "
          f"measured={snap.get('perf.roofline_measured_us', 0.0):.1f}us "
          f"error={snap.get('perf.roofline_error_pct', 0.0):+.1f}%  "
          f"(CPU error is large by design — trend, not level)")

    # -- 5. the scrape surface ---------------------------------------------
    print("\n== promtext snapshot (what --metrics-path exports) ==")
    print(to_promtext(snap), end="")


if __name__ == "__main__":
    main()

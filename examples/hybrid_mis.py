"""Hybrid tile routing in ~40 lines: classify, partition, solve faster.

    PYTHONPATH=src python examples/hybrid_mis.py

Skewed (power-law) graphs tile badly: a few hub block-rows pack thousands
of edges per tile while the long tail stores a handful.  The hybrid plan
(DESIGN.md §16) classifies every stored tile by nnz against a roofline
break-even threshold, routes the dense survivors through the tensor-core
tile path, and streams the sparse tail as COO through segment ops — same
solution, bit for bit, less superfluous tile work.
"""

import numpy as np

from repro.api import Solver, SolveOptions
from repro.graphs.generators import powerlaw


def _solve_ms(solver: Solver, g, iters: int = 3) -> float:
    solver.solve(g)                      # warm: plan + compile off the clock
    return min(float(solver.solve(g).stats["solve_ms"]) for _ in range(iters))


def main() -> None:
    g = powerlaw(4096, avg_deg=16.0, seed=0)
    print(f"graph: |V|={g.n_nodes} |E|={g.n_edges // 2} (power-law)")

    # 1. the hybrid plan: same graph, per-tile dense/sparse classification.
    #    On CPU the analytic roofline threshold routes everything sparse;
    #    the explicit override keeps the hub tiles on the tile path so the
    #    split is visible (on TPU, leave hybrid_threshold=None).
    hybrid = Solver(SolveOptions(engine="tiled_ref", tile_size=64,
                                 hybrid="forced", hybrid_threshold=32))
    plan = hybrid.plan(g)
    part = plan.tiled.partition
    total = part.n_dense_tiles + part.n_sparse_tiles
    print(f"partition @ nnz>={part.threshold}: "
          f"{part.n_dense_tiles} dense tiles ({part.n_dense_tiles / total:.0%}) "
          f"+ {part.n_sparse_tiles} sparse tiles "
          f"({part.sp_nnz} COO edges) of {total} stored")

    # 2. solve both routings — the solutions must be bit-identical
    dense = Solver(SolveOptions(engine="tiled_ref", tile_size=64,
                                hybrid="off"))
    hy_ms = _solve_ms(hybrid, g)
    de_ms = _solve_ms(dense, g)
    r_h, r_d = hybrid.solve(g), dense.solve(g)
    assert (np.asarray(r_h.in_mis) == np.asarray(r_d.in_mis)).all(), (
        "routing changed the solution"
    )
    print(f"|MIS|={r_h.mis_size} rounds={r_h.rounds} (both routings)")
    print(f"hybrid {hy_ms:.1f} ms  vs  dense {de_ms:.1f} ms  "
          f"-> {de_ms / max(hy_ms, 1e-9):.2f}x")

    # 3. per-round routing telemetry: how many tiles each path carried
    tsolver = Solver(SolveOptions(engine="tiled_ref", tile_size=64,
                                  hybrid="forced", hybrid_threshold=32,
                                  telemetry=True))
    rt = tsolver.solve(g).telemetry
    for r in range(rt.rounds):
        print(f"  round {r}: alive={rt.alive[r]:5d}  "
              f"tiles routed dense={rt.tiles_dense[r]:4d} "
              f"sparse={rt.tiles_sparse[r]:4d}")


if __name__ == "__main__":
    main()

"""Reproduce the paper's Fig. 3 quality study on one graph: H1 vs H2 vs H3
vs ECL-MIS cardinality, plus the Pallas-kernel backend equivalence check.

    PYTHONPATH=src python examples/mis_heuristics.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    TCMISConfig, build_block_tiles, cardinality, ecl_mis, is_valid_mis, tc_mis,
)
from repro.graphs.generators import powerlaw


def main() -> None:
    # hub-heavy graph (wiki-Talk-like) — where heuristics matter most
    g = powerlaw(20_000, avg_deg=4.0, seed=0)
    tiled = build_block_tiles(g, tile_size=64)
    key = jax.random.key(0)

    base = cardinality(ecl_mis(g, key).in_mis)
    print(f"ECL-MIS baseline: |MIS| = {base:,}")
    for h in ("h1", "h2", "h3"):
        res = tc_mis(g, tiled, key, TCMISConfig(heuristic=h))
        c = cardinality(res.in_mis)
        print(f"TC-MIS {h}: |MIS| = {c:,}  ({100*(c-base)/base:+.2f}% vs ECL)"
              f"  rounds={int(res.rounds)} valid={is_valid_mis(g, res.in_mis)}")

    # the Pallas kernel path must agree bit-for-bit with the jnp oracle
    r_ref = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3", backend="ref",
                                              phase1="tiled"))
    r_pal = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3", backend="pallas",
                                              phase1="tiled"))
    print("pallas == oracle:", bool(jnp.all(r_ref.in_mis == r_pal.in_mis)))


if __name__ == "__main__":
    main()

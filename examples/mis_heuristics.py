"""Reproduce the paper's Fig. 3 quality study on one graph: H1 vs H2 vs H3
vs ECL-MIS cardinality, plus the Pallas-kernel backend equivalence check.

    PYTHONPATH=src python examples/mis_heuristics.py
"""
import dataclasses

import jax
import numpy as np

from repro.api import PlanCache, Solver, SolveOptions
from repro.core import cardinality, ecl_mis, is_valid_mis
from repro.graphs.generators import powerlaw


def main() -> None:
    # hub-heavy graph (wiki-Talk-like) — where heuristics matter most
    g = powerlaw(20_000, avg_deg=4.0, seed=0)
    plans = PlanCache(tile_size=64)   # one BSR build serves every solver below
    key = jax.random.key(0)

    base = cardinality(ecl_mis(g, key).in_mis)
    print(f"ECL-MIS baseline: |MIS| = {base:,}")
    for h in ("h1", "h2", "h3"):
        res = Solver(SolveOptions(heuristic=h, engine="tiled_ref", tile_size=64),
                     plans=plans).solve(g)
        c = res.mis_size
        print(f"TC-MIS {h}: |MIS| = {c:,}  ({100*(c-base)/base:+.2f}% vs ECL)"
              f"  rounds={res.rounds} "
              f"valid={is_valid_mis(g, jax.numpy.asarray(res.in_mis))}")

    # the Pallas kernel path must agree bit-for-bit with the jnp oracle
    # (smaller graph: off-TPU the kernel interprets python per grid step)
    g_s = powerlaw(2_000, avg_deg=4.0, seed=0)
    opts = SolveOptions(heuristic="h3", phase1="tiled", tile_size=32)
    r_ref = Solver(dataclasses.replace(opts, engine="tiled_ref"), plans=plans).solve(g_s)
    r_pal = Solver(dataclasses.replace(opts, engine="tiled_pallas"), plans=plans).solve(g_s)
    print("pallas == oracle:", bool(np.all(r_ref.in_mis == r_pal.in_mis)))


if __name__ == "__main__":
    main()

"""CI source guards that a grep can't express precisely (DESIGN.md §11–§13).

Guard 1 — packed tiles must stay packed until VMEM: in the kernel modules
(`src/repro/kernels/`, excluding the oracle `ref.py`), `unpack_tile_bits` /
`unpack_tile_mask` may only be CALLED inside Pallas kernel-body functions
(names ending in `_kernel`).  An unpack anywhere else — e.g. in `ops.py`
before the `pallas_call` — would materialise the dense (nt, T, T) array in
HBM and forfeit the 8× DMA reduction the storage axis exists for.  The jnp
oracle paths (`kernels/ref.py`, `core/engine.py`) are the sanctioned
exceptions.

Guard 2 — kernel modules must not densify via the whole-array helpers
either: `dense_tiles` / `dense_tile_mask` (the oracle dispatches) and
`to_storage` (the format converter) never appear under `src/repro/kernels/`
outside `ref.py`.

Guard 3 — the dyngraph delta path edits packed tiles AS packed words
(word-level bit edits, DESIGN.md §12): under `src/repro/dyngraph/`, none of
`unpack_tile_bits` / `unpack_tile_mask` / `dense_tiles` / `dense_tile_mask`
/ `to_storage` may be called outside a function whose name ends in
`_oracle` (the sanctioned densify path for reference checks).  A densify in
`retile.py` would silently turn the O(delta) patch into an O(tiles)
unpack-repack; in `repair.py` it would materialise dense tiles the engines
never need.

Guard 4 — frontier words stay packed on the hot path (DESIGN.md §13): in
all of `src/repro/` EXCEPT the packing substrate (`core/tiling.py`, which
defines the contract and owns the word-level repacks) and the sanctioned
densifying reference (`kernels/ref.py`), `unpack_frontier_bits` /
`unpack_frontier_words` may only be called inside a `*_kernel` or
`*_oracle` body, or in one of the explicitly allowlisted seam functions:
`core/tc_mis.py::_result` (the run epilogue — the ONE unpack on the solve
path, after the convergence loop) and `core/distributed.py::gather_bool`
(the all-gather payload boundary — shard-local phases are dense ops).  Any
other densify would smuggle a (n_padded,) bool round-trip back into the
packed round body the bitwise mode exists to eliminate.

Guard 5 — the hot loop stays host-silent (DESIGN.md §14): under
`src/repro/core/` and `src/repro/kernels/`, no call to `io_callback` /
`pure_callback` / `debug_callback` / `debug.print`, and no reference to the
legacy `host_callback` module at all.  Observability of the round loop goes
through the on-device telemetry buffer (`repro.obs.rounds`) — ONE
device→host transfer at the epilogue — never through per-round host
round-trips, which would serialise the `lax.while_loop` on host sync and
quietly destroy the very timings the telemetry exists to measure.

Run: python tools/ci_guards.py   (exit 0 = clean)
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC_DIR = ROOT / "src/repro"
KERNEL_DIR = ROOT / "src/repro/kernels"
DYNGRAPH_DIR = ROOT / "src/repro/dyngraph"
ORACLE_FILES = {"ref.py"}          # the sanctioned full-unpack path
KERNEL_FN_SUFFIX = "_kernel"
ORACLE_FN_SUFFIX = "_oracle"

# tile densifies: bit-extraction to int8 (kernel-body only) vs whole-array
# oracle dispatches (never in kernel modules)
TILE_UNPACKS = ("unpack_tile_bits", "unpack_tile_mask")
TILE_DENSE_DISPATCH = ("dense_tiles", "dense_tile_mask")
DENSIFY_CALLS = TILE_UNPACKS + TILE_DENSE_DISPATCH

# host round-trips banned from the device-hot modules (Guard 5)
HOT_DIRS = ("core", "kernels")          # relative to src/repro
HOST_CALLBACK_CALLS = (
    "io_callback", "pure_callback", "debug_callback",
)
# `jax.debug.print(...)` parses as Attribute(attr='print') on a Name 'debug'
# or Attribute '...debug' receiver — catch the attr name + receiver check
HOST_PRINT_RECEIVERS = ("debug",)

# frontier densifies (Guard 4)
FRONTIER_UNPACKS = ("unpack_frontier_bits", "unpack_frontier_words")
# rel-path → allowed enclosing function names (sanctioned seams, see above)
FRONTIER_ALLOWLIST = {
    "core/tc_mis.py": {"_result"},
    "core/distributed.py": {"gather_bool"},
}
FRONTIER_EXCLUDED_FILES = {"core/tiling.py", "kernels/ref.py"}


def _call_name(node: ast.Call):
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _walk_calls(path: pathlib.Path):
    """Yield (call_name, lineno, enclosing_fn_stack) for every call."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []

    class Visitor(ast.NodeVisitor):
        def __init__(self):
            self.stack = []

        def _visit_fn(self, node):
            self.stack.append(node.name)
            self.generic_visit(node)
            self.stack.pop()

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def visit_Call(self, node):
            name = _call_name(node)
            if name:
                out.append((name, node.lineno, tuple(self.stack)))
            self.generic_visit(node)

    Visitor().visit(tree)
    return out


def kernel_violations(path: pathlib.Path) -> list:
    """Guards 1+2: unpack only inside *_kernel bodies; never densify."""
    out = []
    for name, lineno, stack in _walk_calls(path):
        if name in DENSIFY_CALLS:
            in_kernel_body = any(fn.endswith(KERNEL_FN_SUFFIX) for fn in stack)
            if name in TILE_DENSE_DISPATCH or not in_kernel_body:
                out.append(
                    f"{path}:{lineno}: {name} called "
                    f"outside a *{KERNEL_FN_SUFFIX} body (scope: "
                    f"{'.'.join(stack) or '<module>'}) — this "
                    f"materialises (nt, T, T) in HBM"
                )
        if name == "to_storage":
            out.append(
                f"{path}:{lineno}: to_storage() in a kernel module "
                f"— kernels must consume tiles as stored"
            )
    return out


def dyngraph_violations(path: pathlib.Path) -> list:
    """Guard 3: the delta path never densifies outside a *_oracle body."""
    out = []
    for name, lineno, stack in _walk_calls(path):
        if name in DENSIFY_CALLS + ("to_storage",):
            if any(fn.endswith(ORACLE_FN_SUFFIX) for fn in stack):
                continue
            out.append(
                f"{path}:{lineno}: {name} called outside a "
                f"*{ORACLE_FN_SUFFIX} body (scope: "
                f"{'.'.join(stack) or '<module>'}) — the delta path must "
                f"edit packed tiles as packed words, never densify"
            )
    return out


def frontier_violations(path: pathlib.Path) -> list:
    """Guard 4: frontier words densify only in kernels, oracles, or the
    allowlisted seams (run epilogue, gather payload boundary)."""
    rel = path.relative_to(SRC_DIR).as_posix()
    if rel in FRONTIER_EXCLUDED_FILES:
        return []
    allowed_fns = FRONTIER_ALLOWLIST.get(rel, set())
    out = []
    for name, lineno, stack in _walk_calls(path):
        if name not in FRONTIER_UNPACKS:
            continue
        if any(
            fn.endswith((KERNEL_FN_SUFFIX, ORACLE_FN_SUFFIX)) or fn in allowed_fns
            for fn in stack
        ):
            continue
        out.append(
            f"{path}:{lineno}: {name} called outside a *{KERNEL_FN_SUFFIX}/"
            f"*{ORACLE_FN_SUFFIX} body or an allowlisted seam (scope: "
            f"{'.'.join(stack) or '<module>'}) — frontier vectors stay "
            f"packed words on the hot path (DESIGN.md §13)"
        )
    return out


def host_silence_violations(path: pathlib.Path) -> list:
    """Guard 5: no host callbacks or debug prints in the device-hot modules.

    Catches the call forms (`io_callback(...)`, `jax.experimental
    .io_callback(...)`, `pure_callback`, `debug_callback`,
    `jax.debug.print(...)`) via the AST and the legacy `host_callback`
    module by name anywhere in the tree (imports included)."""
    src = path.read_text()
    out = []
    tree = ast.parse(src, filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name in HOST_CALLBACK_CALLS:
                out.append(
                    f"{path}:{node.lineno}: {name}() in a device-hot module "
                    f"— round-loop observability goes through the telemetry "
                    f"buffer (repro.obs.rounds), never host callbacks"
                )
            elif (
                name == "print"
                and isinstance(node.func, ast.Attribute)
                and (
                    (isinstance(node.func.value, ast.Name)
                     and node.func.value.id in HOST_PRINT_RECEIVERS)
                    or (isinstance(node.func.value, ast.Attribute)
                        and node.func.value.attr in HOST_PRINT_RECEIVERS)
                )
            ):
                out.append(
                    f"{path}:{node.lineno}: debug.print() in a device-hot "
                    f"module — it forces a host sync per round inside the "
                    f"while_loop"
                )
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            names = [a.name for a in node.names]
            module = getattr(node, "module", "") or ""
            if "host_callback" in module or any(
                "host_callback" in n for n in names
            ):
                out.append(
                    f"{path}:{node.lineno}: host_callback import in a "
                    f"device-hot module — the legacy host round-trip API is "
                    f"banned here"
                )
    return out


def main() -> int:
    problems = []
    for path in sorted(KERNEL_DIR.glob("*.py")):
        if path.name in ORACLE_FILES:
            continue
        problems += kernel_violations(path)
    n_kernel = len(problems)
    for path in sorted(DYNGRAPH_DIR.glob("*.py")):
        problems += dyngraph_violations(path)
    n_dyngraph = len(problems) - n_kernel
    for path in sorted(SRC_DIR.rglob("*.py")):
        problems += frontier_violations(path)
    n_frontier = len(problems) - n_kernel - n_dyngraph
    n_before_host = len(problems)
    for d in HOT_DIRS:
        for path in sorted((SRC_DIR / d).rglob("*.py")):
            problems += host_silence_violations(path)
    n_host = len(problems) - n_before_host
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        print(
            f"\n{len(problems)} guard violation(s) "
            f"({n_kernel} kernel, {n_dyngraph} dyngraph, {n_frontier} "
            f"frontier, {n_host} host-silence): HBM and the round loop must "
            f"only ever see packed words outside the oracle/int8/epilogue "
            f"paths, and the hot loop never talks to the host mid-round",
            file=sys.stderr,
        )
        return 1
    print(
        "ci_guards: kernel + dyngraph + frontier + host-silence "
        "guards clean"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

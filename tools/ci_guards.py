"""CI source guards that a grep can't express precisely (DESIGN.md §11).

Guard 1 — packed tiles must stay packed until VMEM: in the kernel modules
(`src/repro/kernels/`, excluding the oracle `ref.py`), `unpack_tile_bits`
may only be CALLED inside Pallas kernel-body functions (names ending in
`_kernel`).  An unpack anywhere else — e.g. in `ops.py` before the
`pallas_call` — would materialise the dense (nt, T, T) array in HBM and
forfeit the 8× DMA reduction the storage axis exists for.  The jnp oracle
paths (`kernels/ref.py`, `core/engine.py`) are the sanctioned exceptions.

Guard 2 — kernel modules must not densify via the whole-array helpers
either: `dense_tiles` (the oracle dispatch) and `to_storage` (the format
converter) never appear under `src/repro/kernels/` outside `ref.py`.

Run: python tools/ci_guards.py   (exit 0 = clean)
"""
from __future__ import annotations

import ast
import pathlib
import sys

KERNEL_DIR = pathlib.Path(__file__).resolve().parent.parent / "src/repro/kernels"
ORACLE_FILES = {"ref.py"}          # the sanctioned full-unpack path
KERNEL_FN_SUFFIX = "_kernel"


def _violations(path: pathlib.Path) -> list:
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []

    class Visitor(ast.NodeVisitor):
        def __init__(self):
            self.stack = []

        def _visit_fn(self, node):
            self.stack.append(node.name)
            self.generic_visit(node)
            self.stack.pop()

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def visit_Call(self, node):
            name = None
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            if name in ("unpack_tile_bits", "dense_tiles"):
                in_kernel_body = any(
                    fn.endswith(KERNEL_FN_SUFFIX) for fn in self.stack
                )
                if name == "dense_tiles" or not in_kernel_body:
                    out.append(
                        f"{path}:{node.lineno}: {name} called "
                        f"outside a *{KERNEL_FN_SUFFIX} body (scope: "
                        f"{'.'.join(self.stack) or '<module>'}) — this "
                        f"materialises (nt, T, T) in HBM"
                    )
            if name == "to_storage":
                out.append(
                    f"{path}:{node.lineno}: to_storage() in a kernel module "
                    f"— kernels must consume tiles as stored"
                )
            self.generic_visit(node)

    Visitor().visit(tree)
    return out


def main() -> int:
    problems = []
    for path in sorted(KERNEL_DIR.glob("*.py")):
        if path.name in ORACLE_FILES:
            continue
        problems += _violations(path)
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        print(
            f"\n{len(problems)} packed-storage guard violation(s): HBM must "
            f"only ever see packed words outside the oracle/int8 path",
            file=sys.stderr,
        )
        return 1
    print("ci_guards: kernel packed-storage guard clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""CI source guards that a grep can't express precisely (DESIGN.md §11/§12).

Guard 1 — packed tiles must stay packed until VMEM: in the kernel modules
(`src/repro/kernels/`, excluding the oracle `ref.py`), `unpack_tile_bits`
may only be CALLED inside Pallas kernel-body functions (names ending in
`_kernel`).  An unpack anywhere else — e.g. in `ops.py` before the
`pallas_call` — would materialise the dense (nt, T, T) array in HBM and
forfeit the 8× DMA reduction the storage axis exists for.  The jnp oracle
paths (`kernels/ref.py`, `core/engine.py`) are the sanctioned exceptions.

Guard 2 — kernel modules must not densify via the whole-array helpers
either: `dense_tiles` (the oracle dispatch) and `to_storage` (the format
converter) never appear under `src/repro/kernels/` outside `ref.py`.

Guard 3 — the dyngraph delta path edits packed tiles AS packed words
(word-level bit edits, DESIGN.md §12): under `src/repro/dyngraph/`, none
of `unpack_tile_bits` / `dense_tiles` / `to_storage` may be called outside
a function whose name ends in `_oracle` (the sanctioned densify path for
reference checks — none exist today; the suffix names the ONLY place one
would be allowed).  A densify in `retile.py` would silently turn the
O(delta) patch into an O(tiles) unpack-repack; in `repair.py` it would
materialise dense tiles the engines never need.

Run: python tools/ci_guards.py   (exit 0 = clean)
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
KERNEL_DIR = ROOT / "src/repro/kernels"
DYNGRAPH_DIR = ROOT / "src/repro/dyngraph"
ORACLE_FILES = {"ref.py"}          # the sanctioned full-unpack path
KERNEL_FN_SUFFIX = "_kernel"
ORACLE_FN_SUFFIX = "_oracle"

DENSIFY_CALLS = ("unpack_tile_bits", "dense_tiles")


def _call_name(node: ast.Call):
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _walk_calls(path: pathlib.Path):
    """Yield (call_name, lineno, enclosing_fn_stack) for every call."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []

    class Visitor(ast.NodeVisitor):
        def __init__(self):
            self.stack = []

        def _visit_fn(self, node):
            self.stack.append(node.name)
            self.generic_visit(node)
            self.stack.pop()

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def visit_Call(self, node):
            name = _call_name(node)
            if name:
                out.append((name, node.lineno, tuple(self.stack)))
            self.generic_visit(node)

    Visitor().visit(tree)
    return out


def kernel_violations(path: pathlib.Path) -> list:
    """Guards 1+2: unpack only inside *_kernel bodies; never densify."""
    out = []
    for name, lineno, stack in _walk_calls(path):
        if name in DENSIFY_CALLS:
            in_kernel_body = any(fn.endswith(KERNEL_FN_SUFFIX) for fn in stack)
            if name == "dense_tiles" or not in_kernel_body:
                out.append(
                    f"{path}:{lineno}: {name} called "
                    f"outside a *{KERNEL_FN_SUFFIX} body (scope: "
                    f"{'.'.join(stack) or '<module>'}) — this "
                    f"materialises (nt, T, T) in HBM"
                )
        if name == "to_storage":
            out.append(
                f"{path}:{lineno}: to_storage() in a kernel module "
                f"— kernels must consume tiles as stored"
            )
    return out


def dyngraph_violations(path: pathlib.Path) -> list:
    """Guard 3: the delta path never densifies outside a *_oracle body."""
    out = []
    for name, lineno, stack in _walk_calls(path):
        if name in DENSIFY_CALLS + ("to_storage",):
            if any(fn.endswith(ORACLE_FN_SUFFIX) for fn in stack):
                continue
            out.append(
                f"{path}:{lineno}: {name} called outside a "
                f"*{ORACLE_FN_SUFFIX} body (scope: "
                f"{'.'.join(stack) or '<module>'}) — the delta path must "
                f"edit packed tiles as packed words, never densify"
            )
    return out


def main() -> int:
    problems = []
    for path in sorted(KERNEL_DIR.glob("*.py")):
        if path.name in ORACLE_FILES:
            continue
        problems += kernel_violations(path)
    n_kernel = len(problems)
    for path in sorted(DYNGRAPH_DIR.glob("*.py")):
        problems += dyngraph_violations(path)
    for p in problems:
        print(p, file=sys.stderr)
    if problems:
        print(
            f"\n{len(problems)} packed-storage guard violation(s) "
            f"({n_kernel} kernel, {len(problems) - n_kernel} dyngraph): HBM "
            f"must only ever see packed words outside the oracle/int8 path",
            file=sys.stderr,
        )
        return 1
    print("ci_guards: kernel + dyngraph packed-storage guards clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

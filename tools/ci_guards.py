#!/usr/bin/env python3
"""Thin compatibility shim over `python -m repro.lint` (DESIGN.md §15).

The five AST guards that used to live here are now rules RPR001–RPR005 of
the repro.lint engine; this script runs exactly those rules over src/repro
with the baseline disabled, preserving the historical exit semantics
(0 = clean, 1 = violations).
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.lint.cli import main  # noqa: E402
from repro.lint.rules import GUARD_RULE_IDS  # noqa: E402

if __name__ == "__main__":
    sys.exit(
        main(
            [
                "--rules", ",".join(GUARD_RULE_IDS),
                "--no-baseline",
                str(ROOT / "src" / "repro"),
            ]
        )
    )

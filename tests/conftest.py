"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device; anything
multi-device runs in a subprocess (helpers below)."""
import os
import subprocess
import sys
import textwrap

import pytest


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run `script` in a fresh python with n fake devices; return stdout.

    Scripts are written against the modern jax sharding API; the preamble
    backfills it on older jax (repro.dist.compat)."""
    preamble = "from repro.dist.compat import install as _i; _i()\n"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", preamble + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout

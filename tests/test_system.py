"""End-to-end system behaviour: the paper's full pipeline on real (reduced)
graph instances, plus registry completeness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, REGISTRY


def test_all_assigned_archs_registered():
    for arch in ASSIGNED_ARCHS:
        assert arch in REGISTRY, f"missing assigned arch {arch}"
    assert "tcmis" in REGISTRY  # the paper's own config
    # LM archs expose the 4 LM shapes, GNN archs the 4 GNN shapes, etc.
    for arch in ASSIGNED_ARCHS:
        assert len(REGISTRY[arch].cells) == 4, arch
    assert len(REGISTRY["tcmis"].cells) == 8  # G1..G8


def test_tcmis_smoke():
    REGISTRY["tcmis"].smoke()


@pytest.mark.parametrize("paper_id", ["G2", "G4"])
def test_paper_pipeline_on_suite_graph(paper_id):
    """Generate a Table-1 stand-in, tile it, run all three algorithms,
    validate, and check the paper's qualitative claims hold."""
    from repro.core import (
        TCMISConfig, build_block_tiles, cardinality, ecl_mis, is_valid_mis,
        luby_mis, tc_mis,
    )
    from repro.graphs.generators import GRAPH_SUITE

    spec = GRAPH_SUITE[paper_id]
    g = spec.make(4000, 0)
    tiled = build_block_tiles(g, tile_size=64)
    key = jax.random.key(0)

    r_luby = luby_mis(g, key)
    r_ecl = ecl_mis(g, key)
    r_tc = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3"))
    for r in (r_luby, r_ecl, r_tc):
        assert bool(r.converged)
        assert is_valid_mis(g, r.in_mis)
    # degree-aware beats pure-random cardinality (paper Fig. 3 direction)
    assert cardinality(r_ecl.in_mis) >= cardinality(r_luby.in_mis)
    # rounds are logarithmic-ish, not linear
    assert int(r_tc.rounds) < 64


def test_train_loop_end_to_end_lm(tmp_path):
    """examples/train driver logic: tiny LM trains and loss decreases."""
    import numpy as np

    from repro.configs.qwen15_0_5b import SMOKE
    from repro.configs.common import make_lm_train_step
    from repro.data.pipeline import TokenStream
    from repro.models import transformer as tf
    from repro.train import LoopConfig, OptConfig, TrainLoop, adamw_init

    cfg = SMOKE
    params = tf.init_lm(jax.random.key(0), cfg)
    raw = jax.jit(make_lm_train_step(cfg, OptConfig(lr=3e-3, warmup_steps=5,
                                                    total_steps=100)))

    def step_fn(state, batch):
        params, opt = state
        tokens, targets = batch
        params, opt, loss, xent = raw(params, opt, jnp.asarray(tokens),
                                      jnp.asarray(targets))
        return (params, opt), {"loss": loss}

    loop = TrainLoop(
        step_fn=step_fn,
        init_state=(params, adamw_init(params)),
        stream=TokenStream(cfg.vocab, 8, 32, seed=3),
        cfg=LoopConfig(ckpt_dir=str(tmp_path), checkpoint_every=20),
    )
    first = []
    orig_step = loop.step_fn

    res = loop.run(60)
    assert np.isfinite(res["metrics"]["loss"])
    # copy-structure stream is learnable: loss must drop below uniform
    assert res["metrics"]["loss"] < float(np.log(cfg.vocab)) - 0.3

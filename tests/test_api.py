"""The `repro.api` front door: Plan/SolveOptions/Solver.

Acceptance contract of the API redesign: for each routing target (local,
batched, sharded) `Solver.solve` returns a bit-identical `in_mis` to the
pre-redesign direct call on the same graph/seed; the profiler twin matches
the jitted path for EVERY registered engine; `solve_many` never builds a
bucket for nothing/a singleton; and the legacy entry points warn but keep
working.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice
from repro.api import (
    Plan,
    PlanCache,
    Solver,
    SolveOptions,
    choose_tile_size,
    fit_tile_size,
)
from repro.core import (
    TCMISConfig,
    build_block_tiles,
    engine_names,
    get_engine,
    is_valid_mis,
    run_phases,
    tc_mis,
)
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw
from repro.graphs.graph import from_edges

ALL_ENGINES = ("segment", "tiled_ref", "tiled_pallas", "fused_pallas")


def _legacy(fn, *args, **kwargs):
    """Call a deprecated shim without polluting the warning log."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kwargs)


def _hetero(n=6, seed=0):
    return [
        grid2d(3 + seed, 4),
        powerlaw(40 + seed, avg_deg=3.0, seed=seed + 1),
        erdos_renyi(25 + seed, avg_deg=4.0, seed=seed + 2),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 7),
        erdos_renyi(33 + seed, avg_deg=2.0, seed=seed + 3),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 1),
    ][:n]


# --------------------------------------------------------------------------
# routing target: local — bit-identical to the direct tc_mis call
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_solve_local_bit_identical_to_direct_call(engine):
    g = erdos_renyi(90, avg_deg=5.0, seed=3)
    res = Solver(SolveOptions(engine=engine, tile_size=16, seed=0)).solve(g)
    direct = _legacy(
        tc_mis, g, build_block_tiles(g, tile_size=16), jax.random.key(0),
        TCMISConfig(heuristic="h3", backend=engine),
    )
    assert res.placement == "local"
    np.testing.assert_array_equal(res.in_mis, np.asarray(direct.in_mis))
    assert res.rounds == int(direct.rounds)
    assert res.converged == bool(direct.converged)


def test_solve_accepts_plan_and_respects_explicit_key():
    g = powerlaw(64, avg_deg=4.0, seed=1)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8, seed=5))
    plan = solver.plan(g)
    res = solver.solve(plan, key=jax.random.key(42))
    direct = _legacy(
        tc_mis, plan.g, plan.tiled, jax.random.key(42),
        TCMISConfig(backend="tiled_ref"),
    )
    np.testing.assert_array_equal(res.in_mis, np.asarray(direct.in_mis))


# --------------------------------------------------------------------------
# routing target: batched — members bit-identical to solo runs, own rounds
# --------------------------------------------------------------------------

def test_solve_many_members_bit_identical_to_solo_with_own_rounds():
    graphs = _hetero(6)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8))
    results = solver.solve_many(graphs)
    assert [r.placement for r in results] == ["batched"] * 6
    assert len({r.stats["bucket"] for r in results}) == 1  # ONE dispatch
    for g, res in zip(graphs, results):
        solo = _legacy(
            tc_mis, res.plan.g, res.plan.tiled, solver.request_key(res.plan),
            TCMISConfig(heuristic="h3", backend="tiled_ref"),
        )
        np.testing.assert_array_equal(res.in_mis, np.asarray(solo.in_mis))
        # the satellite contract: each member reports its OWN convergence
        # round, not the batch-slowest
        assert res.rounds == int(solo.rounds)
        assert is_valid_mis(g, jnp.asarray(res.in_mis))
    assert len({r.rounds for r in results}) > 1, "fixture should span rounds"


def test_solve_many_empty_and_singleton_build_no_bucket():
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8))
    assert solver.solve_many([]) == []
    assert solver.stats["batches"] == 0

    # singleton: routed through the single-graph path (no bucket), and the
    # batcher's hard cases — zero-edge and 1-vertex graphs — must survive it
    for g in (
        erdos_renyi(20, avg_deg=3.0, seed=0),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 5),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 1),
    ):
        [res] = solver.solve_many([g])
        assert res.placement == "local"
        assert "bucket" not in res.stats
        assert is_valid_mis(g, jnp.asarray(res.in_mis))
        assert res.converged
    assert solver.stats["batches"] == 0

    # the singleton result equals the same member inside a real batch
    g = erdos_renyi(20, avg_deg=3.0, seed=0)
    [single] = solver.solve_many([g])
    batched = solver.solve_many([g, grid2d(4, 4)])[0]
    np.testing.assert_array_equal(single.in_mis, batched.in_mis)
    assert single.rounds == batched.rounds


def test_solve_many_honours_custom_keys_despite_priority_cache():
    """Regression: the content-keyed priority cache must be bypassed when
    the caller supplies explicit keys, or custom-key members would silently
    get the cached default-key priorities."""
    g = erdos_renyi(40, avg_deg=4.0, seed=1)
    h = erdos_renyi(36, avg_deg=4.0, seed=2)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8))
    solver.solve_many([g, h])   # warms the priority cache under default keys
    k1, k2 = jax.random.key(101), jax.random.key(202)
    custom = solver.solve_many([g, h], keys=[k1, k2])
    for res, key in zip(custom, (k1, k2)):
        solo = _legacy(
            tc_mis, res.plan.g, res.plan.tiled, key,
            TCMISConfig(heuristic="h3", backend="tiled_ref"),
        )
        np.testing.assert_array_equal(res.in_mis, np.asarray(solo.in_mis))
    # ...and the default-key path still reuses its cache afterwards
    again = solver.solve_many([g, h])
    for res in again:
        solo = _legacy(
            tc_mis, res.plan.g, res.plan.tiled, solver.request_key(res.plan),
            TCMISConfig(heuristic="h3", backend="tiled_ref"),
        )
        np.testing.assert_array_equal(res.in_mis, np.asarray(solo.in_mis))


def test_solve_many_keeps_input_order_and_compile_reuse():
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8))
    graphs = _hetero(4, seed=0)
    first = solver.solve_many(graphs)
    assert all(r.stats["compile"] == "compiled" for r in first)
    second = solver.solve_many(graphs)
    assert all(r.stats["compile"] == "reused" for r in second)
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a.in_mis, b.in_mis)
    assert [r.plan.n_nodes for r in first] == [g.n_nodes for g in graphs]
    assert solver.stats["batches"] == 2
    if hasattr(solver._jit_packed, "_cache_size"):
        assert solver._jit_packed._cache_size() == 1  # same bucket, one program


# --------------------------------------------------------------------------
# routing target: sharded — bit-identical to the direct shard_map call
# --------------------------------------------------------------------------

def test_solve_sharded_bit_identical_to_direct_call():
    out = run_multidevice("""
        import jax, numpy as np
        from repro.api import Solver, SolveOptions
        from repro.core import (build_block_tiles, shard_tiled,
                                build_distributed_mis, DistConfig,
                                make_priorities, is_valid_mis)
        from repro.graphs.generators import powerlaw

        g = powerlaw(2000, avg_deg=5.0, seed=2)
        solver = Solver(SolveOptions(heuristic="h3", tile_size=64,
                                     placement="sharded", seed=0))
        plan = solver.plan(g)
        assert solver.route(plan) == "sharded"
        res = solver.solve(g)
        assert res.placement == "sharded"
        assert res.stats["n_shards"] == 8
        assert is_valid_mis(g, jax.numpy.asarray(res.in_mis))

        # pre-redesign direct call, same graph/seed
        tiled = build_block_tiles(g, tile_size=64)
        sharded = shard_tiled(tiled, n_shards=8)
        mesh = jax.make_mesh((8,), ("shard",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        pri = make_priorities("h3", jax.random.key(0), g.n_nodes, g.degrees())
        direct = build_distributed_mis(sharded, mesh, DistConfig())(pri)
        assert bool(np.all(res.in_mis == np.asarray(direct.in_mis)[:g.n_nodes]))
        assert res.rounds == int(direct.rounds)

        # the auto policy routes big graphs to shards, small ones locally
        auto = Solver(SolveOptions(heuristic="h3", tile_size=64,
                                   placement="auto", shard_threshold=1024))
        assert auto.route(plan) == "sharded"
        small = auto.plan(powerlaw(100, avg_deg=3.0, seed=0))
        assert auto.route(small) == "local"
        auto_res = auto.solve(g)
        assert bool(np.all(auto_res.in_mis == res.in_mis))
        print("API_SHARDED_OK")
    """)
    assert "API_SHARDED_OK" in out


# --------------------------------------------------------------------------
# profiler twin parity — EVERY registered engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", engine_names())
def test_profile_matches_solve_for_every_registered_engine(engine):
    g = erdos_renyi(70, avg_deg=4.0, seed=1)
    solver = Solver(SolveOptions(engine=engine, tile_size=16))
    want = solver.solve(g)
    got, times = solver.profile(g)
    np.testing.assert_array_equal(got.in_mis, want.in_mis)
    assert times["rounds"] == want.rounds
    assert set(times) == {"phase1", "phase2", "phase3", "rounds"}


# --------------------------------------------------------------------------
# Plan + auto-T policy
# --------------------------------------------------------------------------

def test_plan_build_through_cache_and_auto_tile_size():
    g = erdos_renyi(50, avg_deg=3.0, seed=0)
    cache = PlanCache(tile_size=8)
    a = Plan.build(g, cache=cache)
    b = Plan.build(g, cache=cache)
    assert a is b                       # content hit, zero work
    assert cache.stats["mem_hits"] == 1
    assert Plan.build(a) is a           # plans pass through

    auto = Plan.build(g)                # no cache: SAME auto-T, same key —
    assert auto.tile_size == choose_tile_size(g.n_nodes, g.n_edges)
    assert auto.key == a.key            # the cache never changes the plan
    assert auto.tile_size == a.tile_size

    explicit = Plan.build(g, tile_size=8, cache=cache)
    assert explicit.tile_size == 8
    assert explicit.key != a.key        # T is part of the content key

    # budget policy: shrinking the budget shrinks T, floor at 16
    big_n, big_e = 1 << 20, 8 << 20
    assert choose_tile_size(big_n, big_e, budget=1 << 40) == 128
    assert choose_tile_size(big_n, big_e, budget=1 << 20) == 16
    # tiny graphs never take tiles wider than their padded range
    assert choose_tile_size(20, 40) <= 32
    assert fit_tile_size(lambda T: T * T, budget=64 * 64) == 64


def test_solve_options_validation_and_engine_failfast():
    with pytest.raises(ValueError, match="placement"):
        SolveOptions(placement="cloud")
    with pytest.raises(ValueError, match="unknown engine"):
        Solver(SolveOptions(engine="cuda_warp"))


def test_rcm_plans_return_original_ids():
    g = grid2d(6, 6, seed=0)
    solver = Solver(SolveOptions(
        engine="tiled_ref", tile_size=8, reorder="rcm",
    ))
    res = solver.solve(g)
    assert res.plan.perm is not None
    assert is_valid_mis(g, jnp.asarray(res.in_mis))   # ORIGINAL numbering
    # in_mis_plan maps back into the permuted plan space
    assert is_valid_mis(res.plan.g, jnp.asarray(res.in_mis_plan))


# --------------------------------------------------------------------------
# deprecation surface
# --------------------------------------------------------------------------

def test_legacy_entry_points_emit_deprecation_warnings():
    g = erdos_renyi(30, avg_deg=3.0, seed=0)
    tiled = build_block_tiles(g, tile_size=8)
    with pytest.warns(DeprecationWarning, match="repro.api"):
        tc_mis(g, tiled, jax.random.key(0), TCMISConfig(backend="tiled_ref"))
    with pytest.warns(DeprecationWarning, match="profile"):
        run_phases(g, tiled, jax.random.key(0), TCMISConfig(backend="tiled_ref"))
    with pytest.warns(DeprecationWarning, match="tiled_ref"):
        get_engine("ref")
    with pytest.warns(DeprecationWarning, match="tiled_pallas"):
        get_engine("pallas")


def test_legacy_shims_match_the_front_door():
    g = powerlaw(60, avg_deg=4.0, seed=7)
    res = Solver(SolveOptions(engine="fused_pallas", tile_size=16)).solve(g)
    shim = _legacy(
        tc_mis, g, build_block_tiles(g, tile_size=16), jax.random.key(0),
        TCMISConfig(backend="fused_pallas"),
    )
    np.testing.assert_array_equal(res.in_mis, np.asarray(shim.in_mis))

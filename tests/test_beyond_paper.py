"""Beyond-paper extensions: RCM tile densification, fused phase-②+③ kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TCMISConfig, build_block_tiles, cardinality, is_valid_mis, tc_mis,
)
from repro.core.tiling import rcm_ordering, tile_stats
from repro.graphs.generators import delaunay_like, powerlaw
from repro.graphs.graph import Graph, from_edges


def test_rcm_improves_tile_density():
    """RCM reordering must reduce non-empty tiles on mesh-like graphs."""
    g = delaunay_like(8192, seed=0)
    # destroy the generator's natural locality first
    rng = np.random.default_rng(0)
    perm = rng.permutation(g.n_nodes)
    s = perm[np.asarray(g.senders)[: g.n_edges]]
    r = perm[np.asarray(g.receivers)[: g.n_edges]]
    g_shuffled = from_edges(s, r, g.n_nodes)

    base = tile_stats(build_block_tiles(g_shuffled, tile_size=64))
    rcm = tile_stats(build_block_tiles(g_shuffled, tile_size=64, reorder="rcm"))
    assert rcm["n_tiles"] < base["n_tiles"] * 0.5, (base["n_tiles"], rcm["n_tiles"])
    assert rcm["intra_tile_density"] > base["intra_tile_density"]


def test_rcm_mis_roundtrip():
    """MIS on the RCM-permuted graph maps back to a valid MIS."""
    g = powerlaw(2000, avg_deg=5.0, seed=1)
    perm = rcm_ordering(g)                      # perm[new_id] = old_id
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.n_nodes)
    s = inv[np.asarray(g.senders)[: g.n_edges]]
    r = inv[np.asarray(g.receivers)[: g.n_edges]]
    g_perm = from_edges(s, r, g.n_nodes)
    tiled = build_block_tiles(g_perm, tile_size=64)
    res = tc_mis(g_perm, tiled, jax.random.key(0), TCMISConfig(heuristic="h3"))
    # map the solution back to original ids and validate on the original graph
    in_mis_orig = np.zeros(g.n_nodes, bool)
    in_mis_orig[perm[np.flatnonzero(np.asarray(res.in_mis))]] = True
    assert is_valid_mis(g, jnp.asarray(in_mis_orig))


@pytest.mark.parametrize("T", [16, 32])
@pytest.mark.parametrize("seed", [0, 1])
def test_fused_phase23_kernel(T, seed):
    """Fused ②+③ must reproduce the unfused pipeline exactly."""
    from repro.core.spmv import spmv_tiled
    from repro.kernels.ops import tc_spmv_fused
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(300, avg_deg=6.0, seed=seed)
    tiled = build_block_tiles(g, tile_size=T)
    n_pad = tiled.n_padded
    key = jax.random.key(seed)
    alive = jnp.pad(
        jax.random.uniform(key, (g.n_nodes,)) > 0.3,
        (0, n_pad - g.n_nodes),
    )
    cand = alive & (jax.random.uniform(jax.random.key(seed + 1), (n_pad,)) > 0.7)

    rhs = jnp.zeros((n_pad, 8), jnp.float32)
    rhs = rhs.at[:, 0].set(cand.astype(jnp.float32))
    rhs = rhs.at[:, 1].set(alive.astype(jnp.float32))

    n_c, new_alive, mis_add = tc_spmv_fused(tiled, rhs, cand, alive)

    # unfused reference
    n_c_ref = spmv_tiled(tiled, rhs, backend="ref")
    alive_ref = alive & ~cand & ~(n_c_ref[:, 0] > 0)
    np.testing.assert_allclose(np.asarray(n_c), np.asarray(n_c_ref), atol=1e-5)
    assert bool(jnp.all(new_alive == alive_ref))
    assert bool(jnp.all(mis_add == cand))


def test_fused_kernel_isolated_rows():
    """Block-rows with no tiles take the trivial-epilogue path."""
    from repro.kernels.ops import tc_spmv_fused

    # two components far apart -> empty block-rows in between
    s = np.array([0, 1, 200, 201])
    r = np.array([1, 0, 201, 200])
    g = from_edges(s, r, 256)
    tiled = build_block_tiles(g, tile_size=16)
    n_pad = tiled.n_padded
    alive = jnp.ones((n_pad,), bool).at[250:].set(False)
    cand = jnp.zeros((n_pad,), bool).at[0].set(True).at[100].set(True)
    rhs = jnp.zeros((n_pad, 8), jnp.float32).at[:, 0].set(cand.astype(jnp.float32))
    n_c, new_alive, mis_add = tc_spmv_fused(tiled, rhs, cand, alive)
    assert bool(mis_add[0]) and bool(mis_add[100])
    assert not bool(new_alive[1])     # neighbour of candidate 0 dies
    assert bool(new_alive[100 + 1])   # isolated vertex 101: no cand nbr, alive

"""Round-engine layer: registry contract, fused ②+③ vs the jnp oracle on
degenerate tilings (empty block-rows, isolated vertices), live col_flags
equivalence, and the every-engine-same-MIS property on seeded graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TCMISConfig,
    build_block_tiles,
    engine_names,
    get_engine,
    is_valid_mis,
    tc_mis,
    run_phases,
)
from repro.core.engine import EngineContext, block_col_flags
from repro.core.tiling import pack_vertex_vector
from repro.graphs.graph import from_edges
from repro.kernels.ops import tc_spmv_fused

ALL_ENGINES = ("segment", "tiled_ref", "tiled_pallas", "fused_pallas")


def _random_graph(n, density, seed):
    rng = np.random.default_rng(seed)
    m = int(density * n * (n - 1) / 2)
    src = rng.integers(0, n, max(m, 1))
    dst = rng.integers(0, n, max(m, 1))
    return from_edges(src, dst, n)


def _clustered_graph(n=100, tile=16, seed=0):
    """Edges confined to vertices [0, n//3): most block-rows store no tiles
    and vertices ≥ n//3 are isolated — the fused kernel's patched epilogue
    (uncovered rows) and the trivial rule must both fire."""
    rng = np.random.default_rng(seed)
    hi = max(n // 3, 2)
    src = rng.integers(0, hi, 4 * hi)
    dst = rng.integers(0, hi, 4 * hi)
    g = from_edges(src, dst, n)
    return g, build_block_tiles(g, tile_size=tile)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_contents_and_aliases():
    assert set(ALL_ENGINES) <= set(engine_names())
    assert get_engine("ref") is get_engine("tiled_ref")
    assert get_engine("pallas") is get_engine("tiled_pallas")
    assert get_engine("fused") is get_engine("fused_pallas")
    assert get_engine("fused_pallas").fused
    assert not get_engine("tiled_ref").fused
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("cuda_warp")


# --------------------------------------------------------------------------
# fused ②+③ kernel vs the split oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("graph_kind", ["random", "clustered"])
def test_fused_step_matches_oracle(seed, graph_kind):
    """fused_step's (new_alive, mis_add) == oracle phase ② + phase ③ rules,
    including block-rows with no tiles and isolated vertices."""
    if graph_kind == "random":
        g = _random_graph(150, 0.05, seed)
        tiled = build_block_tiles(g, tile_size=16)
    else:
        g, tiled = _clustered_graph(n=100 + 7 * seed, tile=16, seed=seed)
    cfg = TCMISConfig()
    ctx = EngineContext(g=g, tiled=tiled, cfg=cfg)
    ref = get_engine("tiled_ref")
    fused = get_engine("fused_pallas")

    key = jax.random.key(seed)
    alive = pack_vertex_vector(
        jax.random.uniform(key, (g.n_nodes,)) < 0.8, tiled
    )
    cand = alive & pack_vertex_vector(
        jax.random.uniform(jax.random.key(seed + 99), (g.n_nodes,)) < 0.3,
        tiled,
    )
    flags = ref.col_flags(ctx, cand, alive)

    n_c = ref.phase2_counts(ctx, cand, alive, flags)
    want_alive = alive & ~cand & ~(n_c > 0)
    got_alive, got_mis = fused.fused_step(ctx, cand, alive, flags)
    assert bool(jnp.all(got_alive == want_alive))
    assert bool(jnp.all(got_mis == cand))


@pytest.mark.parametrize("skip_dma", [False, True])
def test_fused_kernel_nc_matches_oracle_with_flags(skip_dma):
    """The fused kernel's N_c output equals the flag-gated oracle on every
    lane (skipped slabs contribute nothing anywhere)."""
    from repro.core.engine import tile_spmv

    g, tiled = _clustered_graph(n=90, tile=16, seed=4)
    rhs = jax.random.normal(jax.random.key(0), (tiled.n_padded, 4), jnp.float32)
    cand = jax.random.uniform(jax.random.key(1), (tiled.n_padded,)) < 0.3
    rhs = rhs.at[:, 0].set(cand.astype(jnp.float32))
    alive = jnp.ones((tiled.n_padded,), bool)
    flags = block_col_flags(cand, tiled.tile_size)

    n_c, _, _ = tc_spmv_fused(
        tiled, rhs, cand, alive, col_flags=flags, skip_dma=skip_dma
    )
    want = tile_spmv(
        tiled.tiles, tiled.tile_rows, tiled.tile_cols, rhs,
        tiled.n_block_rows, tiled.tile_size, col_flags=flags,
    )
    # uncovered block-rows are patched to zero by the wrapper
    covered = np.zeros(tiled.n_block_rows, bool)
    covered[np.asarray(tiled.tile_rows[: max(tiled.n_tiles, 1)])] = tiled.n_tiles > 0
    want = jnp.where(
        jnp.repeat(jnp.asarray(covered), tiled.tile_size)[:, None], want, 0.0
    )
    np.testing.assert_allclose(
        np.asarray(n_c), np.asarray(want), rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------------------
# whole-algorithm equivalence across engines
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("heuristic", ["ecl", "h3"])
def test_every_engine_same_valid_mis(seed, heuristic):
    """Same seeded priorities ⇒ all four engines return the SAME valid MIS
    (the acceptance contract of the engine layer)."""
    g = _random_graph(120 + 30 * seed, 0.04, seed)
    tiled = build_block_tiles(g, tile_size=16)
    key = jax.random.key(seed)
    ref = None
    for backend in ALL_ENGINES:
        res = tc_mis(g, tiled, key, TCMISConfig(heuristic=heuristic, backend=backend))
        assert bool(res.converged), backend
        assert is_valid_mis(g, res.in_mis), backend
        if ref is None:
            ref = res.in_mis
        else:
            assert bool(jnp.all(res.in_mis == ref)), backend


@pytest.mark.parametrize("backend", ["fused_pallas", "tiled_pallas"])
def test_skip_dma_and_tiled_phase1_equivalent(backend):
    g, tiled = _clustered_graph(n=140, tile=16, seed=7)
    key = jax.random.key(0)
    ref = tc_mis(g, tiled, key, TCMISConfig(backend="tiled_ref"))
    got = tc_mis(
        g, tiled, key,
        TCMISConfig(backend=backend, phase1="tiled", skip_dma=True),
    )
    assert is_valid_mis(g, got.in_mis)
    assert bool(jnp.all(got.in_mis == ref.in_mis))


def test_run_phases_matches_while_loop_driver():
    """The profiler twin drives the same engine round body — identical sets,
    fused and split."""
    g = _random_graph(200, 0.05, 3)
    tiled = build_block_tiles(g, tile_size=32)
    key = jax.random.key(3)
    want = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3"))
    for backend in ("segment", "tiled_ref", "fused_pallas"):
        res, times = run_phases(
            g, tiled, key, TCMISConfig(heuristic="h3", backend=backend)
        )
        assert bool(jnp.all(res.in_mis == want.in_mis)), backend
        assert times["rounds"] == int(want.rounds), backend


def test_isolated_vertices_all_selected():
    """Isolated vertices must end up in the MIS under every engine (the
    fused kernel reaches them only via the uncovered-row patch)."""
    g, tiled = _clustered_graph(n=100, tile=16, seed=1)
    deg = np.asarray(g.degrees())
    isolated = np.flatnonzero(deg == 0)
    assert isolated.size > 0, "fixture must contain isolated vertices"
    for backend in ALL_ENGINES:
        res = tc_mis(g, tiled, jax.random.key(5), TCMISConfig(backend=backend))
        assert bool(jnp.all(res.in_mis[isolated])), backend

"""Observability subsystem (DESIGN.md §14): round telemetry bit-neutrality
and invariants across engine × storage × frontier, `Solver.profile` parity,
span tracing with the compile/execute split, batched solve_ms attribution,
metrics-registry views, the JSONL report CLI, and Guard 5 (host-silent hot
loop)."""
import importlib.util
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import SolveOptions, Solver
from repro.core.engine import engine_names, get_engine
from repro.graphs.generators import erdos_renyi
from repro.obs import (
    COL_ALIVE,
    COL_FRONTIER,
    COL_SELECTED,
    COL_TILES_SKIPPED,
    REGISTRY,
    MetricsRegistry,
    RoundTrace,
    TELEMETRY_COLS,
    TELEMETRY_FILL,
    Trace,
    trace_span,
)
from repro.obs.report import main as report_main
from repro.serve_mis.service import MISService, ServeConfig

ROOT = pathlib.Path(__file__).resolve().parent.parent
ENGINES = engine_names()
STORAGES = ("int8", "bitpack")
FRONTIERS = ("dense", "bitwise")


def _graph(n=128, seed=0):
    return erdos_renyi(n, avg_deg=6.0, seed=seed)


def _opts(engine, storage, frontier, telemetry, **kw):
    return SolveOptions(
        engine=engine, storage=storage, frontier=frontier,
        telemetry=telemetry, tile_size=32, placement="local", **kw,
    )


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_metrics_registry_basics():
    reg = MetricsRegistry("t")
    reg.counter("a").inc()
    reg.counter("a").inc(2)
    reg.gauge("g").set(7)
    reg.histogram("h").observe(1.0)
    reg.histogram("h").observe(3.0)
    snap = reg.snapshot()
    assert snap["a"] == 3
    assert snap["g"] == 7.0
    assert snap["h"] == dict(count=2, total=4.0, min=1.0, max=3.0, mean=2.0)
    # first registration fixes the kind
    with pytest.raises(TypeError):
        reg.gauge("a")


def test_stats_properties_are_metrics_views():
    """The legacy dicts survive as read-only views — same keys, same ints —
    so nothing downstream re-learns a spelling."""
    solver = Solver(SolveOptions(engine="tiled_ref", placement="local"))
    assert solver.stats == {"solves": 0, "batches": 0, "compiles": 0}
    solver.solve(_graph())
    assert solver.stats["solves"] == 1
    assert solver.stats["compiles"] == 1
    with pytest.raises(AttributeError):
        solver.stats = {}
    assert set(solver.plans.stats) == {
        "mem_hits", "disk_hits", "misses", "evicted_stale",
    }
    assert solver.plans.stats["misses"] == 1


# --------------------------------------------------------------------------
# RoundTrace: construction, JSONL round-trip, validation
# --------------------------------------------------------------------------

def _fake_buffer(rows):
    buf = np.full((8, TELEMETRY_COLS), TELEMETRY_FILL, np.int32)
    for i, (a, f, s, k) in enumerate(rows):
        buf[i, COL_ALIVE] = a
        buf[i, COL_FRONTIER] = f
        buf[i, COL_SELECTED] = s
        buf[i, COL_TILES_SKIPPED] = k
    return buf


def test_roundtrace_roundtrip_and_summary():
    buf = _fake_buffer([(10, 4, 3, 1), (5, 2, 2, 2), (1, 1, 1, 3)])
    rt = RoundTrace.from_buffer(buf, 3, tiles_total=4, meta={"engine": "x"})
    rt.check_invariants()
    assert rt.rounds == 3 and list(rt.alive) == [10, 5, 1]
    line = rt.to_jsonl_line()
    assert json.loads(line)["kind"] == "rounds"
    rt2 = RoundTrace.from_jsonl_line(line)
    assert rt2.to_dict() == rt.to_dict()
    s = rt.summary()
    assert s["alive0"] == 10 and s["selected_total"] == 6
    assert s["frontier_peak"] == 4


def test_roundtrace_rejects_bad_buffers():
    with pytest.raises(ValueError):
        RoundTrace.from_buffer(np.zeros((4, TELEMETRY_COLS + 1), np.int32), 2)
    # a used row still holding the fill value = the loop never wrote it
    buf = _fake_buffer([(10, 4, 3, 0)])
    with pytest.raises(ValueError):
        RoundTrace.from_buffer(buf, 2)
    # alive must be non-increasing
    rt = RoundTrace.from_buffer(_fake_buffer([(5, 2, 2, 0), (9, 1, 1, 0)]), 2)
    with pytest.raises(AssertionError):
        rt.check_invariants()


# --------------------------------------------------------------------------
# telemetry: bit-neutral, invariant-clean, across every combination
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_telemetry_bit_identity_and_invariants(engine):
    """Telemetry on/off must trace to the same solution for every storage ×
    frontier, and the recorded series must satisfy the round invariants."""
    g = _graph(n=128, seed=3)
    for storage in STORAGES:
        for frontier in FRONTIERS:
            off = Solver(_opts(engine, storage, frontier, False)).solve(g)
            on = Solver(_opts(engine, storage, frontier, True)).solve(g)
            assert np.array_equal(
                np.asarray(off.in_mis), np.asarray(on.in_mis)
            ), (engine, storage, frontier)
            assert off.rounds == on.rounds
            rt = on.telemetry
            assert rt is not None and off.telemetry is None
            rt.check_invariants()
            # the buffer's trimmed length IS the convergence round count,
            # and the series opens on the full vertex set
            assert rt.rounds == on.rounds
            assert rt.alive[0] == g.n_nodes
            # a cold solve never evicts: selections accumulate to |MIS|
            assert sum(rt.selected) == on.mis_size
            assert rt.meta["engine"] == engine
            assert rt.meta["frontier"] in ("dense", "bitwise")


def test_telemetry_tiles_skipped_bounded():
    g = _graph(n=256, seed=5)
    res = Solver(_opts("tiled_ref", "bitpack", "auto", True)).solve(g)
    rt = res.telemetry
    assert rt.tiles_total > 0
    assert min(rt.tiles_skipped) >= 0
    assert max(rt.tiles_skipped) <= rt.tiles_total


# --------------------------------------------------------------------------
# Solver.profile parity (satellite: PR 6 left the bitwise frontier uncovered)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_profile_bit_matches_solve(engine):
    g = _graph(n=128, seed=7)
    for storage in STORAGES:
        for frontier in FRONTIERS:
            solver = Solver(_opts(engine, storage, frontier, False))
            res = solver.solve(g)
            prof, times = solver.profile(g)
            assert np.array_equal(
                np.asarray(res.in_mis), np.asarray(prof.in_mis)
            ), (engine, storage, frontier)
            assert prof.rounds == res.rounds
            assert set(times) >= {"phase1", "phase2", "phase3", "rounds"}
            assert times["rounds"] == res.rounds
            assert all(
                times[k] >= 0.0 for k in ("phase1", "phase2", "phase3")
            )
            # the stepped loop did real work: some phase accumulated time
            assert times["phase1"] + times["phase2"] + times["phase3"] > 0


# --------------------------------------------------------------------------
# span tracing + the compile/execute split
# --------------------------------------------------------------------------

def test_trace_span_tree_and_noop():
    tr = Trace("t")
    with trace_span(tr, "outer", k=1):
        with trace_span(tr, "inner"):
            pass
    names = [(s.name, s.depth) for s in tr.spans]
    assert ("outer", 0) in names and ("inner", 1) in names
    d = json.loads(tr.to_jsonl_line())
    assert d["kind"] == "trace" and len(d["spans"]) == 2
    # trace=None is a no-op seam, not an error
    with trace_span(None, "ignored"):
        pass


def test_traced_solve_splits_compile_from_execute():
    g = _graph(n=128, seed=9)
    solver = Solver(_opts("tiled_ref", "int8", "auto", False))
    tr = Trace("cold")
    res = solver.solve(g, trace=tr)
    names = [s.name for s in tr.spans]
    assert "solver.plan" in names and "solver.compile" in names
    assert "solver.execute" in names
    assert res.stats["compile_ms"] > 0 and res.stats["execute_ms"] >= 0
    assert res.stats["solve_ms"] >= res.stats["execute_ms"]
    # warm re-dispatch: AOT cache hit, no compile span, identical bits
    tr2 = Trace("warm")
    res2 = solver.solve(g, trace=tr2)
    assert "solver.compile" not in [s.name for s in tr2.spans]
    assert "compile_ms" not in res2.stats
    assert np.array_equal(np.asarray(res.in_mis), np.asarray(res2.in_mis))
    # traced and untraced dispatches agree bit-for-bit too
    res3 = Solver(_opts("tiled_ref", "int8", "auto", False)).solve(g)
    assert np.array_equal(np.asarray(res.in_mis), np.asarray(res3.in_mis))


def test_batched_solve_ms_attribution():
    """Members report their SHARE of the batch wall plus the explicit
    `batch_ms` — the old code booked the whole batch on every member."""
    gs = [_graph(n=96, seed=s) for s in (1, 2, 3)]
    solver = Solver(_opts("tiled_ref", "int8", "auto", True))
    tr = Trace("batch")
    plans = [solver.plan(g) for g in gs]
    results = solver.solve_many(plans, trace=tr)
    assert len(results) == 3
    for r in results:
        assert r.stats["batch_size"] == 3
        assert r.stats["batch_ms"] == pytest.approx(
            r.stats["solve_ms"] * 3, rel=0.01
        )
        assert r.telemetry is not None
        assert r.telemetry.meta["batch_size"] == 3
    # batch-global series is shared, not duplicated per member
    assert len({id(r.telemetry) for r in results}) == 1


# --------------------------------------------------------------------------
# service: end-to-end JSONL through the report CLI
# --------------------------------------------------------------------------

def test_service_telemetry_trace_jsonl(tmp_path):
    trace_path = str(tmp_path / "trace.jsonl")
    svc = MISService(ServeConfig(
        engine="tiled_ref", max_batch=4,
        telemetry=True, trace_path=trace_path,
    ))
    svc.submit(_graph(n=96, seed=11))
    svc.submit(_graph(n=96, seed=12))
    responses = svc.drain()
    assert all(r.valid for r in responses)
    for r in responses:
        assert "rounds_summary" in r.stats
        # the series is BATCH-global (like `converged`): its round count
        # bounds every member's own convergence round from above
        assert r.stats["rounds_summary"]["rounds"] >= r.rounds
        assert "batch_ms" in r.stats and "execute_ms" in r.stats
    kinds = [
        json.loads(line)["kind"]
        for line in open(trace_path).read().splitlines()
    ]
    assert "trace" in kinds and "rounds" in kinds
    # the merged snapshot spans every layer's prefix
    snap = svc.metrics_snapshot()
    assert snap["service.requests"] == 2
    assert any(k.startswith("solver.") for k in snap)
    assert any(k.startswith("plan_cache.") for k in snap)
    assert svc.stats["requests"] == 2
    # the report CLI renders it (exit 0) and rejects an empty file (exit 2)
    assert report_main(["report", trace_path]) == 0
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert report_main(["report", str(empty)]) == 2


def test_service_disabled_obs_is_quiet(tmp_path):
    """No trace_path, no telemetry → no writer, no telemetry payloads, and
    the response stats keep exactly the legacy solve keys."""
    svc = MISService(ServeConfig(engine="tiled_ref", max_batch=2))
    svc.submit(_graph(n=96, seed=13))
    (r,) = svc.drain()
    assert svc._trace_writer is None
    assert "rounds_summary" not in r.stats
    assert "compile_ms" not in r.stats
    assert r.valid


# --------------------------------------------------------------------------
# repair metrics (process registry) — eager-only contract
# --------------------------------------------------------------------------

def test_update_records_repair_metrics():
    from repro.dyngraph.delta import EdgeDelta

    before = REGISTRY.snapshot().get("repair.incremental", 0)
    solver = Solver(_opts(
        "tiled_ref", "int8", "auto", True, repair="incremental",
    ))
    g = _graph(n=96, seed=15)
    res = solver.solve(g)
    res2 = solver.update(res, EdgeDelta.make([0, 7], [5, 9], [], []))
    assert res2.stats["repair"] == "incremental"
    assert res2.telemetry is not None
    assert res2.telemetry.meta["scope"] == "repair"
    assert REGISTRY.snapshot()["repair.incremental"] == before + 1


# --------------------------------------------------------------------------
# Guard 5: the hot loop stays host-silent
# --------------------------------------------------------------------------

def _guard5_findings(tree_root):
    from repro.lint.analysis import load_universe
    from repro.lint.rules import get_rules, run_rules

    ctx = load_universe([tree_root])
    return [f for f in run_rules(ctx, get_rules(["RPR005"])) if f.active]


def test_guard5_detects_host_roundtrips(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent("""
        import jax
        from jax.experimental import io_callback
        from jax.experimental import host_callback as hcb

        def f(x):
            jax.debug.print("x = {}", x)
            io_callback(print, None, x)
            return x
    """))
    msgs = [f.message for f in _guard5_findings(tmp_path / "src")]
    assert len(msgs) == 3, msgs
    assert any("debug.print" in m for m in msgs)
    assert any("io_callback" in m for m in msgs)
    assert any("host_callback" in m for m in msgs)
    bad.write_text("import jax\n\ndef f(x):\n    return x + 1\n")
    assert _guard5_findings(tmp_path / "src") == []


def test_ci_guards_clean_on_repo():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "ci_guards.py")],
        capture_output=True, text=True, cwd=str(ROOT),
        env=dict(os.environ, PYTHONPATH=str(ROOT / "src")),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 error(s)" in proc.stdout

"""Observability subsystem (DESIGN.md §14): round telemetry bit-neutrality
and invariants across engine × storage × frontier, `Solver.profile` parity,
span tracing with the compile/execute split, batched solve_ms attribution,
metrics-registry views, the JSONL report CLI, and Guard 5 (host-silent hot
loop)."""
import importlib.util
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import SolveOptions, Solver
from repro.core.engine import engine_names, get_engine
from repro.graphs.generators import erdos_renyi
from repro.obs import (
    COL_ALIVE,
    COL_FRONTIER,
    COL_SELECTED,
    COL_TILES_SKIPPED,
    REGISTRY,
    MetricsRegistry,
    RoundTrace,
    TELEMETRY_COLS,
    TELEMETRY_FILL,
    Trace,
    trace_span,
)
from repro.obs.report import main as report_main
from repro.serve_mis.service import MISService, ServeConfig

ROOT = pathlib.Path(__file__).resolve().parent.parent
ENGINES = engine_names()
STORAGES = ("int8", "bitpack")
FRONTIERS = ("dense", "bitwise")


def _graph(n=128, seed=0):
    return erdos_renyi(n, avg_deg=6.0, seed=seed)


def _opts(engine, storage, frontier, telemetry, **kw):
    return SolveOptions(
        engine=engine, storage=storage, frontier=frontier,
        telemetry=telemetry, tile_size=32, placement="local", **kw,
    )


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_metrics_registry_basics():
    reg = MetricsRegistry("t")
    reg.counter("a").inc()
    reg.counter("a").inc(2)
    reg.gauge("g").set(7)
    reg.histogram("h").observe(1.0)
    reg.histogram("h").observe(3.0)
    snap = reg.snapshot()
    assert snap["a"] == 3
    assert snap["g"] == 7.0
    h = snap["h"]
    assert (h["count"], h["total"], h["min"], h["max"], h["mean"]) == (
        2, 4.0, 1.0, 3.0, 2.0,
    )
    # quantiles are bucket upper edges clamped to the observed max
    assert h["p50"] == 1.0 and h["p95"] == 3.0 and h["p99"] == 3.0
    # cumulative bucket counts, +Inf last
    assert h["buckets"][-1] == ["+Inf", 2]
    # first registration fixes the kind
    with pytest.raises(TypeError):
        reg.gauge("a")


def test_stats_properties_are_metrics_views():
    """The legacy dicts survive as read-only views — same keys, same ints —
    so nothing downstream re-learns a spelling."""
    solver = Solver(SolveOptions(engine="tiled_ref", placement="local"))
    assert solver.stats == {"solves": 0, "batches": 0, "compiles": 0}
    solver.solve(_graph())
    assert solver.stats["solves"] == 1
    assert solver.stats["compiles"] == 1
    with pytest.raises(AttributeError):
        solver.stats = {}
    assert set(solver.plans.stats) == {
        "mem_hits", "disk_hits", "misses", "evicted_stale",
    }
    assert solver.plans.stats["misses"] == 1


# --------------------------------------------------------------------------
# RoundTrace: construction, JSONL round-trip, validation
# --------------------------------------------------------------------------

def _fake_buffer(rows):
    buf = np.full((8, TELEMETRY_COLS), TELEMETRY_FILL, np.int32)
    for i, (a, f, s, k) in enumerate(rows):
        buf[i, COL_ALIVE] = a
        buf[i, COL_FRONTIER] = f
        buf[i, COL_SELECTED] = s
        buf[i, COL_TILES_SKIPPED] = k
    return buf


def test_roundtrace_roundtrip_and_summary():
    buf = _fake_buffer([(10, 4, 3, 1), (5, 2, 2, 2), (1, 1, 1, 3)])
    rt = RoundTrace.from_buffer(buf, 3, tiles_total=4, meta={"engine": "x"})
    rt.check_invariants()
    assert rt.rounds == 3 and list(rt.alive) == [10, 5, 1]
    line = rt.to_jsonl_line()
    assert json.loads(line)["kind"] == "rounds"
    rt2 = RoundTrace.from_jsonl_line(line)
    assert rt2.to_dict() == rt.to_dict()
    s = rt.summary()
    assert s["alive0"] == 10 and s["selected_total"] == 6
    assert s["frontier_peak"] == 4


def test_roundtrace_rejects_bad_buffers():
    with pytest.raises(ValueError):
        RoundTrace.from_buffer(np.zeros((4, TELEMETRY_COLS + 1), np.int32), 2)
    # a used row still holding the fill value = the loop never wrote it
    buf = _fake_buffer([(10, 4, 3, 0)])
    with pytest.raises(ValueError):
        RoundTrace.from_buffer(buf, 2)
    # alive must be non-increasing
    rt = RoundTrace.from_buffer(_fake_buffer([(5, 2, 2, 0), (9, 1, 1, 0)]), 2)
    with pytest.raises(AssertionError):
        rt.check_invariants()


# --------------------------------------------------------------------------
# telemetry: bit-neutral, invariant-clean, across every combination
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_telemetry_bit_identity_and_invariants(engine):
    """Telemetry on/off must trace to the same solution for every storage ×
    frontier, and the recorded series must satisfy the round invariants."""
    g = _graph(n=128, seed=3)
    for storage in STORAGES:
        for frontier in FRONTIERS:
            off = Solver(_opts(engine, storage, frontier, False)).solve(g)
            on = Solver(_opts(engine, storage, frontier, True)).solve(g)
            assert np.array_equal(
                np.asarray(off.in_mis), np.asarray(on.in_mis)
            ), (engine, storage, frontier)
            assert off.rounds == on.rounds
            rt = on.telemetry
            assert rt is not None and off.telemetry is None
            rt.check_invariants()
            # the buffer's trimmed length IS the convergence round count,
            # and the series opens on the full vertex set
            assert rt.rounds == on.rounds
            assert rt.alive[0] == g.n_nodes
            # a cold solve never evicts: selections accumulate to |MIS|
            assert sum(rt.selected) == on.mis_size
            assert rt.meta["engine"] == engine
            assert rt.meta["frontier"] in ("dense", "bitwise")


def test_telemetry_tiles_skipped_bounded():
    g = _graph(n=256, seed=5)
    res = Solver(_opts("tiled_ref", "bitpack", "auto", True)).solve(g)
    rt = res.telemetry
    assert rt.tiles_total > 0
    assert min(rt.tiles_skipped) >= 0
    assert max(rt.tiles_skipped) <= rt.tiles_total


# --------------------------------------------------------------------------
# Solver.profile parity (satellite: PR 6 left the bitwise frontier uncovered)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_profile_bit_matches_solve(engine):
    g = _graph(n=128, seed=7)
    for storage in STORAGES:
        for frontier in FRONTIERS:
            solver = Solver(_opts(engine, storage, frontier, False))
            res = solver.solve(g)
            prof, times = solver.profile(g)
            assert np.array_equal(
                np.asarray(res.in_mis), np.asarray(prof.in_mis)
            ), (engine, storage, frontier)
            assert prof.rounds == res.rounds
            assert set(times) >= {"phase1", "phase2", "phase3", "rounds"}
            assert times["rounds"] == res.rounds
            assert all(
                times[k] >= 0.0 for k in ("phase1", "phase2", "phase3")
            )
            # the stepped loop did real work: some phase accumulated time
            assert times["phase1"] + times["phase2"] + times["phase3"] > 0


# --------------------------------------------------------------------------
# span tracing + the compile/execute split
# --------------------------------------------------------------------------

def test_trace_span_tree_and_noop():
    tr = Trace("t")
    with trace_span(tr, "outer", k=1):
        with trace_span(tr, "inner"):
            pass
    names = [(s.name, s.depth) for s in tr.spans]
    assert ("outer", 0) in names and ("inner", 1) in names
    d = json.loads(tr.to_jsonl_line())
    assert d["kind"] == "trace" and len(d["spans"]) == 2
    # trace=None is a no-op seam, not an error
    with trace_span(None, "ignored"):
        pass


def test_traced_solve_splits_compile_from_execute():
    g = _graph(n=128, seed=9)
    solver = Solver(_opts("tiled_ref", "int8", "auto", False))
    tr = Trace("cold")
    res = solver.solve(g, trace=tr)
    names = [s.name for s in tr.spans]
    assert "solver.plan" in names and "solver.compile" in names
    assert "solver.execute" in names
    assert res.stats["compile_ms"] > 0 and res.stats["execute_ms"] >= 0
    assert res.stats["solve_ms"] >= res.stats["execute_ms"]
    # warm re-dispatch: AOT cache hit, no compile span, identical bits
    tr2 = Trace("warm")
    res2 = solver.solve(g, trace=tr2)
    assert "solver.compile" not in [s.name for s in tr2.spans]
    assert "compile_ms" not in res2.stats
    assert np.array_equal(np.asarray(res.in_mis), np.asarray(res2.in_mis))
    # traced and untraced dispatches agree bit-for-bit too
    res3 = Solver(_opts("tiled_ref", "int8", "auto", False)).solve(g)
    assert np.array_equal(np.asarray(res.in_mis), np.asarray(res3.in_mis))


def test_batched_solve_ms_attribution():
    """Members report their SHARE of the batch wall plus the explicit
    `batch_ms` — the old code booked the whole batch on every member."""
    gs = [_graph(n=96, seed=s) for s in (1, 2, 3)]
    solver = Solver(_opts("tiled_ref", "int8", "auto", True))
    tr = Trace("batch")
    plans = [solver.plan(g) for g in gs]
    results = solver.solve_many(plans, trace=tr)
    assert len(results) == 3
    for r in results:
        assert r.stats["batch_size"] == 3
        assert r.stats["batch_ms"] == pytest.approx(
            r.stats["solve_ms"] * 3, rel=0.01
        )
        assert r.telemetry is not None
        assert r.telemetry.meta["batch_size"] == 3
    # batch-global series is shared, not duplicated per member
    assert len({id(r.telemetry) for r in results}) == 1


# --------------------------------------------------------------------------
# service: end-to-end JSONL through the report CLI
# --------------------------------------------------------------------------

def test_service_telemetry_trace_jsonl(tmp_path):
    trace_path = str(tmp_path / "trace.jsonl")
    svc = MISService(ServeConfig(
        engine="tiled_ref", max_batch=4,
        telemetry=True, trace_path=trace_path,
    ))
    svc.submit(_graph(n=96, seed=11))
    svc.submit(_graph(n=96, seed=12))
    responses = svc.drain()
    assert all(r.valid for r in responses)
    for r in responses:
        assert "rounds_summary" in r.stats
        # the series is BATCH-global (like `converged`): its round count
        # bounds every member's own convergence round from above
        assert r.stats["rounds_summary"]["rounds"] >= r.rounds
        assert "batch_ms" in r.stats and "execute_ms" in r.stats
    kinds = [
        json.loads(line)["kind"]
        for line in open(trace_path).read().splitlines()
    ]
    assert "trace" in kinds and "rounds" in kinds
    # the merged snapshot spans every layer's prefix
    snap = svc.metrics_snapshot()
    assert snap["service.requests"] == 2
    assert any(k.startswith("solver.") for k in snap)
    assert any(k.startswith("plan_cache.") for k in snap)
    assert svc.stats["requests"] == 2
    # the report CLI renders it (exit 0) and rejects an empty file (exit 2)
    assert report_main(["report", trace_path]) == 0
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert report_main(["report", str(empty)]) == 2


def test_service_disabled_obs_is_quiet(tmp_path):
    """No trace_path, no telemetry → no writer, no telemetry payloads, and
    the response stats keep exactly the legacy solve keys."""
    svc = MISService(ServeConfig(engine="tiled_ref", max_batch=2))
    svc.submit(_graph(n=96, seed=13))
    (r,) = svc.drain()
    assert svc._trace_writer is None
    assert "rounds_summary" not in r.stats
    assert "compile_ms" not in r.stats
    assert r.valid


# --------------------------------------------------------------------------
# repair metrics (process registry) — eager-only contract
# --------------------------------------------------------------------------

def test_update_records_repair_metrics():
    from repro.dyngraph.delta import EdgeDelta

    before = REGISTRY.snapshot().get("repair.incremental", 0)
    solver = Solver(_opts(
        "tiled_ref", "int8", "auto", True, repair="incremental",
    ))
    g = _graph(n=96, seed=15)
    res = solver.solve(g)
    res2 = solver.update(res, EdgeDelta.make([0, 7], [5, 9], [], []))
    assert res2.stats["repair"] == "incremental"
    assert res2.telemetry is not None
    assert res2.telemetry.meta["scope"] == "repair"
    assert REGISTRY.snapshot()["repair.incremental"] == before + 1


# --------------------------------------------------------------------------
# Guard 5: the hot loop stays host-silent
# --------------------------------------------------------------------------

def _guard5_findings(tree_root):
    from repro.lint.analysis import load_universe
    from repro.lint.rules import get_rules, run_rules

    ctx = load_universe([tree_root])
    return [f for f in run_rules(ctx, get_rules(["RPR005"])) if f.active]


def test_guard5_detects_host_roundtrips(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent("""
        import jax
        from jax.experimental import io_callback
        from jax.experimental import host_callback as hcb

        def f(x):
            jax.debug.print("x = {}", x)
            io_callback(print, None, x)
            return x
    """))
    msgs = [f.message for f in _guard5_findings(tmp_path / "src")]
    assert len(msgs) == 3, msgs
    assert any("debug.print" in m for m in msgs)
    assert any("io_callback" in m for m in msgs)
    assert any("host_callback" in m for m in msgs)
    bad.write_text("import jax\n\ndef f(x):\n    return x + 1\n")
    assert _guard5_findings(tmp_path / "src") == []


def test_ci_guards_clean_on_repo():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "ci_guards.py")],
        capture_output=True, text=True, cwd=str(ROOT),
        env=dict(os.environ, PYTHONPATH=str(ROOT / "src")),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 error(s)" in proc.stdout


# --------------------------------------------------------------------------
# §17: fixed-bucket histogram quantiles
# --------------------------------------------------------------------------

def test_histogram_quantiles_monotone_and_upper_bound():
    from repro.obs.metrics import Histogram

    vals = [0.2, 0.4, 0.9, 3.0, 7.0, 40.0, 90.0, 400.0, 2000.0, 9000.0,
            20000.0]   # last one lands in the +Inf overflow bucket
    h = Histogram("lat")
    for v in vals:
        h.observe(v)
    # monotone in q
    qs = [h.quantile(q) for q in (0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0)]
    assert qs == sorted(qs)
    # upper-bound property: never below the true q-th ranked observation
    s = sorted(vals)
    for q in (0.1, 0.5, 0.9, 0.95, 0.99):
        rank = max(int(-(-q * len(s) // 1)), 1)
        assert h.quantile(q) >= s[rank - 1], q
    # ... and never above the observed max (overflow reports the max)
    assert h.quantile(0.99) <= max(vals)
    assert h.quantile(1.0) == max(vals)
    # empty histogram: None quantiles, count-0 snapshot
    empty = Histogram("e")
    assert empty.quantile(0.5) is None
    snap = empty.snapshot()
    assert snap["count"] == 0 and snap["p99"] is None


def test_histogram_merge_across_registries():
    from repro.obs import MetricsRegistry
    from repro.obs.metrics import Histogram

    a, b = MetricsRegistry("a"), MetricsRegistry("b")
    for v in (1.0, 2.0):
        a.histogram("lat").observe(v)
    for v in (300.0, 700.0):
        b.histogram("lat").observe(v)
    a.counter("n").inc(2)
    b.counter("n").inc(3)
    b.gauge("depth").set(9)
    a.merge(b)
    snap = a.snapshot()
    assert snap["n"] == 5                       # counters add
    assert snap["depth"] == 9.0                 # gauges take the last value
    h = snap["lat"]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 700.0
    assert h["p99"] == 700.0
    # merging a different bucket scheme would silently mis-bin: refuse
    other = Histogram("lat", buckets=(1.0, 10.0))
    with pytest.raises(ValueError):
        a.histogram("lat").merge(other)


# --------------------------------------------------------------------------
# §17: bench history + bench-diff
# --------------------------------------------------------------------------

def test_write_bench_stamps_and_appends_history(tmp_path):
    from repro.obs.bench import load_records, write_bench

    hist = str(tmp_path / "hist")
    doc = dict(bench="t", backend="fake", results=[
        dict(op="a", n=4, us_per_call=5.0, rounds=3),
        dict(op="b", n=4, solve_ms=2.0, mis_size=7),
    ])
    out = write_bench(doc, str(tmp_path / "snap.json"), history_dir=hist)
    # stamp fills the header but never overwrites the bench's own fields
    assert out["schema_version"] == 1 and out["backend"] == "fake"
    assert out["git_sha"] and out["timestamp"] and out["jax_version"]
    snap = json.loads((tmp_path / "snap.json").read_text())
    assert snap["bench"] == "t" and snap["git_sha"] == out["git_sha"]
    recs = load_records(hist)
    assert len(recs) == 2
    by_metric = {r["metric"]: r for r in recs}
    # values normalised to µs; outcome fields stay out of the identity key
    assert by_metric["us_per_call"]["value_us"] == 5.0
    assert by_metric["solve_ms"]["value_us"] == 2000.0
    assert "rounds" not in by_metric["us_per_call"]["key"]
    assert "op=a" in by_metric["us_per_call"]["key"]
    # append-only: a second write grows the file
    write_bench(doc, str(tmp_path / "snap.json"), history_dir=hist)
    assert len(load_records(hist)) == 4
    # empty history dir string disables the append, snapshot still written
    write_bench(doc, str(tmp_path / "snap2.json"), history_dir="")
    assert (tmp_path / "snap2.json").exists()


def _bench_records(value_us, metric="us_per_call", key="bench=t op=a", k=1):
    return [dict(schema=1, bench="t", key=key, metric=metric,
                 value_us=v) for v in ([value_us] * k)]


def test_bench_diff_verdicts_and_bars():
    from repro.obs.bench import diff

    base = _bench_records(1000.0)
    # small drift: inside both bars -> same
    assert diff(base, _bench_records(1100.0))["status"] == "ok"
    # 2.5x slowdown: both bars trip -> regression
    rep = diff(base, _bench_records(2500.0))
    assert rep["status"] == "regression"
    assert rep["regressions"][0]["ratio"] == 2.5
    # mirrored improvement: reported, never failing
    rep = diff(base, _bench_records(300.0))
    assert rep["status"] == "ok" and len(rep["improvements"]) == 1
    # micro-kernel jitter: 1.9x relative but under the 200us floor -> same
    rep = diff(_bench_records(100.0), _bench_records(190.0))
    assert rep["status"] == "ok" and not rep["regressions"]
    # slow op drifting a few percent: over the floor, under the bar -> same
    rep = diff(_bench_records(100000.0), _bench_records(110000.0))
    assert rep["status"] == "ok" and not rep["regressions"]
    # median-of-k: one noisy outlier run must not gate
    noisy = (_bench_records(1000.0) + _bench_records(1000.0)
             + _bench_records(5000.0))
    rep = diff(noisy, _bench_records(1010.0))
    assert rep["status"] == "ok"
    assert rep["rows"][0]["base_us"] == 1000.0      # the median, not the max
    # disjoint keys must fail loudly, not pass vacuously
    rep = diff(base, _bench_records(1000.0, key="bench=t op=OTHER"))
    assert rep["status"] == "no-overlap"


def test_bench_diff_cli_exit_codes(tmp_path, capsys):
    from repro.obs.bench import main as bench_main

    def _write(name, records):
        p = tmp_path / name
        p.write_text("".join(json.dumps(r) + "\n" for r in records))
        return str(p)

    base = _write("base.jsonl", _bench_records(1000.0))
    same = _write("same.jsonl", _bench_records(1050.0))
    slow = _write("slow.jsonl", _bench_records(2000.0))
    other = _write("other.jsonl", _bench_records(1000.0, key="bench=u op=z"))
    assert bench_main([base, same]) == 0
    assert bench_main([base, slow]) == 1           # synthetic 2x slowdown
    assert bench_main([base, other]) == 2          # mis-pointed baseline
    # the report CLI front door dispatches the subcommand too
    assert report_main(["bench-diff", base, same]) == 0
    assert report_main(["bench-diff", base, slow, "--json"]) == 1
    out = capsys.readouterr().out
    assert '"status": "regression"' in out
    # raising the relative bar clears the 2x verdict
    assert bench_main([base, slow, "--rel-bar", "1.5"]) == 0


# --------------------------------------------------------------------------
# §17: Prometheus text exposition
# --------------------------------------------------------------------------

def test_promtext_rendering_and_atomic_write(tmp_path):
    from repro.obs import MetricsRegistry, to_promtext, write_promtext

    reg = MetricsRegistry("t")
    reg.counter("svc.requests").inc(3)
    reg.gauge("svc.queue_depth").set(1.5)
    reg.histogram("svc.latency_ms").observe(2.0)
    txt = to_promtext(reg.snapshot())
    assert "# TYPE repro_svc_requests_total counter" in txt
    assert "repro_svc_requests_total 3" in txt
    assert "repro_svc_queue_depth 1.5" in txt
    assert 'repro_svc_latency_ms_bucket{le="2.5"} 1' in txt
    assert 'repro_svc_latency_ms_bucket{le="+Inf"} 1' in txt
    assert "repro_svc_latency_ms_sum 2.0" in txt
    assert "repro_svc_latency_ms_count 1" in txt
    assert 'repro_svc_latency_ms{quantile="0.99"} 2.0' in txt
    assert txt.endswith("\n")
    path = tmp_path / "metrics.prom"
    write_promtext(reg.snapshot(), str(path))
    assert path.read_text() == txt
    assert list(tmp_path.iterdir()) == [path]      # no tmp file left behind


# --------------------------------------------------------------------------
# §17: service health (SLO histograms, gauges, span stages) + drift + roofline
# --------------------------------------------------------------------------

def test_service_health_drift_and_attribution(tmp_path):
    from repro.dyngraph import random_delta

    before_epochs = REGISTRY.snapshot().get("dyngraph.epochs", 0)
    svc = MISService(ServeConfig(
        engine="tiled_ref", max_batch=2, repair="incremental",
        telemetry=True, trace_path=str(tmp_path / "trace.jsonl"),
    ))
    svc.submit(_graph(n=96, seed=21))
    svc.submit(_graph(n=96, seed=22))
    responses = svc.drain()
    assert all(r.valid for r in responses)
    # a chained delta stream: each update targets the previous one
    target = responses[0].id
    for step in (1, 2):
        plan = svc._results[target].plan
        delta = random_delta(plan.g, n_add=4, n_remove=4, seed=step)
        target = svc.submit_update(target, delta)
        (r,) = svc.drain()
        assert r.valid

    snap = svc.metrics_snapshot()
    # per-op SLO latency histograms (enqueue -> response)
    assert snap["service.latency_ms.batched"]["count"] == 2
    assert snap["service.latency_ms.update"]["count"] == 2
    for op in ("batched", "update"):
        h = snap[f"service.latency_ms.{op}"]
        assert h["p50"] <= h["p95"] <= h["p99"] <= h["max"] * 1.0 + 1e-9 \
            or h["p99"] == h["max"]
    # health gauges settle to empty after drain
    assert snap["service.queue_depth"] == 0.0
    assert snap["service.inflight"] == 0.0
    # span-taxonomy stage histograms (traced steps only): one span per
    # worker step — the solve batch plus each update's own window
    assert snap["service.span_ms.service.step"]["count"] == 3
    assert "service.span_ms.service.batch" in snap
    assert "service.span_ms.solver.update" in snap
    # drift metrics: one epoch recorded per applied delta, via patch_plan
    assert snap["dyngraph.epochs"] == before_epochs + 2
    assert snap["dyngraph.touched_tiles"]["count"] >= 2
    assert snap["dyngraph.epoch"] == 2.0
    assert snap["dyngraph.occupancy"] > 0.0
    assert 0.0 < snap["dyngraph.dirty_frac"] <= 1.0
    assert "dyngraph.locality_decay" in snap
    # roofline attribution gauges fed from the measured solve
    assert snap["perf.roofline_predicted_us"] > 0.0
    assert snap["perf.roofline_measured_us"] > 0.0
    assert "perf.roofline_error_pct" in snap


def test_drift_helpers():
    from repro.dyngraph.delta import EdgeDelta
    from repro.dyngraph.drift import (
        dirty_vertex_frac,
        tile_occupancy,
        touched_tile_count,
    )

    # (0,1) lives in tile (0,0); (40,41) in tile (1,1) of a 2x2 block grid
    delta = EdgeDelta.make([0, 40], [1, 41], [], [])
    assert touched_tile_count(delta, tile_size=32, n_block_cols=2) == 2
    # a cross-block edge dirties both half-edge tiles
    cross = EdgeDelta.make([0], [40], [], [])
    assert touched_tile_count(cross, tile_size=32, n_block_cols=2) == 2
    assert touched_tile_count(EdgeDelta.make(), 32, 2) == 0
    assert dirty_vertex_frac(delta, 64) == pytest.approx(4 / 64)
    assert dirty_vertex_frac(EdgeDelta.make(), 64) == 0.0
    assert tile_occupancy(4, 4, 32) == pytest.approx(8 / (4 * 32 * 32))
    assert tile_occupancy(0, 4, 32) == 0.0


def test_plan_carries_occupancy0_through_patches():
    from repro.dyngraph.delta import EdgeDelta

    solver = Solver(_opts("tiled_ref", "int8", "auto", False,
                          repair="incremental"))
    g = _graph(n=96, seed=23)
    res = solver.solve(g)
    occ0 = res.plan.occupancy0
    assert occ0 > 0.0
    res2 = solver.update(res, EdgeDelta.make([0, 7], [5, 9], [], []))
    # the epoch-0 baseline rides through the patch lineage unchanged
    assert res2.plan.occupancy0 == occ0
    assert res2.plan.epoch == 1


# --------------------------------------------------------------------------
# §17: report CLI — degenerate traces and --json
# --------------------------------------------------------------------------

def test_report_handles_degenerate_traces_and_json(tmp_path, capsys):
    from repro.obs.report import report_json

    # 1-round trace with 0 alive everywhere: no div-by-zero sparklines
    rt1 = RoundTrace.from_buffer(_fake_buffer([(0, 0, 0, 0)]), 1,
                                 tiles_total=0)
    path = tmp_path / "degenerate.jsonl"
    path.write_text(rt1.to_jsonl_line() + "\n")
    assert report_main(["report", str(path)]) == 0
    capsys.readouterr()
    assert report_main(["report", "--json", str(path)]) == 0
    d = json.loads(capsys.readouterr().out)
    assert d["n_records"] == 1 and d["counts"] == {"rounds": 1}
    doc = report_json(str(path))
    assert doc["records"][0]["summary"]["rounds"] == 1
    # bench-history records render through the report CLI too
    hist = tmp_path / "hist.jsonl"
    hist.write_text("".join(
        json.dumps(r) + "\n" for r in _bench_records(123.0)
    ))
    assert report_main(["report", str(hist)]) == 0
    out = capsys.readouterr().out
    assert "us_per_call" in out

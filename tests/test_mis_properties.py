"""Property-based MIS correctness: every algorithm × heuristic must produce
a set that is (a) independent and (b) maximal, on arbitrary graphs — checked
both by our validators and against networkx ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import networkx as nx
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    TCMISConfig,
    build_block_tiles,
    cardinality,
    ecl_mis,
    is_independent,
    is_maximal,
    luby_mis,
    tc_mis,
)
from repro.graphs.graph import from_edges, to_networkx


def _random_graph(n, density, seed):
    rng = np.random.default_rng(seed)
    m = int(density * n * (n - 1) / 2)
    src = rng.integers(0, n, max(m, 1))
    dst = rng.integers(0, n, max(m, 1))
    return from_edges(src, dst, n)


def _assert_valid(g, in_mis):
    assert is_independent(g, in_mis), "adjacent vertices both selected"
    assert is_maximal(g, in_mis), "an unselected vertex has no selected neighbour"
    # cross-check against networkx on the same graph
    G = to_networkx(g)
    sel = set(np.flatnonzero(np.asarray(in_mis)).tolist())
    for u, v in G.edges():
        assert not (u in sel and v in sel)
    for v in G.nodes():
        if v not in sel:
            assert any(u in sel for u in G.neighbors(v)), f"{v} uncovered"


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(5, 120),
    density=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_luby_property(n, density, seed):
    g = _random_graph(n, density, seed)
    res = luby_mis(g, jax.random.key(seed))
    assert bool(res.converged)
    _assert_valid(g, res.in_mis)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(5, 120),
    density=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_ecl_property(n, density, seed):
    g = _random_graph(n, density, seed)
    res = ecl_mis(g, jax.random.key(seed))
    assert bool(res.converged)
    _assert_valid(g, res.in_mis)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(5, 100),
    density=st.floats(0.01, 0.4),
    seed=st.integers(0, 2**31 - 1),
    heuristic=st.sampled_from(["h1", "h2", "h3", "ecl"]),
    tile=st.sampled_from([16, 32]),
    phase1=st.sampled_from(["segment", "tiled"]),
)
def test_tcmis_property(n, density, seed, heuristic, tile, phase1):
    g = _random_graph(n, density, seed)
    tiled = build_block_tiles(g, tile_size=tile)
    res = tc_mis(
        g, tiled, jax.random.key(seed),
        TCMISConfig(heuristic=heuristic, phase1=phase1),
    )
    assert bool(res.converged)
    _assert_valid(g, res.in_mis)


def test_tc_equals_ecl_bitwise():
    """Same priorities ⇒ TC-MIS and ECL-MIS must agree bit-for-bit."""
    for seed in range(5):
        g = _random_graph(300, 0.05, seed)
        tiled = build_block_tiles(g, tile_size=32)
        key = jax.random.key(seed)
        r_ecl = ecl_mis(g, key)
        r_tc = tc_mis(g, tiled, key, TCMISConfig(heuristic="ecl"))
        assert bool(jnp.all(r_ecl.in_mis == r_tc.in_mis))


def test_pallas_backend_equals_ref():
    for seed in range(3):
        g = _random_graph(200, 0.08, seed)
        tiled = build_block_tiles(g, tile_size=32)
        key = jax.random.key(seed)
        r_ref = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3", backend="ref", phase1="tiled"))
        r_pal = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3", backend="pallas", phase1="tiled"))
        assert bool(jnp.all(r_ref.in_mis == r_pal.in_mis))


def test_quality_ordering_matches_paper():
    """Fig. 3: H1 clearly below degree-aware heuristics; H3 ≈ ECL."""
    from repro.graphs.generators import powerlaw

    g = powerlaw(3000, avg_deg=6.0, seed=0)
    tiled = build_block_tiles(g, tile_size=64)
    cards = {}
    for h in ["h1", "h2", "h3", "ecl"]:
        res = tc_mis(g, tiled, jax.random.key(0), TCMISConfig(heuristic=h))
        cards[h] = cardinality(res.in_mis)
    assert cards["h1"] < cards["h3"], cards
    assert abs(cards["h3"] - cards["ecl"]) / cards["ecl"] < 0.05, cards


def test_empty_and_complete_graphs():
    # empty graph: MIS = all vertices
    g = from_edges(np.array([], np.int64), np.array([], np.int64), 10)
    res = luby_mis(g, jax.random.key(0))
    assert cardinality(res.in_mis) == 10
    # complete graph: MIS = exactly one vertex
    n = 12
    src, dst = np.triu_indices(n, 1)
    g = from_edges(src, dst, n)
    tiled = build_block_tiles(g, tile_size=16)
    res = tc_mis(g, tiled, jax.random.key(0), TCMISConfig(heuristic="h3"))
    assert cardinality(res.in_mis) == 1
    assert is_maximal(g, res.in_mis)

"""Training substrate: optimizer, checkpointing (incl. corruption recovery),
compression, data determinism, fault-tolerant loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.train import (
    LoopConfig,
    OptConfig,
    TrainLoop,
    adamw_init,
    adamw_update,
    checkpoint as ckpt,
    compress_with_error_feedback,
    ef_init,
    schedule,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, m = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clipping():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0, total_steps=10)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, grads, opt, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    s = [float(schedule(cfg, jnp.int32(i))) for i in [0, 5, 10, 50, 100]]
    assert s[0] == 0.0 and s[1] == 0.5 and s[2] == pytest.approx(1.0)
    assert s[3] < 1.0 and s[4] == pytest.approx(0.1, rel=1e-3)


def test_zero1_specs():
    from jax.sharding import PartitionSpec as P

    from repro.train.optimizer import zero1_specs

    params = {"a": jnp.zeros((64, 8)), "b": jnp.zeros((7,))}
    specs = {"a": P(None, "model"), "b": P(None)}
    z = zero1_specs(specs, params, mesh_axis="data", mesh_size=16)
    assert z["a"] == P("data", "model")   # largest divisible free axis sharded
    assert z["b"] == P(None)              # 7 not divisible -> untouched


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (32, 16)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    out = ckpt.restore(str(tmp_path), 3)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_fallback(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree(1))
    ckpt.save(str(tmp_path), 2, _tree(2))
    # corrupt step 2's first leaf payload
    d = os.path.join(str(tmp_path), "step_00000002", "arrays")
    victim = os.path.join(d, sorted(os.listdir(d))[0])
    with open(victim, "r+b") as f:
        f.seek(4)
        f.write(b"\xde\xad\xbe\xef")
    step, tree = ckpt.restore_latest(str(tmp_path))
    assert step == 1, "must fall back past the corrupted checkpoint"
    for a, b in zip(jax.tree.leaves(_tree(1)), jax.tree.leaves(tree)):
        assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_gc(tmp_path):
    for s in range(6):
        ckpt.save(str(tmp_path), s, {"x": jnp.float32(s)})
    ckpt.garbage_collect(str(tmp_path), keep=2)
    assert ckpt.available_steps(str(tmp_path)) == [4, 5]


def test_tmp_dirs_not_picked_up(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.float32(1)})
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_error_feedback_lossless_over_time():
    """EF guarantees Σ applied = Σ true grads (up to the residual in flight)."""
    grads = {"w": jax.random.normal(jax.random.key(0), (128,))}
    ef = ef_init(grads)
    applied_sum = jnp.zeros((128,))
    true_sum = jnp.zeros((128,))
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.key(i), (128,))}
        applied, ef = compress_with_error_feedback(g, ef, ratio=0.1)
        applied_sum += applied["w"]
        true_sum += g["w"]
    resid = np.asarray(true_sum - applied_sum)
    assert_allclose(resid, np.asarray(ef["w"]), rtol=1e-4, atol=1e-4)


def test_compression_ratio_bytes():
    from repro.train.compression import compress_tree, compressed_bytes

    grads = {"w": jax.random.normal(jax.random.key(0), (1000,))}
    comp = compress_tree(grads, ratio=0.05)
    assert compressed_bytes(comp) == 50 * 8   # 50 values + 50 indices


def test_compressed_training_converges():
    params = {"w": jnp.asarray([4.0, -4.0, 4.0, -4.0])}
    opt = adamw_init(params)
    ef = ef_init(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=300, weight_decay=0.0)
    for _ in range(250):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        grads, ef = compress_with_error_feedback(grads, ef, ratio=0.25)
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_stream_determinism_and_resume():
    from repro.data.pipeline import TokenStream

    s1 = TokenStream(100, 4, 16, seed=7)
    s2 = TokenStream(100, 4, 16, seed=7)
    a, _ = s1.batch_at(42)
    b, _ = s2.batch_at(42)
    np.testing.assert_array_equal(a, b)
    c, _ = s1.batch_at(43)
    assert not np.array_equal(a, c)


def test_prefetch_preserves_order():
    from repro.data.pipeline import prefetch

    out = list(prefetch(iter(range(20)), size=4))
    assert out == list(range(20))


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

class _QuadStream:
    def batch_at(self, step):
        rng = np.random.default_rng(step)
        return rng.standard_normal(4).astype(np.float32)


def _make_loop(tmp, **kw):
    opt_cfg = OptConfig(lr=0.05, warmup_steps=0, total_steps=1000, weight_decay=0.0)

    @jax.jit
    def raw(params, opt, x):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - x) ** 2)
        )(params)
        params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss

    def step_fn(state, batch):
        params, opt = state
        params, opt, loss = raw(params, opt, jnp.asarray(batch))
        return (params, opt), {"loss": loss}

    params = {"w": jnp.zeros((4,))}
    return TrainLoop(
        step_fn=step_fn,
        init_state=(params, adamw_init(params)),
        stream=_QuadStream(),
        cfg=LoopConfig(ckpt_dir=str(tmp), checkpoint_every=10, **kw),
    )


def test_loop_checkpoints_and_resumes_bitwise(tmp_path):
    loop1 = _make_loop(tmp_path / "a")
    res1 = loop1.run(25)
    w_straight = np.asarray(loop1.state[0]["w"])

    # same run, interrupted at 20 then resumed
    loop2a = _make_loop(tmp_path / "b")
    loop2a.run(20)
    loop2b = _make_loop(tmp_path / "b")    # fresh process restores step 19
    assert loop2b.start_step == 20
    loop2b.run(5)
    w_resumed = np.asarray(loop2b.state[0]["w"])
    np.testing.assert_array_equal(w_straight, w_resumed)


def test_loop_recovers_from_node_failure(tmp_path):
    loop = _make_loop(tmp_path)
    boom = {"armed": True}

    def fail_hook(step):
        if step == 13 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated ICI timeout / node loss")

    res = loop.run(30, fail_hook=fail_hook)
    assert res["recoveries"] >= 1
    assert res["final_step"] == 29
    assert np.isfinite(res["metrics"]["loss"])

"""Bitwise frontier mode (DESIGN.md §13): packed word round-trips, the
popcount SpMV and clz neighbour-max against their densifying oracles, the
Pallas bits kernels, the `resolve_frontier` policy, and end-to-end
bit-identity of every engine × storage × frontier combination."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.api import SolveOptions, Solver
from repro.core.engine import (
    engine_names,
    get_engine,
    resolve_frontier,
    tile_neighbor_max,
    tile_neighbor_max_bits,
    tile_spmv,
    tile_spmv_bits,
)
from repro.core.spmv import _NEG
from repro.core.tiling import (
    build_block_tiles,
    pack_frontier_words,
    pack_priority_planes,
    sort_block_priorities,
    sorted_tile_bits,
    sorted_frontier_words,
    tiles_as_words,
    unpack_frontier_words,
)
from repro.core.validate import is_valid_mis_jit
from repro.dyngraph import random_delta
from repro.graphs.generators import erdos_renyi, powerlaw
from repro.kernels import ops, ref

TILE_ENGINES = tuple(
    e for e in engine_names() if get_engine(e).supports_bitwise
)


# --------------------------------------------------------------------------
# the packing contract
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    T=st.sampled_from([8, 16, 32, 64, 128, 256]),
    k=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
def test_frontier_words_roundtrip(T, k, seed):
    """pack∘unpack is the identity for every tile size, and the word count
    follows the (n_tiles, W) shape contract with W = max(T//32, 1)."""
    n = k * T
    x = jax.random.uniform(jax.random.key(seed), (n,)) > 0.5
    w = pack_frontier_words(x, T)
    assert w.dtype == jnp.uint32
    assert w.shape == (k, max(T // 32, 1))
    assert bool(jnp.all(unpack_frontier_words(w, T) == x))


def test_frontier_words_bit_layout():
    """Bit j of word w is vertex slot 32·w + j — the layout the popcount
    SpMV's word-AND against packed tile columns depends on."""
    T = 64
    x = np.zeros(T, dtype=bool)
    x[0], x[31], x[32], x[63] = True, True, True, True
    w = np.asarray(pack_frontier_words(jnp.asarray(x), T))
    assert w.shape == (1, 2)
    assert w[0, 0] == (1 | (1 << 31)) and w[0, 1] == (1 | (1 << 31))


# --------------------------------------------------------------------------
# raw ops vs the densifying oracles (kernels/ref.py)
# --------------------------------------------------------------------------

def _graph_and_words(n=230, T=16, seed=0, p_cand=0.5):
    g = erdos_renyi(n, avg_deg=6.0, seed=seed)
    t = build_block_tiles(g, tile_size=T).to_storage("bitpack")
    cand = jax.random.uniform(jax.random.key(seed + 1), (t.n_padded,)) > p_cand
    return g, t, pack_frontier_words(cand, T)


@pytest.mark.parametrize("with_flags", [False, True])
def test_spmv_bits_matches_ref_oracle(with_flags):
    _, t, cand_w = _graph_and_words()
    T = t.tile_size
    tw = tiles_as_words(t.tiles, T)
    flags = None
    if with_flags:
        flags = (jnp.arange(t.n_block_rows) % 2).astype(jnp.int32)
    got = tile_spmv_bits(
        tw, t.tile_rows, t.tile_cols, cand_w, t.n_block_rows, T,
        col_flags=flags,
    )
    want = ref.tc_spmv_bits_ref(
        t.tiles, t.tile_rows, t.tile_cols, cand_w, t.n_block_rows,
        col_flags=flags,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("signed", [False, True])
def test_neighbor_max_bits_matches_dense(signed):
    """The clz/sorted-priority formulation equals the dense masked max for
    both priority regimes: non-negative select values and the negative
    resolve keys (-deg·n - id)."""
    _, t, mask_w = _graph_and_words(seed=3, p_cand=0.35)
    T = t.tile_size
    if signed:
        p = -jax.random.randint(
            jax.random.key(9), (t.n_padded,), 1, 1 << 24, dtype=jnp.int32
        )
    else:
        p = jax.random.randint(
            jax.random.key(9), (t.n_padded,), 0, 1 << 20, dtype=jnp.int32
        )
    order, p_sorted = sort_block_priorities(p, T)
    tiles_sorted = sorted_tile_bits(t.tiles, t.tile_cols, order, T)
    got = tile_neighbor_max_bits(
        tiles_sorted, t.tile_rows, t.tile_cols, p_sorted,
        sorted_frontier_words(mask_w, order, T), t.n_block_rows, T,
    )
    mask = unpack_frontier_words(mask_w, T)
    want = tile_neighbor_max(
        t.to_storage("int8").tiles, t.tile_rows, t.tile_cols,
        jnp.where(mask, p, _NEG), t.n_block_rows, T,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    want_ref = ref.tc_neighbor_max_bits_ref(
        t.tiles, t.tile_rows, t.tile_cols, p, mask_w, t.n_block_rows
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_ref))


# --------------------------------------------------------------------------
# the Pallas bits kernels (interpret mode off-TPU) vs the jnp substrate
# --------------------------------------------------------------------------

def test_kernel_spmv_bits_matches_op():
    _, t, cand_w = _graph_and_words(n=140, T=8, seed=5)
    T = t.tile_size
    got = ops.tc_spmv_bits(t, cand_w)
    want = tile_spmv_bits(
        tiles_as_words(t.tiles, T), t.tile_rows, t.tile_cols, cand_w,
        t.n_block_rows, T,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("signed", [False, True])
def test_kernel_neighbor_max_bits_matches_op(signed):
    """The plane-scan kernel (the TPU form) equals the clz jnp op —
    including the sign-bias trick for the negative resolve keys."""
    _, t, mask_w = _graph_and_words(n=140, T=8, seed=6, p_cand=0.3)
    T = t.tile_size
    if signed:
        p = -jax.random.randint(
            jax.random.key(11), (t.n_padded,), 1, 1 << 24, dtype=jnp.int32
        )
        planes = pack_priority_planes(p, T, 32, signed=True)
    else:
        p = jax.random.randint(
            jax.random.key(11), (t.n_padded,), 0, 1 << 20, dtype=jnp.int32
        )
        planes = pack_priority_planes(p, T, 31)
    got = ops.tc_neighbor_max_bits(t, planes, mask_w, signed=signed)
    order, p_sorted = sort_block_priorities(p, T)
    want = tile_neighbor_max_bits(
        sorted_tile_bits(t.tiles, t.tile_cols, order, T),
        t.tile_rows, t.tile_cols, p_sorted,
        sorted_frontier_words(mask_w, order, T), t.n_block_rows, T,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_fused_bits_matches_split():
    _, t, cand_w = _graph_and_words(n=140, T=8, seed=7)
    T = t.tile_size
    alive = jax.random.uniform(jax.random.key(8), (t.n_padded,)) > 0.2
    alive_w = pack_frontier_words(alive, T) | cand_w
    hit, new_alive, mis_add = ops.tc_spmv_fused_bits(t, cand_w, alive_w)
    hit_want = ops.tc_spmv_bits(t, cand_w)
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(hit_want))
    np.testing.assert_array_equal(
        np.asarray(new_alive), np.asarray(alive_w & ~cand_w & ~hit_want)
    )
    np.testing.assert_array_equal(np.asarray(mis_add), np.asarray(cand_w))


# --------------------------------------------------------------------------
# resolve_frontier policy
# --------------------------------------------------------------------------

def test_resolve_frontier_policy():
    tiled_eng = get_engine("tiled_ref")
    seg_eng = get_engine("segment")

    def cfg(frontier="auto", phase1="tiled"):
        return SolveOptions(frontier=frontier, phase1=phase1)

    # auto: bitwise exactly on (tile engine, tiled ①, bitpack, scalar rnd)
    assert resolve_frontier(cfg(), tiled_eng, storage="bitpack") == "bitwise"
    assert resolve_frontier(cfg(), tiled_eng, storage="int8") == "dense"
    assert resolve_frontier(cfg(), seg_eng, storage="bitpack") == "dense"
    assert resolve_frontier(
        cfg(phase1="segment"), tiled_eng, storage="bitpack"
    ) == "dense"
    assert resolve_frontier(
        cfg(), tiled_eng, storage="bitpack", member_rounds=True
    ) == "dense"
    # explicit bitwise falls back (never errors) where it can't be honoured
    assert resolve_frontier(
        cfg("bitwise"), seg_eng, storage="bitpack"
    ) == "dense"
    assert resolve_frontier(
        cfg("bitwise"), tiled_eng, storage="bitpack", member_rounds=True
    ) == "dense"
    assert resolve_frontier(
        cfg("bitwise"), tiled_eng, storage="int8"
    ) == "bitwise"
    # explicit dense always wins
    assert resolve_frontier(cfg("dense"), tiled_eng, storage="bitpack") == "dense"


def test_solve_options_rejects_unknown_frontier():
    with pytest.raises(ValueError):
        SolveOptions(frontier="packed")


# --------------------------------------------------------------------------
# end-to-end bit-identity: engines × storages × frontier modes
# --------------------------------------------------------------------------

def _baseline(g, T=16):
    return Solver(SolveOptions(
        engine="tiled_ref", tile_size=T, storage="int8", frontier="dense",
        seed=0,
    )).solve(g)


@pytest.mark.parametrize("engine", engine_names())
@pytest.mark.parametrize("storage", ["int8", "bitpack"])
@pytest.mark.parametrize("frontier", ["auto", "dense", "bitwise"])
def test_solve_bit_identical_across_frontier_modes(engine, storage, frontier):
    g = powerlaw(150, avg_deg=5.0, seed=21)
    base = _baseline(g)
    res = Solver(SolveOptions(
        engine=engine, tile_size=16, storage=storage, frontier=frontier,
        seed=0, placement="local",
    )).solve(g)
    np.testing.assert_array_equal(res.in_mis, base.in_mis)
    assert res.rounds == base.rounds
    assert is_valid_mis_jit(g, jnp.asarray(res.in_mis))


@pytest.mark.parametrize("heuristic", ["h1", "h3"])
def test_bitwise_matches_dense_per_heuristic(heuristic):
    """One- and two-pass phase ① both survive the packed round body."""
    g = erdos_renyi(260, avg_deg=7.0, seed=22)
    runs = [
        Solver(SolveOptions(
            engine="fused_pallas", tile_size=32, storage="bitpack",
            heuristic=heuristic, frontier=f, seed=1, placement="local",
        )).solve(g)
        for f in ("dense", "bitwise")
    ]
    np.testing.assert_array_equal(runs[0].in_mis, runs[1].in_mis)
    assert runs[0].rounds == runs[1].rounds


def test_solve_many_bitwise_request_falls_back_bit_identical():
    """Batched members carry per-member round vectors, so the packed state
    cannot honour bitwise — the run must silently use dense and match."""
    graphs = [erdos_renyi(60 + 17 * i, avg_deg=5.0, seed=i) for i in range(3)]
    dense = Solver(SolveOptions(
        engine="tiled_ref", tile_size=8, storage="bitpack", frontier="dense",
    )).solve_many(graphs)
    bitw = Solver(SolveOptions(
        engine="tiled_ref", tile_size=8, storage="bitpack", frontier="bitwise",
    )).solve_many(graphs)
    for rd, rb in zip(dense, bitw):
        np.testing.assert_array_equal(rd.in_mis, rb.in_mis)
        assert rd.rounds == rb.rounds


@pytest.mark.parametrize("engine", TILE_ENGINES)
def test_update_repair_bit_identical_dense_vs_bitwise(engine):
    """The warm re-entry (packed seed state, `_covered_bits` SpMV) repairs
    to the same MIS the dense warm state does, on every tile engine."""
    g = erdos_renyi(120, avg_deg=6.0, seed=23)
    results = []
    for frontier in ("dense", "bitwise"):
        solver = Solver(SolveOptions(
            engine=engine, tile_size=16, storage="bitpack",
            frontier=frontier, repair="incremental", seed=2,
            placement="local",
        ))
        prior = solver.solve(g)
        d = random_delta(g, n_add=5, n_remove=5, seed=24)
        res = solver.update(prior, d)
        assert res.stats["repair"] == "incremental"
        assert all(is_valid_mis_jit(res.plan.g, jnp.asarray(res.in_mis_plan)))
        results.append(res)
    np.testing.assert_array_equal(results[0].in_mis, results[1].in_mis)

"""shard_map expert-parallel MoE: equivalence with the pjit formulation and
collective-profile check (one psum vs the GSPMD gather chain)."""
from conftest import run_multidevice


def test_shardmap_moe_matches_pjit_moe():
    out = run_multidevice("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.models.lm_config import MoEConfig
        from repro.models.moe import moe_ffn
        from repro.models.moe_shardmap import moe_ffn_shardmap

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        # ample capacity => no drops => per-sender and global ranking agree
        cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
        N, D = 64, 16
        key = jax.random.key(0)
        params = {
            "router": jax.random.normal(jax.random.key(1), (D, 8)),
            "we1": jax.random.normal(jax.random.key(2), (8, D, 32)) * 0.1,
            "we3": jax.random.normal(jax.random.key(3), (8, D, 32)) * 0.1,
            "we2": jax.random.normal(jax.random.key(4), (8, 32, D)) * 0.1,
            "ws1": jax.random.normal(jax.random.key(5), (D, 32)) * 0.1,
            "ws3": jax.random.normal(jax.random.key(6), (D, 32)) * 0.1,
            "ws2": jax.random.normal(jax.random.key(7), (32, D)) * 0.1,
        }
        x = jax.random.normal(jax.random.key(8), (N, D))

        ref, _ = moe_ffn(params, x, cfg, "swiglu")
        with mesh:
            out = jax.jit(
                lambda p, x: moe_ffn_shardmap(p, x, cfg, "swiglu", mesh)
            )(params, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

        # gradients flow through the shard_map dispatch
        g = jax.jit(jax.grad(
            lambda p: moe_ffn_shardmap(p, x, cfg, "swiglu", mesh).sum()
        ))(params)
        assert all(np.all(np.isfinite(np.asarray(v)))
                   for v in jax.tree.leaves(g))

        # collective profile: ONE all-reduce (psum) and nothing else
        import re
        with mesh:
            txt = jax.jit(
                lambda p, x: moe_ffn_shardmap(p, x, cfg, "swiglu", mesh)
            ).lower(params, x).compile().as_text()
        colls = re.findall(
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt)
        kinds = set(colls)
        assert "all-reduce" in kinds, kinds
        assert "all-gather" not in kinds and "all-to-all" not in kinds, kinds
        print("SHARDMAP_MOE_OK", sorted(kinds))
    """)
    assert "SHARDMAP_MOE_OK" in out

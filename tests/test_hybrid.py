"""Per-tile hybrid execution (DESIGN.md §16): nnz classification, compacted
dense/sparse routing, and its plumbing through every front-door route.

The load-bearing contract is BIT-IDENTITY: routing is an execution-plan
choice, so `hybrid="forced"` must return exactly the dense-only solution for
every engine × storage × frontier combination — partitioning never changes
what is computed, only where.  On top of that: partition invariants (the two
compacted lists tile the stored nonzeros exactly), plan-cache v3 persistence
(policy re-attached on load, off-mode keys byte-identical to v2), the auto
gate, delta-driven reclassification (tiles crossing the nnz threshold in
either direction), and the batched / repair routes.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.api import Plan, PlanCache, SolveOptions, Solver, patch_plan
from repro.api.plan import (
    _PLAN_VERSION,
    build_plan,
    plan_cache_key,
    resolve_hybrid_threshold,
)
from repro.core.tiling import (
    attach_partition,
    build_block_tiles,
    partition_tiles,
    tile_nnz,
)
from repro.core.validate import is_valid_mis_jit
from repro.dyngraph import EdgeDelta, apply_delta, apply_graph_delta
from repro.graphs.generators import erdos_renyi, powerlaw
from repro.perf import hybrid_density_threshold
from repro.serve_mis.batcher import pack_batch


def _mis(g, **kw):
    return np.asarray(Solver(options=SolveOptions(**kw)).solve(g).in_mis)


# ---------------------------------------------------------------------------
# bit-identity: forced routing == dense-only, everywhere
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["tiled_ref", "tiled_pallas", "fused_pallas"])
@pytest.mark.parametrize("storage,frontier", [
    ("int8", "dense"), ("bitpack", "dense"), ("bitpack", "bitwise"),
])
def test_hybrid_bit_identity(engine, storage, frontier):
    g = powerlaw(384, avg_deg=6.0, seed=11)
    kw = dict(engine=engine, storage=storage, frontier=frontier, tile_size=32)
    ref = _mis(g, hybrid="off", **kw)
    for thr in (2, 64):       # mixed partition and (nearly) all-sparse
        got = _mis(g, hybrid="forced", hybrid_threshold=thr, **kw)
        np.testing.assert_array_equal(got, ref)


def test_hybrid_all_sparse_and_all_dense_extremes():
    # threshold 1: every non-empty tile is dense; huge threshold: all sparse
    g = erdos_renyi(300, avg_deg=5.0, seed=3)
    ref = _mis(g, engine="tiled_ref", tile_size=32, hybrid="off")
    for thr in (1, 10**6):
        got = _mis(g, engine="tiled_ref", tile_size=32,
                   hybrid="forced", hybrid_threshold=thr)
        np.testing.assert_array_equal(got, ref)


def test_segment_engine_never_partitions():
    g = erdos_renyi(200, avg_deg=4.0, seed=1)
    s = Solver(options=SolveOptions(engine="segment", hybrid="forced",
                                    hybrid_threshold=4))
    assert s.plan(g).tiled.partition is None
    np.testing.assert_array_equal(
        np.asarray(s.solve(g).in_mis), _mis(g, engine="segment", hybrid="off"))


# ---------------------------------------------------------------------------
# partition invariants
# ---------------------------------------------------------------------------


def test_partition_tiles_exactly_covers_stored_nonzeros():
    g = powerlaw(256, avg_deg=8.0, seed=7)
    tiled = build_block_tiles(g, tile_size=32)
    nnz = np.asarray(tile_nnz(tiled))[: tiled.n_tiles]
    thr = 16
    part = partition_tiles(tiled, thr)

    # counts: every stored tile with nnz >= thr is dense, 0 < nnz < thr sparse
    assert part.threshold == thr
    assert part.n_dense_tiles == int((nnz >= thr).sum())
    assert part.n_sparse_tiles == int(((nnz > 0) & (nnz < thr)).sum())
    assert part.sp_nnz == int(nnz[(nnz > 0) & (nnz < thr)].sum())

    # dense sub-tiling holds exactly the dense tiles' payload
    dn = np.asarray(tile_nnz(part.dense))[: part.dense.n_tiles]
    assert part.dense.n_tiles == part.n_dense_tiles
    assert (dn >= thr).all()

    # COO tail: real pairs scatter inside the graph, padding is the sentinel
    sp_r = np.asarray(part.sp_rows)
    sp_c = np.asarray(part.sp_cols)
    n_pad = tiled.n_padded
    real = sp_r[: part.sp_nnz]
    assert (real < n_pad).all() and (sp_c[: part.sp_nnz] < n_pad).all()
    assert (sp_r[part.sp_nnz:] == n_pad).all()
    assert (sp_c[part.sp_nnz:] == n_pad).all()

    # dense payload nnz + COO nnz == every stored nonzero
    assert int(dn.sum()) + part.sp_nnz == int(nnz.sum())


def test_partition_deterministic_and_padding_excluded():
    g = erdos_renyi(200, avg_deg=6.0, seed=5)
    tiled = build_block_tiles(g, tile_size=32)
    p1 = partition_tiles(tiled, 8)
    p2 = partition_tiles(tiled, 8)
    np.testing.assert_array_equal(np.asarray(p1.sp_rows), np.asarray(p2.sp_rows))
    np.testing.assert_array_equal(
        np.asarray(p1.dense.tiles), np.asarray(p2.dense.tiles))
    # padding tiles are all-zero -> in neither list
    stored = tiled.tiles.shape[0]
    assert p1.n_dense_tiles + p1.n_sparse_tiles <= tiled.n_tiles <= stored


# ---------------------------------------------------------------------------
# options / threshold resolution / auto gate
# ---------------------------------------------------------------------------


def test_invalid_hybrid_options_rejected():
    with pytest.raises(ValueError, match="hybrid"):
        SolveOptions(hybrid="sometimes")
    with pytest.raises(ValueError, match="hybrid_threshold"):
        SolveOptions(hybrid_threshold=0)


def test_threshold_resolution_prefers_override():
    assert resolve_hybrid_threshold(64, "int8", 7) == 7
    auto = resolve_hybrid_threshold(64, "int8", None)
    assert auto == hybrid_density_threshold(64, "int8")
    assert auto > 0


def test_auto_gate_skips_tiny_tilings():
    # a tiling with a handful of tiles never routes hybrid under "auto"
    g = erdos_renyi(64, avg_deg=4.0, seed=2)
    tiled = build_block_tiles(g, tile_size=32)
    assert attach_partition(tiled, mode="auto", threshold=8).partition is None
    # "forced" overrides the gate on the same tiling
    assert attach_partition(
        tiled, mode="forced", threshold=8).partition is not None


# ---------------------------------------------------------------------------
# plan cache v3
# ---------------------------------------------------------------------------


def test_off_mode_cache_key_is_byte_identical_to_legacy():
    g = erdos_renyi(100, avg_deg=4.0, seed=1)
    legacy = plan_cache_key(g, 32, "none", "int8")
    assert plan_cache_key(
        g, 32, "none", "int8", hybrid="off", hybrid_threshold=0) == legacy
    hy = plan_cache_key(
        g, 32, "none", "int8", hybrid="forced", hybrid_threshold=8)
    assert hy != legacy
    assert plan_cache_key(
        g, 32, "none", "int8", hybrid="forced", hybrid_threshold=9) != hy
    assert plan_cache_key(
        g, 32, "none", "int8", hybrid="forced", hybrid_threshold=8) == hy


def test_plan_cache_v3_roundtrip_reattaches_partition(tmp_path):
    g = powerlaw(300, avg_deg=6.0, seed=4)
    cache = PlanCache(cache_dir=str(tmp_path), tile_size=32,
                      hybrid="forced", hybrid_threshold=8)
    pa, st_a = cache.plan(g)
    assert st_a == "built" and pa.tiled.partition is not None

    fresh = PlanCache(cache_dir=str(tmp_path), tile_size=32,
                      hybrid="forced", hybrid_threshold=8)
    pb, st_b = fresh.plan(g)
    assert st_b == "disk"
    assert (pb.hybrid, pb.hybrid_threshold) == ("forced", 8)
    part_a, part_b = pa.tiled.partition, pb.tiled.partition
    assert part_b is not None and part_b.threshold == 8
    np.testing.assert_array_equal(
        np.asarray(part_a.dense.tiles), np.asarray(part_b.dense.tiles))
    np.testing.assert_array_equal(
        np.asarray(part_a.sp_rows), np.asarray(part_b.sp_rows))
    np.testing.assert_array_equal(
        np.asarray(part_a.sp_cols), np.asarray(part_b.sp_cols))


def test_plan_cache_off_entries_unaffected_by_hybrid_misses(tmp_path):
    # a live current-version off-mode entry must survive a hybrid-mode miss
    g = erdos_renyi(120, avg_deg=4.0, seed=6)
    off = PlanCache(cache_dir=str(tmp_path), tile_size=32)
    off.plan(g)
    _, st = off.plan(g)
    assert st == "mem"
    hy = PlanCache(cache_dir=str(tmp_path), tile_size=32,
                   hybrid="forced", hybrid_threshold=4)
    hy.plan(g)      # miss on the hybrid key; may probe the legacy path
    again = PlanCache(cache_dir=str(tmp_path), tile_size=32)
    _, st2 = again.plan(g)
    assert st2 == "disk"        # off entry still on disk, not evicted


# ---------------------------------------------------------------------------
# dyngraph: delta-driven reclassification
# ---------------------------------------------------------------------------


def test_apply_delta_reclassifies_across_threshold():
    # tile (0,0) starts below the threshold; the delta pushes it above
    T, thr = 8, 6
    g = erdos_renyi(64, avg_deg=3.0, seed=9)
    tiled = attach_partition(
        build_block_tiles(g, tile_size=T), mode="forced", threshold=thr)
    nnz0 = int(np.asarray(tile_nnz(tiled))[0])

    # add intra-tile-0 edges until its nnz (2 per undirected edge) crosses
    have = set()
    sn = np.asarray(g.senders)[: g.n_edges]
    rc = np.asarray(g.receivers)[: g.n_edges]
    for a, b in zip(sn, rc):
        have.add((min(int(a), int(b)), max(int(a), int(b))))
    adds = [(u, v) for u in range(T) for v in range(u + 1, T)
            if (u, v) not in have][: thr]
    delta = EdgeDelta.make([u for u, _ in adds], [v for _, v in adds], [], [])
    out = apply_delta(tiled, delta)

    nnz1 = int(np.asarray(tile_nnz(out))[0])
    assert nnz0 < thr <= nnz1        # the crossing actually happened
    assert out.partition is not None
    assert out.partition.threshold == thr
    assert out.partition.n_dense_tiles == tiled.partition.n_dense_tiles + 1

    # bit-exact with partitioning a from-scratch rebuild of the mutated graph
    oracle = partition_tiles(
        build_block_tiles(apply_graph_delta(g, delta), tile_size=T), thr)
    np.testing.assert_array_equal(
        np.asarray(out.partition.dense.tiles), np.asarray(oracle.dense.tiles))
    np.testing.assert_array_equal(
        np.asarray(out.partition.sp_rows), np.asarray(oracle.sp_rows))

    # and back down: the inverse delta restores the original classification
    back = apply_delta(out, delta.inverse())
    assert back.partition.n_dense_tiles == tiled.partition.n_dense_tiles
    np.testing.assert_array_equal(
        np.asarray(back.partition.sp_rows), np.asarray(tiled.partition.sp_rows))


def _absent_edge(g):
    have = set()
    sn = np.asarray(g.senders)[: g.n_edges]
    rc = np.asarray(g.receivers)[: g.n_edges]
    for a, b in zip(sn, rc):
        have.add((min(int(a), int(b)), max(int(a), int(b))))
    for u in range(g.n_nodes):
        for v in range(u + 1, g.n_nodes):
            if (u, v) not in have:
                return u, v
    raise AssertionError("complete graph")


def test_patch_plan_keeps_hybrid_policy():
    g = powerlaw(300, avg_deg=6.0, seed=12)
    plan = build_plan(g, 32, None, "k0", hybrid="forced", hybrid_threshold=8)
    u, v = _absent_edge(g)
    patched = patch_plan(plan, EdgeDelta.make([u], [v], [], []))
    assert patched.tiled.partition is not None
    assert patched.tiled.partition.threshold == 8
    assert (patched.hybrid, patched.hybrid_threshold) == ("forced", 8)


def test_update_route_repairs_hybrid_bit_identically():
    # incremental repair warm-starts from the prior solution, so the oracle
    # is the SAME update under hybrid="off" — routing must not change it
    g = powerlaw(400, avg_deg=6.0, seed=13)
    u, v = _absent_edge(g)
    delta = EdgeDelta.make([u], [v], [], [])
    results = {}
    for mode in ("off", "forced"):
        s = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                        hybrid=mode, hybrid_threshold=8))
        r1 = s.update(s.solve(g), delta)
        results[mode] = np.asarray(r1.in_mis)
        assert bool(is_valid_mis_jit(
            apply_graph_delta(g, delta), r1.in_mis))
    np.testing.assert_array_equal(results["forced"], results["off"])


# ---------------------------------------------------------------------------
# batched route
# ---------------------------------------------------------------------------


def test_batched_hybrid_bit_identical_and_signed():
    graphs = [powerlaw(200, avg_deg=5.0, seed=i) for i in range(3)]
    runs = {}
    for mode in ("off", "forced"):
        s = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                        hybrid=mode, hybrid_threshold=8))
        runs[mode] = [np.asarray(r.in_mis) for r in s.solve_many(graphs)]
    for a, b in zip(runs["off"], runs["forced"]):
        np.testing.assert_array_equal(a, b)

    s = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                    hybrid="forced", hybrid_threshold=8))
    plans = [s.plan(g) for g in graphs]
    keys = [jax.random.key(0)] * len(plans)
    pb = pack_batch(plans, keys, heuristic=s.options.heuristic)
    assert pb.tiled.partition is not None
    assert ".h8:" in pb.signature()

    s_off = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                        hybrid="off"))
    pb_off = pack_batch([s_off.plan(g) for g in graphs], keys,
                        heuristic=s.options.heuristic)
    assert pb_off.tiled.partition is None
    assert ".h" not in pb_off.signature()
    assert pb.signature() != pb_off.signature()


def test_batched_mixed_modes_falls_back_dense():
    graphs = [erdos_renyi(150, avg_deg=4.0, seed=i) for i in range(2)]
    s_h = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                      hybrid="forced", hybrid_threshold=8))
    s_o = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                      hybrid="off"))
    plans = [s_h.plan(graphs[0]), s_o.plan(graphs[1])]
    pb = pack_batch(plans, [jax.random.key(0)] * 2,
                    heuristic=s_h.options.heuristic)
    assert pb.tiled.partition is None       # incoherent pack -> dense-only


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_telemetry_reports_routing_split():
    g = powerlaw(300, avg_deg=6.0, seed=14)
    s = Solver(options=SolveOptions(engine="tiled_ref", tile_size=32,
                                    hybrid="forced", hybrid_threshold=8,
                                    telemetry=True))
    res = s.solve(g)
    part = s.plan(g).tiled.partition
    rt = res.telemetry
    assert rt.rounds == res.rounds
    assert len(rt.tiles_sparse) == rt.rounds
    n_dense_pad = int(part.dense.tiles.shape[0])
    for dense_n, sparse_n in zip(rt.tiles_dense, rt.tiles_sparse):
        assert sparse_n == part.n_sparse_tiles
        assert 0 <= dense_n <= n_dense_pad

    ref = _mis(g, engine="tiled_ref", tile_size=32, hybrid="off")
    np.testing.assert_array_equal(np.asarray(res.in_mis), ref)

"""BSR tiling invariants + heuristics unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.heuristics import make_priorities
from repro.core.tiling import build_block_tiles, tile_stats
from repro.graphs.generators import erdos_renyi, grid2d
from repro.graphs.graph import build_csr, from_edges


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(10, 200),
    deg=st.floats(1.0, 12.0),
    T=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 1000),
)
def test_tiles_reconstruct_adjacency(n, deg, T, seed):
    """Scattering all tiles back must reproduce the dense adjacency."""
    g = erdos_renyi(n, avg_deg=deg, seed=seed)
    tiled = build_block_tiles(g, tile_size=T)
    dense = np.zeros((tiled.n_padded, tiled.n_padded), np.int8)
    tiles = np.asarray(tiled.tiles)
    tr = np.asarray(tiled.tile_rows)
    tc = np.asarray(tiled.tile_cols)
    for i in range(tiled.n_tiles):
        r0, c0 = tr[i] * T, tc[i] * T
        dense[r0 : r0 + T, c0 : c0 + T] |= tiles[i]
    expect = np.zeros_like(dense)
    s = np.asarray(g.senders)[: g.n_edges]
    r = np.asarray(g.receivers)[: g.n_edges]
    expect[s, r] = 1
    np.testing.assert_array_equal(dense, expect)


def test_tile_rows_sorted_monotone():
    g = erdos_renyi(500, avg_deg=8.0, seed=1)
    tiled = build_block_tiles(g, tile_size=32)
    tr = np.asarray(tiled.tile_rows)
    assert np.all(np.diff(tr) >= 0), "BSR order violated (revisit accumulation breaks)"


def test_padding_tiles_are_noops():
    g = erdos_renyi(100, avg_deg=4.0, seed=2)
    tiled = build_block_tiles(g, tile_size=16, pad_tiles_to=64)
    assert tiled.n_tiles_pad >= 64
    pad = np.asarray(tiled.tiles[tiled.n_tiles :])
    assert pad.sum() == 0


def test_tile_stats_tradeoff():
    """Structured graphs pack denser tiles than random ones (paper §3.2)."""
    g_grid = grid2d(64, 64, diag_frac=0.0)
    g_rand = erdos_renyi(4096, avg_deg=4.0, seed=3)
    s_grid = tile_stats(build_block_tiles(g_grid, tile_size=64))
    s_rand = tile_stats(build_block_tiles(g_rand, tile_size=64))
    assert s_grid["intra_tile_density"] > s_rand["intra_tile_density"]


def test_csr_matches_edges():
    g = erdos_renyi(50, avg_deg=5.0, seed=4)
    indptr, indices = build_csr(g)
    assert indptr[-1] == g.n_edges
    s = np.asarray(g.senders)[: g.n_edges]
    deg = np.bincount(s, minlength=g.n_nodes)
    np.testing.assert_array_equal(np.diff(indptr), deg)


# ---------------------------------------------------------------------------
# heuristics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("heuristic", ["h1", "h2", "ecl"])
def test_priorities_distinct(heuristic):
    g = erdos_renyi(1000, avg_deg=6.0, seed=5)
    pri = make_priorities(heuristic, jax.random.key(0), g.n_nodes, g.degrees())
    vals = np.asarray(pri.select)
    assert len(np.unique(vals)) == g.n_nodes, "ties would stall the permutation variant"


def test_h3_resolve_is_total_order():
    g = erdos_renyi(1000, avg_deg=6.0, seed=6)
    pri = make_priorities("h3", jax.random.key(0), g.n_nodes, g.degrees())
    assert pri.resolve is not None
    vals = np.asarray(pri.resolve)
    assert len(np.unique(vals)) == g.n_nodes


def test_degree_bias_direction():
    """Eq. (1): lower degree ⇒ higher priority (on average)."""
    g = erdos_renyi(2000, avg_deg=10.0, seed=7)
    deg = np.asarray(g.degrees())
    pri = make_priorities("ecl", jax.random.key(1), g.n_nodes, g.degrees())
    sel = np.asarray(pri.select).astype(np.float64)
    lo = sel[deg <= np.percentile(deg, 25)].mean()
    hi = sel[deg >= np.percentile(deg, 75)].mean()
    assert lo > hi


def test_priorities_deterministic():
    g = erdos_renyi(100, avg_deg=5.0, seed=8)
    a = make_priorities("h2", jax.random.key(3), g.n_nodes, g.degrees())
    b = make_priorities("h2", jax.random.key(3), g.n_nodes, g.degrees())
    assert bool(jnp.all(a.select == b.select))

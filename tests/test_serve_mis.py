"""Serving layer: ingestion parsers, tile-plan cache, block-diagonal
batching (the solo-equivalence contract), and the request-queue service.

The load-bearing property: a packed batch is block-diagonal with per-member
priorities, so every member's solution is BIT-IDENTICAL to a solo `tc_mis`
run of that member with the same key — not merely a valid MIS.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import given, settings, st

from repro.core import (
    TCMISConfig,
    build_block_tiles,
    cardinality,
    is_valid_mis,
    is_valid_mis_jit,
    tc_mis,
)
from repro.graphs.graph import Graph, from_edges, pad_graph
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw
from repro.serve_mis import (
    GraphParseError,
    MISService,
    PlanCache,
    ServeConfig,
    bucket_for,
    detect_format,
    load_graph,
    pack_batch,
    plan_cache_key,
    request_key,
)
from repro.serve_mis.__main__ import main as serve_main

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
FIX_MTX = os.path.join(FIXTURES, "tiny.mtx")
FIX_EDGES = os.path.join(FIXTURES, "tiny.edges")
FIX_DIMACS = os.path.join(FIXTURES, "tiny.dimacs")


def _hetero_graphs(n_graphs=8, seed=0):
    """A deliberately mixed batch: meshes, hubs, empty and singleton graphs."""
    out = [
        grid2d(4, 5, seed=seed),
        powerlaw(40, avg_deg=3.0, seed=seed),
        erdos_renyi(25, avg_deg=4.0, seed=seed),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 7),  # no edges
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 1),  # singleton
        load_graph(FIX_DIMACS),
        erdos_renyi(33, avg_deg=2.0, seed=seed + 1),
        grid2d(3, 3, seed=seed),
    ]
    while len(out) < n_graphs:
        out.append(erdos_renyi(10 + len(out), avg_deg=3.0, seed=seed + len(out)))
    return out[:n_graphs]


# --------------------------------------------------------------------------
# io: format detection + parsers
# --------------------------------------------------------------------------

def test_detect_format():
    assert detect_format("a/b.mtx") == "mtx"
    assert detect_format("x.col") == "dimacs"
    assert detect_format("snap.txt") == "edgelist"
    assert detect_format("noext", "%%MatrixMarket matrix coordinate") == "mtx"
    assert detect_format("noext", "p edge 5 3") == "dimacs"
    assert detect_format("noext", "0 1") == "edgelist"
    # unambiguous content markers beat a generic/wrong extension
    assert detect_format("saved_as.txt", "%%MatrixMarket matrix coordinate") == "mtx"
    assert detect_format("saved_as.csv", "c DIMACS comment") == "dimacs"


def test_load_mtx_fixture():
    g = load_graph(FIX_MTX)
    assert g.n_nodes == 12
    assert g.n_edges == 28  # 14 undirected edges, both directions
    with pytest.raises(GraphParseError, match="references vertex"):
        load_graph(FIX_MTX, n_nodes=5)  # override below the file's ids


def test_load_edgelist_fixture():
    g = load_graph(FIX_EDGES)
    assert g.n_nodes == 15
    assert g.n_edges == 2 * 23


def test_load_dimacs_fixture_is_petersen():
    g = load_graph(FIX_DIMACS)
    assert g.n_nodes == 10
    assert g.n_edges == 30
    assert bool(jnp.all(g.degrees() == 3))  # Petersen is 3-regular


def test_parsers_reject_malformed(tmp_path):
    bad_mtx = tmp_path / "bad.mtx"
    bad_mtx.write_text("%%MatrixMarket matrix array real general\n2 2\n1.0\n")
    with pytest.raises(GraphParseError, match="coordinate"):
        load_graph(str(bad_mtx))
    bad_dimacs = tmp_path / "bad.col"
    bad_dimacs.write_text("e 1 2\n")
    with pytest.raises(GraphParseError, match="problem line"):
        load_graph(str(bad_dimacs))
    bad_el = tmp_path / "bad.edges"
    bad_el.write_text("0 1\n2 notanid\n")
    with pytest.raises(GraphParseError, match="line 2"):
        load_graph(str(bad_el))
    float_el = tmp_path / "float.edges"
    float_el.write_text("0 1.9\n")   # must not silently truncate to (0, 1)
    with pytest.raises(GraphParseError, match="non-integer"):
        load_graph(str(float_el))
    empty_el = tmp_path / "empty.edges"
    empty_el.write_text("# a truncated upload, nothing but comments\n")
    with pytest.raises(GraphParseError, match="no edges"):
        load_graph(str(empty_el))
    bad_p = tmp_path / "badp.col"
    bad_p.write_text("p edge ten 15\ne 1 2\n")
    with pytest.raises(GraphParseError, match="non-numeric"):
        load_graph(str(bad_p))


def test_edge_list_n_nodes_override_adds_isolated_tail():
    g = load_graph(FIX_EDGES, n_nodes=20)
    assert g.n_nodes == 20
    assert int(g.degrees()[19]) == 0


# --------------------------------------------------------------------------
# zero-edge / singleton round-tripping (satellite fix)
# --------------------------------------------------------------------------

def test_zero_edge_graph_pad_roundtrip():
    g = from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 5, pad_to=8)
    assert (g.n_edges, g.e_pad) == (0, 8)
    shrunk = pad_graph(g, 4)          # crashed before the fix
    assert (shrunk.n_edges, shrunk.e_pad) == (0, 4)
    grown = pad_graph(shrunk, 16)
    assert grown.e_pad == 16
    assert bool(jnp.all(grown.senders == 5))  # pure sentinel rows
    assert not bool(jnp.any(grown.edge_mask))


def test_pad_graph_shrink_keeps_real_edges():
    g = from_edges(np.array([0, 1]), np.array([1, 2]), 3, pad_to=64)
    shrunk = pad_graph(g, g.n_edges)
    assert shrunk.e_pad == g.n_edges == 4
    assert bool(jnp.all(shrunk.senders == g.senders[: g.n_edges]))
    with pytest.raises(ValueError, match="real edges"):
        pad_graph(g, 2)


# --------------------------------------------------------------------------
# planner: content-hashed plan cache
# --------------------------------------------------------------------------

def test_plan_cache_memory_and_disk_layers(tmp_path):
    cache = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    g = load_graph(FIX_MTX)
    plan, status = cache.plan(g)
    assert status == "built"
    assert cache.plan(g)[1] == "mem"
    # a *different load of the same content* (fresh arrays) also hits
    assert cache.plan(load_graph(FIX_MTX))[1] == "mem"
    # a fresh process (new cache object, same dir) hits the disk layer
    cache2 = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    plan2, status2 = cache2.plan(g)
    assert status2 == "disk"
    assert plan2.tiled.n_tiles == plan.tiled.n_tiles
    assert bool(jnp.all(plan2.tiled.tiles == plan.tiled.tiles))
    assert cache2.stats == {
        "mem_hits": 0, "disk_hits": 1, "misses": 0, "evicted_stale": 0,
    }


def test_plan_cache_key_depends_on_build_params():
    g = load_graph(FIX_MTX)
    k = plan_cache_key(g, 8, None)
    assert plan_cache_key(g, 16, None) != k
    assert plan_cache_key(g, 8, "rcm") != k
    assert plan_cache_key(load_graph(FIX_MTX), 8, None) == k


def test_plan_cache_memory_layer_is_bounded_lru():
    cache = PlanCache(tile_size=8, max_mem_entries=2)
    gs = [erdos_renyi(10 + i, avg_deg=2.0, seed=i) for i in range(3)]
    for g in gs:
        cache.plan(g)
    assert len(cache._mem) == 2
    assert cache.plan(gs[0])[1] == "built"  # evicted (no disk layer to catch it)
    assert cache.plan(gs[2])[1] == "mem"    # most-recent entries survive


def test_rcm_plan_results_map_back_to_original_ids():
    cache = PlanCache(tile_size=8, reorder="rcm")
    g = grid2d(6, 6, seed=0)
    plan, _ = cache.plan(g)
    assert plan.perm is not None
    res = tc_mis(plan.g, plan.tiled, jax.random.key(0), TCMISConfig(backend="ref"))
    in_mis = plan.to_original(np.asarray(res.in_mis))
    assert is_valid_mis(g, jnp.asarray(in_mis))  # valid in ORIGINAL numbering


# --------------------------------------------------------------------------
# batcher: block-diagonal packing == solo runs, bit for bit
# --------------------------------------------------------------------------

def _solo_vs_packed(graphs, backend, tile_size, heuristic="h3"):
    cache = PlanCache(tile_size=tile_size)
    plans = [cache.plan(g)[0] for g in graphs]
    base = jax.random.key(7)
    keys = [request_key(base, p) for p in plans]
    batch = pack_batch(plans, keys, heuristic)
    cfg = TCMISConfig(heuristic=heuristic, backend=backend)
    res = tc_mis(
        batch.g, batch.tiled, base, cfg,
        priorities=batch.priorities, alive0=batch.alive0, col_gate=batch.col_gate,
    )
    assert bool(res.converged)
    slices = batch.unpack(res.in_mis)
    for g, plan, key, got in zip(graphs, plans, keys, slices):
        solo = tc_mis(plan.g, plan.tiled, key, cfg)
        np.testing.assert_array_equal(got, np.asarray(solo.in_mis))
        assert is_valid_mis(plan.g, jnp.asarray(got))
        assert cardinality(jnp.asarray(got)) == cardinality(solo.in_mis)


def test_packed_batch_of_8_matches_solo_oracle_engine():
    _solo_vs_packed(_hetero_graphs(8), backend="tiled_ref", tile_size=16)


def test_packed_batch_of_only_empty_graphs_fused():
    """Zero real tiles in the whole batch: the declared bucket tile count
    must still route every slot through the trivial rule correctly."""
    graphs = [
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), n)
        for n in (3, 1, 9)
    ]
    _solo_vs_packed(graphs, backend="fused_pallas", tile_size=8)


def test_packed_batch_of_8_matches_solo_fused_pallas():
    """The acceptance contract: ≥8 heterogeneous graphs, ONE fused_pallas
    dispatch, every member bit-equal to its solo solve on the same engine."""
    tiny = [
        grid2d(3, 4, seed=1),
        erdos_renyi(14, avg_deg=3.0, seed=2),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 5),
        load_graph(FIX_DIMACS),
        powerlaw(16, avg_deg=3.0, seed=3),
        erdos_renyi(11, avg_deg=2.0, seed=4),
        from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 1),
        grid2d(2, 6, seed=5),
    ]
    _solo_vs_packed(tiny, backend="fused_pallas", tile_size=8)


def test_bucket_rounding_is_stable_across_similar_batches():
    """Same bucket ⇒ identical STATIC fields on the packed containers —
    the property that makes one compiled program serve both batches (the
    static pytree fields n_edges/n_tiles are jit cache keys)."""
    cache = PlanCache(tile_size=16)
    a = [cache.plan(g)[0] for g in _hetero_graphs(8, seed=0)]
    b = [cache.plan(g)[0] for g in _hetero_graphs(8, seed=3)]
    assert bucket_for(a, 16) == bucket_for(b, 16)
    base = jax.random.key(0)
    pa = pack_batch(a, [request_key(base, p) for p in a], "h3")
    pb = pack_batch(b, [request_key(base, p) for p in b], "h3")
    assert pa.n_real_edges != pb.n_real_edges  # genuinely different content
    assert (pa.g.n_nodes, pa.g.n_edges, pa.g.e_pad) == (
        pb.g.n_nodes, pb.g.n_edges, pb.g.e_pad)
    assert (pa.tiled.n_tiles, pa.tiled.n_tiles_pad) == (
        pb.tiled.n_tiles, pb.tiled.n_tiles_pad)
    assert pa.signature() == pb.signature()


def test_packed_batch_rejects_mixed_tile_sizes():
    g = grid2d(3, 3)
    p8 = PlanCache(tile_size=8).plan(g)[0]
    p16 = PlanCache(tile_size=16).plan(g)[0]
    with pytest.raises(ValueError, match="tile_size"):
        pack_batch([p8, p16], [jax.random.key(0)] * 2, "h3")


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
def test_property_packed_members_valid_and_match_solo(seed, n_graphs):
    rng = np.random.default_rng(seed)
    graphs = []
    for i in range(n_graphs):
        n = int(rng.integers(1, 40))
        m = int(rng.integers(0, 3 * n))
        graphs.append(from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n))
    _solo_vs_packed(graphs, backend="tiled_ref", tile_size=8)


# --------------------------------------------------------------------------
# engine hook: the static col_gate must be result-neutral
# --------------------------------------------------------------------------

def test_col_gate_all_ones_is_identity():
    g = erdos_renyi(60, avg_deg=4.0, seed=9)
    tiled = build_block_tiles(g, tile_size=16)
    key = jax.random.key(1)
    want = tc_mis(g, tiled, key, TCMISConfig(backend="tiled_ref"))
    got = tc_mis(
        g, tiled, key, TCMISConfig(backend="tiled_ref"),
        col_gate=jnp.ones((tiled.n_block_cols,), jnp.int32),
    )
    assert bool(jnp.all(want.in_mis == got.in_mis))


# --------------------------------------------------------------------------
# validate: the fused jitted post-condition
# --------------------------------------------------------------------------

def test_is_valid_mis_jit_verdicts():
    g = load_graph(FIX_DIMACS)
    res = tc_mis(g, build_block_tiles(g, tile_size=8), jax.random.key(0),
                 TCMISConfig(backend="ref"))
    assert is_valid_mis_jit(g, res.in_mis) == (True, True)
    empty = jnp.zeros((g.n_nodes,), bool)
    assert is_valid_mis_jit(g, empty) == (True, False)   # independent, not maximal
    everything = jnp.ones((g.n_nodes,), bool)
    assert is_valid_mis_jit(g, everything) == (False, True)


def test_is_valid_mis_jit_compiles_per_shape_bucket_not_per_graph():
    """The validator's jit cache must be keyed on pow2 shape buckets, so a
    stream of similar-but-distinct graph sizes shares one compiled program."""
    from repro.core.validate import _fused_checks_masked

    graphs = [erdos_renyi(17 + i, avg_deg=4.0, seed=i) for i in range(3)]
    results = []
    for g in graphs:
        res = tc_mis(g, build_block_tiles(g, tile_size=8), jax.random.key(0),
                     TCMISConfig(backend="ref"))
        results.append((g, res.in_mis))
    if not hasattr(_fused_checks_masked, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this jax")
    before = _fused_checks_masked._cache_size()
    for g, in_mis in results:
        assert is_valid_mis_jit(g, in_mis) == (True, True)
    grown = _fused_checks_masked._cache_size() - before
    assert grown <= 1  # all three graphs land in one (n_pad, e_pad) bucket


# --------------------------------------------------------------------------
# service: queue → batch → validated responses, cache + compile reuse
# --------------------------------------------------------------------------

def test_service_end_to_end_with_cache_and_compile_reuse(tmp_path):
    svc = MISService(ServeConfig(
        tile_size=16, engine="tiled_ref", max_batch=8,
        cache_dir=str(tmp_path), seed=7,
    ))
    graphs = _hetero_graphs(8)
    for g in graphs:
        svc.submit(g)
    first = svc.drain()
    assert len(first) == 8
    assert all(r.valid for r in first)
    assert all(r.stats["plan_cache"] == "built" for r in first)
    assert all(r.stats["batch_size"] == 8 for r in first)
    assert svc.stats == {"requests": 8, "batches": 1, "compiles": 1}

    # the solo-match guarantee, through the full service path
    cfg = TCMISConfig(heuristic="h3", backend="tiled_ref")
    for g, r in zip(graphs, first):
        plan, status = svc.planner.plan(g)
        assert status == "mem"
        solo = tc_mis(plan.g, plan.tiled, request_key(svc._base_key, plan), cfg)
        assert r.mis_size == cardinality(solo.in_mis)

    # second wave: same graphs ⇒ plan-cache hits, same bucket ⇒ no recompile
    for g in graphs:
        svc.submit(g)
    second = svc.drain()
    assert all(r.stats["plan_cache"] == "mem" for r in second)
    assert all(r.stats["compile"] == "reused" for r in second)
    assert svc.stats["compiles"] == 1
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a.in_mis, b.in_mis)  # content-keyed PRNG

    # third wave: DIFFERENT graphs, same bucket ⇒ still no recompile — the
    # packed statics are bucket-determined, and jax's own jit cache agrees
    for g in _hetero_graphs(8, seed=3):
        svc.submit(g)
    third = svc.drain()
    assert all(r.valid for r in third)
    assert all(r.stats["compile"] == "reused" for r in third)
    if hasattr(svc._solve, "_cache_size"):
        assert svc._solve._cache_size() == 1


def test_service_rejects_unknown_engine_at_construction():
    with pytest.raises(ValueError, match="unknown engine"):
        MISService(ServeConfig(engine="cuda_warp"))


def test_service_partial_batch_and_file_sources():
    # seed=1: h3 under the graph-content request_key derivation finds
    # Petersen's maximum (4) — keeps the quality assertion below strong
    svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref", max_batch=8,
                                 seed=1))
    svc.submit(FIX_MTX)
    svc.submit(FIX_EDGES)
    svc.submit(FIX_DIMACS)
    out = svc.drain()
    assert [r.source for r in out] == [FIX_MTX, FIX_EDGES, FIX_DIMACS]
    assert all(r.valid for r in out)
    assert out[2].mis_size == 4  # Petersen's maximum independent set


def test_unconverged_member_does_not_poison_batchmates():
    """Batch-global `converged` must not flip valid for members whose own
    invariants hold; a cut-off member fails maximality on its own."""
    svc = MISService(ServeConfig(
        tile_size=8, engine="tiled_ref", max_batch=2, max_rounds=1,
    ))
    svc.submit(from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 1))
    big = erdos_renyi(40, avg_deg=6.0, seed=0)
    svc.submit(big)
    plan, _ = svc.planner.plan(big)
    solo = tc_mis(plan.g, plan.tiled, request_key(svc._base_key, plan),
                  TCMISConfig(backend="tiled_ref"))
    assert int(solo.rounds) > 1, "fixture must need more than one round"
    iso_resp, big_resp = svc.drain()
    assert not iso_resp.converged            # batch-global flag is False...
    assert iso_resp.valid                    # ...but the member is done & valid
    assert not big_resp.maximal and not big_resp.valid


def test_service_reports_per_member_rounds():
    """ROADMAP item: a batch member reports ITS OWN convergence round, not
    the global round count of the batch's slowest member."""
    svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref", max_batch=4))
    fast = from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 4)
    slow = erdos_renyi(48, avg_deg=6.0, seed=0)
    svc.submit(fast)
    svc.submit(slow)
    r_fast, r_slow = svc.drain()
    plan, _ = svc.planner.plan(slow)
    solo = tc_mis(plan.g, plan.tiled, request_key(svc._base_key, plan),
                  TCMISConfig(backend="tiled_ref"))
    assert int(solo.rounds) > 1, "fixture must need more than one round"
    assert r_slow.rounds == int(solo.rounds)   # == its solo round count
    assert r_fast.rounds == 1                  # edgeless: done in one round
    assert r_fast.stats["bucket"] == r_slow.stats["bucket"]  # same dispatch


def test_cli_survives_bad_request_path(capsys):
    rc = serve_main([
        "--once", "--tile-size", "8", "--engine", "tiled_ref",
        FIX_MTX, "definitely_missing.edges",
    ])
    assert rc == 1  # the bad request counts as a failure...
    lines = [l for l in capsys.readouterr().out.splitlines() if l.startswith("{")]
    records = [json.loads(l) for l in lines]
    errors = [r for r in records if "error" in r]
    served = [r for r in records if "error" not in r]
    assert len(errors) == 1 and not errors[0]["valid"]
    assert len(served) == 1 and served[0]["valid"]  # ...without killing the stream


def test_cli_once_smoke(tmp_path, capsys):
    rc = serve_main([
        "--once", "--tile-size", "8", "--engine", "tiled_ref",
        "--repeat", "2", "--cache-dir", str(tmp_path),
        FIX_MTX, FIX_EDGES, FIX_DIMACS,
    ])
    assert rc == 0
    lines = [l for l in capsys.readouterr().out.splitlines() if l.startswith("{")]
    assert len(lines) == 6

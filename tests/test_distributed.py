"""Multi-device tests (subprocess with 8 fake devices): distributed MIS
equivalence, small-mesh dry-run compiles, elastic resharding, bit-packing."""
import pytest

from conftest import run_multidevice


def test_distributed_mis_matches_single_device():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.graphs.generators import powerlaw
        from repro.core import (build_block_tiles, shard_tiled,
                                build_distributed_mis, DistConfig,
                                make_priorities, ecl_mis, tc_mis, TCMISConfig,
                                is_valid_mis, cardinality)
        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        g = powerlaw(3000, avg_deg=5.0, seed=2)
        tiled = build_block_tiles(g, tile_size=64)
        sharded = shard_tiled(tiled, n_shards=8)
        key = jax.random.key(0)
        for bitpack in (True, False):
            pri = make_priorities("ecl", key, g.n_nodes, g.degrees())
            run = build_distributed_mis(sharded, mesh, DistConfig(bitpack=bitpack))
            res = run(pri)
            in_mis = res.in_mis[:g.n_nodes]
            assert is_valid_mis(g, in_mis), "invalid distributed MIS"
            r_ref = ecl_mis(g, key)
            assert bool(jnp.all(in_mis == r_ref.in_mis)), "distributed != single"
        # H3 two-pass path
        pri = make_priorities("h3", key, g.n_nodes, g.degrees())
        res = build_distributed_mis(sharded, mesh, DistConfig())(pri)
        assert is_valid_mis(g, res.in_mis[:g.n_nodes])
        r3 = tc_mis(g, tiled, key, TCMISConfig(heuristic="h3"))
        assert bool(jnp.all(res.in_mis[:g.n_nodes] == r3.in_mis)), "h3 mismatch"
        print("DIST_MIS_OK")
    """)
    assert "DIST_MIS_OK" in out


def test_bitpack_roundtrip():
    """The gather payload uses the one frontier-word packing contract from
    core.tiling (the uint8 pair this module once carried is gone)."""
    import jax
    import jax.numpy as jnp

    from repro.core.tiling import pack_frontier_words, unpack_frontier_words

    for T in (16, 64, 128):
        x = jax.random.uniform(jax.random.key(0), (1024,)) > 0.5
        assert bool(jnp.all(unpack_frontier_words(pack_frontier_words(x, T), T) == x))


def test_small_mesh_dryrun_lm():
    """The production cell builders must lower+compile on a small mesh too
    (same code path as the 512-chip dry-run, scaled)."""
    out = run_multidevice("""
        import jax
        from jax.sharding import PartitionSpec as P
        import jax.numpy as jnp
        import dataclasses
        from repro.configs.qwen3_0_6b import SMOKE
        from repro.configs.common import make_lm_train_step, _dryrun_cfg
        from repro.dist.sharding import lm_param_specs, batch_spec
        from repro.models import transformer as tf
        from repro.train.optimizer import OptConfig, adamw_init, AdamWState
        from repro.configs.common import named_shardings

        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = dataclasses.replace(SMOKE, d_model=128, n_heads=8, n_kv_heads=4,
                                  d_head=16, vocab=512)
        with mesh:
            rcfg = _dryrun_cfg(cfg, mesh, unroll=False)
            params_sh = jax.eval_shape(lambda k: tf.init_lm(k, rcfg), jax.random.key(0))
            opt_sh = jax.eval_shape(adamw_init, params_sh)
            p_specs = lm_param_specs(params_sh, mesh)
            o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
            fn = make_lm_train_step(rcfg, OptConfig(total_steps=10))
            inputs = (params_sh, opt_sh,
                      jax.ShapeDtypeStruct((8, 64), jnp.int32),
                      jax.ShapeDtypeStruct((8, 64), jnp.int32))
            shardings = named_shardings(mesh, (p_specs, o_specs,
                                               batch_spec(mesh, 1), batch_spec(mesh, 1)))
            compiled = jax.jit(fn, in_shardings=shardings).lower(*inputs).compile()
            assert compiled.cost_analysis() is not None
        print("SMALL_DRYRUN_OK")
    """)
    assert "SMALL_DRYRUN_OK" in out


def test_elastic_reshard_checkpoint():
    """Checkpoint written single-device restores onto an 8-device mesh and
    training continues identically (grow); and back (shrink)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt
        from repro.dist.elastic import reshard_checkpoint

        tree = {"w": jax.random.normal(jax.random.key(0), (64, 32)),
                "b": jnp.arange(10, dtype=jnp.int32)}
        d = tempfile.mkdtemp()
        ckpt.save(d, 0, tree)

        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        def spec_fn(t, m):
            return jax.tree.map(lambda x: P("data", "model") if x.ndim == 2 else P(), t)
        out = reshard_checkpoint(d, 0, mesh, spec_fn)
        assert out["w"].sharding.spec == P("data", "model")
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))
        # shrink back to logical and compare
        ckpt.save(d, 1, out)
        back = ckpt.restore(d, 1)
        np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(tree["w"]))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_gin_fullgraph_cell_small_mesh():
    """GNN full-graph cell compiles on a small mesh (scaled dry-run)."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.configs import REGISTRY
        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cell = REGISTRY["gin-tu"].cells["full_graph_sm"]
        with mesh:
            fn, inputs, shardings = cell.build(mesh)
            compiled = jax.jit(fn, in_shardings=shardings).lower(*inputs).compile()
        print("GNN_CELL_OK")
    """, timeout=900)
    assert "GNN_CELL_OK" in out

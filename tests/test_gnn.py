"""GNN family: smoke per arch, equivariance properties, backend agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose
from scipy.spatial.transform import Rotation

from repro.configs import REGISTRY
from repro.graphs.generators import erdos_renyi

GNN_ARCHS = [a for a, d in REGISTRY.items() if d.family == "gnn"]


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_arch_smoke(arch):
    REGISTRY[arch].smoke()


def _graph(n=100, deg=6.0, seed=0):
    g = erdos_renyi(n, avg_deg=deg, seed=seed)
    s = jnp.where(g.edge_mask, g.senders, 0)
    r = jnp.where(g.edge_mask, g.receivers, 0)
    return g, s, r, g.edge_mask


@pytest.mark.parametrize("seed", range(3))
def test_egnn_equivariance(seed):
    from repro.models.gnn.egnn import egnn_apply, egnn_init

    g, s, r, m = _graph(seed=seed)
    feats = jax.random.normal(jax.random.key(seed), (g.n_nodes, 8))
    coords = jax.random.normal(jax.random.key(seed + 10), (g.n_nodes, 3))
    params = egnn_init(jax.random.key(seed + 20), 8)
    R = jnp.asarray(Rotation.random(random_state=seed).as_matrix(), jnp.float32)
    t = jnp.asarray([1.0, -2.0, 0.5])

    h1, x1, e1 = egnn_apply(params, feats, coords, s, r, m)
    h2, x2, e2 = egnn_apply(params, feats, coords @ R.T + t, s, r, m)
    # untrained 4-layer MLP stacks amplify features to ~1e5, so equivariance
    # holds to f32 roundoff RELATIVE TO SCALE — compare scale-normalised.
    assert_allclose(float(e1), float(e2), rtol=1e-4)                # E(n)-invariant energy
    scale = np.abs(np.asarray(h1)).max()
    assert_allclose(np.asarray(h1) / scale, np.asarray(h2) / scale, atol=1e-4)
    assert_allclose(np.asarray(x1 @ R.T + t), np.asarray(x2), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("seed", range(2))
def test_mace_invariance_and_l1_equivariance(seed):
    from repro.models.gnn.mace import mace_apply, mace_init

    g, s, r, m = _graph(n=60, seed=seed)
    feats = jax.random.normal(jax.random.key(seed), (g.n_nodes, 8))
    coords = jax.random.normal(jax.random.key(seed + 1), (g.n_nodes, 3)) * 0.8
    params = mace_init(jax.random.key(seed + 2), 8, channels=16)
    R = jnp.asarray(Rotation.random(random_state=seed).as_matrix(), jnp.float32)

    h1, e1 = mace_apply(params, feats, coords, s, r, m)
    h2, e2 = mace_apply(params, feats, coords @ R.T + 3.0, s, r, m)
    assert_allclose(float(e1), float(e2), rtol=1e-4)                 # E(3)-invariant
    # l=0 features invariant
    assert_allclose(np.asarray(h1[0]), np.asarray(h2[0]), rtol=1e-3, atol=1e-4)
    # l=1 features rotate with R in the (y,z,x) real-SH basis
    P = jnp.zeros((3, 3)).at[0, 1].set(1).at[1, 2].set(1).at[2, 0].set(1)
    R_sh = P @ R @ P.T
    rotated = jnp.einsum("ij,njc->nic", R_sh, h1[1])
    assert_allclose(np.asarray(rotated), np.asarray(h2[1]), rtol=1e-3, atol=1e-3)


def test_mace_gaunt_tensors_are_equivariant():
    """∫YYY quadrature must produce genuinely equivariant couplings."""
    from repro.models.gnn.mace import coupling_tensors, real_sph_harm

    rng = np.random.default_rng(0)
    v1 = rng.standard_normal(3)
    v2 = rng.standard_normal(3)
    v1 /= np.linalg.norm(v1)
    v2 /= np.linalg.norm(v2)
    R = Rotation.random(random_state=1).as_matrix()
    Y1 = real_sph_harm(jnp.asarray(v1[None]))
    Y2 = real_sph_harm(jnp.asarray(v2[None]))
    Y1r = real_sph_harm(jnp.asarray((R @ v1)[None]))
    Y2r = real_sph_harm(jnp.asarray((R @ v2)[None]))
    for l1, l2, l3, K in coupling_tensors():
        a = np.einsum("m,n,mnk->k", np.asarray(Y1[l1])[0], np.asarray(Y2[l2])[0], K)
        b = np.einsum("m,n,mnk->k", np.asarray(Y1r[l1])[0], np.asarray(Y2r[l2])[0], K)
        # invariant norm: |couple(x,y)| is rotation-invariant
        assert_allclose(np.linalg.norm(a), np.linalg.norm(b), rtol=1e-4,
                        err_msg=f"coupling ({l1},{l2},{l3}) not equivariant")


def test_gin_tiled_backend_matches_segment():
    """The paper's BSR SpMM backend must agree with the segment path."""
    from repro.core.tiling import build_block_tiles
    from repro.models.gnn.gin import gin_apply, gin_init

    g, s, r, m = _graph(n=150, deg=8.0, seed=5)
    tiled = build_block_tiles(g, tile_size=32)
    feats = jax.random.normal(jax.random.key(0), (g.n_nodes, 8))
    params = gin_init(jax.random.key(1), 8, n_out=4)
    h_seg, out_seg = gin_apply(params, feats, s, r, m, backend="segment")
    h_til, out_til = gin_apply(params, feats, s, r, m, tiled=tiled, backend="tiled")
    scale = np.abs(np.asarray(h_seg)).max()   # untrained stacks reach ~1e5
    assert_allclose(np.asarray(h_seg) / scale, np.asarray(h_til) / scale, atol=1e-5)


def test_pna_aggregators():
    """Hand-check PNA's masked mean/max/min/std on a tiny star graph."""
    from repro.models.gnn.pna import _aggregate

    # edges: 0->2, 1->2 with messages [1, 3]
    m = jnp.asarray([[1.0], [3.0]])
    recv = jnp.asarray([2, 2])
    mask = jnp.asarray([True, True])
    mean, mx, mn, std, cnt = _aggregate(m, recv, mask, 3)
    assert_allclose(float(mean[2, 0]), 2.0)
    assert_allclose(float(mx[2, 0]), 3.0)
    assert_allclose(float(mn[2, 0]), 1.0)
    assert_allclose(float(std[2, 0]), 1.0, rtol=1e-3)
    assert float(cnt[0]) == 0.0 and float(mx[0, 0]) == 0.0  # isolated node neutral


def test_neighbor_sampler():
    from repro.graphs.sampler import NeighborSampler, tree_edges

    g, *_ = _graph(n=200, deg=5.0, seed=7)
    sampler = NeighborSampler(g, fanout=(5, 3))
    seeds = jnp.arange(8, dtype=jnp.int32)
    sub = sampler.sample(jax.random.key(0), seeds)
    assert sub.layers[1].shape == (8, 5)
    assert sub.layers[2].shape == (8, 5, 3)
    # sampled neighbours must be real neighbours
    import numpy as np
    from repro.graphs.graph import build_csr

    indptr, indices = build_csr(g)
    l1 = np.asarray(sub.layers[1])
    m1 = np.asarray(sub.masks[1])
    for i, seed in enumerate(np.asarray(seeds)):
        nbrs = set(indices[indptr[seed] : indptr[seed + 1]].tolist())
        for j in range(5):
            if m1[i, j]:
                assert l1[i, j] in nbrs
    # deterministic given key
    sub2 = sampler.sample(jax.random.key(0), seeds)
    assert bool(jnp.all(sub.layers[2] == sub2.layers[2]))
    # tree flattening is consistent
    ids, nmask, snd, rcv, emask = tree_edges(sub)
    assert ids.shape[0] == 8 + 8 * 5 + 8 * 5 * 3
    assert snd.shape == rcv.shape == emask.shape
    assert int(rcv.max()) < 8 + 8 * 5

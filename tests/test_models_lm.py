"""LM model family: per-arch smoke + decode/forward consistency.

The decode-consistency test is the serving-correctness keystone: logits from
prefill+step-by-step decode (ring caches, MLA latent absorption) must match
the full forward pass position-for-position.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs import REGISTRY
from repro.models import transformer as tf

LM_ARCHS = [a for a, d in REGISTRY.items() if d.family == "lm"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_smoke(arch):
    REGISTRY[arch].smoke()


def _smoke_cfg(arch):
    import importlib

    mod = {
        "qwen1.5-0.5b": "repro.configs.qwen15_0_5b",
        "qwen3-0.6b": "repro.configs.qwen3_0_6b",
        "nemotron-4-340b": "repro.configs.nemotron4_340b",
        "mixtral-8x22b": "repro.configs.mixtral_8x22b",
        "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    }[arch]
    return importlib.import_module(mod).SMOKE


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward logits."""
    cfg = _smoke_cfg(arch)
    # MoE decode vs batch forward can differ via capacity drops; widen capacity
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    B, S, k = 2, 24, 4
    params = tf.init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab, jnp.int32)

    h, _, _ = tf.forward(params, cfg, tokens)
    full_logits = (h @ (params["head"] if "head" in params else params["embed"].T)
                   ).astype(jnp.float32)

    logits, cache = tf.prefill(params, cfg, tokens[:, : S - k], max_len=S + 1)
    assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, S - k - 1]),
        rtol=2e-3, atol=2e-3,
    )
    for i in range(S - k, S):
        logits, cache = tf.decode_step(params, cfg, cache, tokens[:, i])
        window_ok = cfg.window is None or cache.length >= i + 1
        if window_ok:
            assert_allclose(
                np.asarray(logits), np.asarray(full_logits[:, i]),
                rtol=2e-3, atol=2e-3,
                err_msg=f"{arch}: decode diverges at position {i}",
            )


def test_swa_ring_buffer_consistency():
    """Windowed decode with a ring cache must equal full-cache windowed attn."""
    from repro.models.lm_config import LMConfig

    cfg = LMConfig(
        name="swa-test", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=64, window=8, dtype=jnp.float32,
        attn_chunk=8, loss_chunk=8,
    )
    B, S = 1, 32
    params = tf.init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab, jnp.int32)
    h, _, _ = tf.forward(params, cfg, tokens)
    full_logits = (h @ params["head"]).astype(jnp.float32)

    # decode from scratch (prefill only 1 token) — ring must roll many times
    logits, cache = tf.prefill(params, cfg, tokens[:, :1], max_len=S)
    assert cache.length == cfg.window
    for i in range(1, S):
        logits, cache = tf.decode_step(params, cfg, cache, tokens[:, i])
        assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            rtol=2e-3, atol=2e-3, err_msg=f"ring decode diverges at {i}",
        )


def test_flash_attention_vs_naive():
    from repro.models.attention import flash_attention

    B, S, H, Hkv, d = 2, 64, 8, 4, 32
    q = jax.random.normal(jax.random.key(0), (B, S, H, d))
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, d))
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, d))
    for window in [None, 16]:
        out = flash_attention(q, k, v, causal=True, window=window, chunk=16)
        # naive reference
        kr = jnp.repeat(k, H // Hkv, axis=2)
        vr = jnp.repeat(v, H // Hkv, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * d ** -0.5
        mask = jnp.tril(jnp.ones((S, S), bool))
        if window:
            mask &= (jnp.arange(S)[:, None] - jnp.arange(S)[None, :]) < window
        s = jnp.where(mask[None, None], s, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
        assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_ragged_seq():
    """S not divisible by chunk (the MTP S−1 case)."""
    from repro.models.attention import flash_attention

    B, S, H, d = 1, 37, 2, 16
    q = jax.random.normal(jax.random.key(0), (B, S, H, d))
    k = jax.random.normal(jax.random.key(1), (B, S, H, d))
    v = jax.random.normal(jax.random.key(2), (B, S, H, d))
    out = flash_attention(q, k, v, causal=True, chunk=16)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * d ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_counted():
    from repro.models.lm_config import MoEConfig
    from repro.models.moe import moe_ffn, expert_capacity
    import jax

    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=0.5)
    N, D = 64, 16
    params = {
        "router": jax.random.normal(jax.random.key(0), (D, 4)),
        "we1": jax.random.normal(jax.random.key(1), (4, D, 32)) * 0.1,
        "we3": jax.random.normal(jax.random.key(2), (4, D, 32)) * 0.1,
        "we2": jax.random.normal(jax.random.key(3), (4, 32, D)) * 0.1,
    }
    x = jax.random.normal(jax.random.key(4), (N, D))
    out, metrics = moe_ffn(params, x, cfg, "swiglu")
    assert out.shape == (N, D)
    assert float(metrics.drop_frac) > 0.0, "cf=0.5 must drop tokens"
    assert float(metrics.aux_loss) > 0.0


def test_unroll_invariance():
    """unroll=True must not change numerics (dry-run cost pass soundness)."""
    cfg = _smoke_cfg("qwen3-0.6b")
    cfg_u = dataclasses.replace(cfg, unroll=True)
    params = tf.init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab, jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    l1, _ = tf.lm_loss(params, cfg, tokens, targets)
    l2, _ = tf.lm_loss(params, cfg_u, tokens, targets)
    assert_allclose(float(l1), float(l2), rtol=1e-6)

"""The storage axis (DESIGN.md §11): 1-bit tile packing end-to-end.

Covers the bit-parity contract — bitpack solutions are BIT-IDENTICAL to
int8 for every registered engine on the local, batched and sharded routes —
plus the pack/unpack round-trip property, the auto-storage policy, the
plan-cache format-version migration, and the deprecation/validation
hygiene of the `storage` spellings.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from conftest import run_multidevice

from repro.api import (
    BITPACK_AUTO_THRESHOLD,
    PlanCache,
    SolveOptions,
    Solver,
    resolve_storage,
)
from repro.api.plan import _META_LEN, _PLAN_VERSION
from repro.core.engine import engine_names, tile_spmv
from repro.core.tc_mis import _tc_mis_impl
from repro.core.tiling import (
    STORAGES,
    build_block_tiles,
    pack_tile_bits,
    packed_words,
    tile_stats,
    unpack_tile_bits,
)
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw

# ---------------------------------------------------------------------------
# pack/unpack round-trip
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    T=st.sampled_from([8, 16, 32, 64, 128, 256]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 1000),
)
def test_pack_unpack_roundtrip(T, density, seed):
    rng = np.random.default_rng(seed)
    tiles = (rng.random((3, T, T)) < density).astype(np.int8)
    packed = pack_tile_bits(tiles)
    assert packed.shape == (3, T, packed_words(T))
    assert packed.dtype == np.uint32
    np.testing.assert_array_equal(
        np.asarray(unpack_tile_bits(jnp.asarray(packed), T)), tiles
    )


def test_unpack_is_jit_compatible():
    tiles = (np.random.default_rng(0).random((4, 32, 32)) < 0.3).astype(np.int8)
    packed = jnp.asarray(pack_tile_bits(tiles))
    out = jax.jit(lambda p: unpack_tile_bits(p, 32))(packed)
    np.testing.assert_array_equal(np.asarray(out), tiles)


def test_build_block_tiles_bitpack_matches_int8():
    g = erdos_renyi(300, avg_deg=6.0, seed=1)
    a = build_block_tiles(g, tile_size=32, pad_tiles_to=64)
    b = build_block_tiles(g, tile_size=32, pad_tiles_to=64, storage="bitpack")
    assert b.storage == "bitpack" and b.tiles.dtype == jnp.uint32
    assert b.n_tiles_pad == a.n_tiles_pad  # padding tiles pack too
    np.testing.assert_array_equal(
        np.asarray(unpack_tile_bits(b.tiles, 32)), np.asarray(a.tiles)
    )
    # converters round-trip between the formats
    np.testing.assert_array_equal(
        np.asarray(b.to_storage("int8").tiles), np.asarray(a.tiles)
    )
    np.testing.assert_array_equal(
        np.asarray(a.to_storage("bitpack").tiles), np.asarray(b.tiles)
    )


# ---------------------------------------------------------------------------
# stats fixes ride along with the storage axis
# ---------------------------------------------------------------------------


def test_nnz_density_and_memory_bytes_both_storages():
    g = erdos_renyi(200, avg_deg=5.0, seed=2)
    a = build_block_tiles(g, tile_size=16)
    b = a.to_storage("bitpack")
    assert a.nnz() == b.nnz() == g.n_edges
    assert a.density() == b.density() > 0
    # memory_bytes now includes row_starts, and the bitpack payload is the
    # packed word count — not an unpacked shadow
    for t in (a, b):
        idx_bytes = (t.tile_rows.size + t.tile_cols.size + t.row_starts.size) * 4
        assert t.memory_bytes() == t.tile_payload_bytes() + idx_bytes
    assert b.tile_payload_bytes() * 4 == a.tile_payload_bytes()  # T=16: W=1
    sa, sb = tile_stats(a), tile_stats(b)
    assert sa["intra_tile_density"] == sb["intra_tile_density"]
    assert (sa["storage"], sb["storage"]) == ("int8", "bitpack")


def test_tile_payload_reduction_at_t128():
    g = erdos_renyi(1024, avg_deg=8.0, seed=3)
    a = build_block_tiles(g, tile_size=128)
    b = a.to_storage("bitpack")
    assert a.tile_payload_bytes() / b.tile_payload_bytes() == 8.0
    assert a.memory_bytes() / b.memory_bytes() >= 6.0


# ---------------------------------------------------------------------------
# bit-parity: every engine, local route
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", engine_names())
def test_solver_bit_parity_every_engine(engine):
    g = erdos_renyi(90, avg_deg=5.0, seed=4)
    res = {}
    for storage in ("int8", "bitpack"):
        r = Solver(SolveOptions(
            engine=engine, tile_size=8, storage=storage, placement="local",
        )).solve(g)
        res[storage] = r
    np.testing.assert_array_equal(res["int8"].in_mis, res["bitpack"].in_mis)
    assert res["int8"].rounds == res["bitpack"].rounds
    assert res["bitpack"].plan.tiled.tiles.dtype == jnp.uint32


def test_profile_bit_parity():
    g = grid2d(8, 10)
    out = {}
    for storage in ("int8", "bitpack"):
        r, _ = Solver(SolveOptions(
            engine="tiled_ref", tile_size=8, storage=storage,
        )).profile(g)
        out[storage] = r
    np.testing.assert_array_equal(out["int8"].in_mis, out["bitpack"].in_mis)


# ---------------------------------------------------------------------------
# bit-parity: batched route (block-diagonal bucket + col_gate)
# ---------------------------------------------------------------------------


def test_solve_many_bucket_bit_parity():
    graphs = [
        erdos_renyi(70, avg_deg=4.0, seed=5),
        grid2d(6, 9),
        powerlaw(60, avg_deg=3.0, seed=6),
    ]
    outs = {}
    for storage in ("int8", "bitpack"):
        solver = Solver(SolveOptions(
            engine="tiled_ref", tile_size=8, storage=storage,
        ))
        outs[storage] = solver.solve_many(graphs)
    for a, b in zip(outs["int8"], outs["bitpack"]):
        assert a.placement == b.placement == "batched"
        np.testing.assert_array_equal(a.in_mis, b.in_mis)
        assert a.rounds == b.rounds
    # the bucket signature carries the storage (distinct compiled programs)
    assert outs["int8"][0].stats["bucket"].endswith(".int8")
    assert outs["bitpack"][0].stats["bucket"].endswith(".bitpack")


def test_col_gate_bit_parity():
    """The static col_gate (batch empty-slot gate) composes with either
    storage: gating trailing block-columns gives identical solutions."""
    g = erdos_renyi(60, avg_deg=4.0, seed=7)
    key = jax.random.key(0)
    res = {}
    for storage in ("int8", "bitpack"):
        tiled = build_block_tiles(g, tile_size=8, storage=storage)
        gate = jnp.ones((tiled.n_block_cols,), jnp.int32)
        opts = SolveOptions(engine="tiled_ref", tile_size=8, storage=storage)
        res[storage] = _tc_mis_impl(g, tiled, key, opts, col_gate=gate)
    np.testing.assert_array_equal(
        np.asarray(res["int8"].in_mis), np.asarray(res["bitpack"].in_mis)
    )


def test_mixed_storage_members_split_into_separate_buckets():
    """solve_many must not pack int8 and bitpack plans into one batch."""
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8))
    plans = [
        solver.plans.plan(erdos_renyi(40, avg_deg=3.0, seed=8),
                          tile_size=8, storage="int8")[0],
        solver.plans.plan(erdos_renyi(44, avg_deg=3.0, seed=9),
                          tile_size=8, storage="bitpack")[0],
        solver.plans.plan(erdos_renyi(48, avg_deg=3.0, seed=10),
                          tile_size=8, storage="int8")[0],
    ]
    out = solver.solve_many(plans)
    assert [r.placement for r in out] == ["batched", "local", "batched"]
    for r in out:
        assert r.mis_size > 0


# ---------------------------------------------------------------------------
# bit-parity: sharded route
# ---------------------------------------------------------------------------


def test_sharded_bit_parity():
    out = run_multidevice("""
        import numpy as np
        from repro.api import Solver, SolveOptions
        from repro.graphs.generators import powerlaw
        g = powerlaw(1024, avg_deg=5.0, seed=11)
        res = {}
        for storage in ("int8", "bitpack"):
            r = Solver(SolveOptions(
                engine="tiled_ref", tile_size=32, storage=storage,
                placement="sharded",
            )).solve(g)
            assert r.placement == "sharded", r.placement
            res[storage] = r
        np.testing.assert_array_equal(
            res["int8"].in_mis, res["bitpack"].in_mis
        )
        assert res["int8"].rounds == res["bitpack"].rounds
        # and the sharded result matches the local route bit-for-bit
        local = Solver(SolveOptions(
            engine="tiled_ref", tile_size=32, storage="bitpack",
            placement="local",
        )).solve(g)
        np.testing.assert_array_equal(local.in_mis, res["bitpack"].in_mis)
        print("SHARDED_STORAGE_OK")
    """, n_devices=4)
    assert "SHARDED_STORAGE_OK" in out


# ---------------------------------------------------------------------------
# the auto policy
# ---------------------------------------------------------------------------


def test_resolve_storage_policy():
    # tiny graph: worst-case int8 payload under the threshold → int8
    assert resolve_storage("auto", 100, 400, 16) == "int8"
    # huge graph: far over the threshold → bitpack
    big_edges = BITPACK_AUTO_THRESHOLD  # E·T² ≥ threshold at any T
    assert resolve_storage("auto", 1 << 20, big_edges, 128) == "bitpack"
    # concrete spellings pass through
    assert resolve_storage("int8", 1 << 20, big_edges, 128) == "int8"
    assert resolve_storage("bitpack", 100, 400, 16) == "bitpack"
    with pytest.raises(ValueError, match="valid"):
        resolve_storage("packed", 100, 400, 16)


def test_solver_auto_storage_resolves_per_graph():
    small = erdos_renyi(60, avg_deg=4.0, seed=12)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8, storage="auto"))
    assert solver.plan(small).tiled.storage == "int8"
    # force the threshold down: the same policy flips to bitpack
    assert resolve_storage(
        "auto", small.n_nodes, small.n_edges, 8, threshold=1
    ) == "bitpack"


# ---------------------------------------------------------------------------
# validation / deprecation hygiene
# ---------------------------------------------------------------------------


def test_unknown_storage_spellings_rejected_with_valid_set():
    with pytest.raises(ValueError) as ei:
        SolveOptions(storage="uint1")
    assert "int8" in str(ei.value) and "bitpack" in str(ei.value)
    with pytest.raises(ValueError, match="valid"):
        build_block_tiles(erdos_renyi(10, avg_deg=2.0, seed=0),
                          tile_size=8, storage="dense")
    with pytest.raises(ValueError, match="valid"):
        build_block_tiles(
            erdos_renyi(10, avg_deg=2.0, seed=0), tile_size=8
        ).to_storage("nibble")
    assert STORAGES == ("int8", "bitpack")


# ---------------------------------------------------------------------------
# plan-cache format migration
# ---------------------------------------------------------------------------


def _rewrite_as_v1(path: str) -> None:
    """Rewrite a v2 npz as the pre-storage-axis v1 layout (6-int meta)."""
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    arrays["meta"] = arrays["meta"][:6]
    np.savez(path.replace(".npz", ""), **arrays)


def test_plan_cache_migration_smoke(tmp_path):
    """An old-format disk entry is detected, warned about, evicted and
    REBUILT — never mis-read as a current plan."""
    g = erdos_renyi(80, avg_deg=4.0, seed=13)
    cache = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    plan, status = cache.plan(g)
    assert status == "built"
    path = cache._path(plan.key)
    _rewrite_as_v1(path)

    fresh = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        plan2, status2 = fresh.plan(g)
    assert status2 == "built"           # rebuilt, not disk-loaded
    assert fresh.stats["evicted_stale"] == 1
    msgs = [str(w.message) for w in caught]
    assert any("stale plan-cache entry" in m for m in msgs), msgs
    np.testing.assert_array_equal(
        np.asarray(plan2.tiled.tiles), np.asarray(plan.tiled.tiles)
    )
    # the rebuilt entry is current-format: a third cache disk-hits it
    assert PlanCache(tile_size=8, cache_dir=str(tmp_path)).plan(g)[1] == "disk"


def test_plan_cache_migration_of_genuine_v1_keyed_entry(tmp_path):
    """A REAL v1 upgrade: the old entry sits at the v1 key path (storage
    was not part of the key then), so the disk miss at the current key must
    probe the legacy path, evict the orphan with a warning, and rebuild."""
    from repro.api.plan import _legacy_v1_cache_key

    g = erdos_renyi(60, avg_deg=4.0, seed=20)
    cache = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    # manufacture the v1 entry exactly where a v1 process would have put it
    v1_path = cache._path(_legacy_v1_cache_key(g, 8, None))
    plan, _ = cache.plan(g)                    # v2 build (writes the v2 file)
    import shutil
    shutil.copy(cache._path(plan.key), v1_path)
    _rewrite_as_v1(v1_path)

    fresh = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    import os
    os.unlink(cache._path(plan.key))           # leave ONLY the v1 orphan
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _, status = fresh.plan(g)
    assert status == "built"
    assert fresh.stats["evicted_stale"] == 1
    assert not os.path.exists(v1_path)          # orphan cleaned up
    assert any("v1 key" in str(w.message) for w in caught)


def test_plan_cache_version_mismatch_evicts(tmp_path):
    """A versioned entry from a DIFFERENT format version is evicted too."""
    g = erdos_renyi(40, avg_deg=3.0, seed=14)
    cache = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    plan, _ = cache.plan(g)
    path = cache._path(plan.key)
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta = arrays["meta"].copy()
    meta[6] = _PLAN_VERSION + 1
    arrays["meta"] = meta
    np.savez(path.replace(".npz", ""), **arrays)
    fresh = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        _, status = fresh.plan(g)
    assert status == "built" and fresh.stats["evicted_stale"] == 1


def test_disk_cache_stores_packed_tiles_packed(tmp_path):
    """The 8× plan-cache byte reduction is real on disk: the bitpack entry's
    tiles array persists as uint32 words."""
    g = erdos_renyi(256, avg_deg=6.0, seed=15)
    cache = PlanCache(tile_size=32, cache_dir=str(tmp_path))
    p_int8, _ = cache.plan(g, storage="int8")
    p_pack, _ = cache.plan(g, storage="bitpack")
    assert p_int8.key != p_pack.key     # distinct cache entries
    with np.load(cache._path(p_pack.key)) as z:
        assert z["tiles"].dtype == np.uint32
        assert int(z["meta"][6]) == _PLAN_VERSION
        assert z["meta"].shape[0] == _META_LEN
        packed_nbytes = z["tiles"].nbytes
    with np.load(cache._path(p_int8.key)) as z:
        assert z["tiles"].dtype == np.int8
        int8_nbytes = z["tiles"].nbytes
    assert int8_nbytes == 8 * packed_nbytes
    # round-trip through the disk layer preserves the packed form
    fresh = PlanCache(tile_size=32, cache_dir=str(tmp_path))
    loaded, status = fresh.plan(g, storage="bitpack")
    assert status == "disk" and loaded.tiled.storage == "bitpack"
    np.testing.assert_array_equal(
        np.asarray(loaded.tiled.tiles), np.asarray(p_pack.tiled.tiles)
    )


# ---------------------------------------------------------------------------
# request-key invariance (the mechanism behind batched parity)
# ---------------------------------------------------------------------------


def test_graph_key_is_storage_and_tiling_invariant():
    from repro.serve_mis.batcher import request_key

    g = erdos_renyi(50, avg_deg=4.0, seed=16)
    cache = PlanCache(tile_size=8)
    a = cache.plan(g, storage="int8")[0]
    b = cache.plan(g, storage="bitpack")[0]
    c = cache.plan(g, tile_size=16, storage="int8")[0]
    assert a.graph_key == b.graph_key == c.graph_key
    base = jax.random.key(0)
    ka, kb = request_key(base, a), request_key(base, b)
    assert jnp.all(jax.random.key_data(ka) == jax.random.key_data(kb))


@pytest.mark.parametrize("skip_dma", [False, True])
def test_kernel_col_flags_skip_dma_compose_with_bitpack(skip_dma):
    """The empty-C tile skip (and its DMA-skip variant) must be exact on
    packed tiles too — the skipped-or-not transfer is just 8× smaller."""
    from repro.kernels import tc_spmv

    g = erdos_renyi(200, avg_deg=6.0, seed=18)
    a = build_block_tiles(g, tile_size=16)
    b = a.to_storage("bitpack")
    flags = (
        jax.random.uniform(jax.random.key(3), (a.n_block_cols,)) > 0.5
    ).astype(jnp.int32)
    rhs = jax.random.normal(jax.random.key(4), (a.n_padded, 2), jnp.float32)
    rhs = rhs * jnp.repeat(flags, a.tile_size)[:, None].astype(jnp.float32)
    out_a = tc_spmv(a, rhs, col_flags=flags, skip_dma=skip_dma)
    out_b = tc_spmv(b, rhs, col_flags=flags, skip_dma=skip_dma)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_oracle_accepts_raw_packed_arrays():
    """The raw-array seam (core.distributed's entry) is storage-polymorphic:
    packed uint32 tiles flow through tile_spmv unchanged."""
    g = erdos_renyi(100, avg_deg=5.0, seed=17)
    a = build_block_tiles(g, tile_size=16)
    b = a.to_storage("bitpack")
    rhs = jax.random.normal(jax.random.key(1), (a.n_padded, 4), jnp.float32)
    oa = tile_spmv(a.tiles, a.tile_rows, a.tile_cols, rhs, a.n_block_rows, 16)
    ob = tile_spmv(b.tiles, b.tile_rows, b.tile_cols, rhs, b.n_block_rows, 16)
    np.testing.assert_array_equal(np.asarray(oa), np.asarray(ob))

"""Tests for repro.lint — rule engine, call-graph reachability, CLI.

Fixtures are tmp-dir `src/` trees (the linter is purely syntactic, so no
jax import is needed): each rule gets a positive and a negative fixture,
every hot-path category gets a *transitive* fixture where the violation
lives in a different module than the jitted entry point that reaches it,
and the suite self-checks that the real repo lints clean.
"""
import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.lint.analysis import load_universe
from repro.lint.baseline import Baseline
from repro.lint.cli import main
from repro.lint.emit import emit_sarif
from repro.lint.rules import ALL_RULES, get_rules, run_rules

REPO = pathlib.Path(__file__).resolve().parent.parent
ENV = {"PYTHONPATH": str(REPO / "src")}


def build(tmp_path, files):
    root = tmp_path / "src"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return root


def lint(tmp_path, files, rules=None):
    root = build(tmp_path, files)
    ctx = load_universe([root])
    return ctx, run_rules(ctx, get_rules(rules))


def active(findings, rule=None):
    return [
        f for f in findings
        if f.active and (rule is None or f.rule == rule)
    ]


# --------------------------------------------------------------------------
# RPR001–RPR005: the ported guards
# --------------------------------------------------------------------------
def test_rpr001_tile_unpack_outside_kernel_body(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/kernels/ops.py": """
            from repro.core.tiling import unpack_tile_bits
            def launch(tiles):
                return unpack_tile_bits(tiles)
        """,
    })
    assert len(active(fs, "RPR001")) == 1


def test_rpr001_kernel_body_and_oracle_are_sanctioned(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/kernels/ops.py": """
            from repro.core.tiling import unpack_tile_bits
            def _foo_kernel(ref, o_ref):
                o_ref[...] = unpack_tile_bits(ref[...])
        """,
        "repro/kernels/ref.py": """
            from repro.core.tiling import unpack_tile_bits
            def oracle(tiles):
                return unpack_tile_bits(tiles)
        """,
    })
    assert not active(fs, "RPR001")


def test_rpr002_densify_in_kernel_module(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/kernels/ops.py": """
            from repro.core.tiling import dense_tile_mask
            def _foo_kernel(ref):
                return dense_tile_mask(ref)
        """,
    })
    # flagged even inside a *_kernel body, exactly like the old Guard 2
    assert len(active(fs, "RPR002")) == 1


def test_rpr003_dyngraph_densify_outside_oracle(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/dyngraph/deltas.py": """
            from repro.core.storage import to_storage
            def apply_delta(t):
                return to_storage(t)
            def check_oracle(t):
                return to_storage(t)
        """,
    })
    hits = active(fs, "RPR003")
    assert len(hits) == 1 and hits[0].symbol == "apply_delta"


def test_rpr004_frontier_unpack_seams(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/misc.py": """
            from repro.core.tiling import unpack_frontier_bits
            def bad(words, n):
                return unpack_frontier_bits(words, n)
        """,
        "repro/core/tc_mis.py": """
            from repro.core.tiling import unpack_frontier_bits
            def _result(words, n):
                return unpack_frontier_bits(words, n)
        """,
        "repro/core/tiling.py": """
            def unpack_frontier_bits(words, n):
                return sorted_frontier_words(words)
            def sorted_frontier_words(words):
                return words
        """,
    })
    hits = active(fs, "RPR004")
    assert [f.symbol for f in hits] == ["bad"]


def test_rpr005_host_callbacks_and_debug_print(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/loopy.py": """
            import jax
            from jax.experimental import io_callback
            def tick(x):
                io_callback(print, None, x)
                jax.debug.print("x={}", x)
                return x
        """,
        "repro/api/report.py": """
            import jax
            def show(x):
                jax.debug.print("x={}", x)
        """,
    })
    hits = active(fs, "RPR005")
    assert len(hits) == 2  # io_callback + debug.print; api module exempt
    assert all(f.module == "repro.core.loopy" for f in hits)


# --------------------------------------------------------------------------
# RPR010 host sync — home module and transitively
# --------------------------------------------------------------------------
def test_rpr010_home_module_and_cold_negative(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import jax.numpy as jnp
            def _tc_mis_impl(state):
                return float(jnp.sum(state))
            def cold_helper(state):
                return state.alive.item()
        """,
    })
    hits = active(fs, "RPR010")
    assert [f.symbol for f in hits] == ["_tc_mis_impl"]


def test_rpr010_transitive_through_other_module(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            from repro.util.helpers import peek
            def _tc_mis_impl(state):
                return peek(state)
        """,
        "repro/util/helpers.py": """
            import numpy as np
            def peek(state):
                return np.asarray(state)
        """,
    })
    hits = active(fs, "RPR010")
    assert len(hits) == 1 and hits[0].module == "repro.util.helpers"


def test_rpr010_int_of_plain_shape_math_not_flagged(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(n, tile):
                return int(n // tile)
        """,
    })
    assert not active(fs, "RPR010")


# --------------------------------------------------------------------------
# RPR011 impurity — home module and transitively
# --------------------------------------------------------------------------
def test_rpr011_stdlib_time_and_global_write(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import time
            COUNT = 0
            def _tc_mis_impl(state):
                global COUNT
                COUNT += 1
                return time.perf_counter()
        """,
    })
    assert len(active(fs, "RPR011")) == 2  # global decl + time call


def test_rpr011_transitive_np_rng(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            from repro.util.noise import jitter
            def _tc_mis_impl(state):
                return jitter(state)
        """,
        "repro/util/noise.py": """
            import numpy as np
            def jitter(state):
                return np.random.default_rng(0)
        """,
    })
    hits = active(fs, "RPR011")
    assert len(hits) == 1 and hits[0].module == "repro.util.noise"


def test_rpr011_jax_random_is_fine(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import jax.random as random
            def _tc_mis_impl(key):
                return random.split(key)
        """,
    })
    assert not active(fs, "RPR011")


# --------------------------------------------------------------------------
# RPR012 dtype discipline
# --------------------------------------------------------------------------
def test_rpr012_builtin_and_64bit_dtypes(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import jax.numpy as jnp
            def _tc_mis_impl(x):
                a = jnp.zeros((4,), dtype=float)
                b = x.astype(jnp.float64)
                c = jnp.ones((4,), dtype=jnp.float32)
                return a, b, c
        """,
    })
    assert len(active(fs, "RPR012")) == 2


def test_rpr012_transitive(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            from repro.util.casts import widen
            def _tc_mis_impl(x):
                return widen(x)
        """,
        "repro/util/casts.py": """
            def widen(x):
                return x.astype(float)
        """,
    })
    hits = active(fs, "RPR012")
    assert len(hits) == 1 and hits[0].module == "repro.util.casts"


# --------------------------------------------------------------------------
# RPR013 loop-carry hygiene
# --------------------------------------------------------------------------
def test_rpr013_concatenate_in_named_body(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import jax.numpy as jnp
            from jax import lax
            def _tc_mis_impl(x):
                def body(c):
                    return jnp.concatenate([c, c])
                return lax.while_loop(lambda c: True, body, x)
        """,
    })
    assert len(active(fs, "RPR013")) == 1


def test_rpr013_lambda_body_and_clean_body(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import jax.numpy as jnp
            from jax import lax
            def grow(x):
                return lax.fori_loop(
                    0, 4, lambda i, c: jnp.hstack([c, c]), x)
            def fine(x):
                def body(c):
                    return c.at[0].set(1)
                return lax.while_loop(lambda c: True, body, x)
            def listy(x, acc):
                def body(c):
                    acc.append(1)  # plain list append: not an array op
                    return c
                return lax.while_loop(lambda c: True, body, x)
        """,
    })
    hits = active(fs, "RPR013")
    assert len(hits) == 1 and hits[0].symbol == "grow"


def test_rpr013_body_defined_in_other_module(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            from jax import lax
            from repro.util.bodies import body
            def _tc_mis_impl(x):
                return lax.while_loop(lambda c: True, body, x)
        """,
        "repro/util/bodies.py": """
            import jax.numpy as jnp
            def body(c):
                return jnp.concatenate([c, c])
        """,
    })
    hits = active(fs, "RPR013")
    assert len(hits) == 1 and hits[0].module == "repro.util.bodies"


# --------------------------------------------------------------------------
# RPR014 deprecation
# --------------------------------------------------------------------------
def test_rpr014_deprecated_import_and_call(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/analysis/run.py": """
            from repro.core.tc_mis import tc_mis
            def go(g):
                return tc_mis(g)
        """,
        "repro/analysis/ok.py": """
            from repro.api import Solver
            def go(g):
                return Solver().solve(g)
        """,
    })
    hits = active(fs, "RPR014")
    assert len(hits) == 2  # the import and the call
    assert all(f.module == "repro.analysis.run" for f in hits)


def test_rpr014_shim_modules_exempt(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/tc_mis.py": """
            def tc_mis(g):
                return g
        """,
        "repro/core/__init__.py": """
            from repro.core.tc_mis import tc_mis
        """,
    })
    assert not active(fs, "RPR014")


# --------------------------------------------------------------------------
# RPR015 Pallas kernel hygiene
# --------------------------------------------------------------------------
def test_rpr015_non_allowlisted_call_in_kernel(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/kernels/k.py": """
            import jax.numpy as jnp
            from repro.core.tiling import unpack_tile_mask
            from repro.util.debug import spy
            def _foo_kernel(ref, o_ref):
                t = unpack_tile_mask(ref[...])
                spy(t)
                def _epilogue(v):
                    return jnp.dot(v, v)
                o_ref[...] = _epilogue(t)
        """,
    })
    hits = active(fs, "RPR015")
    assert len(hits) == 1 and "spy" in hits[0].message


def test_rpr015_host_helpers_outside_kernels_fine(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/kernels/k.py": """
            from repro.core.tiling import pack_tile_bits
            def launch(tiles):
                return pack_tile_bits(tiles)
        """,
    })
    assert not active(fs, "RPR015")


# --------------------------------------------------------------------------
# RPR016 hot densify — the call-graph generalisation of Guard 4
# --------------------------------------------------------------------------
def test_rpr016_transitive_densify_outside_repro_pkg(tmp_path):
    # helper lives OUTSIDE the repro package, where the module-scoped
    # RPR004 cannot see it — only hot-path reachability catches it
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            from hotutil import blend
            def _tc_mis_impl(state):
                return blend(state)
        """,
        "hotutil.py": """
            from repro.core.tiling import unpack_frontier_bits
            def blend(state):
                return unpack_frontier_bits(state, 8)
        """,
    })
    assert not active(fs, "RPR004")
    hits = active(fs, "RPR016")
    assert len(hits) == 1 and hits[0].module == "hotutil"


# --------------------------------------------------------------------------
# call-graph reachability
# --------------------------------------------------------------------------
def test_reach_direct_call(tmp_path):
    ctx, _ = lint(tmp_path, {
        "repro/core/driver.py": """
            def helper(x):
                return x
            def _tc_mis_impl(x):
                return helper(x)
        """,
    })
    assert ctx.graph.is_hot("repro.core.driver:helper")


def test_reach_aliased_and_module_imports(tmp_path):
    ctx, _ = lint(tmp_path, {
        "repro/core/driver.py": """
            from repro.util.helpers import peek as p
            import repro.util.helpers as H
            def _tc_mis_impl(x):
                return p(x) + H.poke(x)
        """,
        "repro/util/helpers.py": """
            def peek(x):
                return x
            def poke(x):
                return x
        """,
    })
    assert ctx.graph.is_hot("repro.util.helpers:peek")
    assert ctx.graph.is_hot("repro.util.helpers:poke")


def test_reach_engine_methods_seeded_via_subclass(tmp_path):
    ctx, fs = lint(tmp_path, {
        "repro/core/engine.py": """
            class RoundEngine:
                def step(self, ctx):
                    raise NotImplementedError
        """,
        "repro/core/mine.py": """
            from repro.core.engine import RoundEngine
            class MyEngine(RoundEngine):
                def step(self, ctx):
                    return ctx.frontier.item()
        """,
    })
    assert ctx.graph.is_hot("repro.core.mine:MyEngine.step")
    assert len(active(fs, "RPR010")) == 1


def test_reach_method_dispatch_on_untyped_receiver_is_a_miss(tmp_path):
    # DOCUMENTED MISS: `obj.meth()` on a non-engine receiver does not
    # resolve — the receiver's type is not tracked (callgraph.py policy 5)
    ctx, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            class Bag:
                def bad(self):
                    return self.x.item()
            def _tc_mis_impl(bag):
                return bag.bad()
        """,
    })
    assert not ctx.graph.is_hot("repro.core.driver:Bag.bad")
    assert not active(fs, "RPR010")


def test_reach_pallas_call_reference_seeds_kernel(tmp_path):
    ctx, fs = lint(tmp_path, {
        "repro/core/launch.py": """
            from jax.experimental import pallas as pl
            def body(ref, o_ref):
                v = ref[...]
                o_ref[...] = v.item()
            def launch(x):
                return pl.pallas_call(body, out_shape=x)(x)
        """,
    })
    assert ctx.graph.is_hot("repro.core.launch:body")
    assert len(active(fs, "RPR010")) == 1


def test_kernel_suffix_outside_kernels_pkg_not_seeded(tmp_path):
    ctx, fs = lint(tmp_path, {
        "bench_stuff.py": """
            import numpy as np
            def _bench_pallas_kernel(n):
                return np.zeros(n)
        """,
    })
    assert not ctx.graph.is_hot("bench_stuff:_bench_pallas_kernel")
    assert not active(fs)


# --------------------------------------------------------------------------
# suppressions
# --------------------------------------------------------------------------
def test_inline_suppression_on_flagged_line(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                return x.item()  # repro-lint: disable=RPR010 epilogue sync
        """,
    })
    assert not active(fs)
    assert any(f.suppressed and f.rule == "RPR010" for f in fs)


def test_def_line_suppression_covers_whole_function(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            import time
            def _tc_mis_impl(x):  # repro-lint: disable=RPR010,RPR011 host-stepped twin
                t = time.perf_counter()
                return x.item(), t
            def other(x):
                return x
        """,
    })
    assert not active(fs)
    assert sum(1 for f in fs if f.suppressed) == 2


def test_suppression_for_other_rule_does_not_mask(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                return x.item()  # repro-lint: disable=RPR011 wrong rule
        """,
    })
    assert len(active(fs, "RPR010")) == 1


# --------------------------------------------------------------------------
# baseline round-trip
# --------------------------------------------------------------------------
def test_baseline_round_trip(tmp_path):
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                return x.item()
        """,
    })
    assert active(fs)
    path = tmp_path / "baseline.json"
    Baseline.from_findings(fs).save(path)
    reloaded = Baseline.load(path)
    assert len(reloaded) == len([f for f in fs if not f.suppressed])
    applied = reloaded.apply(fs)
    assert not [f for f in applied if f.active]
    assert all(f.baselined for f in applied if not f.suppressed)


def test_baseline_count_semantics(tmp_path):
    # two identical findings, one baseline slot -> one stays active
    _, fs = lint(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                a = x.item()
                b = x.item()
                return a, b
        """,
    })
    assert len(active(fs)) == 2
    bl = Baseline.from_findings(fs[:1])
    applied = bl.apply(fs)
    assert sum(1 for f in applied if f.baselined) == 1
    assert sum(1 for f in applied if f.active) == 1


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def test_cli_exit_codes(tmp_path, capsys):
    root = build(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                return x.item()
        """,
    })
    assert main([str(root), "--no-baseline"]) == 1
    assert main([str(root), "--rules", "RPR001", "--no-baseline"]) == 0
    assert main([str(root), "--rules", "NOPE", "--no-baseline"]) == 2
    assert main([str(tmp_path / "missing"), "--no-baseline"]) == 2
    assert main(["--list-rules"]) == 0
    capsys.readouterr()


def test_cli_baseline_flow(tmp_path, capsys):
    root = build(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                return x.item()
        """,
    })
    bl = tmp_path / "bl.json"
    assert main([str(root), "--baseline", str(bl), "--update-baseline"]) == 0
    assert main([str(root), "--baseline", str(bl)]) == 0
    capsys.readouterr()


def test_cli_sarif_output(tmp_path, capsys):
    root = build(tmp_path, {
        "repro/core/driver.py": """
            def _tc_mis_impl(x):
                return x.item()  # repro-lint: disable=RPR010 fixture
        """,
    })
    out = tmp_path / "out.sarif"
    assert main(
        [str(root), "--no-baseline", "--format", "sarif", "-o", str(out)]
    ) == 0
    doc = json.loads(out.read_text())
    assert doc["version"] == "2.1.0"
    run = doc["runs"][0]
    assert any(r["id"] == "RPR010" for r in run["tool"]["driver"]["rules"])
    assert run["results"][0]["suppressions"][0]["kind"] == "inSource"
    capsys.readouterr()


def test_sarif_rule_metadata_complete():
    doc = json.loads(emit_sarif([], ALL_RULES))
    rules = doc["runs"][0]["tool"]["driver"]["rules"]
    assert len(rules) == len(ALL_RULES)
    assert all(r["help"]["text"] for r in rules)


# --------------------------------------------------------------------------
# self-checks against the real repo
# --------------------------------------------------------------------------
def test_repo_src_lints_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "src"],
        cwd=REPO, env={**ENV, "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 error(s)" in proc.stdout


def test_repo_shipped_baseline_is_empty():
    data = json.loads((REPO / "tools" / "lint_baseline.json").read_text())
    assert data == {"version": 1, "entries": []}


def test_ci_guards_shim_delegates_and_passes():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "ci_guards.py")],
        cwd=REPO, env={"PATH": "/usr/bin:/bin"},
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    shim = (REPO / "tools" / "ci_guards.py").read_text()
    assert len(shim.splitlines()) <= 30
    assert "repro.lint" in shim

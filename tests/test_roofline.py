"""Roofline machinery: HLO collective parser, per-device cost semantics."""
import numpy as np
import pytest

from benchmarks.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    parse_collective_bytes,
)


def test_parser_basic_ops():
    hlo = """
    %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups={}
    %ar = f32[1024]{0} all-reduce(%y), to_apply=%add
    %rs = f32[64,32]{1,0} reduce-scatter(%z), dimensions={0}
    %a2a = s32[128]{0} all-to-all(%w)
    %cp = u8[256]{0} collective-permute(%v)
    """
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 16 * 512 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 64 * 32 * 4
    assert out["all-to-all"] == 128 * 4
    assert out["collective-permute"] == 256


def test_parser_async_start_not_double_counted():
    hlo = """
    %ags = (bf16[8,8]{1,0}, bf16[32,8]{1,0}) all-gather-start(%x)
    %agd = bf16[32,8]{1,0} all-gather-done(%ags)
    """
    out = parse_collective_bytes(hlo)
    # counted once, from the -start tuple payload
    assert out["all-gather"] == (8 * 8 + 32 * 8) * 2
    assert len(out) == 1


def test_parser_tuple_allreduce():
    hlo = "%t = (f32[10]{0}, f32[20]{0}) all-reduce(%a, %b), to_apply=%add"
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == (10 + 20) * 4


def test_parser_ignores_non_collectives():
    hlo = "%d = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}"
    assert parse_collective_bytes(hlo) == {}


def test_terms_dominance_and_mfu():
    t = RooflineTerms(
        compute_s=1.0, memory_s=2.0, collective_s=0.5,
        flops=PEAK_FLOPS, bytes_accessed=2 * HBM_BW,
        collective_bytes=int(0.5 * ICI_BW), collectives={},
        model_flops=PEAK_FLOPS / 2,
    )
    assert t.dominant == "memory"
    assert t.step_time_s == 2.0
    assert t.mfu == pytest.approx(0.25)
    assert t.useful_flop_fraction == pytest.approx(0.5)


def test_per_device_cost_semantics():
    """cost_analysis of an SPMD-compiled program reports PER-DEVICE numbers
    (the roofline denominators assume this)."""
    from conftest import run_multidevice

    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
        M, K, N = 256, 128, 64
        def f(a, b):
            return a @ b
        with mesh:
            c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("d", None)),
                                         NamedSharding(mesh, P(None, None)))) \\
                .lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                       jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
        flops = c.cost_analysis()["flops"]
        expect_per_dev = 2 * M * K * N / 8
        ratio = flops / expect_per_dev
        assert 0.9 < ratio < 1.1, f"not per-device: {ratio}"
        print("PER_DEVICE_OK")
    """)
    assert "PER_DEVICE_OK" in out


def test_affine_extrapolation_math():
    from repro.launch.dryrun import _affine

    a = dict(flops=10.0, bytes_accessed=100.0, collectives={"all-reduce": 4})
    b = dict(flops=16.0, bytes_accessed=130.0, collectives={"all-reduce": 10})
    out = _affine(a, b, la=2, lb=4, lfull=10)
    assert out["flops"] == pytest.approx(10 + 3 * 8)       # +3/layer × 8 layers
    assert out["bytes_accessed"] == pytest.approx(100 + 15 * 8)
    assert out["collectives"]["all-reduce"] == 4 + 3 * 8

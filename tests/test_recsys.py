"""DeepFM: FM identity, retrieval factorisation exactness, smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs import REGISTRY
from repro.models.deepfm import (
    DeepFMConfig,
    deepfm_init,
    deepfm_logits,
    deepfm_loss,
    retrieval_score,
)


def test_arch_smoke():
    REGISTRY["deepfm"].smoke()


def test_fm_identity_vs_bruteforce():
    """½(‖Σv‖²−Σ‖v‖²) == Σ_{i<j} ⟨v_i, v_j⟩."""
    cfg = DeepFMConfig(field_vocabs=(7, 5, 9, 4), embed_dim=6, mlp_dims=(8,))
    params = deepfm_init(jax.random.key(0), cfg)
    fields = jax.random.randint(jax.random.key(1), (10, 4), 0, 4, jnp.int32)
    flat = fields + cfg.offsets[None, :]
    v = params["embed"][flat]                     # (B, F, d)
    brute = jnp.zeros((10,))
    F = 4
    for i in range(F):
        for j in range(i + 1, F):
            brute += jnp.sum(v[:, i] * v[:, j], axis=-1)
    s = v.sum(axis=1)
    fm = 0.5 * (jnp.sum(s * s, -1) - jnp.sum(v * v, axis=(1, 2)))
    assert_allclose(np.asarray(fm), np.asarray(brute), rtol=1e-5, atol=1e-5)


def test_retrieval_matches_full_model_when_deep_is_user_side():
    """With the deep tower blind to the item field, the factorised retrieval
    sweep must EXACTLY equal full DeepFM logits per candidate."""
    cfg = DeepFMConfig(field_vocabs=(50, 8, 8, 8), embed_dim=6, mlp_dims=(16,))
    params = deepfm_init(jax.random.key(0), cfg)
    user = jnp.asarray([0, 3, 1, 5], jnp.int32)   # item_field=0 ignored
    cands = jnp.arange(50, dtype=jnp.int32)

    scores = retrieval_score(params, cfg, user, cands, item_field=0)

    # full model, with the item embedding zeroed INSIDE the deep tower only
    full = []
    for c in range(50):
        fields = user.at[0].set(c)[None, :]
        flat = fields + cfg.offsets[None, :]
        v = params["embed"][flat]
        lin = params["linear"][flat].sum(1)
        s = v.sum(1)
        fm = 0.5 * (jnp.sum(s * s, -1) - jnp.sum(v * v, axis=(1, 2)))
        v_deep = v.at[:, 0].set(0.0)              # deep tower = user side only
        from repro.models.gnn.common import mlp_apply

        deep = mlp_apply(params["mlp"], v_deep.reshape(1, -1), act=jax.nn.relu)[:, 0]
        full.append(params["bias"] + lin + fm + deep)
    full = jnp.concatenate(full)
    assert_allclose(np.asarray(scores), np.asarray(full), rtol=1e-4, atol=1e-4)


def test_training_reduces_loss():
    from repro.data.pipeline import ClickStream
    from repro.train.optimizer import OptConfig, adamw_init, adamw_update

    cfg = DeepFMConfig(field_vocabs=tuple([32] * 10), embed_dim=8, mlp_dims=(32,))
    params = deepfm_init(jax.random.key(0), cfg)
    opt = adamw_init(params)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=100, weight_decay=0.0)
    stream = ClickStream(cfg.field_vocabs, batch=256, seed=0)

    @jax.jit
    def step(params, opt, fields, labels):
        loss, grads = jax.value_and_grad(
            lambda p: deepfm_loss(p, cfg, fields, labels)
        )(params)
        params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss

    losses = []
    for i in range(60):
        f, l = stream.batch_at(i)
        params, opt, loss = step(params, opt, jnp.asarray(f), jnp.asarray(l))
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.01, losses[::10]

"""The dyngraph subsystem (DESIGN.md §12): deltas, retiling, repair, stream.

Covers the delta-lifecycle contract end to end: canonical `EdgeDelta`s with
a true inverse, tile-local retiling that is BIT-EXACT with a from-scratch
rebuild of the mutated graph (the correctness oracle), warm-started MIS
repair that stays valid for every registered engine and both storages,
epoch-keyed plan-cache patching with stale pre-delta eviction, the serving
update op, the chunked ingestion readers, and the CI guard that keeps the
delta path from ever densifying packed tiles.
"""
import importlib.util
import os
import pathlib
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.api import (
    Plan,
    PlanCache,
    SolveOptions,
    Solver,
    delta_cache_key,
    patch_plan,
)
from repro.api.plan import _PLAN_VERSION
from repro.core.engine import engine_names
from repro.core.tiling import build_block_tiles
from repro.core.validate import is_valid_mis_jit
from repro.dyngraph import (
    EdgeDelta,
    apply_delta,
    apply_graph_delta,
    load_delta,
    load_graph_stream,
    parse_delta,
    random_delta,
)
from repro.graphs.generators import erdos_renyi, grid2d
from repro.serve_mis import MISService, ServeConfig
from repro.serve_mis.io import GraphParseError, load_graph

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


# ---------------------------------------------------------------------------
# EdgeDelta canonicalisation
# ---------------------------------------------------------------------------


def test_delta_canonicalises_like_from_edges():
    # duplicates, both directions, self loops — one canonical (lo, hi) set
    d = EdgeDelta.make([3, 1, 1, 2, 5], [1, 3, 1, 4, 5], [7], [6])
    np.testing.assert_array_equal(d.add, [[1, 3], [2, 4]])
    np.testing.assert_array_equal(d.remove, [[6, 7]])
    assert (d.n_add, d.n_remove, d.is_empty) == (2, 1, False)
    np.testing.assert_array_equal(d.touched(), [1, 2, 3, 4, 6, 7])


def test_delta_content_key_is_input_order_invariant():
    a = EdgeDelta.make([1, 5], [2, 6], [8], [9])
    b = EdgeDelta.make([6, 2], [5, 1], [9], [8])
    assert a.content_key == b.content_key
    assert a.content_key != a.inverse().content_key
    assert EdgeDelta.make().is_empty


def test_delta_overlap_and_bounds_rejected():
    with pytest.raises(ValueError, match="both add and remove"):
        EdgeDelta.make([1], [2], [2], [1])
    with pytest.raises(ValueError, match="grow the vertex set"):
        EdgeDelta.make([1], [99]).check_bounds(50)


def test_delta_inverse_and_mapped():
    d = EdgeDelta.make([0], [1], [2], [3])
    inv = d.inverse()
    np.testing.assert_array_equal(inv.add, d.remove)
    np.testing.assert_array_equal(inv.remove, d.add)
    # a permutation that flips (lo, hi) order still canonicalises
    mapping = np.array([3, 2, 1, 0])
    m = d.mapped(mapping)
    np.testing.assert_array_equal(m.add, [[2, 3]])
    np.testing.assert_array_equal(m.remove, [[0, 1]])


# ---------------------------------------------------------------------------
# graph-level application (strict set semantics)
# ---------------------------------------------------------------------------


def test_apply_graph_delta_matches_fresh_build():
    g = erdos_renyi(60, avg_deg=4.0, seed=0)
    d = random_delta(g, n_add=5, n_remove=5, seed=1)
    g2 = apply_graph_delta(g, d)
    assert g2.n_edges == g.n_edges  # 5 in, 5 out (half-edges balance)
    # strictness both ways
    with pytest.raises(ValueError, match="already in the graph"):
        apply_graph_delta(g2, EdgeDelta(add=d.add, remove=np.zeros((0, 2), np.int64)))
    with pytest.raises(ValueError, match="not in the graph"):
        apply_graph_delta(g2, EdgeDelta(add=np.zeros((0, 2), np.int64), remove=d.remove))
    # inverse restores the edge list bit-exactly
    g3 = apply_graph_delta(g2, d.inverse())
    np.testing.assert_array_equal(np.asarray(g3.senders), np.asarray(g.senders))
    np.testing.assert_array_equal(np.asarray(g3.receivers), np.asarray(g.receivers))


# ---------------------------------------------------------------------------
# tile-local retiling: the rebuild oracle
# ---------------------------------------------------------------------------


def _assert_tiled_equal(a, b):
    assert a.n_tiles == b.n_tiles and a.storage == b.storage
    np.testing.assert_array_equal(np.asarray(a.tiles), np.asarray(b.tiles))
    np.testing.assert_array_equal(np.asarray(a.tile_rows), np.asarray(b.tile_rows))
    np.testing.assert_array_equal(np.asarray(a.tile_cols), np.asarray(b.tile_cols))
    np.testing.assert_array_equal(np.asarray(a.row_starts), np.asarray(b.row_starts))


@pytest.mark.parametrize("storage", ["int8", "bitpack"])
@pytest.mark.parametrize("T", [8, 32])
def test_apply_delta_bit_exact_with_rebuild(T, storage):
    g = erdos_renyi(150, avg_deg=5.0, seed=2)
    tiled = build_block_tiles(g, tile_size=T, storage=storage)
    d = random_delta(g, n_add=12, n_remove=9, seed=3)
    patched = apply_delta(tiled, d)
    rebuilt = build_block_tiles(
        apply_graph_delta(g, d), tile_size=T, storage=storage
    )
    _assert_tiled_equal(patched, rebuilt)


def test_apply_delta_fast_path_reuses_index_arrays():
    """Edits confined to existing tiles must not re-upload the tile index
    (same device arrays), and an empty delta is a pure pass-through."""
    g = grid2d(8, 8)
    tiled = build_block_tiles(g, tile_size=8)
    # removing one existing edge never changes the tile list on a grid tile
    s = np.asarray(g.senders)[0]
    r = np.asarray(g.receivers)[0]
    d = EdgeDelta.make(rem_src=[int(s)], rem_dst=[int(r)])
    patched = apply_delta(tiled, d)
    assert patched.tile_rows is tiled.tile_rows
    assert patched.tile_cols is tiled.tile_cols
    assert patched.row_starts is tiled.row_starts
    assert apply_delta(tiled, EdgeDelta.make()) is tiled


def test_apply_delta_drains_and_inserts_tiles():
    """Removing a tile's last edge drops it; adding into an untouched block
    inserts one — both matching the rebuild (includes the drained case the
    fast path alone never exercises)."""
    g = erdos_renyi(64, avg_deg=2.0, seed=4)
    T = 8
    for storage in ("int8", "bitpack"):
        tiled = build_block_tiles(g, tile_size=T, storage=storage)
        # remove EVERY edge of the first block-row pair, add a far-corner edge
        s = np.asarray(g.senders)[: g.n_edges]
        r = np.asarray(g.receivers)[: g.n_edges]
        in_first = (s // T == 0) & (r // T == 0)
        d = EdgeDelta.make(
            add_src=[0], add_dst=[g.n_nodes - 1],
            rem_src=s[in_first], rem_dst=r[in_first],
        )
        patched = apply_delta(tiled, d)
        rebuilt = build_block_tiles(
            apply_graph_delta(g, d), tile_size=T, storage=storage
        )
        _assert_tiled_equal(patched, rebuilt)


@settings(max_examples=15, deadline=None)
@given(
    T=st.sampled_from([8, 16, 32, 64, 128, 256]),
    storage=st.sampled_from(["int8", "bitpack"]),
    n_add=st.integers(0, 12),
    n_remove=st.integers(0, 12),
    seed=st.integers(0, 1000),
)
def test_delta_roundtrip_property(T, storage, n_add, n_remove, seed):
    """apply_delta(·, d) then apply_delta(·, d.inverse()) restores the
    tiling bit-exactly — any T, either storage (the satellite property)."""
    g = erdos_renyi(90, avg_deg=4.0, seed=seed % 17)
    tiled = build_block_tiles(g, tile_size=T, storage=storage)
    d = random_delta(g, n_add=n_add, n_remove=n_remove, seed=seed)
    restored = apply_delta(apply_delta(tiled, d), d.inverse())
    _assert_tiled_equal(restored, tiled)


# ---------------------------------------------------------------------------
# Plan patching + the epoch-keyed cache
# ---------------------------------------------------------------------------


def test_patch_plan_epoch_and_key_lineage():
    g = erdos_renyi(70, avg_deg=4.0, seed=5)
    plan = Plan.build(g, tile_size=8)
    d = random_delta(g, n_add=3, n_remove=3, seed=6)
    p1 = plan.apply_delta(d)
    assert p1.epoch == 1 and plan.epoch == 0
    assert p1.key == delta_cache_key(plan.key, d.content_key)
    assert p1.tile_size == plan.tile_size and p1.storage == plan.storage
    # empty delta: pure pass-through, no epoch bump
    assert plan.apply_delta(EdgeDelta.make()) is plan
    # lineage keys differ from content keys: same state, different history
    p2 = p1.apply_delta(d.inverse())
    assert p2.epoch == 2 and p2.key != plan.key
    np.testing.assert_array_equal(
        np.asarray(p2.tiled.tiles), np.asarray(plan.tiled.tiles)
    )


def test_patch_plan_maps_delta_through_rcm_perm():
    g = erdos_renyi(80, avg_deg=4.0, seed=7)
    plan = Plan.build(g, tile_size=8, reorder="rcm")
    d = random_delta(g, n_add=4, n_remove=4, seed=8)   # ORIGINAL ids
    p1 = patch_plan(plan, d)
    assert p1.perm is not None and p1.reorder == "rcm"
    # patched plan's graph == fresh RCM-mapped build of the mutated graph
    g2 = apply_graph_delta(g, d)
    s = np.asarray(g2.senders)[: g2.n_edges]
    r = np.asarray(g2.receivers)[: g2.n_edges]
    from repro.graphs.graph import from_edges

    expect = from_edges(p1.inv[s], p1.inv[r], g2.n_nodes)
    np.testing.assert_array_equal(
        np.asarray(p1.g.senders), np.asarray(expect.senders)
    )


def test_plan_cache_apply_delta_statuses_and_epoch_eviction(tmp_path):
    """THE epoch-eviction satellite: a patched plan's stale pre-delta npz
    entry is detected, warned about once, unlinked, and counted in
    `stats.evicted_stale` — mirroring the v1-migration smoke."""
    g = erdos_renyi(60, avg_deg=4.0, seed=9)
    cache = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    plan, status = cache.plan(g)
    assert status == "built"
    parent_path = cache._path(plan.key)
    assert os.path.exists(parent_path)

    d = random_delta(g, n_add=3, n_remove=2, seed=10)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        p1, status = cache.apply_delta(plan, d)
    assert status == "built" and p1.epoch == 1
    # pre-delta entry: detected, warned once, unlinked, counted
    assert not os.path.exists(parent_path)
    assert cache.stats["evicted_stale"] == 1
    msgs = [str(w.message) for w in caught]
    assert sum("pre-delta entry" in m for m in msgs) == 1, msgs

    # the patched entry persists under the CURRENT (v2) format
    with np.load(cache._path(p1.key)) as z:
        assert int(z["meta"][6]) == _PLAN_VERSION
        assert int(z["epoch"][0]) == 1

    # memoisation layers: mem hit same cache, disk hit from a fresh cache
    assert cache.apply_delta(plan, d)[1] == "mem"
    fresh = PlanCache(tile_size=8, cache_dir=str(tmp_path))
    p1d, status = fresh.apply_delta(plan, d)
    assert status == "disk" and p1d.epoch == 1
    np.testing.assert_array_equal(
        np.asarray(p1d.tiled.tiles), np.asarray(p1.tiled.tiles)
    )

    # chaining: the epoch-1 entry is itself retired by the epoch-2 patch
    d2 = random_delta(p1.g, n_add=2, n_remove=2, seed=11)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        p2, _ = cache.apply_delta(p1, d2)
    assert p2.epoch == 2
    assert not os.path.exists(cache._path(p1.key))
    assert cache.stats["evicted_stale"] == 2


# ---------------------------------------------------------------------------
# incremental repair: every engine, both storages
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", engine_names())
@pytest.mark.parametrize("storage", ["int8", "bitpack"])
def test_repair_valid_and_empty_delta_bit_identical(engine, storage):
    g = erdos_renyi(90, avg_deg=5.0, seed=12)
    solver = Solver(SolveOptions(
        engine=engine, tile_size=8, storage=storage, placement="local",
        repair="incremental",
    ))
    prior = solver.solve(g)
    d = random_delta(g, n_add=6, n_remove=6, seed=13)
    res = solver.update(prior, d)
    assert res.stats["repair"] == "incremental"
    assert res.plan.epoch == 1
    assert all(is_valid_mis_jit(res.plan.g, jnp.asarray(res.in_mis_plan)))
    # empty delta: bit-identical to the prior (== cold, by determinism)
    res0 = solver.update(prior, EdgeDelta.make())
    assert res0.rounds == 0
    np.testing.assert_array_equal(res0.in_mis, prior.in_mis)


def test_repair_empty_delta_matches_cold_mode_exactly():
    g = erdos_renyi(90, avg_deg=5.0, seed=14)
    inc = Solver(SolveOptions(engine="tiled_ref", tile_size=8,
                              repair="incremental"))
    cold = Solver(SolveOptions(engine="tiled_ref", tile_size=8,
                               repair="cold"))
    prior_i, prior_c = inc.solve(g), cold.solve(g)
    np.testing.assert_array_equal(prior_i.in_mis, prior_c.in_mis)
    ri = inc.update(prior_i, EdgeDelta.make())
    rc = cold.update(prior_c, EdgeDelta.make())
    assert (ri.stats["repair"], rc.stats["repair"]) == ("incremental", "cold")
    np.testing.assert_array_equal(ri.in_mis, rc.in_mis)


def test_repair_fewer_rounds_than_cold_on_small_delta():
    g = erdos_renyi(400, avg_deg=8.0, seed=15)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=16,
                                 repair="incremental"))
    prior = solver.solve(g)
    d = random_delta(g, n_add=4, n_remove=4, seed=16)   # ≪ 1% of edges
    res = solver.update(prior, d)
    cold = solver.solve(res.plan)
    assert res.rounds < cold.rounds, (res.rounds, cold.rounds)


def test_repair_auto_policy_falls_back_to_cold():
    g = erdos_renyi(60, avg_deg=4.0, seed=17)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8,
                                 repair="auto", repair_threshold=0.05))
    prior = solver.solve(g)
    # touches far more than 5% of vertices → auto goes cold
    d = random_delta(g, n_add=30, n_remove=30, seed=18)
    res = solver.update(prior, d)
    assert res.stats["repair"] == "cold"
    assert all(is_valid_mis_jit(res.plan.g, jnp.asarray(res.in_mis_plan)))
    # a single-edge delta stays incremental
    d2 = random_delta(res.plan.g, n_add=1, n_remove=0, seed=19)
    res2 = solver.update(res, d2)
    assert res2.stats["repair"] == "incremental"


def test_repair_chain_stays_valid_with_rcm():
    """Updates compose across epochs, including through an RCM permutation
    (deltas arrive in original ids; results stay original-id)."""
    g = erdos_renyi(120, avg_deg=5.0, seed=20)
    solver = Solver(SolveOptions(engine="tiled_ref", tile_size=8,
                                 reorder="rcm", repair="incremental"))
    res = solver.solve(g)
    rng = np.random.default_rng(21)
    for step in range(3):
        # deltas in ORIGINAL ids: regenerate against the original-id view
        orig_mis = res.in_mis
        d = random_delta(_original_graph(res.plan), 3, 3, rng=rng)
        res = solver.update(res, d)
        assert res.plan.epoch == step + 1
        assert all(is_valid_mis_jit(res.plan.g, jnp.asarray(res.in_mis_plan)))
        assert res.in_mis.shape == orig_mis.shape


def _original_graph(plan):
    """The plan's graph mapped back to original vertex ids."""
    from repro.graphs.graph import from_edges

    g = plan.g
    if plan.perm is None:
        return g
    s = np.asarray(g.senders)[: g.n_edges]
    r = np.asarray(g.receivers)[: g.n_edges]
    return from_edges(plan.perm[s], plan.perm[r], g.n_nodes)


def test_unknown_repair_spelling_rejected():
    with pytest.raises(ValueError, match="valid"):
        SolveOptions(repair="warm")


# ---------------------------------------------------------------------------
# serving: the update op
# ---------------------------------------------------------------------------


def test_service_update_flow():
    svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref"))
    g = erdos_renyi(80, avg_deg=4.0, seed=22)
    rid = svc.submit(g)
    (base,) = svc.drain()
    assert base.valid

    d = random_delta(g, n_add=4, n_remove=4, seed=23)
    uid = svc.submit_update(rid, d)
    (resp,) = svc.drain()
    assert resp.id == uid and resp.valid
    assert resp.stats["repair"] == "incremental"
    assert resp.stats["plan_epoch"] == 1 and resp.stats["base_id"] == rid
    assert resp.summary()["plan_epoch"] == 1

    # chaining targets the update's own id; unknown/unserved ids raise
    d2 = random_delta(svc._results[uid].plan.g, n_add=2, n_remove=1, seed=24)
    svc.submit_update(uid, d2)
    (resp2,) = svc.drain()
    assert resp2.valid and resp2.stats["plan_epoch"] == 2
    with pytest.raises(KeyError, match="has not completed"):
        svc.submit_update(999, d)


def test_service_bad_delta_yields_error_response_not_crash():
    """A strictness-violating delta passes submit (bounds are the only
    cheap check) but must surface as an INVALID error response at step —
    never an exception that kills the stream or its window-mates."""
    svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref"))
    g = erdos_renyi(60, avg_deg=4.0, seed=27)
    rid = svc.submit(g)
    svc.drain()
    # a guaranteed NON-edge (random_delta samples adds from non-edges),
    # submitted as a removal — strict set semantics reject it at patch time
    non_edge = random_delta(g, n_add=1, n_remove=0, seed=28).add
    bad = EdgeDelta(add=np.zeros((0, 2), np.int64), remove=non_edge)
    svc.submit_update(rid, bad)
    svc.submit(grid2d(5, 5))                    # the window-mate survives
    out = svc.step()
    assert len(out) == 2
    err, ok = out
    assert not err.valid and "not in the graph" in err.stats["error"]
    assert ok.valid
    # out-of-range endpoints fail fast at submit instead
    with pytest.raises(ValueError, match="grow the vertex set"):
        svc.submit_update(rid, EdgeDelta.make([0], [10_000]))


def test_service_cold_empty_delta_bit_identical_to_base():
    """The service keys updates off the patched graph's content
    (`request_key`), so even repair='cold' reproduces the base response
    bit-for-bit on an empty delta — the §12 empty-delta contract holds in
    serving, not just at the Solver level."""
    g = erdos_renyi(70, avg_deg=4.0, seed=29)
    for repair in ("cold", "incremental"):
        svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref",
                                     repair=repair))
        rid = svc.submit(g)
        (base,) = svc.drain()
        svc.submit_update(rid, EdgeDelta.make())
        (resp,) = svc.drain()
        assert resp.stats["repair"] == repair
        np.testing.assert_array_equal(resp.in_mis, base.in_mis)


def test_service_update_mixes_with_solves_in_one_step():
    svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref", max_batch=4))
    g = erdos_renyi(70, avg_deg=4.0, seed=25)
    rid = svc.submit(g)
    svc.drain()
    svc.submit(grid2d(6, 6))
    svc.submit_update(rid, random_delta(g, 2, 2, seed=26))
    svc.submit(grid2d(5, 7))
    out = svc.step()                      # one window: solve, update, solve
    assert [type(r).__name__ for r in out] == ["Response"] * 3
    assert all(r.valid for r in out)
    kinds = ["repair" in r.stats for r in out]
    assert kinds == [False, True, False]  # response order is pop order


# ---------------------------------------------------------------------------
# streaming ingestion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["tiny.mtx", "tiny.edges", "tiny.dimacs"])
def test_stream_ingestion_matches_readlines(name):
    path = os.path.join(FIXTURES, name)
    a = load_graph(path)
    b = load_graph_stream(path, chunk_edges=2)   # force many tiny chunks
    assert (a.n_nodes, a.n_edges) == (b.n_nodes, b.n_edges)
    np.testing.assert_array_equal(np.asarray(a.senders), np.asarray(b.senders))
    np.testing.assert_array_equal(
        np.asarray(a.receivers), np.asarray(b.receivers)
    )


def test_stream_parse_errors(tmp_path):
    bad = tmp_path / "bad.edges"
    bad.write_text("0 1\n2 x\n")
    with pytest.raises(GraphParseError, match="line 2"):
        load_graph_stream(str(bad))
    trunc = tmp_path / "trunc.mtx"
    trunc.write_text("%%MatrixMarket matrix coordinate pattern general\n"
                     "4 4 3\n1 2\n2 3\n")
    with pytest.raises(GraphParseError, match="promised 3"):
        load_graph_stream(str(trunc))
    nop = tmp_path / "nop.dimacs"
    nop.write_text("c no problem line\ne 1 2\n")
    with pytest.raises(GraphParseError, match="no `p` problem line"):
        load_graph_stream(str(nop))


def test_stream_service_submit_parity():
    svc = MISService(ServeConfig(tile_size=8, engine="tiled_ref"))
    path = os.path.join(FIXTURES, "tiny.edges")
    svc.submit(path)
    svc.submit(path, stream=True)
    a, b = svc.drain()
    np.testing.assert_array_equal(a.in_mis, b.in_mis)
    assert b.stats["plan_cache"] == "mem"   # same content hash → cache hit


def test_load_delta_format(tmp_path):
    p = tmp_path / "d.delta"
    p.write_text("# comment\n+ 1 2\n3 4\n- 5 6\n")
    d = load_delta(str(p))
    np.testing.assert_array_equal(d.add, [[1, 2], [3, 4]])
    np.testing.assert_array_equal(d.remove, [[5, 6]])
    with pytest.raises(GraphParseError, match="line 1"):
        parse_delta(["+ 1 x"])


# ---------------------------------------------------------------------------
# the CI guard: dyngraph never densifies
# ---------------------------------------------------------------------------


def test_ci_guard_dyngraph_clean_and_detects_violations(tmp_path):
    from repro.lint.analysis import load_universe
    from repro.lint.cli import main as lint_main
    from repro.lint.rules import get_rules, run_rules

    # the shipped dyngraph modules are clean (guard rule RPR003)
    root = pathlib.Path(__file__).resolve().parent.parent
    assert lint_main(
        ["--rules", "RPR003", "--no-baseline", str(root / "src" / "repro")]
    ) == 0
    # a densify outside an *_oracle body is flagged; inside one is allowed
    bad = tmp_path / "src" / "repro" / "dyngraph" / "sneaky.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(
        "def patch(t):\n"
        "    return unpack_tile_bits(t.tiles, t.tile_size)\n"
        "def check_oracle(t):\n"
        "    return dense_tiles(t.tiles, t.tile_size)\n"
    )
    ctx = load_universe([tmp_path / "src"])
    problems = [
        f for f in run_rules(ctx, get_rules(["RPR003"])) if f.active
    ]
    assert len(problems) == 1 and "unpack_tile_bits" in problems[0].message

"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode)
against its pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.tiling import build_block_tiles
from repro.graphs.generators import erdos_renyi
from repro.kernels import embedding_bag, tc_neighbor_max, tc_spmv
from repro.kernels.ref import (
    embedding_bag_ref,
    tc_neighbor_max_ref,
    tc_spmv_ref,
)

_NEG = -(1 << 30)


def _tiled(n, deg, T, seed):
    g = erdos_renyi(n, avg_deg=deg, seed=seed)
    return g, build_block_tiles(g, tile_size=T)


@pytest.mark.parametrize("T", [8, 16, 32, 64, 128])
@pytest.mark.parametrize("lanes", [1, 8])
def test_spmv_shape_sweep(T, lanes):
    g, tiled = _tiled(4 * T + 7, 6.0, T, seed=T)
    rhs = jax.random.normal(jax.random.key(1), (tiled.n_padded, lanes), jnp.float32)
    out = tc_spmv(tiled, rhs)
    ref = tc_spmv_ref(tiled.tiles, tiled.tile_rows, tiled.tile_cols, rhs,
                      tiled.n_block_rows)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_dtype_sweep(dtype):
    g, tiled = _tiled(150, 8.0, 32, seed=0)
    rhs = jax.random.normal(jax.random.key(2), (tiled.n_padded, 4)).astype(dtype)
    out = tc_spmv(tiled, rhs)
    ref = tc_spmv_ref(tiled.tiles, tiled.tile_rows, tiled.tile_cols,
                      rhs.astype(jnp.float32), tiled.n_block_rows)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol)


@pytest.mark.parametrize("skip_dma", [False, True])
def test_spmv_col_flags(skip_dma):
    """Empty-column skipping must not change results (paper's early exit)."""
    g, tiled = _tiled(200, 6.0, 16, seed=3)
    flags = (jax.random.uniform(jax.random.key(3), (tiled.n_block_cols,)) > 0.5)
    flags_i = flags.astype(jnp.int32)
    rhs = jax.random.normal(jax.random.key(4), (tiled.n_padded, 2), jnp.float32)
    # zero out gated columns so flagged-off slabs are genuinely empty
    rhs = rhs * jnp.repeat(flags_i, tiled.tile_size)[:, None].astype(jnp.float32)
    out = tc_spmv(tiled, rhs, col_flags=flags_i, skip_dma=skip_dma)
    ref = tc_spmv_ref(tiled.tiles, tiled.tile_rows, tiled.tile_cols, rhs,
                      tiled.n_block_rows)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T", [8, 16, 64])
@pytest.mark.parametrize("mask_frac", [0.0, 0.4, 1.0])
def test_neighbor_max_sweep(T, mask_frac):
    g, tiled = _tiled(3 * T + 5, 7.0, T, seed=T + 1)
    p = jax.random.randint(jax.random.key(5), (tiled.n_padded,), 0, 1 << 20,
                           dtype=jnp.int32)
    mask = jax.random.uniform(jax.random.key(6), (tiled.n_padded,)) >= mask_frac
    out = tc_neighbor_max(tiled, p, mask)
    pm = jnp.where(mask, p, _NEG)
    ref = tc_neighbor_max_ref(tiled.tiles, tiled.tile_rows, tiled.tile_cols,
                              pm, tiled.n_block_rows)
    assert bool(jnp.all(out == ref))


@pytest.mark.parametrize("B,K,D", [(4, 1, 8), (16, 5, 16), (32, 13, 32)])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_sweep(B, K, D, weighted):
    V = 500
    table = jax.random.normal(jax.random.key(7), (V, D), jnp.float32)
    idx = jax.random.randint(jax.random.key(8), (B, K), 0, V, dtype=jnp.int32)
    w = (jax.random.uniform(jax.random.key(9), (B, K)) if weighted
         else jnp.ones((B, K)))
    out = embedding_bag(table, idx, w)
    ref = embedding_bag_ref(table, idx, w)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_spmv_matches_segment_path():
    """Tiled SpMV == edge-list segment_sum (the two paper paths agree)."""
    from repro.core.spmv import neighbor_sum_segment

    g, tiled = _tiled(300, 10.0, 32, seed=11)
    x = jax.random.normal(jax.random.key(12), (g.n_nodes,), jnp.float32)
    xp = jnp.pad(x, (0, tiled.n_padded - g.n_nodes))
    out_tiled = tc_spmv(tiled, xp[:, None])[: g.n_nodes, 0]
    out_seg = neighbor_sum_segment(g, x)
    assert_allclose(np.asarray(out_tiled), np.asarray(out_seg), rtol=1e-4, atol=1e-4)

"""Optional-hypothesis shim: property tests skip (not error) when the test
extra (requirements-test.txt) isn't installed, while plain tests in the same
module still run.

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r requirements-test.txt)"
            )(fn)

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        """Builds inert placeholders; only touched at collection time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

"""Serving throughput: graphs/sec vs batch size, cold vs warm plan cache.

Two passes per batch size over the same request set:

  cold   fresh service + empty plan cache: pays ingestion hashing, RCM/tile
         preprocessing AND the jit compile of the batch bucket
  warm   same service, same graphs again: plan-cache memory hits, bucket
         already compiled — the steady-state serving rate

Emits the usual CSV rows plus ``BENCH_serve.json`` (consumed by
`make_tables` tooling / CI artefacts).  The acceptance bar for the serving
layer is warm > cold at every batch size — if warm is not faster, the
caches are not doing their job.

    BENCH_ENGINE=tiled_ref PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import os
import time

from benchmarks.common import QUICK, emit
from repro.graphs.generators import erdos_renyi, grid2d, powerlaw
from repro.obs.bench import write_bench
from repro.serve_mis import MISService, ServeConfig

OUT_PATH = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
# the jnp tile oracle is the honest CPU default; Pallas engines interpret
# python-per-grid-step off-TPU, which would benchmark the interpreter.
ENGINE = os.environ.get("BENCH_ENGINE", "tiled_ref")


def _request_mix(n: int, scale: int, seed: int = 0):
    """Heterogeneous small graphs, the serving layer's target workload."""
    makers = [
        lambda s: grid2d(scale // 8, 8, seed=s),
        lambda s: powerlaw(scale, avg_deg=4.0, seed=s),
        lambda s: erdos_renyi(scale, avg_deg=6.0, seed=s),
        lambda s: erdos_renyi(scale // 2, avg_deg=3.0, seed=s),
    ]
    return [makers[i % len(makers)](seed + i // len(makers)) for i in range(n)]


def _run_wave(service: MISService, graphs) -> float:
    t0 = time.perf_counter()
    for g in graphs:
        service.submit(g)
    responses = service.drain()
    dt = time.perf_counter() - t0
    assert all(r.valid for r in responses), "post-condition failed in benchmark"
    return dt


def main() -> None:
    scale = 256 if QUICK else 1024
    n_requests = 16 if QUICK else 64
    batch_sizes = (1, 4, 8) if QUICK else (1, 2, 4, 8, 16)
    results = []
    for batch in batch_sizes:
        graphs = _request_mix(n_requests, scale, seed=batch)
        service = MISService(ServeConfig(
            tile_size=32, engine=ENGINE, max_batch=batch, seed=0,
        ))
        t_cold = _run_wave(service, graphs)
        t_warm = _run_wave(service, graphs)
        cold_gps = n_requests / t_cold
        warm_gps = n_requests / t_warm
        results.append(dict(
            engine=ENGINE,
            batch_size=batch,
            n_requests=n_requests,
            scale=scale,
            cold_s=round(t_cold, 4),
            warm_s=round(t_warm, 4),
            cold_graphs_per_s=round(cold_gps, 2),
            warm_graphs_per_s=round(warm_gps, 2),
            speedup=round(warm_gps / cold_gps, 2),
            compiles=service.stats["compiles"],
            plan_cache=dict(service.planner.stats),
        ))
        emit(f"serve_cold_b{batch}", t_cold / n_requests * 1e6,
             f"{cold_gps:.1f} graphs/s")
        emit(f"serve_warm_b{batch}", t_warm / n_requests * 1e6,
             f"{warm_gps:.1f} graphs/s warm/cold={warm_gps / cold_gps:.2f}x")

    # stamped (git_sha/timestamp/backend/jax_version) + history-appended
    # through the one bench emission seam (repro.obs.bench, DESIGN.md §17)
    write_bench(dict(bench="serve_throughput", engine=ENGINE, quick=QUICK,
                     results=results), OUT_PATH)

    slow = [r for r in results if r["warm_graphs_per_s"] <= r["cold_graphs_per_s"]]
    if slow:
        raise AssertionError(
            f"warm cache not faster than cold at batch sizes "
            f"{[r['batch_size'] for r in slow]}"
        )


if __name__ == "__main__":
    main()

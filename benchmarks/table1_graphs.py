"""Table 1: the graph suite — stand-in structural fidelity check.

Columns: |V|, |E| (directed half-edges / 2), |E|/|V|, max degree — compared
against the paper's numbers at the reduced scale (ratios should match)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, suite_graphs


def main() -> None:
    for gid, (spec, g) in suite_graphs().items():
        deg = np.asarray(g.degrees())
        e_undirected = g.n_edges / 2
        emit(
            f"table1.{gid}.{spec.name}",
            0.0,
            f"V={g.n_nodes};E={int(e_undirected)};EoverV={e_undirected/g.n_nodes:.1f}"
            f";paper_EoverV={spec.e_over_v:.1f};dmax={int(deg.max())}",
        )


if __name__ == "__main__":
    main()

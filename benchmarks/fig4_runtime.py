"""Fig. 4: end-to-end runtime, TC-MIS vs ECL-MIS (and Luby) across the suite.

Two evidence levels:
  * CPU wall-clock of the full jitted algorithms (structural sanity — shows
    rounds-to-convergence and relative algorithm cost, NOT TC speedups);
  * roofline-projected TPU step times read from the dry-run JSONs when
    present (experiments/dryrun/tcmis__G*__single.json) — the real per-round
    performance model on the target hardware.
"""
from __future__ import annotations

import glob
import json
import os

import jax

from benchmarks.common import emit, suite_graphs, time_fn
from repro.api import Solver, SolveOptions
from repro.core import ecl_mis, luby_mis


def main() -> None:
    solver = Solver(SolveOptions(heuristic="h3", engine="tiled_ref", tile_size=64))
    for gid, (spec, g) in suite_graphs(scale_div=8).items():
        plan = solver.plan(g)   # pre-plan: time the solve, not the BSR build
        key = jax.random.key(0)

        t_luby = time_fn(lambda: luby_mis(g, key))
        t_ecl = time_fn(lambda: ecl_mis(g, key))
        # end-to-end through the front door (the plan is prebuilt, so this
        # times dispatch + solve + unpack — the serving-path cost shape)
        t_tc = time_fn(lambda: solver.solve(plan, key=key))
        emit(f"fig4.{gid}.luby", 1e6 * t_luby, "")
        emit(f"fig4.{gid}.ecl", 1e6 * t_ecl, "")
        emit(
            f"fig4.{gid}.tcmis", 1e6 * t_tc,
            f"cpu_ratio_vs_ecl={t_ecl/t_tc:.2f}x",
        )

    # roofline-projected TPU per-round times from the dry-run
    for path in sorted(glob.glob("experiments/dryrun/tcmis__G*__single.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        emit(
            f"fig4.tpu_projection.{rec['shape']}",
            1e6 * r["step_time_s"],
            f"dominant={r['dominant']};mfu={r['mfu']:.4f}",
        )


if __name__ == "__main__":
    main()

"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.make_tables experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_s(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.0f}µs"
    if s < 1:
        return f"{s*1e3:.1f}ms"
    return f"{s:.2f}s"


def load(out_dir):
    recs = []
    for p in sorted(glob.glob(f"{out_dir}/*.json")):
        recs.append(json.load(open(p)))
    return recs


def dryrun_table(recs):
    print("| arch | shape | mesh | status | GiB/dev | compile | collectives (GiB/dev) |")
    print("|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | N/A | — | — | "
                  f"{r['skip_reason'][:60]}… |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **{r['status']}** | — | — | {r.get('error','')[:60]} |")
            continue
        colls = ", ".join(
            f"{k.replace('all-','a').replace('reduce-scatter','rs').replace('collective-permute','cp')}:{v/2**30:.2f}"
            for k, v in sorted(r["cost"]["collectives"].items())
        ) or "none"
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {fmt_bytes(r['memory']['total_per_device'])} "
            f"| {r['times']['compile_s']}s | {colls} |"
        )


def roofline_table(recs):
    print("| arch | shape | compute | memory | collective | dominant | model/HLO | MFU |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        rf = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | **{rf['dominant']}** "
            f"| {rf['useful_flop_fraction']:.3f} | {rf['mfu']:.4f} |"
        )


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(out_dir)
    ok = sum(1 for r in recs if r["status"] == "ok")
    na = sum(1 for r in recs if r["status"] == "skipped")
    err = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    print(f"### cells: {ok} ok, {na} N/A (documented skips), {err} errors\n")
    print("#### Dry-run\n")
    dryrun_table(recs)
    print("\n#### Roofline (single-pod, per-device terms)\n")
    roofline_table(recs)


if __name__ == "__main__":
    main()

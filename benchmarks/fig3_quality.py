"""Fig. 3: MIS cardinality of TC-MIS H1/H2/H3 vs ECL-MIS across the suite.

Paper's claim: H1 ≈ −10.4 % vs ECL, H2 ≈ −2.4 %, H3 ≈ −0.17 %.
Cardinality is an algorithmic property — it reproduces exactly on CPU."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, suite_graphs
from repro.api import PlanCache, Solver, SolveOptions
from repro.core import cardinality, ecl_mis
from repro.core.validate import is_valid_mis


def main() -> None:
    devs = {"h1": [], "h2": [], "h3": []}
    plans = PlanCache(tile_size=64)   # shared: one BSR build per graph
    solvers = {
        h: Solver(SolveOptions(heuristic=h, engine="tiled_ref", tile_size=64),
                  plans=plans)
        for h in ("h1", "h2", "h3")
    }
    for gid, (spec, g) in suite_graphs().items():
        key = jax.random.key(0)
        base = cardinality(ecl_mis(g, key).in_mis)
        row = []
        for h in ("h1", "h2", "h3"):
            res = solvers[h].solve(g)
            assert is_valid_mis(g, jax.numpy.asarray(res.in_mis)), (gid, h)
            c = res.mis_size
            dev = 100.0 * (base - c) / base
            devs[h].append(dev)
            row.append(f"{h}={c}({dev:+.2f}%)")
        emit(f"fig3.{gid}", 0.0, f"ecl={base};" + ";".join(row))
    for h, d in devs.items():
        emit(f"fig3.avg_deviation.{h}", 0.0,
             f"{np.mean(d):+.2f}%_vs_paper({{'h1': -10.43, 'h2': -2.42, 'h3': -0.17}}['{h}']%)".replace("'", ""))


if __name__ == "__main__":
    main()

"""Fig. 3: MIS cardinality of TC-MIS H1/H2/H3 vs ECL-MIS across the suite.

Paper's claim: H1 ≈ −10.4 % vs ECL, H2 ≈ −2.4 %, H3 ≈ −0.17 %.
Cardinality is an algorithmic property — it reproduces exactly on CPU."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, suite_graphs
from repro.core import TCMISConfig, build_block_tiles, cardinality, ecl_mis, tc_mis
from repro.core.validate import is_valid_mis


def main() -> None:
    devs = {"h1": [], "h2": [], "h3": []}
    for gid, (spec, g) in suite_graphs().items():
        tiled = build_block_tiles(g, tile_size=64)
        key = jax.random.key(0)
        base = cardinality(ecl_mis(g, key).in_mis)
        row = []
        for h in ("h1", "h2", "h3"):
            res = tc_mis(g, tiled, key, TCMISConfig(heuristic=h))
            assert is_valid_mis(g, res.in_mis), (gid, h)
            c = cardinality(res.in_mis)
            dev = 100.0 * (base - c) / base
            devs[h].append(dev)
            row.append(f"{h}={c}({dev:+.2f}%)")
        emit(f"fig3.{gid}", 0.0, f"ecl={base};" + ";".join(row))
    for h, d in devs.items():
        emit(f"fig3.avg_deviation.{h}", 0.0,
             f"{np.mean(d):+.2f}%_vs_paper({{'h1': -10.43, 'h2': -2.42, 'h3': -0.17}}['{h}']%)".replace("'", ""))


if __name__ == "__main__":
    main()

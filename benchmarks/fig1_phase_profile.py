"""Fig. 1: per-phase time split.

The paper profiles ECL-MIS and finds phase ② (candidate selection /
neighbour elimination over adjacency lists) dominating at 56.4 % average.
We profile the round engines of OUR system (the registry's CPU-viable
subset by default — the interpret-mode Pallas engines are opt-in via
FIG1_ENGINES=all since they execute python per grid step):

  segment     (ECL-analogue)  — phases on the edge-list/segment substrate
  tiled_ref   (TC-MIS)        — phase ② on the BSR SpMV, phase ① tiled
  fused_pallas                — phase ②+③ as one kernel pass (charged to p2)

and report the phase share shift that motivates the paper (phase ② shrinking
under the tiled engine).  CPU wall-clock is a structural signal only; the TPU
evidence is the roofline table."""
from __future__ import annotations

import os

from benchmarks.common import emit, suite_graphs
from repro.api import PlanCache, Solver, SolveOptions
from repro.core import engine_names


def _options():
    base = dict(heuristic="h3", tile_size=64)
    opts = [
        ("segment", SolveOptions(engine="segment", **base)),
        ("tiled_ref", SolveOptions(engine="tiled_ref", phase1="tiled", **base)),
    ]
    if os.environ.get("FIG1_ENGINES") == "all":
        opts += [
            (name, SolveOptions(engine=name, phase1="tiled", **base))
            for name in engine_names()
            if name.endswith("pallas")
        ]
    return opts


def main() -> None:
    plans = PlanCache(tile_size=64)   # shared: one BSR build per graph
    for gid, (spec, g) in suite_graphs(scale_div=8).items():
        for label, opts in _options():
            _, t = Solver(opts, plans=plans).profile(g)
            total = t["phase1"] + t["phase2"] + t["phase3"]
            emit(
                f"fig1.{gid}.{label}",
                1e6 * total / max(t["rounds"], 1),
                f"p1={100*t['phase1']/total:.1f}%;p2={100*t['phase2']/total:.1f}%"
                f";p3={100*t['phase3']/total:.1f}%;rounds={t['rounds']}",
            )


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimbing harness: compile a cell VARIANT and print its roofline
terms next to the recorded baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb qwen3_fused
    PYTHONPATH=src python -m benchmarks.hillclimb tcmis_g8 --tile 32 --lanes 8

Each experiment function builds a modified config/cell and reuses the
dry-run's three-pass methodology.  Results are appended (by hand) to
EXPERIMENTS.md §Perf with the hypothesis → before → after record.
"""
import argparse
import dataclasses
import json
import sys

import jax
import numpy as np


def _measure(cell, mesh_kind="single"):
    from repro.launch.dryrun import _affine, _compile_pass, _cost_record
    from repro.launch.mesh import make_production_mesh
    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    with mesh:
        compiled, _, t_mem = _compile_pass(cell, mesh, "memory")
        ma = compiled.memory_analysis()
        mem_gib = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                   + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 2**30
        if cell.extrapolate:
            ex = cell.extrapolate
            a, _, _ = _compile_pass(cell, mesh, "cost_a")
            ca = _cost_record(a)
            del a
            b, _, _ = _compile_pass(cell, mesh, "cost_b")
            cb = _cost_record(b)
            del b
            cost = _affine(ca, cb, ex["la"], ex["lb"], ex["lfull"])
        else:
            cost = _cost_record(compiled)
    coll = sum(cost["collectives"].values())
    terms = dict(
        compute_s=cost["flops"] / PEAK_FLOPS,
        memory_s=cost["bytes_accessed"] / HBM_BW,
        collective_s=coll / ICI_BW,
    )
    step = max(terms.values())
    mf = cell.model_flops / n_dev
    print(json.dumps(dict(
        mem_gib=round(mem_gib, 2),
        **{k: round(v, 4) for k, v in terms.items()},
        dominant=max(terms, key=terms.get),
        step_s=round(step, 4),
        mfu=round(mf / (PEAK_FLOPS * step), 5) if step else 0,
        useful=round(mf / cost["flops"], 4) if cost["flops"] else 0,
        collectives={k: round(v / 2**30, 3) for k, v in cost["collectives"].items()},
    ), indent=1))


# --------------------------------------------------------------------------
# experiments
# --------------------------------------------------------------------------

def qwen3_baseline():
    from repro.configs import REGISTRY

    _measure(REGISTRY["qwen3-0.6b"].cells["train_4k"])


def qwen3_fused():
    """H-C iter 1: fused QKV + fused gate/up projections."""
    import repro.configs.qwen3_0_6b as q3
    from repro.configs.common import _lm_train_cell

    cfg = dataclasses.replace(q3.CONFIG, fuse_qkv=True, fuse_gate=True)
    _measure(_lm_train_cell("qwen3-fused", cfg, "train_4k"))


def qwen3_noremat():
    """H-C iter 2: remat off (recompute flops −, activation memory +)."""
    import repro.configs.qwen3_0_6b as q3
    from repro.configs.common import _lm_train_cell

    cfg = dataclasses.replace(q3.CONFIG, remat=False, fuse_qkv=True, fuse_gate=True)
    _measure(_lm_train_cell("qwen3-noremat", cfg, "train_4k"))


def qwen3_chunks(attn_chunk=1024, loss_chunk=2048):
    """H-C iter 3: bigger flash/xent chunks (fewer intermediate writes)."""
    import repro.configs.qwen3_0_6b as q3
    from repro.configs.common import _lm_train_cell

    cfg = dataclasses.replace(
        q3.CONFIG, fuse_qkv=True, fuse_gate=True,
        attn_chunk=attn_chunk, loss_chunk=loss_chunk,
    )
    _measure(_lm_train_cell("qwen3-chunks", cfg, "train_4k"))


def tcmis_g8(tile=None, lanes=None, bitpack=None):
    """H-A: tile size / lane width / frontier bit-packing on kron_g500."""
    import repro.configs.tcmis as tc

    if tile is not None:
        tc.choose_tile_size_orig = tc.choose_tile_size
        tc.choose_tile_size = lambda pid, n: tile
    if lanes is not None:
        tc.DRYRUN_LANES = lanes
    cell = tc._mis_cell("G8")
    if bitpack is not None:
        # rebuild the cell with bitpack toggled
        import repro.core.distributed as dist

        orig = dist.DistConfig
        _measure_cell = cell
    _measure(cell)


def deepseek_capacity(cf=1.0):
    """H-B iter: dispatch volume ∝ capacity factor."""
    import repro.configs.deepseek_v3_671b as ds
    from repro.configs.common import _lm_train_cell

    cfg = dataclasses.replace(
        ds.CONFIG, moe=dataclasses.replace(ds.CONFIG.moe, capacity_factor=cf)
    )
    _measure(_lm_train_cell("deepseek-cf", cfg, "train_4k"))


def deepseek_nomtp():
    """H-B iter: MTP head off (isolates its contribution)."""
    import repro.configs.deepseek_v3_671b as ds
    from repro.configs.common import _lm_train_cell

    cfg = dataclasses.replace(ds.CONFIG, mtp=False)
    _measure(_lm_train_cell("deepseek-nomtp", cfg, "train_4k"))


def qwen3_dots_remat():
    """H-C iter 4: selective remat — save matmul outputs only."""
    import repro.configs.qwen3_0_6b as q3
    from repro.configs.common import _lm_train_cell

    cfg = dataclasses.replace(
        q3.CONFIG, fuse_qkv=True, fuse_gate=True,
        attn_chunk=1024, loss_chunk=2048, remat_policy="dots",
    )
    _measure(_lm_train_cell("qwen3-dots", cfg, "train_4k"))


def tcmis_engine(engine="fused_pallas", skip_dma=False):
    """H-A iter: live round-engine sweep — per-phase wall-clock of one engine
    (vs the tiled_ref oracle) on a reduced suite graph.  Unlike the dry-run
    experiments this MEASURES the engine registry end-to-end, col_flags
    skipping included.

        PYTHONPATH=src python -m benchmarks.hillclimb tcmis_engine --engine fused_pallas
    """
    import json as _json

    from benchmarks.common import suite_graphs
    from repro.api import PlanCache, Solver, SolveOptions

    gid, (spec, g) = next(iter(suite_graphs(scale_div=8).items()))
    plans = PlanCache(tile_size=64)   # shared: one BSR build, two engines
    out = {}
    for name in ("tiled_ref", engine):
        opts = SolveOptions(engine=name, phase1="tiled", skip_dma=skip_dma,
                            tile_size=64)
        _, t = Solver(opts, plans=plans).profile(g)
        out[name] = {k: round(v, 5) for k, v in t.items()}
    n_tiles = plans.plan(g, tile_size=64)[0].tiled.n_tiles
    print(_json.dumps(dict(graph=gid, tiles=n_tiles, **out), indent=1))


def tcmis_g3_rcm(rcm=True):
    """H-A iter 3: RCM-informed tiling on delaunay (G3)."""
    import repro.configs.tcmis as tc

    tc.RCM = bool(rcm)
    tc._occupancy_ratio.cache_clear()
    _measure(tc._mis_cell("G3"))


EXPERIMENTS = {
    "tcmis_engine": tcmis_engine,
    "tcmis_g3_rcm": tcmis_g3_rcm,
    "qwen3_dots_remat": qwen3_dots_remat,
    "qwen3_baseline": qwen3_baseline,
    "qwen3_fused": qwen3_fused,
    "qwen3_noremat": qwen3_noremat,
    "qwen3_chunks": qwen3_chunks,
    "tcmis_g8": tcmis_g8,
    "deepseek_capacity": deepseek_capacity,
    "deepseek_nomtp": deepseek_nomtp,
}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("experiment", choices=list(EXPERIMENTS))
    p.add_argument("--tile", type=int, default=None)
    p.add_argument("--lanes", type=int, default=None)
    p.add_argument("--cf", type=float, default=None)
    p.add_argument("--engine", type=str, default="fused_pallas")
    p.add_argument("--skip-dma", action="store_true")
    args = p.parse_args()
    fn = EXPERIMENTS[args.experiment]
    kw = {}
    if args.experiment == "tcmis_g8":
        kw = dict(tile=args.tile, lanes=args.lanes)
    if args.experiment == "tcmis_engine":
        kw = dict(engine=args.engine, skip_dma=args.skip_dma)
    if args.experiment == "deepseek_capacity" and args.cf:
        kw = dict(cf=args.cf)
    if args.experiment == "tcmis_g3_rcm":
        kw = dict(rcm=(args.lanes != 0))  # --lanes 0 => no rcm
    print(f"# experiment: {args.experiment} {kw}")
    fn(**kw)


if __name__ == "__main__":
    main()

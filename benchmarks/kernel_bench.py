"""Kernel micro-benchmarks, engine-parameterised: one phase-② (or fused
②+③) timing per registered round engine, with and without live empty-column
flags, plus the embedding-bag oracle.  Interpret-mode CPU numbers catch
wrapper/schedule regressions; the TPU performance story is the roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit, time_fn
from repro.api import SolveOptions
from repro.core import build_block_tiles, engine_names, get_engine
from repro.core.engine import EngineContext
from repro.graphs.generators import erdos_renyi
from repro.kernels.ref import embedding_bag_ref


def main() -> None:
    n = 1024 if QUICK else 4096   # interpret-mode kernels are O(tiles) python
    g = erdos_renyi(n, avg_deg=8.0, seed=0)
    tiled = build_block_tiles(g, tile_size=64)
    note = f"tiles={tiled.n_tiles};T=64;lanes=8"

    # a late-round state: few, clustered candidates — most block-columns are
    # empty, so the col_flags rows show the live tile skip actually gating
    key = jax.random.key(0)
    alive = jax.random.uniform(key, (tiled.n_padded,)) < 0.5
    cand = (
        alive
        & (jax.random.uniform(jax.random.key(1), (tiled.n_padded,)) < 0.25)
        & (jnp.arange(tiled.n_padded) < tiled.n_padded // 4)
    )
    ctx = EngineContext(g=g, tiled=tiled, cfg=SolveOptions())

    for name in engine_names():
        eng = get_engine(name)
        run2 = eng.fused_step if eng.fused else eng.phase2_counts
        f_none = jax.jit(lambda c, a, _run=run2: _run(ctx, c, a, None))
        emit(f"kernel.phase2.{name}", 1e6 * time_fn(f_none, cand, alive), note)
        flags = eng.col_flags(ctx, cand, alive)
        if flags is not None:
            f_flag = jax.jit(
                lambda c, a, fl, _run=run2: _run(ctx, c, a, fl)
            )
            emit(
                f"kernel.phase2.{name}.col_flags",
                1e6 * time_fn(f_flag, cand, alive, flags),
                f"{note};active={int(flags.sum())}/{tiled.n_block_cols}",
            )

    table = jax.random.normal(jax.random.key(1), (100_000, 16))
    idx = jax.random.randint(jax.random.key(2), (1024, 8), 0, 100_000, jnp.int32)
    w = jnp.ones((1024, 8))
    f_bag = jax.jit(embedding_bag_ref)
    emit("kernel.embedding_bag.ref_jnp", 1e6 * time_fn(f_bag, table, idx, w),
         "B=1024;K=8;D=16")


if __name__ == "__main__":
    main()

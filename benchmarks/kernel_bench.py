"""Kernel micro-benchmarks: tc_spmv / tc_neighbor_max / embedding_bag on
interpret mode (CPU correctness-path timing) + the jnp oracle; the TPU
performance story is the roofline, these catch regressions in the wrappers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import build_block_tiles
from repro.core.spmv import spmv_tiled
from repro.graphs.generators import erdos_renyi
from repro.kernels.ref import embedding_bag_ref


def main() -> None:
    g = erdos_renyi(4096, avg_deg=8.0, seed=0)
    tiled = build_block_tiles(g, tile_size=64)
    rhs = jax.random.normal(jax.random.key(0), (tiled.n_padded, 8), jnp.float32)

    f_ref = jax.jit(lambda r: spmv_tiled(tiled, r, backend="ref"))
    emit("kernel.tc_spmv.ref_jnp", 1e6 * time_fn(f_ref, rhs),
         f"tiles={tiled.n_tiles};T=64;lanes=8")

    table = jax.random.normal(jax.random.key(1), (100_000, 16))
    idx = jax.random.randint(jax.random.key(2), (1024, 8), 0, 100_000, jnp.int32)
    w = jnp.ones((1024, 8))
    f_bag = jax.jit(embedding_bag_ref)
    emit("kernel.embedding_bag.ref_jnp", 1e6 * time_fn(f_bag, table, idx, w),
         "B=1024;K=8;D=16")


if __name__ == "__main__":
    main()

"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # full
    BENCH_ONLY=fig3 PYTHONPATH=src python -m benchmarks.run

Output format: ``name,us_per_call,derived`` CSV rows on stdout.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    only = os.environ.get("BENCH_ONLY")
    sections = [
        ("table1", "benchmarks.table1_graphs"),
        ("core", "benchmarks.core_bench"),
        ("mem", "benchmarks.memory_footprint"),
        ("fig3", "benchmarks.fig3_quality"),
        ("fig1", "benchmarks.fig1_phase_profile"),
        ("fig4", "benchmarks.fig4_runtime"),
        ("kernel", "benchmarks.kernel_bench"),
        ("hybrid", "benchmarks.hybrid_bench"),
        ("serve", "benchmarks.serve_throughput"),
        ("dyngraph", "benchmarks.dyngraph_bench"),
    ]
    failures = 0
    for name, module in sections:
        if only and only != name:
            continue
        print(f"# --- {name} ({module}) ---", flush=True)
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

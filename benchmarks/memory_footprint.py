"""Paper §3.2: memory footprint of the tiled representation vs CSR,
swept over tile size — the space-for-regularity trade-off, quantified.

Derived fields: bytes ratio BSR/CSR, block occupancy, intra-tile density.
The T=128 MXU-native tiles are cheap on mesh-like graphs and explode on
hub-heavy ones — exactly why configs/tcmis.py auto-selects T per graph."""
from __future__ import annotations

from benchmarks.common import emit, suite_graphs
from repro.core import build_block_tiles, tile_stats


def main() -> None:
    for gid, (spec, g) in suite_graphs(scale_div=8).items():
        for T in (16, 32, 64, 128):
            s = tile_stats(build_block_tiles(g, tile_size=T))
            emit(
                f"mem.{gid}.T{T}",
                0.0,
                f"bsr_bytes={s['bsr_bytes']};csr_bytes={s['csr_bytes']}"
                f";ratio={s['bsr_bytes']/max(s['csr_bytes'],1):.2f}"
                f";occupancy={s['block_occupancy']:.4f}"
                f";density={s['intra_tile_density']:.5f}",
            )
        # beyond-paper: RCM locality reordering at the MXU-native tile size
        s0 = tile_stats(build_block_tiles(g, tile_size=128))
        s1 = tile_stats(build_block_tiles(g, tile_size=128, reorder="rcm"))
        emit(
            f"mem.{gid}.T128_rcm",
            0.0,
            f"tiles={s1['n_tiles']}(vs {s0['n_tiles']})"
            f";bsr_bytes={s1['bsr_bytes']}"
            f";density={s1['intra_tile_density']:.5f}(vs {s0['intra_tile_density']:.5f})",
        )


if __name__ == "__main__":
    main()

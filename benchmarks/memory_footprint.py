"""Paper §3.2: memory footprint of the tiled representation vs CSR,
swept over tile size AND storage format — the space-for-regularity
trade-off, quantified, plus the 1-bit storage axis that claws the space
back (DESIGN.md §11).

Derived fields: bytes ratio BSR/CSR, block occupancy, intra-tile density,
and the int8→bitpack tile-HBM reduction.  The T=128 MXU-native tiles are
cheap on mesh-like graphs and explode on hub-heavy ones — exactly why
configs/tcmis.py auto-selects T per graph; bit-packing shrinks whatever T
wins by ~8× (exactly 8× on the tile payload, ≥6× including indices)."""
from __future__ import annotations

from benchmarks.common import emit, suite_graphs
from repro.core import build_block_tiles, tile_stats

# the acceptance bar for the storage axis: ≥ 6× tile-HBM reduction at the
# MXU-native tile size (8× on payload, minus the shared index arrays)
MIN_BITPACK_REDUCTION_T128 = 6.0


def main() -> None:
    reductions = []
    for gid, (spec, g) in suite_graphs(scale_div=8).items():
        for T in (16, 32, 64, 128):
            tiled = build_block_tiles(g, tile_size=T)
            s = tile_stats(tiled)
            sp = tile_stats(tiled.to_storage("bitpack"))
            emit(
                f"mem.{gid}.T{T}",
                0.0,
                f"bsr_bytes={s['bsr_bytes']};csr_bytes={s['csr_bytes']}"
                f";ratio={s['bsr_bytes']/max(s['csr_bytes'],1):.2f}"
                f";occupancy={s['block_occupancy']:.4f}"
                f";density={s['intra_tile_density']:.5f}",
            )
            # the gate ratio includes the (unshrunk) index arrays — the
            # payload-only ratio is 8.0 by dtype arithmetic at T=128 and
            # would assert nothing about real HBM
            reduction = s["bsr_bytes"] / max(sp["bsr_bytes"], 1)
            emit(
                f"mem.{gid}.T{T}_bitpack",
                0.0,
                f"tile_bytes={sp['tile_payload_bytes']}"
                f"(vs {s['tile_payload_bytes']})"
                f";bsr_bytes={sp['bsr_bytes']}"
                f";hbm_reduction={reduction:.2f}x",
            )
            if T == 128:
                reductions.append((gid, reduction))
        # beyond-paper: RCM locality reordering at the MXU-native tile size
        s0 = tile_stats(build_block_tiles(g, tile_size=128))
        s1 = tile_stats(build_block_tiles(g, tile_size=128, reorder="rcm"))
        emit(
            f"mem.{gid}.T128_rcm",
            0.0,
            f"tiles={s1['n_tiles']}(vs {s0['n_tiles']})"
            f";bsr_bytes={s1['bsr_bytes']}"
            f";density={s1['intra_tile_density']:.5f}(vs {s0['intra_tile_density']:.5f})",
        )

    short = [(gid, r) for gid, r in reductions if r < MIN_BITPACK_REDUCTION_T128]
    if short:
        raise AssertionError(
            f"bitpack tile-HBM reduction below {MIN_BITPACK_REDUCTION_T128}x "
            f"at T=128: {short}"
        )
    print(
        f"# bitpack tile-HBM reduction at T=128: "
        f"{min(r for _, r in reductions):.2f}x min over {len(reductions)} graphs"
    )


if __name__ == "__main__":
    main()

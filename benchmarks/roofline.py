"""Re-export shim: the roofline model moved to ``repro.perf.roofline``.

The cost model is now importable from ``src/repro`` (the hybrid tile
classifier needs it at plan time — DESIGN.md §16); benchmarks and tests
keep their historical ``benchmarks.roofline`` import path through this
shim.
"""
from repro.perf.roofline import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    dense_tile_cost_s,
    hybrid_density_threshold,
    parse_collective_bytes,
    roofline_from_compiled,
    sparse_edge_cost_s,
)

"""Core perf trajectory: kernel + end-to-end round timings, both storage
formats, persisted to ``BENCH_core.json`` at the repo root.

Three layers per storage format (int8 | bitpack, DESIGN.md §11):

  spmv / nbr_max   the raw tile operators on the jnp oracle substrate —
                   the honest CPU numbers (Pallas interpret mode executes
                   python per grid step, which would benchmark the
                   interpreter; on TPU the same harness times the Mosaic
                   kernels)
  kernel_spmv      ONE small Pallas interpret-mode case per storage so the
                   kernel path's trajectory is tracked at all off-TPU
  solve            `Solver.solve` end-to-end, per-round wall clock

Plus the bitwise frontier layer (DESIGN.md §13), bitpack storage only:

  spmv_bitwise     popcount SpMV on packed words — asserted ≥2× faster
  nbr_max_bitwise  priority-sorted clz Max_Np — asserted ≥2× faster
                   (both vs the unpack-then-dense bitpack path, same n/T)

Every timing row carries `gb_per_s` — effective tile-payload bandwidth
(payload_bytes / wall time), so the trajectory tracks bytes-moved-per-
second, not just latency.  The JSON also records the T=128 memory-footprint
reduction (the storage axis's acceptance bar).

    PYTHONPATH=src python -m benchmarks.core_bench [--quick]
    BENCH_ONLY=core PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit, time_fn
from repro.api import Solver, SolveOptions
from repro.obs.bench import write_bench
from repro.core import build_block_tiles, tile_stats
from repro.core.engine import (
    tile_neighbor_max,
    tile_neighbor_max_bits,
    tile_spmv,
    tile_spmv_bits,
)
from repro.core.spmv import _NEG
from repro.core.tiling import (
    pack_frontier_words,
    sort_block_priorities,
    sorted_frontier_words,
    sorted_tile_bits,
    tiles_as_words,
)
from repro.graphs.generators import erdos_renyi
from repro.kernels import tc_spmv

OUT_PATH = os.environ.get("BENCH_CORE_OUT", "BENCH_core.json")
STORAGES = ("int8", "bitpack")


def _gb_per_s(payload_bytes: int, us: float) -> float:
    """bytes / µs·10³ = bytes/ns = GB/s of tile-payload traffic."""
    return round(payload_bytes / max(us * 1e3, 1e-9), 3)


def _bench_tile_ops(n: int, T: int, lanes: int) -> list:
    g = erdos_renyi(n, avg_deg=8.0, seed=0)
    base = build_block_tiles(g, tile_size=T)
    rhs = jax.random.normal(jax.random.key(1), (base.n_padded, lanes), jnp.float32)
    pm = jnp.where(
        jax.random.uniform(jax.random.key(2), (base.n_padded,)) > 0.2,
        jax.random.randint(
            jax.random.key(3), (base.n_padded,), 0, 1 << 20, dtype=jnp.int32
        ),
        _NEG,
    )
    rows = []
    for storage in STORAGES:
        t = base.to_storage(storage)
        spmv = jax.jit(
            lambda tiles, tr, tc: tile_spmv(tiles, tr, tc, rhs, t.n_block_rows, T)
        )
        nbr = jax.jit(
            lambda tiles, tr, tc: tile_neighbor_max(
                tiles, tr, tc, pm, t.n_block_rows, T
            )
        )
        s_spmv = time_fn(spmv, t.tiles, t.tile_rows, t.tile_cols)
        s_nbr = time_fn(nbr, t.tiles, t.tile_rows, t.tile_cols, iters=5)
        payload = t.tile_payload_bytes()
        rows.append(dict(
            op="spmv", storage=storage, n=n, tile_size=T, lanes=lanes,
            n_tiles=t.n_tiles, us_per_call=round(s_spmv * 1e6, 1),
            tile_payload_bytes=payload,
            gb_per_s=_gb_per_s(payload, s_spmv * 1e6),
        ))
        rows.append(dict(
            op="nbr_max", storage=storage, n=n, tile_size=T, lanes=lanes,
            n_tiles=t.n_tiles, us_per_call=round(s_nbr * 1e6, 1),
            tile_payload_bytes=payload,
            gb_per_s=_gb_per_s(payload, s_nbr * 1e6),
        ))
        emit(f"core.spmv.{storage}.T{T}", s_spmv * 1e6, f"n_tiles={t.n_tiles}")
        emit(f"core.nbr_max.{storage}.T{T}", s_nbr * 1e6, f"n_tiles={t.n_tiles}")
    rows += _bench_bitwise_ops(base, pm, n, T)
    return rows


def _bench_bitwise_ops(base, pm, n: int, T: int) -> list:
    """The DESIGN.md §13 layer: packed-frontier popcount SpMV and the
    priority-sorted clz neighbour max, on bitpack storage.  These replace
    the unpack-then-dense bitpack path in the bitwise round body, so the
    row pair to compare against is (op, storage="bitpack") above."""
    t = base.to_storage("bitpack")
    tw = tiles_as_words(t.tiles, T)
    payload = t.tile_payload_bytes()

    cand = jax.random.uniform(jax.random.key(8), (base.n_padded,)) > 0.5
    cand_words = pack_frontier_words(cand, T)
    spmv_b = jax.jit(
        lambda tiles, tr, tc, rw: tile_spmv_bits(
            tiles, tr, tc, rw, t.n_block_rows, T
        )
    )
    s_spmv = time_fn(spmv_b, tw, t.tile_rows, t.tile_cols, cand_words)

    # the engine re-sorts the mask words every round (priorities are static,
    # the alive mask is not) — time that repack as part of the op
    order, p_sorted = sort_block_priorities(pm, T)
    tiles_sorted = sorted_tile_bits(t.tiles, t.tile_cols, order, T)
    mask_words = pack_frontier_words(pm != _NEG, T)
    nbr_b = jax.jit(
        lambda tiles, tr, tc, mw: tile_neighbor_max_bits(
            tiles, tr, tc, p_sorted, sorted_frontier_words(mw, order, T),
            t.n_block_rows, T,
        )
    )
    s_nbr = time_fn(nbr_b, tiles_sorted, t.tile_rows, t.tile_cols, mask_words,
                    iters=5)

    rows = []
    for op, s in (("spmv_bitwise", s_spmv), ("nbr_max_bitwise", s_nbr)):
        rows.append(dict(
            op=op, storage="bitpack", n=n, tile_size=T,
            n_tiles=t.n_tiles, us_per_call=round(s * 1e6, 1),
            tile_payload_bytes=payload,
            gb_per_s=_gb_per_s(payload, s * 1e6),
        ))
        emit(f"core.{op}.bitpack.T{T}", s * 1e6, f"n_tiles={t.n_tiles}")
    return rows


def _bench_pallas_kernel(n: int, T: int) -> list:
    """One small interpret-mode case per storage: trajectory, not truth."""
    g = erdos_renyi(n, avg_deg=6.0, seed=4)
    base = build_block_tiles(g, tile_size=T)
    rhs = jax.random.normal(jax.random.key(5), (base.n_padded, 2), jnp.float32)
    rows = []
    for storage in STORAGES:
        t = base.to_storage(storage)
        s = time_fn(lambda: tc_spmv(t, rhs), warmup=1, iters=2)
        rows.append(dict(
            op="kernel_spmv", storage=storage, n=n, tile_size=T,
            n_tiles=t.n_tiles, us_per_call=round(s * 1e6, 1),
            tile_payload_bytes=t.tile_payload_bytes(),
            gb_per_s=_gb_per_s(t.tile_payload_bytes(), s * 1e6),
            interpret=jax.default_backend() != "tpu",
        ))
        emit(f"core.kernel_spmv.{storage}.T{T}", s * 1e6, f"n_tiles={t.n_tiles}")
    return rows


def _best_of(solver, g, iters: int = 3):
    """(best solve_ms, that run's result) — best-of-N because the per-round
    wall clock feeds the telemetry-overhead bar, which needs a stable
    denominator, not a scheduler-noise sample."""
    best_ms, best_res = None, None
    for _ in range(iters):
        res = solver.solve(g)
        ms = float(res.stats["solve_ms"])
        if best_ms is None or ms < best_ms:
            best_ms, best_res = ms, res
    return best_ms, best_res


def _bench_solve(n: int, T: int) -> list:
    g = erdos_renyi(n, avg_deg=6.0, seed=6)
    rows = []
    for storage in STORAGES:
        solver = Solver(SolveOptions(
            engine="tiled_ref", tile_size=T, storage=storage, placement="local",
        ))
        solver.solve(g)          # warm: plan + compile outside the timer
        ms, res = _best_of(solver, g)
        rounds = max(res.rounds, 1)
        rows.append(dict(
            op="solve", storage=storage, engine="tiled_ref", n=n, tile_size=T,
            rounds=res.rounds, solve_ms=ms,
            us_per_round=round(ms * 1e3 / rounds, 1),
            mis_size=res.mis_size,
        ))
        emit(f"core.solve.{storage}.T{T}", ms * 1e3 / rounds,
             f"rounds={res.rounds};mis={res.mis_size}")

        # the telemetry-on twin (repro.obs, DESIGN.md §14): same graph, same
        # plan shape, round buffer recorded — its row embeds the per-round
        # summary so the BENCH trajectory carries convergence shape, and its
        # solution must be bit-identical to the untelemetered run
        tsolver = Solver(SolveOptions(
            engine="tiled_ref", tile_size=T, storage=storage,
            placement="local", telemetry=True,
        ))
        tsolver.solve(g)
        tms, tres = _best_of(tsolver, g)
        assert (tres.in_mis == res.in_mis).all(), (
            "telemetry must not change the solution", storage,
        )
        rt = tres.telemetry
        trounds = max(tres.rounds, 1)
        rows.append(dict(
            op="solve_telemetry", storage=storage, engine="tiled_ref",
            n=n, tile_size=T, rounds=tres.rounds, solve_ms=tms,
            us_per_round=round(tms * 1e3 / trounds, 1),
            mis_size=tres.mis_size,
            rounds_summary=rt.summary(),
        ))
        emit(f"core.solve_telemetry.{storage}.T{T}", tms * 1e3 / trounds,
             f"rounds={tres.rounds};alive0={rt.summary()['alive0']}")
    return rows


def _telemetry_overhead_guard(prev, cur) -> None:
    """The disabled-telemetry zero-cost bar (DESIGN.md §14): against a prior
    run of the SAME configuration (backend/quick match, same n/T per row),
    the telemetry-off per-round wall clock may not regress more than 5%
    plus a 300 µs absolute slack (sub-ms rows are all timer noise).  CI
    arms this by running the bench twice against one BENCH_CORE_OUT path —
    the second run compares itself to the first."""
    if prev is None:
        return
    if any(prev.get(k) != cur[k] for k in ("bench", "backend", "quick")):
        print("# overhead bar skipped: prior run has a different config")
        return
    prior = {
        r["storage"]: r for r in prev.get("results", ())
        if r.get("op") == "solve"
    }
    for r in cur["results"]:
        if r["op"] != "solve":
            continue
        old = prior.get(r["storage"])
        if (old is None or old.get("n") != r["n"]
                or old.get("tile_size") != r["tile_size"]):
            continue
        bar = old["us_per_round"] * 1.05 + 300.0
        assert r["us_per_round"] <= bar, (
            "disabled-telemetry solve regressed >5% vs prior run",
            r["storage"], r["us_per_round"], old["us_per_round"],
        )
        print(
            f"# overhead bar ok ({r['storage']}): "
            f"{r['us_per_round']} us/round vs bar {round(bar, 1)}"
        )


def main() -> None:
    # --quick forces the small sizes regardless of BENCH_QUICK — the CI
    # smoke step invokes `core_bench.py --quick` without env plumbing
    quick = QUICK or "--quick" in sys.argv
    n = 2048 if quick else 8192
    T = 64
    prev = None
    if os.path.exists(OUT_PATH):     # prior run = the overhead-bar baseline
        try:
            with open(OUT_PATH) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = None
    results = []
    results += _bench_tile_ops(n, T, lanes=8)
    results += _bench_pallas_kernel(256, 32)
    results += _bench_solve(n, T)

    # the storage axis's memory bar, recorded alongside the timings
    g = erdos_renyi(2048, avg_deg=8.0, seed=7)
    tiled = build_block_tiles(g, tile_size=128)
    s_int8 = tile_stats(tiled)
    s_pack = tile_stats(tiled.to_storage("bitpack"))
    # whole-representation ratio (indices included) — the payload-only
    # ratio is 8.0 by dtype arithmetic and says nothing about real HBM
    reduction = s_int8["bsr_bytes"] / max(s_pack["bsr_bytes"], 1)
    emit("core.mem.T128_reduction", 0.0, f"{reduction:.2f}x")

    # stamped (git_sha/timestamp/backend/jax_version) + history-appended
    # through the one bench emission seam (repro.obs.bench, DESIGN.md §17)
    doc = write_bench(dict(
        bench="core",
        backend=jax.default_backend(),
        quick=quick,
        results=results,
        t128_tile_hbm_reduction=round(reduction, 2),
    ), OUT_PATH)

    # bit-parity of the storage formats is asserted by tier-1 tests; here we
    # only guard that both formats actually ran every layer
    by_op = {r["op"] for r in results}
    assert by_op == {
        "spmv", "nbr_max", "spmv_bitwise", "nbr_max_bitwise",
        "kernel_spmv", "solve", "solve_telemetry",
    }, by_op
    assert all(
        any(r["storage"] == s for r in results) for s in STORAGES
    ), "both storage formats must be measured"

    # the §13 perf bars (ISSUE 6 acceptance): the bitwise ops beat the
    # unpack-then-dense bitpack path ≥2× at the same (n, T), and the dense
    # bitpack neighbour max is no longer slower than int8 (in-VMEM mask
    # unpack fix; 1.15 leaves headroom for timer noise — the steady-state
    # ratio is ~1.0)
    def _us(op, storage):
        return next(
            r["us_per_call"] for r in results
            if r["op"] == op and r.get("storage") == storage
        )

    assert _us("spmv", "bitpack") >= 2 * _us("spmv_bitwise", "bitpack"), (
        "bitwise SpMV must be ≥2× faster than dense bitpack",
        _us("spmv", "bitpack"), _us("spmv_bitwise", "bitpack"),
    )
    assert _us("nbr_max", "bitpack") >= 2 * _us("nbr_max_bitwise", "bitpack"), (
        "bitwise neighbour max must be ≥2× faster than dense bitpack",
        _us("nbr_max", "bitpack"), _us("nbr_max_bitwise", "bitpack"),
    )
    assert _us("nbr_max", "bitpack") <= 1.15 * _us("nbr_max", "int8"), (
        "bitpack neighbour max regressed vs int8 again",
        _us("nbr_max", "bitpack"), _us("nbr_max", "int8"),
    )

    # the §14 zero-cost bar: telemetry off must not have slowed down
    _telemetry_overhead_guard(prev, doc)


if __name__ == "__main__":
    main()

"""Dynamic-graph trajectory: incremental repair vs cold re-solve, persisted
to ``BENCH_dyngraph.json`` at the repo root (DESIGN.md §12).

For each tile storage format and each delta size (as a fraction of the
graph's edges), one pre-solved graph takes a random `EdgeDelta`
(adds + removes, strict-valid by construction) and is re-solved twice:

  repair   `Solver.update` with repair='incremental' — tile-local plan
           patch + warm-started round-engine re-entry from the prior
           solution (only the dirty frontier alive)
  cold     a fresh `Solver.solve` of the SAME patched plan (identical
           priorities/key, so the two differ only in the warm start)

Reported per case: wall time (warm second run — each delta changes the
static edge shapes, so the first run of either path pays an XLA compile
that would swamp the per-round comparison), round counts, |MIS| of both
answers, and validity of the repaired solution.  The acceptance bar is
encoded as an assert: at delta fractions ≤ 1% the incremental repair runs
STRICTLY fewer rounds than the cold re-solve, in both storage formats.

    PYTHONPATH=src python -m benchmarks.dyngraph_bench
    BENCH_ONLY=dyngraph PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit
from repro.api import Solver, SolveOptions
from repro.core.validate import is_valid_mis_jit
from repro.dyngraph import random_delta
from repro.graphs.generators import erdos_renyi
from repro.obs.bench import write_bench

OUT_PATH = os.environ.get("BENCH_DYNGRAPH_OUT", "BENCH_dyngraph.json")
STORAGES = ("int8", "bitpack")
DELTA_FRACS = (0.002, 0.01, 0.05)   # of the graph's undirected edges
SMALL_FRAC = 0.01                   # the strictly-fewer-rounds bar


def _timed(fn):
    """Warm wall-clock of one already-compiled call."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jnp.asarray(out.in_mis))
    return out, (time.perf_counter() - t0) * 1e3


def _bench_storage(storage: str, n: int, T: int) -> list:
    g = erdos_renyi(n, avg_deg=8.0, seed=0)
    solver = Solver(SolveOptions(
        engine="tiled_ref", tile_size=T, storage=storage,
        placement="local", repair="incremental",
    ))
    prior = solver.solve(g)
    n_und = g.n_edges // 2
    rows = []
    for frac in DELTA_FRACS:
        k = max(int(n_und * frac) // 2, 1)   # k adds + k removes
        delta = random_delta(g, n_add=k, n_remove=k, seed=int(frac * 1e4))
        # first runs compile (new static shapes per delta); time the reruns
        rep = solver.update(prior, delta)
        rep, repair_ms = _timed(lambda: solver.update(prior, delta))
        cold = solver.solve(rep.plan)
        cold, cold_ms = _timed(lambda: solver.solve(rep.plan))
        valid = all(is_valid_mis_jit(rep.plan.g, jnp.asarray(rep.in_mis_plan)))
        rows.append(dict(
            storage=storage, n=n, tile_size=T, delta_frac=frac,
            n_add=delta.n_add, n_remove=delta.n_remove,
            touched=int(delta.touched().size),
            repair_rounds=rep.rounds, cold_rounds=cold.rounds,
            repair_ms=round(repair_ms, 3), cold_ms=round(cold_ms, 3),
            repair_mis=rep.mis_size, cold_mis=cold.mis_size,
            repair_valid=valid,
        ))
        emit(
            f"dyngraph.repair.{storage}.f{frac}", repair_ms * 1e3,
            f"rounds={rep.rounds}/{cold.rounds};cold_ms={cold_ms:.1f}",
        )
        assert valid, f"repaired solution invalid ({storage}, frac={frac})"
        if frac <= SMALL_FRAC:
            assert rep.rounds < cold.rounds, (
                f"incremental repair must run strictly fewer rounds than a "
                f"cold re-solve at delta_frac={frac} ({storage}): "
                f"{rep.rounds} vs {cold.rounds}"
            )
    return rows


def main() -> None:
    n = 2048 if QUICK else 8192
    T = 32
    results = []
    for storage in STORAGES:
        results += _bench_storage(storage, n, T)

    # stamped (git_sha/timestamp/backend/jax_version) + history-appended
    # through the one bench emission seam (repro.obs.bench, DESIGN.md §17)
    write_bench(dict(
        bench="dyngraph",
        backend=jax.default_backend(),
        quick=QUICK,
        small_delta_frac=SMALL_FRAC,
        results=results,
    ), OUT_PATH)


if __name__ == "__main__":
    main()

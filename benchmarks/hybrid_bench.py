"""Hybrid routing density sweep: per-tile dense/sparse classification vs
the all-dense tile path and the pure segment path (DESIGN.md §16).

One sweep axis per degree distribution:

  uniform     Erdős–Rényi — every tile draws the same expected nnz, so the
              classifier flips the WHOLE tiling at once as density crosses
              the roofline threshold
  powerlaw    skewed — hub block-rows go dense while the tail stays sparse,
              the regime the per-tile split exists for

and three engine rows per (distribution, density) point:

  hybrid      tiled_ref with `hybrid="forced"` — compacted dense tile list
              through the tile path, sparse-tail COO through segment ops
  dense       tiled_ref with `hybrid="off"` — every stored tile through the
              tile path (the pre-§16 behaviour)
  segment     the segment engine — the all-COO lower bound the sparse tail
              borrows its ops from

Every row carries `gb_per_s` — effective payload bandwidth, where the
hybrid payload counts the dense sub-tiling's tiles plus the COO index
arrays (the bytes the round actually touches), so routing wins show up as
bandwidth gains, not just latency.

Acceptance bars (ISSUE 9), asserted at the sweep's top density:

  skewed    hybrid ≥1.3× faster per round than dense
  uniform   hybrid ≥0.95× — routing must not tax the distribution that
            never needed it

    PYTHONPATH=src python -m benchmarks.hybrid_bench [--quick]
    BENCH_ONLY=hybrid PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import sys

import jax

from benchmarks.common import QUICK, emit
from repro.api import Solver, SolveOptions
from repro.graphs.generators import erdos_renyi, powerlaw
from repro.obs.bench import write_bench

OUT_PATH = os.environ.get("BENCH_HYBRID_OUT", "BENCH_hybrid.json")
ENGINES = ("hybrid", "dense", "segment")

SKEW_BAR = 1.3       # hybrid speedup over dense on the skewed sweep point
UNIFORM_BAR = 0.95   # hybrid may not be >5% slower where routing can't help


def _gb_per_s(payload_bytes: int, us: float) -> float:
    """bytes / µs·10³ = bytes/ns = GB/s of payload traffic."""
    return round(payload_bytes / max(us * 1e3, 1e-9), 3)


def _options(engine_row: str, T: int) -> SolveOptions:
    if engine_row == "segment":
        return SolveOptions(engine="segment", hybrid="off", placement="local")
    return SolveOptions(
        engine="tiled_ref", tile_size=T, placement="local",
        hybrid="forced" if engine_row == "hybrid" else "off",
    )


def _payload_bytes(plan, engine_row: str) -> int:
    """Bytes the phase-② path actually reads for this routing choice."""
    tiled = plan.tiled
    if engine_row == "segment":
        # COO over the whole graph: both index arrays
        return 2 * 4 * int(plan.g.senders.shape[0])
    if engine_row == "hybrid" and tiled.partition is not None:
        part = tiled.partition
        return (part.dense.tile_payload_bytes()
                + part.sp_rows.nbytes + part.sp_cols.nbytes)
    return tiled.tile_payload_bytes()


def _best_round_us(solver: Solver, g, iters: int = 3):
    """(best µs/round, that run's result) — best-of-N for a stable bar."""
    solver.solve(g)                  # warm: plan + compile outside the timer
    best_us, best_res = None, None
    for _ in range(iters):
        res = solver.solve(g)
        us = float(res.stats["solve_ms"]) * 1e3 / max(res.rounds, 1)
        if best_us is None or us < best_us:
            best_us, best_res = us, res
    return best_us, best_res


def _sweep(kind: str, n: int, T: int, densities) -> list:
    rows = []
    for d in densities:
        avg_deg = max(2.0, d * n)
        g = (powerlaw(n, avg_deg=avg_deg, seed=9) if kind == "powerlaw"
             else erdos_renyi(n, avg_deg=avg_deg, seed=9))
        base_mis = None
        for engine_row in ENGINES:
            solver = Solver(options=_options(engine_row, T))
            us, res = _best_round_us(solver, g)
            if base_mis is None:
                base_mis = res.in_mis
            else:
                assert (res.in_mis == base_mis).all(), (
                    "hybrid routing changed the solution", kind, d, engine_row,
                )
            plan = solver.plan(g)
            payload = _payload_bytes(plan, engine_row)
            row = dict(
                kind=kind, density=d, n=n, tile_size=T, engine=engine_row,
                rounds=res.rounds, us_per_round=round(us, 1),
                mis_size=res.mis_size, payload_bytes=payload,
                gb_per_s=_gb_per_s(payload, us),
            )
            part = plan.tiled.partition
            if part is not None:
                row.update(
                    n_dense_tiles=part.n_dense_tiles,
                    n_sparse_tiles=part.n_sparse_tiles,
                    threshold=part.threshold,
                )
            rows.append(row)
            emit(f"hybrid.{kind}.d{d:g}.{engine_row}", us,
                 f"rounds={res.rounds};mis={res.mis_size}")
    return rows


def _us(rows, kind: str, density: float, engine_row: str) -> float:
    return next(
        r["us_per_round"] for r in rows
        if r["kind"] == kind and r["density"] == density
        and r["engine"] == engine_row
    )


def main() -> None:
    # --quick forces the small sweep regardless of BENCH_QUICK — the CI
    # smoke step invokes `hybrid_bench.py --quick` without env plumbing
    quick = QUICK or "--quick" in sys.argv
    n = 2048 if quick else 8192
    T = 64
    densities = (0.002, 0.008) if quick else (0.0005, 0.002, 0.008, 0.03)

    rows = []
    for kind in ("uniform", "powerlaw"):
        rows += _sweep(kind, n, T, densities)

    # stamped (git_sha/timestamp/backend/jax_version) + history-appended
    # through the one bench emission seam (repro.obs.bench, DESIGN.md §17)
    write_bench(dict(
        bench="hybrid",
        backend=jax.default_backend(),
        quick=quick,
        results=rows,
    ), OUT_PATH)

    # the §16 perf bars (ISSUE 9 acceptance).  Skewed takes the sweep's BEST
    # point — the bar asserts the routing win exists, and where it lands on
    # the density axis is backend-dependent.  Uniform takes the WORST point —
    # routing must not tax any density of the distribution it can't help.
    def _ratio(kind, d):
        return _us(rows, kind, d, "dense") \
            / max(_us(rows, kind, d, "hybrid"), 1e-9)

    skew_ratio = max(_ratio("powerlaw", d) for d in densities)
    uni_ratio = min(_ratio("uniform", d) for d in densities)
    assert skew_ratio >= SKEW_BAR, (
        f"hybrid must be ≥{SKEW_BAR}× dense on the skewed sweep", skew_ratio,
    )
    assert uni_ratio >= UNIFORM_BAR, (
        f"hybrid must stay within {UNIFORM_BAR}× of dense on the uniform "
        f"sweep", uni_ratio,
    )
    emit("hybrid.bar.skewed_speedup", 0.0, f"{skew_ratio:.2f}x>=" f"{SKEW_BAR}")
    emit("hybrid.bar.uniform_ratio", 0.0,
         f"{uni_ratio:.2f}x>={UNIFORM_BAR}")


if __name__ == "__main__":
    main()

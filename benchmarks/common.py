"""Shared benchmark utilities: CSV emission, timing, graph suite access."""
from __future__ import annotations

import os
import time
from typing import Callable

import jax

QUICK = os.environ.get("BENCH_QUICK", "1") == "1"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock seconds of a jit'd callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def suite_graphs(scale_div: int | None = None):
    """Reduced-scale stand-ins for G1..G8 (generator-matched to Table 1)."""
    from repro.graphs.generators import GRAPH_SUITE

    div = scale_div if scale_div is not None else (4 if QUICK else 1)
    out = {}
    for gid, spec in GRAPH_SUITE.items():
        n = max(2048, spec.n_reduced // div)
        out[gid] = (spec, spec.make(n, 0))
    return out

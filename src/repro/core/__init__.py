"""TC-MIS core: the paper's contribution as composable JAX modules."""
from repro.core.engine import (
    ENGINES,
    EngineContext,
    MISRoundState,
    RoundEngine,
    block_col_flags,
    engine_names,
    get_engine,
    register_engine,
)
from repro.core.heuristics import HEURISTICS, Priorities, make_priorities
from repro.core.luby import MISResult, luby_mis
from repro.core.ecl_mis import ecl_mis
from repro.core.tc_mis import TCMISConfig, tc_mis, run_phases
from repro.core.tiling import (
    STORAGES,
    BlockTiledGraph,
    TilePartition,
    attach_partition,
    build_block_tiles,
    gather_frontier_bits,
    pack_tile_bits,
    pack_vertex_vector,
    packed_words,
    partition_tiles,
    tile_nnz,
    tile_stats,
    unpack_tile_bits,
    unpack_vertex_vector,
)
from repro.core.validate import (
    cardinality,
    is_independent,
    is_maximal,
    is_valid_mis,
    is_valid_mis_jit,
)
from repro.core.distributed import (
    DistConfig,
    ShardedTiledGraph,
    build_distributed_mis,
    shard_tiled,
)

__all__ = [
    "ENGINES", "EngineContext", "MISRoundState", "RoundEngine",
    "block_col_flags", "engine_names", "get_engine", "register_engine",
    "HEURISTICS", "Priorities", "make_priorities",
    "MISResult", "luby_mis", "ecl_mis",
    "TCMISConfig", "tc_mis", "run_phases",
    "STORAGES", "BlockTiledGraph", "TilePartition", "attach_partition",
    "build_block_tiles", "gather_frontier_bits", "pack_tile_bits",
    "pack_vertex_vector", "packed_words", "partition_tiles", "tile_nnz",
    "tile_stats", "unpack_tile_bits", "unpack_vertex_vector",
    "cardinality", "is_independent", "is_maximal", "is_valid_mis",
    "is_valid_mis_jit",
    "DistConfig", "ShardedTiledGraph", "build_distributed_mis", "shard_tiled",
]

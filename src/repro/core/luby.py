"""Luby's randomized parallel MIS (paper Algorithm 1) — the classical baseline.

Fresh uniform priorities every round; three phases per round exactly as the
paper states them.  Runs as a single `lax.while_loop`, so the whole algorithm
is one XLA program.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.spmv import neighbor_any_segment, neighbor_max_segment
from repro.graphs.graph import Graph


class MISResult(NamedTuple):
    in_mis: jnp.ndarray   # (n,) bool
    rounds: jnp.ndarray   # int32 — rounds to convergence
    converged: jnp.ndarray  # bool — False iff max_rounds hit


def luby_mis(g: Graph, key: jax.Array, *, max_rounds: int = 1024) -> MISResult:
    n = g.n_nodes

    def cond(state):
        alive, _, rnd = state
        return jnp.any(alive) & (rnd < max_rounds)

    def body(state):
        alive, in_mis, rnd = state
        # Phase 1: fresh random priorities (ties vanishingly rare; a tie only
        # delays both vertices one round, never breaks independence).
        p = jax.random.randint(
            jax.random.fold_in(key, rnd), (n,), 0, jnp.iinfo(jnp.int32).max,
            dtype=jnp.int32,
        )
        max_np = neighbor_max_segment(g, p, alive)
        cand = alive & (p > max_np)
        # Phase 2: who neighbours a candidate?
        hit = neighbor_any_segment(g, cand)
        # Phase 3: own-state-only update.
        in_mis = in_mis | cand
        alive = alive & ~cand & ~hit
        return alive, in_mis, rnd + 1

    alive0 = jnp.ones((n,), dtype=bool)
    in_mis0 = jnp.zeros((n,), dtype=bool)
    alive, in_mis, rounds = jax.lax.while_loop(
        cond, body, (alive0, in_mis0, jnp.int32(0))
    )
    return MISResult(in_mis=in_mis, rounds=rounds, converged=~jnp.any(alive))

"""Neighbourhood operators — the two execution paths of the paper.

* the **segment path** (`*_segment`): gather-by-edge + `segment_{sum,max}`
  over the edge list.  This is the JAX analogue of ECL-MIS's CSR traversal on
  CUDA cores — irregular, but the natural baseline.
* the **tiled path** (`*_tiled`): dense T×T tiles in BSR order.  `spmv_tiled`
  is the paper's phase-② `N_c = A × C` (MXU on TPU; the pure-jnp form here is
  also the Pallas kernel's oracle).  `neighbor_max_tiled` is our beyond-paper
  extension: phase ① on the *same* tile schedule (DESIGN.md §6.1).

Both paths accept multi-lane right-hand sides (T, L): lane-packing C / alive /
priorities into one pass is free on a 128-lane TPU (DESIGN.md §6.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import BlockTiledGraph
from repro.graphs.graph import Graph

_NEG = np.int32(-(1 << 30))  # numpy scalar: safe to create at import time under a trace


# --------------------------------------------------------------------------
# segment (edge-list) path — the CC baseline substrate
# --------------------------------------------------------------------------

def neighbor_sum_segment(g: Graph, x: jnp.ndarray) -> jnp.ndarray:
    """N_c(v) = Σ_{u∈N(v)} x(u) via gather + segment_sum (CSR-style path)."""
    contrib = jnp.where(g.edge_mask, x[g.senders], 0)
    return jax.ops.segment_sum(contrib, g.receivers, num_segments=g.n_nodes + 1)[
        : g.n_nodes
    ]


def neighbor_max_segment(
    g: Graph, p: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Max_Np(v) = max_{u∈N(v), mask(u)} p(u); −inf-like where no live nbr."""
    contrib = jnp.where(g.edge_mask & mask[g.senders], p[g.senders], _NEG)
    return jax.ops.segment_max(contrib, g.receivers, num_segments=g.n_nodes + 1)[
        : g.n_nodes
    ]


def neighbor_any_segment(g: Graph, flag: jnp.ndarray) -> jnp.ndarray:
    """Does v have a neighbour with flag set? (bool, no counting needed)."""
    contrib = (g.edge_mask & flag[g.senders]).astype(jnp.int32)
    s = jax.ops.segment_max(contrib, g.receivers, num_segments=g.n_nodes + 1)
    return s[: g.n_nodes] > 0


# --------------------------------------------------------------------------
# tiled (BSR) path — the paper's phase ② + the tiled phase ① extension
# --------------------------------------------------------------------------

def spmv_tiled(
    tiled: BlockTiledGraph,
    rhs: jnp.ndarray,
    *,
    backend: str = "ref",
    col_flags: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """N = A @ rhs over the BSR tiles.

    rhs: (n_padded, L) multi-lane right-hand side (lane 0 is the paper's C).
    col_flags: (n_block_cols,) active-column flags — gated slabs contribute
    nothing (the empty-C skip; exact on every lane, see core.engine).
    Returns (n_padded, L) float32.

    backend='ref'    pure-jnp (this function doubles as the kernel oracle)
    backend='pallas' the TPU Pallas kernel (interpret-mode on CPU)
    """
    if backend == "pallas":
        from repro.kernels.ops import tc_spmv

        return tc_spmv(tiled, rhs, col_flags=col_flags)
    from repro.core.engine import tile_spmv

    return tile_spmv(
        tiled.tiles, tiled.tile_rows, tiled.tile_cols, rhs,
        tiled.n_block_rows, tiled.tile_size, col_flags=col_flags,
    )


def neighbor_max_tiled(
    tiled: BlockTiledGraph,
    p: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    backend: str = "ref",
) -> jnp.ndarray:
    """Tiled phase ①: Max_Np via masked max over the same BSR schedule.

    p, mask: (n_padded,).  Returns (n_padded,) int32, −inf-like where no live
    neighbour.  VPU work (max has no MXU form), but identical memory schedule
    to `spmv_tiled` — the point of DESIGN.md §6.1.
    """
    if backend == "pallas":
        from repro.kernels.ops import tc_neighbor_max

        return tc_neighbor_max(tiled, p, mask)
    from repro.core.engine import tile_neighbor_max

    return tile_neighbor_max(
        tiled.tiles, tiled.tile_rows, tiled.tile_cols,
        jnp.where(mask, p, _NEG), tiled.n_block_rows, tiled.tile_size,
    )

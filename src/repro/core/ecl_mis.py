"""ECL-MIS baseline (Burtscher et al., TOPC'18) — the paper's comparison point.

Random-permutation variant of Luby: degree-aware priorities (Eq. 1, scaled and
discretised, hashed tie-break) are assigned **once** and reused across rounds.
Candidate selection and neighbour elimination run on the edge-list/segment
path — the JAX analogue of ECL's CSR traversal on CUDA cores (we cannot, and
do not, emulate its asynchronous lock-free races; see DESIGN.md §4).

With a static total order the algorithm is fully deterministic given the key,
and — because TC-MIS with the same priorities computes exactly the same
candidate sets — `tc_mis(heuristic='ecl')` must produce the *identical* MIS.
The test suite asserts this bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.heuristics import make_priorities
from repro.core.luby import MISResult
from repro.core.spmv import neighbor_any_segment, neighbor_max_segment
from repro.graphs.graph import Graph


def ecl_mis(
    g: Graph,
    key: jax.Array,
    *,
    heuristic: str = "ecl",
    max_rounds: int = 1024,
) -> MISResult:
    n = g.n_nodes
    deg = g.degrees()
    pri = make_priorities(heuristic, key, n, deg)
    select = pri.select

    def cond(state):
        alive, _, rnd = state
        return jnp.any(alive) & (rnd < max_rounds)

    def body(state):
        alive, in_mis, rnd = state
        # ① neighbour max over live vertices, candidate test
        max_np = neighbor_max_segment(g, select, alive)
        if pri.resolve is None:
            cand = alive & (select > max_np)
        else:
            pending = alive & (select >= max_np)
            max_res = neighbor_max_segment(g, pri.resolve, pending)
            cand = pending & (pri.resolve > max_res)
        # ② neighbour elimination (irregular traversal path)
        hit = neighbor_any_segment(g, cand)
        # ③ state update
        in_mis = in_mis | cand
        alive = alive & ~cand & ~hit
        return alive, in_mis, rnd + 1

    alive0 = jnp.ones((n,), dtype=bool)
    in_mis0 = jnp.zeros((n,), dtype=bool)
    alive, in_mis, rounds = jax.lax.while_loop(
        cond, body, (alive0, in_mis0, jnp.int32(0))
    )
    return MISResult(in_mis=in_mis, rounds=rounds, converged=~jnp.any(alive))

"""Block-tiled adjacency representation (the paper's §3.2, TPU-sized).

The adjacency matrix is cut into ``T×T`` dense tiles; only non-empty tiles are
stored, sorted by block-row then block-column (BSR order).  Sorting by
block-row is load-bearing: the Pallas SpMV kernel walks tiles in this order
and accumulates consecutive same-row tiles into one resident VMEM output
block — the TPU replacement for the paper's per-row-per-tile atomics.

The paper uses T=16 (WMMA fragment size).  The TPU MXU is a 128×128 systolic
array, so T defaults to 128 here; the builder takes any power of two ≥ 8 and
the benchmarks sweep it (see DESIGN.md §2 for the density trade-off).

Tiles store 0/1 in int8 (HBM-compact); kernels upcast to bf16 at the MXU.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import Graph


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (≥ 1) — the shape-bucket quantiser shared by
    the serving batcher and the bucketed validator (one definition, or their
    bucket shapes drift apart)."""
    return 1 << max(int(x) - 1, 0).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockTiledGraph:
    """BSR adjacency: only non-empty T×T tiles, row-major block order.

    Attributes:
      tiles:      (n_tiles_pad, T, T) int8 — 0/1 dense tiles (padding = zeros).
      tile_rows:  (n_tiles_pad,) int32 — block-row of each tile (padding tiles
                  carry the *last real* block-row so revisit-accumulation
                  stays monotone and adds zero).
      tile_cols:  (n_tiles_pad,) int32 — block-column of each tile.
      row_starts: (n_block_rows+1,) int32 — CSR-style pointer into the tile
                  list per block-row (host metadata for partitioning).
      n_tiles:    static — number of real tiles.
      n_nodes:    static — vertex count (pre-padding).
      tile_size:  static — T.
      n_block_rows / n_block_cols: static — ceil(n_nodes / T).
    """
    tiles: jnp.ndarray
    tile_rows: jnp.ndarray
    tile_cols: jnp.ndarray
    row_starts: jnp.ndarray
    n_tiles: int = dataclasses.field(metadata=dict(static=True))
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    tile_size: int = dataclasses.field(metadata=dict(static=True))
    n_block_rows: int = dataclasses.field(metadata=dict(static=True))
    n_block_cols: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_tiles_pad(self) -> int:
        return int(self.tiles.shape[0])

    @property
    def n_padded(self) -> int:
        """Vertex count rounded up to a whole number of tiles."""
        return self.n_block_rows * self.tile_size

    def density(self) -> float:
        """Fraction of tile cells that are real edges (the paper's trade-off)."""
        t = np.asarray(self.tiles[: self.n_tiles])
        return float(t.sum()) / max(t.size, 1)

    def memory_bytes(self) -> int:
        """HBM footprint of the tiled representation."""
        return (
            self.tiles.size * self.tiles.dtype.itemsize
            + self.tile_rows.size * 4
            + self.tile_cols.size * 4
        )


def rcm_ordering(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee vertex permutation (beyond-paper, DESIGN.md §6).

    Locality reordering concentrates edges near the diagonal, raising
    intra-tile density and cutting the non-empty tile count — the lever that
    makes 128×128 MXU tiles viable on graphs the paper would tile at 16×16.
    Returns perm such that new_id = perm_inv[old_id].
    """
    from scipy.sparse import coo_matrix
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    s = np.asarray(g.senders)[: g.n_edges]
    r = np.asarray(g.receivers)[: g.n_edges]
    adj = coo_matrix(
        (np.ones(len(s), np.int8), (s, r)), shape=(g.n_nodes, g.n_nodes)
    ).tocsr()
    return np.asarray(reverse_cuthill_mckee(adj, symmetric_mode=True))


def build_block_tiles(
    g: Graph,
    tile_size: int = 128,
    *,
    pad_tiles_to: int | None = None,
    reorder: str | None = None,   # None | 'rcm'
) -> BlockTiledGraph:
    """Tile ``g``'s adjacency matrix (host-side, numpy).

    Steps (mirrors the paper's Listing 1 preprocessing):
      1. (optional) RCM locality reordering — beyond-paper, see rcm_ordering,
      2. map each half-edge (u, v) to tile key (u//T, v//T),
      3. unique keys, sorted row-major → tile index per edge,
      4. scatter edges into dense tiles,
      5. pad the tile list so shapes are static/shardable.

    NOTE with reorder='rcm' the returned tiling indexes PERMUTED vertex ids;
    callers must map priorities/results through the same permutation (the
    MIS solution set is permutation-equivariant, so validity is unaffected —
    tests/test_tiling.py::test_rcm_mis_roundtrip).
    """
    T = int(tile_size)
    if T < 8 or (T & (T - 1)):
        raise ValueError(f"tile_size must be a power of two >= 8, got {T}")
    s = np.asarray(g.senders)[: g.n_edges].astype(np.int64)
    r = np.asarray(g.receivers)[: g.n_edges].astype(np.int64)
    if reorder == "rcm":
        perm = rcm_ordering(g)                 # perm[new_id] = old_id
        inv = np.empty_like(perm)
        inv[perm] = np.arange(g.n_nodes)
        s, r = inv[s], inv[r]
        order = np.lexsort((r, s))
        s, r = s[order], r[order]
    nb = -(-g.n_nodes // T)  # ceil
    tr, tc = s // T, r // T
    key = tr * nb + tc
    uniq, inv = np.unique(key, return_inverse=True)
    n_tiles = int(uniq.shape[0])

    tiles = np.zeros((max(n_tiles, 1), T, T), dtype=np.int8)
    tiles[inv, s % T, r % T] = 1
    tile_rows = (uniq // nb).astype(np.int32)
    tile_cols = (uniq % nb).astype(np.int32)
    if n_tiles == 0:
        tile_rows = np.zeros(1, dtype=np.int32)
        tile_cols = np.zeros(1, dtype=np.int32)
        n_tiles = 0

    # row_starts: CSR over block-rows (tiles are already row-major sorted)
    counts = np.bincount(tile_rows[: max(n_tiles, 1)] if n_tiles else [], minlength=nb)
    row_starts = np.zeros(nb + 1, dtype=np.int32)
    np.cumsum(counts, out=row_starts[1:])

    # pad: zero tiles pinned to the last real block-row (monotone, no-op adds)
    stored = tiles.shape[0]
    target = pad_tiles_to or stored
    target = max(target, stored)
    target = ((target + 7) // 8) * 8  # modest alignment for sharding
    if target > stored:
        last_row = tile_rows[-1] if n_tiles else 0
        tiles = np.concatenate(
            [tiles, np.zeros((target - stored, T, T), dtype=np.int8)], axis=0
        )
        tile_rows = np.concatenate(
            [tile_rows, np.full(target - stored, last_row, dtype=np.int32)]
        )
        tile_cols = np.concatenate(
            [tile_cols, np.zeros(target - stored, dtype=np.int32)]
        )

    return BlockTiledGraph(
        tiles=jnp.asarray(tiles),
        tile_rows=jnp.asarray(tile_rows),
        tile_cols=jnp.asarray(tile_cols),
        row_starts=jnp.asarray(row_starts),
        n_tiles=n_tiles,
        n_nodes=g.n_nodes,
        tile_size=T,
        n_block_rows=int(nb),
        n_block_cols=int(nb),
    )


def pack_vertex_vector(x: jnp.ndarray, tiled: BlockTiledGraph) -> jnp.ndarray:
    """(n_nodes,) -> (n_padded,) zero-padded to whole tiles."""
    pad = tiled.n_padded - x.shape[0]
    return jnp.pad(x, (0, pad)) if pad else x


def unpack_vertex_vector(x: jnp.ndarray, tiled: BlockTiledGraph) -> jnp.ndarray:
    return x[: tiled.n_nodes]


def tile_stats(tiled: BlockTiledGraph) -> dict:
    """Host-side stats for the memory-footprint benchmark (paper §3.2)."""
    t = np.asarray(tiled.tiles[: max(tiled.n_tiles, 1)])
    nnz = int(t.sum())
    total_blocks = tiled.n_block_rows * tiled.n_block_cols
    return dict(
        tile_size=tiled.tile_size,
        n_tiles=tiled.n_tiles,
        block_grid=total_blocks,
        block_occupancy=tiled.n_tiles / max(total_blocks, 1),
        intra_tile_density=nnz / max(t.size, 1),
        bsr_bytes=tiled.memory_bytes(),
        csr_bytes=8 * nnz + 4 * (tiled.n_nodes + 1),  # int32 idx + int64-ish ptr
    )

"""Block-tiled adjacency representation (the paper's §3.2, TPU-sized).

The adjacency matrix is cut into ``T×T`` dense tiles; only non-empty tiles are
stored, sorted by block-row then block-column (BSR order).  Sorting by
block-row is load-bearing: the Pallas SpMV kernel walks tiles in this order
and accumulates consecutive same-row tiles into one resident VMEM output
block — the TPU replacement for the paper's per-row-per-tile atomics.

The paper uses T=16 (WMMA fragment size).  The TPU MXU is a 128×128 systolic
array, so T defaults to 128 here; the builder takes any power of two ≥ 8 and
the benchmarks sweep it (see DESIGN.md §2 for the density trade-off).

Tiles are 0/1 matrices, stored in one of two formats — the `storage` axis of
the representation (DESIGN.md §11):

  int8      (nt, T, T) int8 — one byte per cell.  The original layout and
            the oracle substrate; kernels upcast to bf16/f32 at the MXU.
  bitpack   (nt, T, W) uint32 with W = max(T // 32, 1) — 1 bit per cell,
            packed along columns (bit j of word w of row v = column
            32·w + j).  8× less HBM, DMA traffic and plan-cache bytes; the
            Pallas kernels unpack per-tile in VMEM after the DMA, so HBM
            only ever sees packed words.

`pack_tile_bits` (host, numpy) and `unpack_tile_bits` (jnp, jit- and
kernel-safe) convert between them; every consumer detects the format from
the tile dtype, so raw-array call sites stay storage-polymorphic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import Graph

STORAGES = ("int8", "bitpack")   # concrete tile storage formats
_BITS = 32                       # bits per packed word (uint32)

# auto-mode gate for hybrid tile routing (DESIGN.md §16): attaching a
# partition only pays off once there are enough tiles for the split to
# matter AND a real sparse tail to peel off.
HYBRID_AUTO_MIN_TILES = 16
HYBRID_AUTO_MIN_SPARSE_FRAC = 0.25


def packed_words(tile_size: int) -> int:
    """Words per packed tile row: ceil over 32, floor 1 (T=8/16 use the low
    T bits of a single word)."""
    return max(int(tile_size) // _BITS, 1)


def pack_tile_bits(tiles) -> np.ndarray:
    """(..., T, T) 0/1 -> (..., T, W) uint32, bits packed along columns.

    Host-side (numpy): the build/cache path packs once; unpacking is the
    jit/kernel-side operation (`unpack_tile_bits`)."""
    t = np.asarray(tiles)
    T = t.shape[-1]
    W = packed_words(T)
    bits = (t != 0).astype(np.uint32)
    if W * _BITS != T:  # T < 32: pad columns up to one full word
        pad = np.zeros(t.shape[:-1] + (W * _BITS - T,), np.uint32)
        bits = np.concatenate([bits, pad], axis=-1)
    bits = bits.reshape(t.shape[:-1] + (W, _BITS))
    weights = np.uint32(1) << np.arange(_BITS, dtype=np.uint32)
    # disjoint bit positions ⇒ OR-reduce is an overflow-free sum
    return np.bitwise_or.reduce(bits * weights, axis=-1)


def unpack_tile_bits(packed: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """(..., T, W) uint32 -> (..., T, T) int8 — the jit/kernel-side inverse.

    Uses `broadcasted_iota` (not 1-D arange) so the same expression lowers
    inside Pallas TPU kernel bodies, where it runs on the VMEM-resident
    block right after the (8× smaller) DMA."""
    W = packed.shape[-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, packed.shape + (_BITS,), len(packed.shape)
    )
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    full = bits.reshape(packed.shape[:-1] + (W * _BITS,))
    return full[..., : int(tile_size)].astype(jnp.int8)


def unpack_tile_mask(packed: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """(..., T, W) uint32 -> (..., T, T) bool — `unpack_tile_bits` without the
    int8 materialisation.  Consumers that only need an edge *mask* (the
    neighbour-max `where`, the SpMV 0/1 upcast) should use this form: it
    skips one full elementwise pass over the dense tile (the int8 cast) —
    the pass that made the packed neighbour-max slower than int8 at T=64.
    Same
    `broadcasted_iota` construction, so it lowers inside Pallas kernel
    bodies (restricted to them by tools/ci_guards.py, like the int8 form).
    """
    W = packed.shape[-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, packed.shape + (_BITS,), len(packed.shape)
    )
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    full = bits.reshape(packed.shape[:-1] + (W * _BITS,))
    return full[..., : int(tile_size)] != 0


def dense_tiles(tiles: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """Storage dispatch for ORACLE paths (jnp engine ops, `kernels/ref.py`):
    packed uint32 tiles densify under jit, int8 tiles pass through.  The
    Pallas kernels must never call this — they unpack per-tile in VMEM so
    HBM only sees packed words (enforced by tools/ci_guards.py)."""
    if tiles.dtype == jnp.uint32:
        return unpack_tile_bits(tiles, tile_size)
    return tiles


def dense_tile_mask(tiles: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """`dense_tiles` counterpart yielding a bool edge MASK: packed uint32
    tiles bit-extract straight to bool (no int8 intermediate), int8 tiles
    compare against zero.  The jnp tile operators use this form; kernels
    never may (tools/ci_guards.py — it materialises (nt, T, T) in HBM)."""
    if tiles.dtype == jnp.uint32:
        return unpack_tile_mask(tiles, tile_size)
    return tiles != 0


def tiles_as_words(tiles: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """Tiles in the packed-word form, whatever the storage: bitpack tiles
    pass through, int8 tiles pack (jit-safe — the bitwise frontier path
    needs packed words even when the PLAN stores int8).  Packing is safe
    anywhere; it is the *unpack* direction the CI guards restrict."""
    if tiles.dtype == jnp.uint32:
        return tiles
    return pack_frontier_bits(tiles, tile_size)


def padded_tile_count(n_real: int, pad_tiles_to: int | None = None) -> int:
    """Stored tile count for `n_real` real tiles: floor 1 (an empty graph
    still stores one zero tile), optional caller floor, aligned up to 8
    for sharding.  THE single definition of the tile-list pad convention —
    `build_block_tiles` and the delta path (`repro.dyngraph.retile`) must
    agree on it, or patched tilings stop being bit-exact with rebuilds."""
    stored = max(int(n_real), 1)
    target = max(pad_tiles_to or stored, stored)
    return ((target + 7) // 8) * 8


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (≥ 1) — the shape-bucket quantiser shared by
    the serving batcher and the bucketed validator (one definition, or their
    bucket shapes drift apart)."""
    return 1 << max(int(x) - 1, 0).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockTiledGraph:
    """BSR adjacency: only non-empty T×T tiles, row-major block order.

    Attributes:
      tiles:      (n_tiles_pad, T, T) int8 or (n_tiles_pad, T, W) uint32 —
                  0/1 dense tiles per `storage` (padding = zeros).
      tile_rows:  (n_tiles_pad,) int32 — block-row of each tile (padding tiles
                  carry the *last real* block-row so revisit-accumulation
                  stays monotone and adds zero).
      tile_cols:  (n_tiles_pad,) int32 — block-column of each tile.
      row_starts: (n_block_rows+1,) int32 — CSR-style pointer into the tile
                  list per block-row (host metadata for partitioning).
      n_tiles:    static — number of real tiles.
      n_nodes:    static — vertex count (pre-padding).
      tile_size:  static — T.
      n_block_rows / n_block_cols: static — ceil(n_nodes / T).
      storage:    static — 'int8' | 'bitpack' (the tile dtype's declared
                  format; raw-array consumers detect it from the dtype).
      partition:  optional hybrid routing split (DESIGN.md §16): a
                  `TilePartition` whose compacted dense sub-tiling and
                  COO sparse tail the hybrid engines dispatch instead of
                  `tiles`.  The FULL tile list above stays authoritative —
                  repair, retiling and sharding operate on it; the
                  partition is a derived, rebuildable view.
    """
    tiles: jnp.ndarray
    tile_rows: jnp.ndarray
    tile_cols: jnp.ndarray
    row_starts: jnp.ndarray
    n_tiles: int = dataclasses.field(metadata=dict(static=True))
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    tile_size: int = dataclasses.field(metadata=dict(static=True))
    n_block_rows: int = dataclasses.field(metadata=dict(static=True))
    n_block_cols: int = dataclasses.field(metadata=dict(static=True))
    storage: str = dataclasses.field(default="int8", metadata=dict(static=True))
    partition: Optional["TilePartition"] = None

    @property
    def n_tiles_pad(self) -> int:
        return int(self.tiles.shape[0])

    @property
    def n_padded(self) -> int:
        """Vertex count rounded up to a whole number of tiles."""
        return self.n_block_rows * self.tile_size

    def nnz(self) -> int:
        """Edge count over stored tiles, computed ON DEVICE — only the
        scalar crosses to host (bitpack counts bits via popcount)."""
        if self.n_tiles == 0:
            return 0
        t = self.tiles[: self.n_tiles]
        if self.storage == "bitpack":
            count = jnp.sum(
                jax.lax.population_count(t).astype(jnp.int32), dtype=jnp.int32
            )
        else:
            count = jnp.count_nonzero(t)
        return int(count)

    def density(self) -> float:
        """Fraction of tile cells that are real edges (the paper's trade-off)."""
        cells = self.n_tiles * self.tile_size * self.tile_size
        return self.nnz() / max(cells, 1)

    def tile_payload_bytes(self) -> int:
        """Bytes of stored tile payload alone (the HBM/DMA term the storage
        axis shrinks 8×)."""
        return self.tiles.size * self.tiles.dtype.itemsize

    def memory_bytes(self) -> int:
        """HBM footprint of the tiled representation (payload + indices)."""
        return (
            self.tile_payload_bytes()
            + self.tile_rows.size * 4
            + self.tile_cols.size * 4
            + self.row_starts.size * 4
        )

    def to_storage(self, storage: str) -> "BlockTiledGraph":
        """Convert between tile storage formats (host-side, exact)."""
        if storage not in STORAGES:
            raise ValueError(
                f"unknown storage {storage!r}; valid: {STORAGES}"
            )
        if storage == self.storage:
            return self
        if storage == "bitpack":
            tiles = jnp.asarray(pack_tile_bits(np.asarray(self.tiles)))
        else:
            tiles = jnp.asarray(
                np.asarray(unpack_tile_bits(self.tiles, self.tile_size))
            )
        out = dataclasses.replace(
            self, tiles=tiles, storage=storage, partition=None
        )
        if self.partition is not None:
            # the partition's dense sub-tiling must share the new storage —
            # rebuild it (deterministic, so bit-identical up to format)
            out = dataclasses.replace(
                out, partition=partition_tiles(out, self.partition.threshold)
            )
        return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TilePartition:
    """nnz-classified hybrid routing split of a tiled adjacency (§16).

    Built at plan time by `partition_tiles`: tiles at or above the density
    threshold form a COMPACTED dense sub-tiling (same block grid, same
    storage, own `row_starts` — all dense tile ops run on it unchanged,
    and the sparse/empty tiles vanish from its dispatch entirely); tiles
    below the threshold are lowered to COO edge lists executed through the
    `core/spmv.py` segment ops.  Empty tiles appear in NEITHER list.

    Attributes:
      dense:     compacted `BlockTiledGraph` over the dense tile set
                 (its own `partition` is always None).
      sp_rows:   (sp_pad,) int32 — GLOBAL padded output-vertex id per
                 sparse nnz (tile row axis: the SpMV scatter target).
      sp_cols:   (sp_pad,) int32 — GLOBAL padded input-vertex id per
                 sparse nnz (tile column axis: the gather source).
                 Both padded to a power of two with the sentinel id
                 `n_padded`; segment consumers use `num_segments =
                 n_padded + 1` and slice the sentinel row off, exactly
                 like the Graph sentinel-edge convention.
      threshold: static — nnz cut: dense iff nnz >= threshold.
      n_dense_tiles / n_sparse_tiles: static — real tiles per class.
      sp_nnz:    static — real (unpadded) sparse-tail edge count.
    """
    dense: BlockTiledGraph
    sp_rows: jnp.ndarray
    sp_cols: jnp.ndarray
    threshold: int = dataclasses.field(metadata=dict(static=True))
    n_dense_tiles: int = dataclasses.field(metadata=dict(static=True))
    n_sparse_tiles: int = dataclasses.field(metadata=dict(static=True))
    sp_nnz: int = dataclasses.field(metadata=dict(static=True))


def tile_nnz(tiled: BlockTiledGraph) -> np.ndarray:
    """Per-tile nnz over the stored tile list, computed ON DEVICE — one
    (n_tiles_pad,) int32 transfer (bitpack counts bits via popcount;
    padding tiles are all-zero so their entries read 0)."""
    t = tiled.tiles
    if tiled.storage == "bitpack":
        counts = jnp.sum(
            jax.lax.population_count(t).astype(jnp.int32),
            axis=(1, 2), dtype=jnp.int32,
        )
    else:
        counts = jnp.sum(
            (t != 0).astype(jnp.int32), axis=(1, 2), dtype=jnp.int32
        )
    return np.asarray(counts)


def _host_unpack_tile_bits(packed: np.ndarray, tile_size: int) -> np.ndarray:
    """Host-side (numpy) inverse of `pack_tile_bits` for the plan-time
    partition build — no device round-trip, no jit trace."""
    shifts = np.arange(_BITS, dtype=np.uint32)
    bits = (packed[..., None] >> shifts) & np.uint32(1)
    full = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * _BITS,))
    return (full[..., : int(tile_size)] != 0).astype(np.int8)


def partition_tiles(
    tiled: BlockTiledGraph,
    threshold: int,
    *,
    nnz: np.ndarray | None = None,
) -> TilePartition:
    """Classify tiles by nnz and build the hybrid split (host-side, numpy).

    Deterministic in (tiles, threshold): rebuilding after a delta or a
    storage conversion yields bit-identical partitions, which keeps the
    dyngraph rebuild oracle exact.  Dense tiles keep their row-major order
    so the compacted CSR stays kernel-legal; the sparse tail needs no
    ordering (segment ops scatter by id).
    """
    T = tiled.tile_size
    thr = int(threshold)
    if nnz is None:
        nnz = tile_nnz(tiled)
    real = np.asarray(nnz)[: tiled.n_tiles]
    dense_idx = np.nonzero(real >= thr)[0]
    sparse_idx = np.nonzero((real > 0) & (real < thr))[0]

    tiles_h = np.asarray(tiled.tiles)
    rows_h = np.asarray(tiled.tile_rows)
    cols_h = np.asarray(tiled.tile_cols)

    # -- dense subset: gather, recompute CSR, re-pad (empty tiles vanish) --
    n_dense = int(dense_idx.shape[0])
    d_tiles = tiles_h[dense_idx]
    d_rows = rows_h[dense_idx].astype(np.int32)
    d_cols = cols_h[dense_idx].astype(np.int32)
    counts = np.bincount(
        d_rows if n_dense else np.zeros(0, np.int64),
        minlength=tiled.n_block_rows,
    )
    row_starts = np.zeros(tiled.n_block_rows + 1, dtype=np.int32)
    np.cumsum(counts, out=row_starts[1:])
    target = padded_tile_count(n_dense)
    if target > n_dense:
        last_row = d_rows[-1] if n_dense else np.int32(0)
        pad_shape = (target - n_dense,) + tiles_h.shape[1:]
        d_tiles = np.concatenate(
            [d_tiles, np.zeros(pad_shape, tiles_h.dtype)], axis=0
        )
        d_rows = np.concatenate(
            [d_rows, np.full(target - n_dense, last_row, np.int32)]
        )
        d_cols = np.concatenate(
            [d_cols, np.zeros(target - n_dense, np.int32)]
        )
    dense = BlockTiledGraph(
        tiles=jnp.asarray(d_tiles),
        tile_rows=jnp.asarray(d_rows),
        tile_cols=jnp.asarray(d_cols),
        row_starts=jnp.asarray(row_starts),
        n_tiles=n_dense,
        n_nodes=tiled.n_nodes,
        tile_size=T,
        n_block_rows=tiled.n_block_rows,
        n_block_cols=tiled.n_block_cols,
        storage=tiled.storage,
    )

    # -- sparse tail: COO in GLOBAL padded vertex ids, sentinel-padded --
    sp_sub = tiles_h[sparse_idx]
    if tiled.storage == "bitpack":
        sp_sub = _host_unpack_tile_bits(sp_sub, T)
    t_i, r_i, c_i = np.nonzero(sp_sub)
    v = rows_h[sparse_idx][t_i].astype(np.int64) * T + r_i
    u = cols_h[sparse_idx][t_i].astype(np.int64) * T + c_i
    sp_nnz = int(v.shape[0])
    cap = next_pow2(max(sp_nnz, 8))
    sentinel = np.int32(tiled.n_padded)
    sp_rows = np.full(cap, sentinel, np.int32)
    sp_cols = np.full(cap, sentinel, np.int32)
    sp_rows[:sp_nnz] = v.astype(np.int32)
    sp_cols[:sp_nnz] = u.astype(np.int32)

    return TilePartition(
        dense=dense,
        sp_rows=jnp.asarray(sp_rows),
        sp_cols=jnp.asarray(sp_cols),
        threshold=thr,
        n_dense_tiles=n_dense,
        n_sparse_tiles=int(sparse_idx.shape[0]),
        sp_nnz=sp_nnz,
    )


def attach_partition(
    tiled: BlockTiledGraph,
    mode: str = "auto",
    threshold: int | None = None,
) -> BlockTiledGraph:
    """Hybrid-routing policy front door (the knob behind
    `SolveOptions.hybrid`): returns `tiled` with a partition attached,
    or partition-free when the policy says the split won't pay.

      off     never partition (drop any stale one).
      forced  always partition (tests force tiny graphs through hybrid).
      auto    partition iff there are ≥ HYBRID_AUTO_MIN_TILES non-empty
              tiles AND the sub-threshold tail is ≥
              HYBRID_AUTO_MIN_SPARSE_FRAC of them.

    `threshold` defaults to the roofline break-even
    (`repro.perf.hybrid_density_threshold`).
    """
    if mode == "off":
        if tiled.partition is None:
            return tiled
        return dataclasses.replace(tiled, partition=None)
    if mode not in ("auto", "forced"):
        raise ValueError(f"unknown hybrid mode {mode!r}; valid: auto|off|forced")
    if threshold is None:
        from repro.perf.roofline import hybrid_density_threshold

        threshold = hybrid_density_threshold(tiled.tile_size, tiled.storage)
    thr = int(threshold)
    nnz = tile_nnz(tiled)
    real = nnz[: tiled.n_tiles]
    nonempty = int(np.count_nonzero(real))
    n_sparse = int(np.count_nonzero((real > 0) & (real < thr)))
    if mode == "auto" and (
        nonempty < HYBRID_AUTO_MIN_TILES
        or n_sparse == 0
        or n_sparse < HYBRID_AUTO_MIN_SPARSE_FRAC * nonempty
    ):
        if tiled.partition is None:
            return tiled
        return dataclasses.replace(tiled, partition=None)
    part = partition_tiles(tiled, thr, nnz=nnz)
    return dataclasses.replace(tiled, partition=part)


def rcm_ordering(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee vertex permutation (beyond-paper, DESIGN.md §6).

    Locality reordering concentrates edges near the diagonal, raising
    intra-tile density and cutting the non-empty tile count — the lever that
    makes 128×128 MXU tiles viable on graphs the paper would tile at 16×16.
    Returns perm such that new_id = perm_inv[old_id].
    """
    from scipy.sparse import coo_matrix
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    s = np.asarray(g.senders)[: g.n_edges]
    r = np.asarray(g.receivers)[: g.n_edges]
    adj = coo_matrix(
        (np.ones(len(s), np.int8), (s, r)), shape=(g.n_nodes, g.n_nodes)
    ).tocsr()
    return np.asarray(reverse_cuthill_mckee(adj, symmetric_mode=True))


def build_block_tiles(
    g: Graph,
    tile_size: int = 128,
    *,
    pad_tiles_to: int | None = None,
    reorder: str | None = None,   # None | 'rcm'
    storage: str = "int8",        # 'int8' | 'bitpack'
) -> BlockTiledGraph:
    """Tile ``g``'s adjacency matrix (host-side, numpy).

    Steps (mirrors the paper's Listing 1 preprocessing):
      1. (optional) RCM locality reordering — beyond-paper, see rcm_ordering,
      2. map each half-edge (u, v) to tile key (u//T, v//T),
      3. unique keys, sorted row-major → tile index per edge,
      4. scatter edges into dense tiles,
      5. pad the tile list so shapes are static/shardable,
      6. (storage='bitpack') pack each tile's columns into uint32 words.

    NOTE with reorder='rcm' the returned tiling indexes PERMUTED vertex ids;
    callers must map priorities/results through the same permutation (the
    MIS solution set is permutation-equivariant, so validity is unaffected —
    tests/test_tiling.py::test_rcm_mis_roundtrip).
    """
    T = int(tile_size)
    if T < 8 or (T & (T - 1)):
        raise ValueError(f"tile_size must be a power of two >= 8, got {T}")
    if storage not in STORAGES:
        raise ValueError(f"unknown storage {storage!r}; valid: {STORAGES}")
    s = np.asarray(g.senders)[: g.n_edges].astype(np.int64)
    r = np.asarray(g.receivers)[: g.n_edges].astype(np.int64)
    if reorder == "rcm":
        perm = rcm_ordering(g)                 # perm[new_id] = old_id
        inv = np.empty_like(perm)
        inv[perm] = np.arange(g.n_nodes)
        s, r = inv[s], inv[r]
        order = np.lexsort((r, s))
        s, r = s[order], r[order]
    nb = -(-g.n_nodes // T)  # ceil
    tr, tc = s // T, r // T
    key = tr * nb + tc
    uniq, inv = np.unique(key, return_inverse=True)
    n_tiles = int(uniq.shape[0])

    tiles = np.zeros((max(n_tiles, 1), T, T), dtype=np.int8)
    tiles[inv, s % T, r % T] = 1
    tile_rows = (uniq // nb).astype(np.int32)
    tile_cols = (uniq % nb).astype(np.int32)
    if n_tiles == 0:
        tile_rows = np.zeros(1, dtype=np.int32)
        tile_cols = np.zeros(1, dtype=np.int32)
        n_tiles = 0

    # row_starts: CSR over block-rows (tiles are already row-major sorted)
    counts = np.bincount(tile_rows[: max(n_tiles, 1)] if n_tiles else [], minlength=nb)
    row_starts = np.zeros(nb + 1, dtype=np.int32)
    np.cumsum(counts, out=row_starts[1:])

    # pad: zero tiles pinned to the last real block-row (monotone, no-op adds)
    stored = tiles.shape[0]
    target = padded_tile_count(n_tiles, pad_tiles_to)
    if target > stored:
        last_row = tile_rows[-1] if n_tiles else 0
        tiles = np.concatenate(
            [tiles, np.zeros((target - stored, T, T), dtype=np.int8)], axis=0
        )
        tile_rows = np.concatenate(
            [tile_rows, np.full(target - stored, last_row, dtype=np.int32)]
        )
        tile_cols = np.concatenate(
            [tile_cols, np.zeros(target - stored, dtype=np.int32)]
        )

    if storage == "bitpack":
        tiles = pack_tile_bits(tiles)
    return BlockTiledGraph(
        tiles=jnp.asarray(tiles),
        tile_rows=jnp.asarray(tile_rows),
        tile_cols=jnp.asarray(tile_cols),
        row_starts=jnp.asarray(row_starts),
        n_tiles=n_tiles,
        n_nodes=g.n_nodes,
        tile_size=T,
        n_block_rows=int(nb),
        n_block_cols=int(nb),
        storage=storage,
    )


def pack_vertex_vector(x: jnp.ndarray, tiled: BlockTiledGraph) -> jnp.ndarray:
    """(n_nodes,) -> (n_padded,) zero-padded to whole tiles."""
    pad = tiled.n_padded - x.shape[0]
    return jnp.pad(x, (0, pad)) if pad else x


def unpack_vertex_vector(x: jnp.ndarray, tiled: BlockTiledGraph) -> jnp.ndarray:
    return x[: tiled.n_nodes]


# --------------------------------------------------------------------------
# bit-packed frontier vectors (DESIGN.md §13) — THE single site of the
# frontier packing contract.  `cand`/`alive`/`in_mis` ride the bitwise round
# body as (n_block_cols, W) uint32 words; `core.distributed` packs its
# all-gather frontiers through the same helpers.  Unpacking a frontier is
# restricted to kernel bodies / oracles / this module by tools/ci_guards.py.
# --------------------------------------------------------------------------

def pack_frontier_bits(bits: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """(..., T) truthy -> (..., W) uint32, bit j of word w = slot 32·w + j.

    The SAME bit layout as `pack_tile_bits` (so a packed tile row ANDs
    directly against a packed frontier word), but jit- and kernel-safe:
    `broadcasted_iota` only, no host numpy — the kernels use it to emit
    packed result bits and the engine uses it on candidate masks each round.
    """
    T = int(tile_size)
    W = packed_words(T)
    shape = bits.shape[:-1] + (W, T)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
    w = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 2)
    weight = jnp.where(
        (c >> 5) == w, jnp.uint32(1) << (c & jnp.uint32(31)), jnp.uint32(0)
    )
    vals = jnp.where(bits[..., None, :] != 0, weight, jnp.uint32(0))
    # disjoint bit positions ⇒ the OR-reduce is an overflow-free sum
    return jnp.sum(vals, axis=-1, dtype=jnp.uint32)


def unpack_frontier_bits(words: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """(..., W) uint32 -> (..., T) bool — inverse of `pack_frontier_bits`.

    A frontier DENSIFY: allowed only inside `*_kernel` bodies, `kernels/
    ref.py`, `*_oracle` functions, the extraction/collective sites named in
    tools/ci_guards.py, and this module (the packing substrate itself)."""
    T = int(tile_size)
    W = words.shape[-1]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, words.shape + (_BITS,), len(words.shape)
    )
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (W * _BITS,))[..., :T] != 0


def pack_frontier_words(x: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """(n_blocks·T,) truthy vertex vector -> (n_blocks, W) uint32 words."""
    return pack_frontier_bits(x.reshape(-1, int(tile_size)), tile_size)


def unpack_frontier_words(words: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """(n_blocks, W) uint32 -> (n_blocks·T,) bool (same guard as
    `unpack_frontier_bits` — this is the extraction-time densify)."""
    return unpack_frontier_bits(words, tile_size).reshape(-1)


def gather_frontier_bits(
    words: jnp.ndarray, ids: jnp.ndarray, tile_size: int
) -> jnp.ndarray:
    """Per-id bit extraction from standard-layout frontier words: for each
    GLOBAL padded vertex id, the bool at its (block, word, bit) slot.

    The hybrid sparse tail reads single frontier bits at its COO gather
    sites; this is a shift-and-mask per id — NOT a frontier densify, so it
    stays legal on hot paths (and lives here, in the packing substrate,
    like every other consumer of the bit layout).  Sentinel ids (= the
    padded vertex count) land out of range and clamp under jnp gather
    semantics; hybrid callers pair them with sentinel scatter rows, so the
    clamped garbage is always dropped.
    """
    T = int(tile_size)
    ids = ids.astype(jnp.int32)
    slot = ids % T
    word = words[ids // T, slot // _BITS]
    return ((word >> (slot % _BITS).astype(jnp.uint32)) & jnp.uint32(1)) != 0


# -- priority-sorted bit order (the bitwise neighbour-max substrate) --------
#
# The bitwise Max_Np is a priority-plane scan collapsed to one pass: sort
# each block-column's slots by descending priority ONCE per solve, pack the
# tiles in that slot order with the MSB-first layout below, and per round the
# scan "iterate planes high→low, AND, fold" degenerates to "index of the
# first set bit" — one AND + count-leading-zeros per word (DESIGN.md §13).

def sort_block_priorities(
    p: jnp.ndarray, tile_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(n_blocks·T,) int32 -> (order, p_sorted), both (n_blocks, T).

    `order[b, s]` is the in-block column index occupying descending-priority
    slot `s` of block `b`; `p_sorted` the priorities in slot order.  Exact
    for ANY int32 priorities (negative resolve keys included) — the sort
    carries the values, no bit-plane sign handling needed."""
    blocks = p.reshape(-1, int(tile_size))
    order = jnp.argsort(-blocks, axis=1).astype(jnp.int32)
    return order, jnp.take_along_axis(blocks, order, axis=1)


def pack_sorted_frontier_bits(
    bits_sorted: jnp.ndarray, tile_size: int
) -> jnp.ndarray:
    """(..., T) truthy in sorted-slot order -> (..., W) uint32 with slot s at
    bit 31 − (s mod 32) of word s // 32 — MSB-first, so `clz(word)` IS the
    first occupied slot within the word."""
    T = int(tile_size)
    W = packed_words(T)
    shape = bits_sorted.shape[:-1] + (W, T)
    s = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
    w = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 2)
    weight = jnp.where(
        (s >> 5) == w,
        jnp.uint32(1) << (jnp.uint32(31) - (s & jnp.uint32(31))),
        jnp.uint32(0),
    )
    vals = jnp.where(bits_sorted[..., None, :] != 0, weight, jnp.uint32(0))
    return jnp.sum(vals, axis=-1, dtype=jnp.uint32)


def sorted_tile_bits(
    tiles: jnp.ndarray,
    tile_cols: jnp.ndarray,
    order: jnp.ndarray,
    tile_size: int,
) -> jnp.ndarray:
    """Tiles (either storage) column-permuted into each block-column's
    priority-slot order and packed MSB-first: (nt, T, W) uint32.

    Setup-time, once per solve (the order is static for a run's priorities);
    the transient dense mask lives only inside this jit scope."""
    mask = dense_tile_mask(tiles, tile_size)                 # (nt, T, T)
    g_order = order[tile_cols]                               # (nt, T)
    permuted = jnp.take_along_axis(mask, g_order[:, None, :], axis=2)
    return pack_sorted_frontier_bits(permuted, tile_size)


def sorted_frontier_words(
    words: jnp.ndarray, order: jnp.ndarray, tile_size: int
) -> jnp.ndarray:
    """Standard-layout frontier words -> sorted-slot words, per block column.

    The per-round word remap feeding the clz scan: an O(n/32 → n) bit
    permutation (lane shuffles on TPU, ~1/10 the cost of the scan itself).
    The bit-level round-trip lives HERE, in the packing substrate — hot-path
    modules never touch frontier bits (tools/ci_guards.py)."""
    bits = unpack_frontier_bits(words, tile_size)            # (nbc, T)
    bits_sorted = jnp.take_along_axis(bits, order, axis=1)
    return pack_sorted_frontier_bits(bits_sorted, tile_size)


def pack_priority_planes(
    p: jnp.ndarray, tile_size: int, n_bits: int, *, signed: bool = False
) -> jnp.ndarray:
    """(n_blocks·T,) int32 -> (n_bits, n_blocks, W) uint32 bit-planes in the
    STANDARD frontier layout — the Pallas plane-scan kernel's input
    (`kernels.tc_neighbor_max`).  `signed` applies the order-preserving
    bias (bitcast ^ 0x80000000) so two's-complement keys scan correctly;
    the kernel un-biases on output."""
    u = jax.lax.bitcast_convert_type(p.astype(jnp.int32), jnp.uint32)
    if signed:
        u = u ^ jnp.uint32(0x80000000)
    blocks = u.reshape(-1, int(tile_size))
    planes = [
        pack_frontier_bits((blocks >> b) & jnp.uint32(1), tile_size)
        for b in range(int(n_bits))
    ]
    return jnp.stack(planes)


def tile_stats(tiled: BlockTiledGraph) -> dict:
    """Stats for the memory-footprint benchmark (paper §3.2) and the hybrid
    classifier (§16).

    Per-tile nnz is computed on device (`tile_nnz` popcount) — ONE
    (n_tiles_pad,) transfer; the aggregate nnz and the histogram derive
    from it on host, so adding the distribution cost no extra traffic
    (the old aggregate-only scalar pull is gone)."""
    per_tile = tile_nnz(tiled)[: tiled.n_tiles]
    nnz = int(per_tile.sum())
    cells = tiled.n_tiles * tiled.tile_size * tiled.tile_size
    total_blocks = tiled.n_block_rows * tiled.n_block_cols
    # power-of-two-bucketed nnz histogram: bucket `u` counts stored tiles
    # with nnz in (u/2, u]; bucket 0 would be empty tiles (never stored by
    # the builder, but deltas can drain a tile in place).
    cap = tiled.tile_size * tiled.tile_size
    hist = {0: int(np.count_nonzero(per_tile == 0))}
    upper = 1
    while True:
        hist[upper] = int(
            np.count_nonzero((per_tile > upper // 2) & (per_tile <= upper))
        )
        if upper >= cap:
            break
        upper *= 2
    return dict(
        tile_size=tiled.tile_size,
        n_tiles=tiled.n_tiles,
        storage=tiled.storage,
        block_grid=total_blocks,
        block_occupancy=tiled.n_tiles / max(total_blocks, 1),
        intra_tile_density=nnz / max(cells, 1),
        tile_nnz=per_tile.tolist(),
        nnz_hist=hist,
        tile_payload_bytes=tiled.tile_payload_bytes(),
        bsr_bytes=tiled.memory_bytes(),
        csr_bytes=8 * nnz + 4 * (tiled.n_nodes + 1),  # int32 idx + int64-ish ptr
    )

"""The round-engine layer: one backend interface for every TC-MIS phase.

Every execution path of the system — the paper-faithful CC baseline, the jnp
tile oracle, the Pallas SpMV kernel, and the fused phase-②+③ kernel — is a
`RoundEngine`: an object that knows how to run one MIS round (DESIGN.md §4).
The driver (`core.tc_mis`) is engine-agnostic; it owns only the convergence
loop.  Benchmarks, examples and future backends (GPU Pallas) select engines
from the registry instead of hard-coding call sites — kernel selection is a
pluggable policy over one tiled schedule, the way BLEST/HC-SpMM treat their
kernel zoos.  (Bit-packed masks — once a forward reference here — are now a
first-class STORAGE axis, not a backend: every engine runs either tile
format, see DESIGN.md §11 and `core.tiling.STORAGES`.)

Registered engines:

  segment       gather/segment ops over the edge list (ECL-MIS analogue);
                the paper's CUDA-core baseline substrate.
  tiled_ref     pure-jnp BSR tile schedule — the oracle every kernel is
                validated against.
  tiled_pallas  phase ② on the Pallas SpMV kernel (MXU on TPU), phase ① per
                `cfg.phase1` (segment, or the beyond-paper tiled max kernel).
  fused_pallas  the fast path: phase ②+③ in ONE kernel pass — N_c never
                round-trips through HBM (DESIGN.md §6.3).

Per-round metadata: tiled engines compute **active block-column flags** from
the candidate vector each round (`block_col_flags`) so the kernels' empty-C
tile skip — `@pl.when` on the MXU op, and the `skip_dma` HBM-read skip — is
exercised live, not just in unit tests.  Skipping is exact: a tile whose
candidate slab is all-zero contributes exactly zero to N_c (lane 0).  Lanes
≥ 1 of a skipped column are dropped too, so the jnp oracle emulates the skip
by zeroing gated slabs — ref and kernel agree on ALL lanes.

This module also owns the raw-array tile operators (`tile_spmv`,
`tile_neighbor_max`) shared by `core.spmv` (padded-vector forms) and
`core.distributed` (shard-local slabs inside `shard_map`).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import (
    BlockTiledGraph,
    dense_tile_mask,
    gather_frontier_bits,
    pack_frontier_bits,
    pack_frontier_words,
    pack_priority_planes,
    pack_vertex_vector,
    sort_block_priorities,
    sorted_frontier_words,
    sorted_tile_bits,
    tiles_as_words,
)
from repro.graphs.graph import Graph

# Round-telemetry buffer columns (DESIGN.md §14).  obs.rounds is the owner
# of the layout and is deliberately numpy-only, so this import cannot cycle
# back into core.
from repro.obs.rounds import (
    COL_ALIVE,
    COL_FRONTIER,
    COL_SELECTED,
    COL_TILES_DENSE,
    COL_TILES_SKIPPED,
    COL_TILES_SPARSE,
    TELEMETRY_COLS,
)

_NEG = np.int32(-(1 << 30))  # numpy scalar: safe to create at import time under a trace


# --------------------------------------------------------------------------
# raw-array tile operators (shared: core.spmv, core.distributed, engines)
# --------------------------------------------------------------------------

def tile_spmv(
    tiles: jnp.ndarray,          # (nt, T, T) int8 | (nt, T, W) uint32 packed
    tile_rows: jnp.ndarray,      # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,      # (nt,) int32
    rhs: jnp.ndarray,            # (nbc*T, L) float
    n_block_rows: int,
    tile_size: int,
    *,
    col_flags: jnp.ndarray | None = None,   # (nbc,) int32; None = all active
) -> jnp.ndarray:
    """N = A @ rhs over BSR tiles, pure jnp (the Pallas kernels' oracle).

    With `col_flags`, gated RHS slabs are zeroed before the contraction —
    the exact semantics of the kernel's `@pl.when` tile skip (a skipped tile
    contributes nothing on any lane).  Returns (n_block_rows*T, L) float32.
    """
    T = tile_size
    tiles = dense_tile_mask(tiles, T)        # bool mask, no int8 intermediate
    blocks = rhs.reshape(-1, T, rhs.shape[-1])
    gathered = blocks[tile_cols]                             # (nt, T, L)
    if col_flags is not None:
        gathered = gathered * col_flags[tile_cols][:, None, None].astype(
            gathered.dtype
        )
    prod = jnp.einsum(
        "ijk,ikl->ijl", tiles.astype(jnp.float32), gathered.astype(jnp.float32)
    )
    out = jax.ops.segment_sum(prod, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T, rhs.shape[-1])


def tile_neighbor_max(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    pm: jnp.ndarray,             # (nbc*T,) pre-masked priorities (_NEG = dead)
    n_block_rows: int,
    tile_size: int,
) -> jnp.ndarray:
    """Max_Np over the same BSR schedule (VPU work — max has no MXU form).

    Storage dispatch goes through `dense_tile_mask`, not `dense_tiles`: the
    packed form bit-extracts straight to the bool mask the `where` needs,
    skipping the int8 materialisation that made bitpack LOSE to int8 here
    (733 vs 673 µs at T=64 in the pre-fix BENCH_core.json)."""
    T = tile_size
    mask = dense_tile_mask(tiles, T)
    gathered = pm.reshape(-1, T)[tile_cols]                  # (nt, T)
    # tile (T,T) row v, col u: edge v->u.  masked max over columns.
    vals = jnp.where(mask, gathered[:, None, :], _NEG)       # (nt, T, T)
    tile_max = vals.max(axis=2)                              # (nt, T)
    out = jax.ops.segment_max(tile_max, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T)


def block_col_flags(x: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """Per-block-column activity: (nbc*T,) vector -> (nbc,) int32 0/1 flags.

    The per-round metadata of the engine layer: a block-column is active iff
    any vertex in it carries a nonzero entry (the paper's empty-C test)."""
    return x.reshape(-1, tile_size).astype(bool).any(axis=1).astype(jnp.int32)


# --------------------------------------------------------------------------
# bitwise raw tile operators (DESIGN.md §13) — the packed-frontier round
# body's substrate.  Frontiers are (n_block_cols, W) uint32 words; nothing
# here densifies a frontier (tools/ci_guards.py).
# --------------------------------------------------------------------------

def tile_spmv_bits(
    tiles_bits: jnp.ndarray,     # (nt, T, W) uint32, standard bit layout
    tile_rows: jnp.ndarray,      # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,      # (nt,) int32
    rhs_words: jnp.ndarray,      # (nbc, W) uint32 — packed candidate vector
    n_block_rows: int,
    tile_size: int,
    *,
    col_flags: jnp.ndarray | None = None,   # (nbc,) int32; None = all active
) -> jnp.ndarray:
    """② as pure word arithmetic: row v is hit iff popcount(tile_row_word &
    cand_word) ≠ 0 for any word — `(a & c) != 0` per word, OR over words.
    No f32 accumulator, no densify; returns (n_block_rows, W) packed hit
    words.  Exactly `tile_spmv(...)[:, 0] > 0` (the paper's N_c > 0 test —
    counts beyond 0/1 are only needed by lanes the pure-MIS round drops).

    `col_flags` zeroes gated candidate words before the AND — the same
    empty-C skip semantics as the dense path (a skipped column contributes
    no hits)."""
    gathered = rhs_words[tile_cols]                          # (nt, W)
    if col_flags is not None:
        gathered = gathered * col_flags[tile_cols][:, None].astype(jnp.uint32)
    hit = jnp.any((tiles_bits & gathered[:, None, :]) != 0, axis=2)  # (nt, T)
    acc = jax.ops.segment_max(
        hit.astype(jnp.uint32), tile_rows, num_segments=n_block_rows
    )
    return pack_frontier_bits(acc, tile_size)                # (nbr, W)


def tile_neighbor_max_bits(
    tiles_sorted: jnp.ndarray,       # (nt, T, W) uint32, MSB-first slot order
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    p_sorted: jnp.ndarray,           # (nbc, T) int32, descending per block
    mask_sorted_words: jnp.ndarray,  # (nbc, W) uint32, sorted-slot layout
    n_block_rows: int,
    tile_size: int,
) -> jnp.ndarray:
    """① Max_Np over packed words: the priority-plane scan collapsed to one
    pass.  With each block-column's slots pre-sorted by descending priority
    (`sort_block_priorities` / `sorted_tile_bits`, once per solve), "iterate
    planes high→low, AND against the mask, fold" degenerates to "first set
    slot of (tile_row & mask)" — one AND + count-leading-zeros per word,
    then a gather from `p_sorted`.  Exact for any int32 priorities (the
    sort carries signed values; no bit-plane sign bias needed).  Returns
    (n_block_rows·T,) int32 values, `_NEG`-floored like the dense op."""
    T = int(tile_size)
    W = tiles_sorted.shape[-1]
    m = tiles_sorted & mask_sorted_words[tile_cols][:, None, :]   # (nt, T, W)
    first = jnp.full(m.shape[:2], jnp.int32(T), jnp.int32)        # T = none
    for w in range(W):
        word = m[..., w]
        pw = jnp.where(
            word != 0,
            jnp.int32(w * 32) + jax.lax.clz(word).astype(jnp.int32),
            jnp.int32(T),
        )
        first = jnp.minimum(first, pw)
    ps_g = p_sorted[tile_cols]                                    # (nt, T)
    idx = jnp.minimum(first, jnp.int32(T - 1))
    val = jnp.take_along_axis(ps_g, idx, axis=1)
    tile_max = jnp.where(first < T, val, jnp.int32(_NEG))
    out = jax.ops.segment_max(tile_max, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T)


class SortedPriorityTiles(NamedTuple):
    """Per-priority-key setup artefact for the bitwise phase ①: the static
    block-column sort of one priority vector plus the adjacency re-packed in
    that slot order (built once per solve by `make_bitwise_context`)."""
    order: jnp.ndarray      # (nbc, T) int32 — descending-priority column order
    p_sorted: jnp.ndarray   # (nbc, T) int32 — priorities in slot order
    tiles: jnp.ndarray      # (nt, T, W) uint32 — MSB-first sorted-slot layout


class BitwiseContext(NamedTuple):
    """Everything the packed-frontier round body precomputes per solve.

    `tiles_bits` is the adjacency in standard word layout (phase ②);
    `select`/`resolve` carry the sorted-priority structures for the clz
    formulation of phase ①; `*_planes` are the explicit bit-plane stacks
    ((n_bits, nbc, W)) the Pallas plane-scan kernel consumes — built only
    when an engine asks for them (TPU runs), None otherwise."""
    tiles_bits: jnp.ndarray
    select: SortedPriorityTiles
    resolve: Optional[SortedPriorityTiles]
    select_planes: Optional[jnp.ndarray]
    resolve_planes: Optional[jnp.ndarray]


# H3 select keys are (q << 23) ≥ 0 with q ≤ 255 → 31 bits suffice; resolve
# keys are negative (-deg·n - id) → full 32 signed planes.
_SELECT_PLANE_BITS = 31
_RESOLVE_PLANE_BITS = 32


def make_bitwise_context(
    tiled: BlockTiledGraph, pri, *, planes: bool = False
) -> BitwiseContext:
    """Build the per-solve bitwise structures from static priorities.

    Priorities are fixed for the whole solve (only the alive/pending masks
    change per round), so the argsort, the column-permuted adjacency repack
    and the optional plane stacks are all one-time setup cost."""
    T = tiled.tile_size
    tiles_bits = tiles_as_words(tiled.tiles, T)

    def _sorted_for(p):
        order, p_sorted = sort_block_priorities(p, T)
        tiles_sorted = sorted_tile_bits(tiled.tiles, tiled.tile_cols, order, T)
        return SortedPriorityTiles(order, p_sorted, tiles_sorted)

    select = _sorted_for(pri.select)
    resolve = _sorted_for(pri.resolve) if pri.resolve is not None else None
    select_planes = resolve_planes = None
    if planes:
        select_planes = pack_priority_planes(
            pri.select, T, _SELECT_PLANE_BITS, signed=False
        )
        if pri.resolve is not None:
            resolve_planes = pack_priority_planes(
                pri.resolve, T, _RESOLVE_PLANE_BITS, signed=True
            )
    return BitwiseContext(tiles_bits, select, resolve, select_planes, resolve_planes)


FRONTIERS = ("auto", "dense", "bitwise")


def resolve_frontier(config, engine, *, storage: str, member_rounds: bool = False) -> str:
    """Resolve `SolveOptions.frontier` to the concrete mode a run uses.

    "auto" picks bitwise exactly when it is the fastest sound choice: a
    tile-schedule engine (`supports_bitwise`), the tiled phase ① (the
    segment phase ① would densify every round to reach the edge list),
    bitpack storage (word-AND needs word tiles), and a scalar round counter
    (per-member round vectors — the batched serving mode — need per-vertex
    alive increments the packed state does not expose).  An explicit
    "bitwise" on an engine that cannot honour it falls back to dense rather
    than erroring — mode is a performance knob, never a semantics knob."""
    mode = getattr(config, "frontier", "auto") or "auto"
    if mode == "auto":
        if (
            engine.supports_bitwise
            and not member_rounds
            and getattr(config, "phase1", "tiled") == "tiled"
            and storage == "bitpack"
        ):
            return "bitwise"
        return "dense"
    if mode == "bitwise" and (not engine.supports_bitwise or member_rounds):
        return "dense"
    return mode


# --------------------------------------------------------------------------
# engine state + context
# --------------------------------------------------------------------------

class MISRoundState(NamedTuple):
    """Per-round algorithm state; `alive`/`in_mis` are (n_padded,).

    `rnd` is polymorphic: a scalar int32 counts rounds globally (the classic
    single-graph run), while an (n_padded,) int32 vector — the batched
    serving mode — advances per vertex only while that vertex is alive, so
    `rnd[v]` converges to v's settle round and a packed member's OWN round
    count is the max over its slot (`round_increment`).  A member that
    converges early stops counting even though the batch keeps looping.
    """
    alive: jnp.ndarray    # bool
    in_mis: jnp.ndarray   # bool
    rnd: jnp.ndarray      # int32 — () global, or (n_padded,) per-vertex


@dataclasses.dataclass(frozen=True)
class EngineContext:
    """Immutable per-run bundle an engine closes over: the graph in both
    representations plus the run config (lanes, phase1 policy, skip_dma).

    `col_gate` is the batch-aware extension of the per-round flags: a static
    (n_block_cols,) 0/1 vector ANDed into every round's `col_flags`.  The
    block-diagonal batcher (`repro.serve_mis.batcher`) sets it to the
    real-vertex occupancy of each block column, so a padded bucket's empty
    trailing slots are pinned inactive from round 0 — the empty-C skip never
    depends on the candidate vector reaching those slots first.  `None`
    (single-graph runs) means "all columns may carry candidates".

    `frontier` is the RESOLVED mode ("dense" | "bitwise", never "auto" —
    see `resolve_frontier`); when bitwise, `bits` holds the per-solve packed
    structures and `MISRoundState.alive`/`in_mis` ride as (nbc, W) uint32
    words through the whole round loop (DESIGN.md §13).
    """
    g: Graph
    tiled: BlockTiledGraph
    cfg: Any   # options bundle: anything with backend/heuristic/lanes/
               # phase1/skip_dma/max_rounds (repro.api.SolveOptions, or the
               # legacy TCMISConfig shim)
    col_gate: Optional[jnp.ndarray] = None
    frontier: str = "dense"
    bits: Optional[BitwiseContext] = None


def round_increment(state: MISRoundState) -> jnp.ndarray:
    """The per-round `rnd` advance matching the state's counting mode.

    Scalar `rnd` ⇒ +1 (the driver's while_loop only runs while something is
    alive).  Vector `rnd` ⇒ +alive, so converged members / vertices stop
    counting — the per-member round-counter contract (MISRoundState)."""
    if getattr(state.rnd, "ndim", 0):
        return state.alive.astype(jnp.int32)
    return jnp.int32(1)


def phase3_update(
    state: MISRoundState,
    cand: jnp.ndarray,
    n_c: jnp.ndarray,
    rnd_inc: Optional[jnp.ndarray] = None,
) -> MISRoundState:
    """③ lock-free own-state update (paper's three rules, verbatim)."""
    return MISRoundState(
        alive=state.alive & ~cand & ~(n_c > 0),
        in_mis=state.in_mis | cand,
        rnd=state.rnd + (round_increment(state) if rnd_inc is None else rnd_inc),
    )


def phase3_update_bits(
    state: MISRoundState,
    cand_words: jnp.ndarray,
    hit_words: jnp.ndarray,
    rnd_inc: Optional[jnp.ndarray] = None,
) -> MISRoundState:
    """③ on packed words — the same three rules, 32 vertices per op.  The
    `N_c > 0` test is already folded into `hit_words` by the popcount SpMV,
    so the update is pure word logic: `alive & ~cand & ~hit`, `in_mis |
    cand`."""
    return MISRoundState(
        alive=state.alive & ~cand_words & ~hit_words,
        in_mis=state.in_mis | cand_words,
        rnd=state.rnd + (round_increment(state) if rnd_inc is None else rnd_inc),
    )


# --------------------------------------------------------------------------
# round telemetry reductions (DESIGN.md §14) — cheap folds over state the
# round body already holds; used only by `step_with_stats`, never by `step`
# --------------------------------------------------------------------------

def _popcount_words(words: jnp.ndarray) -> jnp.ndarray:
    """Σ popcount over a packed (nbc, W) uint32 frontier — scalar int32."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32))


def _count(mask: jnp.ndarray) -> jnp.ndarray:
    """popcount of a dense bool vector — scalar int32."""
    return jnp.sum(mask.astype(jnp.int32))


def _tiles_skipped(ctx: EngineContext, flags: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Tiles gated off this round by the empty-C col_flags skip: every tile
    whose block column carries flag 0.  Engines without flags (segment) skip
    nothing — 0."""
    if flags is None:
        return jnp.int32(0)
    n_tiles = int(ctx.tiled.tile_cols.shape[0])
    return jnp.int32(n_tiles) - jnp.sum(flags[ctx.tiled.tile_cols].astype(jnp.int32))


def _telemetry_row(
    alive, frontier, selected, skipped, tiles_dense, tiles_sparse
) -> jnp.ndarray:
    """(TELEMETRY_COLS,) int32 row in the obs.rounds column layout."""
    vals = [None] * TELEMETRY_COLS
    vals[COL_ALIVE] = alive
    vals[COL_FRONTIER] = frontier
    vals[COL_SELECTED] = selected
    vals[COL_TILES_SKIPPED] = skipped
    vals[COL_TILES_DENSE] = tiles_dense
    vals[COL_TILES_SPARSE] = tiles_sparse
    return jnp.stack([jnp.asarray(v, jnp.int32) for v in vals])


def _tiles_routed_dense(
    ctx: EngineContext, skipped: jnp.ndarray, flags: Optional[jnp.ndarray]
) -> jnp.ndarray:
    """Tiles actually dispatched on the dense path this round: the stored
    list minus the flag-gated ones.  Engines with no tile schedule (flags
    None) route zero tiles."""
    if flags is None:
        return jnp.int32(0)
    return jnp.int32(int(ctx.tiled.tile_cols.shape[0])) - skipped


def _covered_rows(tiled) -> jnp.ndarray:
    """(n_block_rows,) bool — block rows owning at least one stored tile.

    The Pallas kernels write an output block only when a tile's grid step
    visits it (`@pl.when` zero-init on the row transition); a block row no
    tile maps to keeps whatever was in the output buffer.  Full tilings
    cover every row by construction, but the COMPACTED hybrid dense
    partition routinely has rows whose every tile went to the sparse tail —
    their lanes must be masked out before merging with the sparse half."""
    return tiled.row_starts[1:] > tiled.row_starts[:-1]


def _covered_vertices(tiled) -> jnp.ndarray:
    """`_covered_rows` expanded to the (n_padded,) vertex axis."""
    return jnp.repeat(_covered_rows(tiled), tiled.tile_size)


# --------------------------------------------------------------------------
# the engine interface
# --------------------------------------------------------------------------

class RoundEngine:
    """One MIS round as three pluggable pieces.

    Subclasses implement `_nbr_max` (phase ① substrate) and either
    `phase2_counts` (split engines) or `fused_step` (fused engines,
    `fused = True`).  `step` — the single round body every driver uses —
    is shared; `col_flags` is the per-round metadata hook.

    Tile-schedule engines additionally advertise `supports_bitwise` and
    implement the packed-frontier round body (`step_bits` et al., DESIGN.md
    §13): state rides as (nbc, W) uint32 words, phase ② is the popcount
    SpMV, phase ① the sorted-priority clz scan.  `step` dispatches on the
    resolved `ctx.frontier`.
    """

    name: str = "abstract"
    fused: bool = False
    supports_bitwise: bool = False
    # honours a `BlockTiledGraph.partition` (hybrid dense/sparse routing,
    # DESIGN.md §16) — tile-schedule engines only; the segment engine has
    # no tiles to split, so a partition is simply inert there
    supports_hybrid: bool = False
    # wants the (n_bits, nbc, W) plane stacks built at setup — only the
    # Pallas engines, whose bitwise phase ① can run the plane-scan kernel
    plane_kernel_nbr_max: bool = False

    # -- phase ① ----------------------------------------------------------
    def _nbr_max(
        self, ctx: EngineContext, p: jnp.ndarray, mask: jnp.ndarray
    ) -> jnp.ndarray:
        raise NotImplementedError

    def phase1_candidates(
        self, ctx: EngineContext, pri, alive: jnp.ndarray
    ) -> jnp.ndarray:
        """① Max_Np + candidate test (+ H3 pending-set resolution)."""
        max_np = self._nbr_max(ctx, pri.select, alive)
        if pri.resolve is None:
            return alive & (pri.select > max_np)
        # H3: conflicts resolved on the pending set before C is finalised.
        pending = alive & (pri.select >= max_np)
        max_res = self._nbr_max(ctx, pri.resolve, pending)
        return pending & (pri.resolve > max_res)

    # -- per-round metadata -----------------------------------------------
    def col_flags(
        self, ctx: EngineContext, cand: jnp.ndarray, alive: jnp.ndarray
    ) -> Optional[jnp.ndarray]:
        """Active block-column flags for the empty-C tile skip.  Candidates
        drive phase ②'s lane 0, so a column block with no candidate is dead
        weight — flag it off.  Batched runs AND in the static `col_gate`
        (columns of empty bucket slots stay dark in every round).  Segment
        engines have no tiles to skip."""
        flags = block_col_flags(cand, ctx.tiled.tile_size)
        if ctx.col_gate is not None:
            flags = flags * ctx.col_gate.astype(flags.dtype)
        return flags

    # -- phase ② ----------------------------------------------------------
    def _pack_rhs(
        self, ctx: EngineContext, cand: jnp.ndarray, alive: jnp.ndarray
    ) -> jnp.ndarray:
        """Lane-packed RHS: lane 0 = C (the paper's SpMV input), lane 1 =
        alive (live-neighbour counts ride along free on a wide-lane TPU)."""
        rhs = jnp.zeros((ctx.tiled.n_padded, ctx.cfg.lanes), dtype=jnp.float32)
        rhs = rhs.at[:, 0].set(cand.astype(jnp.float32))
        rhs = rhs.at[:, 1].set(alive.astype(jnp.float32))
        return rhs

    def phase2_counts(
        self,
        ctx: EngineContext,
        cand: jnp.ndarray,
        alive: jnp.ndarray,
        col_flags: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """② N_c = A × C.  Returns (n_padded,) float32."""
        raise NotImplementedError(f"{self.name} is a fused engine")

    # -- fused ②+③ --------------------------------------------------------
    def fused_step(
        self,
        ctx: EngineContext,
        cand: jnp.ndarray,
        alive: jnp.ndarray,
        col_flags: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """②+③ in one pass.  Returns (new_alive, mis_add) bool vectors."""
        raise NotImplementedError(f"{self.name} is a split engine")

    # -- bitwise round body (packed-frontier engines only) -----------------
    def step_bits(
        self, ctx: EngineContext, pri, state: MISRoundState
    ) -> MISRoundState:
        raise NotImplementedError(
            f"{self.name} has no packed-frontier round body "
            f"(supports_bitwise={self.supports_bitwise})"
        )

    # -- the round body (shared by tc_mis AND run_phases) ------------------
    def step(
        self, ctx: EngineContext, pri, state: MISRoundState
    ) -> MISRoundState:
        if self.supports_hybrid and ctx.tiled.partition is not None:
            if ctx.frontier == "bitwise":
                return self.step_bits_hybrid(ctx, pri, state)
            return self.step_hybrid(ctx, pri, state)
        if ctx.frontier == "bitwise":
            return self.step_bits(ctx, pri, state)
        cand = self.phase1_candidates(ctx, pri, state.alive)
        flags = self.col_flags(ctx, cand, state.alive)
        inc = round_increment(state)
        if self.fused:
            new_alive, mis_add = self.fused_step(ctx, cand, state.alive, flags)
            return MISRoundState(
                alive=new_alive,
                in_mis=state.in_mis | mis_add,
                rnd=state.rnd + inc,
            )
        n_c = self.phase2_counts(ctx, cand, state.alive, flags)
        return phase3_update(state, cand, n_c, inc)

    # -- the instrumented round body (telemetry runs only) -----------------
    def _step_bits_with_stats(
        self, ctx: EngineContext, pri, state: MISRoundState
    ) -> Tuple[MISRoundState, jnp.ndarray]:
        raise NotImplementedError(
            f"{self.name} has no packed-frontier round body "
            f"(supports_bitwise={self.supports_bitwise})"
        )

    def step_with_stats(
        self, ctx: EngineContext, pri, state: MISRoundState
    ) -> Tuple[MISRoundState, jnp.ndarray]:
        """`step` plus a (TELEMETRY_COLS,) int32 telemetry row — the same
        round body with six extra reductions (no extra SpMVs, no host
        callbacks).  Kept separate from `step` so the telemetry-off program
        is the byte-exact pre-telemetry jaxpr (DESIGN.md §14's zero-cost
        guarantee)."""
        if self.supports_hybrid and ctx.tiled.partition is not None:
            if ctx.frontier == "bitwise":
                return self._step_bits_hybrid_with_stats(ctx, pri, state)
            return self._step_hybrid_with_stats(ctx, pri, state)
        if ctx.frontier == "bitwise":
            return self._step_bits_with_stats(ctx, pri, state)
        alive_count = _count(state.alive)
        cand = self.phase1_candidates(ctx, pri, state.alive)
        flags = self.col_flags(ctx, cand, state.alive)
        inc = round_increment(state)
        if self.fused:
            new_alive, mis_add = self.fused_step(ctx, cand, state.alive, flags)
            new = MISRoundState(
                alive=new_alive,
                in_mis=state.in_mis | mis_add,
                rnd=state.rnd + inc,
            )
        else:
            n_c = self.phase2_counts(ctx, cand, state.alive, flags)
            new = phase3_update(state, cand, n_c, inc)
        skipped = _tiles_skipped(ctx, flags)
        row = _telemetry_row(
            alive_count,
            _count(cand),
            _count(new.in_mis) - _count(state.in_mis),
            skipped,
            _tiles_routed_dense(ctx, skipped, flags),
            jnp.int32(0),
        )
        return new, row


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

ENGINES: Dict[str, RoundEngine] = {}

# legacy TCMISConfig.backend spellings kept working (but deprecated)
_ALIASES = {"ref": "tiled_ref", "pallas": "tiled_pallas", "fused": "fused_pallas"}
_DEPRECATED_SPELLINGS = ("ref", "pallas")


def register_engine(engine: RoundEngine) -> RoundEngine:
    ENGINES[engine.name] = engine
    return engine


def get_engine(name: str) -> RoundEngine:
    resolved = _ALIASES.get(name, name)
    if name in _DEPRECATED_SPELLINGS:
        warnings.warn(
            f"engine spelling {name!r} is deprecated; use {resolved!r} "
            f"(repro.api: SolveOptions(engine={resolved!r}))",
            DeprecationWarning,
            stacklevel=2,
        )
    if resolved not in ENGINES:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(ENGINES)} "
            f"(aliases: {_ALIASES})"
        )
    return ENGINES[resolved]


def engine_names() -> Tuple[str, ...]:
    """Registered engine names, stable registration order."""
    return tuple(ENGINES)


# --------------------------------------------------------------------------
# the four engines
# --------------------------------------------------------------------------

def _segment_nbr_max(ctx: EngineContext, p, mask) -> jnp.ndarray:
    from repro.core.spmv import neighbor_max_segment

    n = ctx.g.n_nodes
    out = neighbor_max_segment(ctx.g, p[:n], mask[:n])
    return pack_vertex_vector(out, ctx.tiled)


class SegmentEngine(RoundEngine):
    """Paper-faithful CC baseline: every phase on the edge-list substrate."""

    name = "segment"

    def _nbr_max(self, ctx, p, mask):
        return _segment_nbr_max(ctx, p, mask)

    def col_flags(self, ctx, cand, alive):
        return None   # no tiles, nothing to skip

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        from repro.core.spmv import neighbor_sum_segment

        n = ctx.g.n_nodes
        n_c = neighbor_sum_segment(ctx.g, cand[:n].astype(jnp.float32))
        return pack_vertex_vector(n_c, ctx.tiled)


def _segment_nbr_max_bits_oracle(ctx: EngineContext, p, mask_words) -> jnp.ndarray:
    """Phase ① for bitwise runs that pin `phase1="segment"`: the edge-list
    substrate has no word form, so the pending mask densifies here — the
    sanctioned boundary (`_oracle` suffix, tools/ci_guards.py) between the
    packed round body and the paper-faithful CC baseline."""
    from repro.core.tiling import unpack_frontier_words

    mask = unpack_frontier_words(mask_words, ctx.tiled.tile_size)
    return _segment_nbr_max(ctx, p, mask)


class _TiledEngine(RoundEngine):
    """Shared phase-① policy for tile-schedule engines: `cfg.phase1` picks
    the paper-faithful segment max or the beyond-paper tiled max.

    Also owns the HYBRID round bodies (DESIGN.md §16): when the tiling
    carries a `TilePartition`, phase ① and ② each run twice — the existing
    dense machinery over the COMPACTED dense sub-tiling (`partition.dense`,
    via a sub-context that swaps `ctx.tiled`) and the COO sparse tail
    through segment gather/scatter — and the two halves merge exactly
    (`max` for Max_Np, `+` / `|` for N_c) before phase ③, so hybrid
    solutions are bit-identical to the dense-only path.  Fused engines
    demote to the split ② under hybrid (the in-kernel ③ can't see the
    sparse hits) via the `_dense_phase2` indirection."""

    supports_bitwise = True
    supports_hybrid = True

    def _tiled_nbr_max(self, ctx, p, mask) -> jnp.ndarray:
        t = ctx.tiled
        return tile_neighbor_max(
            t.tiles, t.tile_rows, t.tile_cols, jnp.where(mask, p, _NEG),
            t.n_block_rows, t.tile_size,
        )

    def _nbr_max(self, ctx, p, mask):
        if ctx.cfg.phase1 != "tiled":
            return _segment_nbr_max(ctx, p, mask)
        return self._tiled_nbr_max(ctx, p, mask)

    # -- packed-frontier round body (DESIGN.md §13) ------------------------
    def _nbr_max_bits(
        self, ctx, st: SortedPriorityTiles, planes, mask_words
    ) -> jnp.ndarray:
        """Bitwise Max_Np: remap the mask words into `st`'s sorted-slot
        layout (an O(n)-word repack inside the packing substrate), then the
        clz scan.  `planes` is ignored here; the Pallas engine overrides to
        run the plane-scan kernel when a plane stack was built."""
        t = ctx.tiled
        mask_sorted = sorted_frontier_words(mask_words, st.order, t.tile_size)
        return tile_neighbor_max_bits(
            st.tiles, t.tile_rows, t.tile_cols, st.p_sorted, mask_sorted,
            t.n_block_rows, t.tile_size,
        )

    def phase1_candidates_bits(self, ctx, pri, alive_words) -> jnp.ndarray:
        """① on packed frontiers.  Priorities stay dense (they are values,
        not frontiers); the select/pending/candidate SETS stay packed.  The
        padded-slot divergence between substrates (segment pads Max_Np with
        0, tiled floors at _NEG) is erased by the `& alive_words` /
        `& pending` guards — padded alive bits are always 0."""
        T = ctx.tiled.tile_size
        b = ctx.bits
        if ctx.cfg.phase1 != "tiled":
            max_np = _segment_nbr_max_bits_oracle(ctx, pri.select, alive_words)
        else:
            max_np = self._nbr_max_bits(ctx, b.select, b.select_planes, alive_words)
        if pri.resolve is None:
            return pack_frontier_words(pri.select > max_np, T) & alive_words
        # H3: conflicts resolved on the pending set before C is finalised.
        pending = pack_frontier_words(pri.select >= max_np, T) & alive_words
        if ctx.cfg.phase1 != "tiled":
            max_res = _segment_nbr_max_bits_oracle(ctx, pri.resolve, pending)
        else:
            max_res = self._nbr_max_bits(ctx, b.resolve, b.resolve_planes, pending)
        return pack_frontier_words(pri.resolve > max_res, T) & pending

    def col_flags_bits(self, ctx, cand_words) -> jnp.ndarray:
        """Active block-column flags straight from the words — a column is
        live iff any of its W candidate words is nonzero (no densify)."""
        flags = (cand_words != 0).any(axis=1).astype(jnp.int32)
        if ctx.col_gate is not None:
            flags = flags * ctx.col_gate.astype(flags.dtype)
        return flags

    def phase2_hits(self, ctx, cand_words, alive_words, col_flags):
        """② popcount SpMV → packed hit words.  Returns (nbc, W) uint32."""
        raise NotImplementedError(f"{self.name} is a fused engine")

    def fused_step_bits(self, ctx, cand_words, alive_words, col_flags):
        """②+③ fused on words.  Returns (new_alive_words, mis_add_words)."""
        raise NotImplementedError(f"{self.name} is a split engine")

    def step_bits(self, ctx, pri, state: MISRoundState) -> MISRoundState:
        cand_w = self.phase1_candidates_bits(ctx, pri, state.alive)
        flags = self.col_flags_bits(ctx, cand_w)
        inc = round_increment(state)   # scalar: bitwise excludes member_rounds
        if self.fused:
            new_alive, mis_add = self.fused_step_bits(
                ctx, cand_w, state.alive, flags
            )
            return MISRoundState(
                alive=new_alive,
                in_mis=state.in_mis | mis_add,
                rnd=state.rnd + inc,
            )
        hit_w = self.phase2_hits(ctx, cand_w, state.alive, flags)
        return phase3_update_bits(state, cand_w, hit_w, inc)

    def _step_bits_with_stats(
        self, ctx, pri, state: MISRoundState
    ) -> Tuple[MISRoundState, jnp.ndarray]:
        """`step_bits` + telemetry row; the counts are word popcounts
        (`jax.lax.population_count`) — the frontier never densifies."""
        alive_count = _popcount_words(state.alive)
        cand_w = self.phase1_candidates_bits(ctx, pri, state.alive)
        flags = self.col_flags_bits(ctx, cand_w)
        inc = round_increment(state)
        if self.fused:
            new_alive, mis_add = self.fused_step_bits(
                ctx, cand_w, state.alive, flags
            )
            new = MISRoundState(
                alive=new_alive,
                in_mis=state.in_mis | mis_add,
                rnd=state.rnd + inc,
            )
        else:
            hit_w = self.phase2_hits(ctx, cand_w, state.alive, flags)
            new = phase3_update_bits(state, cand_w, hit_w, inc)
        skipped = _tiles_skipped(ctx, flags)
        row = _telemetry_row(
            alive_count,
            _popcount_words(cand_w),
            _popcount_words(new.in_mis) - _popcount_words(state.in_mis),
            skipped,
            _tiles_routed_dense(ctx, skipped, flags),
            jnp.int32(0),
        )
        return new, row

    # -- hybrid round bodies (DESIGN.md §16) -------------------------------
    #
    # The dense half reuses the engine's own machinery verbatim on a
    # sub-context whose `tiled` is the compacted dense partition; the
    # sparse tail is pure segment gather/scatter in GLOBAL padded vertex
    # ids.  Sentinel pairs (row == col == n_padded) scatter into the
    # dropped segment row, so padding contributes nothing — the same
    # convention as the Graph sentinel edges.

    def _dense_phase2(self, ctx, cand, alive, col_flags):
        """Split-② over the dense partition, masked to covered rows (the
        Pallas kernel leaves unvisited output blocks uninitialised — see
        `_covered_rows`).  `ctx` here is the DENSE sub-context."""
        counts = self._dense_phase2_counts(ctx, cand, alive, col_flags)
        return jnp.where(_covered_vertices(ctx.tiled), counts, 0.0)

    def _dense_phase2_counts(self, ctx, cand, alive, col_flags):
        """Kernel dispatch seam for the hybrid split ②.  Fused engines
        override to reach their parent's split kernel: the fused ②+③ would
        commit phase ③ before the sparse hits can merge in."""
        return self.phase2_counts(ctx, cand, alive, col_flags)

    def _sparse_nbr_max(self, ctx, p, mask) -> jnp.ndarray:
        """① over the COO tail: masked priority gather at the senders,
        segment max at the receivers.  Empty segments come back at the
        int32 min (< _NEG), so `jnp.maximum` with the dense half is exact."""
        part = ctx.tiled.partition
        pm = jnp.where(mask, p, _NEG)
        return jax.ops.segment_max(
            pm[part.sp_cols], part.sp_rows,
            num_segments=ctx.tiled.n_padded + 1,
        )[:-1]

    def _sparse_counts(self, ctx, cand) -> jnp.ndarray:
        """② over the COO tail: candidate gather + segment sum — the exact
        nnz-wise slice of N_c the dense partition no longer covers."""
        part = ctx.tiled.partition
        return jax.ops.segment_sum(
            cand[part.sp_cols].astype(jnp.float32), part.sp_rows,
            num_segments=ctx.tiled.n_padded + 1,
        )[:-1]

    def _hybrid_nbr_max(self, ctx, dctx, p, mask) -> jnp.ndarray:
        if ctx.cfg.phase1 != "tiled":
            # the segment phase ① already covers the WHOLE graph — no merge
            return _segment_nbr_max(ctx, p, mask)
        dense_mx = jnp.where(
            _covered_vertices(dctx.tiled),
            self._tiled_nbr_max(dctx, p, mask),
            _NEG,
        )
        return jnp.maximum(dense_mx, self._sparse_nbr_max(ctx, p, mask))

    def _hybrid_candidates(self, ctx, dctx, pri, alive) -> jnp.ndarray:
        max_np = self._hybrid_nbr_max(ctx, dctx, pri.select, alive)
        if pri.resolve is None:
            return alive & (pri.select > max_np)
        pending = alive & (pri.select >= max_np)
        max_res = self._hybrid_nbr_max(ctx, dctx, pri.resolve, pending)
        return pending & (pri.resolve > max_res)

    def step_hybrid(self, ctx, pri, state: MISRoundState) -> MISRoundState:
        dctx = dataclasses.replace(ctx, tiled=ctx.tiled.partition.dense)
        cand = self._hybrid_candidates(ctx, dctx, pri, state.alive)
        flags = self.col_flags(dctx, cand, state.alive)
        inc = round_increment(state)
        n_c = self._dense_phase2(dctx, cand, state.alive, flags)
        n_c = n_c + self._sparse_counts(ctx, cand)
        return phase3_update(state, cand, n_c, inc)

    def _step_hybrid_with_stats(
        self, ctx, pri, state: MISRoundState
    ) -> Tuple[MISRoundState, jnp.ndarray]:
        dctx = dataclasses.replace(ctx, tiled=ctx.tiled.partition.dense)
        alive_count = _count(state.alive)
        cand = self._hybrid_candidates(ctx, dctx, pri, state.alive)
        flags = self.col_flags(dctx, cand, state.alive)
        inc = round_increment(state)
        n_c = self._dense_phase2(dctx, cand, state.alive, flags)
        n_c = n_c + self._sparse_counts(ctx, cand)
        new = phase3_update(state, cand, n_c, inc)
        skipped = _tiles_skipped(dctx, flags)
        row = _telemetry_row(
            alive_count,
            _count(cand),
            _count(new.in_mis) - _count(state.in_mis),
            skipped,
            _tiles_routed_dense(dctx, skipped, flags),
            jnp.int32(ctx.tiled.partition.n_sparse_tiles),
        )
        return new, row

    # -- hybrid, packed frontiers ------------------------------------------

    def _sparse_nbr_max_bits(self, ctx, p, mask_words) -> jnp.ndarray:
        """① tail on packed frontiers: a single-bit gather per nnz
        (`gather_frontier_bits` — shift-and-mask, not a densify), then the
        same masked segment max.  Priorities stay dense (they are values,
        not frontiers)."""
        part = ctx.tiled.partition
        T = ctx.tiled.tile_size
        bit = gather_frontier_bits(mask_words, part.sp_cols, T)
        pm = jnp.where(bit, p[part.sp_cols], _NEG)
        return jax.ops.segment_max(
            pm, part.sp_rows, num_segments=ctx.tiled.n_padded + 1
        )[:-1]

    def _sparse_hits_bits(self, ctx, cand_words) -> jnp.ndarray:
        """② tail on packed frontiers: candidate-bit gather, segment max
        (any-hit), repacked to (nbc, W) words for the `|` merge."""
        part = ctx.tiled.partition
        T = ctx.tiled.tile_size
        bit = gather_frontier_bits(cand_words, part.sp_cols, T)
        hit = jax.ops.segment_max(
            bit.astype(jnp.uint32), part.sp_rows,
            num_segments=ctx.tiled.n_padded + 1,
        )[:-1]
        return pack_frontier_words(hit, T)

    def _dense_hits_bits(self, dctx, cand_words, alive_words, flags) -> jnp.ndarray:
        """② hit words over the dense partition, masked to covered rows
        (same uninitialised-output hazard as `_dense_phase2`)."""
        hit_w = self.phase2_hits(dctx, cand_words, alive_words, flags)
        return jnp.where(_covered_rows(dctx.tiled)[:, None], hit_w, jnp.uint32(0))

    def _hybrid_nbr_max_bits(
        self, ctx, dctx, st, planes, p, mask_words
    ) -> jnp.ndarray:
        dense_mx = jnp.where(
            _covered_vertices(dctx.tiled),
            self._nbr_max_bits(dctx, st, planes, mask_words),
            _NEG,
        )
        return jnp.maximum(dense_mx, self._sparse_nbr_max_bits(ctx, p, mask_words))

    def _hybrid_candidates_bits(self, ctx, dctx, pri, alive_words) -> jnp.ndarray:
        """`phase1_candidates_bits` with the merged Max_Np.  The bitwise
        setup artefacts (`ctx.bits`) are built over the DENSE PARTITION in
        hybrid runs (`make_bitwise_context(partition.dense, ...)`), so the
        sorted-tile scan only walks dense tiles."""
        T = ctx.tiled.tile_size
        b = ctx.bits
        if ctx.cfg.phase1 != "tiled":
            max_np = _segment_nbr_max_bits_oracle(ctx, pri.select, alive_words)
        else:
            max_np = self._hybrid_nbr_max_bits(
                ctx, dctx, b.select, b.select_planes, pri.select, alive_words
            )
        if pri.resolve is None:
            return pack_frontier_words(pri.select > max_np, T) & alive_words
        pending = pack_frontier_words(pri.select >= max_np, T) & alive_words
        if ctx.cfg.phase1 != "tiled":
            max_res = _segment_nbr_max_bits_oracle(ctx, pri.resolve, pending)
        else:
            max_res = self._hybrid_nbr_max_bits(
                ctx, dctx, b.resolve, b.resolve_planes, pri.resolve, pending
            )
        return pack_frontier_words(pri.resolve > max_res, T) & pending

    def step_bits_hybrid(self, ctx, pri, state: MISRoundState) -> MISRoundState:
        dctx = dataclasses.replace(ctx, tiled=ctx.tiled.partition.dense)
        cand_w = self._hybrid_candidates_bits(ctx, dctx, pri, state.alive)
        flags = self.col_flags_bits(ctx, cand_w)
        inc = round_increment(state)
        hit_w = self._dense_hits_bits(dctx, cand_w, state.alive, flags)
        hit_w = hit_w | self._sparse_hits_bits(ctx, cand_w)
        return phase3_update_bits(state, cand_w, hit_w, inc)

    def _step_bits_hybrid_with_stats(
        self, ctx, pri, state: MISRoundState
    ) -> Tuple[MISRoundState, jnp.ndarray]:
        dctx = dataclasses.replace(ctx, tiled=ctx.tiled.partition.dense)
        alive_count = _popcount_words(state.alive)
        cand_w = self._hybrid_candidates_bits(ctx, dctx, pri, state.alive)
        flags = self.col_flags_bits(ctx, cand_w)
        inc = round_increment(state)
        hit_w = self._dense_hits_bits(dctx, cand_w, state.alive, flags)
        hit_w = hit_w | self._sparse_hits_bits(ctx, cand_w)
        new = phase3_update_bits(state, cand_w, hit_w, inc)
        skipped = _tiles_skipped(dctx, flags)
        row = _telemetry_row(
            alive_count,
            _popcount_words(cand_w),
            _popcount_words(new.in_mis) - _popcount_words(state.in_mis),
            skipped,
            _tiles_routed_dense(dctx, skipped, flags),
            jnp.int32(ctx.tiled.partition.n_sparse_tiles),
        )
        return new, row


class TiledRefEngine(_TiledEngine):
    """jnp oracle on the BSR schedule — ground truth for both kernels."""

    name = "tiled_ref"

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        t = ctx.tiled
        out = tile_spmv(
            t.tiles, t.tile_rows, t.tile_cols,
            self._pack_rhs(ctx, cand, alive),
            t.n_block_rows, t.tile_size, col_flags=col_flags,
        )
        return out[:, 0]

    def phase2_hits(self, ctx, cand_words, alive_words, col_flags):
        t = ctx.tiled
        return tile_spmv_bits(
            ctx.bits.tiles_bits, t.tile_rows, t.tile_cols, cand_words,
            t.n_block_rows, t.tile_size, col_flags=col_flags,
        )


class TiledPallasEngine(_TiledEngine):
    """Phase ② on the Pallas SpMV kernel; live empty-C skip via col_flags."""

    name = "tiled_pallas"
    plane_kernel_nbr_max = True

    def _tiled_nbr_max(self, ctx, p, mask):
        from repro.kernels.ops import tc_neighbor_max

        return tc_neighbor_max(ctx.tiled, p, mask)

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        from repro.kernels.ops import tc_spmv

        out = tc_spmv(
            ctx.tiled, self._pack_rhs(ctx, cand, alive),
            col_flags=col_flags, skip_dma=ctx.cfg.skip_dma,
        )
        return out[:, 0]

    def _nbr_max_bits(self, ctx, st, planes, mask_words):
        # The plane-scan kernel runs only when a plane stack was built (real
        # TPU — `make_bitwise_context(planes=True)`); otherwise the clz jnp
        # form, which is the same scan collapsed (bit-identical either way).
        if planes is None:
            return super()._nbr_max_bits(ctx, st, planes, mask_words)
        from repro.kernels.ops import tc_neighbor_max_bits

        signed = planes.shape[0] == _RESOLVE_PLANE_BITS
        return tc_neighbor_max_bits(ctx.tiled, planes, mask_words, signed=signed)

    def phase2_hits(self, ctx, cand_words, alive_words, col_flags):
        from repro.kernels.ops import tc_spmv_bits

        return tc_spmv_bits(
            ctx.tiled, cand_words, tiles_words=ctx.bits.tiles_bits,
            col_flags=col_flags, skip_dma=ctx.cfg.skip_dma,
        )


class FusedPallasEngine(TiledPallasEngine):
    """The production fast path: phase ②+③ in one kernel pass — the state
    update runs in the SpMV epilogue, N_c never round-trips through HBM."""

    name = "fused_pallas"
    fused = True

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        raise NotImplementedError("fused_pallas runs ②+③ as one fused_step")

    def _dense_phase2_counts(self, ctx, cand, alive, col_flags):
        # hybrid demotes fused ②+③ to the split ② (the in-kernel ③ can't
        # merge the sparse hits) — reach TiledPallasEngine's SpMV kernel
        # past this class's intentionally-raising phase2_counts.  The
        # bitwise twin needs no indirection: `phase2_hits` is inherited,
        # not overridden.
        return super().phase2_counts(ctx, cand, alive, col_flags)

    def fused_step(self, ctx, cand, alive, col_flags=None):
        from repro.kernels.ops import tc_spmv_fused

        _, new_alive, mis_add = tc_spmv_fused(
            ctx.tiled, self._pack_rhs(ctx, cand, alive), cand, alive,
            col_flags=col_flags, skip_dma=ctx.cfg.skip_dma,
        )
        return new_alive, mis_add

    def fused_step_bits(self, ctx, cand_words, alive_words, col_flags):
        from repro.kernels.ops import tc_spmv_fused_bits

        _, new_alive, mis_add = tc_spmv_fused_bits(
            ctx.tiled, cand_words, alive_words,
            tiles_words=ctx.bits.tiles_bits,
            col_flags=col_flags, skip_dma=ctx.cfg.skip_dma,
        )
        return new_alive, mis_add


register_engine(SegmentEngine())
register_engine(TiledRefEngine())
register_engine(TiledPallasEngine())
register_engine(FusedPallasEngine())

"""The round-engine layer: one backend interface for every TC-MIS phase.

Every execution path of the system — the paper-faithful CC baseline, the jnp
tile oracle, the Pallas SpMV kernel, and the fused phase-②+③ kernel — is a
`RoundEngine`: an object that knows how to run one MIS round (DESIGN.md §4).
The driver (`core.tc_mis`) is engine-agnostic; it owns only the convergence
loop.  Benchmarks, examples and future backends (GPU Pallas) select engines
from the registry instead of hard-coding call sites — kernel selection is a
pluggable policy over one tiled schedule, the way BLEST/HC-SpMM treat their
kernel zoos.  (Bit-packed masks — once a forward reference here — are now a
first-class STORAGE axis, not a backend: every engine runs either tile
format, see DESIGN.md §11 and `core.tiling.STORAGES`.)

Registered engines:

  segment       gather/segment ops over the edge list (ECL-MIS analogue);
                the paper's CUDA-core baseline substrate.
  tiled_ref     pure-jnp BSR tile schedule — the oracle every kernel is
                validated against.
  tiled_pallas  phase ② on the Pallas SpMV kernel (MXU on TPU), phase ① per
                `cfg.phase1` (segment, or the beyond-paper tiled max kernel).
  fused_pallas  the fast path: phase ②+③ in ONE kernel pass — N_c never
                round-trips through HBM (DESIGN.md §6.3).

Per-round metadata: tiled engines compute **active block-column flags** from
the candidate vector each round (`block_col_flags`) so the kernels' empty-C
tile skip — `@pl.when` on the MXU op, and the `skip_dma` HBM-read skip — is
exercised live, not just in unit tests.  Skipping is exact: a tile whose
candidate slab is all-zero contributes exactly zero to N_c (lane 0).  Lanes
≥ 1 of a skipped column are dropped too, so the jnp oracle emulates the skip
by zeroing gated slabs — ref and kernel agree on ALL lanes.

This module also owns the raw-array tile operators (`tile_spmv`,
`tile_neighbor_max`) shared by `core.spmv` (padded-vector forms) and
`core.distributed` (shard-local slabs inside `shard_map`).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import (
    BlockTiledGraph,
    dense_tiles,
    pack_vertex_vector,
)
from repro.graphs.graph import Graph

_NEG = np.int32(-(1 << 30))  # numpy scalar: safe to create at import time under a trace


# --------------------------------------------------------------------------
# raw-array tile operators (shared: core.spmv, core.distributed, engines)
# --------------------------------------------------------------------------

def tile_spmv(
    tiles: jnp.ndarray,          # (nt, T, T) int8 | (nt, T, W) uint32 packed
    tile_rows: jnp.ndarray,      # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,      # (nt,) int32
    rhs: jnp.ndarray,            # (nbc*T, L) float
    n_block_rows: int,
    tile_size: int,
    *,
    col_flags: jnp.ndarray | None = None,   # (nbc,) int32; None = all active
) -> jnp.ndarray:
    """N = A @ rhs over BSR tiles, pure jnp (the Pallas kernels' oracle).

    With `col_flags`, gated RHS slabs are zeroed before the contraction —
    the exact semantics of the kernel's `@pl.when` tile skip (a skipped tile
    contributes nothing on any lane).  Returns (n_block_rows*T, L) float32.
    """
    T = tile_size
    tiles = dense_tiles(tiles, T)
    blocks = rhs.reshape(-1, T, rhs.shape[-1])
    gathered = blocks[tile_cols]                             # (nt, T, L)
    if col_flags is not None:
        gathered = gathered * col_flags[tile_cols][:, None, None].astype(
            gathered.dtype
        )
    prod = jnp.einsum(
        "ijk,ikl->ijl", tiles.astype(jnp.float32), gathered.astype(jnp.float32)
    )
    out = jax.ops.segment_sum(prod, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T, rhs.shape[-1])


def tile_neighbor_max(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    pm: jnp.ndarray,             # (nbc*T,) pre-masked priorities (_NEG = dead)
    n_block_rows: int,
    tile_size: int,
) -> jnp.ndarray:
    """Max_Np over the same BSR schedule (VPU work — max has no MXU form)."""
    T = tile_size
    tiles = dense_tiles(tiles, T)
    gathered = pm.reshape(-1, T)[tile_cols]                  # (nt, T)
    # tile (T,T) row v, col u: edge v->u.  masked max over columns.
    vals = jnp.where(tiles != 0, gathered[:, None, :], _NEG)  # (nt, T, T)
    tile_max = vals.max(axis=2)                              # (nt, T)
    out = jax.ops.segment_max(tile_max, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T)


def block_col_flags(x: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """Per-block-column activity: (nbc*T,) vector -> (nbc,) int32 0/1 flags.

    The per-round metadata of the engine layer: a block-column is active iff
    any vertex in it carries a nonzero entry (the paper's empty-C test)."""
    return x.reshape(-1, tile_size).astype(bool).any(axis=1).astype(jnp.int32)


# --------------------------------------------------------------------------
# engine state + context
# --------------------------------------------------------------------------

class MISRoundState(NamedTuple):
    """Per-round algorithm state; `alive`/`in_mis` are (n_padded,).

    `rnd` is polymorphic: a scalar int32 counts rounds globally (the classic
    single-graph run), while an (n_padded,) int32 vector — the batched
    serving mode — advances per vertex only while that vertex is alive, so
    `rnd[v]` converges to v's settle round and a packed member's OWN round
    count is the max over its slot (`round_increment`).  A member that
    converges early stops counting even though the batch keeps looping.
    """
    alive: jnp.ndarray    # bool
    in_mis: jnp.ndarray   # bool
    rnd: jnp.ndarray      # int32 — () global, or (n_padded,) per-vertex


@dataclasses.dataclass(frozen=True)
class EngineContext:
    """Immutable per-run bundle an engine closes over: the graph in both
    representations plus the run config (lanes, phase1 policy, skip_dma).

    `col_gate` is the batch-aware extension of the per-round flags: a static
    (n_block_cols,) 0/1 vector ANDed into every round's `col_flags`.  The
    block-diagonal batcher (`repro.serve_mis.batcher`) sets it to the
    real-vertex occupancy of each block column, so a padded bucket's empty
    trailing slots are pinned inactive from round 0 — the empty-C skip never
    depends on the candidate vector reaching those slots first.  `None`
    (single-graph runs) means "all columns may carry candidates".
    """
    g: Graph
    tiled: BlockTiledGraph
    cfg: Any   # options bundle: anything with backend/heuristic/lanes/
               # phase1/skip_dma/max_rounds (repro.api.SolveOptions, or the
               # legacy TCMISConfig shim)
    col_gate: Optional[jnp.ndarray] = None


def round_increment(state: MISRoundState) -> jnp.ndarray:
    """The per-round `rnd` advance matching the state's counting mode.

    Scalar `rnd` ⇒ +1 (the driver's while_loop only runs while something is
    alive).  Vector `rnd` ⇒ +alive, so converged members / vertices stop
    counting — the per-member round-counter contract (MISRoundState)."""
    if getattr(state.rnd, "ndim", 0):
        return state.alive.astype(jnp.int32)
    return jnp.int32(1)


def phase3_update(
    state: MISRoundState,
    cand: jnp.ndarray,
    n_c: jnp.ndarray,
    rnd_inc: Optional[jnp.ndarray] = None,
) -> MISRoundState:
    """③ lock-free own-state update (paper's three rules, verbatim)."""
    return MISRoundState(
        alive=state.alive & ~cand & ~(n_c > 0),
        in_mis=state.in_mis | cand,
        rnd=state.rnd + (round_increment(state) if rnd_inc is None else rnd_inc),
    )


# --------------------------------------------------------------------------
# the engine interface
# --------------------------------------------------------------------------

class RoundEngine:
    """One MIS round as three pluggable pieces.

    Subclasses implement `_nbr_max` (phase ① substrate) and either
    `phase2_counts` (split engines) or `fused_step` (fused engines,
    `fused = True`).  `step` — the single round body every driver uses —
    is shared; `col_flags` is the per-round metadata hook.
    """

    name: str = "abstract"
    fused: bool = False

    # -- phase ① ----------------------------------------------------------
    def _nbr_max(
        self, ctx: EngineContext, p: jnp.ndarray, mask: jnp.ndarray
    ) -> jnp.ndarray:
        raise NotImplementedError

    def phase1_candidates(
        self, ctx: EngineContext, pri, alive: jnp.ndarray
    ) -> jnp.ndarray:
        """① Max_Np + candidate test (+ H3 pending-set resolution)."""
        max_np = self._nbr_max(ctx, pri.select, alive)
        if pri.resolve is None:
            return alive & (pri.select > max_np)
        # H3: conflicts resolved on the pending set before C is finalised.
        pending = alive & (pri.select >= max_np)
        max_res = self._nbr_max(ctx, pri.resolve, pending)
        return pending & (pri.resolve > max_res)

    # -- per-round metadata -----------------------------------------------
    def col_flags(
        self, ctx: EngineContext, cand: jnp.ndarray, alive: jnp.ndarray
    ) -> Optional[jnp.ndarray]:
        """Active block-column flags for the empty-C tile skip.  Candidates
        drive phase ②'s lane 0, so a column block with no candidate is dead
        weight — flag it off.  Batched runs AND in the static `col_gate`
        (columns of empty bucket slots stay dark in every round).  Segment
        engines have no tiles to skip."""
        flags = block_col_flags(cand, ctx.tiled.tile_size)
        if ctx.col_gate is not None:
            flags = flags * ctx.col_gate.astype(flags.dtype)
        return flags

    # -- phase ② ----------------------------------------------------------
    def _pack_rhs(
        self, ctx: EngineContext, cand: jnp.ndarray, alive: jnp.ndarray
    ) -> jnp.ndarray:
        """Lane-packed RHS: lane 0 = C (the paper's SpMV input), lane 1 =
        alive (live-neighbour counts ride along free on a wide-lane TPU)."""
        rhs = jnp.zeros((ctx.tiled.n_padded, ctx.cfg.lanes), dtype=jnp.float32)
        rhs = rhs.at[:, 0].set(cand.astype(jnp.float32))
        rhs = rhs.at[:, 1].set(alive.astype(jnp.float32))
        return rhs

    def phase2_counts(
        self,
        ctx: EngineContext,
        cand: jnp.ndarray,
        alive: jnp.ndarray,
        col_flags: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """② N_c = A × C.  Returns (n_padded,) float32."""
        raise NotImplementedError(f"{self.name} is a fused engine")

    # -- fused ②+③ --------------------------------------------------------
    def fused_step(
        self,
        ctx: EngineContext,
        cand: jnp.ndarray,
        alive: jnp.ndarray,
        col_flags: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """②+③ in one pass.  Returns (new_alive, mis_add) bool vectors."""
        raise NotImplementedError(f"{self.name} is a split engine")

    # -- the round body (shared by tc_mis AND run_phases) ------------------
    def step(
        self, ctx: EngineContext, pri, state: MISRoundState
    ) -> MISRoundState:
        cand = self.phase1_candidates(ctx, pri, state.alive)
        flags = self.col_flags(ctx, cand, state.alive)
        inc = round_increment(state)
        if self.fused:
            new_alive, mis_add = self.fused_step(ctx, cand, state.alive, flags)
            return MISRoundState(
                alive=new_alive,
                in_mis=state.in_mis | mis_add,
                rnd=state.rnd + inc,
            )
        n_c = self.phase2_counts(ctx, cand, state.alive, flags)
        return phase3_update(state, cand, n_c, inc)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

ENGINES: Dict[str, RoundEngine] = {}

# legacy TCMISConfig.backend spellings kept working (but deprecated)
_ALIASES = {"ref": "tiled_ref", "pallas": "tiled_pallas", "fused": "fused_pallas"}
_DEPRECATED_SPELLINGS = ("ref", "pallas")


def register_engine(engine: RoundEngine) -> RoundEngine:
    ENGINES[engine.name] = engine
    return engine


def get_engine(name: str) -> RoundEngine:
    resolved = _ALIASES.get(name, name)
    if name in _DEPRECATED_SPELLINGS:
        warnings.warn(
            f"engine spelling {name!r} is deprecated; use {resolved!r} "
            f"(repro.api: SolveOptions(engine={resolved!r}))",
            DeprecationWarning,
            stacklevel=2,
        )
    if resolved not in ENGINES:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(ENGINES)} "
            f"(aliases: {_ALIASES})"
        )
    return ENGINES[resolved]


def engine_names() -> Tuple[str, ...]:
    """Registered engine names, stable registration order."""
    return tuple(ENGINES)


# --------------------------------------------------------------------------
# the four engines
# --------------------------------------------------------------------------

def _segment_nbr_max(ctx: EngineContext, p, mask) -> jnp.ndarray:
    from repro.core.spmv import neighbor_max_segment

    n = ctx.g.n_nodes
    out = neighbor_max_segment(ctx.g, p[:n], mask[:n])
    return pack_vertex_vector(out, ctx.tiled)


class SegmentEngine(RoundEngine):
    """Paper-faithful CC baseline: every phase on the edge-list substrate."""

    name = "segment"

    def _nbr_max(self, ctx, p, mask):
        return _segment_nbr_max(ctx, p, mask)

    def col_flags(self, ctx, cand, alive):
        return None   # no tiles, nothing to skip

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        from repro.core.spmv import neighbor_sum_segment

        n = ctx.g.n_nodes
        n_c = neighbor_sum_segment(ctx.g, cand[:n].astype(jnp.float32))
        return pack_vertex_vector(n_c, ctx.tiled)


class _TiledEngine(RoundEngine):
    """Shared phase-① policy for tile-schedule engines: `cfg.phase1` picks
    the paper-faithful segment max or the beyond-paper tiled max."""

    def _tiled_nbr_max(self, ctx, p, mask) -> jnp.ndarray:
        t = ctx.tiled
        return tile_neighbor_max(
            t.tiles, t.tile_rows, t.tile_cols, jnp.where(mask, p, _NEG),
            t.n_block_rows, t.tile_size,
        )

    def _nbr_max(self, ctx, p, mask):
        if ctx.cfg.phase1 != "tiled":
            return _segment_nbr_max(ctx, p, mask)
        return self._tiled_nbr_max(ctx, p, mask)


class TiledRefEngine(_TiledEngine):
    """jnp oracle on the BSR schedule — ground truth for both kernels."""

    name = "tiled_ref"

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        t = ctx.tiled
        out = tile_spmv(
            t.tiles, t.tile_rows, t.tile_cols,
            self._pack_rhs(ctx, cand, alive),
            t.n_block_rows, t.tile_size, col_flags=col_flags,
        )
        return out[:, 0]


class TiledPallasEngine(_TiledEngine):
    """Phase ② on the Pallas SpMV kernel; live empty-C skip via col_flags."""

    name = "tiled_pallas"

    def _tiled_nbr_max(self, ctx, p, mask):
        from repro.kernels.ops import tc_neighbor_max

        return tc_neighbor_max(ctx.tiled, p, mask)

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        from repro.kernels.ops import tc_spmv

        out = tc_spmv(
            ctx.tiled, self._pack_rhs(ctx, cand, alive),
            col_flags=col_flags, skip_dma=ctx.cfg.skip_dma,
        )
        return out[:, 0]


class FusedPallasEngine(TiledPallasEngine):
    """The production fast path: phase ②+③ in one kernel pass — the state
    update runs in the SpMV epilogue, N_c never round-trips through HBM."""

    name = "fused_pallas"
    fused = True

    def phase2_counts(self, ctx, cand, alive, col_flags=None):
        raise NotImplementedError("fused_pallas runs ②+③ as one fused_step")

    def fused_step(self, ctx, cand, alive, col_flags=None):
        from repro.kernels.ops import tc_spmv_fused

        _, new_alive, mis_add = tc_spmv_fused(
            ctx.tiled, self._pack_rhs(ctx, cand, alive), cand, alive,
            col_flags=col_flags, skip_dma=ctx.cfg.skip_dma,
        )
        return new_alive, mis_add


register_engine(SegmentEngine())
register_engine(TiledRefEngine())
register_engine(TiledPallasEngine())
register_engine(FusedPallasEngine())

"""MIS solution validators — the invariants every algorithm must satisfy.

Used by tests (property-based, vs networkx) and by the benchmark harness as a
post-condition on every reported number.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spmv import neighbor_any_segment
from repro.graphs.graph import Graph


@jax.jit
def _checks(senders, receivers, edge_mask, in_mis, n_nodes_arr):
    del n_nodes_arr
    return in_mis


def is_independent(g: Graph, in_mis: jnp.ndarray) -> bool:
    """No edge has both endpoints selected."""
    both = g.edge_mask & in_mis[g.senders] & in_mis[g.receivers]
    return not bool(jnp.any(both))


def is_maximal(g: Graph, in_mis: jnp.ndarray) -> bool:
    """Every unselected vertex has a selected neighbour."""
    covered = in_mis | neighbor_any_segment(g, in_mis)
    return bool(jnp.all(covered))


def is_valid_mis(g: Graph, in_mis: jnp.ndarray) -> bool:
    return is_independent(g, in_mis) and is_maximal(g, in_mis)


def cardinality(in_mis: jnp.ndarray) -> int:
    return int(jnp.sum(in_mis))

"""MIS solution validators — the invariants every algorithm must satisfy.

Used by tests (property-based, vs networkx), by the benchmark harness as a
post-condition on every reported number, and by the serving layer
(`repro.serve_mis.service`) as a post-condition on every response.

The serving hot path wants both invariants from ONE jitted dispatch (one
host↔device round-trip per response, not three): `is_valid_mis_jit` fuses the
independence and maximality checks into a single compiled call and
`is_valid_mis` rides on it.  Its jitted core takes raw shape-BUCKETED arrays
(edge/vertex arrays padded to powers of two, with explicit validity masks),
so a long-running service validating graphs of many sizes compiles
O(log|V|·log|E|) validator programs — not one per distinct graph shape.
The single-invariant `is_independent` / `is_maximal` forms share the graph's
exact shapes and compute only their own invariant (eagerly — callers that
want one check shouldn't pay for two).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spmv import neighbor_any_segment
from repro.core.tiling import next_pow2
from repro.graphs.graph import Graph


def _independent(g: Graph, in_mis: jnp.ndarray) -> jnp.ndarray:
    """Scalar bool: no edge has both endpoints selected."""
    both = g.edge_mask & in_mis[g.senders] & in_mis[g.receivers]
    return ~jnp.any(both)


def _maximal(g: Graph, in_mis: jnp.ndarray) -> jnp.ndarray:
    """Scalar bool: every unselected vertex has a selected neighbour."""
    return jnp.all(in_mis | neighbor_any_segment(g, in_mis))


@jax.jit
def _fused_checks_masked(
    senders: jnp.ndarray,     # (e_pad,) int32; padding rows point at a dead slot
    receivers: jnp.ndarray,   # (e_pad,) int32
    edge_ok: jnp.ndarray,     # (e_pad,) bool — False on padding rows
    in_mis: jnp.ndarray,      # (n_pad,) bool — False on padding slots
    vertex_ok: jnp.ndarray,   # (n_pad,) bool — False on padding slots
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Both invariants in one compiled pass; jit cache keyed on the padded
    shapes only (no per-graph static fields — raw arrays, not a Graph)."""
    sel = in_mis & vertex_ok
    both = edge_ok & sel[senders] & sel[receivers]
    contrib = (edge_ok & sel[senders]).astype(jnp.int32)
    nbr = jax.ops.segment_max(
        contrib, receivers, num_segments=sel.shape[0]
    )
    covered = sel | (nbr > 0)
    return ~jnp.any(both), jnp.all(covered | ~vertex_ok)


def is_valid_mis_jit(g: Graph, in_mis: jnp.ndarray) -> Tuple[bool, bool]:
    """Fused validity check: returns ``(independent, maximal)`` python bools
    from a single jitted call — the serving layer's per-response post-condition.

    Inputs are padded host-side to pow2 shape buckets before the dispatch, so
    validating a stream of differently-sized graphs reuses a small, bounded
    set of compiled programs.
    """
    n, e = g.n_nodes, g.n_edges
    n_pad = next_pow2(n + 1)            # ≥ n+1: slot n absorbs sentinel edges
    e_pad = next_pow2(max(e, 1))
    s = np.full(e_pad, n, np.int32)
    r = np.full(e_pad, n, np.int32)
    s[:e] = np.asarray(g.senders)[:e]
    r[:e] = np.asarray(g.receivers)[:e]
    edge_ok = np.zeros(e_pad, bool)
    edge_ok[:e] = True
    mis = np.zeros(n_pad, bool)
    mis[:n] = np.asarray(in_mis)[:n].astype(bool)
    vertex_ok = np.zeros(n_pad, bool)
    vertex_ok[:n] = True
    independent, maximal = _fused_checks_masked(
        jnp.asarray(s), jnp.asarray(r), jnp.asarray(edge_ok),
        jnp.asarray(mis), jnp.asarray(vertex_ok),
    )
    return bool(independent), bool(maximal)


def is_independent(g: Graph, in_mis: jnp.ndarray) -> bool:
    """No edge has both endpoints selected (single-invariant form)."""
    return bool(_independent(g, in_mis.astype(bool)))


def is_maximal(g: Graph, in_mis: jnp.ndarray) -> bool:
    """Every unselected vertex has a selected neighbour (single-invariant)."""
    return bool(_maximal(g, in_mis.astype(bool)))


def is_valid_mis(g: Graph, in_mis: jnp.ndarray) -> bool:
    return all(is_valid_mis_jit(g, in_mis))


def cardinality(in_mis: jnp.ndarray) -> int:
    return int(jnp.sum(in_mis))

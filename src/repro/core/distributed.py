"""Distributed TC-MIS: block-row-partitioned BSR over a device mesh.

Layout (DESIGN.md §5): each chip owns a contiguous slab of block-rows of the
tiled adjacency matrix plus the matching slice of the state vectors.  Per
round the only communication is the `all_gather` of the candidate / alive
bit-vectors (optionally packed 8× as uint32 frontier words via the one
packing contract in `core.tiling`, DESIGN.md §6.4/§13) — the
distributed-Luby lower bound.  Everything else (phase ① tiled max, phase ②
tiled SpMV, phase ③ state update) is shard-local.

The mesh axes are flattened into one logical partition axis, so the same code
runs on (16,16) single-pod and (2,16,16) multi-pod meshes — the "pod" axis
simply becomes the slowest-varying factor of the row partition.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import block_col_flags, tile_neighbor_max, tile_spmv
from repro.core.heuristics import Priorities
from repro.core.spmv import _NEG
from repro.core.tiling import (
    BlockTiledGraph,
    pack_frontier_words,
    unpack_frontier_words,
)


# --------------------------------------------------------------------------
# host-side shard construction
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedTiledGraph:
    """Row-partitioned BSR; leading axis is the shard axis.

    tiles:     (S, nt_pad, T, T) int8 — or (S, nt_pad, T, W) uint32 when the
               source tiling is bit-packed (DESIGN.md §11); sharding is
               storage-agnostic, each shard's slab stays in the source format
    tile_rows: (S, nt_pad) int32 — block-row LOCAL to the shard
    tile_cols: (S, nt_pad) int32 — GLOBAL block-column
    """
    tiles: jnp.ndarray
    tile_rows: jnp.ndarray
    tile_cols: jnp.ndarray
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    tile_size: int = dataclasses.field(metadata=dict(static=True))
    rows_per_shard: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    n_block_cols: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_padded(self) -> int:
        """Global padded vertex count = S · rows_per_shard · T."""
        return self.n_shards * self.rows_per_shard * self.tile_size


def shard_tiled(tiled: BlockTiledGraph, n_shards: int) -> ShardedTiledGraph:
    """Split a BSR graph into ``n_shards`` row slabs, padded to a rectangle.

    Storage-agnostic: packed uint32 tiles shard shard-locally in their
    packed form (the per-shard HBM slab shrinks by the same 8×)."""
    T = tiled.tile_size
    nbr = tiled.n_block_rows
    rows_per_shard = -(-nbr // n_shards)
    nbr_pad = rows_per_shard * n_shards

    t = np.asarray(tiled.tiles[: max(tiled.n_tiles, 1)])
    tr = np.asarray(tiled.tile_rows[: max(tiled.n_tiles, 1)])
    tc = np.asarray(tiled.tile_cols[: max(tiled.n_tiles, 1)])
    if tiled.n_tiles == 0:
        t, tr, tc = t[:0], tr[:0], tc[:0]

    owner = tr // rows_per_shard
    max_nt = max(int(np.max(np.bincount(owner, minlength=n_shards))) if tr.size else 0, 1)
    max_nt = ((max_nt + 7) // 8) * 8

    tiles_s = np.zeros((n_shards, max_nt) + t.shape[1:], dtype=t.dtype)
    # padding tiles carry the last local row (monotone) and column 0
    rows_s = np.full((n_shards, max_nt), rows_per_shard - 1, dtype=np.int32)
    cols_s = np.zeros((n_shards, max_nt), dtype=np.int32)
    for s in range(n_shards):
        sel = owner == s
        k = int(sel.sum())
        tiles_s[s, :k] = t[sel]
        rows_s[s, :k] = tr[sel] - s * rows_per_shard
        cols_s[s, :k] = tc[sel]

    # column space must cover the padded vertex range (gathered RHS length)
    n_block_cols = nbr_pad
    return ShardedTiledGraph(
        tiles=jnp.asarray(tiles_s),
        tile_rows=jnp.asarray(rows_s),
        tile_cols=jnp.asarray(cols_s),
        n_nodes=tiled.n_nodes,
        tile_size=T,
        rows_per_shard=rows_per_shard,
        n_shards=n_shards,
        n_block_cols=n_block_cols,
    )


# --------------------------------------------------------------------------
# bit-packed frontier collectives (beyond-paper, DESIGN.md §6.4): the gather
# payload rides as the SAME (…, W) uint32 frontier words the bitwise round
# engine uses — `core.tiling.pack_frontier_words` is the single packing
# contract; this module no longer carries its own uint8 variant.  A shard's
# local slice is rps·T vertices — an exact multiple of T, so the word layout
# tiles cleanly across shards and `all_gather(tiled=True)` concatenates to
# the global word vector.
# --------------------------------------------------------------------------
# shard-local tile operators: the engine layer's raw-array forms applied to
# this shard's slab — local rows, GLOBAL columns.  SpMV needs no wrapper
# (`tile_spmv` is called directly); the max adds the priority masking.
# --------------------------------------------------------------------------

def _local_nbr_max(tiles, tile_rows, tile_cols, p_global, mask_global,
                   n_local_rows, T):
    return tile_neighbor_max(
        tiles, tile_rows, tile_cols, jnp.where(mask_global, p_global, _NEG),
        n_local_rows, T,
    )


# --------------------------------------------------------------------------
# the distributed algorithm
# --------------------------------------------------------------------------

class DistMISResult(NamedTuple):
    in_mis: jnp.ndarray     # (n_padded,) bool, row-sharded
    rounds: jnp.ndarray     # int32 (replicated)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    max_rounds: int = 1024
    bitpack: bool = True     # gather uint8-packed frontiers (8× fewer bytes)
    lanes: int = 8


def make_mis_step_fn(
    mesh: Mesh,
    cfg: DistConfig,
    *,
    n_nodes: int,
    tile_size: int,
    rows_per_shard: int,
    two_pass: bool = True,
):
    """The lowerable distributed-MIS entry: returns a shard_map'd callable

        fn(tiles, tile_rows, tile_cols, select, resolve) -> (in_mis, rounds)

    with tiles/rows/cols row-slab-sharded over the flattened mesh and the
    priority vectors replicated.  This is what launch/dryrun.py lowers for
    the paper's graph suite and what `build_distributed_mis` wraps for live
    runs.
    """
    axis = tuple(mesh.axis_names)
    T = tile_size
    rps = rows_per_shard
    n_local = rps * T

    def gather_bool(x_local):
        # the one sanctioned densify outside kernels/oracles on this path:
        # the shard-local phases below are dense ops (tools/ci_guards.py
        # allowlists gather_bool); only the WIRE payload is packed words.
        if cfg.bitpack:
            packed = pack_frontier_words(x_local, T)
            g = jax.lax.all_gather(packed, axis, tiled=True)
            return unpack_frontier_words(g, T)
        return jax.lax.all_gather(x_local, axis, tiled=True)

    def body_fn(tiles, tile_rows, tile_cols, select, resolve):
        """Inside shard_map: tiles/rows/cols are this shard's slab (leading
        shard axis of local size 1 — squeeze it); select/resolve are
        replicated global vectors."""
        tiles, tile_rows, tile_cols = tiles[0], tile_rows[0], tile_cols[0]
        idx = jax.lax.axis_index(axis)
        off = idx * n_local
        select_l = jax.lax.dynamic_slice(select, (off,), (n_local,))
        resolve_l = jax.lax.dynamic_slice(resolve, (off,), (n_local,))

        def cond(state):
            alive_g, _, rnd = state
            return jnp.any(alive_g) & (rnd < cfg.max_rounds)

        def body(state):
            alive_g, in_mis_l, rnd = state
            alive_l = jax.lax.dynamic_slice(alive_g, (off,), (n_local,))
            # ① tiled neighbour max (local rows, global columns)
            max_np = _local_nbr_max(
                tiles, tile_rows, tile_cols, select, alive_g, rps, T
            )
            if two_pass:
                pend_l = alive_l & (select_l >= max_np)
                pend_g = gather_bool(pend_l)
                max_res = _local_nbr_max(
                    tiles, tile_rows, tile_cols, resolve, pend_g, rps, T
                )
                cand_l = pend_l & (resolve_l > max_res)
            else:
                cand_l = alive_l & (select_l > max_np)
            # ② tiled SpMV against the gathered global candidate vector.
            # Per-round active-column flags (engine-layer metadata): every
            # shard sees the same gathered C, so the empty-C tile skip is
            # applied identically — and exactly — shard-locally.
            cand_g = gather_bool(cand_l)
            rhs = jnp.zeros((cand_g.shape[0], cfg.lanes), dtype=jnp.float32)
            rhs = rhs.at[:, 0].set(cand_g.astype(jnp.float32))
            rhs = rhs.at[:, 1].set(alive_g.astype(jnp.float32))
            flags = block_col_flags(cand_g, T)
            n_c = tile_spmv(
                tiles, tile_rows, tile_cols, rhs, rps, T, col_flags=flags
            )[:, 0]
            # ③ local own-state update, then gather the new frontier
            in_mis_l = in_mis_l | cand_l
            alive_l = alive_l & ~cand_l & ~(n_c > 0)
            alive_g = gather_bool(alive_l)
            return alive_g, in_mis_l, rnd + 1

        alive0_l = (jnp.arange(n_local) + off) < n_nodes
        alive0_g = gather_bool(alive0_l)
        in_mis0 = jnp.zeros((n_local,), dtype=bool)
        alive_g, in_mis_l, rounds = jax.lax.while_loop(
            cond, body, (alive0_g, in_mis0, jnp.int32(0))
        )
        return in_mis_l, rounds

    from repro.dist.compat import shard_map

    shard_spec = P(axis)
    return shard_map(
        body_fn,
        mesh=mesh,
        in_specs=(shard_spec, shard_spec, shard_spec, P(), P()),
        out_specs=(shard_spec, P()),
        check_vma=False,
    )


def build_distributed_mis(
    sharded: ShardedTiledGraph,
    mesh: Mesh,
    cfg: DistConfig = DistConfig(),
):
    """Live-run wrapper around `make_mis_step_fn`, closed over the shards."""

    def run(pri: Priorities, two_pass: Optional[bool] = None) -> DistMISResult:
        two = (pri.resolve is not None) if two_pass is None else two_pass
        fn = make_mis_step_fn(
            mesh, cfg,
            n_nodes=sharded.n_nodes,
            tile_size=sharded.tile_size,
            rows_per_shard=sharded.rows_per_shard,
            two_pass=two,
        )
        n_padded = sharded.n_padded
        pad_to = lambda x: jnp.pad(
            x, (0, n_padded - x.shape[0]), constant_values=int(_NEG)
        )
        select = pad_to(pri.select)
        resolve = pad_to(
            pri.resolve
            if pri.resolve is not None
            else jnp.full_like(pri.select, _NEG)
        )
        in_mis, rounds = fn(
            sharded.tiles, sharded.tile_rows, sharded.tile_cols, select, resolve
        )
        return DistMISResult(in_mis=in_mis, rounds=rounds)

    return run

"""Priority-assignment heuristics H1/H2/H3 (paper §3.3) + ECL's Eq. (1).

All heuristics produce **int32 total orders**: the high bits carry the
structural bias (quantised Eq. 1), the low 23 bits carry a random permutation
of vertex ids so priorities are *globally distinct*.  Distinctness is what
makes phase ③ lock-free-by-construction exact: two adjacent vertices can never
both satisfy ``P(v) > Max_Np(v)``, so candidates are guaranteed independent
and the algorithm is deterministic given the key.

Execution-semantics modelling (see DESIGN.md §4): the paper's H2-vs-H3 quality
gap arises from priority inversions during warp-asynchronous tile execution.
A JAX array program is synchronous, so we model the same effect where it
actually lives — in the *resolution order of ties of the quantised priority*:

  H1  pure random permutation                       (paper: hash(v))
  H2  coarse 4-bit Eq. 1 ‖ random tie resolution    (ties resolved by chance,
      mirroring the paper's unordered premature eliminations)
  H3  8-bit Eq. 1 ‖ *ordered* resolution on the pending set: remaining ties
      resolve deterministically by (lower degree, then id) before C is
      finalised — the paper's "Alive → conflict resolution → candidate
      finalisation → state update" pipeline.

ECL-MIS itself uses Eq. 1 at its native ~8-bit discretisation with hashed tie
break, which is what `ecl_priorities` provides.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# low-bit budget for the distinctness permutation: supports |V| < 2^23 ≈ 8.4M,
# which covers the paper's whole suite (max 4.85M vertices).
_LOW_BITS = 23
_LOW_MASK = (1 << _LOW_BITS) - 1


class Priorities(NamedTuple):
    """Total-order priorities plus (for H3) the two-pass resolution key.

    select:  (n,) int32 — used for the phase-① candidate test.
    resolve: optional (n,) int32 — when set, candidate generation runs the
             H3 two-pass: pending by quantised `select`, finalise by strict
             `resolve` order among pending vertices.
    """
    select: jnp.ndarray
    resolve: Optional[jnp.ndarray] = None


def _perm(key: jax.Array, n: int) -> jnp.ndarray:
    return jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))


def eq1_quantized(
    deg: jnp.ndarray, key: jax.Array, bits: int
) -> jnp.ndarray:
    """Paper Eq. (1): P(v) = d̄ / (d̄ + deg(v) − ε(v)), discretised to ``bits``."""
    deg_f = deg.astype(jnp.float32)
    dbar = jnp.mean(deg_f)
    eps = jax.random.uniform(key, deg.shape, minval=0.0, maxval=1.0)
    p = dbar / (dbar + deg_f - eps)
    levels = (1 << bits) - 1
    return jnp.clip((p * levels).astype(jnp.int32), 0, levels)


def h1_priorities(key: jax.Array, n: int, deg: jnp.ndarray) -> Priorities:
    """H1: random priority — maximal parallelism, no structural bias."""
    del deg
    return Priorities(select=_perm(key, n))


def h2_priorities(key: jax.Array, n: int, deg: jnp.ndarray) -> Priorities:
    """H2: coarse degree-aware priority, ties broken by chance."""
    kq, kp = jax.random.split(key)
    q = eq1_quantized(deg, kq, bits=4)
    return Priorities(select=(q << _LOW_BITS) | _perm(kp, n))


def h3_priorities(key: jax.Array, n: int, deg: jnp.ndarray) -> Priorities:
    """H3: fine degree-aware priority + ordered conflict resolution.

    ``select`` keeps only the quantised structural priority (ties allowed —
    tied vertices enter the *pending* set); ``resolve`` is the deterministic
    ordered key (degree-major, id-minor) that finalises C conflict-free.
    """
    kq, _ = jax.random.split(key)
    q = eq1_quantized(deg, kq, bits=8)
    # ordered resolution: lower degree wins, then lower id — encode as a
    # strictly decreasing function so "larger key wins" stays the convention.
    n_arr = jnp.int32(n)
    rank = (-deg.astype(jnp.int32)) * n_arr - jnp.arange(n, dtype=jnp.int32)
    return Priorities(select=(q << _LOW_BITS), resolve=rank)


def ecl_priorities(key: jax.Array, n: int, deg: jnp.ndarray) -> Priorities:
    """ECL-MIS native priority: 8-bit Eq. (1) with hashed low bits."""
    kq, kp = jax.random.split(key)
    q = eq1_quantized(deg, kq, bits=8)
    return Priorities(select=(q << _LOW_BITS) | _perm(kp, n))


HEURISTICS = {
    "h1": h1_priorities,
    "h2": h2_priorities,
    "h3": h3_priorities,
    "ecl": ecl_priorities,
}


def make_priorities(
    heuristic: str, key: jax.Array, n: int, deg: jnp.ndarray
) -> Priorities:
    try:
        fn = HEURISTICS[heuristic]
    except KeyError:
        raise ValueError(f"unknown heuristic {heuristic!r}; options {list(HEURISTICS)}")
    return fn(key, n, deg)

"""TC-MIS (paper Algorithm 2): the three-phase, tile-accelerated MIS.

Per round:

  ① priority max over live neighbours → candidate vector C
     (`phase1='segment'` is paper-faithful — the paper runs ① on CUDA cores;
      `phase1='tiled'` is our beyond-paper variant that reuses the BSR
      schedule, DESIGN.md §6.1)
  ② N_c = A × C as block-tiled SpMV — the paper's tensor-core kernel.
     Lane-packing puts C in lane 0 and the alive mask in lane 1 of the
     (T, L) right-hand side, so one MXU pass also yields live-neighbour
     counts (free on TPU; DESIGN.md §6.2).
  ③ own-state-only update: candidates join Δm, their neighbours (N_c>0) die.
     Lock-free by construction — here that is literal: it is one elementwise
     `where`, fused by XLA into the SpMV epilogue (DESIGN.md §6.3).

The whole loop is one `lax.while_loop`; `run_phases` is the instrumented
python-stepped twin used by the Fig.-1-style phase profiler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.heuristics import Priorities, make_priorities
from repro.core.luby import MISResult
from repro.core.spmv import (
    _NEG,
    neighbor_max_segment,
    neighbor_max_tiled,
    spmv_tiled,
)
from repro.core.tiling import BlockTiledGraph, pack_vertex_vector
from repro.graphs.graph import Graph


@dataclasses.dataclass(frozen=True)
class TCMISConfig:
    heuristic: str = "h3"        # h1 | h2 | h3 | ecl
    lanes: int = 8               # RHS lane count (128 on TPU; 8 keeps CPU cheap)
    backend: str = "ref"         # ref | pallas — phase-② SpMV implementation
    phase1: str = "segment"      # segment (paper-faithful) | tiled (beyond-paper)
    max_rounds: int = 1024


class TCMISState(NamedTuple):
    alive: jnp.ndarray    # (n_padded,) bool
    in_mis: jnp.ndarray   # (n_padded,) bool
    rnd: jnp.ndarray      # int32


def _pad_priorities(pri: Priorities, tiled: BlockTiledGraph) -> Priorities:
    n_pad = tiled.n_padded - (pri.select.shape[0])
    pad = lambda x: jnp.pad(x, (0, n_pad), constant_values=int(_NEG)) if n_pad else x
    return Priorities(
        select=pad(pri.select),
        resolve=None if pri.resolve is None else pad(pri.resolve),
    )


def _phase1_candidates(
    g: Graph,
    tiled: BlockTiledGraph,
    pri: Priorities,
    alive: jnp.ndarray,
    cfg: TCMISConfig,
) -> jnp.ndarray:
    """① Max_Np + candidate test (+ H3 pending-set resolution).  All shapes
    are n_padded; the segment path round-trips through the unpadded view."""
    n = g.n_nodes

    def nbr_max(p, mask):
        if cfg.phase1 == "tiled":
            return neighbor_max_tiled(tiled, p, mask, backend=cfg.backend)
        out = neighbor_max_segment(g, p[:n], mask[:n])
        return pack_vertex_vector(out, tiled)

    max_np = nbr_max(pri.select, alive)
    if pri.resolve is None:
        return alive & (pri.select > max_np)
    # H3: conflicts resolved on the pending set before C is finalised.
    pending = alive & (pri.select >= max_np)
    max_res = nbr_max(pri.resolve, pending)
    return pending & (pri.resolve > max_res)


def _phase2_counts(
    tiled: BlockTiledGraph, cand: jnp.ndarray, alive: jnp.ndarray, cfg: TCMISConfig
) -> jnp.ndarray:
    """② N_c = A × C on the tiled representation (lane 0 = C, lane 1 = alive)."""
    rhs = jnp.zeros((tiled.n_padded, cfg.lanes), dtype=jnp.float32)
    rhs = rhs.at[:, 0].set(cand.astype(jnp.float32))
    rhs = rhs.at[:, 1].set(alive.astype(jnp.float32))
    out = spmv_tiled(tiled, rhs, backend=cfg.backend)
    return out[:, 0]


def _phase3_update(
    state: TCMISState, cand: jnp.ndarray, n_c: jnp.ndarray
) -> TCMISState:
    """③ lock-free own-state update (paper's three rules, verbatim)."""
    in_mis = state.in_mis | cand
    alive = state.alive & ~cand & ~(n_c > 0)
    return TCMISState(alive=alive, in_mis=in_mis, rnd=state.rnd + 1)


def tc_mis(
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config: TCMISConfig = TCMISConfig(),
) -> MISResult:
    """Run TC-MIS to convergence inside one `lax.while_loop`."""
    n = g.n_nodes
    pri = _pad_priorities(
        make_priorities(config.heuristic, key, n, g.degrees()), tiled
    )

    def cond(state: TCMISState):
        return jnp.any(state.alive) & (state.rnd < config.max_rounds)

    def body(state: TCMISState):
        cand = _phase1_candidates(g, tiled, pri, state.alive, config)
        n_c = _phase2_counts(tiled, cand, state.alive, config)
        return _phase3_update(state, cand, n_c)

    alive0 = pack_vertex_vector(jnp.ones((n,), dtype=bool), tiled)
    state0 = TCMISState(
        alive=alive0,
        in_mis=jnp.zeros((tiled.n_padded,), dtype=bool),
        rnd=jnp.int32(0),
    )
    final = jax.lax.while_loop(cond, body, state0)
    return MISResult(
        in_mis=final.in_mis[:n],
        rounds=final.rnd,
        converged=~jnp.any(final.alive),
    )


# --------------------------------------------------------------------------
# instrumented twin (python-stepped) for the Fig.-1 phase profiler
# --------------------------------------------------------------------------

def run_phases(
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config: TCMISConfig = TCMISConfig(),
    warmup: bool = True,
) -> Tuple[MISResult, Dict[str, float]]:
    """Same algorithm, stepped from python with per-phase wall-clock timers.

    Used only by benchmarks — the jitted `tc_mis` is the production entry.
    Returns (result, {"phase1": s, "phase2": s, "phase3": s, "rounds": k}).
    """
    n = g.n_nodes
    pri = _pad_priorities(
        make_priorities(config.heuristic, key, n, g.degrees()), tiled
    )

    p1 = jax.jit(
        lambda alive: _phase1_candidates(g, tiled, pri, alive, config)
    )
    p2 = jax.jit(lambda cand, alive: _phase2_counts(tiled, cand, alive, config))
    p3 = jax.jit(
        lambda alive, in_mis, rnd, cand, n_c: _phase3_update(
            TCMISState(alive, in_mis, rnd), cand, n_c
        )
    )

    alive = pack_vertex_vector(jnp.ones((n,), dtype=bool), tiled)
    in_mis = jnp.zeros((tiled.n_padded,), dtype=bool)
    rnd = jnp.int32(0)

    if warmup:  # compile outside the timers
        c = p1(alive)
        nc = p2(c, alive)
        p3(alive, in_mis, rnd, c, nc)[0].block_until_ready()

    times = {"phase1": 0.0, "phase2": 0.0, "phase3": 0.0}
    rounds = 0
    while bool(jnp.any(alive)) and rounds < config.max_rounds:
        t0 = time.perf_counter()
        cand = p1(alive)
        cand.block_until_ready()
        t1 = time.perf_counter()
        n_c = p2(cand, alive)
        n_c.block_until_ready()
        t2 = time.perf_counter()
        alive, in_mis, rnd = p3(alive, in_mis, rnd, cand, n_c)
        alive.block_until_ready()
        t3 = time.perf_counter()
        times["phase1"] += t1 - t0
        times["phase2"] += t2 - t1
        times["phase3"] += t3 - t2
        rounds += 1
    times["rounds"] = rounds
    result = MISResult(
        in_mis=in_mis[:n], rounds=jnp.int32(rounds), converged=~jnp.any(alive)
    )
    return result, times

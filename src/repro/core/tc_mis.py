"""TC-MIS (paper Algorithm 2): the three-phase, tile-accelerated MIS.

Per round:

  ① priority max over live neighbours → candidate vector C
     (`phase1='segment'` is paper-faithful — the paper runs ① on CUDA cores;
      `phase1='tiled'` is our beyond-paper variant that reuses the BSR
      schedule, DESIGN.md §6.1)
  ② N_c = A × C as block-tiled SpMV — the paper's tensor-core kernel.
     Lane-packing puts C in lane 0 and the alive mask in lane 1 of the
     (T, L) right-hand side, so one MXU pass also yields live-neighbour
     counts (free on TPU; DESIGN.md §6.2).
  ③ own-state-only update: candidates join Δm, their neighbours (N_c>0) die.
     Lock-free by construction — here that is literal: it is one elementwise
     `where`, fused by XLA into the SpMV epilogue (DESIGN.md §6.3), or run
     INSIDE the kernel epilogue by the `fused_pallas` engine.

How a round executes is delegated to a `RoundEngine` (core.engine): the
`backend` config field names an engine from the registry.  Both drivers here
— the jitted `lax.while_loop` production entry and the python-stepped
profiler twin — run the SAME engine round body.

**Public entry points live in `repro.api`** (DESIGN.md §10): `Solver.solve`
wraps `_tc_mis_impl`, `Solver.profile` wraps `_run_phases_impl`.  The
module-level `tc_mis` / `run_phases` and `TCMISConfig` remain as thin
deprecated shims for pre-API callers.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (
    EngineContext,
    MISRoundState,
    get_engine,
    make_bitwise_context,
    phase3_update,
    phase3_update_bits,
    resolve_frontier,
    round_increment,
)
from repro.core.heuristics import Priorities, make_priorities
from repro.core.luby import MISResult
from repro.core.spmv import _NEG
from repro.core.tiling import (
    BlockTiledGraph,
    pack_frontier_words,
    pack_vertex_vector,
    unpack_frontier_words,
)
from repro.graphs.graph import Graph
from repro.obs.rounds import TELEMETRY_COLS, TELEMETRY_FILL

# back-compat alias: the round state now lives with the engine layer
TCMISState = MISRoundState


@dataclasses.dataclass(frozen=True)
class TCMISConfig:
    """DEPRECATED algorithm-knob bundle — superseded by
    `repro.api.SolveOptions` (which adds preprocessing + placement policy).
    Kept as the shim config for `tc_mis`/`run_phases` callers."""
    heuristic: str = "h3"        # h1 | h2 | h3 | ecl
    lanes: int = 8               # RHS lane count (128 on TPU; 8 keeps CPU cheap)
    backend: str = "ref"         # engine name: segment | tiled_ref |
                                 # tiled_pallas | fused_pallas (ref/pallas ok)
    phase1: str = "segment"      # segment (paper-faithful) | tiled (beyond-paper)
    skip_dma: bool = False       # empty-C slabs also skip their HBM read
    max_rounds: int = 1024
    frontier: str = "auto"       # auto | dense | bitwise (DESIGN.md §13)


def _pad_priorities(pri: Priorities, tiled: BlockTiledGraph) -> Priorities:
    n_pad = tiled.n_padded - (pri.select.shape[0])
    pad = lambda x: jnp.pad(x, (0, n_pad), constant_values=int(_NEG)) if n_pad else x
    return Priorities(
        select=pad(pri.select),
        resolve=None if pri.resolve is None else pad(pri.resolve),
    )


def _setup(
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config,
    priorities: Priorities | None = None,
    alive0: jnp.ndarray | None = None,
    col_gate: jnp.ndarray | None = None,
    member_rounds: bool = False,
    in_mis0: jnp.ndarray | None = None,
):
    """Shared run prologue: engine resolution, context, priorities, state₀.

    `config` is any options bundle with backend/heuristic/lanes/phase1/
    skip_dma/max_rounds (`repro.api.SolveOptions` or the `TCMISConfig` shim).

    `priorities` / `alive0` / `col_gate` are the batch-serving overrides
    (repro.api.Solver.solve_many): a block-diagonal packed graph must carry
    *per-graph* priorities (each member graph's own key and degree statistics
    — Eq. 1's d̄ is per-graph, so batch-wide `make_priorities` would change
    every member's solution) and must start padding-slot vertices dead so
    they never enter the MIS or cost a round.  When `priorities` is given,
    `key` is unused; vectors may be `n_nodes`- or `n_padded`-long.

    `member_rounds` switches `rnd` to the per-vertex counting mode
    (core.engine.MISRoundState): each vertex's counter advances only while
    it is alive, so a packed member's own convergence round is the max over
    its slot — not the batch-slowest.

    `in_mis0` is the warm-start override (repro.dyngraph.repair): seed the
    MIS set with a prior solution so the convergence loop only works the
    dirty frontier the caller left alive.  Callers guarantee `in_mis0` is
    independent in `g` and disjoint from `alive0` — the engine preserves
    both invariants but never re-checks them.  In bitwise runs `alive0`/
    `in_mis0` may arrive already packed as (nbc, W) uint32 words (the repair
    path hands its warm state over without densifying) — detected by
    shape/dtype.
    """
    engine = get_engine(config.backend)
    if priorities is None:
        priorities = make_priorities(config.heuristic, key, g.n_nodes, g.degrees())
    pri = _pad_priorities(priorities, tiled)
    frontier = resolve_frontier(
        config, engine, storage=tiled.storage, member_rounds=member_rounds
    )
    bits = None
    if frontier == "bitwise":
        # plane stacks only where the plane-scan kernel actually runs (real
        # TPU); everywhere else the clz formulation needs no planes.
        planes = engine.plane_kernel_nbr_max and jax.default_backend() == "tpu"
        # hybrid runs walk only the compacted dense partition with the tile
        # machinery — build the sorted-tile / word structures over it, not
        # the full list (the sparse tail never touches them, DESIGN.md §16)
        bits_tiled = tiled
        if engine.supports_hybrid and tiled.partition is not None:
            bits_tiled = tiled.partition.dense
        bits = make_bitwise_context(bits_tiled, pri, planes=planes)
    ctx = EngineContext(
        g=g, tiled=tiled, cfg=config, col_gate=col_gate,
        frontier=frontier, bits=bits,
    )
    if alive0 is None:
        alive0 = jnp.ones((g.n_nodes,), dtype=bool)

    def as_state_vec(x):
        """Vertex mask → the state representation of this run: (n_padded,)
        bool dense, or (nbc, W) uint32 words when the frontier is bitwise.
        Already-packed inputs pass through."""
        if getattr(x, "ndim", 0) == 2 and x.dtype == jnp.uint32:
            return x
        padded = pack_vertex_vector(x.astype(bool), tiled)
        if frontier == "bitwise":
            return pack_frontier_words(padded, tiled.tile_size)
        return padded

    rnd0 = (
        jnp.zeros((tiled.n_padded,), dtype=jnp.int32)
        if member_rounds
        else jnp.int32(0)
    )
    zero_mis = jnp.zeros((tiled.n_padded,), dtype=bool)
    state0 = MISRoundState(
        alive=as_state_vec(alive0),
        in_mis=as_state_vec(zero_mis if in_mis0 is None else in_mis0),
        rnd=rnd0,
    )
    return engine, ctx, pri, state0


def _result(final: MISRoundState, g: Graph, tiled: BlockTiledGraph) -> MISResult:
    """Run epilogue — and, for bitwise runs, THE single sanctioned unpack
    site on the solve path: packed `in_mis` words densify here, after the
    convergence loop, never inside it (tools/ci_guards.py allowlists this
    function by name)."""
    in_mis = final.in_mis
    if getattr(in_mis, "ndim", 0) == 2 and in_mis.dtype == jnp.uint32:
        in_mis = unpack_frontier_words(in_mis, tiled.tile_size)
    rounds = final.rnd[: g.n_nodes] if getattr(final.rnd, "ndim", 0) else final.rnd
    return MISResult(
        in_mis=in_mis[: g.n_nodes],
        rounds=rounds,
        converged=~jnp.any(final.alive),
    )


def _tc_mis_impl(
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config,
    *,
    priorities: Priorities | None = None,
    alive0: jnp.ndarray | None = None,
    col_gate: jnp.ndarray | None = None,
    member_rounds: bool = False,
    in_mis0: jnp.ndarray | None = None,
) -> MISResult:
    """Run TC-MIS to convergence inside one `lax.while_loop`.

    The production driver behind `repro.api.Solver.solve`/`solve_many`; the
    whole function is jit-compatible with `config` static, which is how the
    Solver amortises ONE compiled dispatch per shape bucket over every
    request in a batch.  With `member_rounds`, `MISResult.rounds` is the
    per-vertex settle-round vector (sliced to real vertices) instead of the
    global round count.  `alive0`+`in_mis0` together are the warm-start
    seam (`repro.dyngraph.repair`): an already-converged warm state runs
    ZERO rounds — the while_loop condition fails on entry.
    """
    engine, ctx, pri, state0 = _setup(
        g, tiled, key, config, priorities, alive0, col_gate, member_rounds,
        in_mis0,
    )

    def cond(state: MISRoundState):
        return jnp.any(state.alive) & (jnp.max(state.rnd) < config.max_rounds)

    if not getattr(config, "telemetry", False):
        final = jax.lax.while_loop(
            cond, lambda s: engine.step(ctx, pri, s), state0
        )
        return _result(final, g, tiled)

    # Telemetry run (SolveOptions.telemetry; the deprecated TCMISConfig
    # never sets it): the loop carries a fixed-shape (max_rounds, K) int32
    # buffer, round r writes row r via `engine.step_with_stats`, and the
    # return becomes (result, buffer) — ONE device→host transfer when the
    # caller materialises the buffer at the epilogue (RoundTrace.from_buffer).
    # The flag is static under jit, so the telemetry-off program above stays
    # the byte-exact pre-telemetry while_loop (DESIGN.md §14).
    buf0 = jnp.full(
        (int(config.max_rounds), TELEMETRY_COLS), TELEMETRY_FILL, jnp.int32
    )

    def body(carry):
        s, buf = carry
        new, row = engine.step_with_stats(ctx, pri, s)
        # max(rnd) is the current round index in BOTH counting modes: a
        # scalar rnd counts rounds directly, and in member_rounds mode every
        # currently-alive vertex has incremented in every prior round (alive
        # is monotone per vertex), so the max over vertices is the round
        # index while anything is alive — which `cond` guarantees here.
        return new, buf.at[jnp.max(s.rnd)].set(row)

    final, buf = jax.lax.while_loop(
        lambda c: cond(c[0]), body, (state0, buf0)
    )
    return _result(final, g, tiled), buf


# --------------------------------------------------------------------------
# instrumented twin (python-stepped) for the Fig.-1 phase profiler
# --------------------------------------------------------------------------

def _run_phases_impl(  # repro-lint: disable=RPR010,RPR011 host-stepped profiler twin: per-phase wall timing requires sync
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config,
    warmup: bool = True,
    *,
    priorities: Priorities | None = None,
    alive0: jnp.ndarray | None = None,
    col_gate: jnp.ndarray | None = None,
    member_rounds: bool = False,
) -> Tuple[MISResult, Dict[str, float]]:
    """Same engine round body, stepped from python with per-phase timers.

    The driver behind `repro.api.Solver.profile` — benchmarks only; the
    jitted `_tc_mis_impl` is the production entry.
    Returns (result, {"phase1": s, "phase2": s, "phase3": s, "rounds": k}).
    For fused engines the ②+③ kernel pass is charged to phase2 and the
    residual state merge to phase3.
    """
    engine, ctx, pri, state0 = _setup(
        g, tiled, key, config, priorities, alive0, col_gate, member_rounds
    )

    # Hybrid runs always profile as SPLIT ②+③: fused engines demote under a
    # partition (the in-kernel ③ cannot merge the sparse-tail hits), exactly
    # like the production `step_hybrid` path.
    hybrid = engine.supports_hybrid and ctx.tiled.partition is not None
    fused_call = engine.fused and not hybrid
    if hybrid:
        dctx = dataclasses.replace(ctx, tiled=ctx.tiled.partition.dense)
    if ctx.frontier == "bitwise":
        # the packed-frontier round body, split at the same phase seams
        if hybrid:
            p1 = jax.jit(
                lambda alive: engine._hybrid_candidates_bits(ctx, dctx, pri, alive)
            )
            p2 = jax.jit(
                lambda cand, alive: engine._dense_hits_bits(
                    dctx, cand, alive, engine.col_flags_bits(ctx, cand)
                )
                | engine._sparse_hits_bits(ctx, cand)
            )
            p3 = jax.jit(phase3_update_bits)
        elif fused_call:
            p1 = jax.jit(lambda alive: engine.phase1_candidates_bits(ctx, pri, alive))
            p2 = jax.jit(
                lambda cand, alive: engine.fused_step_bits(
                    ctx, cand, alive, engine.col_flags_bits(ctx, cand)
                )
            )
            p3 = jax.jit(
                lambda state, out, inc: MISRoundState(
                    alive=out[0], in_mis=state.in_mis | out[1], rnd=state.rnd + inc
                )
            )
        else:
            p1 = jax.jit(lambda alive: engine.phase1_candidates_bits(ctx, pri, alive))
            p2 = jax.jit(
                lambda cand, alive: engine.phase2_hits(
                    ctx, cand, alive, engine.col_flags_bits(ctx, cand)
                )
            )
            p3 = jax.jit(phase3_update_bits)
    else:
        if hybrid:
            p1 = jax.jit(
                lambda alive: engine._hybrid_candidates(ctx, dctx, pri, alive)
            )
            p2 = jax.jit(
                lambda cand, alive: engine._dense_phase2(
                    dctx, cand, alive, engine.col_flags(dctx, cand, alive)
                )
                + engine._sparse_counts(ctx, cand)
            )
            p3 = jax.jit(phase3_update)
        elif fused_call:
            p1 = jax.jit(lambda alive: engine.phase1_candidates(ctx, pri, alive))
            p2 = jax.jit(
                lambda cand, alive: engine.fused_step(
                    ctx, cand, alive, engine.col_flags(ctx, cand, alive)
                )
            )
            p3 = jax.jit(
                lambda state, out, inc: MISRoundState(
                    alive=out[0], in_mis=state.in_mis | out[1], rnd=state.rnd + inc
                )
            )
        else:
            p1 = jax.jit(lambda alive: engine.phase1_candidates(ctx, pri, alive))
            p2 = jax.jit(
                lambda cand, alive: engine.phase2_counts(
                    ctx, cand, alive, engine.col_flags(ctx, cand, alive)
                )
            )
            p3 = jax.jit(phase3_update)

    def advance(state, cand, out):
        inc = round_increment(state)
        return p3(state, out, inc) if fused_call else p3(state, cand, out, inc)

    if warmup:  # compile outside the timers
        c = p1(state0.alive)
        out = p2(c, state0.alive)
        step = advance(state0, c, out)
        step.alive.block_until_ready()

    state = state0
    times = {"phase1": 0.0, "phase2": 0.0, "phase3": 0.0}
    rounds = 0
    while bool(jnp.any(state.alive)) and rounds < config.max_rounds:
        t0 = time.perf_counter()
        cand = p1(state.alive)
        cand.block_until_ready()
        t1 = time.perf_counter()
        out = p2(cand, state.alive)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        state = advance(state, cand, out)
        state.alive.block_until_ready()
        t3 = time.perf_counter()
        times["phase1"] += t1 - t0
        times["phase2"] += t2 - t1
        times["phase3"] += t3 - t2
        rounds += 1
    times["rounds"] = rounds
    result = _result(state, g, tiled)
    if not member_rounds:
        result = result._replace(rounds=jnp.int32(rounds))
    return result, times


# --------------------------------------------------------------------------
# deprecated shims — the pre-`repro.api` entry points
# --------------------------------------------------------------------------

def tc_mis(
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config: TCMISConfig = TCMISConfig(),
    *,
    priorities: Priorities | None = None,
    alive0: jnp.ndarray | None = None,
    col_gate: jnp.ndarray | None = None,
    member_rounds: bool = False,
) -> MISResult:
    """DEPRECATED: use `repro.api.Solver`.

    `Solver(SolveOptions(engine=..., tile_size=...)).solve(graph)` plans,
    routes and runs in one call; `Solver.solve_many` replaces the
    `priorities`/`alive0`/`col_gate` batch-kwarg spelling."""
    warnings.warn(
        "tc_mis(g, tiled, key, config) is deprecated; use repro.api: "
        "Solver(SolveOptions(engine=..., tile_size=...)).solve(graph) "
        "(solve_many for batches)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _tc_mis_impl(
        g, tiled, key, config,
        priorities=priorities, alive0=alive0, col_gate=col_gate,
        member_rounds=member_rounds,
    )


def run_phases(
    g: Graph,
    tiled: BlockTiledGraph,
    key: jax.Array,
    config: TCMISConfig = TCMISConfig(),
    warmup: bool = True,
    *,
    priorities: Priorities | None = None,
    alive0: jnp.ndarray | None = None,
    col_gate: jnp.ndarray | None = None,
) -> Tuple[MISResult, Dict[str, float]]:
    """DEPRECATED: use `repro.api.Solver.profile(graph)`."""
    warnings.warn(
        "run_phases(...) is deprecated; use repro.api: "
        "Solver(SolveOptions(engine=...)).profile(graph)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _run_phases_impl(
        g, tiled, key, config, warmup,
        priorities=priorities, alive0=alive0, col_gate=col_gate,
    )

"""Distribution helpers: partition-spec policies + elastic resharding.

`repro.core.distributed` owns the MIS-specific shard_map algorithm; this
package owns the generic machinery every arch family shares — how params,
caches and batches map onto a mesh (`sharding`), and how checkpoints move
between meshes (`elastic`).
"""
from repro.dist.sharding import (
    batch_spec,
    cache_specs,
    data_axes,
    deepfm_specs,
    lm_param_specs,
)

__all__ = [
    "batch_spec", "cache_specs", "data_axes", "deepfm_specs", "lm_param_specs",
]

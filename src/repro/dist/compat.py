"""jax version compatibility for the distribution layer.

The repo targets the modern sharding surface — `jax.sharding.AxisType`,
`jax.make_mesh(..., axis_types=...)`, `jax.shard_map(..., check_vma=...)` —
but must also run on 0.4.x jax where those are `jax.experimental.shard_map`
with `check_rep` and a `make_mesh` without axis types.  Library code calls
the dispatching functions below; scripts written against the modern API
verbatim (e.g. the subprocess tests) call `install()` once to backfill the
missing names onto the jax namespace.
"""
from __future__ import annotations

import enum
import inspect
from typing import Any, Optional, Sequence

import jax

_ORIG_MAKE_MESH = jax.make_mesh   # bound pre-install (install() rebinds jax.make_mesh to our wrapper)
_MODERN_MESH = "axis_types" in inspect.signature(_ORIG_MAKE_MESH).parameters


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[Sequence[Any]] = None,
    **kw,
):
    """`jax.make_mesh` that tolerates `axis_types` on every jax version
    (older jax has no explicit/auto axis distinction — dropping the kwarg
    reproduces its only behaviour, fully-auto axes)."""
    if _MODERN_MESH and axis_types is not None:
        kw["axis_types"] = tuple(axis_types)
    return _ORIG_MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kw)


_ORIG_SHARD_MAP = getattr(jax, "shard_map", None)   # pre-install binding


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` on modern jax, `jax.experimental.shard_map` (with the
    pre-rename `check_rep` flag) on 0.4.x."""
    if _ORIG_SHARD_MAP is not None:
        return _ORIG_SHARD_MAP(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


class _AxisType(enum.Enum):
    """Stand-in for `jax.sharding.AxisType` (values match the modern enum
    names; on old jax every mesh axis is implicitly Auto)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _normalized_cost_analysis() -> None:
    """0.4.x `Compiled.cost_analysis()` returns a one-element list of dicts;
    modern jax returns the dict itself.  Normalize to the dict form."""
    orig = jax.stages.Compiled.cost_analysis
    if getattr(orig, "_repro_normalized", False):
        return

    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, (list, tuple)):
            return out[0] if out else {}
        return out

    cost_analysis._repro_normalized = True
    jax.stages.Compiled.cost_analysis = cost_analysis


def install() -> None:
    """Backfill the modern names onto jax for code written against them.

    Idempotent; a no-op on jax versions that already provide the surface."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not _MODERN_MESH:
        jax.make_mesh = make_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    _normalized_cost_analysis()

"""Partition-spec policies (DESIGN.md §5): how each param/activation family
maps onto the production mesh.

Axis convention: the mesh has a 'model' axis (tensor parallelism) and one or
more batch axes — 'data', optionally preceded by 'pod'.  `data_axes` returns
the batch axes as a tuple; specs place that tuple on batch-like dimensions so
the same policy serves (data, model) single-pod and (pod, data, model)
multi-pod meshes unchanged.

Every rule is divisibility-guarded: a dimension that doesn't divide by its
target axis size stays replicated (the dry-run sweeps many meshes; a policy
must never fail to lower, only degrade to replication).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch axes: every mesh axis except 'model' ('pod' composes with
    'data' — cross-pod traffic is then only gradient/frontier collectives)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_size(mesh: Mesh, axes: Union[str, Tuple[str, ...], None]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    shape = dict(mesh.shape)
    return int(np.prod([shape[a] for a in axes])) if axes else 1


def batch_spec(mesh: Mesh, extra_dims: int = 0) -> P:
    """Batch-sharded leading dim + `extra_dims` replicated trailing dims."""
    return P(data_axes(mesh), *([None] * extra_dims))


def _model_size(mesh: Mesh) -> int:
    return dict(mesh.shape).get("model", 1)


# --------------------------------------------------------------------------
# LM params — Megatron-style tensor parallelism on 'model', optional FSDP
# --------------------------------------------------------------------------

# leaf name -> which dim (counted from the END, so layer-stacked leaves with
# a leading L axis share the rule with unstacked ones) carries 'model'
_TP_FROM_END = {
    # column-parallel projections: output features sharded
    "wq": 1, "wk": 1, "wv": 1, "wqkv": 1, "bq": 1, "bk": 1, "bv": 1,
    "w1": 1, "w3": 1, "w13": 1, "ws1": 1, "ws3": 1,
    "w_dq": 1, "w_uq": 1,
    "head": 1, "proj": 1,
    # row-parallel projections: input features sharded (output all-reduced)
    "wo": 2, "w2": 2, "ws2": 2,
    # MLA per-head factors: shard the head dim
    "w_uk": 3, "w_uv": 3,
    # vocab-parallel embedding
    "embed": 2,
}
# expert stacks (L, E, D, d_expert)-ish: prefer expert parallelism on E,
# fall back to feature TP when n_experts doesn't divide the model axis
_EXPERT_FROM_END = {"we1": (3, 1), "we3": (3, 1), "we2": (3, 2)}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _spec_with(leaf, dim_from_end: Optional[int], axis, axis_size: int) -> P:
    """P placing `axis` at ndim-dim_from_end if the dim divides; else P()."""
    nd = len(leaf.shape)
    if (
        dim_from_end is None
        or axis_size <= 1
        or dim_from_end > nd
        or leaf.shape[nd - dim_from_end] % axis_size != 0
    ):
        return P()
    parts: list = [None] * nd
    parts[nd - dim_from_end] = axis
    return P(*parts)


def _fsdp_extend(spec: P, leaf, dp: Tuple[str, ...], dp_size: int) -> P:
    """ZeRO-3-style extension: shard the largest still-replicated dim over
    the batch axes (same policy as optimizer.zero1_specs)."""
    if dp_size <= 1:
        return spec
    parts = list(spec) if len(spec) else []
    while len(parts) < len(leaf.shape):
        parts.append(None)
    order = sorted(range(len(parts)), key=lambda i: -leaf.shape[i])
    for i in order:
        if parts[i] is None and leaf.shape[i] % dp_size == 0:
            parts[i] = dp
            return P(*parts)
    return spec


def lm_param_specs(params_sh: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """PartitionSpec tree for `transformer.init_lm` params.

    params_sh: the param pytree (ShapeDtypeStructs from eval_shape is enough).
    fsdp: additionally shard each leaf over the batch axes (for archs whose
    model-parallel-only shards exceed per-chip HBM).
    """
    msz = _model_size(mesh)
    dp = data_axes(mesh)
    dp_size = _axis_size(mesh, dp)

    def rule(path, leaf):
        name = _leaf_name(path)
        if name in _EXPERT_FROM_END:
            expert_dim, feat_dim = _EXPERT_FROM_END[name]
            nd = len(leaf.shape)
            if msz > 1 and expert_dim <= nd and leaf.shape[nd - expert_dim] % msz == 0:
                spec = _spec_with(leaf, expert_dim, "model", msz)
            else:
                spec = _spec_with(leaf, feat_dim, "model", msz)
        else:
            spec = _spec_with(leaf, _TP_FROM_END.get(name), "model", msz)
        if fsdp:
            spec = _fsdp_extend(spec, leaf, dp, dp_size)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_sh)


def cache_specs(cfg, mesh: Mesh, batch: int, length: int):
    """DecodeCache spec: batch over the data axes, KV heads over 'model'.

    GQA caches are (L, B, C, Hkv, dh); MLA latent caches (L, B, C, r) have
    no head dim — the latent is replicated across the model axis (it is the
    absorbed-weight trade: tiny cache, model-parallel up-projections)."""
    from repro.models.transformer import DecodeCache

    dp = data_axes(mesh)
    b_axes = dp if batch % max(_axis_size(mesh, dp), 1) == 0 else None
    msz = _model_size(mesh)
    if cfg.mla is not None:
        latent = P(None, b_axes, None, None)
        data = {"ckv": latent, "krope": latent}
    else:
        h_axes = "model" if (msz > 1 and cfg.n_kv_heads % msz == 0) else None
        kv = P(None, b_axes, None, h_axes, None)
        data = {"k": kv, "v": kv}
    return DecodeCache(data=data, pos=P(), length=length)


# --------------------------------------------------------------------------
# recsys — vocab-parallel embedding tables over the WHOLE mesh
# --------------------------------------------------------------------------

def deepfm_specs(params_sh: Any, mesh: Mesh) -> Any:
    """DeepFM: the ~34M-row embedding/linear tables are the footprint, so
    their vocab dim shards over every mesh axis; the MLP tower is small and
    takes plain feature TP."""
    flat = tuple(mesh.axis_names)
    full = _axis_size(mesh, flat)
    msz = _model_size(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        if name in ("embed", "linear"):
            if leaf.shape[0] % max(full, 1) == 0:
                return P(flat, *([None] * (len(leaf.shape) - 1)))
            return _spec_with(leaf, len(leaf.shape), "model", msz)
        if name == "ws" or (len(path) >= 2 and _leaf_name(path[:-1]) == "ws"):
            return _spec_with(leaf, 1, "model", msz)
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_sh)

"""Elastic rescale: restore a checkpoint onto a DIFFERENT mesh.

Checkpoints store logical (fully-gathered) arrays, so growing from one host
to a pod — or shrinking back — is just a resharding policy applied at
restore: build target shardings from the manifest's shapes (no payload
reads), then stream each leaf through `checkpoint.restore`'s per-leaf
`device_put` so host memory stays bounded by the largest leaf.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train import checkpoint as ckpt


def reshard_checkpoint(
    ckpt_dir: str,
    step: int,
    mesh: Mesh,
    spec_fn: Callable[[Any, Mesh], Any],
) -> Any:
    """Restore checkpoint `step` sharded onto `mesh`.

    spec_fn(shapes_tree, mesh) -> PartitionSpec tree: the placement policy,
    called with the checkpoint's ShapeDtypeStruct pytree (e.g. wrap
    `dist.sharding.lm_param_specs`).  Returns the restored pytree with every
    leaf device_put under its NamedSharding.
    """
    shapes = ckpt.tree_shapes(ckpt_dir, step)
    specs = spec_fn(shapes, mesh)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return ckpt.restore(ckpt_dir, step, shardings=shardings)

"""Fault-tolerant training loop: checkpoint/restart, straggler deadlines,
retry-on-failure, metrics logging.

The loop is deliberately host-side-dumb: ALL numerics live in the jitted
`step_fn`; the loop only moves batches, enforces deadlines, checkpoints, and
recovers.  Recovery semantics:

* **restart**: on construction the loop restores the newest *valid*
  checkpoint (corrupted ones are detected by crc and skipped) and seeks the
  data stream to that step — training resumes bitwise-identically (tested).
* **step failure** (a worker exception — on real pods, a NCCL/ICI timeout or
  preemption): the step is retried up to `max_retries` from the last good
  state; past that, the loop restores the last checkpoint and continues.
* **straggler deadline**: each step has a wall-clock budget
  (`deadline_factor` × rolling median).  Breaches are logged and counted —
  on real hardware this hook triggers the replacement/rebalance path; on CPU
  we record them (simulation documented in DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    max_retries: int = 2
    deadline_factor: float = 5.0   # × rolling median step time
    log_path: Optional[str] = None


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,           # (state, batch) -> (state, metrics)
        init_state: Any,
        stream,                      # has .batch_at(step)
        cfg: LoopConfig,
        to_device: Callable = lambda b: b,
    ):
        self.step_fn = step_fn
        self.stream = stream
        self.cfg = cfg
        self.to_device = to_device
        self.step_times: list = []
        self.straggler_events = 0
        self.recoveries = 0

        restored_step, restored = ckpt.restore_latest(cfg.ckpt_dir)
        if restored is not None:
            self.state = restored
            self.start_step = restored_step + 1
        else:
            self.state = init_state
            self.start_step = 0

    # -- internals ----------------------------------------------------------

    def _deadline(self) -> Optional[float]:
        if len(self.step_times) < 5:
            return None
        return float(np.median(self.step_times[-20:]) * self.cfg.deadline_factor)

    def _log(self, record: dict) -> None:
        if self.cfg.log_path:
            with open(self.cfg.log_path, "a") as f:
                f.write(json.dumps(record) + "\n")

    def _checkpoint(self, step: int) -> None:
        ckpt.save(self.cfg.ckpt_dir, step, self.state)
        ckpt.garbage_collect(self.cfg.ckpt_dir, keep=self.cfg.keep_checkpoints)

    # -- main entry ----------------------------------------------------------

    def run(self, n_steps: int, fail_hook: Optional[Callable] = None) -> dict:
        """Run up to global step `start_step + n_steps`.

        fail_hook(step) may raise to simulate node failures (used by tests to
        exercise the retry/restore path).
        """
        last_metrics: dict = {}
        for step in range(self.start_step, self.start_step + n_steps):
            batch = self.to_device(self.stream.batch_at(step))
            attempt = 0
            while True:
                t0 = time.perf_counter()
                try:
                    if fail_hook is not None:
                        fail_hook(step)
                    new_state, metrics = self.step_fn(self.state, batch)
                    jax.block_until_ready(jax.tree.leaves(new_state)[0])
                    break
                except ckpt.CorruptCheckpoint:
                    raise
                except Exception as e:  # noqa: BLE001 — worker failure path
                    attempt += 1
                    self.recoveries += 1
                    if attempt <= self.cfg.max_retries:
                        self._log(dict(step=step, event="retry", error=repr(e)))
                        continue
                    # hard failure: restore last good checkpoint and continue
                    restored_step, restored = ckpt.restore_latest(self.cfg.ckpt_dir)
                    self._log(dict(step=step, event="restore", error=repr(e)))
                    if restored is not None:
                        self.state = restored
                    attempt = 0
                    if fail_hook is not None:
                        fail_hook = None  # the "node" has been replaced
            dt = time.perf_counter() - t0
            deadline = self._deadline()
            if deadline is not None and dt > deadline:
                self.straggler_events += 1
                self._log(dict(step=step, event="straggler", dt=dt, deadline=deadline))
            self.step_times.append(dt)
            self.state = new_state
            last_metrics = {
                k: float(np.asarray(v)) for k, v in metrics.items()
            }
            self._log(dict(step=step, dt=dt, **last_metrics))
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self._checkpoint(step)
        final_step = self.start_step + n_steps - 1
        self._checkpoint(final_step)
        return dict(
            final_step=final_step,
            metrics=last_metrics,
            stragglers=self.straggler_events,
            recoveries=self.recoveries,
        )

"""Fault-tolerant checkpointing: atomic, checksummed, mesh-portable.

Format: one directory per step —
    ckpt_dir/step_000123/
        manifest.msgpack   {path -> {shape, dtype, crc32, file}}   + treedef
        arrays/<idx>.npy.zst    zstd-compressed npy payload per leaf

Properties the restart logic relies on:
* **atomic**: written to `step_X.tmp` then `os.replace`d — a crash mid-write
  never produces a directory that `latest_step` will pick up.
* **checksummed**: every leaf carries a crc32; a corrupted checkpoint is
  detected at restore and skipped (restore falls back to the previous step —
  exercised by tests/test_checkpoint.py).
* **mesh-portable**: leaves are stored as *logical* (fully-gathered) arrays,
  so a checkpoint written on a (16,16) mesh restores onto (2,16,16) or a
  single host (elastic scaling; dist/elastic.py re-device_puts with the new
  sharding).  Leaves stream one at a time to bound host memory.
* **zstandard is optional**: payloads are zstd-compressed when the module is
  installed and fall back to stdlib zlib otherwise; the codec is recorded
  per leaf in the manifest.  Restoring a zstd checkpoint on a machine
  without zstandard raises a clear error naming the missing dependency.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import shutil
import zlib
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard

    _CTX = zstandard.ZstdCompressor(level=3)
except ImportError:  # optional dep: fall back to stdlib zlib
    zstandard = None
    _CTX = None


def _compress(raw: bytes) -> Tuple[bytes, str]:
    if _CTX is not None:
        return _CTX.compress(raw), "zstd"
    return zlib.compress(raw, 6), "zlib"


def _decompress(payload: bytes, codec: str) -> bytes:
    """Raises CorruptCheckpoint on damaged frames, RuntimeError on a missing
    codec module (a flipped bit in the frame header fails before the CRC)."""
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd compression but the "
                "'zstandard' module is not installed — `pip install "
                "zstandard` (see requirements.txt) or re-save the checkpoint"
            )
        try:
            return zstandard.ZstdDecompressor().decompress(payload)
        except zstandard.ZstdError as e:
            raise CorruptCheckpoint(f"zstd frame: {e}") from e
    if codec == "zlib":
        try:
            return zlib.decompress(payload)
        except zlib.error as e:
            raise CorruptCheckpoint(f"zlib stream: {e}") from e
    raise CorruptCheckpoint(f"unknown codec {codec!r}")


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically write `tree` as checkpoint `step`. Returns final path."""
    final = _step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest: List[dict] = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        payload, codec = _compress(raw)
        fname = f"{i}.bin.zst" if codec == "zstd" else f"{i}.bin.z"
        with open(os.path.join(tmp, "arrays", fname), "wb") as f:
            f.write(payload)
        manifest.append(
            dict(
                file=fname,
                codec=codec,
                shape=list(arr.shape),
                dtype=str(arr.dtype),
                crc32=zlib.crc32(raw) & 0xFFFFFFFF,
            )
        )
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(
            msgpack.packb(
                dict(step=step, leaves=manifest, treedef=pickle.dumps(treedef).hex())
            )
        )
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class CorruptCheckpoint(RuntimeError):
    pass


def _read_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


def tree_shapes(ckpt_dir: str, step: int) -> Any:
    """The checkpoint's pytree as ShapeDtypeStructs — no payload reads.

    This is how `dist.elastic` builds target shardings before streaming the
    arrays in (spec policies only need shapes)."""
    meta = _read_manifest(_step_dir(ckpt_dir, step))
    treedef = pickle.loads(bytes.fromhex(meta["treedef"]))
    leaves = [
        jax.ShapeDtypeStruct(tuple(m["shape"]), np.dtype(m["dtype"]))
        for m in meta["leaves"]
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(ckpt_dir: str, step: int, *, shardings: Any = None) -> Any:
    """Restore checkpoint `step`.  Raises CorruptCheckpoint on crc mismatch.

    shardings: optional pytree of jax.sharding.Sharding (same structure) —
    each leaf is device_put with its sharding as it streams in (this is the
    elastic-rescale path: any mesh works, the arrays are logical).
    """
    path = _step_dir(ckpt_dir, step)
    meta = _read_manifest(path)
    treedef = pickle.loads(bytes.fromhex(meta["treedef"]))
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    leaves = []
    for i, m in enumerate(meta["leaves"]):
        with open(os.path.join(path, "arrays", m["file"]), "rb") as f:
            try:
                raw = _decompress(f.read(), m.get("codec", "zstd"))
            except CorruptCheckpoint as e:
                raise CorruptCheckpoint(f"{path} leaf {i}: {e}") from e
        if (zlib.crc32(raw) & 0xFFFFFFFF) != m["crc32"]:
            raise CorruptCheckpoint(f"{path} leaf {i}: crc mismatch")
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def available_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_latest(
    ckpt_dir: str, *, shardings: Any = None
) -> Tuple[Optional[int], Any]:
    """Restore the newest *valid* checkpoint, skipping corrupted ones.

    This is the node-failure recovery path: if the most recent checkpoint was
    half-written or bit-flipped, fall back until one verifies.
    """
    for step in reversed(available_steps(ckpt_dir)):
        try:
            return step, restore(ckpt_dir, step, shardings=shardings)
        except (CorruptCheckpoint, FileNotFoundError, ValueError):
            continue
    return None, None


def garbage_collect(ckpt_dir: str, keep: int = 3) -> None:
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(ckpt_dir, s), ignore_errors=True)

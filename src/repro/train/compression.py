"""Gradient compression for the DP all-reduce: top-k + error feedback.

At 1000+-node scale the data-parallel gradient all-reduce is the dominant
inter-pod collective; top-k sparsification with error feedback (Stich et al.,
'18; Lin et al., Deep Gradient Compression '17) cuts its bytes by 10–100×
with negligible quality loss.  The compressor is a pure pytree transform, so
it slots between `jax.grad` and the optimizer in the train step:

    comp, ef = compress_tree(grads + ef_residual, ratio)
    grads'   = decompress_tree(comp)          # what actually gets all-reduced
    ef'      = (grads + ef_residual) - grads' # stays local

The all-reduce itself is whatever the surrounding pjit does — compression
changes *what* is reduced (a sparse tree), not *how*.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedLeaf(NamedTuple):
    values: jnp.ndarray    # (k,) kept magnitudes
    indices: jnp.ndarray   # (k,) int32 flat positions
    size: int              # original flat size (static)


def compress_leaf(g: jnp.ndarray, ratio: float) -> CompressedLeaf:
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return CompressedLeaf(values=flat[idx], indices=idx.astype(jnp.int32), size=flat.size)


def decompress_leaf(c: CompressedLeaf, shape) -> jnp.ndarray:
    return (
        jnp.zeros((c.size,), jnp.float32).at[c.indices].set(c.values).reshape(shape)
    )


def compress_tree(grads: Any, ratio: float) -> Any:
    return jax.tree.map(lambda g: compress_leaf(g, ratio), grads)


def decompress_tree(comp: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda c, g: decompress_leaf(c, g.shape).astype(g.dtype),
        comp,
        like,
        is_leaf=lambda x: isinstance(x, CompressedLeaf),
    )


def ef_init(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_with_error_feedback(
    grads: Any, ef: Any, ratio: float
) -> Tuple[Any, Any]:
    """Returns (dense decompressed grads to reduce/apply, new EF residual)."""
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)
    comp = compress_tree(corrected, ratio)
    dense = decompress_tree(comp, corrected)
    new_ef = jax.tree.map(lambda c, d: c - d, corrected, dense)
    applied = jax.tree.map(lambda d, g: d.astype(g.dtype), dense, grads)
    return applied, new_ef


def compressed_bytes(comp: Any) -> int:
    """Wire bytes of a compressed tree (values f32 + indices i32)."""
    total = 0
    for leaf in jax.tree.leaves(comp, is_leaf=lambda x: isinstance(x, CompressedLeaf)):
        if isinstance(leaf, CompressedLeaf):
            total += leaf.values.size * 4 + leaf.indices.size * 4
    return total

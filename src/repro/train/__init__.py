from repro.train.optimizer import (
    AdamWState,
    OptConfig,
    adamw_init,
    adamw_update,
    global_norm,
    schedule,
    zero1_specs,
)
from repro.train.train_loop import LoopConfig, TrainLoop
from repro.train import checkpoint
from repro.train.compression import (
    compress_tree,
    decompress_tree,
    compress_with_error_feedback,
    ef_init,
)

__all__ = [
    "AdamWState", "OptConfig", "adamw_init", "adamw_update", "global_norm",
    "schedule", "zero1_specs", "LoopConfig", "TrainLoop", "checkpoint",
    "compress_tree", "decompress_tree", "compress_with_error_feedback", "ef_init",
]

"""AdamW with global-norm clipping, warmup+cosine schedule, ZeRO-1 layout.

Plain-pytree implementation (no optax dependency).  Moments are f32
regardless of param dtype (bf16 training keeps f32 optimizer state — the
standard mixed-precision recipe).  `zero1_specs` extends any param
PartitionSpec tree with a 'data'-axis shard on the largest divisible axis,
which is exactly the ZeRO-1 optimizer-state partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(t, 0, 1)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: OptConfig, grads, state: AdamWState, params
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    # flatten explicitly: trees may legitimately contain tuple-typed leaves'
    # containers (e.g. MLP NamedTuples), so tuple-is_leaf tricks are unsafe
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    m_leaves = jax.tree_util.tree_leaves(state.m)
    v_leaves = jax.tree_util.tree_leaves(state.v)
    p_leaves = jax.tree_util.tree_leaves(params)
    triples = [upd(g, m, v, p) for g, m, v, p in
               zip(g_leaves, m_leaves, v_leaves, p_leaves)]
    new_params = jax.tree_util.tree_unflatten(treedef, [t[0] for t in triples])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in triples])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in triples])
    return (
        new_params,
        AdamWState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )


def zero1_specs(param_specs, params, mesh_axis: str = "data", mesh_size: int = 1):
    """ZeRO-1: shard optimizer moments over `mesh_axis` on the largest
    param axis that is divisible and not already sharded."""

    def extend(spec, p):
        parts = list(spec) if spec is not None else [None] * p.ndim
        while len(parts) < p.ndim:
            parts.append(None)
        # an axis name may appear at most once per spec (FSDP'd params
        # already consume the data axes)
        names = set(mesh_axis) if isinstance(mesh_axis, tuple) else {mesh_axis}
        used = set()
        for q in parts:
            if q is None:
                continue
            used |= set(q) if isinstance(q, tuple) else {q}
        if used & names:
            return P(*parts)
        order = sorted(range(p.ndim), key=lambda i: -p.shape[i])
        for i in order:
            if parts[i] is None and p.shape[i] % max(mesh_size, 1) == 0 and mesh_size > 1:
                parts[i] = mesh_axis
                break
        return P(*parts)

    return jax.tree.map(
        extend, param_specs, params, is_leaf=lambda x: isinstance(x, P) or x is None
    )

"""Tile-plan caching — ABSORBED into `repro.api.plan` (DESIGN.md §10).

The `TilePlan`/`PlanCache` machinery that used to live here is now the
public `Plan` artifact of the front-door API; this module re-exports the
old names so pre-API importers (`repro.serve_mis.batcher`, tests) keep
working.  `TilePlan` is literally `repro.api.plan.Plan`.
"""
from repro.api.plan import (  # noqa: F401 — compatibility re-exports
    Plan,
    PlanCache,
    TilePlan,
    build_plan,
    delta_cache_key,
    graph_content_key,
    patch_plan,
    plan_cache_key,
    resolve_storage,
)

__all__ = [
    "Plan", "PlanCache", "TilePlan", "build_plan", "delta_cache_key",
    "graph_content_key", "patch_plan", "plan_cache_key", "resolve_storage",
]

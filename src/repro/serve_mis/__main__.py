"""MIS serving CLI.

One-shot (CI smoke / batch jobs): solve the named files and exit non-zero
unless every response is a validated MIS:

    PYTHONPATH=src python -m repro.serve_mis --once \
        tests/fixtures/tiny.mtx tests/fixtures/tiny.edges

Streaming: with no ``--once``, graph file paths are read one per line from
stdin and dispatched whenever a full batch accumulates (EOF drains the
queue) — `cat work.list | python -m repro.serve_mis`.

``--repeat N`` submits every input N times — the way to watch the tile-plan
cache and compiled-program reuse do their job in the stats output.

Dynamic graphs (DESIGN.md §12): the ``update`` verb patches a served
request's graph with a delta file (``+ u v`` / ``- u v`` lines, see
`repro.dyngraph.stream.load_delta`) and repairs its solution instead of
re-ingesting:

    stream mode    a line ``update <request_id> <delta_file>``
    --once mode    ``--update ID:DELTA_FILE`` (repeatable), applied after
                   the initial solves drain

``--stream-ingest`` loads graph files through the chunked readers
(`repro.dyngraph.stream.load_graph_stream`) instead of `readlines()`.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.serve_mis.service import MISService, ServeConfig


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="python -m repro.serve_mis")
    p.add_argument("paths", nargs="*", help="graph files (.mtx/.edges/.dimacs/...)")
    p.add_argument("--once", action="store_true",
                   help="solve the given paths, print stats, exit")
    p.add_argument("--fmt", default=None, choices=["edgelist", "mtx", "dimacs"],
                   help="override format auto-detection")
    p.add_argument("--repeat", type=int, default=1,
                   help="submit every input N times (exercises the plan cache)")
    p.add_argument("--tile-size", type=int, default=32)
    p.add_argument("--storage", default="auto",
                   choices=["auto", "int8", "bitpack"],
                   help="tile storage format (DESIGN.md §11)")
    p.add_argument("--engine", default="fused_pallas")
    p.add_argument("--heuristic", default="h3")
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--reorder", default=None, choices=["rcm"])
    p.add_argument("--cache-dir", default=None,
                   help="persist tile plans here (content-addressed .npz)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--repair", default="auto",
                   choices=["auto", "cold", "incremental"],
                   help="how `update` requests re-solve (DESIGN.md §12)")
    p.add_argument("--update", action="append", default=[],
                   metavar="ID:DELTA_FILE",
                   help="--once mode: after the initial solves, patch "
                        "request ID with the delta file and repair")
    p.add_argument("--stream-ingest", action="store_true",
                   help="ingest via the chunked readers (dyngraph.stream) "
                        "instead of readlines()")
    p.add_argument("--telemetry", action="store_true",
                   help="record the on-device round buffer; responses carry "
                        "a per-round summary (DESIGN.md §14)")
    p.add_argument("--trace-path", default=None, metavar="FILE",
                   help="append span traces + round series as JSONL here "
                        "(render with `python -m repro.obs report FILE`)")
    p.add_argument("--metrics", action="store_true",
                   help="print the merged metrics snapshot as JSON on stderr "
                        "at exit")
    p.add_argument("--metrics-path", default=None, metavar="FILE",
                   help="write the merged snapshot as Prometheus text to "
                        "FILE at exit (atomic replace — point a textfile "
                        "collector's glob at it; DESIGN.md §17)")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    service = MISService(ServeConfig(
        tile_size=args.tile_size,
        storage=args.storage,
        engine=args.engine,
        heuristic=args.heuristic,
        max_batch=args.max_batch,
        reorder=args.reorder,
        cache_dir=args.cache_dir,
        seed=args.seed,
        repair=args.repair,
        telemetry=args.telemetry,
        trace_path=args.trace_path,
    ))

    def emit(responses) -> int:
        bad = 0
        for r in responses:
            print(json.dumps(r.summary()), flush=True)
            bad += 0 if r.valid else 1
        return bad

    def submit(path) -> int:
        """One bad request must not kill the stream: report it, keep serving."""
        try:
            for _ in range(args.repeat):
                service.submit(path, fmt=args.fmt, stream=args.stream_ingest)
            return 0
        except (OSError, ValueError) as e:  # missing file, GraphParseError, ...
            print(json.dumps(dict(source=str(path), valid=False,
                                  error=f"{type(e).__name__}: {e}")), flush=True)
            return args.repeat

    def submit_update(base_id, delta_path) -> int:
        """The `update` verb: patch a served request's graph, repair."""
        from repro.dyngraph.stream import load_delta

        try:
            service.submit_update(int(base_id), load_delta(delta_path))
            return 0
        except (OSError, ValueError, KeyError) as e:
            print(json.dumps(dict(source=f"update:{base_id}:{delta_path}",
                                  valid=False,
                                  error=f"{type(e).__name__}: {e}")), flush=True)
            return 1

    failures = 0
    if args.once:
        if not args.paths:
            print("--once needs at least one graph file", file=sys.stderr)
            return 2
        for path in args.paths:
            failures += submit(path)
        failures += emit(service.drain())
        for spec in args.update:
            base_id, _, delta_path = spec.partition(":")
            failures += submit_update(base_id, delta_path)
            # drain per update, so a later spec can chain off this one's id
            failures += emit(service.drain())
    else:
        sources = args.paths or (line.strip() for line in sys.stdin)
        for src in sources:
            if not src:
                continue
            if src.startswith("update "):
                # `update <request_id> <delta_file>` — the target must have
                # been served already, so flush the queue first
                failures += emit(service.drain())
                parts = src.split(maxsplit=2)
                if len(parts) != 3:
                    print(json.dumps(dict(source=src, valid=False,
                                          error="usage: update <id> <delta_file>")),
                          flush=True)
                    failures += 1
                    continue
                failures += submit_update(parts[1], parts[2])
                continue
            failures += submit(src)
            while service.pending >= service.config.max_batch:
                failures += emit(service.step())
        failures += emit(service.drain())

    s, p = service.stats, service.planner.stats
    print(
        f"# served={s['requests']} batches={s['batches']} "
        f"compiles={s['compiles']} plan_cache mem={p['mem_hits']} "
        f"disk={p['disk_hits']} built={p['misses']} failures={failures}",
        file=sys.stderr,
    )
    if args.metrics:
        print(json.dumps(service.metrics_snapshot(), sort_keys=True),
              file=sys.stderr)
    if args.metrics_path:
        from repro.obs.promtext import write_promtext

        write_promtext(service.metrics_snapshot(), args.metrics_path)
        print(f"# wrote promtext to {args.metrics_path}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""repro.serve_mis — the serving layer over the TC-MIS round engines.

Turns the single-graph reproduction into a request-driven system
(DESIGN.md §9):

  io        file ingestion (SNAP edge lists, MatrixMarket, DIMACS)
  planner   content-hashed tile-plan cache (absorbed into repro.api.plan;
            re-exported here for compatibility)
  batcher   block-diagonal multi-graph packing into shape buckets
  service   request queue → one `repro.api.Solver.solve_many` dispatch per
            batch → validated per-graph responses with serving stats;
            `submit_update` patches a served graph with an `EdgeDelta` and
            repairs its solution in place (repro.dyngraph, DESIGN.md §12)

CLI: ``python -m repro.serve_mis --once graph1.mtx graph2.edges``
     (``update <id> <delta_file>`` lines / ``--update ID:FILE`` mutate
     served graphs; ``--stream-ingest`` uses the chunked readers)
"""
from repro.serve_mis.io import GraphParseError, detect_format, load_graph
from repro.serve_mis.planner import PlanCache, TilePlan, build_plan, plan_cache_key
from repro.serve_mis.batcher import (
    Bucket,
    PackedBatch,
    bucket_for,
    pack_batch,
    request_key,
)
from repro.serve_mis.service import (
    MISService,
    Request,
    Response,
    ServeConfig,
    UpdateRequest,
)

__all__ = [
    "GraphParseError", "detect_format", "load_graph",
    "PlanCache", "TilePlan", "build_plan", "plan_cache_key",
    "Bucket", "PackedBatch", "bucket_for", "pack_batch", "request_key",
    "MISService", "Request", "Response", "ServeConfig", "UpdateRequest",
]

"""The MIS serving loop: requests in, validated per-graph solutions out.

Request lifecycle (DESIGN.md §9):

    submit ─ ingest (io) ─ plan (planner cache) ─┐
    submit ─ ingest ─ plan ───────────────────────┤ queue
    ...                                           │
                 step(): pop ≤ max_batch ─ pack (batcher) ─ ONE jitted
                 tc_mis dispatch ─ unpack ─ fused validity post-condition
                 per member ─ Response

Every response carries per-request stats — queue time, plan-cache layer
(mem/disk/built), bucket signature, whether this batch reused a compiled
program, batch solve time, rounds, |MIS| — and the post-condition verdict
from `validate.is_valid_mis_jit` (one fused jitted check per member).

The jit story: `_solve` is one `jax.jit` wrapper over `tc_mis`; its cache is
keyed by the packed batch's static shapes, which the batcher buckets, so a
steady request mix converges onto a handful of compiled programs.  The
service additionally tracks bucket signatures it has seen to report
compile reuse per batch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Union

import jax
import numpy as np

from repro.core.engine import get_engine
from repro.core.tc_mis import TCMISConfig, tc_mis
from repro.core.validate import is_valid_mis_jit
from repro.graphs.graph import Graph
from repro.serve_mis.batcher import PriorityCache, pack_batch, request_key
from repro.serve_mis.io import load_graph
from repro.serve_mis.planner import PlanCache, TilePlan


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving layer (the algorithm knobs mirror TCMISConfig)."""
    tile_size: int = 32
    heuristic: str = "h3"
    engine: str = "fused_pallas"   # any registered round engine
    phase1: str = "segment"
    lanes: int = 8
    skip_dma: bool = False
    max_rounds: int = 1024
    max_batch: int = 8             # requests per packed dispatch
    reorder: Optional[str] = None  # None | 'rcm'
    cache_dir: Optional[str] = None
    plan_cache_entries: int = 256  # memory-layer LRU bound (disk is unbounded)
    validate: bool = True
    seed: int = 0

    def mis_config(self) -> TCMISConfig:
        return TCMISConfig(
            heuristic=self.heuristic,
            lanes=self.lanes,
            backend=self.engine,
            phase1=self.phase1,
            skip_dma=self.skip_dma,
            max_rounds=self.max_rounds,
        )


@dataclasses.dataclass
class Request:
    id: int
    source: str
    plan: TilePlan
    plan_status: str      # mem | disk | built
    t_enqueue: float


@dataclasses.dataclass
class Response:
    id: int
    source: str
    in_mis: np.ndarray    # (n_nodes,) bool, ORIGINAL vertex ids
    mis_size: int
    independent: bool
    maximal: bool
    converged: bool       # BATCH-global (the shared while_loop's flag)
    rounds: int
    stats: Dict[str, object]

    @property
    def valid(self) -> bool:
        """Per-member verdict — deliberately NOT ANDed with `converged`.

        `converged` is batch-global, so one max_rounds-limited member must
        not poison its batchmates.  The invariants alone are exact per
        member: a member cut off mid-solve still has alive vertices, and an
        alive vertex is by construction unselected with no selected
        neighbour — which is precisely a maximality violation, so
        `maximal` is False for any unconverged member.
        """
        return self.independent and self.maximal

    def summary(self) -> Dict[str, object]:
        """JSON-friendly per-request record (solution vector elided)."""
        return dict(
            id=self.id,
            source=self.source,
            n_nodes=int(self.in_mis.shape[0]),
            mis_size=self.mis_size,
            valid=self.valid,
            rounds=self.rounds,
            **self.stats,
        )


class MISService:
    """Request-queue MIS worker over the plan cache + block-diagonal batcher."""

    def __init__(self, config: ServeConfig = ServeConfig()):
        get_engine(config.engine)  # fail fast, before any request is queued
        self.config = config
        self.planner = PlanCache(
            tile_size=config.tile_size,
            reorder=config.reorder,
            cache_dir=config.cache_dir,
            max_mem_entries=config.plan_cache_entries,
        )
        self._queue: Deque[Request] = deque()
        self._next_id = 0
        self._base_key = jax.random.key(config.seed)
        # sound per service instance: one base key, one heuristic (batcher)
        self._priority_cache: PriorityCache = {}
        self._seen_buckets: set = set()
        self.stats = {"requests": 0, "batches": 0, "compiles": 0}
        mis_cfg = config.mis_config()
        self._solve = jax.jit(
            lambda g, tiled, pri, alive0, gate: tc_mis(
                g, tiled, self._base_key, mis_cfg,
                priorities=pri, alive0=alive0, col_gate=gate,
            )
        )

    # -- intake ------------------------------------------------------------

    def submit(
        self,
        source: Union[str, Graph],
        *,
        fmt: Optional[str] = None,
        n_nodes: Optional[int] = None,
    ) -> int:
        """Ingest + plan (cache-aware) and enqueue; returns the request id."""
        if isinstance(source, Graph):
            graph, name = source, f"<graph:{source.n_nodes}v>"
        else:
            name = str(source)
            graph = load_graph(name, fmt=fmt, n_nodes=n_nodes)
        plan, status = self.planner.plan(graph)
        req = Request(
            id=self._next_id,
            source=name,
            plan=plan,
            plan_status=status,
            t_enqueue=time.perf_counter(),
        )
        self._next_id += 1
        self.stats["requests"] += 1
        self._queue.append(req)
        return req.id

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- the worker step ----------------------------------------------------

    def step(self) -> List[Response]:
        """Pop ≤ max_batch requests, solve them in ONE dispatch, respond."""
        if not self._queue:
            return []
        reqs = [
            self._queue.popleft()
            for _ in range(min(self.config.max_batch, len(self._queue)))
        ]
        t_pop = time.perf_counter()
        batch = pack_batch(
            [r.plan for r in reqs],
            [request_key(self._base_key, r.plan) for r in reqs],
            self.config.heuristic,
            priority_cache=self._priority_cache,
        )
        sig = batch.signature()
        reused = sig in self._seen_buckets
        self._seen_buckets.add(sig)
        self.stats["batches"] += 1
        if not reused:
            self.stats["compiles"] += 1

        t0 = time.perf_counter()
        result = self._solve(
            batch.g, batch.tiled, batch.priorities, batch.alive0, batch.col_gate
        )
        jax.block_until_ready(result.in_mis)
        solve_ms = (time.perf_counter() - t0) * 1e3
        rounds = int(result.rounds)
        converged = bool(result.converged)

        responses = []
        for req, mis_plan_ids in zip(reqs, batch.unpack(result.in_mis)):
            independent = maximal = True
            if self.config.validate:
                independent, maximal = is_valid_mis_jit(
                    req.plan.g, jax.numpy.asarray(mis_plan_ids)
                )
            in_mis = req.plan.to_original(mis_plan_ids).astype(bool)
            responses.append(Response(
                id=req.id,
                source=req.source,
                in_mis=in_mis,
                mis_size=int(in_mis.sum()),
                independent=independent,
                maximal=maximal,
                converged=converged,
                rounds=rounds,
                stats=dict(
                    queue_ms=round((t_pop - req.t_enqueue) * 1e3, 3),
                    solve_ms=round(solve_ms, 3),
                    plan_cache=req.plan_status,
                    bucket=sig,
                    compile="reused" if reused else "compiled",
                    batch_size=len(reqs),
                ),
            ))
        return responses

    def drain(self) -> List[Response]:
        """Run worker steps until the queue is empty."""
        out: List[Response] = []
        while self._queue:
            out.extend(self.step())
        return out

"""The MIS serving loop: requests in, validated per-graph solutions out.

Request lifecycle (DESIGN.md §9):

    submit ─ ingest (io) ─ plan (planner cache) ─┐
    submit ─ ingest ─ plan ───────────────────────┤ queue
    submit_update ─ (targets a served result) ────┤
    ...                                           │
                 step(): pop ≤ max_batch ─ Solver.solve_many (block-diagonal
                 pack, ONE dispatch per batch; updates patch their cached
                 plan tile-locally + warm-repair, DESIGN.md §12) ─ fused
                 validity post-condition per member ─ Response

Every response carries per-request stats — queue time, plan-cache layer
(mem/disk/built), bucket signature, whether this batch reused a compiled
program, batch solve time, the member's OWN convergence round, |MIS| — and
the post-condition verdict from `validate.is_valid_mis_jit` (one fused
jitted check per member).

The execution seam is `repro.api.Solver` (DESIGN.md §10): the service owns
the queue and the per-request bookkeeping, the Solver owns planning,
routing (batched here; large graphs can peel off to the shard_map path on
multi-device hosts) and compiled-program reuse — its jit cache is keyed by
the packed batch's static shapes, which the batcher buckets, so a steady
request mix converges onto a handful of compiled programs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.api import Solver, SolveOptions
from repro.core.validate import is_valid_mis_jit
from repro.dyngraph.delta import EdgeDelta
from repro.graphs.graph import Graph
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import JsonlWriter, Trace, trace_span
from repro.serve_mis.io import load_graph
from repro.serve_mis.planner import TilePlan


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving layer (the solve knobs mirror `SolveOptions`)."""
    tile_size: int = 32
    heuristic: str = "h3"
    engine: str = "fused_pallas"   # any registered round engine
    phase1: str = "segment"
    lanes: int = 8
    skip_dma: bool = False
    max_rounds: int = 1024
    max_batch: int = 8             # requests per packed dispatch
    reorder: Optional[str] = None  # None | 'rcm'
    storage: str = "auto"          # tile storage: auto | int8 | bitpack
    cache_dir: Optional[str] = None
    plan_cache_entries: int = 256  # memory-layer LRU bound (disk is unbounded)
    validate: bool = True
    seed: int = 0
    repair: str = "auto"           # delta-update policy (SolveOptions.repair)
    # completed-result retention (the targets `submit_update` may name).
    # Each retained result pins its Plan — tiles included — so this bound
    # matches plan_cache_entries by default: retention must not out-pin
    # the plan cache's own memory bound.
    result_entries: int = 256
    # observability (repro.obs, DESIGN.md §14): `telemetry` turns on the
    # on-device round buffer (responses carry per-round series);
    # `trace_path` appends span traces + round series as JSONL there (each
    # worker step is one Trace).  Both off = the pre-obs zero-cost path.
    telemetry: bool = False
    trace_path: Optional[str] = None

    def solve_options(self) -> SolveOptions:
        """The Solver half of this config (the front door, DESIGN.md §10)."""
        return SolveOptions(
            heuristic=self.heuristic,
            engine=self.engine,
            phase1=self.phase1,
            lanes=self.lanes,
            skip_dma=self.skip_dma,
            max_rounds=self.max_rounds,
            tile_size=self.tile_size,
            reorder=self.reorder,
            storage=self.storage,
            placement="auto",
            seed=self.seed,
            cache_dir=self.cache_dir,
            plan_cache_entries=self.plan_cache_entries,
            repair=self.repair,
            telemetry=self.telemetry,
        )


@dataclasses.dataclass
class Request:
    id: int
    source: str
    plan: TilePlan
    plan_status: str      # mem | disk | built
    t_enqueue: float


@dataclasses.dataclass
class UpdateRequest:
    """A graph-mutation request: patch request `base_id`'s graph with
    `delta` and repair its solution (DESIGN.md §12).  `base_id` must name a
    COMPLETED request — chain mutations by targeting each update's own id
    once it has been served."""
    id: int
    base_id: int
    source: str
    delta: EdgeDelta
    t_enqueue: float


@dataclasses.dataclass
class Response:
    id: int
    source: str
    in_mis: np.ndarray    # (n_nodes,) bool, ORIGINAL vertex ids
    mis_size: int
    independent: bool
    maximal: bool
    converged: bool       # BATCH-global (the shared while_loop's flag)
    rounds: int           # this member's OWN convergence round
    stats: Dict[str, object]

    @property
    def valid(self) -> bool:
        """Per-member verdict — deliberately NOT ANDed with `converged`.

        `converged` is batch-global, so one max_rounds-limited member must
        not poison its batchmates.  The invariants alone are exact per
        member: a member cut off mid-solve still has alive vertices, and an
        alive vertex is by construction unselected with no selected
        neighbour — which is precisely a maximality violation, so
        `maximal` is False for any unconverged member.
        """
        return self.independent and self.maximal

    def summary(self) -> Dict[str, object]:
        """JSON-friendly per-request record (solution vector elided)."""
        return dict(
            id=self.id,
            source=self.source,
            n_nodes=int(self.in_mis.shape[0]),
            mis_size=self.mis_size,
            valid=self.valid,
            rounds=self.rounds,
            **self.stats,
        )


class MISService:
    """Request-queue MIS worker over the `Solver` front door."""

    def __init__(self, config: ServeConfig = ServeConfig()):
        self.config = config
        self.solver = Solver(config.solve_options())  # raises on bad engine
        self.planner = self.solver.plans
        self._queue: Deque[Union[Request, UpdateRequest]] = deque()
        self._next_id = 0
        self._steps = 0
        # completed results by request id — the targets `submit_update`
        # may name (bounded FIFO; a long stream retires old targets)
        self._results: "OrderedDict[int, object]" = OrderedDict()
        # compat aliases for introspection (tests, tooling): the Solver owns
        # the base key and the jitted packed dispatch now
        self._base_key = self.solver._base_key
        self._solve = self.solver._jit_packed
        # observability (repro.obs): service-level metrics registry + the
        # optional JSONL sink for span traces and round series
        self.metrics = MetricsRegistry("service")
        self.metrics.counter("service.requests")
        self._trace_writer = (
            JsonlWriter(config.trace_path) if config.trace_path else None
        )

    @property
    def stats(self) -> Dict[str, int]:
        return {
            "requests": self.metrics.counter("service.requests").value,
            "batches": self.solver.stats["batches"],
            "compiles": self.solver.stats["compiles"],
        }

    def metrics_snapshot(self) -> Dict[str, object]:
        """One operator-facing dict over every registry this service can
        see: its own instruments, the Solver's, the plan cache's, and the
        process-wide registry (batcher priority cache, repair decisions).
        Names are layer-prefixed (`service.*`, `solver.*`, `plan_cache.*`,
        `batcher.*`, `repair.*`), so the flat merge cannot collide."""
        out: Dict[str, object] = {}
        for reg in (REGISTRY, self.solver.metrics, self.planner.metrics,
                    self.metrics):
            out.update(reg.snapshot())
        return out

    # -- intake ------------------------------------------------------------

    def submit(
        self,
        source: Union[str, Graph],
        *,
        fmt: Optional[str] = None,
        n_nodes: Optional[int] = None,
        stream: bool = False,
    ) -> int:
        """Ingest + plan (cache-aware) and enqueue; returns the request id.

        `stream=True` ingests file sources through the chunked readers
        (`repro.dyngraph.stream.load_graph_stream`) — same Graph, same
        plan-cache hits, without the whole-file line list."""
        if isinstance(source, Graph):
            graph, name = source, f"<graph:{source.n_nodes}v>"
        elif stream:
            from repro.dyngraph.stream import load_graph_stream

            name = str(source)
            graph = load_graph_stream(name, fmt=fmt, n_nodes=n_nodes)
        else:
            name = str(source)
            graph = load_graph(name, fmt=fmt, n_nodes=n_nodes)
        plan, status = self.planner.plan(graph)
        req = Request(
            id=self._next_id,
            source=name,
            plan=plan,
            plan_status=status,
            t_enqueue=time.perf_counter(),
        )
        self._next_id += 1
        self.metrics.counter("service.requests").inc()
        self._queue.append(req)
        return req.id

    def submit_update(self, base_id: int, delta: EdgeDelta) -> int:
        """Enqueue a graph mutation against a COMPLETED request (DESIGN.md
        §12): the base request's cached plan is patched tile-locally and
        its solution repaired per `config.repair` — never a re-ingest, and
        for small deltas never a cold re-solve.  Chain mutations by
        targeting the previous update's own id once it has been served;
        an unknown or not-yet-completed `base_id` raises KeyError."""
        if base_id not in self._results:
            raise KeyError(
                f"update targets request {base_id}, which has not completed "
                f"(updates chain off served results; drain first)"
            )
        # fail fast on the cheap structural check; set-strictness (absent
        # removes / present adds) surfaces at step time as an error response
        delta.check_bounds(self._results[base_id].plan.n_nodes)
        req = UpdateRequest(
            id=self._next_id,
            base_id=base_id,
            source=f"<update:{base_id}+{delta.n_add}-{delta.n_remove}>",
            delta=delta,
            t_enqueue=time.perf_counter(),
        )
        self._next_id += 1
        self.metrics.counter("service.requests").inc()
        self._queue.append(req)
        return req.id

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- the worker step ----------------------------------------------------

    def step(self) -> List[Response]:
        """Pop ≤ max_batch requests, solve them through the Solver, respond.

        Solve requests in the window share one batched dispatch; update
        requests repair individually (each is one warm-started dispatch
        against its own patched plan).  A failing update — a delta that
        violates set strictness against the graph it targets, or a base
        result that aged out of retention — yields an INVALID error
        response; it never kills the stream or its window-mates.  Response
        order is pop order.
        """
        if not self._queue:
            return []
        reqs = [
            self._queue.popleft()
            for _ in range(min(self.config.max_batch, len(self._queue)))
        ]
        # one Trace per worker step, created only when a sink is configured
        # — tr=None keeps the Solver on its untraced (pre-obs) dispatch path
        tr = (
            Trace(f"step-{self._steps}", profiler=False)
            if self._trace_writer is not None else None
        )
        self._steps += 1
        self.metrics.counter("service.steps").inc()
        self.metrics.histogram("service.window").observe(len(reqs))
        # health gauges (DESIGN.md §17), sampled once per worker step:
        # what's still waiting behind this window, and what's in flight now
        self.metrics.gauge("service.queue_depth").set(len(self._queue))
        self.metrics.gauge("service.inflight").set(len(reqs))
        t_pop = time.perf_counter()
        solves = [r for r in reqs if isinstance(r, Request)]
        with trace_span(tr, "service.step", size=len(reqs)):
            with trace_span(tr, "service.batch", size=len(solves)):
                results = dict(zip(
                    (r.id for r in solves),
                    self.solver.solve_many(
                        [r.plan for r in solves], trace=tr
                    ),
                ))
            for r in reqs:
                if isinstance(r, UpdateRequest):
                    try:
                        results[r.id] = self._run_update(r, tr)
                    except (ValueError, KeyError) as e:
                        results[r.id] = e

        responses = []
        for req, res in ((r, results[r.id]) for r in reqs):
            queue_ms = round((t_pop - req.t_enqueue) * 1e3, 3)
            self.metrics.histogram("service.queue_ms").observe(queue_ms)
            if isinstance(res, Exception):
                self.metrics.counter("service.errors").inc()
                responses.append(Response(
                    id=req.id, source=req.source,
                    in_mis=np.zeros(0, dtype=bool), mis_size=0,
                    independent=False, maximal=False, converged=False,
                    rounds=0,
                    stats=dict(
                        queue_ms=queue_ms,
                        error=f"{type(res).__name__}: {res}",
                        batch_size=len(reqs),
                    ),
                ))
                continue
            independent = maximal = True
            if self.config.validate:
                with trace_span(tr, "service.validate", id=req.id):
                    independent, maximal = is_valid_mis_jit(
                        res.plan.g, jnp.asarray(res.in_mis_plan)
                    )
            in_mis = np.asarray(res.in_mis).astype(bool)
            is_update = isinstance(req, UpdateRequest)
            stats = dict(
                queue_ms=queue_ms,
                solve_ms=res.stats.get("solve_ms", 0.0),
                plan_cache=res.stats["patch"] if is_update else req.plan_status,
                bucket=res.stats.get("bucket", res.placement),
                compile=res.stats.get("compile", "n/a"),
                batch_size=len(reqs),
            )
            # traced dispatches split solve_ms into its phases — surface
            # them (plus the batch wall the member's share came from)
            for k in ("batch_ms", "compile_ms", "execute_ms"):
                if k in res.stats:
                    stats[k] = res.stats[k]
            if is_update:
                stats.update(
                    repair=res.stats["repair"],
                    plan_epoch=res.stats["plan_epoch"],
                    base_id=req.base_id,
                )
            rt = getattr(res, "telemetry", None)
            if rt is not None:
                stats["rounds_summary"] = rt.summary()
            # per-op SLO latency (enqueue → response built): one fixed-
            # bucket histogram per op, so p50/p95/p99 read per route
            op = ("update" if is_update
                  else "batched" if res.placement == "batched" else "solve")
            self.metrics.histogram(f"service.latency_ms.{op}").observe(
                round((time.perf_counter() - req.t_enqueue) * 1e3, 3)
            )
            responses.append(Response(
                id=req.id,
                source=req.source,
                in_mis=in_mis,
                mis_size=int(in_mis.sum()),
                independent=independent,
                maximal=maximal,
                converged=res.converged,
                rounds=res.rounds,
                stats=stats,
            ))
            self._results[req.id] = res
            while len(self._results) > max(self.config.result_entries, 1):
                self._results.popitem(last=False)
        self.metrics.gauge("service.inflight").set(0)
        if tr is not None:
            # per-stage latency distributions over the span taxonomy
            # (service.step ⊃ service.batch/validate; solver.solve ⊃
            # plan/pack/compile/execute; solver.update) — traced steps
            # only, so the untraced path records nothing extra
            for s in tr.spans:
                self.metrics.histogram(
                    f"service.span_ms.{s.name}"
                ).observe(round(s.dur_ms, 3))
        if self._trace_writer is not None:
            self._trace_writer.write_trace(tr)
            # one rounds record per distinct RoundTrace — batched members
            # share the batch-global series, so dedupe by object identity
            seen_ids = set()
            for req in reqs:
                res = results[req.id]
                rt = getattr(res, "telemetry", None)
                if rt is not None and id(rt) not in seen_ids:
                    seen_ids.add(id(rt))
                    self._trace_writer.write_rounds(rt)
        return responses

    def _run_update(self, r: UpdateRequest, trace: Optional[Trace] = None):
        """One update's repair dispatch, under the CONTENT-DERIVED key of
        the patched graph — the key a fresh submission of that mutated
        graph would be solved under (`Solver.request_key`), and, for an
        empty delta, exactly the key the base response was solved under.
        That keeps update responses bit-consistent with the service's own
        solve path in every repair mode (a plain `Solver.update` defaults
        to the classic seed key instead, matching `Solver.solve`)."""
        if r.base_id not in self._results:
            raise KeyError(
                f"update {r.id} targets request {r.base_id}, whose result "
                f"aged out of retention (result_entries="
                f"{self.config.result_entries})"
            )
        prior = self._results[r.base_id]
        # this first patch is the authoritative cache probe; Solver.update's
        # own apply_delta then mem-hits by construction, so ITS patch stat
        # would always read 'mem' — overwrite with the real layer
        plan2, patch_status = self.solver.plans.apply_delta(prior.plan, r.delta)
        res = self.solver.update(
            prior, r.delta, key=self.solver.request_key(plan2), trace=trace
        )
        res.stats["patch"] = patch_status
        return res

    def drain(self) -> List[Response]:
        """Run worker steps until the queue is empty."""
        out: List[Response] = []
        while self._queue:
            out.extend(self.step())
        return out

"""Graph file ingestion: real-world formats → `graphs.Graph`.

The paper's suite (Table 1) ships as SNAP edge lists and SuiteSparse
MatrixMarket files; DIMACS is the lingua franca of the MIS/colouring
benchmark world.  This module parses all three into the repo's canonical
`Graph` (undirected, deduped, both half-edge directions — `from_edges` does
the normalisation, so a directed or weighted input file yields the same
graph the paper's preprocessing would).

Formats:

  edge list   one `u v` pair per line (SNAP / Konect style); `#` and `%`
              comment lines skipped; extra columns (weights, timestamps)
              ignored; vertex ids need not be contiguous — they are kept
              as-is with ``n_nodes = max_id + 1`` unless overridden.
  .mtx        MatrixMarket `coordinate` (pattern/real/integer, general or
              symmetric); 1-indexed; values ignored (adjacency structure
              only).  Array (dense) Matrix Market files are rejected.
  DIMACS      `c` comments, `p edge|col N M` header, `e u v` edge lines,
              1-indexed.

Parsers are host-side numpy (ingestion is preprocessing; devices never see
file bytes), deterministic, and total: every malformed line raises
`GraphParseError` with the offending line number.

Each format has ONE line-level implementation — the `iter_*_chunks`
generators, which stream `(src, dst)` int64 chunk pairs with bounded
memory.  The classic whole-file `parse_*` functions collect those chunks
and add the per-format vertex-count resolution; the streaming ingestion
layer (`repro.dyngraph.stream`, DESIGN.md §12) consumes the same
generators directly, so the format contract is single-sited.  Whole-file
invariants a stream can only know at EOF (MatrixMarket entry-count
promises, the missing DIMACS `p` line) raise when the generator is
exhausted.
"""
from __future__ import annotations

import os
from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.graphs.graph import Graph, from_edges

DEFAULT_CHUNK_EDGES = 1 << 16

Chunk = Tuple[np.ndarray, np.ndarray]   # (src, dst) int64, equal length


class GraphParseError(ValueError):
    """A graph file violated its format contract."""


_EXT_FORMATS = {
    ".mtx": "mtx",
    ".mm": "mtx",
    ".dimacs": "dimacs",
    ".col": "dimacs",
    ".clq": "dimacs",
    ".edges": "edgelist",
    ".el": "edgelist",
    ".txt": "edgelist",
    ".tsv": "edgelist",
    ".csv": "edgelist",
}


def detect_format(path: str, first_line: str = "") -> str:
    """Format detection: unambiguous content markers outrank the extension.

    The MatrixMarket banner and a DIMACS `c`/`p` head are mandatory in their
    formats and illegal in an edge list, so a `.txt`-named `.mtx` file must
    not be silently mis-parsed as an edge list; extensions only decide when
    the first line is not self-identifying.
    """
    head = first_line.strip().lower()
    if head.startswith("%%matrixmarket"):
        return "mtx"
    if head.startswith(("c ", "p ")) or head in ("c", "p"):
        return "dimacs"
    return _EXT_FORMATS.get(os.path.splitext(path)[1].lower(), "edgelist")


def _split_ints(line: str, lineno: int, want: int) -> List[int]:
    parts = line.replace(",", " ").split()
    if len(parts) < want:
        raise GraphParseError(f"line {lineno}: expected {want} fields, got {line!r}")
    try:
        # strict int(): '1.9' or float-precision-losing 64-bit ids must be a
        # parse error, not a silently truncated vertex id
        return [int(p) for p in parts[:want]]
    except ValueError as e:
        raise GraphParseError(f"line {lineno}: non-integer field in {line!r}") from e


# --------------------------------------------------------------------------
# the line-level implementations: one chunked generator per format
# --------------------------------------------------------------------------


class _ChunkBuf:
    """Accumulate (u, v) pairs, flush as int64 array pairs every `cap`."""

    def __init__(self, cap: int):
        self.cap = max(int(cap), 1)
        self.src: List[int] = []
        self.dst: List[int] = []

    def push(self, u: int, v: int) -> bool:
        self.src.append(u)
        self.dst.append(v)
        return len(self.src) >= self.cap

    def flush(self) -> Chunk:
        out = (np.asarray(self.src, np.int64), np.asarray(self.dst, np.int64))
        self.src, self.dst = [], []
        return out


def iter_edgelist_chunks(
    lines: Iterable[str],
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    info: Optional[dict] = None,
) -> Iterator[Chunk]:
    """SNAP-style `u v` lines → 0-indexed (src, dst) chunk pairs."""
    del info   # edge lists declare no vertex count
    buf = _ChunkBuf(chunk_edges)
    for lineno, raw in enumerate(lines, start=1):
        line = raw.strip()
        if not line or line.startswith(("#", "%")):
            continue
        u, v = _split_ints(line, lineno, 2)
        if u < 0 or v < 0:
            raise GraphParseError(f"line {lineno}: negative vertex id in {line!r}")
        if buf.push(u, v):
            yield buf.flush()
    yield buf.flush()


def iter_mtx_chunks(
    lines: Iterable[str],
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    info: Optional[dict] = None,
) -> Iterator[Chunk]:
    """MatrixMarket coordinate lines → 0-indexed chunk pairs (values
    dropped).  `info['n_declared']` receives max(rows, cols) once the size
    line is reached."""
    info = {} if info is None else info
    it = iter(enumerate(lines, start=1))
    try:
        lineno, header = next(it)
    except StopIteration:
        raise GraphParseError("empty MatrixMarket file")
    fields = header.strip().lower().split()
    if not fields or fields[0] != "%%matrixmarket":
        raise GraphParseError(f"line {lineno}: missing %%MatrixMarket banner")
    if "coordinate" not in fields:
        raise GraphParseError("only sparse `coordinate` MatrixMarket is supported")
    dims: Optional[Tuple[int, int, int]] = None
    seen = 0
    buf = _ChunkBuf(chunk_edges)
    for lineno, raw in it:
        line = raw.strip()
        if not line or line.startswith("%"):
            continue
        if dims is None:
            rows, cols, nnz = _split_ints(line, lineno, 3)
            dims = (rows, cols, nnz)
            info["n_declared"] = max(rows, cols)
            continue
        i, j = _split_ints(line, lineno, 2)
        if not (1 <= i <= dims[0] and 1 <= j <= dims[1]):
            raise GraphParseError(
                f"line {lineno}: entry ({i},{j}) outside {dims[0]}x{dims[1]}"
            )
        seen += 1
        if buf.push(i - 1, j - 1):
            yield buf.flush()
    if dims is None:
        raise GraphParseError("MatrixMarket file has no size line")
    if seen != dims[2]:
        raise GraphParseError(f"size line promised {dims[2]} entries, found {seen}")
    yield buf.flush()


def iter_dimacs_chunks(
    lines: Iterable[str],
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    info: Optional[dict] = None,
) -> Iterator[Chunk]:
    """DIMACS `e u v` records → 0-indexed chunk pairs.  `info['n_declared']`
    receives the `p` line's vertex count."""
    info = {} if info is None else info
    n_declared: Optional[int] = None
    buf = _ChunkBuf(chunk_edges)
    for lineno, raw in enumerate(lines, start=1):
        line = raw.strip()
        if not line or line[0] in ("c", "%", "#"):
            continue
        if line[0] == "p":
            parts = line.split()
            if len(parts) < 3:
                raise GraphParseError(f"line {lineno}: malformed problem line {line!r}")
            try:
                n_declared = int(parts[2])
            except ValueError as e:
                raise GraphParseError(
                    f"line {lineno}: non-numeric vertex count in {line!r}"
                ) from e
            info["n_declared"] = n_declared
            continue
        if line[0] == "e":
            u, v = _split_ints(line[1:], lineno, 2)
            if u < 1 or v < 1:
                raise GraphParseError(f"line {lineno}: DIMACS ids are 1-indexed")
            if buf.push(u - 1, v - 1):
                yield buf.flush()
            continue
        raise GraphParseError(f"line {lineno}: unknown DIMACS record {line!r}")
    if n_declared is None:
        raise GraphParseError("DIMACS file has no `p` problem line")
    yield buf.flush()


CHUNKERS = {
    "edgelist": iter_edgelist_chunks,
    "mtx": iter_mtx_chunks,
    "dimacs": iter_dimacs_chunks,
}


def collect_chunks(
    chunks: Iterable[Chunk],
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Drain a chunk iterator into whole arrays; returns (src, dst, max_id)
    with max_id = -1 for an edgeless stream.  Shared by the whole-file
    parsers below and `dyngraph.stream.load_graph_stream`."""
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    for s, d in chunks:
        if s.size:
            srcs.append(s)
            dsts.append(d)
    s = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    d = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    return s, d, int(max(s.max(initial=-1), d.max(initial=-1)))


def resolve_n_nodes(
    fmt: str,
    max_id: int,
    declared: Optional[int] = None,
    n_nodes: Optional[int] = None,
) -> int:
    """The per-format vertex-count resolution and its guards, single-sited:
    explicit override > the file's declared count > max_id + 1 — rejecting
    counts the edges overflow and the describes-no-graph case with each
    format's established error message (tests pin the wording)."""
    n = int(n_nodes) if n_nodes is not None else (
        declared if declared is not None else max_id + 1
    )
    if n <= max_id:
        raise GraphParseError({
            "edgelist": f"n_nodes={n} but file references vertex {max_id}",
            "mtx": f"n_nodes={n} but file references vertex {max_id + 1}",
            "dimacs": f"problem line says {n} vertices, file uses {max_id + 1}",
        }[fmt])
    if n < 1:
        raise GraphParseError({
            "edgelist": "edge list contains no edges (and no n_nodes override)",
            "mtx": "MatrixMarket size line declares a 0-vertex matrix",
            "dimacs": "DIMACS problem line declares 0 vertices",
        }[fmt])
    return n


# --------------------------------------------------------------------------
# whole-file parsers: collect chunks + per-format vertex-count resolution
# --------------------------------------------------------------------------


def parse_edge_list(
    lines: Iterable[str], n_nodes: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """SNAP-style `u v` pairs → (src, dst, n_nodes)."""
    s, d, max_id = collect_chunks(iter_edgelist_chunks(lines))
    return s, d, resolve_n_nodes("edgelist", max_id, None, n_nodes)


def parse_mtx(
    lines: Iterable[str], n_nodes: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """MatrixMarket coordinate file → (src, dst, n_nodes); values dropped."""
    info: dict = {}
    s, d, max_id = collect_chunks(iter_mtx_chunks(lines, info=info))
    return s, d, resolve_n_nodes("mtx", max_id, info.get("n_declared"), n_nodes)


def parse_dimacs(
    lines: Iterable[str], n_nodes: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """DIMACS `p edge` file → (src, dst, n_nodes); 1-indexed `e u v` lines."""
    info: dict = {}
    s, d, max_id = collect_chunks(iter_dimacs_chunks(lines, info=info))
    return s, d, resolve_n_nodes(
        "dimacs", max_id, info.get("n_declared"), n_nodes
    )


_PARSERS = {
    "edgelist": parse_edge_list,
    "mtx": parse_mtx,
    "dimacs": parse_dimacs,
}


def load_graph(
    path: str,
    *,
    fmt: Optional[str] = None,
    n_nodes: Optional[int] = None,
    pad_to: Optional[int] = None,
) -> Graph:
    """Parse a graph file into a canonical undirected :class:`Graph`.

    ``fmt`` overrides detection (`edgelist` | `mtx` | `dimacs`); ``n_nodes``
    overrides the file's vertex count (e.g. to include isolated tail
    vertices an edge list cannot express); ``pad_to`` pre-pads the edge
    arrays (see `graphs.graph.from_edges`).

    Reads the whole file; `repro.dyngraph.stream.load_graph_stream` is the
    bounded-memory twin over the same chunk generators (DESIGN.md §12).
    """
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        lines = f.readlines()
    if fmt is None:
        fmt = detect_format(path, lines[0] if lines else "")
    if fmt not in _PARSERS:
        raise ValueError(f"unknown graph format {fmt!r}; options {sorted(_PARSERS)}")
    src, dst, n = _PARSERS[fmt](lines, n_nodes)
    return from_edges(src, dst, n, pad_to=pad_to)

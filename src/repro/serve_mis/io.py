"""Graph file ingestion: real-world formats → `graphs.Graph`.

The paper's suite (Table 1) ships as SNAP edge lists and SuiteSparse
MatrixMarket files; DIMACS is the lingua franca of the MIS/colouring
benchmark world.  This module parses all three into the repo's canonical
`Graph` (undirected, deduped, both half-edge directions — `from_edges` does
the normalisation, so a directed or weighted input file yields the same
graph the paper's preprocessing would).

Formats:

  edge list   one `u v` pair per line (SNAP / Konect style); `#` and `%`
              comment lines skipped; extra columns (weights, timestamps)
              ignored; vertex ids need not be contiguous — they are kept
              as-is with ``n_nodes = max_id + 1`` unless overridden.
  .mtx        MatrixMarket `coordinate` (pattern/real/integer, general or
              symmetric); 1-indexed; values ignored (adjacency structure
              only).  Array (dense) Matrix Market files are rejected.
  DIMACS      `c` comments, `p edge|col N M` header, `e u v` edge lines,
              1-indexed.

Parsers are host-side numpy (ingestion is preprocessing; devices never see
file bytes), deterministic, and total: every malformed line raises
`GraphParseError` with the offending line number.
"""
from __future__ import annotations

import os
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.graphs.graph import Graph, from_edges


class GraphParseError(ValueError):
    """A graph file violated its format contract."""


_EXT_FORMATS = {
    ".mtx": "mtx",
    ".mm": "mtx",
    ".dimacs": "dimacs",
    ".col": "dimacs",
    ".clq": "dimacs",
    ".edges": "edgelist",
    ".el": "edgelist",
    ".txt": "edgelist",
    ".tsv": "edgelist",
    ".csv": "edgelist",
}


def detect_format(path: str, first_line: str = "") -> str:
    """Format detection: unambiguous content markers outrank the extension.

    The MatrixMarket banner and a DIMACS `c`/`p` head are mandatory in their
    formats and illegal in an edge list, so a `.txt`-named `.mtx` file must
    not be silently mis-parsed as an edge list; extensions only decide when
    the first line is not self-identifying.
    """
    head = first_line.strip().lower()
    if head.startswith("%%matrixmarket"):
        return "mtx"
    if head.startswith(("c ", "p ")) or head in ("c", "p"):
        return "dimacs"
    return _EXT_FORMATS.get(os.path.splitext(path)[1].lower(), "edgelist")


def _split_ints(line: str, lineno: int, want: int) -> List[int]:
    parts = line.replace(",", " ").split()
    if len(parts) < want:
        raise GraphParseError(f"line {lineno}: expected {want} fields, got {line!r}")
    try:
        # strict int(): '1.9' or float-precision-losing 64-bit ids must be a
        # parse error, not a silently truncated vertex id
        return [int(p) for p in parts[:want]]
    except ValueError as e:
        raise GraphParseError(f"line {lineno}: non-integer field in {line!r}") from e


def parse_edge_list(
    lines: Iterable[str], n_nodes: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """SNAP-style `u v` pairs → (src, dst, n_nodes)."""
    src: List[int] = []
    dst: List[int] = []
    for lineno, raw in enumerate(lines, start=1):
        line = raw.strip()
        if not line or line.startswith(("#", "%")):
            continue
        u, v = _split_ints(line, lineno, 2)
        if u < 0 or v < 0:
            raise GraphParseError(f"line {lineno}: negative vertex id in {line!r}")
        src.append(u)
        dst.append(v)
    s = np.asarray(src, dtype=np.int64)
    d = np.asarray(dst, dtype=np.int64)
    max_id = int(max(s.max(initial=-1), d.max(initial=-1)))
    n = max_id + 1 if n_nodes is None else int(n_nodes)
    if n <= max_id:
        raise GraphParseError(f"n_nodes={n} but file references vertex {max_id}")
    if n < 1:
        # an empty/comment-only file describes NO graph; a truncated upload
        # must not come back as a bogus 1-vertex success
        raise GraphParseError("edge list contains no edges (and no n_nodes override)")
    return s, d, n


def parse_mtx(
    lines: Iterable[str], n_nodes: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """MatrixMarket coordinate file → (src, dst, n_nodes); values dropped."""
    it = iter(enumerate(lines, start=1))
    try:
        lineno, header = next(it)
    except StopIteration:
        raise GraphParseError("empty MatrixMarket file")
    fields = header.strip().lower().split()
    if not fields or fields[0] != "%%matrixmarket":
        raise GraphParseError(f"line {lineno}: missing %%MatrixMarket banner")
    if "coordinate" not in fields:
        raise GraphParseError("only sparse `coordinate` MatrixMarket is supported")

    dims: Optional[Tuple[int, int, int]] = None
    src: List[int] = []
    dst: List[int] = []
    for lineno, raw in it:
        line = raw.strip()
        if not line or line.startswith("%"):
            continue
        if dims is None:
            rows, cols, nnz = _split_ints(line, lineno, 3)
            dims = (rows, cols, nnz)
            continue
        i, j = _split_ints(line, lineno, 2)
        if not (1 <= i <= dims[0] and 1 <= j <= dims[1]):
            raise GraphParseError(
                f"line {lineno}: entry ({i},{j}) outside {dims[0]}x{dims[1]}"
            )
        src.append(i - 1)
        dst.append(j - 1)
    if dims is None:
        raise GraphParseError("MatrixMarket file has no size line")
    if len(src) != dims[2]:
        raise GraphParseError(f"size line promised {dims[2]} entries, found {len(src)}")
    n = max(dims[0], dims[1]) if n_nodes is None else int(n_nodes)
    max_id = int(max(max(src, default=-1), max(dst, default=-1)))
    if n <= max_id:
        raise GraphParseError(f"n_nodes={n} but file references vertex {max_id + 1}")
    if n < 1:
        raise GraphParseError("MatrixMarket size line declares a 0-vertex matrix")
    return np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64), n


def parse_dimacs(
    lines: Iterable[str], n_nodes: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """DIMACS `p edge` file → (src, dst, n_nodes); 1-indexed `e u v` lines."""
    n_declared: Optional[int] = None
    src: List[int] = []
    dst: List[int] = []
    for lineno, raw in enumerate(lines, start=1):
        line = raw.strip()
        if not line or line[0] in ("c", "%", "#"):
            continue
        if line[0] == "p":
            parts = line.split()
            if len(parts) < 3:
                raise GraphParseError(f"line {lineno}: malformed problem line {line!r}")
            try:
                n_declared = int(parts[2])
            except ValueError as e:
                raise GraphParseError(
                    f"line {lineno}: non-numeric vertex count in {line!r}"
                ) from e
            continue
        if line[0] == "e":
            u, v = _split_ints(line[1:], lineno, 2)
            if u < 1 or v < 1:
                raise GraphParseError(f"line {lineno}: DIMACS ids are 1-indexed")
            src.append(u - 1)
            dst.append(v - 1)
            continue
        raise GraphParseError(f"line {lineno}: unknown DIMACS record {line!r}")
    if n_declared is None:
        raise GraphParseError("DIMACS file has no `p` problem line")
    n = n_declared if n_nodes is None else int(n_nodes)
    max_id = int(max(max(src, default=-1), max(dst, default=-1)))
    if n <= max_id:
        raise GraphParseError(f"problem line says {n} vertices, file uses {max_id + 1}")
    if n < 1:
        raise GraphParseError("DIMACS problem line declares 0 vertices")
    return np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64), n


_PARSERS = {
    "edgelist": parse_edge_list,
    "mtx": parse_mtx,
    "dimacs": parse_dimacs,
}


def load_graph(
    path: str,
    *,
    fmt: Optional[str] = None,
    n_nodes: Optional[int] = None,
    pad_to: Optional[int] = None,
) -> Graph:
    """Parse a graph file into a canonical undirected :class:`Graph`.

    ``fmt`` overrides detection (`edgelist` | `mtx` | `dimacs`); ``n_nodes``
    overrides the file's vertex count (e.g. to include isolated tail
    vertices an edge list cannot express); ``pad_to`` pre-pads the edge
    arrays (see `graphs.graph.from_edges`).
    """
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        lines = f.readlines()
    if fmt is None:
        fmt = detect_format(path, lines[0] if lines else "")
    if fmt not in _PARSERS:
        raise ValueError(f"unknown graph format {fmt!r}; options {sorted(_PARSERS)}")
    src, dst, n = _PARSERS[fmt](lines, n_nodes)
    return from_edges(src, dst, n, pad_to=pad_to)

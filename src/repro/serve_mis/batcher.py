"""Block-diagonal multi-graph packing: one engine dispatch, many graphs.

Small-graph MIS requests are latency-dominated by dispatch, not compute, so
the service amortises ONE jitted `tc_mis` invocation over a whole batch.
The packing is block-diagonal BSR concatenation of cached `TilePlan`s:

* every member graph's vertex range is padded up to a whole number of
  `T`-sized blocks before it is offset, so **no tile ever spans two
  graphs** — the batch adjacency is exactly block-diagonal and each
  member's neighbourhood structure is untouched;
* priorities are computed **per member** from its own key and degree
  statistics (Eq. 1's d̄ is a per-graph mean), then placed at the member's
  offset.  Zero cross-graph edges + per-graph priorities ⇒ each slot's
  round dynamics are bit-identical to a solo `tc_mis` run of that member,
  so the packed solve provably returns every member's solo MIS;
* padding-slot vertices start **dead** (`alive0`) — they never join the
  set, never cost a round — and the static `col_gate` pins their block
  columns inactive for the engine's empty-C tile skip (core.engine);
* batch shapes are rounded up to **buckets** (powers of two over the
  block, tile and edge counts), so request mixes of many sizes land on a
  bounded set of compiled programs.  `Graph.n_edges` and
  `BlockTiledGraph.n_tiles` are jit-STATIC pytree fields, so the packed
  containers declare the *bucket* counts, not the real ones — otherwise
  every distinct batch composition would be a fresh XLA compile and the
  bucket would bound nothing.  That makes every static field a pure
  function of the bucket.  It is sound because the padding is inert in
  every op the batch reaches: sentinel edges scatter into the dropped
  dummy segment row, and padding tiles are all-zero and pinned to the
  last real block-row (the same convention `build_block_tiles` uses).
  The real counts live in `PackedBatch.n_real_edges` / `n_real_tiles`.
  Corollary: never run edge-mask consumers that enumerate "real" edges
  (`build_csr`, `to_networkx`, `is_valid_mis`) on `batch.g` — validate
  per member on its plan graph, as the service does.

Tile lists concatenate from the plan cache — a batch never re-tiles its
members, it offsets their cached tiles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heuristics import Priorities, make_priorities
from repro.core.spmv import _NEG
from repro.core.tiling import (
    BlockTiledGraph,
    next_pow2,
    packed_words,
    partition_tiles,
)
from repro.graphs.graph import Graph
# module-level code with no layer instance to own metrics records into the
# process-wide registry (repro.obs; DESIGN.md §14)
from repro.obs import metrics as obs_metrics
from repro.serve_mis.planner import TilePlan


class Bucket(NamedTuple):
    """Static shape class of a packed batch — the jit-compilation key."""
    tile_size: int
    n_blocks: int      # total block rows/cols (incl. empty trailing slots)
    n_tiles_pad: int   # padded stored-tile count
    e_pad: int         # padded half-edge count
    storage: str = "int8"   # tile storage format (members must agree)


def bucket_for(plans: Sequence[TilePlan], tile_size: int) -> Bucket:
    """Smallest bucket that fits `plans`: pow2 rounding bounds the number of
    distinct compiled programs to O(log max_size) per dimension."""
    blocks = sum(p.n_blocks for p in plans)
    tiles = sum(p.tiled.n_tiles for p in plans)
    edges = sum(p.g.n_edges for p in plans)
    return Bucket(
        tile_size=int(tile_size),
        n_blocks=next_pow2(max(blocks, 1)),
        n_tiles_pad=next_pow2(max(tiles, 8)),
        e_pad=next_pow2(max(edges, 8)),
        storage=plans[0].tiled.storage if plans else "int8",
    )


def request_key(base_key: jax.Array, plan: TilePlan) -> jax.Array:
    """Per-graph PRNG key, derived from graph *content* so the priorities a
    member gets do not depend on its batch, slot, or arrival order — the
    property that makes packed results reproducible against solo runs.

    Derived from `plan.graph_key` — the build-parameter-free hash — NOT the
    cache key, so the same graph draws the same priorities in either tile
    storage format (the int8-vs-bitpack bit-parity contract)."""
    return jax.random.fold_in(base_key, int(plan.graph_key[:8], 16) & 0x7FFFFFFF)


# host-side (select, resolve) per plan content hash — see pack_batch.
# Bounded FIFO: priority vectors are small next to plans, but a production
# stream of distinct graphs must not grow host memory without limit.
PriorityCache = Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]]
PRIORITY_CACHE_CAP = 4096


def _member_priorities(
    plan: TilePlan,
    key: jax.Array,
    heuristic: str,
    cache: Optional[PriorityCache],
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Priorities for one member, as host arrays ready to place in a slot.

    Priorities are a pure function of (plan content, heuristic, key), and
    with `request_key` the key itself is content-derived — so a warm-path
    batch of already-seen graphs skips the per-member `degrees()` dispatch
    and priority construction entirely via `cache` (keyed by plan content
    hash; callers mixing base keys or heuristics must use separate caches,
    as `MISService` does by owning one cache per service instance).
    """
    if cache is not None and plan.key in cache:
        obs_metrics.counter("batcher.priority_cache.hits").inc()
        return cache[plan.key]
    if cache is not None:
        obs_metrics.counter("batcher.priority_cache.misses").inc()
    pri = make_priorities(heuristic, key, plan.n_nodes, plan.g.degrees())
    entry = (
        np.asarray(pri.select),
        None if pri.resolve is None else np.asarray(pri.resolve),
    )
    if cache is not None:
        cache[plan.key] = entry
        while len(cache) > PRIORITY_CACHE_CAP:
            del cache[next(iter(cache))]  # FIFO eviction (dicts keep order)
    return entry


@dataclasses.dataclass(frozen=True)
class PackedBatch:
    """A block-diagonal batch, ready for one `tc_mis` dispatch."""
    g: Graph                    # block-diagonal graph, n_nodes = n_blocks*T
    tiled: BlockTiledGraph
    priorities: Priorities      # (n_nodes,), _NEG in padding slots
    alive0: jnp.ndarray         # (n_nodes,) bool, False in padding slots
    col_gate: jnp.ndarray       # (n_blocks,) int32 real-vertex occupancy
    offsets: Tuple[int, ...]    # member vertex offsets (multiples of T)
    sizes: Tuple[int, ...]      # member real vertex counts
    bucket: Bucket
    n_real_edges: int = 0       # g/tiled declare BUCKET counts (static jit
    n_real_tiles: int = 0       # keys); the real totals live here

    @property
    def n_graphs(self) -> int:
        return len(self.sizes)

    def signature(self) -> str:
        """Shape-class id: batches with equal signatures reuse one compile.

        A hybrid-partitioned batch carries the partition's static shapes
        (threshold + both padded compacted-list sizes): the partition is a
        pytree child of the tiling, so these are jit keys — two batches
        differing only there must not claim one compiled program.  The
        storage stays the terminal component (callers key on it)."""
        b = self.bucket
        resolve = "r" if self.priorities.resolve is not None else "-"
        part = self.tiled.partition
        hy = "" if part is None else (
            f".h{part.threshold}:{int(part.dense.tiles.shape[0])}"
            f":{int(part.sp_rows.shape[0])}"
        )
        return (
            f"T{b.tile_size}.b{b.n_blocks}.t{b.n_tiles_pad}.e{b.e_pad}"
            f".{resolve}{hy}.{b.storage}"
        )

    def unpack(self, x) -> List[np.ndarray]:
        """Slice a packed per-vertex vector into per-member vectors (plan ids)."""
        x = np.asarray(x)
        return [x[off : off + n] for off, n in zip(self.offsets, self.sizes)]


def pack_batch(
    plans: Sequence[TilePlan],
    keys: Sequence[jax.Array],
    heuristic: str,
    *,
    bucket: Optional[Bucket] = None,
    priority_cache: Optional[PriorityCache] = None,
) -> PackedBatch:
    """Concatenate cached per-graph plans into one block-diagonal batch."""
    if not plans:
        raise ValueError("pack_batch needs at least one plan")
    if len(keys) != len(plans):
        raise ValueError(f"{len(plans)} plans but {len(keys)} keys")
    T = plans[0].tiled.tile_size
    if any(p.tiled.tile_size != T for p in plans):
        raise ValueError("all plans in a batch must share tile_size")
    storage = plans[0].tiled.storage
    if any(p.tiled.storage != storage for p in plans):
        raise ValueError("all plans in a batch must share tile storage")
    if bucket is None:
        bucket = bucket_for(plans, T)
    need = bucket_for(plans, T)
    if (need.n_blocks > bucket.n_blocks or need.n_tiles_pad > bucket.n_tiles_pad
            or need.e_pad > bucket.e_pad or bucket.tile_size != T
            or bucket.storage != storage):
        raise ValueError(f"batch needs {need}, bucket {bucket} too small")

    n_total = bucket.n_blocks * T
    neg = int(_NEG)

    # per-member priorities: each member's OWN key and degree statistics
    pris = [
        _member_priorities(p, key, heuristic, priority_cache)
        for p, key in zip(plans, keys)
    ]
    has_resolve = pris[0][1] is not None

    offsets: List[int] = []
    sizes: List[int] = []
    sel = np.full(n_total, neg, dtype=np.int32)
    res = np.full(n_total, neg, dtype=np.int32) if has_resolve else None
    alive0 = np.zeros(n_total, dtype=bool)
    col_gate = np.zeros(bucket.n_blocks, dtype=np.int32)

    src_parts: List[np.ndarray] = []
    dst_parts: List[np.ndarray] = []
    tile_parts: List[np.ndarray] = []
    row_parts: List[np.ndarray] = []
    col_parts: List[np.ndarray] = []

    boff = 0
    for plan, (sel_np, res_np) in zip(plans, pris):
        g, t = plan.g, plan.tiled
        voff = boff * T
        offsets.append(voff)
        sizes.append(g.n_nodes)

        sel[voff : voff + g.n_nodes] = sel_np
        if has_resolve:
            res[voff : voff + g.n_nodes] = res_np
        alive0[voff : voff + g.n_nodes] = True
        col_gate[boff : boff + plan.n_blocks] = 1

        src_parts.append(np.asarray(g.senders)[: g.n_edges].astype(np.int64) + voff)
        dst_parts.append(np.asarray(g.receivers)[: g.n_edges].astype(np.int64) + voff)
        if t.n_tiles:
            tile_parts.append(np.asarray(t.tiles)[: t.n_tiles])
            row_parts.append(np.asarray(t.tile_rows)[: t.n_tiles] + boff)
            col_parts.append(np.asarray(t.tile_cols)[: t.n_tiles] + boff)
        boff += plan.n_blocks

    # -- edges: concat + sentinel pad to the bucket's static e_pad.  The
    # Graph DECLARES n_edges = e_pad (see module docstring): n_edges is a
    # static jit key, and sentinel half-edges are inert in the segment ops
    # (their contributions land in the dropped dummy segment row).
    s = np.concatenate(src_parts) if src_parts else np.zeros(0, np.int64)
    r = np.concatenate(dst_parts) if dst_parts else np.zeros(0, np.int64)
    n_real_edges = int(s.shape[0])
    pad = np.full(bucket.e_pad - n_real_edges, n_total, dtype=np.int64)
    batch_g = Graph(
        senders=jnp.asarray(np.concatenate([s, pad]).astype(np.int32)),
        receivers=jnp.asarray(np.concatenate([r, pad]).astype(np.int32)),
        n_nodes=n_total,
        n_edges=bucket.e_pad,
    )

    # -- tiles: concat + zero-tile pad pinned to the last real block-row.
    # Block-diagonal concatenation is storage-agnostic: packed members
    # concatenate their (nt, T, W) uint32 words exactly like int8 tiles,
    # and all-zero packed padding tiles are equally inert.
    if storage == "bitpack":
        empty_shape, tile_dtype = (0, T, packed_words(T)), np.uint32
    else:
        empty_shape, tile_dtype = (0, T, T), np.int8
    if tile_parts:
        tiles = np.concatenate(tile_parts)
        rows = np.concatenate(row_parts).astype(np.int32)
        cols = np.concatenate(col_parts).astype(np.int32)
    else:
        tiles = np.zeros(empty_shape, dtype=tile_dtype)
        rows = np.zeros(0, dtype=np.int32)
        cols = np.zeros(0, dtype=np.int32)
    n_real_tiles = int(tiles.shape[0])
    n_pad_tiles = bucket.n_tiles_pad - n_real_tiles
    last_row = np.int32(rows[-1]) if n_real_tiles else np.int32(0)
    tiles = np.concatenate(
        [tiles, np.zeros((n_pad_tiles,) + tiles.shape[1:], tiles.dtype)]
    )
    rows = np.concatenate([rows, np.full(n_pad_tiles, last_row, np.int32)])
    cols = np.concatenate([cols, np.zeros(n_pad_tiles, np.int32)])

    counts = np.bincount(rows[:n_real_tiles], minlength=bucket.n_blocks)
    row_starts = np.zeros(bucket.n_blocks + 1, dtype=np.int32)
    np.cumsum(counts, out=row_starts[1:])

    # n_tiles DECLARES the bucket count (static jit key; see docstring).
    # All-zero padding tiles pinned to the last real block-row accumulate
    # nothing, and counting them "covered" only routes that row through the
    # kernel epilogue it already takes (zero real tiles ⇒ the zero tile
    # computes exactly the trivial n_c=0 rule the wrapper would patch in).
    batch_tiled = BlockTiledGraph(
        tiles=jnp.asarray(tiles),
        tile_rows=jnp.asarray(rows),
        tile_cols=jnp.asarray(cols),
        row_starts=jnp.asarray(row_starts),
        n_tiles=bucket.n_tiles_pad,
        n_nodes=n_total,
        tile_size=T,
        n_block_rows=bucket.n_blocks,
        n_block_cols=bucket.n_blocks,
        storage=storage,
    )

    # Hybrid routing survives batching only when it is coherent across the
    # whole pack: every member partitioned, all at one threshold.  The batch
    # partition is REBUILT over the packed tile list (padding tiles are
    # all-zero, so they land in neither compacted list) rather than
    # offset-concatenated — `partition_tiles` is deterministic, so this is
    # the same partition a from-scratch plan of the packed graph would get.
    parts = [p.tiled.partition for p in plans]
    if parts and all(pt is not None for pt in parts):
        thr = parts[0].threshold
        if all(pt.threshold == thr for pt in parts):
            batch_tiled = dataclasses.replace(
                batch_tiled, partition=partition_tiles(batch_tiled, thr)
            )

    priorities = Priorities(
        select=jnp.asarray(sel),
        resolve=jnp.asarray(res) if has_resolve else None,
    )
    return PackedBatch(
        g=batch_g,
        tiled=batch_tiled,
        priorities=priorities,
        alive0=jnp.asarray(alive0),
        col_gate=jnp.asarray(col_gate),
        offsets=tuple(offsets),
        sizes=tuple(sizes),
        bucket=bucket,
        n_real_edges=n_real_edges,
        n_real_tiles=n_real_tiles,
    )

"""`python -m repro.lint` — the command-line front end.

Exit codes: 0 clean (no active findings), 1 active findings, 2 bad usage.
`tools/ci_guards.py` delegates here with `--rules RPR001..RPR005` and the
baseline disabled, preserving the old guard script's exact exit semantics.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List, Optional, Sequence

from repro.lint.analysis import load_universe
from repro.lint.baseline import Baseline
from repro.lint.emit import emit_json, emit_sarif, emit_text
from repro.lint.rules import ALL_RULES, get_rules, run_rules

DEFAULT_BASELINE = "tools/lint_baseline.json"


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="jit-aware static analysis for the TC-MIS codebase "
        "(rule catalog: DESIGN.md §15)",
    )
    p.add_argument(
        "paths",
        nargs="*",
        default=["src"],
        help="files or directories to lint (default: src)",
    )
    p.add_argument(
        "--format",
        choices=("text", "json", "sarif"),
        default="text",
        help="output format (default: text)",
    )
    p.add_argument(
        "--output", "-o", default=None, help="write report to a file"
    )
    p.add_argument(
        "--rules",
        default=None,
        help="comma-separated rule ids to run (default: all)",
    )
    p.add_argument(
        "--baseline",
        default=None,
        help=f"baseline file (default: {DEFAULT_BASELINE} when it exists)",
    )
    p.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore any baseline file",
    )
    p.add_argument(
        "--update-baseline",
        action="store_true",
        help="write all current findings to the baseline file and exit 0",
    )
    p.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    return p


def _find_baseline(args) -> Optional[pathlib.Path]:
    if args.no_baseline:
        return None
    if args.baseline:
        return pathlib.Path(args.baseline)
    # default baseline lives next to the repo root: walk up from the first
    # lint path looking for tools/lint_baseline.json
    start = pathlib.Path(args.paths[0]).resolve()
    start = start if start.is_dir() else start.parent
    for d in (start, *start.parents):
        cand = d / DEFAULT_BASELINE
        if cand.is_file():
            return cand
    cand = pathlib.Path.cwd() / DEFAULT_BASELINE
    return cand if cand.is_file() else None


def _list_rules() -> str:
    lines = ["rule      severity  name                    summary"]
    for r in ALL_RULES:
        lines.append(
            f"{r.id:<9} {r.severity:<9} {r.name:<23} {r.summary}"
        )
    return "\n".join(lines) + "\n"


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        sys.stdout.write(_list_rules())
        return 0

    try:
        rule_ids = (
            [r.strip() for r in args.rules.split(",") if r.strip()]
            if args.rules
            else None
        )
        rules = get_rules(rule_ids)
    except KeyError as e:
        sys.stderr.write(f"repro-lint: {e.args[0]}\n")
        return 2

    paths: List[pathlib.Path] = []
    for raw in args.paths:
        p = pathlib.Path(raw)
        if not p.exists():
            sys.stderr.write(f"repro-lint: no such path: {raw}\n")
            return 2
        paths.append(p)

    ctx = load_universe(paths)
    findings = run_rules(ctx, rules)

    baseline_path = _find_baseline(args)
    if args.update_baseline:
        target = pathlib.Path(
            args.baseline or baseline_path or DEFAULT_BASELINE
        )
        target.parent.mkdir(parents=True, exist_ok=True)
        Baseline.from_findings(findings).save(target)
        sys.stderr.write(
            f"repro-lint: baseline written to {target} "
            f"({sum(1 for f in findings if not f.suppressed)} entries)\n"
        )
        return 0
    if baseline_path is not None:
        try:
            findings = Baseline.load(baseline_path).apply(findings)
        except (ValueError, OSError, KeyError) as e:
            sys.stderr.write(f"repro-lint: bad baseline: {e}\n")
            return 2

    if args.format == "text":
        report = emit_text(findings)
    elif args.format == "json":
        report = emit_json(findings)
    else:
        report = emit_sarif(findings, rules)

    if args.output:
        pathlib.Path(args.output).write_text(report, encoding="utf-8")
    else:
        sys.stdout.write(report)

    return 1 if any(f.active for f in findings) else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())

"""Entry point: `python -m repro.lint [paths...]`."""
import sys

from repro.lint.cli import main

sys.exit(main())

"""Interprocedural call graph + hot-path reachability (DESIGN.md §15).

The graph is seeded at the jitted entry points and every function statically
reachable from a seed is "hot" — the hot-path rules (host-sync, impurity,
dtype, hot-densify) apply to the whole reachable set regardless of module,
which is precisely what the directory-scoped guards could not do.

Resolution policy (documented misses included):

  1. bare `f(...)`          -> same-module def, nested def of the caller, or
                               an imported project symbol (alias-aware);
  2. `mod.f(...)`           -> `f` in the imported project module (dotted
                               aliases and `from pkg import mod` both work);
  3. `Cls.f(...)`           -> method `f` of an imported/local project class;
  4. `self.f(...)`          -> `f` in the enclosing class, its project
                               ancestors AND its project descendants (the
                               subclass set over-approximates dispatch);
  5. `obj.f(...)`           -> dispatch-by-name, restricted to ENGINE
                               classes (anything deriving from RoundEngine):
                               `engine.step(...)` reaches every engine's
                               `step`.  Method calls on non-engine values
                               (`ctx.tiled.nnz()`) are a DOCUMENTED MISS —
                               the receiver's type is not tracked, so such
                               callees must be reachable some other way or
                               seeded explicitly.

Function REFERENCES create edges too (`jax.jit(fn)`, `functools.partial(fn)`,
`lax.while_loop(cond, body)`): a function passed around by a hot caller is
assumed callable from it.  Loop-body positions of `while_loop`/`scan`/
`fori_loop` additionally mark the target as a loop body for the loop-carry
rule (lambda bodies are recorded on the enclosing function).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.lint.analysis import (
    LOOP_BODY_KWARGS,
    LOOP_CALLS,
    ClassInfo,
    FunctionInfo,
    LintContext,
    ModuleInfo,
)

# The hot-path seed list (DESIGN.md §15): jitted entry points by leaf name,
# engine round bodies by method name (restricted to RoundEngine subclasses),
# and every Pallas kernel body by suffix.
SEED_FUNCTIONS = frozenset(
    {
        "_tc_mis_impl",
        "_run_phases_impl",
        "repair_mis",
        # jitted helpers reached from the warm-start / validation paths —
        # seeded so hot-path reachability covers them even when the round
        # entry points are refactored (ISSUE 10).  The obs/ metrics layer is
        # deliberately NOT seeded: it is eager-only by contract (§14/§17).
        "warm_state",
        "_covered",
        "_covered_bits",
    }
)
SEED_ENGINE_METHODS = frozenset(
    {
        "step",
        "step_bits",
        "fused_step",
        "fused_step_bits",
        "step_with_stats",
        "_step_bits_with_stats",
        "step_hybrid",
        "step_bits_hybrid",
        "_step_hybrid_with_stats",
        "_step_bits_hybrid_with_stats",
    }
)
SEED_SUFFIXES = ("_kernel",)
ENGINE_BASE = "RoundEngine"

DEFAULT_SEEDS = {
    "functions": sorted(SEED_FUNCTIONS),
    "engine_methods": sorted(SEED_ENGINE_METHODS),
    "suffixes": list(SEED_SUFFIXES),
}


@dataclasses.dataclass
class CallGraph:
    edges: Dict[str, Set[str]]
    hot: Set[str]
    seeds: Set[str]
    loop_bodies: Set[str]
    engine_classes: Set[str]                 # "module:ClassName"
    engine_methods: Dict[str, Set[str]]      # method name -> function keys

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, ctx: LintContext, seeds=None) -> "CallGraph":
        graph = cls(
            edges={},
            hot=set(),
            seeds=set(),
            loop_bodies=set(),
            engine_classes=set(),
            engine_methods={},
        )
        graph._index_engine_classes(ctx)
        for mi in ctx.modules.values():
            for fi in mi.functions.values():
                graph._collect_edges(ctx, mi, fi)
        graph._seed(ctx, seeds)
        graph._reach()
        return graph

    # -- engine classes: RoundEngine + transitive subclasses ---------------
    def _index_engine_classes(self, ctx: LintContext) -> None:
        by_name: Dict[str, List[Tuple[str, ClassInfo]]] = {}
        for mi in ctx.modules.values():
            for ci in mi.classes.values():
                by_name.setdefault(ci.name.split(".")[-1], []).append(
                    (mi.name, ci)
                )
        # fixpoint over "derives (by base name) from an engine class"
        engine_names = {ENGINE_BASE}
        changed = True
        while changed:
            changed = False
            for entries in by_name.values():
                for mod, ci in entries:
                    leaf = ci.name.split(".")[-1]
                    if leaf in engine_names:
                        continue
                    if any(b[-1] in engine_names for b in ci.bases):
                        engine_names.add(leaf)
                        changed = True
        for entries in by_name.values():
            for mod, ci in entries:
                if ci.name.split(".")[-1] in engine_names:
                    self.engine_classes.add(f"{mod}:{ci.name}")
                    for meth, key in ci.methods.items():
                        self.engine_methods.setdefault(meth, set()).add(key)

    # -- per-function edge collection --------------------------------------
    def _add(self, src: str, dst: str) -> None:
        self.edges.setdefault(src, set()).add(dst)

    def _collect_edges(
        self, ctx: LintContext, mi: ModuleInfo, fi: FunctionInfo
    ) -> None:
        for nested in fi.nested:
            self._add(fi.key, nested)  # framework-invoked (`@pl.when`) bodies
        for call in fi.calls:
            if call.chain:
                for dst in self.resolve(ctx, mi, fi, call.chain):
                    self._add(fi.key, dst)
                # loop-body marking for Name-valued body args
                if call.chain[-1] in LOOP_CALLS:
                    self._mark_loop_body(ctx, mi, fi, call)
            for ref in call.arg_chains:
                for dst in self.resolve(ctx, mi, fi, ref, reference=True):
                    self._add(fi.key, dst)

    def _mark_loop_body(self, ctx, mi, fi, call) -> None:
        import ast

        pos = LOOP_CALLS[call.chain[-1]]
        node = call.node
        body_arg = None
        if len(node.args) > pos:
            body_arg = node.args[pos]
        else:
            kw = LOOP_BODY_KWARGS[call.chain[-1]]
            for k in node.keywords:
                if k.arg == kw:
                    body_arg = k.value
        chain = None
        if body_arg is not None and not isinstance(body_arg, ast.Lambda):
            from repro.lint.analysis import attr_chain

            chain = attr_chain(body_arg)
        if chain:
            for dst in self.resolve(ctx, mi, fi, chain, reference=True):
                self.loop_bodies.add(dst)

    # -- chain resolution ---------------------------------------------------
    def resolve(
        self,
        ctx: LintContext,
        mi: ModuleInfo,
        fi: Optional[FunctionInfo],
        chain: Tuple[str, ...],
        reference: bool = False,
    ) -> Set[str]:
        out: Set[str] = set()
        root, rest = chain[0], chain[1:]

        # nested def of the caller (or of an enclosing function)
        scope = fi
        while scope is not None and not rest:
            cand = f"{scope.qualname}.{root}"
            if cand in mi.functions:
                return {f"{mi.name}:{cand}"}
            scope = (
                ctx.function(scope.parent) if scope.parent else None
            )

        if not rest:
            # same-module def (module level or method of the enclosing class)
            if root in mi.functions:
                return {f"{mi.name}:{root}"}
            if fi is not None and fi.class_name:
                cand = f"{fi.class_name}.{root}"
                if cand in mi.functions:
                    return {f"{mi.name}:{cand}"}
            tgt = mi.imports.get(root)
            if tgt and tgt[0] == "symbol":
                _, src_mod, sym = tgt
                dst = ctx.modules.get(src_mod)
                if dst and sym in dst.functions:
                    return {f"{dst.name}:{sym}"}
                # `from pkg import name` re-exported via pkg/__init__
                dst2 = ctx.modules.get(f"{src_mod}.{sym}")
                if dst2 is None and dst is not None:
                    fwd = dst.imports.get(sym)
                    if fwd and fwd[0] == "symbol":
                        dst3 = ctx.modules.get(fwd[1])
                        if dst3 and fwd[2] in dst3.functions:
                            return {f"{dst3.name}:{fwd[2]}"}
            return out

        # self./cls. method dispatch: class family (ancestors + descendants)
        if root in ("self", "cls") and fi is not None and fi.class_name:
            meth = chain[-1]
            for key in self._family_methods(ctx, mi, fi.class_name, meth):
                out.add(key)
            return out

        tgt = mi.imports.get(root)
        if tgt is not None:
            if tgt[0] == "module":
                mod_parts = [tgt[1], *rest[:-1]]
            else:
                mod_parts = [f"{tgt[1]}.{tgt[2]}", *rest[:-1]]
            # longest dotted prefix that names a universe module wins
            for cut in range(len(mod_parts), 0, -1):
                cand_mod = ".".join(mod_parts[:cut])
                dst = ctx.modules.get(cand_mod)
                if dst is None:
                    continue
                tail = [*mod_parts[cut:], chain[-1]]
                if len(tail) == 1 and tail[0] in dst.functions:
                    out.add(f"{dst.name}:{tail[0]}")
                elif len(tail) == 2 and tail[0] in dst.classes:
                    key = dst.classes[tail[0]].methods.get(tail[1])
                    if key:
                        out.add(key)
                break
            if out or tgt[0] == "module":
                return out
            # `Cls.meth(...)` where Cls was imported as a symbol
            if tgt[0] == "symbol" and len(rest) == 1:
                dst = ctx.modules.get(tgt[1])
                if dst and tgt[2] in dst.classes:
                    key = dst.classes[tgt[2]].methods.get(rest[0])
                    if key:
                        return {key}
            return out

        # local class: `Cls.meth(...)` / `Cls().meth(...)` approximations
        if root in mi.classes and len(rest) == 1:
            key = mi.classes[root].methods.get(rest[0])
            if key:
                return {key}

        # dispatch-by-name, engine classes only (`engine.step(...)`).
        # Method calls on other untyped receivers are a documented miss.
        if len(chain) == 2 and not reference:
            out |= self.engine_methods.get(chain[-1], set())
        return out

    def _family_methods(
        self, ctx: LintContext, mi: ModuleInfo, class_name: str, meth: str
    ) -> Set[str]:
        """`self.meth` targets: enclosing class, ancestors, descendants."""
        out: Set[str] = set()
        leaf = class_name.split(".")[-1]
        family = {leaf}
        # expand by base-name ancestry in both directions until fixpoint
        all_classes = [
            (m.name, ci) for m in ctx.modules.values()
            for ci in m.classes.values()
        ]
        changed = True
        while changed:
            changed = False
            for mod, ci in all_classes:
                cleaf = ci.name.split(".")[-1]
                base_leaves = {b[-1] for b in ci.bases}
                if cleaf in family and not base_leaves <= family:
                    family |= base_leaves
                    changed = True
                elif base_leaves & family and cleaf not in family:
                    family.add(cleaf)
                    changed = True
        for mod, ci in all_classes:
            if ci.name.split(".")[-1] in family and meth in ci.methods:
                out.add(ci.methods[meth])
        return out

    # -- seeding + reachability --------------------------------------------
    def _seed(self, ctx: LintContext, seeds=None) -> None:
        seeds = seeds or DEFAULT_SEEDS
        fn_names = set(seeds.get("functions", ()))
        meth_names = set(seeds.get("engine_methods", ()))
        suffixes = tuple(seeds.get("suffixes", ()))
        for mi in ctx.modules.values():
            # `*_kernel` suffix seeding is scoped to kernels packages so a
            # host-side `_bench_pallas_kernel` driver in benchmarks/ does
            # not masquerade as a device kernel ...
            kernels_pkg = "kernels" in mi.name.split(".")
            for fi in mi.functions.values():
                if fi.name in fn_names and fi.class_name is None:
                    self.seeds.add(fi.key)
                elif kernels_pkg and suffixes and fi.name.endswith(suffixes):
                    self.seeds.add(fi.key)
                elif (
                    fi.name in meth_names
                    and fi.class_name is not None
                    and f"{mi.name}:{fi.class_name}" in self.engine_classes
                ):
                    self.seeds.add(fi.key)
            # ... and any function actually handed to pallas_call() is a
            # kernel body wherever it lives.
            for fi in mi.functions.values():
                for call in fi.calls:
                    if call.name == "pallas_call":
                        for ref in call.arg_chains:
                            self.seeds |= self.resolve(
                                ctx, mi, fi, ref, reference=True
                            )
            for call in mi.calls:
                if not call.stack and call.name == "pallas_call":
                    for ref in call.arg_chains:
                        self.seeds |= self.resolve(
                            ctx, mi, None, ref, reference=True
                        )

    def _reach(self) -> None:
        stack = list(self.seeds)
        self.hot = set(self.seeds)
        while stack:
            cur = stack.pop()
            for nxt in self.edges.get(cur, ()):
                if nxt not in self.hot:
                    self.hot.add(nxt)
                    stack.append(nxt)

    # -- queries ------------------------------------------------------------
    def is_hot(self, key: str) -> bool:
        return key in self.hot

    def hot_functions(self, ctx: LintContext):
        for key in sorted(self.hot):
            fi = ctx.function(key)
            if fi is not None:
                yield fi

"""repro.lint — jit-aware static analysis for the TC-MIS codebase.

Six PRs of hot-path invariants ("packed stays packed", "host-silent round
loop", "one unpack at the epilogue") used to live in five ad-hoc AST guards
scoped by *directory* (tools/ci_guards.py).  This package replaces them with
a real analysis pass (DESIGN.md §15):

  * a rule engine — per-rule IDs (RPR0xx), severities, inline suppressions
    (`# repro-lint: disable=RPR0xx <reason>`), a checked-in baseline for
    grandfathered findings, and text/JSON/SARIF emitters so CI renders
    findings as GitHub annotations;
  * an interprocedural hot-path reachability analysis: the call graph is
    seeded at the jitted entry points (`_tc_mis_impl`, `_run_phases_impl`,
    engine `step*` bodies, Pallas `*_kernel` functions, `repair_mis`) and
    the hot-path rules apply to every statically reachable function,
    regardless of which module it lives in — a host sync smuggled in via a
    helper imported into the round body no longer sails through;
  * a rule catalog: the five CI guards ported one-to-one (RPR001–RPR005)
    plus jax/pallas-specific rules — host-sync detection, trace impurity,
    dtype discipline, loop-carry hygiene, hot-path densify, deprecation
    enforcement and Pallas-kernel hygiene (RPR010–RPR016).

Run `python -m repro.lint src/` (exit 0 = clean); `tools/ci_guards.py`
survives as a thin shim that runs only the guard rules.
"""
from repro.lint.analysis import LintContext, load_universe
from repro.lint.baseline import Baseline
from repro.lint.callgraph import CallGraph, DEFAULT_SEEDS
from repro.lint.cli import main
from repro.lint.model import Finding, Rule, Severity
from repro.lint.rules import ALL_RULES, get_rules, run_rules

__all__ = [
    "ALL_RULES",
    "Baseline",
    "CallGraph",
    "DEFAULT_SEEDS",
    "Finding",
    "LintContext",
    "Rule",
    "Severity",
    "get_rules",
    "load_universe",
    "main",
    "run_rules",
]

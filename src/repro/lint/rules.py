"""The rule catalog (DESIGN.md §15 has the rendered table).

RPR001–RPR005 port tools/ci_guards.py Guards 1–5 one-to-one (same module
scoping, same detection) so the shim keeps identical behaviour.  RPR010+ are
the jit-aware rules: they predicate on the call graph's hot set — every
function statically reachable from a jitted entry point — instead of on
directory layout.

Adding a rule: write a generator over `LintContext` yielding `Finding`s,
wrap it in a `Rule` with an unused RPR0xx id, and append it to ALL_RULES.
A new engine inherits every hot-path rule for free the moment its class
derives from `RoundEngine` — its `step*` methods become seeds automatically
(repro.lint.callgraph.DEFAULT_SEEDS).
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator, List, Optional, Sequence, Set, Tuple

from repro.lint.analysis import CallInfo, FunctionInfo, LintContext, ModuleInfo
from repro.lint.model import Finding, Rule, Severity

# --------------------------------------------------------------------------
# shared vocabulary (mirrors tools/ci_guards.py so detection is identical)
# --------------------------------------------------------------------------
TILE_UNPACKS = ("unpack_tile_bits", "unpack_tile_mask")
TILE_DENSE_DISPATCH = ("dense_tiles", "dense_tile_mask")
DENSIFY_CALLS = TILE_UNPACKS + TILE_DENSE_DISPATCH
FRONTIER_UNPACKS = ("unpack_frontier_bits", "unpack_frontier_words")
HOST_CALLBACK_CALLS = ("io_callback", "pure_callback", "debug_callback")
HOST_PRINT_RECEIVERS = ("debug",)
KERNEL_FN_SUFFIX = "_kernel"
ORACLE_FN_SUFFIX = "_oracle"

KERNELS_PKG = "repro.kernels"
DYNGRAPH_PKG = "repro.dyngraph"
HOT_PKGS = ("repro.core", "repro.kernels")
ORACLE_MODULE = "repro.kernels.ref"
TILING_MODULE = "repro.core.tiling"
FRONTIER_ALLOWLIST = {
    ("repro.core.tc_mis", "_result"),
    ("repro.core.distributed", "gather_bool"),
}

HOST_SYNC_METHODS = ("item", "tolist", "block_until_ready", "device_get")
IMPURE_STDLIB = ("random", "time", "datetime")
DTYPE64 = ("float64", "int64", "uint64", "f8")
LOOP_GROWING = (
    "concatenate", "append", "hstack", "vstack", "dstack",
    "column_stack", "insert", "resize",
)
DEPRECATED_SYMBOLS = ("tc_mis", "run_phases", "TCMISConfig")
DEPRECATED_SOURCES = ("repro.core", "repro.core.tc_mis")
DEPRECATION_EXEMPT = ("repro.core.tc_mis", "repro.core")
KERNEL_CALL_ALLOWLIST = frozenset(
    TILE_UNPACKS + ("pack_frontier_bits", "pack_sorted_frontier_bits")
)
KERNEL_PY_BUILTINS = frozenset(
    {"range", "len", "min", "max", "abs", "int", "float", "bool",
     "enumerate", "zip", "tuple"}
)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _in_pkg(module: str, pkg: str) -> bool:
    return module == pkg or module.startswith(pkg + ".")


def _kernel_module(mi: ModuleInfo) -> bool:
    return _in_pkg(mi.name, KERNELS_PKG) and mi.name != ORACLE_MODULE


def _symbol(stack: Tuple[str, ...]) -> str:
    return ".".join(stack) if stack else "<module>"


def _mk(
    mi: ModuleInfo, rule_id: str, severity: str, node, symbol: str, msg: str
) -> Finding:
    return Finding(
        rule=rule_id,
        severity=severity,
        path=mi.rel,
        line=getattr(node, "lineno", 1),
        col=getattr(node, "col_offset", 0) + 1,
        module=mi.name,
        symbol=symbol,
        message=msg,
    )


def _import_target(mi: ModuleInfo, alias: str) -> Optional[str]:
    """Dotted module an alias ultimately refers to (`np` -> `numpy`,
    `lax` -> `jax`, `tiling` -> `repro.core.tiling`-ish)."""
    tgt = mi.imports.get(alias)
    if tgt is None:
        return None
    if tgt[0] == "module":
        return tgt[1]
    return f"{tgt[1]}.{tgt[2]}"


def _is_jax_rooted(mi: ModuleInfo, name: str) -> bool:
    tgt = _import_target(mi, name)
    return tgt is not None and (tgt == "jax" or tgt.startswith("jax."))


def _is_numpy_rooted(mi: ModuleInfo, name: str) -> bool:
    tgt = _import_target(mi, name)
    return tgt is not None and (tgt == "numpy" or tgt.startswith("numpy."))


def _mentions_traced(mi: ModuleInfo, node: ast.AST) -> bool:
    """Heuristic: does the expression visibly involve a jax value (a call or
    attribute rooted at jnp/lax/jax)?  `int(jnp.sum(x))` yes; `int(T // 32)`
    no.  A plain `int(x)` on a traced local is a documented miss."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and _is_jax_rooted(mi, sub.id):
            return True
    return False


def _hot_report_functions(ctx: LintContext) -> Iterator[FunctionInfo]:
    for fi in ctx.graph.hot_functions(ctx):
        if fi.module in ctx.report:
            yield fi


def _stack_is_sanctioned(stack: Tuple[str, ...], *suffixes: str) -> bool:
    return any(fn.endswith(tuple(suffixes)) for fn in stack)


# --------------------------------------------------------------------------
# RPR001 + RPR002 — Guards 1–2: kernel modules keep tiles packed until VMEM
# --------------------------------------------------------------------------
def _check_kernel_tile_unpack(ctx: LintContext) -> Iterator[Finding]:
    for mi in ctx.report_modules():
        if not _kernel_module(mi):
            continue
        for call in mi.calls:
            if call.name in TILE_UNPACKS and not _stack_is_sanctioned(
                call.stack, KERNEL_FN_SUFFIX
            ):
                yield _mk(
                    mi, "RPR001", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    f"{call.name} called outside a *{KERNEL_FN_SUFFIX} body "
                    f"— this materialises (nt, T, T) in HBM and forfeits the "
                    f"8x packed-DMA reduction",
                )


def _check_kernel_densify(ctx: LintContext) -> Iterator[Finding]:
    for mi in ctx.report_modules():
        if not _kernel_module(mi):
            continue
        for call in mi.calls:
            if call.name in TILE_DENSE_DISPATCH:
                yield _mk(
                    mi, "RPR002", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    f"{call.name} in a kernel module — the whole-array "
                    f"oracle dispatches live in kernels/ref.py only",
                )
            elif call.name == "to_storage":
                yield _mk(
                    mi, "RPR002", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    "to_storage() in a kernel module — kernels must consume "
                    "tiles as stored",
                )


# --------------------------------------------------------------------------
# RPR003 — Guard 3: the dyngraph delta path never densifies outside oracles
# --------------------------------------------------------------------------
def _check_dyngraph_densify(ctx: LintContext) -> Iterator[Finding]:
    watched = DENSIFY_CALLS + ("to_storage",)
    for mi in ctx.report_modules():
        if not _in_pkg(mi.name, DYNGRAPH_PKG):
            continue
        for call in mi.calls:
            if call.name in watched and not _stack_is_sanctioned(
                call.stack, ORACLE_FN_SUFFIX
            ):
                yield _mk(
                    mi, "RPR003", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    f"{call.name} outside a *{ORACLE_FN_SUFFIX} body — the "
                    f"delta path edits packed tiles as packed words, never "
                    f"densifies (DESIGN.md §12)",
                )


# --------------------------------------------------------------------------
# RPR004 — Guard 4: frontier words stay packed outside the sanctioned seams
# --------------------------------------------------------------------------
def _frontier_violation(mi: ModuleInfo, call: CallInfo) -> bool:
    if call.name not in FRONTIER_UNPACKS:
        return False
    if mi.name in (TILING_MODULE, ORACLE_MODULE):
        return False
    allowed = {
        fn for (mod, fn) in FRONTIER_ALLOWLIST if mod == mi.name
    }
    return not any(
        fn.endswith((KERNEL_FN_SUFFIX, ORACLE_FN_SUFFIX)) or fn in allowed
        for fn in call.stack
    )


def _check_frontier_unpack(ctx: LintContext) -> Iterator[Finding]:
    for mi in ctx.report_modules():
        if not _in_pkg(mi.name, "repro"):
            continue
        for call in mi.calls:
            if _frontier_violation(mi, call):
                yield _mk(
                    mi, "RPR004", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    f"{call.name} outside a *{KERNEL_FN_SUFFIX}/"
                    f"*{ORACLE_FN_SUFFIX} body or an allowlisted seam — "
                    f"frontier vectors stay packed words on the hot path "
                    f"(DESIGN.md §13)",
                )


# --------------------------------------------------------------------------
# RPR005 — Guard 5: no host callbacks / debug prints in device-hot modules
# --------------------------------------------------------------------------
def _check_host_callbacks(ctx: LintContext) -> Iterator[Finding]:
    for mi in ctx.report_modules():
        if not any(_in_pkg(mi.name, p) for p in HOT_PKGS):
            continue
        for call in mi.calls:
            if call.name in HOST_CALLBACK_CALLS:
                yield _mk(
                    mi, "RPR005", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    f"{call.name}() in a device-hot module — round-loop "
                    f"observability goes through the telemetry buffer "
                    f"(repro.obs.rounds), never host callbacks",
                )
            elif (
                call.name == "print"
                and call.chain is not None
                and len(call.chain) >= 2
                and call.chain[-2] in HOST_PRINT_RECEIVERS
            ):
                yield _mk(
                    mi, "RPR005", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    "debug.print() in a device-hot module — it forces a "
                    "host sync per round inside the while_loop",
                )
        for node in ast.walk(mi.tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                names = [a.name for a in node.names]
                module = getattr(node, "module", "") or ""
                if "host_callback" in module or any(
                    "host_callback" in n for n in names
                ):
                    yield _mk(
                        mi, "RPR005", Severity.ERROR, node, "<module>",
                        "host_callback import in a device-hot module — the "
                        "legacy host round-trip API is banned here",
                    )


# --------------------------------------------------------------------------
# RPR010 — host sync on the jit-reachable hot path
# --------------------------------------------------------------------------
def _check_host_sync(ctx: LintContext) -> Iterator[Finding]:
    for fi in _hot_report_functions(ctx):
        mi = ctx.modules[fi.module]
        for call in fi.calls:
            if call.chain is None:
                continue
            name = call.chain[-1]
            if len(call.chain) >= 2 and name in HOST_SYNC_METHODS:
                yield _mk(
                    mi, "RPR010", Severity.ERROR, call.node, fi.qualname,
                    f".{name}() in jit-reachable `{fi.qualname}` — a "
                    f"device->host sync inside the traced hot path "
                    f"serialises the round loop",
                )
            elif len(call.chain) >= 2 and _is_numpy_rooted(mi, call.chain[0]):
                yield _mk(
                    mi, "RPR010", Severity.ERROR, call.node, fi.qualname,
                    f"numpy call `{'.'.join(call.chain)}` in jit-reachable "
                    f"`{fi.qualname}` — host numpy on traced values forces "
                    f"a transfer (use jnp)",
                )
            elif (
                len(call.chain) == 1
                and name in ("float", "int", "bool")
                and any(
                    _mentions_traced(mi, a) for a in call.node.args
                )
            ):
                yield _mk(
                    mi, "RPR010", Severity.ERROR, call.node, fi.qualname,
                    f"{name}() over a jax expression in jit-reachable "
                    f"`{fi.qualname}` — python scalar conversion is a "
                    f"blocking device->host sync",
                )


# --------------------------------------------------------------------------
# RPR011 — trace impurity on the hot path
# --------------------------------------------------------------------------
def _check_impurity(ctx: LintContext) -> Iterator[Finding]:
    for fi in _hot_report_functions(ctx):
        mi = ctx.modules[fi.module]
        for line in fi.global_decls:
            anchor = type("A", (), {"lineno": line, "col_offset": 0})
            yield _mk(
                mi, "RPR011", Severity.ERROR, anchor, fi.qualname,
                f"global/nonlocal mutation in jit-reachable `{fi.qualname}` "
                f"— traced functions must be pure (the write happens at "
                f"trace time, once, not per call)",
            )
        for call in fi.calls:
            if call.chain is None:
                continue
            dotted = ".".join(call.chain)
            if len(call.chain) >= 2:
                tgt = _import_target(mi, call.chain[0])
                if tgt in IMPURE_STDLIB or (
                    tgt is not None
                    and tgt.split(".")[0] in IMPURE_STDLIB
                ):
                    yield _mk(
                        mi, "RPR011", Severity.ERROR, call.node, fi.qualname,
                        f"`{dotted}` in jit-reachable `{fi.qualname}` — "
                        f"stdlib {tgt.split('.')[0]} is trace-impure (the "
                        f"value freezes at trace time)",
                    )
                elif (
                    _is_numpy_rooted(mi, call.chain[0])
                    and len(call.chain) >= 3
                    and call.chain[1] == "random"
                ):
                    yield _mk(
                        mi, "RPR011", Severity.ERROR, call.node, fi.qualname,
                        f"`{dotted}` in jit-reachable `{fi.qualname}` — "
                        f"numpy RNG is trace-impure; thread a jax.random "
                        f"key instead",
                    )
            elif call.chain == ("print",):
                yield _mk(
                    mi, "RPR011", Severity.ERROR, call.node, fi.qualname,
                    f"print() in jit-reachable `{fi.qualname}` — prints "
                    f"fire at trace time, not per round",
                )


# --------------------------------------------------------------------------
# RPR012 — dtype discipline on the hot path (no implicit 64-bit)
# --------------------------------------------------------------------------
def _dtype64_expr(mi: ModuleInfo, node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id in ("float", "int"):
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in DTYPE64:
        return node.attr
    if isinstance(node, ast.Constant) and node.value in DTYPE64:
        return str(node.value)
    return None


def _check_dtype(ctx: LintContext) -> Iterator[Finding]:
    for fi in _hot_report_functions(ctx):
        mi = ctx.modules[fi.module]
        for call in fi.calls:
            hits: List[str] = []
            if call.name == "astype" and call.node.args:
                d = _dtype64_expr(mi, call.node.args[0])
                if d:
                    hits.append(f"astype({d})")
            for kw in call.node.keywords:
                if kw.arg == "dtype":
                    d = _dtype64_expr(mi, kw.value)
                    if d:
                        hits.append(f"dtype={d}")
            for h in hits:
                yield _mk(
                    mi, "RPR012", Severity.ERROR, call.node, fi.qualname,
                    f"{h} in jit-reachable `{fi.qualname}` — python "
                    f"builtins and 64-bit dtypes promote to float64/int64 "
                    f"(x64 is off; be explicit: jnp.float32 / jnp.int32)",
                )


# --------------------------------------------------------------------------
# RPR013 — loop-carry hygiene inside while_loop / scan / fori_loop bodies
# --------------------------------------------------------------------------
def _growing_call(mi: ModuleInfo, call_node: ast.Call) -> Optional[str]:
    from repro.lint.analysis import attr_chain

    chain = attr_chain(call_node.func)
    if not chain or chain[-1] not in LOOP_GROWING:
        return None
    if len(chain) == 1:
        return chain[-1]
    root_tgt = _import_target(mi, chain[0])
    if root_tgt and (
        root_tgt.startswith("jax") or root_tgt.startswith("numpy")
    ):
        return ".".join(chain)
    return None  # `some_list.append(...)` — not an array op


def _check_loop_carry(ctx: LintContext) -> Iterator[Finding]:
    seen: Set[Tuple[str, int]] = set()
    # named loop-body functions, resolved through the call graph (the body
    # may live in another module than the while_loop that names it)
    for key in sorted(ctx.graph.loop_bodies):
        fi = ctx.function(key)
        if fi is None or fi.module not in ctx.report:
            continue
        mi = ctx.modules[fi.module]
        for call in fi.calls:
            name = _growing_call(mi, call.node)
            if name and (mi.name, call.node.lineno) not in seen:
                seen.add((mi.name, call.node.lineno))
                yield _mk(
                    mi, "RPR013", Severity.ERROR, call.node, fi.qualname,
                    f"`{name}` inside the loop body `{fi.qualname}` — "
                    f"shape-growing ops cannot ride a while_loop/scan carry "
                    f"(XLA requires fixed shapes; preallocate + .at[].set)",
                )
    # lambda loop bodies, anchored on the enclosing function
    for mi in ctx.report_modules():
        for fi in mi.functions.values():
            for lam in fi.loop_lambdas:
                for sub in ast.walk(lam):
                    if isinstance(sub, ast.Call):
                        name = _growing_call(mi, sub)
                        if name and (mi.name, sub.lineno) not in seen:
                            seen.add((mi.name, sub.lineno))
                            yield _mk(
                                mi, "RPR013", Severity.ERROR, sub,
                                fi.qualname,
                                f"`{name}` inside a loop-body lambda of "
                                f"`{fi.qualname}` — shape-growing ops cannot "
                                f"ride a while_loop/scan carry",
                            )


# --------------------------------------------------------------------------
# RPR014 — deprecation: no internal callers of the pre-API shims
# --------------------------------------------------------------------------
def _check_deprecation(ctx: LintContext) -> Iterator[Finding]:
    for mi in ctx.report_modules():
        if mi.name in DEPRECATION_EXEMPT or "test" in mi.name.split(".")[-1]:
            continue
        package = (
            mi.name if mi.path.name == "__init__.py"
            else (mi.name.rsplit(".", 1)[0] if "." in mi.name else "")
        )
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.ImportFrom):
                from repro.lint.analysis import _resolve_relative

                src = _resolve_relative(package, node.module, node.level)
                if src in DEPRECATED_SOURCES:
                    for a in node.names:
                        if a.name in DEPRECATED_SYMBOLS:
                            yield _mk(
                                mi, "RPR014", Severity.ERROR, node,
                                "<module>",
                                f"import of deprecated `{a.name}` from "
                                f"{src} — use the repro.api front door "
                                f"(Solver / SolveOptions, DESIGN.md §10)",
                            )
        for call in mi.calls:
            if call.chain is None or call.chain[-1] not in DEPRECATED_SYMBOLS:
                continue
            flagged = False
            if len(call.chain) == 1:
                tgt = mi.imports.get(call.chain[0])
                flagged = (
                    tgt is not None
                    and tgt[0] == "symbol"
                    and tgt[1] in DEPRECATED_SOURCES
                )
            else:
                root_tgt = _import_target(mi, call.chain[0])
                if root_tgt:
                    dotted = ".".join([root_tgt, *call.chain[1:-1]])
                    flagged = dotted in DEPRECATED_SOURCES
            if flagged:
                yield _mk(
                    mi, "RPR014", Severity.ERROR, call.node,
                    _symbol(call.stack),
                    f"call to deprecated `{call.chain[-1]}` — use the "
                    f"repro.api front door (Solver.solve / Solver.profile / "
                    f"SolveOptions)",
                )


# --------------------------------------------------------------------------
# RPR015 — Pallas kernel hygiene: kernel bodies touch refs + jax ops only
# --------------------------------------------------------------------------
def _kernel_family(ctx: LintContext, fi: FunctionInfo) -> List[FunctionInfo]:
    out = [fi]
    for key in fi.nested:
        sub = ctx.function(key)
        if sub is not None:
            out.extend(_kernel_family(ctx, sub))
    return out


def _check_pallas_hygiene(ctx: LintContext) -> Iterator[Finding]:
    for mi in ctx.report_modules():
        if not _in_pkg(mi.name, KERNELS_PKG):
            continue
        for fi in mi.functions.values():
            if not fi.name.endswith(KERNEL_FN_SUFFIX) or fi.parent:
                continue
            family = _kernel_family(ctx, fi)
            nested_names = {f.name for f in family}
            for member in family:
                for call in member.calls:
                    if call.chain is None:
                        continue
                    root = call.chain[0]
                    if len(call.chain) >= 2:
                        tgt = _import_target(mi, root)
                        if tgt is None or tgt.startswith("jax"):
                            continue  # ref/array methods or jax-family ops
                        yield _mk(
                            mi, "RPR015", Severity.ERROR, call.node,
                            member.qualname,
                            f"`{'.'.join(call.chain)}` inside kernel body "
                            f"`{fi.name}` — kernel bodies may only touch "
                            f"refs and jax/pallas ops ({tgt} is not on the "
                            f"kernel allowlist)",
                        )
                    elif (
                        root not in KERNEL_CALL_ALLOWLIST
                        and root not in KERNEL_PY_BUILTINS
                        and root not in nested_names
                    ):
                        yield _mk(
                            mi, "RPR015", Severity.ERROR, call.node,
                            member.qualname,
                            f"`{root}(...)` inside kernel body `{fi.name}` "
                            f"— not on the kernel call allowlist "
                            f"(refs, jax/pallas ops, in-VMEM pack/unpack "
                            f"helpers and nested defs only)",
                        )


# --------------------------------------------------------------------------
# RPR016 — hot-path densify: the call-graph generalisation of Guard 4
# --------------------------------------------------------------------------
def _check_hot_densify(ctx: LintContext) -> Iterator[Finding]:
    watched = FRONTIER_UNPACKS + ("to_storage",)
    for fi in _hot_report_functions(ctx):
        if fi.module in (TILING_MODULE, ORACLE_MODULE):
            continue
        mi = ctx.modules[fi.module]
        for call in fi.calls:
            if call.name not in watched:
                continue
            allowed = {
                fn for (mod, fn) in FRONTIER_ALLOWLIST if mod == fi.module
            }
            if _stack_is_sanctioned(
                call.stack, KERNEL_FN_SUFFIX, ORACLE_FN_SUFFIX
            ) or any(fn in allowed for fn in call.stack):
                continue
            yield _mk(
                mi, "RPR016", Severity.ERROR, call.node, fi.qualname,
                f"{call.name} in jit-reachable `{fi.qualname}` — a densify "
                f"reached from a jitted entry point smuggles a dense "
                f"round-trip into the packed round body, wherever the "
                f"helper lives (DESIGN.md §13/§15)",
            )


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
ALL_RULES: Tuple[Rule, ...] = (
    Rule(
        id="RPR001", name="kernel-tile-unpack", severity=Severity.ERROR,
        summary="tile unpack outside a *_kernel body in a kernel module",
        rationale="packed tiles must stay packed until VMEM; an unpack "
                  "before pallas_call materialises (nt,T,T) in HBM",
        escapes="kernels/ref.py (the oracle); *_kernel bodies",
        check=_check_kernel_tile_unpack,
    ),
    Rule(
        id="RPR002", name="kernel-densify", severity=Severity.ERROR,
        summary="dense_tiles/dense_tile_mask/to_storage in a kernel module",
        rationale="whole-array densify dispatches belong to the oracle path",
        escapes="kernels/ref.py only",
        check=_check_kernel_densify,
    ),
    Rule(
        id="RPR003", name="dyngraph-densify", severity=Severity.ERROR,
        summary="densify on the dyngraph delta path outside *_oracle",
        rationale="delta application edits packed tiles as packed words; a "
                  "densify turns the O(delta) patch into O(tiles)",
        escapes="*_oracle bodies (reference checks)",
        check=_check_dyngraph_densify,
    ),
    Rule(
        id="RPR004", name="frontier-unpack", severity=Severity.ERROR,
        summary="frontier unpack outside kernel/oracle/seam (module-scoped)",
        rationale="frontier vectors ride the round body as packed words; "
                  "one unpack at the epilogue only",
        escapes="core/tiling.py, kernels/ref.py, *_kernel/*_oracle bodies, "
                "tc_mis._result, distributed.gather_bool",
        check=_check_frontier_unpack,
    ),
    Rule(
        id="RPR005", name="host-callback", severity=Severity.ERROR,
        summary="host callbacks / debug prints in device-hot modules",
        rationale="per-round host round-trips serialise the while_loop and "
                  "destroy the timings telemetry exists to measure",
        escapes="none — use the on-device telemetry buffer (obs.rounds)",
        check=_check_host_callbacks,
    ),
    Rule(
        id="RPR010", name="hot-host-sync", severity=Severity.ERROR,
        summary=".item/.tolist/np.*/float(jnp...) in jit-reachable code",
        rationale="a host sync anywhere in the reachable set of a jitted "
                  "entry point blocks dispatch, wherever the helper lives",
        escapes="suppress on the def line for host-stepped drivers "
                "(e.g. the _run_phases_impl profiler twin)",
        check=_check_host_sync,
    ),
    Rule(
        id="RPR011", name="trace-impurity", severity=Severity.ERROR,
        summary="stdlib random/time/datetime, np RNG, print, global writes "
                "in jit-reachable code",
        rationale="impure values freeze at trace time — the compiled "
                  "program replays the traced constant forever",
        escapes="suppress on the def line for host-stepped drivers",
        check=_check_impurity,
    ),
    Rule(
        id="RPR012", name="dtype-discipline", severity=Severity.ERROR,
        summary="astype(float)/dtype=int/float64 on the hot path",
        rationale="python builtins promote to 64-bit; with x64 off the "
                  "result silently differs between host and device",
        escapes="none — spell jnp.float32/jnp.int32 explicitly",
        check=_check_dtype,
    ),
    Rule(
        id="RPR013", name="loop-carry-hygiene", severity=Severity.ERROR,
        summary="shape-growing ops inside while_loop/scan body functions",
        rationale="XLA loop carries are fixed-shape; concatenate/append in "
                  "a body fails at trace or silently retraces",
        escapes="none — preallocate and .at[].set",
        check=_check_loop_carry,
    ),
    Rule(
        id="RPR014", name="deprecated-shim", severity=Severity.ERROR,
        summary="internal import/call of tc_mis/run_phases/TCMISConfig",
        rationale="the repro.api front door owns routing, caching and "
                  "batching; shim callers bypass all three",
        escapes="the shim modules themselves (core/tc_mis.py, "
                "core/__init__.py) and tests",
        check=_check_deprecation,
    ),
    Rule(
        id="RPR015", name="pallas-kernel-hygiene", severity=Severity.ERROR,
        summary="non-allowlisted call inside a Pallas *_kernel body",
        rationale="kernel bodies compile to Mosaic — only refs, jax/pallas "
                  "ops, the in-VMEM pack/unpack helpers and nested defs "
                  "exist there",
        escapes="extend KERNEL_CALL_ALLOWLIST for new in-VMEM helpers",
        check=_check_pallas_hygiene,
    ),
    Rule(
        id="RPR016", name="hot-densify", severity=Severity.ERROR,
        summary="frontier unpack / to_storage anywhere jit-reachable",
        rationale="the call-graph generalisation of Guard 4: a densify "
                  "smuggled in via any module still lands in the round "
                  "body if a jitted entry point reaches it",
        escapes="core/tiling.py + kernels/ref.py (the substrate), "
                "*_kernel/*_oracle bodies, the Guard-4 seams",
        check=_check_hot_densify,
    ),
)

_BY_ID = {r.id: r for r in ALL_RULES}
GUARD_RULE_IDS = ("RPR001", "RPR002", "RPR003", "RPR004", "RPR005")


def get_rules(ids: Optional[Sequence[str]] = None) -> List[Rule]:
    if not ids:
        return list(ALL_RULES)
    unknown = [i for i in ids if i not in _BY_ID]
    if unknown:
        raise KeyError(f"unknown rule id(s): {', '.join(unknown)}")
    return [_BY_ID[i] for i in ids]


def run_rules(
    ctx: LintContext, rules: Optional[Iterable[Rule]] = None
) -> List[Finding]:
    """Run the catalog and apply inline suppressions.  Baseline matching is
    the caller's job (repro.lint.cli) — rules stay baseline-agnostic."""
    import dataclasses

    from repro.lint.model import sort_findings

    out: List[Finding] = []
    for rule in rules if rules is not None else ALL_RULES:
        for f in rule.run(ctx):
            mi = ctx.modules.get(f.module)
            if mi is not None:
                disabled = mi.disabled_rules(f.line)
                if f.rule in disabled or "all" in disabled:
                    f = dataclasses.replace(f, suppressed=True)
            out.append(f)
    return sort_findings(out)

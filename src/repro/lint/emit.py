"""Finding emitters: text (humans/CI logs), JSON (tooling), SARIF 2.1.0
(GitHub code-scanning annotations — `--format sarif` in the CI workflow).

Suppressed and baselined findings are emitted too (text marks them, SARIF
uses the `suppressions` property) so a clean run still documents what was
grandfathered; only `active` findings flip the exit code.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Sequence

from repro.lint.model import Finding, Rule

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = (
    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/"
    "Schemata/sarif-schema-2.1.0.json"
)
TOOL_NAME = "repro-lint"


def _tag(f: Finding) -> str:
    if f.suppressed:
        return " [suppressed]"
    if f.baselined:
        return " [baselined]"
    return ""


def emit_text(findings: Sequence[Finding]) -> str:
    lines: List[str] = []
    for f in findings:
        lines.append(
            f"{f.location()}: {f.severity} {f.rule}[{_rule_name(f)}]"
            f"{_tag(f)}: {f.message}"
        )
    active = sum(1 for f in findings if f.active)
    suppressed = sum(1 for f in findings if f.suppressed)
    baselined = sum(1 for f in findings if f.baselined)
    lines.append(
        f"repro-lint: {active} error(s), {suppressed} suppressed, "
        f"{baselined} baselined"
    )
    return "\n".join(lines) + "\n"


def _rule_name(f: Finding) -> str:
    from repro.lint.rules import ALL_RULES

    for r in ALL_RULES:
        if r.id == f.rule:
            return r.name
    return "?"


def emit_json(findings: Sequence[Finding]) -> str:
    return (
        json.dumps(
            {
                "tool": TOOL_NAME,
                "findings": [
                    {
                        "rule": f.rule,
                        "severity": f.severity,
                        "path": f.path,
                        "line": f.line,
                        "col": f.col,
                        "module": f.module,
                        "symbol": f.symbol,
                        "message": f.message,
                        "suppressed": f.suppressed,
                        "baselined": f.baselined,
                    }
                    for f in findings
                ],
            },
            indent=2,
        )
        + "\n"
    )


def emit_sarif(
    findings: Sequence[Finding], rules: Iterable[Rule]
) -> str:
    rule_objs = [
        {
            "id": r.id,
            "name": r.name,
            "shortDescription": {"text": r.summary},
            "fullDescription": {"text": r.rationale},
            "help": {"text": f"Sanctioned escapes: {r.escapes}"},
            "defaultConfiguration": {"level": r.severity},
        }
        for r in rules
    ]
    rule_index = {r["id"]: i for i, r in enumerate(rule_objs)}
    results = []
    for f in findings:
        result = {
            "ruleId": f.rule,
            "ruleIndex": rule_index.get(f.rule, -1),
            "level": f.severity,
            "message": {"text": f.message},
            "locations": [
                {
                    "physicalLocation": {
                        "artifactLocation": {
                            "uri": f.path,
                            "uriBaseId": "%SRCROOT%",
                        },
                        "region": {
                            "startLine": f.line,
                            "startColumn": f.col,
                        },
                    }
                }
            ],
        }
        if f.suppressed or f.baselined:
            result["suppressions"] = [
                {
                    "kind": "inSource" if f.suppressed else "external",
                    "justification": (
                        "inline repro-lint: disable comment"
                        if f.suppressed
                        else "grandfathered in tools/lint_baseline.json"
                    ),
                }
            ]
        results.append(result)
    doc = {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": TOOL_NAME,
                        "informationUri": "DESIGN.md#15-static-analysis",
                        "rules": rule_objs,
                    }
                },
                "results": results,
            }
        ],
    }
    return json.dumps(doc, indent=2) + "\n"

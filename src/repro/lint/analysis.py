"""AST universe loader: modules, functions, classes, imports, suppressions.

One parse pass per file builds everything the rules and the call graph need:

  * `FunctionInfo` per function/method (nested defs included, qualnames like
    `Outer.<locals>.inner` collapsed to `Outer.inner` for readability) with
    the calls made *directly* in its body (nested defs own their calls);
  * an import table mapping every local alias to the module or symbol it
    names — call resolution and the impurity/deprecation rules key off it;
  * inline suppression spans (`# repro-lint: disable=RPR0xx <reason>` on a
    flagged line, on a `def` signature line to cover the whole function, or
    `disable-file=` for the module).

Everything is syntactic — nothing is imported or executed, so the linter
runs on any tree (tmp-dir test fixtures included) without jax present.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?P<file>-file)?="
    r"(?P<rules>[A-Za-z0-9_,\s]*?)(?:\s+(?P<reason>\S.*))?$"
)

LOOP_CALLS = {"while_loop": 1, "fori_loop": 2, "scan": 0}  # name -> body arg pos
LOOP_BODY_KWARGS = {"while_loop": "body_fun", "fori_loop": "body_fun", "scan": "f"}


def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """`a.b.c` -> ("a", "b", "c"); None when the root is not a plain Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


@dataclasses.dataclass
class CallInfo:
    """One call site: the node, the dotted chain of its callee (when the
    callee is a Name/Attribute), and the enclosing-function name stack —
    the ported guards predicate on `*_kernel`/`*_oracle` stack membership
    exactly like tools/ci_guards.py did."""

    node: ast.Call
    chain: Optional[Tuple[str, ...]]
    stack: Tuple[str, ...]
    arg_chains: Tuple[Tuple[str, ...], ...]

    @property
    def name(self) -> Optional[str]:
        return self.chain[-1] if self.chain else None


@dataclasses.dataclass
class FunctionInfo:
    module: str
    qualname: str
    name: str
    node: ast.AST                      # FunctionDef | AsyncFunctionDef
    lineno: int
    end_lineno: int
    body_lineno: int                   # first statement — end of the signature
    class_name: Optional[str]          # innermost enclosing class
    parent: Optional[str]              # enclosing function key, for nested defs
    calls: List[CallInfo] = dataclasses.field(default_factory=list)
    nested: List[str] = dataclasses.field(default_factory=list)
    global_decls: List[int] = dataclasses.field(default_factory=list)
    loop_lambdas: List[ast.Lambda] = dataclasses.field(default_factory=list)
    is_loop_body: bool = False

    @property
    def key(self) -> str:
        return f"{self.module}:{self.qualname}"


@dataclasses.dataclass
class ClassInfo:
    module: str
    name: str
    bases: List[Tuple[str, ...]]
    methods: Dict[str, str] = dataclasses.field(default_factory=dict)  # name -> fn key


@dataclasses.dataclass
class ModuleInfo:
    name: str
    path: pathlib.Path
    rel: str                            # root-relative posix path
    tree: ast.Module
    source: str
    imports: Dict[str, Tuple] = dataclasses.field(default_factory=dict)
    functions: Dict[str, FunctionInfo] = dataclasses.field(default_factory=dict)
    classes: Dict[str, ClassInfo] = dataclasses.field(default_factory=dict)
    calls: List[CallInfo] = dataclasses.field(default_factory=list)  # all, any depth
    file_disables: Set[str] = dataclasses.field(default_factory=set)
    line_disables: Dict[int, Set[str]] = dataclasses.field(default_factory=dict)
    span_disables: List[Tuple[int, int, Set[str]]] = dataclasses.field(
        default_factory=list
    )

    def disabled_rules(self, line: int) -> Set[str]:
        out = set(self.file_disables)
        out |= self.line_disables.get(line, set())
        for lo, hi, rules in self.span_disables:
            if lo <= line <= hi:
                out |= rules
        return out


def _module_name(root: pathlib.Path, path: pathlib.Path) -> str:
    rel = path.relative_to(root).with_suffix("")
    parts = list(rel.parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else path.stem


def _resolve_relative(package: str, module: Optional[str], level: int) -> str:
    """`from ..x import y` inside `package` -> absolute dotted module."""
    if level == 0:
        return module or ""
    parts = package.split(".") if package else []
    if level > 1:
        parts = parts[: len(parts) - (level - 1)]
    base = ".".join(parts)
    if module:
        return f"{base}.{module}" if base else module
    return base


class _Collector(ast.NodeVisitor):
    def __init__(self, mi: ModuleInfo, package: str):
        self.mi = mi
        self.package = package
        self.fn_stack: List[FunctionInfo] = []
        self.class_stack: List[ClassInfo] = []

    # -- imports ------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            if a.asname:
                self.mi.imports[a.asname] = ("module", a.name)
            else:
                # `import x.y` binds `x`; attribute chains re-join the rest
                self.mi.imports[a.name.split(".")[0]] = (
                    "module",
                    a.name.split(".")[0],
                )
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        src = _resolve_relative(self.package, node.module, node.level)
        for a in node.names:
            alias = a.asname or a.name
            if a.name == "*":
                continue
            self.mi.imports[alias] = ("symbol", src, a.name)
        self.generic_visit(node)

    # -- scopes -------------------------------------------------------------
    def _qualname(self, name: str) -> str:
        parts = [c.name for c in self.class_stack]
        parts += [f.name for f in self.fn_stack]
        parts.append(name)
        return ".".join(parts)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        ci = ClassInfo(
            module=self.mi.name,
            name=self._qualname(node.name),
            bases=[c for c in (attr_chain(b) for b in node.bases) if c],
        )
        self.mi.classes[ci.name] = ci
        self.class_stack.append(ci)
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_fn(self, node) -> None:
        fi = FunctionInfo(
            module=self.mi.name,
            qualname=self._qualname(node.name),
            name=node.name,
            node=node,
            lineno=node.lineno,
            end_lineno=getattr(node, "end_lineno", node.lineno),
            body_lineno=node.body[0].lineno if node.body else node.lineno,
            class_name=self.class_stack[-1].name if self.class_stack else None,
            parent=self.fn_stack[-1].key if self.fn_stack else None,
        )
        self.mi.functions[fi.qualname] = fi
        if self.fn_stack:
            self.fn_stack[-1].nested.append(fi.key)
        elif self.class_stack:
            self.class_stack[-1].methods[node.name] = fi.key
        self.fn_stack.append(fi)
        self.generic_visit(node)
        self.fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def _visit_global(self, node) -> None:
        if self.fn_stack:
            self.fn_stack[-1].global_decls.append(node.lineno)

    visit_Global = _visit_global
    visit_Nonlocal = _visit_global

    # -- calls --------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        chain = attr_chain(node.func)
        args = tuple(
            c for c in (attr_chain(a) for a in node.args) if c is not None
        )
        info = CallInfo(
            node=node,
            chain=chain,
            stack=tuple(f.name for f in self.fn_stack),
            arg_chains=args,
        )
        self.mi.calls.append(info)
        if self.fn_stack:
            self.fn_stack[-1].calls.append(info)
            # loop-body marking: `lax.while_loop(cond, body, ...)` — record
            # lambda bodies here; Name bodies resolve in the call graph pass
            if chain and chain[-1] in LOOP_CALLS:
                pos = LOOP_CALLS[chain[-1]]
                body_arg = None
                if len(node.args) > pos:
                    body_arg = node.args[pos]
                else:
                    kw = LOOP_BODY_KWARGS[chain[-1]]
                    for k in node.keywords:
                        if k.arg == kw:
                            body_arg = k.value
                if isinstance(body_arg, ast.Lambda):
                    self.fn_stack[-1].loop_lambdas.append(body_arg)
        self.generic_visit(node)


def _scan_suppressions(mi: ModuleInfo) -> None:
    for lineno, line in enumerate(mi.source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        if not rules:
            continue
        if m.group("file"):
            mi.file_disables |= rules
            continue
        mi.line_disables.setdefault(lineno, set()).update(rules)
        # a disable on a `def` signature line covers the whole function body
        for fi in mi.functions.values():
            if fi.lineno <= lineno < max(fi.body_lineno, fi.lineno + 1):
                mi.span_disables.append((fi.lineno, fi.end_lineno, set(rules)))


def parse_module(
    root: pathlib.Path, path: pathlib.Path
) -> Optional[ModuleInfo]:
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    name = _module_name(root, path)
    mi = ModuleInfo(
        name=name,
        path=path,
        rel=path.relative_to(root).as_posix(),
        tree=tree,
        source=source,
    )
    package = name if path.name == "__init__.py" else name.rsplit(".", 1)[0]
    if "." not in name and path.name != "__init__.py":
        package = ""
    _Collector(mi, package).visit(tree)
    _scan_suppressions(mi)
    return mi


def find_root(path: pathlib.Path) -> pathlib.Path:
    """Package root for module naming: the nearest ancestor named `src`
    (so `src/repro/...` parses as `repro.*` wherever the command is run
    from), else the directory itself (tmp fixture trees, `benchmarks/`)."""
    p = path.resolve()
    start = p if p.is_dir() else p.parent
    for d in (start, *start.parents):
        if d.name == "src":
            return d
    return start


@dataclasses.dataclass
class LintContext:
    """Everything a rule sees: the parsed universe + the call graph."""

    modules: Dict[str, ModuleInfo]
    report: Set[str]                  # module names findings are kept for
    graph: "object" = None            # CallGraph, attached by load_universe

    def report_modules(self) -> List[ModuleInfo]:
        return [
            self.modules[n] for n in sorted(self.modules) if n in self.report
        ]

    def function_module(self, key: str) -> Optional[ModuleInfo]:
        return self.modules.get(key.split(":", 1)[0])

    def function(self, key: str) -> Optional[FunctionInfo]:
        mi = self.function_module(key)
        if mi is None:
            return None
        return mi.functions.get(key.split(":", 1)[1])


def load_universe(
    paths: Sequence[pathlib.Path], seeds=None
) -> LintContext:
    """Parse every .py under `paths` into one universe and build the call
    graph.  Files under a path are both analysed and reported; when a path
    sits inside a `src` tree the whole tree is pulled into the universe so
    cross-module reachability sees every edge even when only a subtree is
    being reported."""
    from repro.lint.callgraph import CallGraph

    modules: Dict[str, ModuleInfo] = {}
    report: Set[str] = set()

    def add(root: pathlib.Path, file: pathlib.Path, reported: bool) -> None:
        mi = parse_module(root, file)
        if mi is None:
            return
        if mi.name not in modules or reported:
            modules[mi.name] = mi
        if reported:
            report.add(mi.name)

    for raw in paths:
        p = pathlib.Path(raw).resolve()
        root = find_root(p)
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            add(root, f, reported=True)
        if root != p and root.name == "src":
            for f in sorted(root.rglob("*.py")):
                mi_name = _module_name(root, f)
                if mi_name not in modules:
                    add(root, f, reported=False)

    ctx = LintContext(modules=modules, report=report)
    ctx.graph = CallGraph.build(ctx, seeds=seeds)
    return ctx

"""Core datatypes of the rule engine: findings, severities, rule protocol.

Kept dependency-free (stdlib only) so `tools/ci_guards.py` and CI can import
the engine without jax installed.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Iterable, List

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.lint.analysis import LintContext


class Severity:
    """SARIF-aligned severity levels.  `ERROR` findings fail the run;
    `WARNING`/`NOTE` findings are reported but never flip the exit code."""

    ERROR = "error"
    WARNING = "warning"
    NOTE = "note"

    ORDER = (ERROR, WARNING, NOTE)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location.

    `symbol` is the dotted qualname of the innermost enclosing function
    (`<module>` at module scope) — baselining keys on (rule, module, symbol,
    message) rather than line numbers so unrelated edits above a
    grandfathered finding do not un-baseline it.
    """

    rule: str
    severity: str
    path: str            # root-relative posix path
    line: int
    col: int
    module: str          # dotted module name within the lint universe
    symbol: str          # enclosing function qualname or "<module>"
    message: str
    suppressed: bool = False
    baselined: bool = False

    @property
    def active(self) -> bool:
        """True when the finding should count against the exit code."""
        return (
            not self.suppressed
            and not self.baselined
            and self.severity == Severity.ERROR
        )

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


@dataclasses.dataclass(frozen=True)
class Rule:
    """One entry of the rule catalog (DESIGN.md §15).

    `check` receives the whole `LintContext` (every parsed module, the call
    graph, the hot set) and yields findings for the modules under report.
    `escapes` documents the sanctioned ways around the rule — the DESIGN.md
    catalog table and `--list-rules` render it.
    """

    id: str
    name: str
    severity: str
    summary: str
    rationale: str
    escapes: str
    check: "object" = None  # Callable[[LintContext], Iterable[Finding]]

    def run(self, ctx: "LintContext") -> List[Finding]:
        return list(self.check(ctx))


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))

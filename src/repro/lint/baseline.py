"""Checked-in baseline of grandfathered findings.

A baseline entry fingerprints a finding as (rule, module, symbol, message) —
deliberately **not** line numbers, so edits above a grandfathered finding do
not un-baseline it and a moved-but-unfixed violation stays grandfathered.
Entries carry a count: two identical findings in one function need two
baseline slots, and fixing one of them surfaces the other.

The shipped baseline (tools/lint_baseline.json) is EMPTY for src/repro —
every violation the new rules found was fixed or inline-suppressed with a
reason instead (ISSUE 8, satellite 1).  The mechanism exists for downstream
trees adopting the linter incrementally.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from collections import Counter
from typing import Dict, Iterable, List

from repro.lint.model import Finding

VERSION = 1
_SEP = "␟"  # symbol-for-unit-separator; never appears in fingerprints


def _fingerprint(f: Finding) -> str:
    return _SEP.join((f.rule, f.module, f.symbol, f.message))


@dataclasses.dataclass
class Baseline:
    entries: Counter = dataclasses.field(default_factory=Counter)

    # -- io ------------------------------------------------------------------
    @classmethod
    def load(cls, path: pathlib.Path) -> "Baseline":
        data = json.loads(path.read_text(encoding="utf-8"))
        if data.get("version") != VERSION:
            raise ValueError(
                f"{path}: unsupported baseline version {data.get('version')!r}"
            )
        entries: Counter = Counter()
        for item in data.get("entries", []):
            key = _SEP.join(
                (item["rule"], item["module"], item["symbol"], item["message"])
            )
            entries[key] += int(item.get("count", 1))
        return cls(entries=entries)

    def save(self, path: pathlib.Path) -> None:
        items = []
        for key in sorted(self.entries):
            rule, module, symbol, message = key.split(_SEP)
            items.append(
                {
                    "rule": rule,
                    "module": module,
                    "symbol": symbol,
                    "message": message,
                    "count": self.entries[key],
                }
            )
        path.write_text(
            json.dumps({"version": VERSION, "entries": items}, indent=2)
            + "\n",
            encoding="utf-8",
        )

    # -- matching ------------------------------------------------------------
    @classmethod
    def from_findings(cls, findings: Iterable[Finding]) -> "Baseline":
        """Baseline every non-suppressed finding (for --update-baseline)."""
        entries: Counter = Counter()
        for f in findings:
            if not f.suppressed:
                entries[_fingerprint(f)] += 1
        return cls(entries=entries)

    def apply(self, findings: Iterable[Finding]) -> List[Finding]:
        """Mark matched findings `baselined` (consuming counts in source
        order, so a fixed duplicate un-baselines exactly one slot)."""
        budget = Counter(self.entries)
        out: List[Finding] = []
        for f in findings:
            key = _fingerprint(f)
            if not f.suppressed and budget[key] > 0:
                budget[key] -= 1
                f = dataclasses.replace(f, baselined=True)
            out.append(f)
        return out

    def __len__(self) -> int:
        return sum(self.entries.values())

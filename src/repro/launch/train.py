"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset cpu-small --steps 200 --ckpt-dir /tmp/run1

Presets:
  cpu-small   ~10M-param reduction of the arch, single device — the
              "train a ~100M-class model for a few hundred steps" driver
              scaled to this container (see examples/train_lm.py).
  production  the full assigned config on the production mesh (requires
              real TPU devices; on CPU it will lower but not usefully run).

The loop is the fault-tolerant TrainLoop: checkpoint/restart, straggler
deadlines, retry-on-failure (train/train_loop.py).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def small_variant(cfg, vocab=2048):
    """Shrink an LMConfig to a CPU-trainable size, keeping its structure."""
    from repro.models.lm_config import LMConfig, MLAConfig, MoEConfig

    moe = None
    if cfg.moe:
        moe = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=128,
            n_shared=min(cfg.moe.n_shared, 1),
            router=cfg.moe.router,
        )
    mla = None
    if cfg.mla:
        mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32, d_nope=32, d_rope=16, d_v=32)
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 4),
        d_model=256,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=64,
        d_ff=512,
        vocab=vocab,
        moe=moe,
        mla=mla,
        window=min(cfg.window, 128) if cfg.window else None,
        dtype=jnp.float32,
        attn_chunk=64,
        loss_chunk=64,
        mtp=cfg.mtp,
    )


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen1.5-0.5b")
    p.add_argument("--preset", default="cpu-small", choices=["cpu-small", "production"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train")
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--log", default=None)
    args = p.parse_args()

    from repro.configs import REGISTRY
    from repro.configs.common import make_lm_train_step
    from repro.data.pipeline import TokenStream
    from repro.models import transformer as tf
    from repro.train import LoopConfig, OptConfig, TrainLoop, adamw_init

    arch = REGISTRY[args.arch]
    assert arch.family == "lm", "train.py drives the LM family; see examples/"
    cfg = arch.config if args.preset == "production" else small_variant(arch.config)

    params = tf.init_lm(jax.random.key(0), cfg)
    opt = adamw_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} [{args.preset}]: {n_params/1e6:.1f}M params")

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    raw_step = jax.jit(make_lm_train_step(cfg, opt_cfg))

    def step_fn(state, batch):
        params, opt = state
        tokens, targets = batch
        params, opt, loss, xent = raw_step(
            params, opt, jnp.asarray(tokens), jnp.asarray(targets)
        )
        return (params, opt), {"loss": loss, "xent": xent}

    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=17)
    loop = TrainLoop(
        step_fn=step_fn,
        init_state=(params, opt),
        stream=stream,
        cfg=LoopConfig(
            ckpt_dir=args.ckpt_dir,
            checkpoint_every=args.checkpoint_every,
            log_path=args.log,
        ),
    )
    print(f"starting at step {loop.start_step}")
    result = loop.run(args.steps)
    print(f"done: {result}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell,
record memory/cost analysis, collective schedule, and roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
        --out experiments/dryrun --skip-existing

Methodology (EXPERIMENTS.md §Dry-run):
* memory pass — the production program (rolled scans, loop buffers reused):
  memory_analysis is the fits-on-chip evidence; also the compile-OK gate.
* cost passes — XLA cost_analysis counts loop bodies ONCE, so LM cells
  compile UNROLLED reduced-depth twins (L=2, L=4) and extrapolate affinely
  in layer count (homogeneous stacks ⇒ cost = a + b·L exactly).  Non-LM
  cells have no layer scans (GNN layers are a python loop; the MIS
  while-loop is intentionally counted per-round), so their memory pass
  doubles as the cost pass.

Failures (sharding mismatch, OOM-at-compile, unsupported collective) are
bugs — the run records them and exits non-zero.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np


def _compile_pass(cell, mesh, variant):
    fn, inputs, in_shardings = cell.build(mesh, variant=variant)
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=in_shardings).lower(*inputs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _cost_record(compiled):
    from repro.perf.roofline import parse_collective_bytes

    ca = compiled.cost_analysis() or {}
    colls = parse_collective_bytes(compiled.as_text())
    return dict(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives={k: int(v) for k, v in colls.items()},
    )


def _affine(a: dict, b: dict, la: int, lb: int, lfull: int) -> dict:
    """Per-key affine extrapolation X(L) = Xa + (Xb-Xa)/(lb-la)·(L-la)."""
    t = (lfull - la) / (lb - la)

    def ext(xa, xb):
        return xa + (xb - xa) * t

    colls = {}
    for k in set(a["collectives"]) | set(b["collectives"]):
        colls[k] = int(max(0, ext(a["collectives"].get(k, 0), b["collectives"].get(k, 0))))
    return dict(
        flops=ext(a["flops"], b["flops"]),
        bytes_accessed=ext(a["bytes_accessed"], b["bytes_accessed"]),
        collectives=colls,
    )


def run_cell(arch_id: str, shape: str, mesh_kind: str, out_dir: str,
             skip_existing: bool) -> dict:
    from repro.configs import REGISTRY
    from repro.launch.mesh import make_production_mesh
    from repro.perf.roofline import RooflineTerms, HBM_BW, ICI_BW, PEAK_FLOPS

    tag = f"{arch_id}__{shape}__{mesh_kind}".replace("/", "_")
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skipped"):
            print(f"[skip] {tag}", flush=True)
            return rec

    cell = REGISTRY[arch_id].cells[shape]
    rec = dict(arch=arch_id, shape=shape, mesh=mesh_kind, kind=cell.kind,
               note=cell.note)
    if cell.skip_reason:
        rec.update(status="skipped", skip_reason=cell.skip_reason)
        _write(path, rec)
        print(f"[N/A ] {tag}: {cell.skip_reason}", flush=True)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    try:
        with mesh:
            # ---- memory pass (production program) -------------------------
            compiled, t_lower, t_compile = _compile_pass(cell, mesh, "memory")
            ma = compiled.memory_analysis()
            mem = dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                total_per_device=ma.argument_size_in_bytes
                + ma.temp_size_in_bytes
                + ma.output_size_in_bytes
                - ma.alias_size_in_bytes,
            )
            times = dict(lower_s=round(t_lower, 2), compile_s=round(t_compile, 2))
            # ---- cost passes ----------------------------------------------
            if cell.extrapolate:
                ex = cell.extrapolate
                ca_a, _, tca = _compile_pass(cell, mesh, "cost_a")
                cost_a = _cost_record(ca_a)
                del ca_a
                ca_b, _, tcb = _compile_pass(cell, mesh, "cost_b")
                cost_b = _cost_record(ca_b)
                del ca_b
                cost = _affine(cost_a, cost_b, ex["la"], ex["lb"], ex["lfull"])
                times.update(cost_a_s=round(tca, 2), cost_b_s=round(tcb, 2))
                rec["cost_method"] = (
                    f"affine layer extrapolation L∈{{{ex['la']},{ex['lb']}}} "
                    f"→ {ex['lfull']} (unrolled)"
                )
                rec["cost_samples"] = dict(cost_a=cost_a, cost_b=cost_b)
            else:
                cost = _cost_record(compiled)
                rec["cost_method"] = "direct (no layer scan in program)"

        coll_bytes = sum(cost["collectives"].values())
        terms = dict(
            compute_s=cost["flops"] / PEAK_FLOPS,
            memory_s=cost["bytes_accessed"] / HBM_BW,
            collective_s=coll_bytes / ICI_BW,
        )
        dominant = max(terms, key=terms.get)
        step_time = max(terms.values())
        model_flops_dev = cell.model_flops / n_dev
        rec.update(
            status="ok",
            devices=n_dev,
            times=times,
            memory=mem,
            cost=cost,
            roofline=dict(
                **terms,
                dominant=dominant.replace("_s", ""),
                step_time_s=step_time,
                model_flops=model_flops_dev,
                useful_flop_fraction=(
                    model_flops_dev / cost["flops"] if cost["flops"] else 0.0
                ),
                mfu=(
                    model_flops_dev / (PEAK_FLOPS * step_time)
                    if step_time > 0 else 0.0
                ),
            ),
            model_flops_global=cell.model_flops,
        )
        print(
            f"[ ok ] {tag}: mem {mem['total_per_device']/2**30:.2f} GiB/dev, "
            f"dominant={rec['roofline']['dominant']}, "
            f"mfu={rec['roofline']['mfu']:.3f}, "
            f"compile {times['compile_s']}s", flush=True,
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=repr(e), traceback=traceback.format_exc())
        print(f"[FAIL] {tag}: {e!r}", flush=True)
    _write(path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--list", action="store_true")
    args = p.parse_args()

    from repro.configs import REGISTRY

    archs = list(REGISTRY) if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a in archs:
            for s in REGISTRY[a].cells:
                print(f"{a} × {s}")
        return 0

    failures = 0
    for a in archs:
        shapes = (
            list(REGISTRY[a].cells) if args.shape == "all" else args.shape.split(",")
        )
        for s in shapes:
            if s not in REGISTRY[a].cells:
                continue
            for m in meshes:
                rec = run_cell(a, s, m, args.out, args.skip_existing)
                if rec.get("status") == "error":
                    failures += 1
    print(f"dry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh definition (DESIGN.md §5).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 = 512 chips as (pod=2, data=16, model=16) — the
'pod' axis composes with 'data' for batch sharding, so cross-pod traffic is
only the gradient all-reduce (and the MIS frontier gather).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the device count before first jax init).
"""
from __future__ import annotations

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh(shape, axes)

"""Batched serving driver: prefill a batch of prompts, decode with a KV
cache (ring-buffered for SWA archs, latent for MLA).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    from repro.configs import REGISTRY
    from repro.launch.train import small_variant
    from repro.models import transformer as tf

    arch = REGISTRY[args.arch]
    cfg = small_variant(arch.config)
    params = tf.init_lm(jax.random.key(0), cfg)

    tokens = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab,
        dtype=jnp.int32,
    )
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, t: tf.prefill(p, cfg, t, max_len=max_len))
    decode = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(params, tokens)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            tok = jax.random.categorical(
                jax.random.key(100 + i), logits / args.temperature
            ).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.stack(out, axis=1)
    print(f"arch={args.arch} batch={args.batch}")
    print(f"prefill {args.prompt_len} tok: {t_prefill*1e3:.1f} ms")
    print(
        f"decode  {args.gen} steps: {t_decode*1e3:.1f} ms "
        f"({t_decode/args.gen*1e3:.2f} ms/tok, ring={cache.length})"
    )
    print("sample token ids:", gen[0, :8].tolist())


if __name__ == "__main__":
    main()

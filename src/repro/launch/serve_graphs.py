"""Synthetic-traffic launcher for the MIS serving layer.

Drives `repro.serve_mis.MISService` — and through it the `repro.api.Solver`
front door (plan cache → routing → batched dispatch) — with a stream of
requests drawn from the paper-suite generators (Table-1 structure classes
at serving scale), with a configurable repeat rate so the tile-plan cache
sees realistic re-request traffic.  Prints per-wave throughput and the
cache/compile counters — the serving twin of `launch.serve` (LM decode
loop).

    PYTHONPATH=src python -m repro.launch.serve_graphs \
        --requests 32 --scale 512 --repeat-frac 0.5 --engine tiled_ref
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=32, help="requests per wave")
    p.add_argument("--waves", type=int, default=3)
    p.add_argument("--scale", type=int, default=512, help="vertices per graph (approx)")
    p.add_argument("--repeat-frac", type=float, default=0.5,
                   help="fraction of requests re-asking an already-seen graph")
    p.add_argument("--engine", default="tiled_ref")
    p.add_argument("--tile-size", type=int, default=32)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--cache-dir", default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    from repro.graphs.generators import GRAPH_SUITE
    from repro.serve_mis import MISService, ServeConfig

    service = MISService(ServeConfig(
        tile_size=args.tile_size,
        engine=args.engine,
        max_batch=args.max_batch,
        cache_dir=args.cache_dir,
        seed=args.seed,
    ))

    rng = np.random.default_rng(args.seed)
    specs = list(GRAPH_SUITE.values())
    pool = []  # already-requested graphs, for repeat traffic

    for wave in range(args.waves):
        graphs = []
        for _ in range(args.requests):
            if pool and rng.random() < args.repeat_frac:
                graphs.append(pool[int(rng.integers(len(pool)))])
            else:
                spec = specs[int(rng.integers(len(specs)))]
                g = spec.make(args.scale, int(rng.integers(1 << 30)))
                pool.append(g)
                graphs.append(g)
        t0 = time.perf_counter()
        for g in graphs:
            service.submit(g)
        responses = service.drain()
        dt = time.perf_counter() - t0
        n_valid = sum(r.valid for r in responses)
        sizes = [r.mis_size for r in responses]
        print(
            f"wave {wave}: {len(responses)} req in {dt * 1e3:.1f} ms "
            f"({len(responses) / dt:.1f} graphs/s)  valid={n_valid}/{len(responses)} "
            f"|MIS| p50={int(np.median(sizes))}"
        )
        if n_valid != len(responses):
            raise SystemExit("post-condition failure under synthetic traffic")

    s, pc = service.stats, service.planner.stats
    print(
        f"total: requests={s['requests']} batches={s['batches']} "
        f"compiles={s['compiles']} graphs_solved={service.solver.stats['solves']} "
        f"plan_cache mem={pc['mem_hits']} "
        f"disk={pc['disk_hits']} built={pc['misses']}"
    )


if __name__ == "__main__":
    main()

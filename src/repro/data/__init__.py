from repro.data.pipeline import (
    ClickStream,
    GraphBatchStream,
    TokenStream,
    prefetch,
)

__all__ = ["TokenStream", "ClickStream", "GraphBatchStream", "prefetch"]

"""Deterministic synthetic data pipeline with prefetch and exact resume.

Every stream is a pure function of (seed, step): after a restart, seeking to
step k reproduces the exact batch sequence — this is what makes
checkpoint/restart bitwise reproducible end-to-end (tested).

Streams yield host numpy; `prefetch` double-buffers ahead of the device on a
background thread; `shard_batch` device_puts with a NamedSharding for
multi-chip input feeding.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class TokenStream:
    """Synthetic LM batches: (tokens (B,S) int32, targets (B,S) int32).

    A cheap Markov-ish mixture (unigram + shifted copy) so the loss is
    learnable — a pure-uniform stream gives flat loss and hides optimizer
    bugs.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        base = rng.integers(0, self.vocab, (self.batch, self.seq + 1))
        # inject copy structure: token t+1 = token t + 1 (mod V) half the time
        copy = (np.roll(base, 1, axis=1) + 1) % self.vocab
        use = rng.random((self.batch, self.seq + 1)) < 0.5
        toks = np.where(use, copy, base).astype(np.int32)
        return toks[:, :-1], toks[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ClickStream:
    """Synthetic CTR batches for DeepFM: (fields (B,F) int32, labels (B,))."""

    def __init__(self, field_vocabs: Sequence[int], batch: int, seed: int = 0):
        self.field_vocabs = np.asarray(field_vocabs)
        self.batch, self.seed = batch, seed
        rng = np.random.default_rng(seed)
        self._w = rng.standard_normal(len(field_vocabs)) * 0.5

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        F = len(self.field_vocabs)
        fields = (rng.random((self.batch, F)) * self.field_vocabs).astype(np.int32)
        # learnable signal: label correlates with parity of a weighted sum
        z = ((fields % 7) * self._w).sum(axis=1)
        p = 1 / (1 + np.exp(-z + z.mean()))
        labels = (rng.random(self.batch) < p).astype(np.float32)
        return fields, labels

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class GraphBatchStream:
    """Batched small molecules: coords/features/edges with static shapes."""

    def __init__(self, batch: int, n_nodes: int = 30, n_edges: int = 64,
                 d_feat: int = 16, seed: int = 0):
        self.batch, self.n_nodes, self.n_edges = batch, n_nodes, n_edges
        self.d_feat, self.seed = d_feat, seed

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        B, N, E = self.batch, self.n_nodes, self.n_edges
        coords = rng.standard_normal((B, N, 3)).astype(np.float32)
        feats = rng.standard_normal((B, N, self.d_feat)).astype(np.float32)
        senders = rng.integers(0, N, (B, E)).astype(np.int32)
        receivers = rng.integers(0, N, (B, E)).astype(np.int32)
        mask = (senders != receivers)
        # target: a smooth invariant function (sum of pair distances)
        d = np.linalg.norm(
            coords[np.arange(B)[:, None], senders]
            - coords[np.arange(B)[:, None], receivers],
            axis=-1,
        )
        energy = (d * mask).sum(axis=1).astype(np.float32)
        return feats, coords, senders, receivers, mask, energy

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch (double buffering by default)."""
    q: queue.Queue = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item


def shard_batch(batch, mesh: Mesh, spec: P):
    """device_put a host batch with a NamedSharding (input feeding)."""
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)

"""Pallas TPU kernels for the perf-critical compute layers.

kernels/<name>.py  — pl.pallas_call + BlockSpec (TPU target, interpret on CPU)
kernels/ops.py     — jit'd public wrappers (backend auto-dispatch)
kernels/ref.py     — pure-jnp oracles, the allclose targets for tests
"""
from repro.kernels.ops import embedding_bag, tc_neighbor_max, tc_spmv

__all__ = ["tc_spmv", "tc_neighbor_max", "embedding_bag"]

"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on CPU (this container) kernels run `interpret=True`, which
executes the kernel body in Python per grid step — bit-identical semantics to
the TPU lowering, minus performance.  On TPU the same call sites compile the
real Mosaic kernels.  `interpret=None` means "auto by backend".

Storage axis (DESIGN.md §11): `tiled.tiles` is passed to the kernels AS
STORED — dense int8 or bit-packed uint32 — and never densified here; a
pre-kernel unpack would materialise the (nt, T, T) array in HBM and forfeit
the 8× DMA saving (CI guards this: `tools/ci_guards.py`).  The kernels
detect the format from the dtype and unpack per-tile in VMEM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import BlockTiledGraph
from repro.kernels.tc_spmv import tc_spmv_pallas
from repro.kernels.tc_neighbor_max import tc_neighbor_max_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas

_NEG = np.int32(-(1 << 30))  # numpy scalar: safe to create at import time under a trace


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def tc_spmv(
    tiled: BlockTiledGraph,
    rhs: jnp.ndarray,
    *,
    col_flags: jnp.ndarray | None = None,
    interpret: Optional[bool] = None,
    skip_dma: bool = False,
) -> jnp.ndarray:
    """Paper phase ②: N = A × rhs on the block-tiled adjacency."""
    return tc_spmv_pallas(
        tiled.tiles,
        tiled.tile_rows,
        tiled.tile_cols,
        rhs,
        tiled.n_block_rows,
        col_flags=col_flags,
        interpret=_auto_interpret(interpret),
        skip_dma=skip_dma,
    )


def tc_neighbor_max(
    tiled: BlockTiledGraph,
    p: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Beyond-paper phase ①: Max_Np on the same tile schedule."""
    pm = jnp.where(mask, p, _NEG)
    return tc_neighbor_max_pallas(
        tiled.tiles,
        tiled.tile_rows,
        tiled.tile_cols,
        pm,
        tiled.n_block_rows,
        interpret=_auto_interpret(interpret),
    )


def embedding_bag(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Recsys embedding-bag (weighted sum over a bag of rows)."""
    if weights is None:
        weights = jnp.ones(indices.shape, dtype=jnp.float32)
    return embedding_bag_pallas(
        table, indices, weights, interpret=_auto_interpret(interpret)
    )


def tc_spmv_fused(
    tiled: BlockTiledGraph,
    rhs: jnp.ndarray,
    cand: jnp.ndarray,          # (n_padded,) bool
    alive: jnp.ndarray,         # (n_padded,) bool
    *,
    col_flags: jnp.ndarray | None = None,
    interpret: Optional[bool] = None,
    skip_dma: bool = False,
):
    """Fused phase ②+③ (DESIGN.md §6.3): one kernel pass emits N_c AND the
    updated (alive, in_mis_add) masks.

    Block-rows with no stored tiles never enter the kernel grid, so their
    epilogue is patched here from the trivial rule (no neighbours ⇒ n_c=0 ⇒
    alive' = alive ∧ ¬cand, mis_add = cand).
    """
    from repro.kernels.tc_spmv import tc_spmv_fused_pallas

    T = tiled.tile_size
    n_c, new_alive, mis_add = tc_spmv_fused_pallas(
        tiled.tiles, tiled.tile_rows, tiled.tile_cols, rhs,
        cand.astype(jnp.int8), alive.astype(jnp.int8), tiled.n_block_rows,
        col_flags=col_flags,
        interpret=_auto_interpret(interpret),
        skip_dma=skip_dma,
    )
    # static per-graph coverage: which block-rows own at least one tile
    covered_rows = jnp.zeros((tiled.n_block_rows,), bool).at[
        tiled.tile_rows[: max(tiled.n_tiles, 1)]
    ].set(tiled.n_tiles > 0)
    covered = jnp.repeat(covered_rows, T)
    new_alive_b = jnp.where(covered, new_alive != 0, alive & ~cand)
    mis_add_b = jnp.where(covered, mis_add != 0, cand)
    n_c = jnp.where(covered[:, None], n_c, 0.0)
    return n_c, new_alive_b, mis_add_b


# ---------------------------------------------------------------------------
# bitwise frontier wrappers (DESIGN.md §13): packed (nbc, W) uint32 words in,
# packed words out.  Block-rows with no stored tiles never enter the kernel
# grid, so each wrapper patches them from the trivial rule — same contract as
# the dense `tc_spmv_fused` above, word-wise.
# ---------------------------------------------------------------------------

def _covered_block_rows(tiled: BlockTiledGraph) -> jnp.ndarray:
    """(n_block_rows,) bool — block-rows owning at least one stored tile."""
    return jnp.zeros((tiled.n_block_rows,), bool).at[
        tiled.tile_rows[: max(tiled.n_tiles, 1)]
    ].set(tiled.n_tiles > 0)


def _tiles_words(tiled: BlockTiledGraph, tiles_words) -> jnp.ndarray:
    if tiles_words is not None:
        return tiles_words
    from repro.core.tiling import tiles_as_words

    return tiles_as_words(tiled.tiles, tiled.tile_size)


def tc_spmv_bits(
    tiled: BlockTiledGraph,
    rhs_words: jnp.ndarray,      # (nbc, W) uint32 — packed candidate vector
    *,
    tiles_words: jnp.ndarray | None = None,   # precomputed word tiles
    col_flags: jnp.ndarray | None = None,
    interpret: Optional[bool] = None,
    skip_dma: bool = False,
) -> jnp.ndarray:
    """Phase ② on packed words: hit = (A × C) > 0.  (nbr, W) uint32 out.

    Pass `tiles_words` (from `tiling.tiles_as_words`, cached per solve in
    the engine's BitwiseContext) to avoid re-deriving it per call."""
    from repro.kernels.tc_spmv import tc_spmv_bits_pallas

    hit = tc_spmv_bits_pallas(
        _tiles_words(tiled, tiles_words),
        tiled.tile_rows, tiled.tile_cols, rhs_words, tiled.n_block_rows,
        col_flags=col_flags,
        interpret=_auto_interpret(interpret),
        skip_dma=skip_dma,
    )
    # uncovered block-rows have no neighbours ⇒ no hits (word 0)
    return jnp.where(_covered_block_rows(tiled)[:, None], hit, jnp.uint32(0))


def tc_neighbor_max_bits(
    tiled: BlockTiledGraph,
    planes: jnp.ndarray,         # (n_bits, nbc, W) uint32 priority planes
    mask_words: jnp.ndarray,     # (nbc, W) uint32 packed mask
    *,
    tiles_words: jnp.ndarray | None = None,
    signed: bool = False,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Phase ① on packed words: the priority-plane scan kernel.

    Uncovered block-rows are patched to int32 min — the fill value
    `jax.ops.segment_max` gives rows no tile ever visits, so the jnp clz
    formulation and this kernel stay bit-identical everywhere."""
    from repro.kernels.tc_neighbor_max import tc_neighbor_max_bits_pallas

    out = tc_neighbor_max_bits_pallas(
        _tiles_words(tiled, tiles_words),
        tiled.tile_rows, tiled.tile_cols, planes, mask_words,
        tiled.n_block_rows,
        signed=signed,
        interpret=_auto_interpret(interpret),
    )
    covered = jnp.repeat(_covered_block_rows(tiled), tiled.tile_size)
    return jnp.where(covered, out, jnp.iinfo(jnp.int32).min)


def tc_spmv_fused_bits(
    tiled: BlockTiledGraph,
    cand_words: jnp.ndarray,     # (nbc, W) uint32
    alive_words: jnp.ndarray,    # (nbr, W) uint32
    *,
    tiles_words: jnp.ndarray | None = None,
    col_flags: jnp.ndarray | None = None,
    interpret: Optional[bool] = None,
    skip_dma: bool = False,
):
    """Fused ②+③ on packed words: (hit, new_alive, mis_add) word arrays."""
    from repro.kernels.tc_spmv import tc_spmv_fused_bits_pallas

    hit, new_alive, mis_add = tc_spmv_fused_bits_pallas(
        _tiles_words(tiled, tiles_words),
        tiled.tile_rows, tiled.tile_cols, cand_words, alive_words,
        tiled.n_block_rows,
        col_flags=col_flags,
        interpret=_auto_interpret(interpret),
        skip_dma=skip_dma,
    )
    covered = _covered_block_rows(tiled)[:, None]
    hit = jnp.where(covered, hit, jnp.uint32(0))
    new_alive = jnp.where(covered, new_alive, alive_words & ~cand_words)
    mis_add = jnp.where(covered, mis_add, cand_words)
    return hit, new_alive, mis_add

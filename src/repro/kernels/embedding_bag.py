"""Pallas TPU kernel: embedding-bag (gather + weighted segment-reduce).

JAX has no native EmbeddingBag; the recsys path (DeepFM) builds it from
`jnp.take` + `segment_sum`.  This kernel is the TPU hot-path version: the
*index map itself* performs the gather — grid (B, K), and the table's
BlockSpec selects row `indices[b,k]` for step (b,k), so Pallas DMAs exactly
one (1, D) embedding row per step out of the HBM-resident table.  The output
block (1, D) stays VMEM-resident across the K inner steps (revisit
accumulation), giving the weighted bag-sum without any scatter.

This mirrors the BSR trick in tc_spmv: irregular access is pushed into
scalar-prefetched index maps, the compute stays dense and regular.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, tiles_row_ref, w_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row = tiles_row_ref[...].astype(jnp.float32)   # (1, D)
    w = w_ref[0, 0]
    out_ref[...] += w * row


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(
    table: jnp.ndarray,     # (V, D) float
    indices: jnp.ndarray,   # (B, K) int32
    weights: jnp.ndarray,   # (B, K) float
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Σ_k weights[b,k] · table[indices[b,k]]  ->  (B, D) float32."""
    B, K = indices.shape
    _, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, k, idx: (idx[b * K + k], 0)),
            pl.BlockSpec((1, 1), lambda b, k, idx: (b, k)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, k, idx: (b, 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(indices.reshape(-1), table, weights.astype(jnp.float32))

"""Pure-jnp oracles for every Pallas kernel (the `assert_allclose` targets).

These are intentionally naive — materialise-gather-einsum-segment — so they
are obviously correct and serve as the numerical ground truth for the
shape/dtype sweeps in tests/test_kernels_*.py.  Bit-packed uint32 tiles are
densified up front (this IS the oracle/int8 path — the one place a full
(nt, T, T) unpack is allowed; the Pallas kernels unpack per-tile in VMEM).
The bitwise-frontier oracles likewise densify packed frontier words and
route through the dense oracles — ref.py is the sanctioned densifying
reference (tools/ci_guards.py excludes it), which is exactly what makes it
a trustworthy equivalence target for the packed kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import (
    dense_tiles,
    pack_frontier_words,
    unpack_frontier_bits,
    unpack_frontier_words,
)

_NEG = np.int32(-(1 << 30))  # numpy scalar: safe to create at import time under a trace


def tc_spmv_ref(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    rhs: jnp.ndarray,
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Oracle for tc_spmv_pallas (col_flags only gates *empty* slabs, so the
    result is identical with or without them — asserted in tests)."""
    nt, T, _ = tiles.shape
    tiles = dense_tiles(tiles, T)
    L = rhs.shape[-1]
    blocks = rhs.reshape(-1, T, L)
    gathered = blocks[tile_cols].astype(jnp.float32)
    prod = jnp.einsum("ijk,ikl->ijl", tiles.astype(jnp.float32), gathered)
    out = jax.ops.segment_sum(prod, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T, L)


def tc_neighbor_max_ref(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    pm: jnp.ndarray,
    n_block_rows: int,
) -> jnp.ndarray:
    """Oracle for tc_neighbor_max_pallas."""
    nt, T, _ = tiles.shape
    tiles = dense_tiles(tiles, T)
    pm2 = pm.reshape(-1, T)
    gathered = pm2[tile_cols]                                # (nt, T)
    vals = jnp.where(tiles != 0, gathered[:, None, :], _NEG)  # (nt, T, T)
    tile_max = vals.max(axis=2)                              # (nt, T)
    out = jax.ops.segment_max(tile_max, tile_rows, num_segments=n_block_rows)
    return out.reshape(n_block_rows * T)


def tc_spmv_bits_ref(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    rhs_words: jnp.ndarray,      # (nbc, W) uint32
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Oracle for tc_spmv_bits_pallas: densify the candidate words, run the
    dense SpMV oracle on lane 0, threshold, re-pack.  (n_block_rows, W)."""
    nt, T, _ = tiles.shape
    cand = unpack_frontier_words(rhs_words, T)
    if col_flags is not None:
        cand = cand & (jnp.repeat(col_flags, T) != 0)
    out = tc_spmv_ref(
        tiles, tile_rows, tile_cols,
        cand.astype(jnp.float32)[:, None], n_block_rows,
    )
    return pack_frontier_words(out[:, 0] > 0, T)


def tc_neighbor_max_bits_ref(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    p: jnp.ndarray,              # (nbc*T,) int32 raw priorities
    mask_words: jnp.ndarray,     # (nbc, W) uint32 packed mask
    n_block_rows: int,
) -> jnp.ndarray:
    """Oracle for tc_neighbor_max_bits_pallas / tile_neighbor_max_bits:
    densify the mask words, mask the priorities, run the dense max oracle.
    Matches the bitwise ops' uncovered-row fill (int32 min from
    segment_max), not the interpret-mode kernel's uninitialised blocks."""
    T = tiles.shape[1]
    mask = unpack_frontier_bits(mask_words, T).reshape(-1)
    pm = jnp.where(mask, p, _NEG)
    return tc_neighbor_max_ref(tiles, tile_rows, tile_cols, pm, n_block_rows)


def embedding_bag_ref(
    table: jnp.ndarray,      # (V, D)
    indices: jnp.ndarray,    # (B, K) int32
    weights: jnp.ndarray,    # (B, K) float — 0 masks a slot
) -> jnp.ndarray:
    """Oracle for the recsys embedding-bag: Σ_k w[b,k] · table[idx[b,k]]."""
    rows = table[indices]                                    # (B, K, D)
    return (rows * weights[..., None]).sum(axis=1)

"""Pallas TPU kernel: block-tiled SpMV (the paper's phase-② WMMA listing).

One grid step per stored BSR tile, in block-row-major order:

  HBM layout                          VMEM working set per step
  tiles      (nt, T, T)   int8   ->   (1, T, T)  one adjacency tile
  rhs        (nbc·T, L)   f32    ->   (T, L)     the tile's RHS slab
  out        (nbr·T, L)   f32    ->   (T, L)     resident accumulator

With the default T=128, L=128 the working set is 128·128·(1+4+4) ≈ 144 KiB —
comfortably inside a v5e core's ~128 KiB/slot double-buffered VMEM budget at
bf16 RHS (switch `rhs` to bf16 to halve it; accumulation stays f32 via
`preferred_element_type`).  Both matmul dims are 128-multiples, so every
`jnp.dot` is exactly one MXU pass — the TPU equivalent of the paper's one
`mma_sync` per WMMA fragment.

TPU-native replacements for the paper's GPU mechanics (DESIGN.md §2):
  * per-row-per-tile atomics  -> tiles sorted by block-row; consecutive grid
    steps hitting the same output block accumulate in VMEM; `@pl.when` on the
    row transition zero-initialises the accumulator.
  * warp-level wave scheduling -> Pallas pipelines the HBM→VMEM DMAs of step
    i+1 under the MXU work of step i (automatic double buffering).
  * empty-C tile skipping      -> `col_flags` scalar prefetch: tiles whose RHS
    slab is all-zero skip the MXU op (`@pl.when`).  The DMA itself is also
    skippable by pointing the index_map at the previous block — that variant
    is `skip_dma=True` (hill-climb knob; both validated against the oracle).

Storage axis (DESIGN.md §11): tiles arrive either dense int8 (T, T) or
bit-packed uint32 (T, W) with W = max(T//32, 1).  Packed tiles are unpacked
IN VMEM inside the kernel body, right after the DMA — HBM only ever carries
the 8×-smaller packed words, and with `skip_dma` the skipped-or-not transfer
shrinks by the same factor.  The format is detected from the tile dtype, so
call sites are storage-polymorphic.

Bitwise frontier mode (DESIGN.md §13): `tc_spmv_bits_pallas` and the fused
`tc_spmv_fused_bits_pallas` keep BOTH operands packed — tile words AND the
candidate vector as (nbc, W) uint32 words.  The MXU contraction is replaced
by `popcount(tile_word & cand_word) != 0` per row (the paper's N_c > 0 test
without the f32 accumulator), and phase ③ becomes pure word logic in the
fused epilogue.  No dense vector crosses HBM in either direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import pack_frontier_bits, unpack_tile_mask


def _spmv_kernel(rows_ref, cols_ref, flags_ref, tiles_ref, rhs_ref, out_ref,
                 *, packed: bool, tile_size: int):
    i = pl.program_id(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(flags_ref[cols_ref[i]] != 0)
    def _mma():
        a = tiles_ref[0]                           # (T, T) i8 | (T, W) u32
        if packed:                                 # in-VMEM bit→f32, post-DMA
            a = unpack_tile_mask(a, tile_size).astype(jnp.float32)
        else:
            a = a.astype(jnp.float32)              # (T, T) 0/1 adjacency tile
        b = rhs_ref[...].astype(jnp.float32)       # (T, L) packed RHS lanes
        out_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("n_block_rows", "interpret", "skip_dma")
)
def tc_spmv_pallas(
    tiles: jnp.ndarray,       # (nt, T, T) int8, block-row-major
    tile_rows: jnp.ndarray,   # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,   # (nt,) int32
    rhs: jnp.ndarray,         # (nbc*T, L) float
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,  # (nbc,) int32; None = all active
    interpret: bool = True,
    skip_dma: bool = False,
) -> jnp.ndarray:
    """N = A @ rhs over BSR tiles. Returns (n_block_rows*T, L) float32.

    `tiles` may be dense int8 (nt, T, T) or bit-packed uint32 (nt, T, W) —
    the packed form DMAs 8× fewer bytes and unpacks in VMEM."""
    nt, T, tw = tiles.shape
    packed = tiles.dtype == jnp.uint32
    L = rhs.shape[-1]
    nbc = rhs.shape[0] // T
    if col_flags is None:
        col_flags = jnp.ones((nbc,), dtype=jnp.int32)

    if skip_dma:
        # point the RHS DMA at block 0 when the slab is empty — the MXU op is
        # predicated off anyway, so correctness is unchanged but the HBM read
        # is saved on TPU.  (Interpret mode validates the indexing only.)
        def rhs_index(i, rows, cols, flags):
            c = cols[i]
            return (jnp.where(flags[c] != 0, c, 0), 0)
    else:
        def rhs_index(i, rows, cols, flags):
            return (cols[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, tw), lambda i, rows, cols, flags: (i, 0, 0)),
            pl.BlockSpec((T, L), rhs_index),
        ],
        out_specs=pl.BlockSpec(
            (T, L), lambda i, rows, cols, flags: (rows[i], 0)
        ),
    )
    return pl.pallas_call(
        functools.partial(_spmv_kernel, packed=packed, tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows * T, L), jnp.float32),
        interpret=interpret,
    )(tile_rows, tile_cols, col_flags, tiles, rhs)


# ---------------------------------------------------------------------------
# fused phase ②+③ variant (DESIGN.md §6.3): the state update is applied in
# the SpMV epilogue on the LAST visit to each output block, so N_c never
# round-trips through HBM — the kernel emits the new (alive, in_mis) masks
# directly.
# ---------------------------------------------------------------------------

def _spmv_fused_kernel(
    rows_ref, cols_ref, flags_ref, tiles_ref, rhs_ref, cand_ref, alive_ref,
    nc_ref, alive_out_ref, mis_out_ref, *, packed: bool, tile_size: int,
):
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]
    nxt = rows_ref[jnp.minimum(i + 1, nt - 1)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        nc_ref[...] = jnp.zeros_like(nc_ref)

    @pl.when(flags_ref[cols_ref[i]] != 0)
    def _mma():
        a = tiles_ref[0]
        if packed:                                 # in-VMEM bit→f32, post-DMA
            a = unpack_tile_mask(a, tile_size).astype(jnp.float32)
        else:
            a = a.astype(jnp.float32)
        b = rhs_ref[...].astype(jnp.float32)
        nc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when((i == nt - 1) | (nxt != row))
    def _epilogue():
        # phase ③, paper's three rules — lock-free: own row block only
        cand = cand_ref[...] != 0                      # (T, 1) lanes
        alive = alive_ref[...] != 0
        hit = nc_ref[..., 0:1] > 0
        mis_out_ref[...] = cand.astype(jnp.int8)
        alive_out_ref[...] = (alive & ~cand & ~hit).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("n_block_rows", "interpret", "skip_dma")
)
def tc_spmv_fused_pallas(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    rhs: jnp.ndarray,          # (nbc*T, L): lane 0 = C, lane 1 = alive
    cand: jnp.ndarray,         # (nbr*T,) int8 — candidate mask per row block
    alive: jnp.ndarray,        # (nbr*T,) int8
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,
    interpret: bool = True,
    skip_dma: bool = False,
):
    """Fused phase ②+③: returns (n_c (nbr*T, L) f32, new_alive i8, mis_add i8).

    Storage-polymorphic like the split kernel: bit-packed uint32 tiles DMA
    8× fewer bytes and unpack in VMEM inside the kernel body."""
    nt, T, tw = tiles.shape
    packed = tiles.dtype == jnp.uint32
    L = rhs.shape[-1]
    nbc = rhs.shape[0] // T
    if col_flags is None:
        col_flags = jnp.ones((nbc,), dtype=jnp.int32)

    if skip_dma:
        # same trick as the split kernel: an empty-C slab's DMA is retargeted
        # at block 0 — the MXU op is predicated off, the HBM read is saved.
        def rhs_index(i, rows, cols, flags):
            c = cols[i]
            return (jnp.where(flags[c] != 0, c, 0), 0)
    else:
        def rhs_index(i, rows, cols, flags):
            return (cols[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, tw), lambda i, rows, cols, flags: (i, 0, 0)),
            pl.BlockSpec((T, L), rhs_index),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((T, L), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
        ],
    )
    n_c, new_alive, mis_add = pl.pallas_call(
        functools.partial(_spmv_fused_kernel, packed=packed, tile_size=T),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_block_rows * T, L), jnp.float32),
            jax.ShapeDtypeStruct((n_block_rows * T, 1), jnp.int8),
            jax.ShapeDtypeStruct((n_block_rows * T, 1), jnp.int8),
        ],
        interpret=interpret,
    )(
        tile_rows, tile_cols, col_flags, tiles, rhs,
        cand.reshape(-1, 1), alive.reshape(-1, 1),
    )
    return n_c, new_alive[:, 0], mis_add[:, 0]


# ---------------------------------------------------------------------------
# bitwise frontier kernels (DESIGN.md §13): packed words on BOTH sides of the
# contraction.  Per grid step the DMA moves one (T, W) tile and one (1, W)
# candidate word row — 32× less RHS traffic than the lane-packed f32 slab —
# and the "matmul" is popcount(AND) != 0 folded straight to a result bit.
# ---------------------------------------------------------------------------

def _spmv_bits_kernel(rows_ref, cols_ref, flags_ref, tiles_ref, rhs_ref,
                      out_ref, *, tile_size: int):
    i = pl.program_id(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(flags_ref[cols_ref[i]] != 0)
    def _and():
        a = tiles_ref[0]                          # (T, W) u32: row v's words
        c = rhs_ref[...]                          # (1, W) candidate words
        hit = jnp.any(jax.lax.population_count(a & c) != 0, axis=1)  # (T,)
        out_ref[...] |= pack_frontier_bits(
            hit[None, :].astype(jnp.uint32), tile_size
        )


@functools.partial(
    jax.jit, static_argnames=("n_block_rows", "interpret", "skip_dma")
)
def tc_spmv_bits_pallas(
    tiles_words: jnp.ndarray,  # (nt, T, W) uint32, block-row-major
    tile_rows: jnp.ndarray,    # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,    # (nt,) int32
    rhs_words: jnp.ndarray,    # (nbc, W) uint32 — packed candidate vector
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,
    interpret: bool = True,
    skip_dma: bool = False,
) -> jnp.ndarray:
    """hit = (A @ C) > 0 on packed words.  Returns (n_block_rows, W) uint32.

    Requires packed uint32 tiles (the bitwise mode exists to avoid ever
    touching the dense form; use `tiling.tiles_as_words` to convert)."""
    if tiles_words.dtype != jnp.uint32:
        raise ValueError(
            f"tc_spmv_bits_pallas needs packed uint32 tiles, got "
            f"{tiles_words.dtype} (convert via tiling.tiles_as_words)"
        )
    nt, T, W = tiles_words.shape
    nbc = rhs_words.shape[0]
    if col_flags is None:
        col_flags = jnp.ones((nbc,), dtype=jnp.int32)

    if skip_dma:
        # empty-C word row: retarget the DMA at block 0 — the AND is
        # predicated off, the (tiny) HBM read is saved on TPU.
        def rhs_index(i, rows, cols, flags):
            c = cols[i]
            return (jnp.where(flags[c] != 0, c, 0), 0)
    else:
        def rhs_index(i, rows, cols, flags):
            return (cols[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, W), lambda i, rows, cols, flags: (i, 0, 0)),
            pl.BlockSpec((1, W), rhs_index),
        ],
        out_specs=pl.BlockSpec((1, W), lambda i, rows, cols, flags: (rows[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_spmv_bits_kernel, tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, W), jnp.uint32),
        interpret=interpret,
    )(tile_rows, tile_cols, col_flags, tiles_words, rhs_words)


def _spmv_fused_bits_kernel(
    rows_ref, cols_ref, flags_ref, tiles_ref, rhs_ref, cand_ref, alive_ref,
    hit_ref, alive_out_ref, mis_out_ref, *, tile_size: int,
):
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]
    nxt = rows_ref[jnp.minimum(i + 1, nt - 1)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        hit_ref[...] = jnp.zeros_like(hit_ref)

    @pl.when(flags_ref[cols_ref[i]] != 0)
    def _and():
        a = tiles_ref[0]
        c = rhs_ref[...]
        hit = jnp.any(jax.lax.population_count(a & c) != 0, axis=1)
        hit_ref[...] |= pack_frontier_bits(
            hit[None, :].astype(jnp.uint32), tile_size
        )

    @pl.when((i == nt - 1) | (nxt != row))
    def _epilogue():
        # phase ③ as word logic — 32 vertices per op, own row block only
        cand = cand_ref[...]                      # (1, W)
        alive = alive_ref[...]
        mis_out_ref[...] = cand
        alive_out_ref[...] = alive & ~cand & ~hit_ref[...]


@functools.partial(
    jax.jit, static_argnames=("n_block_rows", "interpret", "skip_dma")
)
def tc_spmv_fused_bits_pallas(
    tiles_words: jnp.ndarray,  # (nt, T, W) uint32
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    cand_words: jnp.ndarray,   # (nbc, W) uint32 — C, the SpMV RHS
    alive_words: jnp.ndarray,  # (nbr, W) uint32
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,
    interpret: bool = True,
    skip_dma: bool = False,
):
    """Fused ②+③ on packed words.

    Returns (hit_words, new_alive_words, mis_add_words), each
    (n_block_rows, W) uint32.  `cand_words` plays both roles: SpMV RHS
    (indexed by block column) and phase-③ own-state input (indexed by block
    row) — same array, two BlockSpecs."""
    if tiles_words.dtype != jnp.uint32:
        raise ValueError(
            f"tc_spmv_fused_bits_pallas needs packed uint32 tiles, got "
            f"{tiles_words.dtype} (convert via tiling.tiles_as_words)"
        )
    nt, T, W = tiles_words.shape
    nbc = cand_words.shape[0]
    if col_flags is None:
        col_flags = jnp.ones((nbc,), dtype=jnp.int32)

    if skip_dma:
        def rhs_index(i, rows, cols, flags):
            c = cols[i]
            return (jnp.where(flags[c] != 0, c, 0), 0)
    else:
        def rhs_index(i, rows, cols, flags):
            return (cols[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, W), lambda i, rows, cols, flags: (i, 0, 0)),
            pl.BlockSpec((1, W), rhs_index),
            pl.BlockSpec((1, W), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((1, W), lambda i, rows, cols, flags: (rows[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, W), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((1, W), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((1, W), lambda i, rows, cols, flags: (rows[i], 0)),
        ],
    )
    hit, new_alive, mis_add = pl.pallas_call(
        functools.partial(_spmv_fused_bits_kernel, tile_size=T),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_block_rows, W), jnp.uint32),
            jax.ShapeDtypeStruct((n_block_rows, W), jnp.uint32),
            jax.ShapeDtypeStruct((n_block_rows, W), jnp.uint32),
        ],
        interpret=interpret,
    )(
        tile_rows, tile_cols, col_flags, tiles_words,
        cand_words, cand_words, alive_words,   # C twice: RHS role + own-row role
    )
    return hit, new_alive, mis_add

"""Pallas TPU kernel: block-tiled SpMV (the paper's phase-② WMMA listing).

One grid step per stored BSR tile, in block-row-major order:

  HBM layout                          VMEM working set per step
  tiles      (nt, T, T)   int8   ->   (1, T, T)  one adjacency tile
  rhs        (nbc·T, L)   f32    ->   (T, L)     the tile's RHS slab
  out        (nbr·T, L)   f32    ->   (T, L)     resident accumulator

With the default T=128, L=128 the working set is 128·128·(1+4+4) ≈ 144 KiB —
comfortably inside a v5e core's ~128 KiB/slot double-buffered VMEM budget at
bf16 RHS (switch `rhs` to bf16 to halve it; accumulation stays f32 via
`preferred_element_type`).  Both matmul dims are 128-multiples, so every
`jnp.dot` is exactly one MXU pass — the TPU equivalent of the paper's one
`mma_sync` per WMMA fragment.

TPU-native replacements for the paper's GPU mechanics (DESIGN.md §2):
  * per-row-per-tile atomics  -> tiles sorted by block-row; consecutive grid
    steps hitting the same output block accumulate in VMEM; `@pl.when` on the
    row transition zero-initialises the accumulator.
  * warp-level wave scheduling -> Pallas pipelines the HBM→VMEM DMAs of step
    i+1 under the MXU work of step i (automatic double buffering).
  * empty-C tile skipping      -> `col_flags` scalar prefetch: tiles whose RHS
    slab is all-zero skip the MXU op (`@pl.when`).  The DMA itself is also
    skippable by pointing the index_map at the previous block — that variant
    is `skip_dma=True` (hill-climb knob; both validated against the oracle).

Storage axis (DESIGN.md §11): tiles arrive either dense int8 (T, T) or
bit-packed uint32 (T, W) with W = max(T//32, 1).  Packed tiles are unpacked
IN VMEM inside the kernel body, right after the DMA — HBM only ever carries
the 8×-smaller packed words, and with `skip_dma` the skipped-or-not transfer
shrinks by the same factor.  The format is detected from the tile dtype, so
call sites are storage-polymorphic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import unpack_tile_bits


def _spmv_kernel(rows_ref, cols_ref, flags_ref, tiles_ref, rhs_ref, out_ref,
                 *, packed: bool, tile_size: int):
    i = pl.program_id(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(flags_ref[cols_ref[i]] != 0)
    def _mma():
        a = tiles_ref[0]                           # (T, T) i8 | (T, W) u32
        if packed:                                 # in-VMEM unpack, post-DMA
            a = unpack_tile_bits(a, tile_size)
        a = a.astype(jnp.float32)                  # (T, T) 0/1 adjacency tile
        b = rhs_ref[...].astype(jnp.float32)       # (T, L) packed RHS lanes
        out_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("n_block_rows", "interpret", "skip_dma")
)
def tc_spmv_pallas(
    tiles: jnp.ndarray,       # (nt, T, T) int8, block-row-major
    tile_rows: jnp.ndarray,   # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,   # (nt,) int32
    rhs: jnp.ndarray,         # (nbc*T, L) float
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,  # (nbc,) int32; None = all active
    interpret: bool = True,
    skip_dma: bool = False,
) -> jnp.ndarray:
    """N = A @ rhs over BSR tiles. Returns (n_block_rows*T, L) float32.

    `tiles` may be dense int8 (nt, T, T) or bit-packed uint32 (nt, T, W) —
    the packed form DMAs 8× fewer bytes and unpacks in VMEM."""
    nt, T, tw = tiles.shape
    packed = tiles.dtype == jnp.uint32
    L = rhs.shape[-1]
    nbc = rhs.shape[0] // T
    if col_flags is None:
        col_flags = jnp.ones((nbc,), dtype=jnp.int32)

    if skip_dma:
        # point the RHS DMA at block 0 when the slab is empty — the MXU op is
        # predicated off anyway, so correctness is unchanged but the HBM read
        # is saved on TPU.  (Interpret mode validates the indexing only.)
        def rhs_index(i, rows, cols, flags):
            c = cols[i]
            return (jnp.where(flags[c] != 0, c, 0), 0)
    else:
        def rhs_index(i, rows, cols, flags):
            return (cols[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, tw), lambda i, rows, cols, flags: (i, 0, 0)),
            pl.BlockSpec((T, L), rhs_index),
        ],
        out_specs=pl.BlockSpec(
            (T, L), lambda i, rows, cols, flags: (rows[i], 0)
        ),
    )
    return pl.pallas_call(
        functools.partial(_spmv_kernel, packed=packed, tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows * T, L), jnp.float32),
        interpret=interpret,
    )(tile_rows, tile_cols, col_flags, tiles, rhs)


# ---------------------------------------------------------------------------
# fused phase ②+③ variant (DESIGN.md §6.3): the state update is applied in
# the SpMV epilogue on the LAST visit to each output block, so N_c never
# round-trips through HBM — the kernel emits the new (alive, in_mis) masks
# directly.
# ---------------------------------------------------------------------------

def _spmv_fused_kernel(
    rows_ref, cols_ref, flags_ref, tiles_ref, rhs_ref, cand_ref, alive_ref,
    nc_ref, alive_out_ref, mis_out_ref, *, packed: bool, tile_size: int,
):
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]
    nxt = rows_ref[jnp.minimum(i + 1, nt - 1)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        nc_ref[...] = jnp.zeros_like(nc_ref)

    @pl.when(flags_ref[cols_ref[i]] != 0)
    def _mma():
        a = tiles_ref[0]
        if packed:                                 # in-VMEM unpack, post-DMA
            a = unpack_tile_bits(a, tile_size)
        a = a.astype(jnp.float32)
        b = rhs_ref[...].astype(jnp.float32)
        nc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when((i == nt - 1) | (nxt != row))
    def _epilogue():
        # phase ③, paper's three rules — lock-free: own row block only
        cand = cand_ref[...] != 0                      # (T, 1) lanes
        alive = alive_ref[...] != 0
        hit = nc_ref[..., 0:1] > 0
        mis_out_ref[...] = cand.astype(jnp.int8)
        alive_out_ref[...] = (alive & ~cand & ~hit).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("n_block_rows", "interpret", "skip_dma")
)
def tc_spmv_fused_pallas(
    tiles: jnp.ndarray,
    tile_rows: jnp.ndarray,
    tile_cols: jnp.ndarray,
    rhs: jnp.ndarray,          # (nbc*T, L): lane 0 = C, lane 1 = alive
    cand: jnp.ndarray,         # (nbr*T,) int8 — candidate mask per row block
    alive: jnp.ndarray,        # (nbr*T,) int8
    n_block_rows: int,
    *,
    col_flags: jnp.ndarray | None = None,
    interpret: bool = True,
    skip_dma: bool = False,
):
    """Fused phase ②+③: returns (n_c (nbr*T, L) f32, new_alive i8, mis_add i8).

    Storage-polymorphic like the split kernel: bit-packed uint32 tiles DMA
    8× fewer bytes and unpack in VMEM inside the kernel body."""
    nt, T, tw = tiles.shape
    packed = tiles.dtype == jnp.uint32
    L = rhs.shape[-1]
    nbc = rhs.shape[0] // T
    if col_flags is None:
        col_flags = jnp.ones((nbc,), dtype=jnp.int32)

    if skip_dma:
        # same trick as the split kernel: an empty-C slab's DMA is retargeted
        # at block 0 — the MXU op is predicated off, the HBM read is saved.
        def rhs_index(i, rows, cols, flags):
            c = cols[i]
            return (jnp.where(flags[c] != 0, c, 0), 0)
    else:
        def rhs_index(i, rows, cols, flags):
            return (cols[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, tw), lambda i, rows, cols, flags: (i, 0, 0)),
            pl.BlockSpec((T, L), rhs_index),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((T, L), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
            pl.BlockSpec((T, 1), lambda i, rows, cols, flags: (rows[i], 0)),
        ],
    )
    n_c, new_alive, mis_add = pl.pallas_call(
        functools.partial(_spmv_fused_kernel, packed=packed, tile_size=T),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_block_rows * T, L), jnp.float32),
            jax.ShapeDtypeStruct((n_block_rows * T, 1), jnp.int8),
            jax.ShapeDtypeStruct((n_block_rows * T, 1), jnp.int8),
        ],
        interpret=interpret,
    )(
        tile_rows, tile_cols, col_flags, tiles, rhs,
        cand.reshape(-1, 1), alive.reshape(-1, 1),
    )
    return n_c, new_alive[:, 0], mis_add[:, 0]

"""Pallas TPU kernel: tiled masked neighbour-max (beyond-paper phase ①).

The paper leaves phase ① (`Max_Np(v) = max_{u∈N(v)∩A} P(u)`) on CUDA cores —
and its own profile shows that after phase ② is tensorised, phase ① dominates
(83.1 % of TC-MIS runtime on G3/H200).  This kernel moves phase ① onto the
*same* BSR schedule as the SpMV: one grid step per tile, masked max over the
tile's columns, max-accumulated into a resident (1, T) output block.

Max has no MXU form, so this is VPU work — but it reads the identical tile
stream as `tc_spmv`, so on TPU the two kernels are bandwidth-twins and the
whole MIS round becomes tile-regular (DESIGN.md §6.1).

Priorities are int32; "dead" columns are encoded by the caller as _NEG
(−2^30) *before* the call, which keeps the kernel a pure max-reduce.

Storage axis (DESIGN.md §11): bit-packed uint32 tiles are supported exactly
as in `tc_spmv` — the DMA carries packed words, the kernel body unpacks the
VMEM-resident block before the masked max.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import unpack_tile_bits

_NEG = -(1 << 30)  # plain int: jnp scalars would be captured as kernel consts


def _nbr_max_kernel(rows_ref, cols_ref, tiles_ref, pm_ref, out_ref,
                    *, packed: bool, tile_size: int):
    i = pl.program_id(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _NEG)

    tile = tiles_ref[0]                       # (T, T): row v, col u
    if packed:                                # in-VMEM unpack, post-DMA
        tile = unpack_tile_bits(tile, tile_size)
    pm = pm_ref[...]                          # (1, T) masked priorities
    vals = jnp.where(tile != 0, pm, _NEG)     # broadcast over rows
    out_ref[...] = jnp.maximum(out_ref[...], vals.max(axis=1, keepdims=True).T)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "interpret"))
def tc_neighbor_max_pallas(
    tiles: jnp.ndarray,       # (nt, T, T) int8 | (nt, T, W) uint32, row-major
    tile_rows: jnp.ndarray,   # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,   # (nt,) int32
    pm: jnp.ndarray,          # (nbc*T,) int32 — priorities, _NEG where masked
    n_block_rows: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Max_Np over BSR tiles. Returns (n_block_rows*T,) int32 (_NEG = none)."""
    nt, T, tw = tiles.shape
    packed = tiles.dtype == jnp.uint32
    pm2 = pm.reshape(-1, T)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, tw), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((1, T), lambda i, rows, cols: (cols[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, T), lambda i, rows, cols: (rows[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_nbr_max_kernel, packed=packed, tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, T), jnp.int32),
        interpret=interpret,
    )(tile_rows, tile_cols, tiles, pm2)
    return out.reshape(n_block_rows * T)

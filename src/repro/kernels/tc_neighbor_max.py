"""Pallas TPU kernel: tiled masked neighbour-max (beyond-paper phase ①).

The paper leaves phase ① (`Max_Np(v) = max_{u∈N(v)∩A} P(u)`) on CUDA cores —
and its own profile shows that after phase ② is tensorised, phase ① dominates
(83.1 % of TC-MIS runtime on G3/H200).  This kernel moves phase ① onto the
*same* BSR schedule as the SpMV: one grid step per tile, masked max over the
tile's columns, max-accumulated into a resident (1, T) output block.

Max has no MXU form, so this is VPU work — but it reads the identical tile
stream as `tc_spmv`, so on TPU the two kernels are bandwidth-twins and the
whole MIS round becomes tile-regular (DESIGN.md §6.1).

Priorities are int32; "dead" columns are encoded by the caller as _NEG
(−2^30) *before* the call, which keeps the kernel a pure max-reduce.

Storage axis (DESIGN.md §11): bit-packed uint32 tiles are supported exactly
as in `tc_spmv` — the DMA carries packed words, the kernel body bit-extracts
the VMEM-resident block straight to the bool mask the masked max needs
(`unpack_tile_mask` — no int8 intermediate, the cast that made the packed
path lose to int8 pre-§13).

Bitwise frontier mode (DESIGN.md §13): `tc_neighbor_max_bits_pallas` is the
priority-plane scan — tiles stay packed words end-to-end, the mask arrives
as (nbc, W) uint32 words, and the max is reconstructed bit-by-bit from a
static stack of priority planes.  (The jnp engine path runs the same scan
collapsed into one clz pass over priority-sorted bit order; the plane form
is the TPU-native formulation — W-word vector ops per plane, no gathers.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import unpack_tile_mask

_NEG = -(1 << 30)  # plain int: jnp scalars would be captured as kernel consts


def _nbr_max_kernel(rows_ref, cols_ref, tiles_ref, pm_ref, out_ref,
                    *, packed: bool, tile_size: int):
    i = pl.program_id(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _NEG)

    tile = tiles_ref[0]                       # (T, T): row v, col u
    if packed:                                # in-VMEM bit→bool, post-DMA
        mask = unpack_tile_mask(tile, tile_size)
    else:
        mask = tile != 0
    pm = pm_ref[...]                          # (1, T) masked priorities
    vals = jnp.where(mask, pm, _NEG)          # broadcast over rows
    out_ref[...] = jnp.maximum(out_ref[...], vals.max(axis=1, keepdims=True).T)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "interpret"))
def tc_neighbor_max_pallas(
    tiles: jnp.ndarray,       # (nt, T, T) int8 | (nt, T, W) uint32, row-major
    tile_rows: jnp.ndarray,   # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,   # (nt,) int32
    pm: jnp.ndarray,          # (nbc*T,) int32 — priorities, _NEG where masked
    n_block_rows: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Max_Np over BSR tiles. Returns (n_block_rows*T,) int32 (_NEG = none)."""
    nt, T, tw = tiles.shape
    packed = tiles.dtype == jnp.uint32
    pm2 = pm.reshape(-1, T)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, tw), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((1, T), lambda i, rows, cols: (cols[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, T), lambda i, rows, cols: (rows[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_nbr_max_kernel, packed=packed, tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, T), jnp.int32),
        interpret=interpret,
    )(tile_rows, tile_cols, tiles, pm2)
    return out.reshape(n_block_rows * T)


def _nbr_max_bits_kernel(rows_ref, cols_ref, tiles_ref, planes_ref, mask_ref,
                         out_ref, *, n_bits: int, signed: bool, tile_size: int):
    """Priority-plane scan over one packed tile (DESIGN.md §13).

    `cur` tracks the surviving neighbour set per tile row; plane b (static
    unroll, high→low) intersects it with "columns whose priority has bit b".
    A nonempty intersection fixes bit b of the max and narrows `cur`; empty
    leaves both.  After all planes `maxv` IS the masked max — never any
    priority value materialised per column, only word AND/OR."""
    i = pl.program_id(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (prev != row))
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _NEG)

    a = tiles_ref[0]                          # (T, W) uint32: row v's words
    cur = a & mask_ref[...]                   # (T, W), mask (1, W) broadcast
    nonempty = jnp.any(cur != 0, axis=1)      # (T,)
    maxv = jnp.zeros((tile_size,), jnp.uint32)
    for b in range(n_bits - 1, -1, -1):
        inter = cur & planes_ref[b]           # (T, W) ∩ plane b's (1, W)
        has = jnp.any(inter != 0, axis=1)
        maxv = maxv | (has.astype(jnp.uint32) << b)
        cur = jnp.where(has[:, None], inter, cur)
    if signed:
        # planes were sign-biased (int32 ^ 0x80000000) so bit-serial max is
        # order-correct for negative priorities; un-bias on the way out.
        vals = jax.lax.bitcast_convert_type(maxv ^ jnp.uint32(0x80000000), jnp.int32)
    else:
        vals = maxv.astype(jnp.int32)
    vals = jnp.where(nonempty, vals, _NEG)
    out_ref[...] = jnp.maximum(out_ref[...], vals[None, :])


@functools.partial(jax.jit, static_argnames=("n_block_rows", "signed", "interpret"))
def tc_neighbor_max_bits_pallas(
    tiles_words: jnp.ndarray,  # (nt, T, W) uint32 — standard bit layout
    tile_rows: jnp.ndarray,    # (nt,) int32, non-decreasing
    tile_cols: jnp.ndarray,    # (nt,) int32
    planes: jnp.ndarray,       # (n_bits, nbc, W) uint32 — static per solve
    mask_words: jnp.ndarray,   # (nbc, W) uint32 — per-round packed mask
    n_block_rows: int,
    *,
    signed: bool = False,
    interpret: bool = True,
) -> jnp.ndarray:
    """Bitwise Max_Np: plane-scan form.  Returns (n_block_rows*T,) int32.

    The priority stack is (n_bits, nbc, W) packed planes from
    `core.tiling.pack_priority_planes` (`signed=True` iff the planes were
    sign-biased there).  Per grid step the DMA moves one packed tile, the
    block-column's plane column and its mask word — all uint32 words; no
    dense frontier or priority vector ever crosses HBM."""
    if tiles_words.dtype != jnp.uint32:
        raise ValueError(
            f"tc_neighbor_max_bits_pallas needs packed uint32 tiles, got "
            f"{tiles_words.dtype} (convert via tiling.tiles_as_words)"
        )
    nt, T, W = tiles_words.shape
    n_bits = int(planes.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, W), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((n_bits, 1, W), lambda i, rows, cols: (0, cols[i], 0)),
            pl.BlockSpec((1, W), lambda i, rows, cols: (cols[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, T), lambda i, rows, cols: (rows[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(
            _nbr_max_bits_kernel, n_bits=n_bits, signed=signed, tile_size=T
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, T), jnp.int32),
        interpret=interpret,
    )(tile_rows, tile_cols, tiles_words, planes, mask_words)
    return out.reshape(n_block_rows * T)

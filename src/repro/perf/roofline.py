"""Roofline-term extraction and the analytic cost model (§Roofline, §16).

Three terms, all in seconds, from the PER-DEVICE compiled module (XLA SPMD
cost_analysis / memory_analysis report per-device numbers, verified
empirically in tests/test_roofline.py):

    compute    = HLO_FLOPs              / peak_FLOP/s          (197 TF bf16)
    memory     = HLO_bytes_accessed     / HBM_bw               (819 GB/s)
    collective = Σ collective op bytes  / ICI link bw          (50 GB/s)

collective bytes are parsed from the compiled HLO text: the output payload of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async -start forms counted once, -done skipped).

Hardware model: TPU v5e — 197e12 bf16 FLOP/s, 819e9 B/s HBM, ~50e9 B/s ICI
per link (constants from the assignment).

The same hardware constants drive the hybrid tile-routing threshold
(DESIGN.md §16): a dense tile pays a fixed cost regardless of occupancy,
a segment-path edge pays a per-nnz cost, and the break-even nnz between
the two is ``hybrid_density_threshold``.  Historically this lived in
``benchmarks/roofline.py``; it moved into ``src/repro`` so the planner can
import it — the benchmarks module is now a re-export shim.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-type output bytes of every collective in a compiled HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        out[op] = out.get(op, 0) + _shape_bytes(m.group("out"))
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: int
    collectives: Dict[str, int]
    model_flops: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS per device — remat/padding/waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips × peak × step_time) — the roofline score."""
        t = self.step_time_s
        return self.model_flops / (PEAK_FLOPS * t) if t > 0 else 0.0

    def as_dict(self) -> dict:
        return dict(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            flops=self.flops,
            bytes_accessed=self.bytes_accessed,
            collective_bytes=self.collective_bytes,
            collectives=self.collectives,
            model_flops=self.model_flops,
            useful_flop_fraction=self.useful_flop_fraction,
            step_time_s=self.step_time_s,
            mfu=self.mfu,
        )


def roofline_from_compiled(
    compiled, n_devices: int, model_flops_global: float
) -> RooflineTerms:
    """Derive the three terms from a compiled (SPMD, per-device) module."""
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    colls = parse_collective_bytes(compiled.as_text())
    coll_bytes = sum(colls.values())
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        flops=flops,
        bytes_accessed=byts,
        collective_bytes=coll_bytes,
        collectives=colls,
        model_flops=model_flops_global / max(n_devices, 1),
    )


# --------------------------------------------------------------------------
# hybrid tile-routing cost model (DESIGN.md §16)
# --------------------------------------------------------------------------

# Bytes one segment-path nnz moves through HBM: two int32 coordinates plus a
# gathered operand word and its scattered contribution.
_SPARSE_BYTES_PER_EDGE = 16


def dense_tile_cost_s(tile_size: int, storage: str = "int8", lanes: int = 8) -> float:
    """Roofline cost of pushing ONE tile through the dense path, any occupancy.

    A dense T×T tile costs the same whether it holds 1 nnz or T² — that
    fixed cost is what the sparse tail wastes.  Compute term: the phase-②
    SpMV MACs over ``lanes`` rhs columns.  Memory term: the tile payload
    (storage-dependent — bitpack is 8× smaller) plus the rhs slab and the
    tile's share of the output.
    """
    if tile_size <= 0:
        raise ValueError(f"tile_size must be positive, got {tile_size}")
    t = int(tile_size)
    flops = 2.0 * t * t * lanes
    if storage == "bitpack":
        payload = t * max(t // 32, 1) * 4
    else:
        payload = t * t
    rhs_bytes = t * lanes * 4
    out_bytes = t * lanes * 4
    compute_s = flops / PEAK_FLOPS
    memory_s = (payload + rhs_bytes + out_bytes) / HBM_BW
    return max(compute_s, memory_s)


def sparse_edge_cost_s() -> float:
    """Roofline cost of ONE nnz on the COO/segment path (pure gather/scatter)."""
    return _SPARSE_BYTES_PER_EDGE / HBM_BW


def predicted_round_cost_s(
    dense_tiles: float,
    sparse_edges: float = 0.0,
    *,
    tile_size: int,
    storage: str = "int8",
    lanes: int = 8,
) -> float:
    """Model-predicted cost of ONE solver round (seconds).

    The same two primitives the hybrid router prices with, summed over a
    round's actual dispatch mix: ``dense_tiles`` tiles through the dense
    path (telemetry COL_TILES_DENSE, skip-gating already subtracted) plus
    ``sparse_edges`` half-edges through the COO/segment tail.  Fractional
    tile counts are fine — callers pass per-round means.
    """
    dense = max(float(dense_tiles), 0.0)
    edges = max(float(sparse_edges), 0.0)
    return (dense * dense_tile_cost_s(tile_size, storage, lanes)
            + edges * sparse_edge_cost_s())


def round_cost_attribution(
    *,
    dense_tiles: float,
    sparse_edges: float,
    tile_size: int,
    storage: str,
    measured_s: float,
    lanes: int = 8,
) -> Dict[str, float]:
    """Predicted-vs-measured per-round cost: the model-error gauge.

    Following HC-SpMM's practice of continuously scoring its hybrid-core
    cost model, this closes the loop on the router's pricing: `error_pct`
    = (measured − predicted) / predicted × 100.  Large positive error on
    a CPU backend is EXPECTED (the constants model a TPU v5e roofline) —
    the signal is the trend, not the absolute: a drifting error under
    churn means the dispatch mix no longer matches what the plan priced.
    """
    predicted = predicted_round_cost_s(
        dense_tiles, sparse_edges,
        tile_size=tile_size, storage=storage, lanes=lanes,
    )
    measured = max(float(measured_s), 0.0)
    error_pct = (
        (measured - predicted) / predicted * 100.0 if predicted > 0 else 0.0
    )
    return dict(
        predicted_us=round(predicted * 1e6, 3),
        measured_us=round(measured * 1e6, 3),
        error_pct=round(error_pct, 1),
    )


def hybrid_density_threshold(
    tile_size: int, storage: str = "int8", lanes: int = 8
) -> int:
    """Break-even nnz per tile between the dense and segment paths.

    A tile with fewer nnz than this is cheaper as scattered edges than as a
    dense MMA; at or above it the dense path wins.  Clamped to [1, T²] so
    degenerate hardware constants can never route everything one way.
    Representative values: T=64/int8 ≈ 512 nnz (12.5% density),
    T=128/int8 ≈ 1536, T=128/bitpack ≈ 640.
    """
    dense = dense_tile_cost_s(tile_size, storage, lanes)
    edge = sparse_edge_cost_s()
    thr = int(dense / edge)
    return max(1, min(thr, int(tile_size) * int(tile_size)))

"""Performance modelling: roofline terms and the hybrid-routing cost model."""
from repro.perf.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    dense_tile_cost_s,
    hybrid_density_threshold,
    parse_collective_bytes,
    predicted_round_cost_s,
    roofline_from_compiled,
    round_cost_attribution,
    sparse_edge_cost_s,
)

__all__ = [
    "predicted_round_cost_s",
    "round_cost_attribution",
    "HBM_BW",
    "ICI_BW",
    "PEAK_FLOPS",
    "RooflineTerms",
    "dense_tile_cost_s",
    "hybrid_density_threshold",
    "parse_collective_bytes",
    "roofline_from_compiled",
    "sparse_edge_cost_s",
]

"""DeepFM (Guo et al., arXiv:1703.04247) — assigned config:
39 sparse fields, embed_dim=10, MLP 400-400-400, FM interaction.

Layout follows the Criteo convention: 39 categorical fields, each with its
own vocabulary, packed into ONE concatenated embedding table with per-field
offsets — the table is the model-parallel axis (row-sharded over 'model',
the classic recsys sharding).  The lookup is the hot path and runs through
the shared embedding-bag substrate (jnp.take + segment-sum; Pallas kernel in
kernels/embedding_bag.py).

FM pairwise term uses the O(N·d) identity  Σ_{i<j}⟨v_i,v_j⟩ =
½(‖Σv‖² − Σ‖v‖²).

`retrieval_score` is the retrieval_cand shape's entry: one user context
scored against 10⁶ candidate items as a single batched matvec — no loop.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn.common import MLP, mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    field_vocabs: Tuple[int, ...]      # per-field vocabulary sizes (39 fields)
    embed_dim: int = 10
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    dtype: jnp.dtype = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.field_vocabs)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.field_vocabs))

    @property
    def offsets(self) -> jnp.ndarray:
        off = jnp.cumsum(jnp.asarray((0,) + self.field_vocabs[:-1], jnp.int32))
        return off

    def param_count(self) -> int:
        n = self.total_vocab * (self.embed_dim + 1)    # embeddings + linear
        d = self.n_fields * self.embed_dim
        for o in self.mlp_dims:
            n += d * o + o
            d = o
        n += d + 1
        return n


def deepfm_init(key, cfg: DeepFMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        embed=(jax.random.normal(k1, (cfg.total_vocab, cfg.embed_dim)) * 0.01).astype(cfg.dtype),
        linear=(jax.random.normal(k2, (cfg.total_vocab,)) * 0.01).astype(cfg.dtype),
        bias=jnp.zeros((), cfg.dtype),
        mlp=mlp_init(
            k3, (cfg.n_fields * cfg.embed_dim,) + tuple(cfg.mlp_dims) + (1,),
            dtype=cfg.dtype,
        ),
    )


def _lookup(params, cfg: DeepFMConfig, fields: jnp.ndarray) -> jnp.ndarray:
    """fields: (B, F) per-field categorical ids -> (B, F, d) embeddings."""
    flat_ids = fields + cfg.offsets[None, :]
    return params["embed"][flat_ids]


def deepfm_logits(params, cfg: DeepFMConfig, fields: jnp.ndarray) -> jnp.ndarray:
    """(B, F) int32 -> (B,) logits."""
    B, F = fields.shape
    flat_ids = fields + cfg.offsets[None, :]
    v = params["embed"][flat_ids]                       # (B, F, d)
    # first-order
    lin = params["linear"][flat_ids].sum(axis=1)        # (B,)
    # FM second-order: ½(‖Σv‖² − Σ‖v‖²)
    s = v.sum(axis=1)
    fm = 0.5 * (jnp.sum(s * s, axis=-1) - jnp.sum(v * v, axis=(1, 2)))
    # deep
    deep = mlp_apply(params["mlp"], v.reshape(B, F * cfg.embed_dim), act=jax.nn.relu)[:, 0]
    return (params["bias"] + lin + fm + deep).astype(jnp.float32)


def deepfm_loss(params, cfg: DeepFMConfig, fields, labels) -> jnp.ndarray:
    """Binary cross-entropy on (B,) {0,1} labels."""
    logits = deepfm_logits(params, cfg, fields)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_score(
    params, cfg: DeepFMConfig, user_fields: jnp.ndarray, cand_ids: jnp.ndarray,
    item_field: int = 0,
) -> jnp.ndarray:
    """Score ONE user context against N candidate items (retrieval_cand).

    The candidate enters DeepFM through `item_field`; factorising the FM term
    around that field turns the sweep into a single matvec over the candidate
    embedding rows:  score(c) = const_user + ⟨v_c, Σ_user v⟩ + w_c.
    (The deep tower is user-side only in this serving mode — the standard
    two-tower deployment of FM models.)

    user_fields: (F,) with user_fields[item_field] ignored; cand_ids: (N,).
    Returns (N,) scores.
    """
    F = cfg.n_fields
    user_mask = jnp.arange(F) != item_field
    flat_ids = user_fields + cfg.offsets
    v_all = params["embed"][flat_ids]                   # (F, d)
    v_user = jnp.where(user_mask[:, None], v_all, 0)
    s_user = v_user.sum(axis=0)                         # (d,)
    fm_user = 0.5 * (jnp.sum(s_user * s_user) - jnp.sum(v_user * v_user))
    lin_user = jnp.where(user_mask, params["linear"][flat_ids], 0).sum()
    deep_in = (v_user.reshape(1, F * cfg.embed_dim))
    deep_user = mlp_apply(params["mlp"], deep_in, act=jax.nn.relu)[0, 0]
    const = params["bias"] + lin_user + fm_user + deep_user

    cand_rows = cfg.offsets[item_field] + cand_ids
    v_c = params["embed"][cand_rows]                    # (N, d)
    w_c = params["linear"][cand_rows]                   # (N,)
    return (const + v_c @ s_user + w_c).astype(jnp.float32)

"""Unified LM transformer covering all five assigned architectures.

Pure-JAX (no flax): params are plain pytrees, layers are stacked on a leading
axis and driven by `lax.scan` (keeps the HLO small enough that a 96-layer
340B config lowers in seconds — essential for the 80-cell dry-run), with
optional per-layer remat.

Feature matrix (selected per LMConfig):
  GQA / MHA, QKV bias, qk-norm, RoPE, sliding-window, squared-ReLU or SwiGLU,
  MoE (top-k, shared experts, leading dense layers), MLA, MTP head,
  chunked online-softmax attention, chunked fused cross-entropy.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    apply_rope,
    decode_attention,
    flash_attention,
    mla_decode_attention,
)
from repro.models.lm_config import LMConfig, MLAConfig, MoEConfig
from repro.models.moe import MoEMetrics, _activation, moe_ffn

Params = Dict[str, Any]


def _shard(x: jnp.ndarray, cfg: LMConfig, *parts) -> jnp.ndarray:
    """Activation sharding hint (no-op unless cfg.dp_axes set).  `parts`
    uses 'dp' as a placeholder for the batch axes tuple."""
    if cfg.dp_axes is None:
        return x
    from jax.sharding import PartitionSpec as P

    resolved = tuple(cfg.dp_axes if p == "dp" else p for p in parts)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _dense(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _init_attn(key, cfg: LMConfig) -> Params:
    D, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 12)
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p: Params = {"ln1": jnp.ones((D,), cfg.dtype)}
    if cfg.mla is not None:
        m = cfg.mla
        p.update(
            w_dq=_dense(ks[0], (D, m.q_lora_rank), cfg.dtype),
            q_norm=jnp.ones((m.q_lora_rank,), cfg.dtype),
            w_uq=_dense(ks[1], (m.q_lora_rank, H * (m.d_nope + m.d_rope)), cfg.dtype),
            w_dkv=_dense(ks[2], (D, m.kv_lora_rank + m.d_rope), cfg.dtype),
            kv_norm=jnp.ones((m.kv_lora_rank,), cfg.dtype),
            w_uk=_dense(ks[3], (H, m.d_nope, m.kv_lora_rank), cfg.dtype),
            w_uv=_dense(ks[4], (H, m.kv_lora_rank, m.d_v), cfg.dtype),
            wo=_dense(ks[5], (H * m.d_v, D), cfg.dtype, out_scale),
        )
        return p
    if cfg.fuse_qkv:
        p.update(
            wqkv=_dense(ks[0], (D, (H + 2 * Hkv) * dh), cfg.dtype),
            wo=_dense(ks[3], (H * dh, D), cfg.dtype, out_scale),
        )
    else:
        p.update(
            wq=_dense(ks[0], (D, H * dh), cfg.dtype),
            wk=_dense(ks[1], (D, Hkv * dh), cfg.dtype),
            wv=_dense(ks[2], (D, Hkv * dh), cfg.dtype),
            wo=_dense(ks[3], (H * dh, D), cfg.dtype, out_scale),
        )
    if cfg.qkv_bias:
        p.update(
            bq=jnp.zeros((H * dh,), cfg.dtype),
            bk=jnp.zeros((Hkv * dh,), cfg.dtype),
            bv=jnp.zeros((Hkv * dh,), cfg.dtype),
        )
    if cfg.qk_norm:
        p.update(
            q_normh=jnp.ones((dh,), cfg.dtype), k_normh=jnp.ones((dh,), cfg.dtype)
        )
    return p


def _init_dense_ffn(key, cfg: LMConfig, d_ff: int) -> Params:
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p = {
        "ln2": jnp.ones((D,), cfg.dtype),
        "w2": _dense(ks[1], (d_ff, D), cfg.dtype, out_scale),
    }
    if cfg.act == "swiglu" and cfg.fuse_gate:
        p["w13"] = _dense(ks[0], (D, 2 * d_ff), cfg.dtype)
    else:
        p["w1"] = _dense(ks[0], (D, d_ff), cfg.dtype)
        if cfg.act == "swiglu":
            p["w3"] = _dense(ks[2], (D, d_ff), cfg.dtype)
    return p


def _init_moe_ffn(key, cfg: LMConfig) -> Params:
    D, e = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 8)
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p = {
        "ln2": jnp.ones((D,), cfg.dtype),
        "router": _dense(ks[0], (D, e.n_experts), jnp.float32),
        "we1": _dense(ks[1], (e.n_experts, D, e.d_expert), cfg.dtype),
        "we2": _dense(ks[2], (e.n_experts, e.d_expert, D), cfg.dtype, out_scale),
    }
    if cfg.act == "swiglu":
        p["we3"] = _dense(ks[3], (e.n_experts, D, e.d_expert), cfg.dtype)
    if e.n_shared:
        d_sh = e.d_expert * e.n_shared
        p["ws1"] = _dense(ks[4], (D, d_sh), cfg.dtype)
        p["ws2"] = _dense(ks[5], (d_sh, D), cfg.dtype, out_scale)
        if cfg.act == "swiglu":
            p["ws3"] = _dense(ks[6], (D, d_sh), cfg.dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key: jax.Array, cfg: LMConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 4)
    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.moe else 0
    n_dense = cfg.n_layers - n_moe

    def layer(k, is_moe):
        ka, kf = jax.random.split(k)
        p = {"attn": _init_attn(ka, cfg)}
        p["ffn"] = _init_moe_ffn(kf, cfg) if is_moe else _init_dense_ffn(kf, cfg, cfg.d_ff)
        return p

    params: Params = {
        "embed": _dense(keys[0], (cfg.vocab, cfg.d_model), cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = _dense(keys[1], (cfg.d_model, cfg.vocab), cfg.dtype)
    if n_dense:
        params["dense_layers"] = _stack(
            [layer(keys[2 + i], False) for i in range(n_dense)]
        )
    if n_moe:
        params["moe_layers"] = _stack(
            [layer(keys[2 + n_dense + i], True) for i in range(n_moe)]
        )
    if cfg.mtp:
        km = jax.random.split(keys[-1], 3)
        params["mtp"] = {
            "proj": _dense(km[0], (2 * cfg.d_model, cfg.d_model), cfg.dtype),
            "norm_h": jnp.ones((cfg.d_model,), cfg.dtype),
            "norm_e": jnp.ones((cfg.d_model,), cfg.dtype),
            "block": layer(km[1], False),
        }
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _attn_forward(
    p: Params, cfg: LMConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (residual update, kv-tensors-for-prefill)."""
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, p["ln1"])
    if cfg.mla is not None:
        m = cfg.mla
        cq = rms_norm(h @ p["w_dq"], p["q_norm"])
        q = (cq @ p["w_uq"]).reshape(B, S, H, m.d_nope + m.d_rope)
        q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope :]
        dkv = h @ p["w_dkv"]
        ckv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_norm"])
        k_rope = dkv[..., m.kv_lora_rank :][:, :, None, :]       # (B,S,1,dr)
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
        k_nope = jnp.einsum("bsr,hdr->bshd", ckv, p["w_uk"])
        v = jnp.einsum("bsr,hrv->bshv", ckv, p["w_uv"])
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.d_rope))], axis=-1
        )
        o = flash_attention(
            q_full, k_full, v,
            causal=True, window=cfg.window, chunk=cfg.attn_chunk,
            scale=(m.d_nope + m.d_rope) ** -0.5, unroll=cfg.unroll,
        )
        kv = {"ckv": ckv, "krope": k_rope[:, :, 0, :]}
        return o.reshape(B, S, H * m.d_v) @ p["wo"], kv

    if cfg.fuse_qkv:
        qkv = h @ p["wqkv"]
        q, k, v = jnp.split(qkv, [H * dh, (H + Hkv) * dh], axis=-1)
    else:
        q = h @ p["wq"]
        k = h @ p["wk"]
        v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_normh"])
        k = rms_norm(k, p["k_normh"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(
        q, k, v, causal=True, window=cfg.window, chunk=cfg.attn_chunk,
        unroll=cfg.unroll,
    )
    return o.reshape(B, S, H * dh) @ p["wo"], {"k": k, "v": v}


def _ffn_forward(
    p: Params, cfg: LMConfig, x: jnp.ndarray, is_moe: bool
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (residual update, aux loss)."""
    B, S, D = x.shape
    h = rms_norm(x, p["ln2"])
    if is_moe:
        out, metrics = moe_ffn(p, h.reshape(B * S, D), cfg.moe, cfg.act)
        return out.reshape(B, S, D), metrics.aux_loss
    if cfg.act == "swiglu" and cfg.fuse_gate:
        h13 = h @ p["w13"]
        h1, h3 = jnp.split(h13, 2, axis=-1)
    else:
        h1 = h @ p["w1"]
        h3 = h @ p["w3"] if cfg.act == "swiglu" else None
    return _activation(h1, h3, cfg.act) @ p["w2"], jnp.float32(0.0)


def _make_layer_fn(cfg: LMConfig, is_moe: bool, collect_kv: bool = False):
    def layer_fn(x_pos, layer_params):
        x, positions = x_pos
        # Megatron-style sequence parallelism on the layer boundary: the
        # remat-saved carry is stored S-sharded over 'model' (16× less HBM);
        # XLA inserts the all-gather before attention / reduce-scatter after.
        if cfg.dp_axes is not None and x.shape[1] % 8 == 0:
            x = _shard(x, cfg, "dp", "model", None)
        upd, kv = _attn_forward(layer_params["attn"], cfg, x, positions)
        x = x + upd
        upd, aux = _ffn_forward(layer_params["ffn"], cfg, x, is_moe)
        x = x + upd
        if cfg.dp_axes is not None and x.shape[1] % 8 == 0:
            # constrain the returned carry as well: MoE combine outputs would
            # otherwise re-replicate S and the remat save balloons 'model'×
            x = _shard(x, cfg, "dp", "model", None)
        ys = (aux, kv) if collect_kv else aux
        return (x, positions), ys

    if cfg.remat:
        if cfg.remat_policy == "dots":
            layer_fn = jax.checkpoint(
                layer_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        else:
            layer_fn = jax.checkpoint(layer_fn)
    return layer_fn


def forward(
    params: Params,
    cfg: LMConfig,
    tokens: jnp.ndarray,                 # (B, S) int32
    *,
    collect_kv: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Returns (hidden (B,S,D), total aux loss, kv caches or None)."""
    B, S = tokens.shape
    x = _shard(params["embed"][tokens], cfg, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux_total = jnp.float32(0.0)
    kvs = []
    for name, is_moe in (("dense_layers", False), ("moe_layers", True)):
        if name not in params:
            continue
        fn = _make_layer_fn(cfg, is_moe, collect_kv)
        (x_pos, ys) = jax.lax.scan(
            fn, (x, positions), params[name], unroll=cfg.unroll
        )
        x, positions = x_pos
        if collect_kv:
            aux, kv = ys
            kvs.append(kv)
        else:
            aux = ys
        aux_total = aux_total + jnp.sum(aux)
    h = rms_norm(x, params["final_norm"])
    return h, aux_total, (kvs if collect_kv else None)


# --------------------------------------------------------------------------
# loss (chunked fused cross-entropy — never materialise (B,S,V))
# --------------------------------------------------------------------------

def _head_weight(params: Params) -> jnp.ndarray:
    return params["head"] if "head" in params else params["embed"].T


def chunked_xent(
    h: jnp.ndarray,            # (B, S, D)
    head: jnp.ndarray,         # (D, V)
    targets: jnp.ndarray,      # (B, S) int32; -1 = ignore
    chunk: int,
    cfg: Optional[LMConfig] = None,
) -> jnp.ndarray:
    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # ragged (e.g. MTP's S−1): pad with ignored targets
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    n = S // chunk
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)      # (n, B, chunk, D)
    tc = targets.reshape(B, n, chunk).swapaxes(0, 1)

    def step(carry, xs):
        tot, cnt = carry
        hx, tx = xs
        logits = (hx @ head).astype(jnp.float32)       # (B, chunk, V)
        if cfg is not None:
            # keep logits vocab-sharded: logsumexp partial-reduces per shard
            logits = _shard(logits, cfg, "dp", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(tx, 0)[..., None], axis=-1
        )[..., 0]
        valid = tx >= 0
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.int32(0)), (hc, tc),
        unroll=(cfg.unroll if cfg is not None else False),
    )
    return tot / jnp.maximum(cnt, 1)


def mtp_loss(
    params: Params, cfg: LMConfig, h: jnp.ndarray, tokens: jnp.ndarray
) -> jnp.ndarray:
    """DeepSeek-V3 multi-token prediction (depth 1): position t predicts t+2."""
    p = params["mtp"]
    B, S, D = h.shape
    e_next = params["embed"][tokens[:, 1:]]            # (B, S-1, D)
    m = jnp.concatenate(
        [rms_norm(h[:, :-1], p["norm_h"]), rms_norm(e_next, p["norm_e"])], axis=-1
    ) @ p["proj"]                                      # (B, S-1, D)
    positions = jnp.broadcast_to(
        jnp.arange(S - 1, dtype=jnp.int32), (B, S - 1)
    )
    upd, _ = _attn_forward(p["block"]["attn"], cfg, m, positions)
    m = m + upd
    upd, _ = _ffn_forward(p["block"]["ffn"], cfg, m, False)
    m = m + upd
    m = rms_norm(m, params["final_norm"])
    # position i of m sees tokens ≤ i and embed of token i+1 → predicts i+2
    targets = jnp.pad(
        tokens[:, 2:], ((0, 0), (0, 1)), constant_values=-1
    )                                                  # (B, S-1)
    return chunked_xent(m, _head_weight(params), targets, cfg.loss_chunk, cfg)


def lm_loss(
    params: Params, cfg: LMConfig, tokens: jnp.ndarray, targets: jnp.ndarray,
    *, aux_weight: float = 0.01, mtp_weight: float = 0.3,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    h, aux, _ = forward(params, cfg, tokens)
    loss = chunked_xent(h, _head_weight(params), targets, cfg.loss_chunk, cfg)
    metrics = {"xent": loss, "aux": aux}
    total = loss + aux_weight * aux
    if cfg.mtp:
        lm = mtp_loss(params, cfg, h, tokens)
        metrics["mtp"] = lm
        total = total + mtp_weight * lm
    return total, metrics


# --------------------------------------------------------------------------
# decode (serve_step) — one token against a cache
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DecodeCache:
    """Per-layer stacked KV cache.  GQA: k/v (L,B,C,Hkv,dh); MLA: ckv
    (L,B,C,r) + krope (L,B,C,dr).  `pos` is the absolute decode position;
    windowed archs use a ring buffer of C=min(window, max_len) slots."""
    data: Dict[str, jnp.ndarray]
    pos: jnp.ndarray            # () int32
    length: int = dataclasses.field(metadata=dict(static=True))  # ring size


def init_decode_cache(cfg: LMConfig, batch: int, max_len: int) -> DecodeCache:
    C = min(cfg.window, max_len) if cfg.window else max_len
    L = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        data = {
            "ckv": jnp.zeros((L, batch, C, m.kv_lora_rank), cfg.dtype),
            "krope": jnp.zeros((L, batch, C, m.d_rope), cfg.dtype),
        }
    else:
        data = {
            "k": jnp.zeros((L, batch, C, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
            "v": jnp.zeros((L, batch, C, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
        }
    return DecodeCache(data=data, pos=jnp.int32(0), length=C)


def _decode_attn(
    p: Params, cfg: LMConfig, x: jnp.ndarray, cache_l: Dict, pos: jnp.ndarray,
    ring: int,
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, D) single token.  Returns (residual update, updated layer cache)."""
    B, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, p["ln1"])
    idx = pos % ring                       # ring slot for this absolute position
    pos1 = pos[None]                       # (1,) — rope positions for new token
    # valid slots: everything already written, including the one written now
    valid = jnp.broadcast_to(
        jnp.arange(ring) <= jnp.minimum(pos, ring - 1), (B, ring)
    )

    if cfg.mla is not None:
        m = cfg.mla
        cq = rms_norm(h @ p["w_dq"], p["q_norm"])
        q = (cq @ p["w_uq"]).reshape(B, H, m.d_nope + m.d_rope)
        q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope :]
        dkv = h @ p["w_dkv"]
        ckv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_norm"])
        k_rope = dkv[..., m.kv_lora_rank :]
        q_rope = apply_rope(q_rope[:, None], pos1[None, :], cfg.rope_theta)[:, 0]
        k_rope = apply_rope(
            k_rope[:, None, None, :], pos1[None, :], cfg.rope_theta
        )[:, 0, 0]
        ckv_c = jax.lax.dynamic_update_index_in_dim(cache_l["ckv"], ckv, idx, 1)
        kr_c = jax.lax.dynamic_update_index_in_dim(cache_l["krope"], k_rope, idx, 1)
        o = mla_decode_attention(
            q_nope, q_rope, ckv_c, kr_c, valid, p["w_uk"], p["w_uv"],
            scale=(m.d_nope + m.d_rope) ** -0.5,
        )
        return o.reshape(B, H * m.d_v) @ p["wo"], {"ckv": ckv_c, "krope": kr_c}

    if cfg.fuse_qkv:
        qkv = h @ p["wqkv"]
        q, k, v = jnp.split(qkv, [H * dh, (H + Hkv) * dh], axis=-1)
    else:
        q = h @ p["wq"]
        k = h @ p["wk"]
        v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, H, dh)
    k = k.reshape(B, Hkv, dh)
    v = v.reshape(B, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_normh"])
        k = rms_norm(k, p["k_normh"])
    q = apply_rope(q[:, None], pos1[None, :], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos1[None, :], cfg.rope_theta)[:, 0]
    k_c = jax.lax.dynamic_update_index_in_dim(cache_l["k"], k, idx, 1)
    v_c = jax.lax.dynamic_update_index_in_dim(cache_l["v"], v, idx, 1)
    o = decode_attention(q, k_c, v_c, valid)
    return o.reshape(B, H * dh) @ p["wo"], {"k": k_c, "v": v_c}


def decode_step(
    params: Params, cfg: LMConfig, cache: DecodeCache, tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, DecodeCache]:
    """One decode step: tokens (B,) -> (logits (B,V), updated cache)."""
    x = params["embed"][tokens]
    pos = cache.pos
    layer_stacks = []
    for name, is_moe in (("dense_layers", False), ("moe_layers", True)):
        if name not in params:
            continue
        layer_stacks.append((name, is_moe, params[name]))

    # split the stacked cache between the (dense, moe) stacks
    offsets = []
    off = 0
    for name, is_moe, stack in layer_stacks:
        L_stack = jax.tree.leaves(stack)[0].shape[0]
        offsets.append((off, L_stack))
        off += L_stack

    new_cache_parts = {k: [] for k in cache.data}
    for (name, is_moe, stack), (off, L_stack) in zip(layer_stacks, offsets):
        cache_slice = {
            k: v[off : off + L_stack] for k, v in cache.data.items()
        }

        def layer_fn(x_, xs, _is_moe=is_moe):
            layer_params, cache_l = xs
            upd, new_cache_l = _decode_attn(
                layer_params["attn"], cfg, x_, cache_l, pos, cache.length
            )
            x_ = x_ + upd
            h = rms_norm(x_, layer_params["ffn"]["ln2"])
            if _is_moe:
                out, _ = moe_ffn(
                    layer_params["ffn"], h, cfg.moe, cfg.act
                )
            else:
                if cfg.act == "swiglu" and cfg.fuse_gate:
                    h13 = h @ layer_params["ffn"]["w13"]
                    h1, h3 = jnp.split(h13, 2, axis=-1)
                else:
                    h1 = h @ layer_params["ffn"]["w1"]
                    h3 = (h @ layer_params["ffn"]["w3"]
                          if cfg.act == "swiglu" else None)
                out = _activation(h1, h3, cfg.act) @ layer_params["ffn"]["w2"]
            return x_ + out, new_cache_l

        x, updated = jax.lax.scan(
            layer_fn, x, (stack, cache_slice), unroll=cfg.unroll
        )
        for k_name in new_cache_parts:
            new_cache_parts[k_name].append(updated[k_name])

    data = {
        k: jnp.concatenate(v, axis=0) if len(v) > 1 else v[0]
        for k, v in new_cache_parts.items()
    }
    h = rms_norm(x, params["final_norm"])
    logits = (h @ _head_weight(params)).astype(jnp.float32)
    return logits, DecodeCache(data=data, pos=pos + 1, length=cache.length)


def prefill(
    params: Params, cfg: LMConfig, tokens: jnp.ndarray, max_len: int
) -> Tuple[jnp.ndarray, DecodeCache]:
    """Prefill S tokens, build the decode cache.  Returns (last logits, cache)."""
    B, S = tokens.shape
    h, _, kvs = forward(params, cfg, tokens, collect_kv=True)
    cache = init_decode_cache(cfg, B, max_len)
    C = cache.length
    take = min(S, C)
    # ring slot for absolute position p is p % C — keep prefill and decode
    # consistent so the first decode step (pos=S) lands in slot S % C.
    slots = (jnp.arange(S - take, S) % C).astype(jnp.int32)
    data = {}
    # kv tensors come back (L_stack, B, S, ...) per stack; concat stacks
    for k_name in cache.data:
        parts = [kv[k_name] for kv in kvs]
        full = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        sl = full[:, :, S - take :]
        buf = cache.data[k_name]
        data[k_name] = buf.at[:, :, slots].set(sl.astype(buf.dtype))
    logits = (h[:, -1] @ _head_weight(params)).astype(jnp.float32)
    return logits, DecodeCache(data=data, pos=jnp.int32(S), length=C)

"""Attention substrate: RoPE, online-softmax (flash-style) chunked attention,
GQA/MQA grouping, sliding windows, MLA (latent) attention, and decode paths.

The training/prefill attention is an **online-softmax scan over KV chunks**
(the FlashAttention recurrence expressed in jnp + `lax.scan`): memory is
O(S·chunk) instead of O(S²), every matmul is MXU-shaped, and XLA fuses the
rescale into the accumulator update.  `attn_chunk` is a §Perf hill-climb
lever.

Decode is a single-token einsum over the cache — no flash machinery needed.
MLA decode uses the *absorbed-weight* latent path: scores and values are
computed directly against the (kv_lora + d_rope) latent cache, which is the
entire point of MLA's cache compression.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(d: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, d) with d even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# flash-style chunked attention (train / prefill)
# --------------------------------------------------------------------------

def flash_attention(
    q: jnp.ndarray,             # (B, S, H, dq)
    k: jnp.ndarray,             # (B, S, Hkv, dq)
    v: jnp.ndarray,             # (B, S, Hkv, dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 512,
    scale: Optional[float] = None,
    unroll: bool = False,
) -> jnp.ndarray:
    """Online-softmax attention, O(S·chunk) memory.  Returns (B, S, H, dv).

    GQA stays *grouped*: q is reshaped to (B, S, Hkv, G, dq) and scores are
    computed against un-replicated K/V — repeated-KV materialisation would
    multiply HBM traffic by G for nothing.
    """
    B, S, H, dq = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    dv = v.shape[-1]
    scale = scale if scale is not None else dq ** -0.5
    chunk = min(chunk, S)
    # ragged sequences (e.g. the MTP block's S−1) pad up to a chunk multiple;
    # padded keys are masked off, padded queries sliced away at the end.
    S_real = S
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    n_chunks = S // chunk

    qg = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, G, dq)
    kc = k.astype(jnp.float32).reshape(B, n_chunks, chunk, Hkv, dq)
    vc = v.astype(jnp.float32).reshape(B, n_chunks, chunk, Hkv, dv)
    q_pos = jnp.arange(S)

    def step(carry, inputs):
        m, l, acc = carry                    # (B,S,Hkv,G), same, (B,S,Hkv,G,dv)
        j, k_j, v_j = inputs                 # k_j (B,chunk,Hkv,dq)
        s = jnp.einsum("bshgd,bchd->bshgc", qg, k_j)      # (B,S,Hkv,G,chunk)
        k_pos = j * chunk + jnp.arange(chunk)
        mask = jnp.broadcast_to(k_pos[None, :] < S_real, (S, chunk))
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p, v_j
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, Hkv, G), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), dtype=jnp.float32)
    acc0 = jnp.zeros((B, S, Hkv, G, dv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        unroll=unroll,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, S, H, dv).astype(q.dtype)
    return out[:, :S_real] if pad else out


# --------------------------------------------------------------------------
# decode attention (one new token against a cache)
# --------------------------------------------------------------------------

def decode_attention(
    q: jnp.ndarray,             # (B, H, dq) — the single new query
    k_cache: jnp.ndarray,       # (B, C, Hkv, dq)
    v_cache: jnp.ndarray,       # (B, C, Hkv, dv)
    valid: jnp.ndarray,         # (B, C) bool — which cache slots are live
    *,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Returns (B, H, dv).  Works for full, windowed (ring) and MQA caches."""
    B, H, dq = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else dq ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, dq)
    s = jnp.einsum("bhgd,bchd->bhgc", qg, k_cache.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, -1).astype(q.dtype)


def mla_decode_attention(
    q_nope: jnp.ndarray,        # (B, H, d_nope)
    q_rope: jnp.ndarray,        # (B, H, d_rope) — rope already applied
    ckv_cache: jnp.ndarray,     # (B, C, r)   latent KV cache
    krope_cache: jnp.ndarray,   # (B, C, d_rope) shared rope key cache
    valid: jnp.ndarray,         # (B, C)
    w_uk: jnp.ndarray,          # (H, d_nope, r)  up-projection K
    w_uv: jnp.ndarray,          # (H, r, d_v)     up-projection V
    *,
    scale: float,
) -> jnp.ndarray:
    """Absorbed-weight MLA decode: attend in the latent space.

    q_lat = q_nope · W_uk   →  scores = q_lat · c_kv + q_rope · k_rope
    ctx_lat = softmax · c_kv →  out_h = ctx_lat · W_uv
    Per-token work is O(C·(r + d_rope)) per head instead of
    O(C·(d_nope + d_rope)) with *materialised* K/V of size H·(d_nope+d_v) —
    the cache shrinks by H·(d_nope+d_v)/(r+d_rope) ≈ 14× for DeepSeek-V3.
    """
    q_lat = jnp.einsum(
        "bhd,hdr->bhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32)
    )
    s = jnp.einsum("bhr,bcr->bhc", q_lat, ckv_cache.astype(jnp.float32))
    s += jnp.einsum(
        "bhd,bcd->bhc", q_rope.astype(jnp.float32), krope_cache.astype(jnp.float32)
    )
    s = jnp.where(valid[:, None, :], s * scale, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhc,bcr->bhr", p, ckv_cache.astype(jnp.float32))
    out = jnp.einsum("bhr,hrv->bhv", ctx, w_uv.astype(jnp.float32))
    return out.astype(q_nope.dtype)

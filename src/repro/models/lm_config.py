"""Configuration dataclasses for the unified LM transformer family.

One config type covers all five assigned LM architectures:
  qwen1.5-0.5b   dense, MHA (GQA kv=16), QKV bias, SwiGLU
  qwen3-0.6b     dense, GQA kv=8, qk-norm, SwiGLU
  nemotron-4     dense, GQA kv=8, squared-ReLU
  mixtral-8x22b  MoE 8e top-2, GQA kv=8, sliding-window attention
  deepseek-v3    MoE 1 shared + 256 routed top-8, MLA, MTP, 3 dense lead layers
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # expert FFN hidden dim
    n_shared: int = 0              # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25  # tokens/expert buffer = avg·cf (GShard-style)
    router: str = "softmax"        # softmax (Mixtral) | sigmoid (DeepSeek aux-free)
    shard_experts: bool = False    # legacy toggle (buf_pspec is authoritative)
    buf_pspec: tuple | None = None # resolved PartitionSpec parts for the
                                   # (E, C, D) dispatch buffers, e.g.
                                   # ('model', ('data',), None) expert-parallel
                                   # or (None, ('data',), 'model') when E is
                                   # not divisible by the model axis (Mixtral)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    d_nope: int = 128              # per-head non-rope q/k dim
    d_rope: int = 64               # per-head rope dim (k_rope is shared)
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    qk_norm: bool = False
    act: str = "swiglu"            # swiglu | relu2 (squared ReLU, Nemotron)
    rope_theta: float = 1_000_000.0
    window: Optional[int] = None   # sliding-window attention (Mixtral)
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0        # leading dense layers before MoE stack
    mla: Optional[MLAConfig] = None
    mtp: bool = False              # multi-token-prediction head (DeepSeek)
    tie_embeddings: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    # performance knobs (hill-climb levers; see EXPERIMENTS.md §Perf)
    attn_chunk: int = 512          # KV-chunk for the online-softmax attention
    loss_chunk: int = 1024         # sequence chunk for the fused xent loss
    remat: bool = True             # activation checkpointing per layer
    remat_policy: str = "full"     # full | dots (save matmul outputs,
                                   # recompute elementwise — §Perf H-C iter 4)
    # dry-run / distribution knobs (set by cell builders, not by hand):
    unroll: bool = False           # fully unroll scans — XLA cost_analysis
                                   # counts loop bodies ONCE, so rolled scans
                                   # undercount flops/bytes/collectives by the
                                   # trip count; the dry-run must unroll.
    dp_axes: Optional[tuple] = None  # activation sharding: batch-axis names;
                                     # enables with_sharding_constraint hints
    fuse_qkv: bool = False         # single (D, (H+2Hkv)·dh) projection — one
                                   # read of h instead of three (§Perf H-C)
    fuse_gate: bool = False        # swiglu w1‖w3 fused the same way

    @property
    def d_q_total(self) -> int:
        if self.mla is not None:
            return self.n_heads * (self.mla.d_nope + self.mla.d_rope)
        return self.n_heads * self.d_head

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS in §Roofline)."""
        D, V, L = self.d_model, self.vocab, self.n_layers
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D
        per_layer_attn = 0
        if self.mla is not None:
            m = self.mla
            per_layer_attn += D * m.q_lora_rank + m.q_lora_rank * self.d_q_total
            per_layer_attn += D * (m.kv_lora_rank + m.d_rope)
            per_layer_attn += m.kv_lora_rank * self.n_heads * (m.d_nope + m.d_v)
            per_layer_attn += self.n_heads * m.d_v * D
        else:
            per_layer_attn += D * self.n_heads * self.d_head        # q
            per_layer_attn += 2 * D * self.n_kv_heads * self.d_head  # k, v
            per_layer_attn += self.n_heads * self.d_head * D        # o
        def ffn_params(dff):
            mult = 3 if self.act == "swiglu" else 2
            return mult * D * dff
        n_moe = L - self.n_dense_layers if self.moe else 0
        n_dense = L - n_moe
        n += L * per_layer_attn + n_dense * ffn_params(self.d_ff)
        if self.moe:
            e = self.moe
            per_moe = (e.n_experts + e.n_shared) * ffn_params(e.d_expert) / (3 if self.act == "swiglu" else 2) * (3 if self.act == "swiglu" else 2)
            per_moe = (e.n_experts + e.n_shared) * ffn_params(e.d_expert)
            per_moe += D * e.n_experts  # router
            n += n_moe * per_moe
        n += 2 * L * D + D  # norms
        if self.mtp:
            n += 2 * D * D + per_layer_attn + ffn_params(self.d_ff) + 3 * D
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        mult = 3 if self.act == "swiglu" else 2
        per_expert = mult * self.d_model * e.d_expert
        inactive = (self.n_layers - self.n_dense_layers) * (
            (e.n_experts - e.top_k) * per_expert
        )
        return int(self.param_count() - inactive)

"""MACE — higher-order equivariant message passing (Batatia et al.,
arXiv:2206.07697).  Assigned config: 2 layers, d_hidden=128 channels,
l_max=2, correlation order 3, n_rbf=8, E(3)-ACE product basis.

Implementation notes (DESIGN.md §8):
* Features are dicts {l: (N, 2l+1, C)} of real-spherical-harmonic irreps.
* Equivariant bilinear couplings use **real Gaunt tensors** (∫ Y Y Y dΩ),
  computed once at import by Gauss–Legendre × uniform-φ quadrature (exact for
  l ≤ 2 products), plus the Levi-Civita tensor for the parity-odd 1⊗1→1
  (cross-product) path.  Each coupling is normalised to unit Frobenius norm.
* Interaction: A_i[l3] = Σ_j Σ_paths R_p(r_ij) · (Y_l1(r̂_ij) ⊗ h_j[l2])_l3 —
  radial Bessel basis (8) with polynomial cutoff, per-path per-channel MLP
  weights.
* ACE product basis: B2 = (A ⊗ A), B3 = (B2 ⊗ A) — correlation order 3 —
  with per-path channel weights, linearly mixed into the message.
* Readout: invariant (l=0) channel → per-node energy → Σ (rotation-invariant
  by construction; property-tested).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import MLP, mlp_apply, mlp_init

LMAX = 2
Feats = Dict[int, jnp.ndarray]


# --------------------------------------------------------------------------
# real spherical harmonics (unit vectors), l ≤ 2
# --------------------------------------------------------------------------

def real_sph_harm(unit: jnp.ndarray) -> Dict[int, jnp.ndarray]:
    """unit: (..., 3) unit vectors -> {l: (..., 2l+1)} orthonormal RSH."""
    x, y, z = unit[..., 0], unit[..., 1], unit[..., 2]
    c0 = 0.28209479177387814           # 1/(2 sqrt(pi))
    c1 = 0.4886025119029199
    c2a = 1.0925484305920792
    c2b = 0.31539156525252005
    c2c = 0.5462742152960396
    y0 = jnp.stack([jnp.full_like(x, c0)], axis=-1)
    y1 = jnp.stack([c1 * y, c1 * z, c1 * x], axis=-1)
    y2 = jnp.stack(
        [
            c2a * x * y,
            c2a * y * z,
            c2b * (3 * z * z - 1.0),
            c2a * x * z,
            c2c * (x * x - y * y),
        ],
        axis=-1,
    )
    return {0: y0, 1: y1, 2: y2}


def _np_sph(l: int, pts: np.ndarray) -> np.ndarray:
    x, y, z = pts[..., 0], pts[..., 1], pts[..., 2]
    if l == 0:
        return np.stack([np.full_like(x, 0.28209479177387814)], axis=-1)
    if l == 1:
        c = 0.4886025119029199
        return np.stack([c * y, c * z, c * x], axis=-1)
    c2a, c2b, c2c = 1.0925484305920792, 0.31539156525252005, 0.5462742152960396
    return np.stack(
        [c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1), c2a * x * z,
         c2c * (x * x - y * y)], axis=-1)


@lru_cache(maxsize=1)
def coupling_tensors() -> List[Tuple[int, int, int, np.ndarray]]:
    """All non-zero equivariant couplings (l1, l2, l3, K) for l ≤ LMAX.

    Gaunt tensors from quadrature (parity-even) + Levi-Civita for (1,1,1).
    Each K has unit Frobenius norm.
    """
    # Gauss-Legendre in cosθ (16 pts) × uniform φ (32 pts): exact for the
    # ≤ degree-6 polynomial integrands arising from l ≤ 2 triples.
    xs, wx = np.polynomial.legendre.leggauss(16)
    phis = np.linspace(0, 2 * np.pi, 32, endpoint=False)
    wphi = 2 * np.pi / len(phis)
    ct = xs[:, None]
    st = np.sqrt(1 - ct ** 2)
    pts = np.stack(
        [
            (st * np.cos(phis)[None, :]),
            (st * np.sin(phis)[None, :]),
            np.broadcast_to(ct, (16, len(phis))),
        ],
        axis=-1,
    ).reshape(-1, 3)
    w = (wx[:, None] * wphi * np.ones((1, len(phis)))).reshape(-1)

    Y = {l: _np_sph(l, pts) for l in range(LMAX + 1)}
    out: List[Tuple[int, int, int, np.ndarray]] = []
    for l1 in range(LMAX + 1):
        for l2 in range(LMAX + 1):
            for l3 in range(LMAX + 1):
                if not (abs(l1 - l2) <= l3 <= l1 + l2):
                    continue
                K = np.einsum(
                    "pm,pn,pk,p->mnk", Y[l1], Y[l2], Y[l3], w
                )
                if np.max(np.abs(K)) < 1e-9:
                    continue
                out.append((l1, l2, l3, (K / np.linalg.norm(K)).astype(np.float32)))
    # parity-odd 1 ⊗ 1 → 1: the cross product, missing from Gaunt
    eps = np.zeros((3, 3, 3), np.float32)
    for a, b, c, s in [(0, 1, 2, 1), (1, 2, 0, 1), (2, 0, 1, 1),
                       (1, 0, 2, -1), (2, 1, 0, -1), (0, 2, 1, -1)]:
        eps[a, b, c] = s
    out.append((1, 1, 1, eps / np.linalg.norm(eps)))
    return out


def couple(x: jnp.ndarray, y: jnp.ndarray, K: np.ndarray) -> jnp.ndarray:
    """Channel-wise equivariant product: (…,2l1+1,C) ⊗ (…,2l2+1,C) -> (…,2l3+1,C)."""
    return jnp.einsum("...mc,...nc,mnk->...kc", x, y, jnp.asarray(K))


# --------------------------------------------------------------------------
# radial basis
# --------------------------------------------------------------------------

def bessel_rbf(r: jnp.ndarray, n_rbf: int, r_cut: float) -> jnp.ndarray:
    """Sinc-Bessel radial basis with smooth polynomial cutoff. r: (E,)."""
    rs = jnp.maximum(r, 1e-6)[:, None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * rs / r_cut) / rs
    u = jnp.clip(r / r_cut, 0, 1)[:, None]
    fcut = 1 - 10 * u ** 3 + 15 * u ** 4 - 6 * u ** 5   # C² polynomial cutoff
    return basis * fcut


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------

def _n_paths_interaction() -> List[Tuple[int, int, int]]:
    return [(l1, l2, l3) for (l1, l2, l3, _) in coupling_tensors()]


def mace_init(
    key,
    d_in: int,
    channels: int = 128,
    n_layers: int = 2,
    n_rbf: int = 8,
    r_cut: float = 5.0,
):
    cts = coupling_tensors()
    n_paths = len(cts)
    ks = jax.random.split(key, 4 * n_layers + 2)
    layers = []
    for t in range(n_layers):
        k0, k1, k2, k3 = ks[4 * t : 4 * t + 4]
        layers.append(
            dict(
                radial=mlp_init(k0, (n_rbf, 64, n_paths * channels)),
                # per-path channel mixers for the ACE products
                w_b2=jax.random.normal(k1, (n_paths, channels)) * (channels ** -0.5),
                w_b3=jax.random.normal(k2, (n_paths, channels)) * (channels ** -0.5),
                # message mix (A ‖ B2 ‖ B3 -> C) and residual, per l
                mix={
                    l: jax.random.normal(jax.random.fold_in(k3, l), (3 * channels, channels))
                    * ((3 * channels) ** -0.5)
                    for l in range(LMAX + 1)
                },
                res={
                    l: jax.random.normal(jax.random.fold_in(k3, 10 + l), (channels, channels))
                    * (channels ** -0.5)
                    for l in range(LMAX + 1)
                },
            )
        )
    return dict(
        embed=mlp_init(ks[-2], (d_in, channels)),
        layers=layers,
        readout=mlp_init(ks[-1], (channels, 16, 1)),
    )


def _interaction(
    layer, h: Feats, Y: Dict[int, jnp.ndarray], rbf, senders, receivers, mask, n
) -> Feats:
    """A-features: radial-weighted (Y ⊗ h_j) couplings, scattered to nodes."""
    cts = coupling_tensors()
    C = h[0].shape[-1]
    R = mlp_apply(layer["radial"], rbf).reshape(rbf.shape[0], len(cts), C)
    A: Feats = {}
    w_edge = mask.astype(jnp.float32)[:, None, None]
    for p, (l1, l2, l3, K) in enumerate(cts):
        if l2 not in h:
            continue
        y_e = Y[l1][:, :, None]                        # (E, 2l1+1, 1)
        h_e = h[l2][senders]                           # (E, 2l2+1, C)
        m = couple(y_e * jnp.ones_like(h_e[:, :1]), h_e, K)  # (E, 2l3+1, C)
        m = m * R[:, p][:, None, :] * w_edge
        A[l3] = A.get(l3, 0) + jax.ops.segment_sum(m, receivers, num_segments=n)
    return A


def _ace_products(layer, A: Feats) -> Tuple[Feats, Feats]:
    """Correlation-2 and -3 symmetric products of the A basis."""
    cts = coupling_tensors()
    B2: Feats = {}
    for p, (l1, l2, l3, K) in enumerate(cts):
        if l1 in A and l2 in A:
            B2[l3] = B2.get(l3, 0) + couple(A[l1], A[l2], K) * layer["w_b2"][p]
    B3: Feats = {}
    for p, (l1, l2, l3, K) in enumerate(cts):
        if l1 in B2 and l2 in A:
            B3[l3] = B3.get(l3, 0) + couple(B2[l1], A[l2], K) * layer["w_b3"][p]
    return B2, B3


def mace_apply(
    params, feats, coords, senders, receivers, mask,
    *, n_rbf: int = 8, r_cut: float = 5.0, **_,
):
    """feats: (N, d_in), coords: (N, 3).  Returns (h dict, total energy).
    n_rbf / r_cut are static (close over them or use functools.partial)."""
    n = feats.shape[0]
    C = params["embed"].ws[-1].shape[-1]
    h: Feats = {0: mlp_apply(params["embed"], feats)[:, None, :]}

    rel = coords[receivers] - coords[senders]
    safe = jnp.where(mask, 1.0, 0.0)
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    unit = rel / jnp.maximum(r, 1e-6)[:, None]
    Y = real_sph_harm(unit)
    rbf = bessel_rbf(r, n_rbf, r_cut) * safe[:, None]

    for layer in params["layers"]:
        A = _interaction(layer, h, Y, rbf, senders, receivers, mask, n)
        # ensure every l is present for the product basis
        for l in range(LMAX + 1):
            A.setdefault(l, jnp.zeros((n, 2 * l + 1, C)))
        B2, B3 = _ace_products(layer, A)
        h_new: Feats = {}
        for l in range(LMAX + 1):
            parts = jnp.concatenate(
                [A[l], B2.get(l, jnp.zeros_like(A[l])), B3.get(l, jnp.zeros_like(A[l]))],
                axis=-1,
            )                                           # (N, 2l+1, 3C)
            m = jnp.einsum("nmc,cd->nmd", parts, layer["mix"][l])
            res = (
                jnp.einsum("nmc,cd->nmd", h[l], layer["res"][l]) if l in h else 0
            )
            h_new[l] = m + res
        h = h_new

    node_energy = mlp_apply(params["readout"], h[0][:, 0, :])   # (N, 1)
    return h, node_energy.sum()

"""EGNN — E(n)-Equivariant GNN (Satorras et al., arXiv:2102.09844).

Assigned config: 4 layers, d_hidden=64, E(n) equivariance.

    m_ij  = φ_e(h_i, h_j, ‖x_i − x_j‖²)
    x_i'  = x_i + (1/deg_i) Σ_j (x_i − x_j) · φ_x(m_ij)
    h_i'  = φ_h(h_i, Σ_j m_ij)

Invariant features interact only through squared distances; coordinate
updates are linear combinations of relative vectors ⇒ rotation/translation
equivariance holds by construction (property-tested in tests/test_gnn.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gnn.common import MLP, mlp_apply, mlp_init


def egnn_init(key, d_in: int, d_hidden: int = 64, n_layers: int = 4, n_out: int = 1):
    ks = jax.random.split(key, 3 * n_layers + 2)
    layers = []
    d = d_in
    for i in range(n_layers):
        layers.append(
            dict(
                phi_e=mlp_init(ks[3 * i], (2 * d + 1, d_hidden, d_hidden)),
                phi_x=mlp_init(ks[3 * i + 1], (d_hidden, d_hidden, 1)),
                phi_h=mlp_init(ks[3 * i + 2], (d + d_hidden, d_hidden, d_hidden)),
            )
        )
        d = d_hidden
    return dict(layers=layers, head=mlp_init(ks[-1], (d_hidden, n_out)))


def egnn_apply(params, h, x, senders, receivers, mask, **_):
    """h: (N, d_in) invariants, x: (N, 3) coordinates.
    Returns (h', x', per-graph energy = Σ head(h'))."""
    n = h.shape[0]
    w = mask.astype(h.dtype)
    deg = jax.ops.segment_sum(w, receivers, num_segments=n)
    inv_deg = (1.0 / jnp.maximum(deg, 1.0))[:, None]

    for layer in params["layers"]:
        rel = x[receivers] - x[senders]                       # (E, 3)
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m = mlp_apply(
            layer["phi_e"],
            jnp.concatenate([h[receivers], h[senders], d2], axis=-1),
        )
        m = m * w[:, None]
        # tanh-bounded coefficient and distance-normalised direction keep the
        # 4-layer coordinate recursion stable (the paper's "C" normalisation)
        coef = jnp.tanh(mlp_apply(layer["phi_x"], m))          # (E, 1)
        rel_n = rel / (jnp.sqrt(d2) + 1.0)
        dx = jax.ops.segment_sum(rel_n * coef * w[:, None], receivers, num_segments=n)
        x = x + dx * inv_deg
        agg = jax.ops.segment_sum(m, receivers, num_segments=n)
        h = mlp_apply(layer["phi_h"], jnp.concatenate([h, agg], axis=-1))
    energy = mlp_apply(params["head"], h).sum()
    return h, x, energy

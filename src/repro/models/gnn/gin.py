"""GIN (Xu et al., arXiv:1810.00826) — assigned config gin-tu:
5 layers, d_hidden=64, sum aggregator, learnable ε.

h_i' = MLP((1+ε)·h_i + Σ_{j∈N(i)} h_j)

The sum aggregation is exactly A × H, so GIN supports two backends:
  'segment' — edge gather + segment_sum (the CC path)
  'tiled'   — the paper's BSR tiled SpMM through the tc_spmv Pallas kernel,
              with the feature matrix as the multi-lane RHS.  This is the
              matrix-RHS generalisation of TC-MIS phase ② and drives the MXU
              at full width (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.gnn.common import MLP, gather_scatter_sum, mlp_apply, mlp_init


def gin_init(key, d_in: int, d_hidden: int = 64, n_layers: int = 5, n_out: int = 7):
    ks = jax.random.split(key, n_layers + 1)
    layers = []
    d = d_in
    for i in range(n_layers):
        layers.append(
            dict(
                mlp=mlp_init(ks[i], (d, d_hidden, d_hidden)),
                eps=jnp.zeros(()),
            )
        )
        d = d_hidden
    return dict(layers=layers, head=mlp_init(ks[-1], (d_hidden, n_out)))


def gin_apply(
    params,
    h: jnp.ndarray,            # (N, d_in)
    senders: jnp.ndarray,
    receivers: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    tiled=None,                # BlockTiledGraph for backend='tiled'
    backend: str = "segment",
):
    """Returns (node embeddings (N, d_hidden), graph logits via head)."""
    n = h.shape[0]
    for layer in params["layers"]:
        if backend == "tiled":
            from repro.core.spmv import spmv_tiled
            from repro.core.tiling import pack_vertex_vector

            pad = tiled.n_padded - n
            hp = jnp.pad(h, ((0, pad), (0, 0))) if pad else h
            agg = spmv_tiled(tiled, hp.astype(jnp.float32), backend="pallas")[:n]
            agg = agg.astype(h.dtype)
        else:
            agg = gather_scatter_sum(h, senders, receivers, mask, n)
        h = mlp_apply(layer["mlp"], (1.0 + layer["eps"]) * h + agg)
    return h, mlp_apply(params["head"], h)

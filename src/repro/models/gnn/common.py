"""Shared GNN substrate: MLPs, masked segment reductions, message passing.

Message passing is expressed over raw edge arrays (senders, receivers, mask)
rather than the Graph object so the same `apply` works for full graphs,
vmapped molecule batches, and sampled-subgraph trees.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp


class MLP(NamedTuple):
    ws: Tuple[jnp.ndarray, ...]
    bs: Tuple[jnp.ndarray, ...]


def mlp_init(key, dims: Sequence[int], dtype=jnp.float32) -> MLP:
    ks = jax.random.split(key, len(dims) - 1)
    ws, bs = [], []
    for k, (i, o) in zip(ks, zip(dims[:-1], dims[1:])):
        ws.append((jax.random.normal(k, (i, o)) * (2.0 / i) ** 0.5).astype(dtype))
        bs.append(jnp.zeros((o,), dtype))
    return MLP(tuple(ws), tuple(bs))


def mlp_apply(p: MLP, x: jnp.ndarray, act=jax.nn.silu, final_act=False) -> jnp.ndarray:
    n = len(p.ws)
    for i, (w, b) in enumerate(zip(p.ws, p.bs)):
        x = x @ w + b
        if i < n - 1 or final_act:
            x = act(x)
    return x


def segment_mean(x, segment_ids, num_segments, mask=None):
    if mask is not None:
        x = jnp.where(mask[..., None], x, 0)
        ones = mask.astype(x.dtype)
    else:
        ones = jnp.ones(x.shape[:-1], x.dtype)
    s = jax.ops.segment_sum(x, segment_ids, num_segments=num_segments)
    cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
    return s / jnp.maximum(cnt, 1.0)[..., None]


def gather_scatter_sum(h, senders, receivers, mask, n_nodes):
    """Σ_{j∈N(i)} h_j — the canonical message-passing primitive."""
    msg = jnp.where(mask[:, None], h[senders], 0)
    return jax.ops.segment_sum(msg, receivers, num_segments=n_nodes)


def degrees_from_edges(receivers, mask, n_nodes):
    return jax.ops.segment_sum(
        mask.astype(jnp.float32), receivers, num_segments=n_nodes
    )

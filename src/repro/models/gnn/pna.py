"""PNA — Principal Neighbourhood Aggregation (Corso et al., arXiv:2004.05718).

Assigned config: 4 layers, d_hidden=75, aggregators {mean, max, min, std},
scalers {identity, amplification, attenuation}.

Per layer: messages m_ij = MLP([h_i ‖ h_j]); the 4 aggregations of m over
N(i) are scaled by the 3 degree scalers (12 concatenated views) and mixed by
a linear layer.  δ (the average log-degree) is computed from the batch, as in
the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gnn.common import MLP, mlp_apply, mlp_init, degrees_from_edges

_NEG = -1e9


def pna_init(key, d_in: int, d_hidden: int = 75, n_layers: int = 4, n_out: int = 7):
    ks = jax.random.split(key, 2 * n_layers + 2)
    layers = []
    d = d_in
    for i in range(n_layers):
        layers.append(
            dict(
                msg=mlp_init(ks[2 * i], (2 * d, d_hidden)),
                mix=mlp_init(ks[2 * i + 1], (12 * d_hidden + d, d_hidden)),
            )
        )
        d = d_hidden
    return dict(layers=layers, head=mlp_init(ks[-1], (d_hidden, n_out)))


def _aggregate(m, receivers, mask, n):
    """mean/max/min/std over incoming messages; masked slots neutral."""
    w = mask[:, None].astype(m.dtype)
    s = jax.ops.segment_sum(m * w, receivers, num_segments=n)
    cnt = jax.ops.segment_sum(w[:, 0], receivers, num_segments=n)
    cnt1 = jnp.maximum(cnt, 1.0)[:, None]
    mean = s / cnt1
    mx = jax.ops.segment_max(jnp.where(mask[:, None], m, _NEG), receivers, num_segments=n)
    mx = jnp.where(cnt[:, None] > 0, mx, 0.0)
    mn = -jax.ops.segment_max(jnp.where(mask[:, None], -m, _NEG), receivers, num_segments=n)
    mn = jnp.where(cnt[:, None] > 0, mn, 0.0)
    sq = jax.ops.segment_sum(m * m * w, receivers, num_segments=n)
    var = jnp.maximum(sq / cnt1 - mean * mean, 0.0)
    std = jnp.sqrt(var + 1e-8)
    return mean, mx, mn, std, cnt


def pna_apply(params, h, senders, receivers, mask, **_):
    n = h.shape[0]
    deg = degrees_from_edges(receivers, mask, n)
    delta = jnp.mean(jnp.log1p(deg))
    log_deg = jnp.log1p(deg)[:, None]
    s_amp = log_deg / jnp.maximum(delta, 1e-6)        # amplification
    s_att = jnp.maximum(delta, 1e-6) / jnp.maximum(log_deg, 1e-6)  # attenuation
    s_att = jnp.where(deg[:, None] > 0, s_att, 0.0)

    for layer in params["layers"]:
        pair = jnp.concatenate([h[receivers], h[senders]], axis=-1)
        m = mlp_apply(layer["msg"], pair)
        mean, mx, mn, std, _ = _aggregate(m, receivers, mask, n)
        aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)      # (N, 4d)
        scaled = jnp.concatenate(
            [aggs, aggs * s_amp, aggs * s_att], axis=-1
        )                                                          # (N, 12d)
        h = mlp_apply(layer["mix"], jnp.concatenate([scaled, h], axis=-1))
    return h, mlp_apply(params["head"], h)

"""GNN model family (assigned archs: egnn, gin-tu, pna, mace).

All four run on the shared edge-index `segment_sum/max` substrate — the same
scatter/segment layer the MIS core's CC path uses (DESIGN.md §8).  GIN's
sum-aggregation additionally supports the paper's BSR tiled-SpMM backend
(`backend='tiled'`), where A × H runs through the tc_spmv Pallas kernel with
the feature matrix as a multi-lane RHS.
"""
from repro.models.gnn.common import MLP, mlp_apply, mlp_init, segment_mean
from repro.models.gnn.gin import gin_init, gin_apply
from repro.models.gnn.pna import pna_init, pna_apply
from repro.models.gnn.egnn import egnn_init, egnn_apply
from repro.models.gnn.mace import mace_init, mace_apply

__all__ = [
    "MLP", "mlp_init", "mlp_apply", "segment_mean",
    "gin_init", "gin_apply", "pna_init", "pna_apply",
    "egnn_init", "egnn_apply", "mace_init", "mace_apply",
]

"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

Dispatch is **sort-based** (no (N, E) one-hot cumsum — that would materialise
N·E ints): flatten the (N, k) assignments, argsort by expert id, and read each
slot's rank within its expert straight off the sorted order.  Tokens ranked
past the capacity are dropped (GShard semantics; DeepSeek-V3 is dropless —
the capacity_factor knob + aux-free router bias approximate it, noted in
DESIGN.md).

Sharding: expert tensors are laid out (E, ...) and sharded on the 'model'
axis (expert parallelism); the scatter from token-sharded activations into
the (E, C, D) buffer is XLA's to lower — on TPU it becomes the expected
all-to-all pair around the expert GEMMs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm_config import MoEConfig


class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray       # load-balance loss (scalar)
    drop_frac: jnp.ndarray      # fraction of assignments dropped (scalar)


def _activation(h1, h3, act: str):
    if act == "swiglu":
        return jax.nn.silu(h1) * h3
    if act == "relu2":
        r = jax.nn.relu(h1)
        return r * r
    raise ValueError(act)


def expert_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    """Static per-expert buffer size (rounded up to a lane multiple)."""
    avg = n_tokens * cfg.top_k / cfg.n_experts
    cap = int(avg * cfg.capacity_factor) + 1
    return ((cap + 7) // 8) * 8


def route_topk(
    logits: jnp.ndarray, cfg: MoEConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(N, E) logits -> (weights (N,k), experts (N,k) int32, probs (N,E))."""
    if cfg.router == "softmax":
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        topv, topi = jax.lax.top_k(probs, cfg.top_k)
        w = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    elif cfg.router == "sigmoid":  # DeepSeek-V3 aux-loss-free style gates
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        topv, topi = jax.lax.top_k(scores, cfg.top_k)
        w = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        raise ValueError(cfg.router)
    return w, topi.astype(jnp.int32), probs


def load_balance_loss(probs: jnp.ndarray, experts: jnp.ndarray, n_experts: int):
    """Switch-style aux loss: E · Σ_e f_e · P_e."""
    N = probs.shape[0]
    f = jnp.zeros((n_experts,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    f = f / (N * experts.shape[-1])
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def moe_ffn(
    params: dict,
    x: jnp.ndarray,             # (N, D) flattened tokens
    cfg: MoEConfig,
    act: str,
) -> Tuple[jnp.ndarray, MoEMetrics]:
    """Top-k routed expert FFN + optional shared experts.  Returns (N, D)."""
    N, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = expert_capacity(N, cfg)

    if cfg.buf_pspec is not None:
        from jax.sharding import PartitionSpec as P

        # the (B·S, D) flatten crosses the (batch×seq)-sharded axes; decide
        # the token layout HERE or GSPMD may replicate everything downstream
        x = jax.lax.with_sharding_constraint(x, P(cfg.buf_pspec[1], None))
    w, experts, probs = route_topk(x @ params["router"].astype(x.dtype), cfg)

    # ---- sort-based slot assignment --------------------------------------
    flat_e = experts.reshape(-1)                       # (N*k,)
    order = jnp.argsort(flat_e, stable=True)           # tokens grouped by expert
    sorted_e = flat_e[order]
    # rank within expert = position in sorted run
    start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=flat_e.dtype))
    rank_sorted = jnp.arange(N * k, dtype=jnp.int32) - start[sorted_e]
    rank = jnp.zeros((N * k,), jnp.int32).at[order].set(rank_sorted)
    keep = (rank < C).reshape(N, k)                    # capacity drop
    slot = jnp.clip(rank, 0, C - 1).reshape(N, k)
    e_nk = flat_e.reshape(N, k)

    # ---- dispatch: GATHER-based (invert the sort permutation) ------------
    # GSPMD lowers a scatter into an expert-sharded buffer by REPLICATING the
    # (N, D) updates on every device (28 GiB/dev at DeepSeek scale).  The
    # gather formulation keeps tokens D-sharded instead: token_for_slot[e,c]
    # names the token occupying slot c of expert e, so the dispatch is a pure
    # gather with D as a pass-through (shardable) dimension; the subsequent
    # buf_pspec constraint is the all-to-all that moves tokens to experts.
    end = jnp.searchsorted(sorted_e, jnp.arange(1, E + 1, dtype=flat_e.dtype))
    c_idx = jnp.arange(C, dtype=jnp.int32)
    pos = start[:, None] + c_idx[None, :]              # (E, C) sorted index
    slot_valid = pos < jnp.minimum(end, start + C)[:, None]
    tok_for_slot = order[jnp.clip(pos, 0, N * k - 1)] // k
    if cfg.buf_pspec is not None:
        # tokens: N replicated, D sharded on 'model' — the gather's indexed
        # dim must be unsharded, the big dim rides along sharded
        x_disp = jax.lax.with_sharding_constraint(x, P(None, "model"))
    else:
        x_disp = x
    buf = x_disp[tok_for_slot] * slot_valid[..., None].astype(x.dtype)
    if cfg.buf_pspec is not None:
        buf = jax.lax.with_sharding_constraint(buf, P(*cfg.buf_pspec))

    # ---- expert GEMMs (E-parallel) ---------------------------------------
    h1 = jnp.einsum("ecd,edf->ecf", buf, params["we1"].astype(x.dtype))
    if act == "swiglu":
        h3 = jnp.einsum("ecd,edf->ecf", buf, params["we3"].astype(x.dtype))
    else:
        h3 = None
    h = _activation(h1, h3, act)
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["we2"].astype(x.dtype))
    if cfg.buf_pspec is not None:
        from jax.sharding import PartitionSpec as P

        y_buf = jax.lax.with_sharding_constraint(y_buf, P(*cfg.buf_pspec))

    # ---- combine: k gathers with (E, C) unsharded, D pass-through --------
    if cfg.buf_pspec is not None:
        # reshard expert-major results to D-sharded (the return all-to-all)
        y_buf = jax.lax.with_sharding_constraint(y_buf, P(None, None, "model"))
    out = jnp.zeros((N, D), x.dtype)
    for j in range(k):
        y_j = y_buf[e_nk[:, j], slot[:, j]]            # (N, D) — D sharded
        y_j = jnp.where(keep[:, j : j + 1], y_j, 0)
        out = out + y_j * w[:, j : j + 1].astype(x.dtype)
    if cfg.buf_pspec is not None:
        out = jax.lax.with_sharding_constraint(out, P(cfg.buf_pspec[1], None))

    # ---- shared experts (DeepSeek): dense FFN on every token -------------
    if "ws1" in params:
        s1 = x @ params["ws1"].astype(x.dtype)
        s3 = x @ params["ws3"].astype(x.dtype) if act == "swiglu" else None
        out = out + _activation(s1, s3, act) @ params["ws2"].astype(x.dtype)

    metrics = MoEMetrics(
        aux_loss=load_balance_loss(probs, experts, E),
        drop_frac=1.0 - keep.mean(),
    )
    return out, metrics

"""Explicit expert-parallel MoE via shard_map — the §Perf H-B follow-up.

The pjit/GSPMD formulation (models/moe.py) cannot shard a gather's indexed
dimension, leaving an N·k·cf·D dispatch volume D-sharded only and a chain of
gather/reshard collectives (EXPERIMENTS.md §Perf H-B).  This module is the
explicit-communication alternative:

* tokens are sharded over the data axis and REPLICATED over the expert
  ('model') axis — which every attention/FFN activation already is in the
  tensor-parallel layout;
* each (data, model=j) device selects, LOCALLY, the tokens routed to its own
  E/n_shards experts, runs the expert FFN, and contributes outputs for its
  local token shard;
* the ONLY collective is one `psum` of the (N_loc, D) output over the expert
  axis per MoE layer — ≈ N_loc·D·2 bytes vs the GSPMD chain's measured
  ~3.6 GiB/dev/layer on DeepSeek-V3 (≈ 8× reduction, EXPERIMENTS.md).

Semantics: capacity is enforced PER (token-shard, expert) pair — the GShard
convention — whereas moe_ffn ranks globally.  With non-binding capacity the
two are numerically equal (tested on an 8-device mesh in
tests/test_distributed.py); under pressure the shard_map version drops more
uniformly across senders.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.lm_config import MoEConfig
from repro.models.moe import _activation, expert_capacity, route_topk


def _slot_assignment(experts: jnp.ndarray, E: int, C: int):
    """Sort-based slot assignment (same algorithm as moe_ffn, local scope).

    experts: (N, k) int32 -> (keep (N,k), slot (N,k), tok_for_slot (E,C),
    slot_valid (E,C))."""
    N, k = experts.shape
    flat_e = experts.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=flat_e.dtype))
    end = jnp.searchsorted(sorted_e, jnp.arange(1, E + 1, dtype=flat_e.dtype))
    rank_sorted = jnp.arange(N * k, dtype=jnp.int32) - start[sorted_e]
    rank = jnp.zeros((N * k,), jnp.int32).at[order].set(rank_sorted)
    keep = (rank < C).reshape(N, k)
    slot = jnp.clip(rank, 0, C - 1).reshape(N, k)
    c_idx = jnp.arange(C, dtype=jnp.int32)
    pos = start[:, None] + c_idx[None, :]
    slot_valid = pos < jnp.minimum(end, start + C)[:, None]
    tok_for_slot = order[jnp.clip(pos, 0, N * k - 1)] // k
    return keep, slot, tok_for_slot, slot_valid


def moe_ffn_shardmap(
    params: dict,
    x: jnp.ndarray,              # (N, D) tokens
    cfg: MoEConfig,
    act: str,
    mesh: Mesh,
    *,
    token_axis="data",
    expert_axis: str = "model",
) -> jnp.ndarray:
    """Expert-parallel MoE with explicit communication.  Returns (N, D)."""
    E, k = cfg.n_experts, cfg.top_k
    n_shards = mesh.shape[expert_axis]
    assert E % n_shards == 0, "expert count must divide the expert axis"
    E_loc = E // n_shards
    N, D = x.shape
    n_tok = mesh.shape[token_axis] if isinstance(token_axis, str) else 1
    C_loc = expert_capacity(N // max(n_tok, 1), cfg)
    has_w3 = "we3" in params
    shared = {kk: params[kk] for kk in ("ws1", "ws2", "ws3") if kk in params}

    def body(x_l, router, we1, we3, we2, ws):
        j = jax.lax.axis_index(expert_axis)
        w, experts, _ = route_topk(x_l @ router.astype(x_l.dtype), cfg)
        keep, slot, tok_for_slot, slot_valid = _slot_assignment(
            experts, E, C_loc
        )
        # ---- select my experts' slots, gather their tokens locally --------
        lo = j * E_loc
        tok_loc = jax.lax.dynamic_slice_in_dim(tok_for_slot, lo, E_loc, 0)
        val_loc = jax.lax.dynamic_slice_in_dim(slot_valid, lo, E_loc, 0)
        buf = x_l[tok_loc] * val_loc[..., None].astype(x_l.dtype)  # (E_loc,C,D)
        # ---- expert FFN ----------------------------------------------------
        h1 = jnp.einsum("ecd,edf->ecf", buf, we1.astype(x_l.dtype))
        h3 = (jnp.einsum("ecd,edf->ecf", buf, we3.astype(x_l.dtype))
              if has_w3 else None)
        y_buf = jnp.einsum(
            "ecf,efd->ecd", _activation(h1, h3, act), we2.astype(x_l.dtype)
        )
        # ---- combine my experts' contributions to my token shard ----------
        out = jnp.zeros_like(x_l)
        for kk in range(k):
            e = experts[:, kk]
            own = (e >= lo) & (e < lo + E_loc) & keep[:, kk]
            y = y_buf[jnp.clip(e - lo, 0, E_loc - 1), slot[:, kk]]
            out = out + jnp.where(own[:, None], y, 0) * w[:, kk:kk + 1].astype(
                x_l.dtype
            )
        # the ONLY collective: combine expert shards' partial outputs
        out = jax.lax.psum(out, expert_axis)
        # shared experts run token-parallel (replicated weights)
        if ws:
            s1 = x_l @ ws["ws1"].astype(x_l.dtype)
            s3 = (x_l @ ws["ws3"].astype(x_l.dtype)
                  if act == "swiglu" and "ws3" in ws else None)
            out = out + _activation(s1, s3, act) @ ws["ws2"].astype(x_l.dtype)
        return out

    e_spec = P(expert_axis, None, None)
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(token_axis, None),                   # tokens
            P(),                                   # router replicated
            e_spec,                                # we1 expert-sharded
            e_spec if has_w3 else P(),
            e_spec,
            jax.tree.map(lambda _: P(), shared),   # shared experts replicated
        ),
        out_specs=P(token_axis, None),
        check_vma=False,
    )(
        x,
        params["router"],
        params["we1"],
        params["we3"] if has_w3 else jnp.zeros((), x.dtype),
        params["we2"],
        shared,
    )

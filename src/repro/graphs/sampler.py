"""Fixed-fanout neighbour sampling (the `minibatch_lg` shape's requirement).

Layered fixed-fanout sampling à la GraphSAGE: for a batch of seed vertices we
draw ``fanout[0]`` neighbours each, then ``fanout[1]`` neighbours of those, …
Fixed fanout (sampling with replacement, masked for isolated vertices) keeps
every shape static, so the whole sampler jits and the sampled step compiles
once for the lifetime of a training run.

The sampler holds the CSR arrays on device; sampling one minibatch is a pure
function of (rng key, seed ids) — re-sampling under a restored checkpoint with
the same key is bitwise reproducible, which the fault-tolerance tests rely on.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import Graph, build_csr


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    """Layered fixed-fanout sample.

    layers[k] has shape (batch, fanout[0], …, fanout[k-1]) of *global* vertex
    ids; masks[k] marks slots backed by a real neighbour.  layers[0] is the
    seed batch itself.
    """
    layers: Tuple[jnp.ndarray, ...]
    masks: Tuple[jnp.ndarray, ...]

    @property
    def batch(self) -> int:
        return int(self.layers[0].shape[0])


class NeighborSampler:
    """Uniform neighbour sampler over a CSR graph."""

    def __init__(self, g: Graph, fanout: Sequence[int]):
        indptr, indices = build_csr(g)
        self.indptr = jnp.asarray(indptr, dtype=jnp.int32)
        self.indices = jnp.asarray(indices, dtype=jnp.int32)
        self.fanout = tuple(int(f) for f in fanout)
        self.n_nodes = g.n_nodes

    @partial(jax.jit, static_argnums=0)
    def sample(self, key: jax.Array, seeds: jnp.ndarray) -> SampledSubgraph:
        layers = [seeds]
        masks = [jnp.ones(seeds.shape, dtype=bool)]
        frontier = seeds
        fmask = masks[0]
        for hop, f in enumerate(self.fanout):
            key, sub = jax.random.split(key)
            start = self.indptr[frontier]
            deg = self.indptr[frontier + 1] - start
            # one uniform draw per slot, with replacement
            u = jax.random.randint(
                sub, frontier.shape + (f,), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
            )
            safe_deg = jnp.maximum(deg, 1)
            offs = u % safe_deg[..., None]
            nbr = self.indices[jnp.minimum(start[..., None] + offs, self.indices.shape[0] - 1)]
            mask = jnp.broadcast_to(
                (deg[..., None] > 0) & fmask[..., None], nbr.shape
            )
            nbr = jnp.where(mask, nbr, 0)
            layers.append(nbr)
            masks.append(mask)
            frontier, fmask = nbr, mask
        return SampledSubgraph(layers=tuple(layers), masks=tuple(masks))


def aggregate_mean(
    child_feats: jnp.ndarray, child_mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked mean over the innermost fanout axis: (…, F, D) -> (…, D)."""
    w = child_mask[..., None].astype(child_feats.dtype)
    s = (child_feats * w).sum(axis=-2)
    cnt = jnp.maximum(w.sum(axis=-2), 1.0)
    return s / cnt


def tree_edges(sub: SampledSubgraph):
    """Flatten a layered sample into (global_ids, senders, receivers, mask).

    Node slots are the union of all layers (seeds first); each sampled child
    slot contributes one *directed* edge child→parent — exactly the
    information flow of sampled-GraphSAGE training.  The flat form lets every
    GNN `apply` (which consumes raw edge arrays) run unchanged on minibatches.
    """
    ids = [sub.layers[0].reshape(-1)]
    masks = [sub.masks[0].reshape(-1)]
    offsets = [0]
    total = ids[0].shape[0]
    for lay, msk in zip(sub.layers[1:], sub.masks[1:]):
        offsets.append(total)
        ids.append(lay.reshape(-1))
        masks.append(msk.reshape(-1))
        total += lay.size
    global_ids = jnp.concatenate(ids)
    node_mask = jnp.concatenate(masks)

    senders, receivers, emask = [], [], []
    for k in range(1, len(sub.layers)):
        child = sub.layers[k]
        fan = child.shape[-1]
        n_parents = int(np.prod(child.shape[:-1]))
        child_slots = offsets[k] + jnp.arange(n_parents * fan, dtype=jnp.int32)
        parent_slots = offsets[k - 1] + jnp.repeat(
            jnp.arange(n_parents, dtype=jnp.int32), fan
        )
        senders.append(child_slots)
        receivers.append(parent_slots)
        emask.append(sub.masks[k].reshape(-1))
    return (
        global_ids,
        node_mask,
        jnp.concatenate(senders),
        jnp.concatenate(receivers),
        jnp.concatenate(emask),
    )

"""Core graph container.

Graphs are stored twice, because the two MIS execution paths want different
layouts (this mirrors the paper's CSR-for-CC vs tiles-for-TC split):

* **edge list** (``senders``/``receivers``): the substrate for the
  ``segment_max`` / ``segment_sum`` path (ECL-MIS baseline, GNN message
  passing).  Both directions of every undirected edge are materialised so a
  single gather+segment pass sees the full neighbourhood.
* **CSR** (``indptr``/``indices``): host-side build artefact, used by the
  neighbour sampler and the BSR tile builder.

Padding convention: edge arrays may be padded to a static size with the
sentinel ``sender == n_nodes`` pointing at a dummy node slot; every consumer
masks on ``senders < n_nodes``.  This keeps shapes static under jit and lets
shards be rectangular.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """A static-shape undirected graph on device.

    Attributes:
      senders:   (E_pad,) int32 — source of each directed half-edge.
      receivers: (E_pad,) int32 — destination of each directed half-edge.
      n_nodes:   static int — number of real vertices (dummy slot excluded).
      n_edges:   static int — number of real directed half-edges (≤ E_pad).
    """
    senders: jnp.ndarray
    receivers: jnp.ndarray
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def e_pad(self) -> int:
        return int(self.senders.shape[0])

    @property
    def edge_mask(self) -> jnp.ndarray:
        """(E_pad,) bool — True for real edges."""
        return jnp.arange(self.e_pad, dtype=jnp.int32) < self.n_edges

    def degrees(self) -> jnp.ndarray:
        """(n_nodes,) int32 — undirected degree of every vertex."""
        ones = self.edge_mask.astype(jnp.int32)
        return jax.ops.segment_sum(ones, self.receivers, num_segments=self.n_nodes + 1)[
            : self.n_nodes
        ]


def _symmetrize(src: np.ndarray, dst: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Drop self loops, dedupe, and materialise both directions."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    key = lo * n + hi
    _, uniq = np.unique(key, return_index=True)
    lo, hi = lo[uniq], hi[uniq]
    s = np.concatenate([lo, hi])
    r = np.concatenate([hi, lo])
    order = np.lexsort((r, s))
    return s[order].astype(np.int32), r[order].astype(np.int32)


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    *,
    pad_to: Optional[int] = None,
) -> Graph:
    """Build an undirected :class:`Graph` from a (possibly noisy) edge list.

    Self-loops are dropped, duplicates removed, both directions materialised,
    and half-edges sorted by sender (so CSR falls out of a cumsum).
    """
    s, r = _symmetrize(src, dst, n_nodes)
    n_edges = int(s.shape[0])
    e_pad = n_edges if pad_to is None else max(pad_to, n_edges)
    if e_pad > n_edges:
        pad = np.full(e_pad - n_edges, n_nodes, dtype=np.int32)
        s = np.concatenate([s, pad])
        r = np.concatenate([r, pad])
    return Graph(
        senders=jnp.asarray(s),
        receivers=jnp.asarray(r),
        n_nodes=int(n_nodes),
        n_edges=n_edges,
    )


def build_csr(g: Graph) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side CSR (indptr, indices) from the (sender-sorted) edge list."""
    s = np.asarray(g.senders)[: g.n_edges]
    r = np.asarray(g.receivers)[: g.n_edges]
    order = np.argsort(s, kind="stable")
    s, r = s[order], r[order]
    counts = np.bincount(s, minlength=g.n_nodes)
    indptr = np.zeros(g.n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, r.astype(np.int32)


def pad_graph(g: Graph, e_pad: int) -> Graph:
    """Return a copy padded (with the dummy-node sentinel) to ``e_pad`` edges.

    ``e_pad`` below the current padding but at or above ``n_edges`` *shrinks*
    the pad: every row past ``n_edges`` is sentinel-only, so slicing it off is
    lossless.  This is what lets zero-edge graphs round-trip through
    ``from_edges(pad_to=...)`` → ``pad_graph`` (the serving batcher re-buckets
    pad sizes and must accept empty and singleton graphs unchanged).
    """
    if e_pad < g.n_edges:
        raise ValueError(f"pad {e_pad} < real edges {g.n_edges}")
    if e_pad == g.e_pad:
        return g
    if e_pad < g.e_pad:
        return Graph(
            senders=g.senders[:e_pad],
            receivers=g.receivers[:e_pad],
            n_nodes=g.n_nodes,
            n_edges=g.n_edges,
        )
    extra = e_pad - g.e_pad
    pad = jnp.full((extra,), g.n_nodes, dtype=jnp.int32)
    return Graph(
        senders=jnp.concatenate([g.senders, pad]),
        receivers=jnp.concatenate([g.receivers, pad]),
        n_nodes=g.n_nodes,
        n_edges=g.n_edges,
    )


def to_networkx(g: Graph):
    """Small-graph escape hatch for oracle comparisons in tests."""
    import networkx as nx

    s = np.asarray(g.senders)[: g.n_edges]
    r = np.asarray(g.receivers)[: g.n_edges]
    G = nx.Graph()
    G.add_nodes_from(range(g.n_nodes))
    G.add_edges_from(zip(s.tolist(), r.tolist()))
    return G

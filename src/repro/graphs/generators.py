"""Synthetic graph generators, structurally matched to the paper's suite.

SuiteSparse is not available offline, so each of the paper's eight graphs
(Table 1) gets a generator that reproduces its *structure class* — degree
distribution shape and |E|/|V| — at any scale:

  G1 amazon0302        co-purchase      -> preferential_attachment (m≈4)
  G2 roadNet-PA        road network     -> grid2d (avg deg ≈ 2.7)
  G3 delaunay_n19      planar mesh      -> delaunay_like (deg ≈ 5.7, regular)
  G4 wiki-Talk         power-law hubs   -> powerlaw (skewed, |E|/|V| ≈ 4.0)
  G5 web-Google        web crawl        -> web_like (clustered power-law)
  G6 web-BerkStan      dense web crawl  -> web_like (higher m)
  G7 soc-LiveJournal1  social           -> preferential_attachment (m≈7)
  G8 kron_g500-logn21  Kronecker        -> rmat (Graph500 a,b,c,d)

Wall-clock benchmarks run the *reduced* scale (CPU-tractable); the dry-run /
roofline path uses the *full* |V|,|E| through shape specs only (no
allocation).  Generators are numpy, deterministic in ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.graphs.graph import Graph, from_edges


# --------------------------------------------------------------------------
# generators (all return Graph; all deterministic in seed)
# --------------------------------------------------------------------------

def grid2d(n_rows: int, n_cols: int, seed: int = 0, diag_frac: float = 0.05) -> Graph:
    """Road-network stand-in: 2-D lattice with a sprinkle of diagonal shortcuts.

    Average degree ≈ 2·(2 + diag_frac) / ... ≈ 2.7 for small diag_frac, matching
    roadNet-PA's |E|/|V| = 2.7 (counting undirected edges once).
    """
    n = n_rows * n_cols
    idx = np.arange(n).reshape(n_rows, n_cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    edges = [right, down]
    if diag_frac > 0:
        rng = np.random.default_rng(seed)
        n_diag = int(diag_frac * n)
        rr = rng.integers(0, n_rows - 1, n_diag)
        cc = rng.integers(0, n_cols - 1, n_diag)
        edges.append(np.stack([idx[rr, cc], idx[rr + 1, cc + 1]], axis=1))
    e = np.concatenate(edges, axis=0)
    return from_edges(e[:, 0], e[:, 1], n)


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> Graph:
    """R-MAT / Kronecker generator with Graph500 defaults (kron_g500 stand-in)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    for i in range(scale):
        bit = 1 << i
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > ab
        dst_bit = np.where(src_bit, r2 > c_norm, r2 > a_norm)
        src |= bit * src_bit
        dst |= bit * dst_bit
    # permute vertex ids so locality is not an artefact of generation order
    perm = rng.permutation(n)
    return from_edges(perm[src], perm[dst], n)


def powerlaw(n: int, avg_deg: float = 4.0, exponent: float = 2.1, seed: int = 0) -> Graph:
    """Configuration-model power-law graph (wiki-Talk stand-in: hubby, skewed)."""
    rng = np.random.default_rng(seed)
    # Zipf-ish degree sequence, clipped so the config model terminates.
    raw = rng.zipf(exponent, n).astype(np.float64)
    raw = np.minimum(raw, np.sqrt(n))
    deg = np.maximum(1, np.round(raw * (avg_deg * n) / raw.sum())).astype(np.int64)
    stubs = np.repeat(np.arange(n), deg)
    rng.shuffle(stubs)
    if stubs.shape[0] % 2:
        stubs = stubs[:-1]
    half = stubs.shape[0] // 2
    return from_edges(stubs[:half], stubs[half:], n)


def delaunay_like(n: int, seed: int = 0) -> Graph:
    """Planar Delaunay triangulation of uniform points (delaunay_n19 stand-in)."""
    from scipy.spatial import Delaunay

    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    tri = Delaunay(pts)
    simplices = tri.simplices
    e = np.concatenate(
        [simplices[:, [0, 1]], simplices[:, [1, 2]], simplices[:, [2, 0]]], axis=0
    )
    return from_edges(e[:, 0], e[:, 1], n)


def preferential_attachment(n: int, m: int = 4, seed: int = 0) -> Graph:
    """Barabási–Albert (amazon / LiveJournal stand-in), vectorised numpy."""
    rng = np.random.default_rng(seed)
    targets = np.arange(m, dtype=np.int64)
    src_all = np.empty((n - m) * m, dtype=np.int64)
    dst_all = np.empty((n - m) * m, dtype=np.int64)
    # repeated-nodes trick: sample targets from the flat endpoint history
    history = list(range(m))
    hist = np.empty(2 * (n - m) * m + m, dtype=np.int64)
    hist[: m] = np.arange(m)
    hlen = m
    k = 0
    for v in range(m, n):
        picks = hist[rng.integers(0, hlen, 2 * m)]
        picks = np.unique(picks)[:m]
        cnt = picks.shape[0]
        src_all[k : k + cnt] = v
        dst_all[k : k + cnt] = picks
        hist[hlen : hlen + cnt] = picks
        hist[hlen + cnt : hlen + 2 * cnt] = v
        hlen += 2 * cnt
        k += cnt
    return from_edges(src_all[:k], dst_all[:k], n)


def web_like(n: int, m: int = 8, p_triangle: float = 0.5, seed: int = 0) -> Graph:
    """Holme–Kim style clustered power-law (web-Google / web-BerkStan stand-in)."""
    import networkx as nx

    G = nx.powerlaw_cluster_graph(n, m, p_triangle, seed=seed)
    e = np.asarray(G.edges(), dtype=np.int64)
    if e.size == 0:
        e = np.zeros((0, 2), dtype=np.int64)
    return from_edges(e[:, 0], e[:, 1], n)


def random_regular(n: int, d: int = 6, seed: int = 0) -> Graph:
    """d-regular random graph (uniform-degree control case)."""
    rng = np.random.default_rng(seed)
    stubs = np.repeat(np.arange(n), d)
    rng.shuffle(stubs)
    half = stubs.shape[0] // 2
    return from_edges(stubs[:half], stubs[half : 2 * half], n)


def erdos_renyi(n: int, avg_deg: float = 8.0, seed: int = 0) -> Graph:
    """G(n, m) uniform random graph."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(src, dst, n)


# --------------------------------------------------------------------------
# the paper's suite, as specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """One row of the paper's Table 1, plus how to synthesise it."""
    name: str
    paper_id: str          # G1..G8
    n_full: int            # |V| at paper scale
    e_full: int            # |E| at paper scale (undirected count)
    n_reduced: int         # CPU-tractable scale for wall-clock benches
    make: Callable[[int, int], Graph]  # (n, seed) -> Graph at requested n

    def reduced(self, seed: int = 0) -> Graph:
        return self.make(self.n_reduced, seed)

    @property
    def e_over_v(self) -> float:
        return self.e_full / self.n_full


def _grid_maker(n: int, seed: int) -> Graph:
    side = int(np.sqrt(n))
    return grid2d(side, side, seed=seed)


GRAPH_SUITE: Dict[str, GraphSpec] = {
    s.paper_id: s
    for s in [
        GraphSpec("amazon0302", "G1", 262_111, 1_234_877, 20_000,
                  lambda n, seed: preferential_attachment(n, m=4, seed=seed)),
        GraphSpec("roadNet-PA", "G2", 1_090_920, 1_541_898, 40_000, _grid_maker),
        GraphSpec("delaunay_n19", "G3", 524_288, 1_572_823, 32_768,
                  lambda n, seed: delaunay_like(n, seed=seed)),
        GraphSpec("wiki-Talk", "G4", 2_394_385, 4_659_565, 30_000,
                  lambda n, seed: powerlaw(n, avg_deg=4.0, seed=seed)),
        GraphSpec("web-Google", "G5", 916_428, 4_322_051, 20_000,
                  lambda n, seed: web_like(n, m=5, seed=seed)),
        GraphSpec("web-BerkStan", "G6", 685_230, 6_649_470, 16_000,
                  lambda n, seed: web_like(n, m=10, seed=seed)),
        GraphSpec("soc-LiveJournal1", "G7", 4_847_571, 42_851_237, 24_000,
                  lambda n, seed: preferential_attachment(n, m=7, seed=seed)),
        GraphSpec("kron_g500-logn21", "G8", 2_097_152, 91_040_932, 16_384,
                  lambda n, seed: rmat(int(np.log2(n)), edge_factor=16, seed=seed)),
    ]
}


def generate(paper_id: str, *, scale: str = "reduced", seed: int = 0) -> Graph:
    """Materialise one of the paper's graphs. ``scale`` is 'reduced' only —
    full scale exists as shape specs for the dry-run, never as host arrays."""
    spec = GRAPH_SUITE[paper_id]
    if scale != "reduced":
        raise ValueError("full-scale graphs are dry-run specs, not arrays")
    return spec.reduced(seed)

"""Graph substrate: CSR/edge-list structures, generators, sampling, partitioning.

This is the data layer shared by the MIS core (the paper's algorithm), the GNN
model family, and the distributed runtime.  Everything device-side is a
registered pytree with static shapes so it jits / shards cleanly.
"""
from repro.graphs.graph import Graph, build_csr, from_edges, pad_graph
from repro.graphs.generators import (
    GraphSpec,
    GRAPH_SUITE,
    generate,
    grid2d,
    rmat,
    powerlaw,
    delaunay_like,
    random_regular,
    web_like,
    preferential_attachment,
)
from repro.graphs.sampler import NeighborSampler, SampledSubgraph
from repro.graphs.partition import partition_edges, partition_rows, pad_to_multiple

__all__ = [
    "Graph", "build_csr", "from_edges", "pad_graph",
    "GraphSpec", "GRAPH_SUITE", "generate",
    "grid2d", "rmat", "powerlaw", "delaunay_like", "random_regular", "web_like",
    "preferential_attachment",
    "NeighborSampler", "SampledSubgraph",
    "partition_edges", "partition_rows", "pad_to_multiple",
]

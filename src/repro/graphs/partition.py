"""Host-side partitioning for the distributed runtime.

Two layouts, matching the two MIS/GNN execution paths:

* ``partition_rows``  — contiguous vertex (block-row) ranges per shard; used by
  the distributed TC-MIS (each chip owns a slab of block-rows of the BSR
  matrix and the matching slice of the state vectors).
* ``partition_edges`` — half-edges dealt round-robin by destination shard;
  used by the full-graph GNN path (segment-reduce locally, all-reduce nodes).

Every shard is padded to a rectangle (sentinel edges / zero tiles) so the
result stacks into one leading-axis-sharded array that `shard_map` consumes
directly.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def pad_to_multiple(x: np.ndarray, multiple: int, fill, axis: int = 0) -> np.ndarray:
    """Pad ``x`` along ``axis`` with ``fill`` up to the next multiple."""
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return np.pad(x, widths, constant_values=fill)


def partition_rows(n_nodes: int, n_shards: int) -> np.ndarray:
    """(n_shards+1,) vertex-range boundaries, balanced to within one."""
    return np.linspace(0, n_nodes, n_shards + 1).round().astype(np.int64)


def partition_edges(
    senders: np.ndarray,
    receivers: np.ndarray,
    n_nodes: int,
    n_shards: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shard half-edges by receiver's owner; pad shards to a rectangle.

    Returns (senders_sh, receivers_sh, mask_sh), each (n_shards, E_shard_pad).
    Receiver-owner sharding means each shard's segment-reduce writes only its
    own vertex slab — the all-reduce then combines slabs, touching each node
    feature once.
    """
    bounds = partition_rows(n_nodes, n_shards)
    owner = np.searchsorted(bounds, receivers, side="right") - 1
    owner = np.clip(owner, 0, n_shards - 1)
    max_e = 0
    per_shard = []
    for sh in range(n_shards):
        sel = owner == sh
        per_shard.append((senders[sel], receivers[sel]))
        max_e = max(max_e, int(sel.sum()))
    # pad to a common, lane-aligned width
    e_pad = ((max_e + 127) // 128) * 128 if max_e else 128
    s_out = np.full((n_shards, e_pad), n_nodes, dtype=np.int32)
    r_out = np.full((n_shards, e_pad), n_nodes, dtype=np.int32)
    m_out = np.zeros((n_shards, e_pad), dtype=bool)
    for sh, (s, r) in enumerate(per_shard):
        k = s.shape[0]
        s_out[sh, :k] = s
        r_out[sh, :k] = r
        m_out[sh, :k] = True
    return s_out, r_out, m_out

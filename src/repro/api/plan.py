"""`Plan` — the immutable solve artifact — and its content-addressed cache.

BLEST and HC-SpMM both measure the format/preprocessing layer — not the
kernel — as the dominant cost of end-to-end tensor-core graph workloads, and
this repo is no different: RCM reordering plus the BSR tile scatter dwarfs a
converged MIS solve at serving scale.  A `Plan` is everything that cost
buys — the canonical (optionally RCM-permuted) graph, its per-graph BSR
tiling, the build parameters (tile size, reorder choice), and the
permutation to map results back — keyed by a sha256 over the canonical edge
list and the build parameters, so a repeat request for the same graph (same
*content*, regardless of which file or object it arrived in) skips
preprocessing entirely:

    memory hit    dict lookup, zero work
    disk hit      one `np.load` (plans persist across processes)
    miss          full build, then written through to both layers

`Plan.build(graph, cache=...)` is the front door; the `PlanCache` it wraps
(formerly `repro.serve_mis.planner`, absorbed here) stays available for
callers that want cache-layer stats.  Per-graph plans are also exactly the
unit the block-diagonal batcher (`serve_mis.batcher`) concatenates: a batch
never re-tiles its members, it offsets their cached tile lists.

This module also owns the default **auto-T policy**: when no tile size is
given, `choose_tile_size` picks the largest MXU-friendly T whose worst-case
BSR payload fits a per-chip byte budget — the paper's §3.2 memory/regularity
trade-off made explicit (hub-less meshes take full 128×128 MXU tiles,
hub-heavy power-law graphs fall back to smaller tiles exactly as the paper's
16×16 WMMA does).  `configs.tcmis` drives the same `fit_tile_size` loop with
its measured-occupancy estimator for the full-scale dry-runs.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
import uuid
import warnings
from collections import OrderedDict
from typing import Callable, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.tiling import (
    STORAGES as TILE_STORAGES,
    BlockTiledGraph,
    attach_partition,
    build_block_tiles,
    next_pow2,
    rcm_ordering,
)
from repro.graphs.graph import Graph, from_edges
from repro.obs.metrics import MetricsRegistry

# the PlanCache's legacy stats spelling, now a view over its metrics
# registry (repro.obs; DESIGN.md §14)
_PLAN_STAT_KEYS = ("mem_hits", "disk_hits", "misses", "evicted_stale")

# v2: the storage axis (DESIGN.md §11) — packed uint32 tiles on disk, storage
# in the cache key, and a version+storage tail on the npz `meta` record.
# The version is deliberately NOT part of the cache key: future bumps land
# on the SAME filename, where `_load`'s meta check detects the stale layout,
# warns once per eviction, deletes the file and rebuilds.  (v1 files are the
# one exception — `storage` joined the key string in v2, so they sit at old
# key paths; `PlanCache.plan` probes the legacy v1 key on a disk miss and
# evicts those too.)  Patched plans (`Plan.apply_delta`, DESIGN.md §12)
# persist in the same v2 layout under delta-chained keys (`delta_cache_key`)
# with an optional `epoch` tail record; superseded pre-delta entries are
# retired through the same eviction machinery (`PlanCache.apply_delta`).
#
# v3: the hybrid axis (DESIGN.md §16) — the tile-partition POLICY (mode +
# resolved nnz threshold) joins the meta record and, for hybrid != 'off',
# the cache key (`|h{mode}:{threshold}` tail; 'off' keys are unchanged so
# off-mode requests land on the v2 paths and the version check retires the
# old layout in place).  The partition ARRAYS are deliberately not
# persisted: `partition_tiles` is deterministic in (tiles, threshold), so
# `_load` re-attaches from the stored policy — disk entries stay exactly as
# big as v2 and can never desynchronise from their tiles.
_PLAN_VERSION = 3
# n_nodes, n_edges, n_tiles, tile_size, nbr, nbc, version, storage,
# hybrid mode, hybrid threshold
_META_LEN = 10

# partition policy axis, in meta-index order (0 = off keeps the v2 keys)
HYBRID_MODES = ("off", "auto", "forced")

# --------------------------------------------------------------------------
# the auto-T policy (paper §3.2: largest T whose BSR fits the budget)
# --------------------------------------------------------------------------

DEFAULT_TILE_BUDGET = 512 << 20   # bytes of BSR payload per chip
TILE_CANDIDATES = (128, 64, 32, 16)


def worst_case_tile_bytes(n_nodes: int, n_edges: int, tile_size: int) -> float:
    """Worst-case stored int8 BSR payload: `min(E, nb²)·T²` — every
    half-edge its own tile, capped by the block grid, so the bound never
    under-estimates.  THE shared estimate of both auto policies (auto-T
    and auto-storage): one definition, or their decisions desynchronise."""
    T = int(tile_size)
    nb = -(-max(int(n_nodes), 1) // T)
    return min(max(int(n_edges), 1), nb * nb) * T * T


def fit_tile_size(
    payload_bytes: Callable[[int], float],
    *,
    budget: int = DEFAULT_TILE_BUDGET,
    candidates: Tuple[int, ...] = TILE_CANDIDATES,
) -> int:
    """Largest candidate T whose estimated per-chip payload fits `budget`.

    `payload_bytes(T)` estimates the stored-BSR bytes at tile size T — the
    caller chooses the estimator (worst-case bound here, measured block
    occupancy in `configs.tcmis.choose_tile_size`).  Falls back to the
    smallest candidate when nothing fits (the paper's 16×16 WMMA floor).
    """
    for T in candidates:
        if payload_bytes(T) <= budget:
            return T
    return candidates[-1]


# --------------------------------------------------------------------------
# the auto-storage policy (DESIGN.md §11: bitpack once tile bytes bite)
# --------------------------------------------------------------------------

BITPACK_AUTO_THRESHOLD = 1 << 20   # est. int8 tile payload bytes → bitpack


def resolve_storage(
    storage: str,
    n_nodes: int,
    n_edges: int,
    tile_size: int,
    *,
    threshold: int = BITPACK_AUTO_THRESHOLD,
) -> str:
    """Concrete tile storage for a graph: 'auto' flips to bitpack once the
    worst-case int8 tile payload (`worst_case_tile_bytes`, shared with the
    auto-T policy so the two agree on the estimate) crosses `threshold`
    bytes — small graphs keep the simpler dense tiles, large ones take the
    8× HBM/DMA reduction.  Concrete spellings pass through."""
    if storage in TILE_STORAGES:
        return storage
    if storage != "auto":
        raise ValueError(
            f"unknown storage {storage!r}; valid: {('auto',) + TILE_STORAGES}"
        )
    est = worst_case_tile_bytes(n_nodes, n_edges, tile_size)
    return "bitpack" if est >= threshold else "int8"


# --------------------------------------------------------------------------
# the hybrid-partition policy (DESIGN.md §16: roofline break-even threshold)
# --------------------------------------------------------------------------


def resolve_hybrid_threshold(
    tile_size: int, storage: str, threshold: Optional[int] = None
) -> int:
    """Concrete nnz classifier cut for a plan: the caller's override, or the
    analytic roofline break-even for this (tile size, storage) — the edge
    count at which one dense tile pass costs the same as streaming its
    edges through the COO/segment tail (`repro.perf.hybrid_density_threshold`).
    Resolved at PLAN time so the cache key and the persisted meta record
    name a concrete integer, never a policy that could drift."""
    if threshold is not None:
        return int(threshold)
    from repro.perf.roofline import hybrid_density_threshold

    return hybrid_density_threshold(tile_size, storage)


def choose_tile_size(
    n_nodes: int,
    n_edges: int,
    *,
    n_chips: int = 1,
    budget: int = DEFAULT_TILE_BUDGET,
) -> int:
    """Default auto-T for an arbitrary graph (no structure measured yet).

    Worst-case tile count is `min(E, nb²)` — every half-edge its own tile,
    capped by the block grid — so the bound never under-estimates.  Tiny
    graphs are additionally capped to tiles no wider than their padded
    vertex range (a 50-vertex graph never takes 128×128 tiles).
    """
    cap = next_pow2(max(min(int(n_nodes), TILE_CANDIDATES[0]), TILE_CANDIDATES[-1]))
    candidates = tuple(T for T in TILE_CANDIDATES if T <= cap) or (TILE_CANDIDATES[-1],)

    def per_chip_bytes(T: int) -> float:
        return worst_case_tile_bytes(n_nodes, n_edges, T) / max(int(n_chips), 1)

    return fit_tile_size(per_chip_bytes, budget=budget, candidates=candidates)


# --------------------------------------------------------------------------
# the plan artifact
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Plan:
    """One graph's cached preprocessing artefacts — the immutable solve unit.

    `g` and `tiled` index *plan ids*: the RCM-permuted vertex numbering when
    `perm` is set, the original numbering otherwise.  Results computed on
    plan ids map back through :meth:`to_original`.

    `epoch` counts applied `EdgeDelta`s along this plan's lineage
    (DESIGN.md §12): epoch 0 is a from-scratch build, and each
    :meth:`apply_delta` produces epoch+1 under a delta-chained cache key
    (`delta_cache_key`) — mutation never aliases the parent's entry.
    """
    g: Graph
    tiled: BlockTiledGraph
    key: str                           # content hash (the cache key)
    perm: Optional[np.ndarray] = None  # perm[plan_id] = original_id
    inv: Optional[np.ndarray] = None   # inv[original_id] = plan_id
    reorder: Optional[str] = None      # the reorder choice this plan was built with
    epoch: int = 0                     # deltas applied since the epoch-0 build
    hybrid: str = "off"                # tile-partition policy (DESIGN.md §16)
    hybrid_threshold: int = 0          # resolved nnz cut (0 iff hybrid == 'off')
    occupancy0: float = 0.0            # stored-tile density at the epoch-0
    #                                    build — the locality-decay baseline
    #                                    (DESIGN.md §17); 0.0 = unknown
    #                                    (directly-constructed plans)

    @property
    def n_nodes(self) -> int:
        return self.g.n_nodes

    @property
    def n_blocks(self) -> int:
        return self.tiled.n_block_rows

    @property
    def tile_size(self) -> int:
        return self.tiled.tile_size

    @property
    def storage(self) -> str:
        """Tile storage format this plan was built with (DESIGN.md §11)."""
        return self.tiled.storage

    @functools.cached_property
    def graph_key(self) -> str:
        """Build-parameter-free content hash — the identity of the *graph*
        alone.  Per-request PRNG keys derive from this (not `key`, which
        bakes in tile_size/reorder/storage), so a member's priorities — and
        therefore its solution — are invariant across storage formats."""
        return graph_content_key(self.g)

    def to_original(self, x: np.ndarray) -> np.ndarray:
        """Map a per-vertex plan-id vector back to original vertex ids."""
        x = np.asarray(x)[: self.g.n_nodes]
        return x if self.inv is None else x[self.inv]

    def to_plan_ids(self, x: np.ndarray) -> np.ndarray:
        """Inverse of :meth:`to_original` (original-id vector → plan ids)."""
        x = np.asarray(x)[: self.g.n_nodes]
        return x if self.perm is None else x[self.perm]

    @classmethod
    def build(
        cls,
        graph: Union[Graph, "Plan"],
        *,
        tile_size: Optional[int] = None,
        reorder: Optional[str] = None,
        storage: str = "int8",
        hybrid: str = "off",
        hybrid_threshold: Optional[int] = None,
        cache: Optional["PlanCache"] = None,
    ) -> "Plan":
        """The front door: plan a graph, through a cache when one is given.

        `tile_size=None` applies the auto-T policy (`choose_tile_size`) —
        with or without a cache, so the same call plans the same graph
        identically either way (the cache's constructor `tile_size` is only
        the default of its own `plan()` method).  `storage` may be a
        concrete format or 'auto' (`resolve_storage`).  `hybrid` is the
        tile-partition policy (DESIGN.md §16); `hybrid_threshold=None`
        resolves to the analytic roofline cut (`resolve_hybrid_threshold`).
        A `Plan` passes through untouched — callers may hold either.
        """
        if isinstance(graph, Plan):
            return graph
        T = tile_size or choose_tile_size(graph.n_nodes, graph.n_edges)
        storage = resolve_storage(storage, graph.n_nodes, graph.n_edges, T)
        if cache is not None:
            return cache.plan(
                graph, tile_size=T, reorder=reorder, storage=storage,
                hybrid=hybrid, hybrid_threshold=hybrid_threshold,
            )[0]
        thr = 0 if hybrid == "off" else resolve_hybrid_threshold(
            T, storage, hybrid_threshold
        )
        key = plan_cache_key(graph, T, reorder, storage, hybrid, thr)
        return build_plan(
            graph, T, reorder, key, storage=storage,
            hybrid=hybrid, hybrid_threshold=thr,
        )

    def apply_delta(
        self, delta, *, cache: Optional["PlanCache"] = None
    ) -> "Plan":
        """Patch this plan with an `EdgeDelta` — tile-local, never a rebuild.

        The delta arrives in ORIGINAL vertex ids (the ids callers hold);
        RCM-reordered plans map it through their permutation first.  The
        patched plan keeps this plan's tile size, storage, reorder choice
        and permutation (the RCM ordering is NOT recomputed — locality can
        drift over many epochs; re-plan from scratch to re-anchor it) and
        carries `epoch + 1` under the delta-chained key.  An empty delta
        returns `self` unchanged — same key, same epoch — which is what
        keeps `repair="incremental"` bit-identical to cold on no-op
        updates.  With `cache`, the patch goes through
        :meth:`PlanCache.apply_delta` (memoised; stale pre-delta disk
        entries evicted).
        """
        if cache is not None:
            return cache.apply_delta(self, delta)[0]
        return patch_plan(self, delta)


# backwards-compatible spelling (`repro.serve_mis.planner.TilePlan`)
TilePlan = Plan


def graph_content_key(g: Graph) -> str:
    """Content hash of the graph ALONE — no build parameters.  The identity
    `request_key` derivations hang off (see `Plan.graph_key`): the same
    graph must draw the same priorities whatever tile size, reordering or
    storage format it was planned with."""
    h = hashlib.sha256()
    h.update(f"tcmis-graph|{g.n_nodes}".encode())
    h.update(np.asarray(g.senders)[: g.n_edges].astype(np.int32).tobytes())
    h.update(np.asarray(g.receivers)[: g.n_edges].astype(np.int32).tobytes())
    return h.hexdigest()


def plan_cache_key(
    g: Graph,
    tile_size: int,
    reorder: Optional[str],
    storage: str = "int8",
    hybrid: str = "off",
    hybrid_threshold: int = 0,
) -> str:
    """Content hash of (canonical edges, n_nodes, build params).

    `from_edges` already canonicalises (dedupe, both directions, sender-sorted),
    so any two loads of the same graph — different files, different formats,
    shuffled edge order — hash identically.  `storage` is a build param:
    int8 and bitpack plans of one graph are distinct cache entries.  So is
    the hybrid-partition policy — but ONLY when it is on: 'off' contributes
    nothing to the key, so hybrid-free keys (and their disk paths) are
    byte-identical to the v2 derivation and old entries retire through the
    in-place version check rather than orphaning.
    """
    h = hashlib.sha256()
    # no version in the key: a format bump must hit the SAME file so the
    # meta check in `PlanCache._load` can detect + evict the stale layout
    tail = "" if hybrid == "off" else f"|h{hybrid}:{int(hybrid_threshold)}"
    h.update(
        f"tcmis-plan|{g.n_nodes}|{tile_size}|{reorder or ''}|{storage}"
        f"{tail}".encode()
    )
    h.update(np.asarray(g.senders)[: g.n_edges].astype(np.int32).tobytes())
    h.update(np.asarray(g.receivers)[: g.n_edges].astype(np.int32).tobytes())
    return h.hexdigest()


def _legacy_v1_cache_key(g: Graph, tile_size: int, reorder: Optional[str]) -> str:
    """The pre-storage-axis (v1) key derivation — kept ONLY so the cache can
    find and evict v1 disk entries, which live at different paths because
    `storage` joined the key string in v2."""
    h = hashlib.sha256()
    h.update(f"tcmis-plan-v1|{g.n_nodes}|{tile_size}|{reorder or ''}".encode())
    h.update(np.asarray(g.senders)[: g.n_edges].astype(np.int32).tobytes())
    h.update(np.asarray(g.receivers)[: g.n_edges].astype(np.int32).tobytes())
    return h.hexdigest()


def delta_cache_key(parent_key: str, delta_content_key: str) -> str:
    """Cache key of a patched plan: sha256 chained over the parent plan's
    key and the delta's content hash (`EdgeDelta.content_key`).  Chaining —
    rather than re-hashing the mutated edge list — makes patching O(delta)
    and names the *lineage*: the same graph state reached through a
    different delta history keys differently, which is deliberate (the
    entry records how the tiling was patched, and epochs retire in lineage
    order)."""
    h = hashlib.sha256()
    h.update(f"tcmis-plan-delta|{parent_key}|{delta_content_key}".encode())
    return h.hexdigest()


def patch_plan(plan: Plan, delta) -> Plan:
    """The uncached patch path: map, mutate both representations, re-key.

    Graph-level strictness (`apply_graph_delta` raises on absent removes /
    present adds) runs FIRST, so the tile edit — which trusts its input —
    only ever sees a validated batch.
    """
    from repro.dyngraph import drift
    from repro.dyngraph.retile import apply_delta as apply_tiled_delta
    from repro.dyngraph.retile import apply_graph_delta

    if delta.is_empty:
        return plan
    mapped = delta if plan.inv is None else delta.mapped(plan.inv)
    g2 = apply_graph_delta(plan.g, mapped)
    tiled2 = apply_tiled_delta(plan.tiled, mapped)
    # drift telemetry (DESIGN.md §17): this is the ONE funnel every actual
    # patch event passes through — cache mem/disk hits replay a patch that
    # was recorded when it happened, so each epoch counts exactly once.
    # Eager seam, observability only: never raise into the patch path.
    try:
        drift.note_drift(
            epoch=plan.epoch + 1,
            touched_tiles=drift.touched_tile_count(
                mapped, plan.tiled.tile_size, plan.tiled.n_block_cols
            ),
            n_tiles=tiled2.n_tiles,
            dirty_frac=drift.dirty_vertex_frac(mapped, plan.g.n_nodes),
            occupancy=drift.tile_occupancy(
                g2.n_edges, tiled2.n_tiles, tiled2.tile_size
            ),
            occupancy0=plan.occupancy0,
        )
    except Exception:  # noqa: BLE001
        pass
    if plan.hybrid == "auto":
        # `apply_tiled_delta` reclassifies an existing partition in place,
        # but only the PLAN knows the auto policy: a delta can push the
        # graph across the auto gate in either direction, so re-run it
        # (forced/off plans need nothing — present stays present, absent
        # stays absent)
        tiled2 = attach_partition(
            dataclasses.replace(tiled2, partition=None),
            mode="auto", threshold=plan.hybrid_threshold,
        )
    return dataclasses.replace(
        plan,
        g=g2,
        tiled=tiled2,
        key=delta_cache_key(plan.key, delta.content_key),
        epoch=plan.epoch + 1,
    )


def build_plan(
    g: Graph,
    tile_size: int,
    reorder: Optional[str],
    key: str,
    storage: str = "int8",
    hybrid: str = "off",
    hybrid_threshold: int = 0,
) -> Plan:
    """The cache-miss path: (optional) RCM + BSR tiling + (optional) tile
    partition, no caching.  `hybrid_threshold` arrives already resolved
    (`resolve_hybrid_threshold`) — this function never invents policy."""
    perm = inv = None
    if reorder == "rcm":
        perm = np.asarray(rcm_ordering(g))
        inv = np.empty_like(perm)
        inv[perm] = np.arange(g.n_nodes)
        s = np.asarray(g.senders)[: g.n_edges]
        r = np.asarray(g.receivers)[: g.n_edges]
        g = from_edges(inv[s], inv[r], g.n_nodes)
    elif reorder is not None:
        raise ValueError(f"unknown reorder {reorder!r} (None or 'rcm')")
    tiled = build_block_tiles(g, tile_size=tile_size, storage=storage)
    if hybrid != "off":
        tiled = attach_partition(
            tiled, mode=hybrid, threshold=int(hybrid_threshold)
        )
    from repro.dyngraph.drift import tile_occupancy

    return Plan(g=g, tiled=tiled, key=key, perm=perm, inv=inv,
                reorder=reorder, hybrid=hybrid,
                hybrid_threshold=int(hybrid_threshold),
                occupancy0=tile_occupancy(
                    g.n_edges, tiled.n_tiles, tile_size))


class PlanCache:
    """Two-layer (memory + optional disk) content-addressed plan store.

    The memory layer is a bounded LRU (`max_mem_entries`) — a long-running
    service must not pin every graph it has ever seen (tiles are the big
    arrays) in host/device memory.  The disk layer is unbounded by design:
    content-addressed `.npz` files are cheap, shared between processes, and
    an operator concern to garbage-collect.

    `tile_size`/`reorder`/`storage` given at construction are defaults;
    `plan` accepts per-call overrides (the `Solver`'s auto policies pick
    per-graph values), and the cache key includes all of them, so entries
    never collide across builds.  Disk entries carry the cache-format
    version (`_PLAN_VERSION`); entries written by an older format — e.g.
    pre-storage-axis v1 files — are detected on load, evicted with a
    warning, and rebuilt rather than mis-read.
    """

    def __init__(
        self,
        tile_size: int = 32,
        reorder: Optional[str] = None,
        cache_dir: Optional[str] = None,
        max_mem_entries: int = 256,
        storage: str = "int8",
        hybrid: str = "off",
        hybrid_threshold: Optional[int] = None,
    ):
        self.tile_size = int(tile_size)
        self.reorder = reorder
        self.storage = storage
        self.hybrid = hybrid
        self.hybrid_threshold = hybrid_threshold
        self.cache_dir = cache_dir
        self.max_mem_entries = max(int(max_mem_entries), 1)
        self._mem: "OrderedDict[str, Plan]" = OrderedDict()
        # per-instance metrics registry (repro.obs); the legacy `stats` dict
        # survives as a read-only property view below
        self.metrics = MetricsRegistry("plan_cache")
        for k in _PLAN_STAT_KEYS:
            self.metrics.counter(f"plan_cache.{k}")
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)

    @property
    def stats(self) -> dict:
        """Read-only `{mem_hits, disk_hits, misses, evicted_stale}` view in
        the legacy spelling; mutation goes through `self.metrics`."""
        return {
            k: self.metrics.counter(f"plan_cache.{k}").value
            for k in _PLAN_STAT_KEYS
        }

    def _count(self, key: str) -> None:
        self.metrics.counter(f"plan_cache.{key}").inc()

    def _remember(self, key: str, plan: Plan) -> None:
        self._mem[key] = plan
        self._mem.move_to_end(key)
        while len(self._mem) > self.max_mem_entries:
            self._mem.popitem(last=False)

    def plan(
        self,
        g: Graph,
        *,
        tile_size: Optional[int] = None,
        reorder: Optional[str] = None,
        storage: Optional[str] = None,
        hybrid: Optional[str] = None,
        hybrid_threshold: Optional[int] = None,
    ) -> Tuple[Plan, str]:
        """Return (plan, status) with status ∈ {'mem', 'disk', 'built'}."""
        T = self.tile_size if tile_size is None else int(tile_size)
        ro = self.reorder if reorder is None else reorder
        st = resolve_storage(
            self.storage if storage is None else storage,
            g.n_nodes, g.n_edges, T,
        )
        hy = self.hybrid if hybrid is None else hybrid
        thr = 0 if hy == "off" else resolve_hybrid_threshold(
            T, st,
            self.hybrid_threshold if hybrid_threshold is None
            else hybrid_threshold,
        )
        key = plan_cache_key(g, T, ro, st, hy, thr)
        hit = self._mem.get(key)
        if hit is not None:
            self._count("mem_hits")
            self._mem.move_to_end(key)
            return hit, "mem"
        if self.cache_dir:
            loaded = self._load(key, ro)
            if loaded is not None:
                self._count("disk_hits")
                self._remember(key, loaded)
                return loaded, "disk"
            # disk miss: a v1 entry for this graph (pre-storage-axis key)
            # may still sit at its legacy path — evict it so upgrades
            # clean up rather than orphan old-format files
            legacy = self._path(_legacy_v1_cache_key(g, T, ro))
            if os.path.exists(legacy):
                self._evict_stale(legacy, "pre-storage-axis entry (v1 key)")
            if hy != "off":
                # hybrid keys moved off the v2 paths — a pre-hybrid entry
                # for this graph sits at the hybrid-free key.  Evict it only
                # if it really is old-format: the same path is a LIVE v3
                # entry for hybrid='off' requests.
                self._evict_legacy_version(
                    self._path(plan_cache_key(g, T, ro, st))
                )
        self._count("misses")
        plan = build_plan(
            g, T, ro, key, storage=st, hybrid=hy, hybrid_threshold=thr
        )
        self._remember(key, plan)
        if self.cache_dir:
            self._store(plan)
        return plan, "built"

    def _evict_legacy_version(self, path: str) -> None:
        """Evict the entry at `path` iff it predates the current format —
        used for probing legacy key locations that may also hold live
        current-format entries (never evict those)."""
        if not os.path.exists(path):
            return
        try:
            with np.load(path) as z:
                meta = z["meta"]
                version = int(meta[6]) if meta.shape[0] > 6 else 1
        except Exception:  # noqa: BLE001 — torn/unreadable: treat as stale
            version = 0
        if version != _PLAN_VERSION:
            self._evict_stale(path, f"pre-hybrid entry (format v{version})")

    def apply_delta(self, plan: Plan, delta) -> Tuple[Plan, str]:
        """Patch a plan through the cache: return (patched, status) with
        status ∈ {'mem', 'disk', 'built'} — 'built' here means *patched*,
        the tile-local `patch_plan`, never a from-scratch rebuild.

        The patched entry persists under the current (v2) npz format at its
        delta-chained key; the parent's now-stale pre-delta entry is then
        retired exactly like PR 4's v1-format entries — detected, warned
        about once, unlinked, and counted in `stats.evicted_stale` — so a
        mutating graph's lineage keeps ONE live disk entry instead of
        accreting an epoch per delta.  (A re-request of the pre-delta
        content simply rebuilds: for a graph that mutates between
        requests, the superseded epoch is the stale layout, the same way
        a superseded format version was.)
        """
        if delta.is_empty:
            return plan, "mem"
        key = delta_cache_key(plan.key, delta.content_key)
        hit = self._mem.get(key)
        if hit is not None:
            self._count("mem_hits")
            self._mem.move_to_end(key)
            return hit, "mem"
        if self.cache_dir:
            loaded = self._load(key, plan.reorder)
            if loaded is not None:
                self._count("disk_hits")
                self._remember(key, loaded)
                self._retire_parent(plan)
                return loaded, "disk"
        self._count("misses")
        patched = patch_plan(plan, delta)
        self._remember(patched.key, patched)
        if self.cache_dir:
            self._store(patched)
            self._retire_parent(plan)
        return patched, "built"

    def _retire_parent(self, parent: Plan) -> None:
        """Unlink the superseded pre-delta disk entry and drop its memory
        copy — the epoch analogue of the v1-format eviction."""
        path = self._path(parent.key)
        if os.path.exists(path):
            self._evict_stale(
                path, f"pre-delta entry (epoch {parent.epoch} superseded)"
            )
        self._mem.pop(parent.key, None)

    # -- disk layer --------------------------------------------------------

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.npz")

    def _store(self, plan: Plan) -> None:
        g, t = plan.g, plan.tiled
        # tiles persist AS STORED — a bitpack plan's disk entry is the same
        # 8× smaller than its int8 twin as its HBM copy
        arrays = dict(
            senders=np.asarray(g.senders)[: g.n_edges],
            receivers=np.asarray(g.receivers)[: g.n_edges],
            tiles=np.asarray(t.tiles),
            tile_rows=np.asarray(t.tile_rows),
            tile_cols=np.asarray(t.tile_cols),
            row_starts=np.asarray(t.row_starts),
            meta=np.asarray(
                [g.n_nodes, g.n_edges, t.n_tiles, t.tile_size,
                 t.n_block_rows, t.n_block_cols,
                 _PLAN_VERSION, TILE_STORAGES.index(t.storage),
                 HYBRID_MODES.index(plan.hybrid), plan.hybrid_threshold],
                dtype=np.int64,
            ),
        )
        if plan.perm is not None:
            arrays["perm"] = plan.perm
        if plan.epoch:
            # optional tail record, like `perm`: patched plans stay within
            # the v2 layout (the 8-int meta is untouched), readers without
            # the field default to epoch 0
            arrays["epoch"] = np.asarray([plan.epoch], dtype=np.int64)
        # write under a per-writer temp name, publish atomically: concurrent
        # workers that both miss on one key each write their own temp file
        # and the last rename wins with identical content
        tmp = self._path(plan.key) + f".tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, self._path(plan.key))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def _evict_stale(self, path: str, found: str) -> None:
        """Old-format disk entry: warn (one line), delete, let the caller
        rebuild — a stale layout must never be mis-read as current."""
        self._count("evicted_stale")
        warnings.warn(
            f"evicting stale plan-cache entry {os.path.basename(path)}: "
            f"{found}, current format v{_PLAN_VERSION} — rebuilding",
            stacklevel=3,
        )
        try:
            os.unlink(path)
        except OSError:
            pass

    def _load(self, key: str, reorder: Optional[str]) -> Optional[Plan]:
        path = self._path(key)
        if not os.path.exists(path):
            return None
        try:
            with np.load(path) as z:
                meta = z["meta"]
                if meta.shape[0] < _META_LEN:
                    self._evict_stale(path, "pre-versioned entry (v1 layout)")
                    return None
                if int(meta[6]) != _PLAN_VERSION:
                    self._evict_stale(path, f"format v{int(meta[6])}")
                    return None
                n_nodes, n_edges, n_tiles, tile_size, nbr, nbc = (
                    int(v) for v in meta[:6]
                )
                storage = TILE_STORAGES[int(meta[7])]
                hybrid = HYBRID_MODES[int(meta[8])]
                hybrid_threshold = int(meta[9])
                g = Graph(
                    senders=jnp.asarray(z["senders"]),
                    receivers=jnp.asarray(z["receivers"]),
                    n_nodes=n_nodes,
                    n_edges=n_edges,
                )
                tiled = BlockTiledGraph(
                    tiles=jnp.asarray(z["tiles"]),
                    tile_rows=jnp.asarray(z["tile_rows"]),
                    tile_cols=jnp.asarray(z["tile_cols"]),
                    row_starts=jnp.asarray(z["row_starts"]),
                    n_tiles=n_tiles,
                    n_nodes=n_nodes,
                    tile_size=tile_size,
                    n_block_rows=nbr,
                    n_block_cols=nbc,
                    storage=storage,
                )
                perm = np.asarray(z["perm"]) if "perm" in z.files else None
                epoch = int(z["epoch"][0]) if "epoch" in z.files else 0
            if hybrid != "off":
                # the partition is policy, not payload: deterministic in
                # (tiles, threshold), so re-attach instead of persisting
                tiled = attach_partition(
                    tiled, mode=hybrid, threshold=hybrid_threshold
                )
            inv = None
            if perm is not None:
                inv = np.empty_like(perm)
                inv[perm] = np.arange(n_nodes)
            from repro.dyngraph.drift import tile_occupancy

            # occupancy0 is not persisted (the npz layout is frozen at v3):
            # a disk-loaded plan re-baselines locality decay at its load
            # state — exact for epoch-0 entries, a documented reset for
            # patched lineages (DESIGN.md §17)
            return Plan(g=g, tiled=tiled, key=key, perm=perm, inv=inv,
                        reorder=reorder, epoch=epoch, hybrid=hybrid,
                        hybrid_threshold=hybrid_threshold,
                        occupancy0=tile_occupancy(
                            n_edges, n_tiles, tile_size))
        except Exception:  # noqa: BLE001 — np.load raises BadZipFile/EOFError/
            return None    # pickle errors on torn files: any failure ⇒ rebuild

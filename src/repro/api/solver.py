"""`Solver` — the one front door to every MIS execution path.

The paper's pitch is that ONE tiled SpMV schedule serves every phase of
MIS; the Solver is that idea at the API layer: one object that decides
*where and how* a graph is solved (BLEST/HC-SpMM treat kernel choice as a
pluggable policy over one schedule — placement is the same kind of policy
one level up).  Routing (DESIGN.md §10):

    solve(graph)         placement policy per graph:
                           local    one jitted `lax.while_loop` dispatch
                                    on the configured round engine
                           sharded  the `core.distributed` shard_map path
                                    (auto: big padded graphs, >1 device)
    solve_many(graphs)   [] → [];  one graph → the single-graph path (no
                         bucket is ever built for a singleton);  many →
                         block-diagonal batcher, ONE dispatch per
                         tile-size group, members bit-identical to solo
                         runs; sharded-routed members peel off to their
                         own dispatch
    profile(graph)       the python-stepped profiler twin with per-phase
                         timers (same engine round body as solve)
    update(prior, delta) dynamic graphs (DESIGN.md §12): patch the plan
                         tile-locally through the cache, then repair the
                         solution per `options.repair` — warm-started
                         round-engine re-entry for small deltas, cold
                         re-solve otherwise

The Solver owns compiled-program reuse: one jitted single-graph program and
one jitted packed-batch program (their caches keyed by jax on the static
shape buckets), a bounded cache of shard_map programs, and the signature
set behind the `compile: reused|compiled` stat.  Determinism contract:
`solve` uses `jax.random.key(options.seed)` — the classic single-graph
spelling — while batched members get content-derived `request_key`s, so a
member's solution never depends on its batch, slot, or arrival order.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.options import SolveOptions
from repro.api.plan import Plan, PlanCache, choose_tile_size, resolve_storage
from repro.core.engine import get_engine, resolve_frontier
from repro.core.heuristics import make_priorities
from repro.core.luby import MISResult
from repro.core.tc_mis import _run_phases_impl, _tc_mis_impl
from repro.graphs.graph import Graph
from repro.obs.metrics import MetricsRegistry
from repro.obs.rounds import RoundTrace
from repro.obs.trace import Trace, trace_span

GraphLike = Union[Graph, Plan]

_DIST_PROGRAM_CACHE = 16       # shard_map closures kept per Solver (LRU)
_SEEN_SIGNATURE_CAP = 4096     # compile-stat signature set bound (FIFO)
_AOT_PROGRAM_CACHE = 16        # AOT-compiled programs kept for traced runs


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """One graph's solution, in ORIGINAL vertex numbering.

    `rounds` is this graph's OWN convergence round — for batched solves the
    per-member counter (max of the member's per-vertex settle rounds), never
    the batch-slowest.  `converged` is exact for local/single solves and
    batch-global for packed members (one `lax.while_loop` flag is shared;
    an unconverged member still fails maximality on its own, which is how
    the serving layer's per-member verdict stays sound).
    """
    in_mis: np.ndarray          # (n_nodes,) bool, original vertex ids
    rounds: int
    converged: bool
    placement: str              # local | batched | sharded
    plan: Plan
    stats: Dict[str, object] = dataclasses.field(default_factory=dict)
    # per-round alive/frontier/selected/tiles-skipped series — populated only
    # when SolveOptions.telemetry is on (repro.obs.rounds; batched members
    # share the bucket's batch-global series, meta marks the scope)
    telemetry: Optional[RoundTrace] = None

    @property
    def mis_size(self) -> int:
        return int(np.asarray(self.in_mis).sum())

    @property
    def in_mis_plan(self) -> np.ndarray:
        """The solution in PLAN-id numbering (RCM-permuted when the plan
        reorders) — what validators over `plan.g` expect."""
        return self.plan.to_plan_ids(self.in_mis)


class Solver:
    """Plan → route → execute, with compiled-program reuse (DESIGN.md §10)."""

    def __init__(
        self,
        options: SolveOptions = SolveOptions(),
        *,
        plans: Optional[PlanCache] = None,
    ):
        get_engine(options.engine)   # fail fast, before any graph is planned
        self.options = options
        self.plans = plans if plans is not None else PlanCache(
            tile_size=options.tile_size or 32,
            reorder=options.reorder,
            storage=options.storage,   # cache default mirrors the Solver
            hybrid=options.hybrid,
            hybrid_threshold=options.hybrid_threshold,
            cache_dir=options.cache_dir,
            max_mem_entries=options.plan_cache_entries,
        )
        self._base_key = jax.random.key(options.seed)
        # host-side per-member priority cache for the batcher (sound per
        # Solver: one base key, one heuristic, and ONLY default request_keys
        # — solve_many bypasses it when the caller supplies custom keys,
        # since entries are keyed by plan content alone)
        self._priority_cache: Dict = {}
        # bounded FIFO set behind the `compile: reused|compiled` stat (note:
        # jax's own jit cache still grows per distinct static shape — a
        # stream of unboundedly many distinct single-graph shapes should
        # prefer solve_many, whose pow2 buckets bound the compiled programs)
        self._seen_signatures: "OrderedDict" = OrderedDict()
        self._dist_runs: "OrderedDict[str, object]" = OrderedDict()
        # AOT-compiled programs (lower().compile()), built only on TRACED
        # cold dispatches so the compile/execute span split is measured, not
        # estimated.  Untraced dispatches never touch this — they keep the
        # plain jit wrappers below, so jax's jit caches (which tests and the
        # default service path observe) behave exactly as before.
        self._aot: "OrderedDict[tuple, object]" = OrderedDict()
        # the metrics registry behind the legacy `stats` view (repro.obs)
        self.metrics = MetricsRegistry("solver")
        for k in ("solver.solves", "solver.batches", "solver.compiles"):
            self.metrics.counter(k)
        # the two compiled-program seams: jax's jit cache keys on the packed
        # containers' static shape buckets, so a steady request mix converges
        # onto a handful of compiled programs
        self._jit_single = jax.jit(
            lambda g, tiled, key: _tc_mis_impl(g, tiled, key, options)
        )
        self._jit_packed = jax.jit(
            lambda g, tiled, pri, alive0, gate: _tc_mis_impl(
                g, tiled, self._base_key, options,
                priorities=pri, alive0=alive0, col_gate=gate,
                member_rounds=True,
            )
        )
        # the warm-start (delta-repair) program; built on the first
        # `update` — repro.dyngraph imports the serving layer, so the seam
        # resolves lazily rather than at api-import time
        self._jit_repair = None

    @property
    def stats(self) -> Dict[str, int]:
        """Read-only view over the metrics registry in the legacy spelling
        (`{"solves": .., "batches": .., "compiles": ..}`) — downstream code
        reads these keys; writes go through `self.metrics`."""
        m = self.metrics
        return {
            "solves": m.counter("solver.solves").value,
            "batches": m.counter("solver.batches").value,
            "compiles": m.counter("solver.compiles").value,
        }

    # -- planning ----------------------------------------------------------

    def plan(self, graph: GraphLike) -> Plan:
        """Plan a graph through the content-addressed cache (a `Plan` passes
        through untouched).  Auto-T applies when `options.tile_size` is
        None; `options.storage='auto'` resolves per graph (bitpack once the
        estimated tile payload crosses the threshold, DESIGN.md §11)."""
        if isinstance(graph, Plan):
            return graph
        tile_size = self.options.tile_size or choose_tile_size(
            graph.n_nodes, graph.n_edges
        )
        storage = resolve_storage(
            self.options.storage, graph.n_nodes, graph.n_edges, tile_size
        )
        # the hybrid policy only partitions where an engine can use it —
        # the segment engine has no tile schedule to split, so hybrid plans
        # for it would carry dead partition arrays through every dispatch
        hybrid = self.options.hybrid
        if not get_engine(self.options.engine).supports_hybrid:
            hybrid = "off"
        plan, _ = self.plans.plan(
            graph, tile_size=tile_size, storage=storage,
            hybrid=hybrid, hybrid_threshold=self.options.hybrid_threshold,
        )
        return plan

    def request_key(self, plan: Plan) -> jax.Array:
        """The content-derived per-graph key batched members are solved
        under (`serve_mis.batcher.request_key` semantics): independent of
        batch, slot and arrival order."""
        from repro.serve_mis.batcher import request_key

        return request_key(self._base_key, plan)

    # -- routing -----------------------------------------------------------

    def route(self, plan: Plan) -> str:
        """The placement policy: where would this plan execute?"""
        if self.options.placement != "auto":
            return self.options.placement
        big = plan.tiled.n_padded >= self.options.shard_threshold
        if big and jax.device_count() > 1:
            return "sharded"
        return "local"

    # -- execution ---------------------------------------------------------

    def solve(
        self,
        graph: GraphLike,
        *,
        key: Optional[jax.Array] = None,
        trace: Optional[Trace] = None,
    ) -> SolveResult:
        """Solve one graph on whatever path the routing policy picks.

        `trace` (repro.obs.Trace, default None = zero-overhead) records
        plan/compile/execute spans; on a cold traced dispatch the program is
        compiled ahead-of-time so `compile_ms` and `execute_ms` are measured
        separately instead of conflated into `solve_ms`."""
        with trace_span(trace, "solver.solve"):
            with trace_span(trace, "solver.plan"):
                plan = self.plan(graph)
            if key is None:
                key = jax.random.key(self.options.seed)
            if self.route(plan) == "sharded":
                return self._solve_sharded(plan, key, trace)
            return self._solve_local(plan, key, trace)

    def solve_many(
        self,
        graphs: Iterable[GraphLike],
        *,
        keys: Optional[Sequence[jax.Array]] = None,
        trace: Optional[Trace] = None,
    ) -> List[SolveResult]:
        """Solve a workload, batching where it pays.

        Empty input returns `[]` and a single graph routes through the
        single-graph path — neither ever builds a bucket.  Two or more
        local-routed members pack block-diagonally (grouped by tile size,
        since a batch must share T) into ONE dispatch each; sharded-routed
        members peel off to their own shard_map dispatch.  Results keep the
        input order.
        """
        with trace_span(trace, "solver.plan"):
            plans = [self.plan(g) for g in graphs]
        if not plans:
            return []
        # the priority cache is keyed by plan content under the DEFAULT
        # request_key; custom keys must bypass it or they would silently
        # receive the cached default-key priorities
        default_keys = keys is None
        if default_keys:
            keys = [self.request_key(p) for p in plans]
        elif len(keys) != len(plans):
            raise ValueError(f"{len(plans)} graphs but {len(keys)} keys")
        if len(plans) == 1:
            return [self.solve(plans[0], key=keys[0], trace=trace)]

        out: List[Optional[SolveResult]] = [None] * len(plans)
        # a batch must share T AND tile storage (one block-diagonal dtype)
        groups: "OrderedDict[tuple, List[int]]" = OrderedDict()
        for i, p in enumerate(plans):
            if self.route(p) == "sharded":
                out[i] = self._solve_sharded(p, keys[i], trace)
            else:
                groups.setdefault((p.tile_size, p.tiled.storage), []).append(i)
        for idxs in groups.values():
            if len(idxs) == 1:
                i = idxs[0]
                out[i] = self._solve_local(plans[i], keys[i], trace)
                continue
            solved = self._solve_batched(
                [plans[i] for i in idxs], [keys[i] for i in idxs],
                use_priority_cache=default_keys, trace=trace,
            )
            for i, r in zip(idxs, solved):
                out[i] = r
        return out   # type: ignore[return-value]

    def update(
        self,
        prior: SolveResult,
        delta,
        *,
        key: Optional[jax.Array] = None,
        trace: Optional[Trace] = None,
    ) -> SolveResult:
        """Apply an `EdgeDelta` to a solved graph and re-solve (DESIGN.md §12).

        The plan is patched tile-locally through the plan cache
        (`PlanCache.apply_delta` — delta-chained epoch key, stale pre-delta
        entry evicted), then the mutated graph is re-solved per
        `options.repair`:

          incremental   warm-start the round engine from `prior.in_mis`
                        with only the dirty frontier alive — small deltas
                        converge in a handful of rounds
          cold          a fresh `solve` of the patched plan
          auto          incremental while the delta touches ≤
                        `options.repair_threshold` of the vertices; also
                        falls back to cold when the patched plan routes
                        sharded (the shard_map loop has no warm seam yet)

        `prior` must be a converged result for the plan the delta applies
        to (chain updates by passing each result to the next `update`).
        Both paths solve under the same key and NEW-graph priorities, so an
        empty delta returns the prior solution bit-exactly either way.
        Stats gain `repair` (the mode taken), `patch` (plan-cache layer of
        the patched plan), `plan_epoch` and the delta sizes.
        """
        from repro.dyngraph.repair import dirty_mask, note_repair, repair_mis

        with trace_span(trace, "solver.plan"):
            plan2, patch_status = self.plans.apply_delta(prior.plan, delta)
        extra = dict(
            patch=patch_status, plan_epoch=plan2.epoch,
            delta_add=delta.n_add, delta_remove=delta.n_remove,
        )
        touched = delta.touched()
        mode = self.options.repair
        if mode == "auto":
            frac = touched.size / max(plan2.n_nodes, 1)
            mode = "incremental" if frac <= self.options.repair_threshold \
                else "cold"
        if mode == "incremental" and self.route(plan2) == "sharded":
            mode = "cold"
        note_repair(mode, dirty_frac=touched.size / max(plan2.n_nodes, 1))
        if mode == "cold":
            with trace_span(trace, "solver.update", mode="cold"):
                res = self.solve(plan2, key=key, trace=trace)
            return dataclasses.replace(
                res, stats=dict(res.stats, repair="cold", **extra)
            )

        if self._jit_repair is None:
            opts = self.options
            # priorities build INSIDE the compiled program from the key —
            # the same construction (new-graph degrees, same heuristic) the
            # cold path jits, so neither path pays eager priority dispatches
            self._jit_repair = jax.jit(
                lambda g, tiled, key, prior_mis, dirty: repair_mis(
                    g, tiled, key, opts, prior_mis, dirty
                )
            )
        if key is None:
            key = jax.random.key(self.options.seed)
        touched_plan = touched if plan2.inv is None else \
            np.asarray(plan2.inv)[touched]
        dirty = jnp.asarray(dirty_mask(plan2.n_nodes, touched_plan))
        prior_plan = jnp.asarray(plan2.to_plan_ids(prior.in_mis).astype(bool))
        t = plan2.tiled
        sig = ("repair", t.tile_size, t.storage, t.n_block_rows,
               t.n_block_cols, t.n_tiles, int(t.tiles.shape[0]), t.n_nodes,
               plan2.g.n_nodes, plan2.g.n_edges, plan2.g.e_pad,
               self._partition_sig(t))
        compile_stat = self._note_signature(sig)
        with trace_span(trace, "solver.update", mode="incremental"):
            out, timing = self._dispatch(
                self._jit_repair, sig, compile_stat, trace,
                plan2.g, plan2.tiled, key, prior_plan, dirty,
            )
        result, rt = self._split_telemetry(out, plan2.g, plan2.tiled,
                                           scope="repair")
        self.metrics.counter("solver.solves").inc()
        self.metrics.histogram("solver.solve_ms").observe(timing["solve_ms"])
        self._note_attribution(plan2.tiled, rt, timing["solve_ms"])
        return self._wrap(plan2, result, "local", dict(
            compile=compile_stat, batch_size=1,
            repair="incremental", **timing, **extra,
        ), telemetry=rt)

    def profile(self, graph: GraphLike, *, key: Optional[jax.Array] = None):
        """The instrumented twin: python-stepped rounds with per-phase wall
        clocks.  Returns `(SolveResult, times)` with times keyed phase1/
        phase2/phase3/rounds; the result bit-matches `solve` on the same
        graph and key (same engine round body)."""
        plan = self.plan(graph)
        if key is None:
            key = jax.random.key(self.options.seed)
        result, times = _run_phases_impl(plan.g, plan.tiled, key, self.options)
        self.metrics.counter("solver.solves").inc()
        return self._wrap(plan, result, "local", dict(times)), times

    # -- the three execution paths ----------------------------------------

    def _wrap(
        self,
        plan: Plan,
        result: MISResult,
        placement: str,
        stats: Dict,
        telemetry: Optional[RoundTrace] = None,
    ) -> SolveResult:
        in_mis_plan = np.asarray(result.in_mis).astype(bool)
        return SolveResult(
            in_mis=plan.to_original(in_mis_plan).astype(bool),
            rounds=int(result.rounds),
            converged=bool(result.converged),
            placement=placement,
            plan=plan,
            stats=stats,
            telemetry=telemetry,
        )

    @staticmethod
    def _partition_sig(tiled):
        """The hybrid partition's static trace inputs (None when absent):
        threshold + both compacted list shapes.  Joins every jit-cache
        signature a partitioned tiling can reach — the partition is a
        pytree child of `BlockTiledGraph`, so jax already recompiles on
        these; the signature must agree or the compile stat lies."""
        p = tiled.partition
        if p is None:
            return None
        return (p.threshold, p.n_dense_tiles, int(p.dense.tiles.shape[0]),
                p.n_sparse_tiles, int(p.sp_rows.shape[0]))

    def _note_signature(self, sig) -> str:
        reused = sig in self._seen_signatures
        self._seen_signatures[sig] = True
        if not reused:
            self.metrics.counter("solver.compiles").inc()
            while len(self._seen_signatures) > _SEEN_SIGNATURE_CAP:
                self._seen_signatures.popitem(last=False)
        return "reused" if reused else "compiled"

    def _dispatch(self, jit_fn, sig, compile_stat, trace, *args):
        """One compiled-program dispatch → (output, timing stats dict).

        Untraced (the default): call the jit wrapper, book the conflated
        wall clock as `solve_ms` — byte-identical behaviour to pre-obs.
        Traced: on a cold signature, lower + compile AHEAD of time under a
        `solver.compile` span (program kept in the bounded `_aot` cache,
        keyed by the same signature as the compile stat), then run under
        `solver.execute` — so `compile_ms` / `execute_ms` are measured
        separately and `solve_ms` is their sum, not a conflation.
        """
        t0 = time.perf_counter()
        if trace is None:
            out = jit_fn(*args)
            jax.block_until_ready(out)
            return out, {"solve_ms": round((time.perf_counter() - t0) * 1e3, 3)}
        timing = {}
        compiled = self._aot.get(sig)
        if compiled is None and compile_stat == "compiled":
            tc = time.perf_counter()
            with trace_span(trace, "solver.compile"):
                compiled = jit_fn.lower(*args).compile()
            timing["compile_ms"] = round((time.perf_counter() - tc) * 1e3, 3)
            self.metrics.histogram("solver.compile_ms").observe(
                timing["compile_ms"]
            )
            self._aot[sig] = compiled
            while len(self._aot) > _AOT_PROGRAM_CACHE:
                self._aot.popitem(last=False)
        fn = compiled if compiled is not None else jit_fn
        te = time.perf_counter()
        with trace_span(trace, "solver.execute"):
            out = fn(*args)
            jax.block_until_ready(out)
        now = time.perf_counter()
        timing["execute_ms"] = round((now - te) * 1e3, 3)
        timing["solve_ms"] = round((now - t0) * 1e3, 3)
        return out, timing

    def _split_telemetry(
        self, out, g: Graph, tiled, *, scope: str = "solve", batch_size: int = 1
    ):
        """Telemetry-off: identity → (result, None).  Telemetry-on: unpack
        the `(result, buffer)` pair `_tc_mis_impl` returns and materialise
        the buffer — THE one device→host telemetry transfer — into a
        `RoundTrace`."""
        if not self.options.telemetry:
            return out, None
        result, buf = out
        rounds = np.asarray(result.rounds)
        # vector (member_rounds) mode: the batch-global executed round count
        # is the max per-vertex settle round — the last round's selections
        # are real member vertices, all inside the slice
        rounds = int(rounds.max()) if rounds.ndim else int(rounds)
        engine = get_engine(self.options.engine)
        meta = dict(
            scope=scope,
            engine=self.options.engine,
            storage=tiled.storage,
            frontier=resolve_frontier(
                self.options, engine, storage=tiled.storage,
                member_rounds=batch_size > 1,
            ),
            n_nodes=g.n_nodes,
        )
        if batch_size > 1:
            meta["batch_size"] = batch_size
        rt = RoundTrace.from_buffer(
            np.asarray(buf), rounds,
            tiles_total=int(tiled.tile_cols.shape[0]), meta=meta,
        )
        return result, rt

    def _note_attribution(self, tiled, rt: Optional[RoundTrace],
                          solve_ms: float) -> None:
        """Roofline model-error gauges (DESIGN.md §17): predicted vs
        measured per-round cost from the telemetry dispatch-mix columns.

        Telemetry-on only (`rt is None` → no-op, so the telemetry-off path
        stays bit-identical), eager, and never raises into the solve path.
        Gauges, not histograms: the operator question is "what is the
        model error NOW / is it trending" — `perf.roofline_error_pct`
        drifting under churn means the dispatch mix no longer matches what
        the plan priced.
        """
        if rt is None or not rt.rounds:
            return
        try:
            from repro.perf.roofline import round_cost_attribution

            dense = sum(rt.tiles_dense) / rt.rounds if rt.tiles_dense else 0.0
            if dense <= 0.0 and rt.tiles_total:
                # engines that don't fill COL_TILES_DENSE (segment): every
                # non-skipped stored tile went through the one dense path
                dense = max(
                    rt.tiles_total - sum(rt.tiles_skipped) / rt.rounds, 0.0
                )
            p = tiled.partition
            # the sentinel-padded COO tail is the per-round sparse stream
            # length — padding entries are processed too, so they cost
            sparse = float(p.sp_rows.shape[0]) if p is not None else 0.0
            att = round_cost_attribution(
                dense_tiles=dense, sparse_edges=sparse,
                tile_size=tiled.tile_size, storage=tiled.storage,
                measured_s=(solve_ms / 1e3) / rt.rounds,
            )
            self.metrics.gauge("perf.roofline_predicted_us").set(
                att["predicted_us"])
            self.metrics.gauge("perf.roofline_measured_us").set(
                att["measured_us"])
            self.metrics.gauge("perf.roofline_error_pct").set(
                att["error_pct"])
        except Exception:  # noqa: BLE001
            pass

    def _solve_local(
        self, plan: Plan, key: jax.Array, trace: Optional[Trace] = None
    ) -> SolveResult:
        # every static trace input of the jitted program, or the stat lies
        t = plan.tiled
        sig = ("local", t.tile_size, t.storage, t.n_block_rows, t.n_block_cols,
               t.n_tiles, int(t.tiles.shape[0]), t.n_nodes, plan.g.n_nodes,
               plan.g.n_edges, plan.g.e_pad, self._partition_sig(t))
        compile_stat = self._note_signature(sig)
        out, timing = self._dispatch(
            self._jit_single, sig, compile_stat, trace, plan.g, plan.tiled, key
        )
        result, rt = self._split_telemetry(out, plan.g, plan.tiled)
        self.metrics.counter("solver.solves").inc()
        self.metrics.histogram("solver.solve_ms").observe(timing["solve_ms"])
        self._note_attribution(plan.tiled, rt, timing["solve_ms"])
        return self._wrap(plan, result, "local", dict(
            compile=compile_stat, batch_size=1, **timing,
        ), telemetry=rt)

    def _solve_batched(
        self,
        plans: Sequence[Plan],
        keys: Sequence[jax.Array],
        use_priority_cache: bool = True,
        trace: Optional[Trace] = None,
    ) -> List[SolveResult]:
        from repro.serve_mis.batcher import pack_batch

        with trace_span(trace, "solver.pack", batch_size=len(plans)):
            batch = pack_batch(
                plans, keys, self.options.heuristic,
                priority_cache=self._priority_cache if use_priority_cache
                else None,
            )
        sig = batch.signature()
        compile_stat = self._note_signature(sig)
        self.metrics.counter("solver.batches").inc()
        self.metrics.histogram("solver.batch_size").observe(len(plans))

        out_raw, timing = self._dispatch(
            self._jit_packed, sig, compile_stat, trace,
            batch.g, batch.tiled, batch.priorities, batch.alive0,
            batch.col_gate,
        )
        result, rt = self._split_telemetry(
            out_raw, batch.g, batch.tiled, scope="batch",
            batch_size=len(plans),
        )
        self.metrics.counter("solver.solves").inc(len(plans))
        self.metrics.histogram("solver.batch_ms").observe(timing["solve_ms"])
        self._note_attribution(batch.tiled, rt, timing["solve_ms"])
        converged = bool(result.converged)

        # attribution (DESIGN.md §14): ONE dispatch served the whole bucket,
        # so each member's `solve_ms` is its 1/batch share, with the shared
        # wall clock reported explicitly as `batch_ms` — summing members'
        # solve_ms across a workload now totals real device time instead of
        # multiply-counting every bucket by its size.  `compile_ms` (cold
        # traced dispatches) stays whole-bucket — compilation is not
        # per-member work.
        batch_ms = timing.pop("solve_ms")
        shared = dict(
            solve_ms=round(batch_ms / len(plans), 3),
            batch_ms=batch_ms, bucket=sig,
            compile=compile_stat, batch_size=len(plans), **timing,
        )
        out = []
        for plan, mis, rnd in zip(
            plans, batch.unpack(result.in_mis), batch.unpack(result.rounds)
        ):
            in_mis_plan = np.asarray(mis).astype(bool)
            out.append(SolveResult(
                in_mis=plan.to_original(in_mis_plan).astype(bool),
                rounds=int(np.max(rnd)) if rnd.size else 0,
                converged=converged,
                placement="batched",
                plan=plan,
                stats=dict(shared),
                telemetry=rt,   # batch-global series, shared by members
            ))
        return out

    def _solve_sharded(
        self, plan: Plan, key: jax.Array, trace: Optional[Trace] = None
    ) -> SolveResult:
        from repro.core.distributed import (
            DistConfig, build_distributed_mis, shard_tiled,
        )
        from repro.dist import compat

        n_dev = jax.device_count()
        run = self._dist_runs.get(plan.key)
        compile_stat = "reused" if run is not None else "compiled"
        if run is None:
            self.metrics.counter("solver.compiles").inc()
            with trace_span(trace, "solver.compile", placement="sharded"):
                axis_type = getattr(jax.sharding, "AxisType", compat._AxisType)
                mesh = compat.make_mesh(
                    (n_dev,), ("shard",), axis_types=(axis_type.Auto,)
                )
                # documented dense-only fallback (DESIGN.md §16): the
                # shard_map loop has no sparse-tail seam, so the partition
                # is stripped rather than half-honoured
                tiled_full = dataclasses.replace(plan.tiled, partition=None)
                sharded = shard_tiled(tiled_full, n_shards=n_dev)
                run = build_distributed_mis(sharded, mesh, DistConfig(
                    max_rounds=self.options.max_rounds,
                    bitpack=self.options.bitpack,
                    lanes=self.options.lanes,
                ))
            self._dist_runs[plan.key] = run
            while len(self._dist_runs) > _DIST_PROGRAM_CACHE:
                self._dist_runs.popitem(last=False)

        pri = make_priorities(
            self.options.heuristic, key, plan.g.n_nodes, plan.g.degrees()
        )
        t0 = time.perf_counter()
        with trace_span(trace, "solver.execute", placement="sharded"):
            res = run(pri)
            jax.block_until_ready(res.in_mis)
        solve_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.counter("solver.solves").inc()
        self.metrics.histogram("solver.solve_ms").observe(round(solve_ms, 3))
        rounds = int(res.rounds)
        in_mis_plan = np.asarray(res.in_mis)[: plan.g.n_nodes].astype(bool)
        return SolveResult(
            in_mis=plan.to_original(in_mis_plan).astype(bool),
            rounds=rounds,
            # the shard_map loop returns no explicit flag; exiting before the
            # bound is the (conservative) convergence signal
            converged=rounds < self.options.max_rounds,
            placement="sharded",
            plan=plan,
            stats=dict(
                solve_ms=round(solve_ms, 3), compile=compile_stat,
                n_shards=n_dev, batch_size=1,
            ),
        )

"""`SolveOptions` — every knob of a MIS solve, in one immutable bundle.

This supersedes the old split between `TCMISConfig` (algorithm knobs), the
`priorities`/`alive0`/`col_gate` kwarg sprawl on `tc_mis` (batch-serving
overrides, now internal to `Solver.solve_many`), and the preprocessing
arguments scattered across `build_block_tiles` / `PlanCache`.  One options
object fully determines how the `Solver` preprocesses, routes and executes
a graph (DESIGN.md §10).

The engine layer consumes this object directly: `EngineContext.cfg` only
needs `backend` / `heuristic` / `lanes` / `phase1` / `skip_dma` /
`max_rounds`, all of which `SolveOptions` provides (`backend` as an alias
of `engine`, so the same object satisfies both the old and new spelling).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

PLACEMENTS = ("auto", "local", "sharded")
STORAGES = ("auto", "int8", "bitpack")   # tile storage axis (DESIGN.md §11)
REPAIRS = ("auto", "cold", "incremental")   # delta-repair policy (§12)
FRONTIERS = ("auto", "dense", "bitwise")    # frontier-vector mode (§13)
HYBRIDS = ("auto", "off", "forced")         # hybrid tile routing (§16)


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """How to solve: algorithm, engine, preprocessing, and placement.

    Algorithm / engine (the former `TCMISConfig` surface):
      heuristic:  h1 | h2 | h3 | ecl          (paper §3.3)
      engine:     registered round engine — segment | tiled_ref |
                  tiled_pallas | fused_pallas (core.engine registry)
      phase1:     segment (paper-faithful) | tiled (beyond-paper)
      lanes:      RHS lane count (128 on TPU; 8 keeps CPU cheap)
      skip_dma:   empty-C slabs also skip their HBM read
      max_rounds: convergence-loop bound
      frontier:   frontier-vector mode (DESIGN.md §13) — 'dense' carries
                  (n_padded,) bool cand/alive/in_mis vectors through the
                  round loop; 'bitwise' carries (n_block_cols, W) uint32
                  words end-to-end (popcount SpMV for phase ②, the
                  priority-sorted clz / plane scan for phase ①, word logic
                  for phase ③).  'auto' picks bitwise exactly when it is
                  the fastest sound choice: a tiled engine, phase1='tiled',
                  storage='bitpack', and not a batched (`solve_many`) run.
                  Solutions are bit-identical in either mode.

    Preprocessing (the `Plan` build policy):
      tile_size:  BSR tile edge T, power of two ≥ 8; None = auto-T (the
                  budgeted policy of `repro.api.plan.choose_tile_size`)
      reorder:    None | 'rcm' locality reordering
      storage:    tile storage format (DESIGN.md §11) — 'int8' (one byte
                  per cell), 'bitpack' (1 bit per cell, uint32 words, 8×
                  less HBM/DMA/cache bytes), or 'auto': bitpack once the
                  estimated int8 tile payload crosses
                  `repro.api.plan.BITPACK_AUTO_THRESHOLD` bytes
                  (`repro.api.plan.resolve_storage`).  Solutions are
                  bit-identical in either format.
      hybrid:     per-tile hybrid routing (DESIGN.md §16) — classify tiles
                  by nnz at plan time and route the sub-threshold sparse
                  tail through COO/segment ops while dense tiles keep the
                  TC/Pallas path, both lists compacted so empty tiles cost
                  zero dispatch.  'auto' partitions when the tiling has
                  ≥ `core.tiling.HYBRID_AUTO_MIN_TILES` non-empty tiles and
                  a ≥ `HYBRID_AUTO_MIN_SPARSE_FRAC` sparse tail; 'forced'
                  always partitions; 'off' never does.  Solutions are
                  bit-identical in every mode (a perf knob, never a
                  semantics knob).  The sharded route ignores the
                  partition (documented dense-only fallback).
      hybrid_threshold: nnz cut for the classifier; None = the analytic
                  roofline break-even (`repro.perf.hybrid_density_threshold`
                  for the plan's tile size and storage).

    Placement (the routing policy, DESIGN.md §10):
      placement:        auto | local | sharded.  `auto` solves on one
                        device unless the padded graph reaches
                        `shard_threshold` vertices AND >1 device is
                        visible, in which case it takes the
                        `core.distributed` shard_map path.
      shard_threshold:  padded-vertex count at which `auto` shards
      bitpack:          sharded path: all-gather frontiers as packed uint32
                        words (`core.tiling.pack_frontier_words`) instead
                        of raw bools

    Dynamic graphs (`Solver.update`, DESIGN.md §12):
      repair:  how an `EdgeDelta` update re-solves the patched graph —
               'incremental' warm-starts the round engine from the prior
               solution with only the dirty frontier alive
               (`repro.dyngraph.repair`), 'cold' re-solves from scratch,
               and 'auto' picks incremental while the delta touches at
               most `repair_threshold` of the graph's vertices (small
               deltas converge in a handful of rounds; a delta that dirties
               most of the graph might as well re-solve).  Empty deltas
               are bit-identical across all three spellings.
      repair_threshold: the 'auto' cutover — dirty-vertex fraction above
               which updates fall back to a cold solve.

    Observability (repro.obs, DESIGN.md §14):
      telemetry:  carry the (max_rounds, K) on-device round-telemetry
                  buffer through the convergence loop and attach a
                  `RoundTrace` (per-round alive / frontier / selected /
                  tiles-skipped series) to `SolveResult.telemetry`.  Off
                  (the default) compiles to the exact pre-telemetry
                  program — zero cost.  Solutions are bit-identical either
                  way.

    Reproducibility / caching:
      seed:               base PRNG seed; `Solver.solve` uses
                          `jax.random.key(seed)` (the classic single-graph
                          spelling) while batched members get
                          content-derived `request_key`s so a member's
                          solution never depends on its batch.
      cache_dir:          persist tile plans here (content-addressed .npz)
      plan_cache_entries: memory-layer LRU bound of the plan cache
    """

    heuristic: str = "h3"
    engine: str = "fused_pallas"
    phase1: str = "segment"
    lanes: int = 8
    skip_dma: bool = False
    max_rounds: int = 1024
    frontier: str = "auto"

    tile_size: Optional[int] = None
    reorder: Optional[str] = None
    storage: str = "auto"
    hybrid: str = "auto"
    hybrid_threshold: Optional[int] = None

    placement: str = "auto"
    shard_threshold: int = 1 << 15
    bitpack: bool = True

    repair: str = "auto"
    repair_threshold: float = 0.25

    telemetry: bool = False

    seed: int = 0
    cache_dir: Optional[str] = None
    plan_cache_entries: int = 256

    def __post_init__(self):
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {self.placement!r}; options {PLACEMENTS}"
            )
        if self.storage not in STORAGES:
            raise ValueError(
                f"unknown storage {self.storage!r}; valid: {STORAGES}"
            )
        if self.repair not in REPAIRS:
            raise ValueError(
                f"unknown repair {self.repair!r}; valid: {REPAIRS}"
            )
        if self.frontier not in FRONTIERS:
            raise ValueError(
                f"unknown frontier {self.frontier!r}; valid: {FRONTIERS}"
            )
        if self.hybrid not in HYBRIDS:
            raise ValueError(
                f"unknown hybrid {self.hybrid!r}; valid: {HYBRIDS}"
            )
        if self.hybrid_threshold is not None and self.hybrid_threshold < 1:
            raise ValueError(
                f"hybrid_threshold must be >= 1, got {self.hybrid_threshold}"
            )

    @property
    def backend(self) -> str:
        """Engine-layer alias: `EngineContext.cfg.backend` and the legacy
        `TCMISConfig.backend` spell the same thing."""
        return self.engine

"""repro.api — the public front door: `Plan` / `SolveOptions` / `Solver`.

Three nouns route every MIS execution path in the system (DESIGN.md §10):

  Plan          immutable solve artifact — canonical graph + BSR tiling +
                build params + content hash, cached by content
                (`Plan.build(graph, cache=...)`)
  SolveOptions  every knob in one bundle — algorithm, engine, tile policy,
                placement, seed (supersedes `TCMISConfig` and the
                `priorities`/`alive0`/`col_gate` kwarg sprawl)
  Solver        `solve` / `solve_many` / `profile`, owning compiled-program
                reuse and the routing policy: small graphs → local engine
                dispatch, many small graphs → the block-diagonal batcher,
                large graphs (auto, multi-device) → the shard_map path

Legacy entry points (`repro.core.tc_mis`, `TCMISConfig`, engine spellings
`ref`/`pallas`) remain as deprecated shims; new code goes through here.
"""
from repro.api.options import REPAIRS, STORAGES, SolveOptions
from repro.api.plan import (
    BITPACK_AUTO_THRESHOLD,
    DEFAULT_TILE_BUDGET,
    Plan,
    PlanCache,
    build_plan,
    choose_tile_size,
    delta_cache_key,
    fit_tile_size,
    graph_content_key,
    patch_plan,
    plan_cache_key,
    resolve_storage,
)
from repro.api.solver import Solver, SolveResult

__all__ = [
    "SolveOptions", "STORAGES", "REPAIRS",
    "BITPACK_AUTO_THRESHOLD", "DEFAULT_TILE_BUDGET", "Plan", "PlanCache",
    "build_plan", "choose_tile_size", "delta_cache_key", "fit_tile_size",
    "graph_content_key", "patch_plan", "plan_cache_key", "resolve_storage",
    "Solver", "SolveResult",
]

"""mixtral-8x22b [arXiv:2401.04088]: 56L d=6144 48H (GQA kv=8)
MoE 8 experts top-2 (d_expert=16384), SWA window 4096, vocab 32768.

The only assigned LM arch with sub-quadratic attention structure, hence the
only one that runs the long_500k cell (DESIGN.md §8)."""
import jax.numpy as jnp

from repro.configs.common import ArchDef, lm_cells, lm_smoke, register
from repro.models.lm_config import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768, act="swiglu", window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384, router="softmax"),
    rope_theta=1_000_000.0, dtype=jnp.bfloat16, loss_chunk=1024,
)

SMOKE = LMConfig(
    name="mixtral-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=128, act="swiglu", window=16,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=96),
    dtype=jnp.float32, attn_chunk=16, loss_chunk=16,
)

ARCH = register(ArchDef(
    arch_id="mixtral-8x22b", family="lm",
    cells=lm_cells("mixtral-8x22b", CONFIG),
    smoke=lambda: lm_smoke(SMOKE),
    config=CONFIG,
))

"""egnn [arXiv:2102.09844]: 4 layers, d_hidden=64, E(n) equivariance."""
from repro.configs.common import ArchDef, register
from repro.configs.gnn_cells import GNNArch, gnn_cells, gnn_smoke
from repro.models.gnn.common import mlp_apply
from repro.models.gnn.egnn import egnn_apply, egnn_init

D_HIDDEN, N_LAYERS = 64, 4


def _init(key, d_in, n_out):
    return egnn_init(key, d_in, d_hidden=D_HIDDEN, n_layers=N_LAYERS, n_out=n_out)


def _node_logits(params, feats, coords, s, r, mask):
    h, _, _ = egnn_apply(params, feats, coords, s, r, mask)
    return mlp_apply(params["head"], h)


def _graph_energy(params, feats, coords, s, r, mask):
    _, _, energy = egnn_apply(params, feats, coords, s, r, mask)
    return energy


def _fwd_flops(n, e, d_feat):
    d = d_feat
    f = 0.0
    for _ in range(N_LAYERS):
        f += 2.0 * e * (2 * d + 1) * D_HIDDEN + 2.0 * e * D_HIDDEN * D_HIDDEN
        f += 2.0 * e * D_HIDDEN * D_HIDDEN            # phi_x
        f += 2.0 * n * (d + D_HIDDEN) * D_HIDDEN + 2.0 * n * D_HIDDEN * D_HIDDEN
        d = D_HIDDEN
    return f


GNN = GNNArch("egnn", _init, _node_logits, _graph_energy, _fwd_flops)
ARCH = register(ArchDef(
    arch_id="egnn", family="gnn", cells=gnn_cells(GNN),
    smoke=lambda: gnn_smoke(GNN), config=GNN,
))

"""Cell builders for the GNN family (full-graph, sampled-minibatch, molecule).

Each GNN arch file supplies:
  node_logits(params, feats, coords, s, r, mask) -> (N, n_out)
  graph_energy(params, feats, coords, s, r, mask) -> scalar
  init(key, d_in, n_out) -> params
and gets the four assigned shapes wired identically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.common import Cell, named_shardings
from repro.dist.sharding import batch_spec, data_axes
from repro.train.optimizer import OptConfig, adamw_init, adamw_update

def _pad512(n: int) -> int:
    """Dry-run shapes must shard over up to 512 chips; graphs keep their
    true size via edge/node masks, the arrays are zero-padded."""
    return -(-n // 512) * 512


GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_out=7),
    "minibatch_lg": dict(
        n_nodes=232965, n_edges=114_615_892, d_feat=602, n_out=41,
        batch_nodes=1024, fanout=(15, 10),
    ),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_out=47),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=16),
}


@dataclasses.dataclass(frozen=True)
class GNNArch:
    arch_id: str
    init: Callable          # (key, d_in, n_out) -> params
    node_logits: Callable   # (params, feats, coords, s, r, mask) -> (N, n_out)
    graph_energy: Callable  # (params, feats, coords, s, r, mask) -> scalar
    fwd_flops: Callable     # (n_nodes, n_edges, d_feat) -> float


def _xent(logits, labels):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - tgt)


def _full_graph_cell(a: GNNArch, shape_name: str) -> Cell:
    s = GNN_SHAPES[shape_name]
    N, E, DF, NO = s["n_nodes"], s["n_edges"], s["d_feat"], s["n_out"]
    E2 = 2 * E  # both directions

    NP, EP = _pad512(N), _pad512(E2)

    def build(mesh: Mesh, variant: str = "memory"):
        params_sh = jax.eval_shape(lambda k: a.init(k, DF, NO), jax.random.key(0))
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        flat = tuple(mesh.axis_names)
        p_specs = jax.tree.map(lambda _: P(), params_sh)
        from repro.train.optimizer import AdamWState

        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        opt_cfg = OptConfig(total_steps=1000)

        def train_step(params, opt, feats, coords, senders, receivers, mask, labels):
            def loss_fn(p):
                logits = a.node_logits(p, feats, coords, senders, receivers, mask)
                return _xent(logits, labels)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
            return params, opt, loss

        inputs = (
            params_sh, opt_sh,
            jax.ShapeDtypeStruct((NP, DF), jnp.float32),
            jax.ShapeDtypeStruct((NP, 3), jnp.float32),
            jax.ShapeDtypeStruct((EP,), jnp.int32),
            jax.ShapeDtypeStruct((EP,), jnp.int32),
            jax.ShapeDtypeStruct((EP,), jnp.bool_),
            jax.ShapeDtypeStruct((NP,), jnp.int32),
        )
        shardings = (
            p_specs, o_specs,
            P(flat, None), P(flat, None), P(flat), P(flat), P(flat), P(flat),
        )
        return train_step, inputs, named_shardings(mesh, shardings)

    return Cell(
        arch=a.arch_id, shape=shape_name, kind="train", build=build,
        model_flops=3.0 * a.fwd_flops(N, E2, DF),
    )


def _minibatch_cell(a: GNNArch) -> Cell:
    s = GNN_SHAPES["minibatch_lg"]
    N, E, DF, NO = s["n_nodes"], s["n_edges"], s["d_feat"], s["n_out"]
    B, fanout = s["batch_nodes"], s["fanout"]
    # sampled tree size: B + B·f1 + B·f1·f2 nodes, B·f1 + B·f1·f2 edges
    n_tree = B * (1 + fanout[0] + fanout[0] * fanout[1])
    e_tree = B * (fanout[0] + fanout[0] * fanout[1])

    def build(mesh: Mesh, variant: str = "memory"):
        params_sh = jax.eval_shape(lambda k: a.init(k, DF, NO), jax.random.key(0))
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        p_specs = jax.tree.map(lambda _: P(), params_sh)
        from repro.train.optimizer import AdamWState

        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        d = data_axes(mesh)
        opt_cfg = OptConfig(total_steps=1000)
        f1, f2 = fanout

        def train_step(params, opt, key, indptr, indices, feats_tab, coords_tab,
                       labels_tab, seeds):
            # --- neighbour sampling (on device, static shapes) -------------
            def sample(frontier, k):
                start = indptr[frontier]
                deg = indptr[frontier + 1] - start
                fan = f1 if frontier.ndim == 1 else f2
                u = jax.random.randint(
                    k, frontier.shape + (fan,),
                    0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
                offs = u % jnp.maximum(deg, 1)[..., None]
                nbr = indices[jnp.minimum(start[..., None] + offs, indices.shape[0] - 1)]
                m = jnp.broadcast_to(deg[..., None] > 0, nbr.shape)
                return jnp.where(m, nbr, 0), m

            k1, k2 = jax.random.split(key)
            l1, m1 = sample(seeds, k1)                    # (B, f1)
            l2, m2 = sample(l1, k2)                       # (B, f1, f2)
            m2 = m2 & m1[..., None]
            # --- flatten to tree edges ------------------------------------
            ids = jnp.concatenate([seeds, l1.reshape(-1), l2.reshape(-1)])
            off1, off2 = B, B + B * f1
            snd = jnp.concatenate([
                off1 + jnp.arange(B * f1, dtype=jnp.int32),
                off2 + jnp.arange(B * f1 * f2, dtype=jnp.int32),
            ])
            rcv = jnp.concatenate([
                jnp.repeat(jnp.arange(B, dtype=jnp.int32), f1),
                off1 + jnp.repeat(jnp.arange(B * f1, dtype=jnp.int32), f2),
            ])
            emask = jnp.concatenate([m1.reshape(-1), m2.reshape(-1)])
            feats = feats_tab[ids]
            coords = coords_tab[ids]

            def loss_fn(p):
                logits = a.node_logits(p, feats, coords, snd, rcv, emask)
                return _xent(logits[:B], labels_tab[seeds])

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
            return params, opt, loss

        NP, EP = _pad512(N + 1), _pad512(E)
        inputs = (
            params_sh, opt_sh,
            jax.ShapeDtypeStruct((2,), jnp.uint32),          # raw PRNG key
            jax.ShapeDtypeStruct((NP,), jnp.int32),
            jax.ShapeDtypeStruct((EP,), jnp.int32),
            jax.ShapeDtypeStruct((NP, DF), jnp.float32),
            jax.ShapeDtypeStruct((NP, 3), jnp.float32),
            jax.ShapeDtypeStruct((NP,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        )
        flat = tuple(mesh.axis_names)
        shardings = (
            p_specs, o_specs, P(),
            P(), P(flat), P(flat, None), P(flat, None), P(flat), P(d),
        )

        def step_with_key(params, opt, key_data, *rest):
            key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
            return train_step(params, opt, key, *rest)

        return step_with_key, inputs, named_shardings(mesh, shardings)

    return Cell(
        arch=a.arch_id, shape="minibatch_lg", kind="train", build=build,
        model_flops=3.0 * a.fwd_flops(n_tree, e_tree, DF),
        note="fixed-fanout 15×10 neighbour sampling on device",
    )


def _molecule_cell(a: GNNArch) -> Cell:
    s = GNN_SHAPES["molecule"]
    N, E, B, DF = s["n_nodes"], s["n_edges"], s["batch"], s["d_feat"]

    def build(mesh: Mesh, variant: str = "memory"):
        params_sh = jax.eval_shape(lambda k: a.init(k, DF, 1), jax.random.key(0))
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        p_specs = jax.tree.map(lambda _: P(), params_sh)
        from repro.train.optimizer import AdamWState

        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        d = data_axes(mesh)
        opt_cfg = OptConfig(total_steps=1000)

        def train_step(params, opt, feats, coords, senders, receivers, mask, energy):
            def loss_fn(p):
                e = jax.vmap(
                    lambda f, c, sd, rc, mk: a.graph_energy(p, f, c, sd, rc, mk)
                )(feats, coords, senders, receivers, mask)
                return jnp.mean((e - energy) ** 2)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
            return params, opt, loss

        inputs = (
            params_sh, opt_sh,
            jax.ShapeDtypeStruct((B, N, DF), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 3), jnp.float32),
            jax.ShapeDtypeStruct((B, E), jnp.int32),
            jax.ShapeDtypeStruct((B, E), jnp.int32),
            jax.ShapeDtypeStruct((B, E), jnp.bool_),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        )
        shardings = (
            p_specs, o_specs,
            P(d, None, None), P(d, None, None), P(d, None), P(d, None),
            P(d, None), P(d),
        )
        return train_step, inputs, named_shardings(mesh, shardings)

    return Cell(
        arch=a.arch_id, shape="molecule", kind="train", build=build,
        model_flops=3.0 * B * a.fwd_flops(N, E, DF),
    )


def gnn_cells(a: GNNArch) -> Dict[str, Cell]:
    return {
        "full_graph_sm": _full_graph_cell(a, "full_graph_sm"),
        "minibatch_lg": _minibatch_cell(a),
        "ogb_products": _full_graph_cell(a, "ogb_products"),
        "molecule": _molecule_cell(a),
    }


def gnn_smoke(a: GNNArch):
    """Reduced full-graph + molecule steps on CPU."""
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(120, avg_deg=5.0, seed=0)
    s = jnp.where(g.edge_mask, g.senders, 0)
    r = jnp.where(g.edge_mask, g.receivers, 0)
    feats = jax.random.normal(jax.random.key(0), (g.n_nodes, 8))
    coords = jax.random.normal(jax.random.key(1), (g.n_nodes, 3))
    labels = jax.random.randint(jax.random.key(2), (g.n_nodes,), 0, 4, dtype=jnp.int32)
    params = a.init(jax.random.key(3), 8, 4)
    logits = jax.jit(a.node_logits)(params, feats, coords, s, r, g.edge_mask)
    assert logits.shape == (g.n_nodes, 4)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: _xent(a.node_logits(p, feats, coords, s, r, g.edge_mask), labels)
    )(params)
    assert np.isfinite(float(loss))
    e = jax.jit(a.graph_energy)(params, feats, coords, s, r, g.edge_mask)
    assert np.isfinite(float(e))

"""nemotron-4-340b [arXiv:2402.16819]: 96L d=18432 96H (GQA kv=8)
d_ff=73728 vocab=256000, squared-ReLU, no gating."""
import jax.numpy as jnp

from repro.configs.common import ArchDef, lm_cells, lm_smoke, register
from repro.models.lm_config import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-340b",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73728, vocab=256000, act="relu2",
    rope_theta=10_000.0, dtype=jnp.bfloat16, loss_chunk=128,
)

SMOKE = LMConfig(
    name="nemotron-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=128, act="relu2",
    dtype=jnp.float32, attn_chunk=16, loss_chunk=16,
)

ARCH = register(ArchDef(
    arch_id="nemotron-4-340b", family="lm",
    cells=lm_cells("nemotron-4-340b", CONFIG),
    smoke=lambda: lm_smoke(SMOKE),
    config=CONFIG,
))

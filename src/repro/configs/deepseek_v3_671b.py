"""deepseek-v3-671b [arXiv:2412.19437]: 61L d=7168 128H, MLA
(q_lora=1536, kv_lora=512, nope=128, rope=64, v=128), MoE 1 shared + 256
routed top-8 (d_expert=2048), first 3 layers dense (d_ff=18432), MTP depth 1,
vocab 129280, sigmoid (aux-free-style) router."""
import jax.numpy as jnp

from repro.configs.common import ArchDef, lm_cells, lm_smoke, register
from repro.models.lm_config import LMConfig, MLAConfig, MoEConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=18432, vocab=129280, act="swiglu",
    n_dense_layers=3,
    moe=MoEConfig(
        n_experts=256, top_k=8, d_expert=2048, n_shared=1,
        router="sigmoid", capacity_factor=1.25,
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, d_nope=128, d_rope=64, d_v=128),
    mtp=True,
    rope_theta=10_000.0, dtype=jnp.bfloat16, loss_chunk=128,
)

SMOKE = LMConfig(
    name="deepseek-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=128, act="swiglu", n_dense_layers=1,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=48, n_shared=1, router="sigmoid"),
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, d_nope=16, d_rope=8, d_v=16),
    mtp=True,
    dtype=jnp.float32, attn_chunk=16, loss_chunk=16,
)

ARCH = register(ArchDef(
    arch_id="deepseek-v3-671b", family="lm",
    cells=lm_cells("deepseek-v3-671b", CONFIG),
    smoke=lambda: lm_smoke(SMOKE),
    config=CONFIG,
))

"""pna [arXiv:2004.05718]: 4 layers, d_hidden=75,
aggregators mean/max/min/std, scalers identity/amplification/attenuation."""
from repro.configs.common import ArchDef, register
from repro.configs.gnn_cells import GNNArch, gnn_cells, gnn_smoke
from repro.models.gnn.pna import pna_apply, pna_init

D_HIDDEN, N_LAYERS = 75, 4


def _init(key, d_in, n_out):
    return pna_init(key, d_in, d_hidden=D_HIDDEN, n_layers=N_LAYERS, n_out=n_out)


def _node_logits(params, feats, coords, s, r, mask):
    del coords
    _, logits = pna_apply(params, feats, s, r, mask)
    return logits


def _graph_energy(params, feats, coords, s, r, mask):
    return _node_logits(params, feats, coords, s, r, mask)[:, 0].sum()


def _fwd_flops(n, e, d_feat):
    d = d_feat
    f = 0.0
    for _ in range(N_LAYERS):
        f += 2.0 * e * (2 * d) * D_HIDDEN          # edge message MLP
        f += 4.0 * e * D_HIDDEN                    # 4 segment reductions
        f += 2.0 * n * (12 * D_HIDDEN + d) * D_HIDDEN  # mix layer
        d = D_HIDDEN
    return f


GNN = GNNArch("pna", _init, _node_logits, _graph_energy, _fwd_flops)
ARCH = register(ArchDef(
    arch_id="pna", family="gnn", cells=gnn_cells(GNN),
    smoke=lambda: gnn_smoke(GNN), config=GNN,
))

"""Cell machinery: every (architecture × input-shape) pair is a `Cell` that
knows how to build its step function, ShapeDtypeStruct inputs, and shardings
for any mesh.  launch/dryrun.py iterates cells; tests smoke the reduced
configs; benchmarks reuse the same builders.

A Cell's `build(mesh)` returns (fn, example_inputs, in_shardings) where
`example_inputs` is a tuple of ShapeDtypeStructs (NO allocation) and
`jax.jit(fn, in_shardings=...).lower(*example_inputs).compile()` is the
dry-run contract.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    batch_spec,
    cache_specs,
    data_axes,
    deepfm_specs,
    lm_param_specs,
)
from repro.models.lm_config import LMConfig
from repro.models import transformer as tf
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str                               # train | prefill | decode | serve
    build: Callable[..., Tuple[Callable, tuple, Any]]  # (mesh, variant=...)
    model_flops: float                      # analytic useful FLOPs per step
    note: str = ""
    skip_reason: Optional[str] = None       # e.g. long_500k on full attention
    # LM cells: cost passes compile unrolled REDUCED-depth models and the
    # runner extrapolates affinely in layer count (costs of a homogeneous
    # stack are exactly a + b·L; validated in EXPERIMENTS.md §Dry-run).
    extrapolate: Optional[dict] = None      # {"la": 2, "lb": 4, "lfull": L}


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                             # lm | gnn | recsys | mis
    cells: Dict[str, Cell]
    smoke: Callable[[], None]               # CPU-runnable reduced-config step
    config: Any = None


REGISTRY: Dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    REGISTRY[arch.arch_id] = arch
    return arch


def named_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# LM cell builders (shared by all five transformer archs)
# --------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _lm_param_shapes(cfg: LMConfig):
    return jax.eval_shape(lambda k: tf.init_lm(k, cfg), jax.random.key(0))


def _dryrun_cfg(
    cfg: LMConfig, mesh: Mesh, *, unroll: bool, seq: int = 4096
) -> LMConfig:
    """Dry-run variant (two-pass methodology, EXPERIMENTS.md §Dry-run):

    * variant='cost'   -> unroll=True: XLA cost_analysis counts loop bodies
      ONCE, so rolled scans undercount flops/bytes/collectives by the trip
      count; the cost pass must unroll.  Inner chunk sizes are raised so the
      unrolled HLO stays compilable (flash/xent FLOPs are chunk-invariant).
    * variant='memory' -> unroll=False: the rolled program is what actually
      runs (loop buffers reused); its memory_analysis is the fits-on-chip
      evidence.
    """
    moe = None
    if cfg.moe:
        model_size = dict(mesh.shape).get("model", 1)
        dp = tuple(data_axes(mesh))
        if cfg.moe.n_experts % max(model_size, 1) == 0:
            # expert parallel on 'model', capacity sharded over pod×data
            buf_pspec = ("model", dp, None)
        else:
            # few big experts (Mixtral): DP over capacity, D kept local so the
            # expert GEMM contracts without gathering (TP lives in the F dim
            # of the expert weights)
            buf_pspec = (None, dp, None)
        moe = dataclasses.replace(cfg.moe, buf_pspec=buf_pspec)
    kw = {}
    if unroll:
        kw = dict(
            attn_chunk=max(cfg.attn_chunk, seq // 8),
            loss_chunk=max(cfg.loss_chunk, seq // 8),
        )
    return dataclasses.replace(
        cfg, unroll=unroll, dp_axes=tuple(data_axes(mesh)), moe=moe, **kw
    )


def _needs_fsdp(cfg: LMConfig, mesh: Mesh) -> bool:
    """Model-parallel-only weights must fit a 16 GB v5e with headroom;
    otherwise shard params over pod×data too (ZeRO-3/FSDP)."""
    model_size = dict(mesh.shape).get("model", 1)
    bytes_per_dev = cfg.param_count() * 2 / max(model_size, 1)
    return bytes_per_dev > 6e9


def _with_stack_layers(cfg: LMConfig, k: int) -> LMConfig:
    """Reduce the scanned stack to k layers (dense archs: k total; MoE
    archs: n_dense_layers kept + k MoE layers)."""
    if cfg.moe is not None:
        return dataclasses.replace(cfg, n_layers=cfg.n_dense_layers + k)
    return dataclasses.replace(cfg, n_layers=k)


def _lm_stack_size(cfg: LMConfig) -> int:
    return (cfg.n_layers - cfg.n_dense_layers) if cfg.moe else cfg.n_layers


def _lm_extrapolate(cfg: LMConfig) -> dict:
    return {"la": 2, "lb": 4, "lfull": _lm_stack_size(cfg)}


def lm_train_flops(cfg: LMConfig, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (fwd 2ND + bwd 4ND)."""
    return 6.0 * cfg.active_param_count() * batch * seq


def lm_decode_flops(cfg: LMConfig, batch: int, cache: int) -> float:
    """Per decode step: 2·N_active per token + attention reads over cache."""
    n = cfg.active_param_count()
    if cfg.mla is not None:
        attn = cfg.n_layers * cfg.n_heads * cache * 2 * (
            cfg.mla.kv_lora_rank + cfg.mla.d_rope + cfg.mla.kv_lora_rank
        )
    else:
        attn = cfg.n_layers * cfg.n_heads * cache * 2 * 2 * cfg.d_head
    return batch * (2.0 * n + attn)


def make_lm_train_step(cfg: LMConfig, opt_cfg: OptConfig):
    def train_step(params, opt_state, tokens, targets):
        (loss, metrics), grads = jax.value_and_grad(
            tf.lm_loss, has_aux=True
        )(params, cfg, tokens, targets)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, loss, metrics["xent"]

    return train_step


def _lm_train_cell(arch_id: str, cfg: LMConfig, shape_name: str) -> Cell:
    s = LM_SHAPES[shape_name]
    B, S = s["global_batch"], s["seq_len"]

    def build(mesh: Mesh, variant: str = "memory"):
        if variant == "memory":
            rcfg = _dryrun_cfg(cfg, mesh, unroll=False, seq=S)
        else:
            k = 2 if variant == "cost_a" else 4
            rcfg = _dryrun_cfg(
                _with_stack_layers(cfg, k), mesh, unroll=True, seq=S
            )
        params_sh = _lm_param_shapes(rcfg)
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        p_specs = lm_param_specs(params_sh, mesh, fsdp=_needs_fsdp(cfg, mesh))
        # ZeRO-1: optimizer moments additionally sharded over pod×data
        from repro.train.optimizer import AdamWState, zero1_specs
        from repro.dist.sharding import _axis_size

        dp = data_axes(mesh)
        m_specs = zero1_specs(
            p_specs, params_sh, mesh_axis=dp, mesh_size=_axis_size(mesh, dp)
        )
        opt_specs = AdamWState(step=P(), m=m_specs, v=m_specs)
        tok_spec = batch_spec(mesh, extra_dims=1)
        fn = make_lm_train_step(rcfg, OptConfig(total_steps=10000))
        inputs = (
            params_sh,
            opt_sh,
            jax.ShapeDtypeStruct((B, S), jnp.int32),
            jax.ShapeDtypeStruct((B, S), jnp.int32),
        )
        in_shardings = (p_specs, opt_specs, tok_spec, tok_spec)
        return fn, inputs, named_shardings(mesh, in_shardings)

    return Cell(
        arch=arch_id, shape=shape_name, kind="train", build=build,
        model_flops=lm_train_flops(cfg, B, S),
        extrapolate=_lm_extrapolate(cfg),
    )


def _lm_prefill_cell(arch_id: str, cfg: LMConfig, shape_name: str) -> Cell:
    s = LM_SHAPES[shape_name]
    B, S = s["global_batch"], s["seq_len"]

    def build(mesh: Mesh, variant: str = "memory"):
        if variant == "memory":
            rcfg = _dryrun_cfg(cfg, mesh, unroll=False, seq=S)
        else:
            k = 2 if variant == "cost_a" else 4
            rcfg = _dryrun_cfg(
                _with_stack_layers(cfg, k), mesh, unroll=True, seq=S
            )
        params_sh = _lm_param_shapes(rcfg)
        p_specs = lm_param_specs(params_sh, mesh, fsdp=_needs_fsdp(cfg, mesh))
        tok_spec = batch_spec(mesh, extra_dims=1)

        def prefill_step(params, tokens):
            logits, cache = tf.prefill(params, rcfg, tokens, max_len=S)
            return logits, cache

        inputs = (params_sh, jax.ShapeDtypeStruct((B, S), jnp.int32))
        return prefill_step, inputs, named_shardings(mesh, (p_specs, tok_spec))

    # prefill ~ forward only: 2·N·D
    return Cell(
        arch=arch_id, shape=shape_name, kind="prefill", build=build,
        model_flops=lm_train_flops(cfg, B, S) / 3.0,
        extrapolate=_lm_extrapolate(cfg),
    )


def _lm_decode_cell(
    arch_id: str, cfg: LMConfig, shape_name: str, skip_reason=None
) -> Cell:
    s = LM_SHAPES[shape_name]
    B, S = s["global_batch"], s["seq_len"]

    def build(mesh: Mesh, variant: str = "memory"):
        if variant == "memory":
            rcfg = _dryrun_cfg(cfg, mesh, unroll=False, seq=S)
        else:
            k = 2 if variant == "cost_a" else 4
            rcfg = _dryrun_cfg(
                _with_stack_layers(cfg, k), mesh, unroll=True, seq=S
            )
        params_sh = _lm_param_shapes(rcfg)
        p_specs = lm_param_specs(params_sh, mesh, fsdp=_needs_fsdp(cfg, mesh))
        cache_sh = jax.eval_shape(
            lambda: tf.init_decode_cache(rcfg, B, S)
        )
        c_specs = cache_specs(rcfg, mesh, B, cache_sh.length)
        c_specs = tf.DecodeCache(
            data=c_specs.data, pos=P(), length=cache_sh.length
        )

        def serve_step(params, cache, tokens):
            return tf.decode_step(params, rcfg, cache, tokens)

        inputs = (params_sh, cache_sh, jax.ShapeDtypeStruct((B,), jnp.int32))
        tok_spec = P(data_axes(mesh)) if B % np.prod(
            [mesh.shape[a] for a in data_axes(mesh)]
        ) == 0 else P()
        shardings = (p_specs, c_specs, tok_spec)
        return serve_step, inputs, named_shardings(mesh, shardings)

    return Cell(
        arch=arch_id, shape=shape_name, kind="decode", build=build,
        model_flops=lm_decode_flops(cfg, B, min(S, cfg.window or S)),
        skip_reason=skip_reason,
        extrapolate=_lm_extrapolate(cfg),
    )


def lm_cells(arch_id: str, cfg: LMConfig) -> Dict[str, Cell]:
    full_attention = cfg.window is None
    return {
        "train_4k": _lm_train_cell(arch_id, cfg, "train_4k"),
        "prefill_32k": _lm_prefill_cell(arch_id, cfg, "prefill_32k"),
        "decode_32k": _lm_decode_cell(arch_id, cfg, "decode_32k"),
        "long_500k": _lm_decode_cell(
            arch_id, cfg, "long_500k",
            skip_reason=(
                "full-attention arch: 500k-token decode requires sub-quadratic "
                "attention structure (DESIGN.md §8)" if full_attention else None
            ),
        ),
    }


def lm_smoke(cfg_small: LMConfig):
    """One CPU train step on the reduced config; asserts shapes + finiteness."""
    import numpy as np

    params = tf.init_lm(jax.random.key(0), cfg_small)
    opt = adamw_init(params)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg_small.vocab, dtype=jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    step = jax.jit(make_lm_train_step(cfg_small, OptConfig(total_steps=100)))
    params2, opt2, loss, xent = step(params, opt, tokens, targets)
    assert np.isfinite(float(loss)), f"loss not finite: {loss}"
    assert jax.tree.structure(params2) == jax.tree.structure(params)
    # decode path
    logits, cache = jax.jit(
        lambda p, t: tf.prefill(p, cfg_small, t, max_len=S + 4)
    )(params2, tokens)
    logits2, _ = jax.jit(
        lambda p, c, t: tf.decode_step(p, cfg_small, c, t)
    )(params2, cache, tokens[:, -1])
    assert logits2.shape == (B, cfg_small.vocab)
    assert np.all(np.isfinite(np.asarray(logits2)))

"""deepfm [arXiv:1703.04247]: 39 sparse fields (13 binned numerics + 26
categoricals, Criteo-style vocabulary skew, ~33.8M total rows), embed_dim=10,
MLP 400-400-400, FM interaction.

Shapes: train_batch 65 536 / serve_p99 512 / serve_bulk 262 144 /
retrieval_cand 1×1 000 000 (single matvec over candidate rows)."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.common import ArchDef, Cell, named_shardings, register
from repro.dist.sharding import batch_spec, data_axes, deepfm_specs
from repro.models.deepfm import (
    DeepFMConfig,
    deepfm_init,
    deepfm_logits,
    deepfm_loss,
    retrieval_score,
)
from repro.train.optimizer import AdamWState, OptConfig, adamw_init, adamw_update

# Criteo-style skewed vocabularies (sum ≈ 33.8M, padded per-field to /16)
_CAT = [10_000_000, 8_000_000, 5_000_000, 4_000_000, 2_000_000, 1_500_000,
        1_000_000, 800_000, 500_000, 400_000, 300_000, 200_000, 100_000,
        50_000, 20_000, 10_000, 5_000, 2_000, 1_000, 500, 200, 100, 100,
        100, 50, 16]
FIELD_VOCABS = tuple([64] * 13 + [(v + 15) // 16 * 16 for v in _CAT])
assert len(FIELD_VOCABS) == 39

CONFIG = DeepFMConfig(field_vocabs=FIELD_VOCABS, embed_dim=10,
                      mlp_dims=(400, 400, 400))
SMOKE_CONFIG = DeepFMConfig(field_vocabs=tuple([32] * 39), embed_dim=10,
                            mlp_dims=(64, 64))

SHAPES = {
    "train_batch": dict(batch=65_536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262_144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="serve"),
}


def _fwd_flops(cfg: DeepFMConfig, batch: int) -> float:
    d = cfg.n_fields * cfg.embed_dim
    f = 2.0 * batch * cfg.n_fields * cfg.embed_dim    # FM term
    for o in cfg.mlp_dims + (1,):
        f += 2.0 * batch * d * o
        d = o
    return f


def _params_shapes():
    return jax.eval_shape(lambda k: deepfm_init(k, CONFIG), jax.random.key(0))


def _train_cell() -> Cell:
    B = SHAPES["train_batch"]["batch"]

    def build(mesh: Mesh, variant: str = "memory"):
        params_sh = _params_shapes()
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        p_specs = deepfm_specs(params_sh, mesh)
        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        opt_cfg = OptConfig(total_steps=10000)

        def train_step(params, opt, fields, labels):
            loss, grads = jax.value_and_grad(
                lambda p: deepfm_loss(p, CONFIG, fields, labels)
            )(params)
            params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
            return params, opt, loss

        inputs = (
            params_sh, opt_sh,
            jax.ShapeDtypeStruct((B, 39), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        )
        shardings = (p_specs, o_specs, batch_spec(mesh, 1), P(data_axes(mesh)))
        return train_step, inputs, named_shardings(mesh, shardings)

    return Cell(arch="deepfm", shape="train_batch", kind="train", build=build,
                model_flops=3.0 * _fwd_flops(CONFIG, B))


def _serve_cell(shape_name: str) -> Cell:
    B = SHAPES[shape_name]["batch"]

    def build(mesh: Mesh, variant: str = "memory"):
        params_sh = _params_shapes()
        p_specs = deepfm_specs(params_sh, mesh)

        def serve_step(params, fields):
            return deepfm_logits(params, CONFIG, fields)

        inputs = (params_sh, jax.ShapeDtypeStruct((B, 39), jnp.int32))
        return serve_step, inputs, named_shardings(
            mesh, (p_specs, batch_spec(mesh, 1))
        )

    return Cell(arch="deepfm", shape=shape_name, kind="serve", build=build,
                model_flops=_fwd_flops(CONFIG, B))


def _retrieval_cell() -> Cell:
    # padded to a 512 multiple so the sweep shards over every mesh size
    NC = -(-SHAPES["retrieval_cand"]["n_candidates"] // 512) * 512

    def build(mesh: Mesh, variant: str = "memory"):
        params_sh = _params_shapes()
        p_specs = deepfm_specs(params_sh, mesh)
        flat = tuple(mesh.axis_names)

        def serve_step(params, user_fields, cand_ids):
            return retrieval_score(params, CONFIG, user_fields, cand_ids)

        inputs = (
            params_sh,
            jax.ShapeDtypeStruct((39,), jnp.int32),
            jax.ShapeDtypeStruct((NC,), jnp.int32),
        )
        return serve_step, inputs, named_shardings(
            mesh, (p_specs, P(), P(flat))
        )

    return Cell(arch="deepfm", shape="retrieval_cand", kind="serve", build=build,
                model_flops=2.0 * NC * CONFIG.embed_dim,
                note="1 user × 1M candidates, factorised FM matvec")


def _smoke():
    params = deepfm_init(jax.random.key(0), SMOKE_CONFIG)
    fields = jax.random.randint(jax.random.key(1), (16, 39), 0, 32, dtype=jnp.int32)
    labels = (jax.random.uniform(jax.random.key(2), (16,)) > 0.5).astype(jnp.float32)
    loss, grads = jax.value_and_grad(
        lambda p: deepfm_loss(p, SMOKE_CONFIG, fields, labels)
    )(params)
    assert np.isfinite(float(loss))
    sc = retrieval_score(params, SMOKE_CONFIG, fields[0], jnp.arange(32, dtype=jnp.int32))
    assert sc.shape == (32,) and bool(jnp.all(jnp.isfinite(sc)))


ARCH = register(ArchDef(
    arch_id="deepfm", family="recsys",
    cells={
        "train_batch": _train_cell(),
        "serve_p99": _serve_cell("serve_p99"),
        "serve_bulk": _serve_cell("serve_bulk"),
        "retrieval_cand": _retrieval_cell(),
    },
    smoke=_smoke,
    config=CONFIG,
))

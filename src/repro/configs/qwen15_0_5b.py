"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: 24L d=1024 16H (GQA kv=16 ≡ MHA)
d_ff=2816 vocab=151936, QKV bias, SwiGLU, RoPE."""
import jax.numpy as jnp

from repro.configs.common import ArchDef, lm_cells, lm_smoke, register
from repro.models.lm_config import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-0.5b",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=2816, vocab=151936, qkv_bias=True, act="swiglu",
    rope_theta=10_000.0, dtype=jnp.bfloat16, loss_chunk=512,
)

SMOKE = LMConfig(
    name="qwen1.5-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=128, qkv_bias=True, act="swiglu",
    dtype=jnp.float32, attn_chunk=16, loss_chunk=16,
)

ARCH = register(ArchDef(
    arch_id="qwen1.5-0.5b", family="lm",
    cells=lm_cells("qwen1.5-0.5b", CONFIG),
    smoke=lambda: lm_smoke(SMOKE),
    config=CONFIG,
))

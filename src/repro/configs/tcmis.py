"""tcmis — the paper's own configuration: distributed TC-MIS over the eight
SuiteSparse graphs of Table 1, at full |V|/|E| scale (dry-run shapes).

Tile-count estimation: full-scale graphs are never materialised; the BSR
size is extrapolated from the *measured* block occupancy of the structurally
matched reduced-scale stand-in:  n_tiles ≈ ratio · min(E, nb²), where ratio
is measured at build time (cached).  Tile size is chosen per graph as the
largest T ∈ {128, 64, 32, 16} whose estimated BSR fits a per-chip budget —
this is the paper's §3.2 memory/regularity trade-off made explicit: hub-less
meshes (road, delaunay) take full 128×128 MXU tiles, hub-heavy power-law
graphs (wiki-Talk, kron) fall back to smaller tiles exactly as the paper's
16×16 WMMA does.  The chosen T is recorded in the dry-run JSON and the
roofline table (§Perf hillclimbs the choice).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.api.plan import DEFAULT_TILE_BUDGET, fit_tile_size
from repro.configs.common import ArchDef, Cell, named_shardings, register
from repro.core.distributed import DistConfig, make_mis_step_fn
from repro.core.tiling import build_block_tiles
from repro.graphs.generators import GRAPH_SUITE

# Table 1 edge counts (stored/directed), used for full-scale extrapolation.
TABLE1_E = {
    "G1": 2_350_000, "G2": 2_930_000, "G3": 3_000_000, "G4": 9_540_000,
    "G5": 9_700_000, "G6": 14_440_000, "G7": 68_990_000, "G8": 182_080_000,
}

# 512 MiB of BSR payload per chip — the shared auto-T budget (repro.api.plan)
PER_CHIP_TILE_BUDGET = DEFAULT_TILE_BUDGET
DRYRUN_LANES = 8                      # lanes carrying data (C, alive, spares)


RCM = False  # set True to estimate with RCM locality reordering (§Perf H-A)


@lru_cache(maxsize=None)
def _occupancy_ratio(paper_id: str, tile_size: int, rcm: bool = False) -> float:
    """Measured block occupancy of the reduced-scale stand-in."""
    g = GRAPH_SUITE[paper_id].reduced(seed=0)
    t = build_block_tiles(g, tile_size=tile_size,
                          reorder="rcm" if rcm else None)
    nb = t.n_block_rows
    return t.n_tiles / max(min(g.n_edges, nb * nb), 1)


def estimate_tiles(paper_id: str, tile_size: int) -> int:
    spec = GRAPH_SUITE[paper_id]
    nb = -(-spec.n_full // tile_size)
    e_dir = TABLE1_E[paper_id]
    return int(_occupancy_ratio(paper_id, tile_size, RCM) * min(e_dir, nb * nb)) + 1


def choose_tile_size(paper_id: str, n_chips: int) -> int:
    """Largest MXU-friendly T whose estimated BSR fits the per-chip budget.

    Same `fit_tile_size` loop as the API's default auto-T policy
    (`repro.api.plan.choose_tile_size`), driven by the measured block
    occupancy of the reduced-scale stand-in instead of the worst-case bound.
    """
    return fit_tile_size(
        lambda T: estimate_tiles(paper_id, T) * T * T / n_chips,
        budget=PER_CHIP_TILE_BUDGET,
    )


def _mis_cell(paper_id: str) -> Cell:
    spec = GRAPH_SUITE[paper_id]

    def build(mesh: Mesh, variant: str = "memory"):
        n_chips = int(np.prod(list(mesh.shape.values())))
        T = choose_tile_size(paper_id, n_chips)
        est_tiles = estimate_tiles(paper_id, T)
        nb = -(-spec.n_full // T)
        rps = -(-nb // n_chips)
        # per-shard tile budget with 15% imbalance headroom, lane-aligned
        nt_pad = (int(est_tiles / n_chips * 1.15) + 8) // 8 * 8

        fn = make_mis_step_fn(
            mesh, DistConfig(bitpack=True, lanes=DRYRUN_LANES),
            n_nodes=spec.n_full, tile_size=T, rows_per_shard=rps,
            two_pass=True,                       # H3 (the paper's default)
        )
        n_padded = n_chips * rps * T
        inputs = (
            jax.ShapeDtypeStruct((n_chips, nt_pad, T, T), jnp.int8),
            jax.ShapeDtypeStruct((n_chips, nt_pad), jnp.int32),
            jax.ShapeDtypeStruct((n_chips, nt_pad), jnp.int32),
            jax.ShapeDtypeStruct((n_padded,), jnp.int32),
            jax.ShapeDtypeStruct((n_padded,), jnp.int32),
        )
        flat = tuple(mesh.axis_names)
        shardings = (
            P(flat, None, None, None), P(flat, None), P(flat, None), P(), P(),
        )
        return fn, inputs, named_shardings(mesh, shardings)

    # PER-ROUND useful work: one SpMV (2E MACs) + one neighbour-max (E cmp).
    # The while-loop body is counted once by cost_analysis, so the roofline
    # for MIS cells is per-round by construction — model_flops matches.
    e_dir = TABLE1_E[paper_id]
    return Cell(
        arch="tcmis", shape=paper_id, kind="mis", build=build,
        model_flops=3.0 * e_dir,
        note=f"{spec.name}: |V|={spec.n_full:,} |E|={e_dir:,}",
    )


def _smoke():
    """Reduced-scale end-to-end TC-MIS on CPU, through the `Solver` front
    door: the oracle engine plus the production fused engine must return
    the same valid set."""
    import numpy as np

    from repro.api import Plan, Solver, SolveOptions
    from repro.core import is_valid_mis
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(500, avg_deg=6.0, seed=0)
    plan = Plan.build(g, tile_size=32)   # one plan serves both engines
    ref = Solver(SolveOptions(heuristic="h3", engine="tiled_ref")).solve(plan)
    assert ref.converged
    assert is_valid_mis(g, jnp.asarray(ref.in_mis))
    fused = Solver(SolveOptions(heuristic="h3", engine="fused_pallas")).solve(plan)
    assert bool(np.all(fused.in_mis == ref.in_mis))


ARCH = register(ArchDef(
    arch_id="tcmis", family="mis",
    cells={gid: _mis_cell(gid) for gid in GRAPH_SUITE},
    smoke=_smoke,
    config=None,
))

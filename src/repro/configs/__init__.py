"""Architecture registry: importing this package registers every assigned
arch (and the paper's own tcmis suite) into `REGISTRY`.

  from repro.configs import REGISTRY
  REGISTRY["qwen3-0.6b"].cells["train_4k"].build(mesh)
"""
from repro.configs.common import REGISTRY, ArchDef, Cell

# importing each module registers its ArchDef
from repro.configs import (  # noqa: F401
    qwen15_0_5b,
    qwen3_0_6b,
    nemotron4_340b,
    mixtral_8x22b,
    deepseek_v3_671b,
    egnn,
    gin_tu,
    pna,
    mace,
    deepfm,
    tcmis,
)

ASSIGNED_ARCHS = [
    "qwen1.5-0.5b", "qwen3-0.6b", "nemotron-4-340b", "mixtral-8x22b",
    "deepseek-v3-671b", "egnn", "gin-tu", "pna", "mace", "deepfm",
]

__all__ = ["REGISTRY", "ArchDef", "Cell", "ASSIGNED_ARCHS"]

"""mace [arXiv:2206.07697]: 2 layers, 128 channels, l_max=2, correlation
order 3, n_rbf=8, E(3)-ACE product basis."""
from repro.configs.common import ArchDef, register
from repro.configs.gnn_cells import GNNArch, gnn_cells, gnn_smoke
from repro.models.gnn.common import mlp_apply
from repro.models.gnn.mace import coupling_tensors, mace_apply, mace_init

CHANNELS, N_LAYERS, N_RBF = 128, 2, 8


def _init(key, d_in, n_out):
    params = mace_init(key, d_in, channels=CHANNELS, n_layers=N_LAYERS, n_rbf=N_RBF)
    if n_out != 1:
        # classification head replaces the scalar energy readout
        from repro.models.gnn.common import mlp_init
        import jax

        params["readout"] = mlp_init(
            jax.random.fold_in(key, 99), (CHANNELS, 16, n_out)
        )
    return params


def _node_logits(params, feats, coords, s, r, mask):
    h, _ = mace_apply(params, feats, coords, s, r, mask, n_rbf=N_RBF)
    return mlp_apply(params["readout"], h[0][:, 0, :])


def _graph_energy(params, feats, coords, s, r, mask):
    _, energy = mace_apply(params, feats, coords, s, r, mask, n_rbf=N_RBF)
    return energy


def _fwd_flops(n, e, d_feat):
    cts = coupling_tensors()
    path_flops = sum(
        2.0 * (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1) for l1, l2, l3, _ in cts
    )
    f = 2.0 * n * d_feat * CHANNELS
    for _ in range(N_LAYERS):
        f += 2.0 * e * (N_RBF * 64 + 64 * len(cts) * CHANNELS)   # radial MLP
        f += e * path_flops * CHANNELS                           # interaction
        f += 2.0 * n * path_flops * CHANNELS                     # B2 + B3
        f += 2.0 * n * 9 * 3 * CHANNELS * CHANNELS               # mixes (Σ_l (2l+1)·3C·C)
    return f


GNN = GNNArch("mace", _init, _node_logits, _graph_energy, _fwd_flops)
ARCH = register(ArchDef(
    arch_id="mace", family="gnn", cells=gnn_cells(GNN),
    smoke=lambda: gnn_smoke(GNN), config=GNN,
))

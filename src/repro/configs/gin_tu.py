"""gin-tu [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator,
learnable ε.  Sum aggregation = A × H, so this arch additionally exposes the
paper's tiled-SpMM backend (exercised in tests + the fig4 benchmark)."""
from functools import partial

from repro.configs.common import ArchDef, register
from repro.configs.gnn_cells import GNNArch, gnn_cells, gnn_smoke
from repro.models.gnn.gin import gin_apply, gin_init

D_HIDDEN, N_LAYERS = 64, 5


def _init(key, d_in, n_out):
    return gin_init(key, d_in, d_hidden=D_HIDDEN, n_layers=N_LAYERS, n_out=n_out)


def _node_logits(params, feats, coords, s, r, mask):
    del coords
    _, logits = gin_apply(params, feats, s, r, mask)
    return logits


def _graph_energy(params, feats, coords, s, r, mask):
    return _node_logits(params, feats, coords, s, r, mask)[:, 0].sum()


def _fwd_flops(n, e, d_feat):
    f = 2.0 * e * d_feat + 2.0 * n * (d_feat * D_HIDDEN + D_HIDDEN * D_HIDDEN)
    f += (N_LAYERS - 1) * (
        2.0 * e * D_HIDDEN + 4.0 * n * D_HIDDEN * D_HIDDEN
    )
    return f


GNN = GNNArch("gin-tu", _init, _node_logits, _graph_energy, _fwd_flops)
ARCH = register(ArchDef(
    arch_id="gin-tu", family="gnn", cells=gnn_cells(GNN),
    smoke=lambda: gnn_smoke(GNN), config=GNN,
))

"""qwen3-0.6b [hf:Qwen/Qwen3-0.6B family]: 28L d=1024 16H (GQA kv=8)
d_ff=3072 vocab=151936, qk-norm, head_dim=128, SwiGLU."""
import jax.numpy as jnp

from repro.configs.common import ArchDef, lm_cells, lm_smoke, register
from repro.models.lm_config import LMConfig

CONFIG = LMConfig(
    name="qwen3-0.6b",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=3072, vocab=151936, qk_norm=True, act="swiglu",
    rope_theta=1_000_000.0, dtype=jnp.bfloat16, loss_chunk=512,
)

SMOKE = LMConfig(
    name="qwen3-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=128, vocab=128, qk_norm=True, act="swiglu",
    dtype=jnp.float32, attn_chunk=16, loss_chunk=16,
)

ARCH = register(ArchDef(
    arch_id="qwen3-0.6b", family="lm",
    cells=lm_cells("qwen3-0.6b", CONFIG),
    smoke=lambda: lm_smoke(SMOKE),
    config=CONFIG,
))

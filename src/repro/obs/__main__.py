"""`python -m repro.obs report <trace.jsonl>` — see report.py."""
import sys

from .report import main

sys.exit(main())

"""`python -m repro.obs report <trace.jsonl>` renders telemetry;
`python -m repro.obs bench-diff <base> <head>` gates perf regressions —
see report.py / bench.py."""
import sys

from .report import main

sys.exit(main())
